"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python tools/make_experiments.py > /tmp/sections.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.report import fmt_si, fmt_time, markdown_table  # noqa: E402


def load(mesh):
    recs = {}
    for f in sorted(glob.glob(f"artifacts/dryrun/{mesh}/*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"])] = r
    return recs


def dryrun_section():
    single = load("pod16x16")
    multi = load("pod2x16x16")
    headers = ["arch", "shape", "16x16 compile", "mem/dev", "collectives (per-dev bytes by kind)",
               "2x16x16 compile", "mem/dev"]
    rows = []
    for key in sorted(single):
        r = single[key]
        m = multi.get(key, {})
        if r["status"] == "skip":
            rows.append([key[0], key[1], "SKIP", "—", r["reason"], "SKIP", "—"])
            continue
        kinds = r.get("collective_bytes_by_kind", {})
        chips = r.get("chips", 256)
        kinds_s = ", ".join(f"{k}:{fmt_si(v/chips, 'B')}" for k, v in
                            sorted(kinds.items(), key=lambda kv: -kv[1])) or "—"
        rows.append([
            key[0], key[1],
            "OK" if r["status"] == "ok" else r["status"].upper(),
            f"{r.get('peak_memory_per_device', 0)/2**30:.2f}GiB",
            kinds_s,
            "OK" if m.get("status") == "ok" else m.get("status", "—").upper(),
            (f"{m.get('peak_memory_per_device', 0)/2**30:.2f}GiB"
             if m.get("status") == "ok" else "—"),
        ])
    return markdown_table(headers, rows)


def roofline_section():
    single = load("pod16x16")
    headers = ["arch", "shape", "t_compute", "t_memory", "t_collective", "t_step",
               "dominant", "MODEL_FLOPS", "useful ratio", "roofline frac"]
    rows = []
    for key in sorted(single):
        r = single[key]
        if r["status"] != "ok":
            continue
        rows.append([
            key[0], key[1],
            fmt_time(r["t_compute"]), fmt_time(r["t_memory"]),
            fmt_time(r["t_collective"]), fmt_time(r["t_step"]), r["dominant"],
            fmt_si(r.get("model_flops"), "F"),
            f"{r['useful_flops_ratio']:.3f}" if r.get("useful_flops_ratio") else "—",
            f"{(r.get('roofline_fraction') or 0)*100:.2f}%",
        ])
    return markdown_table(headers, rows)


def perf_section():
    """Baseline vs optimized per-cell table."""
    base = load("pod16x16")
    opt = {}
    for f in glob.glob("artifacts/dryrun_opt/pod16x16/*.json"):
        r = json.load(open(f))
        opt[(r["arch"], r["shape"])] = r
    headers = ["arch", "shape", "t_step base", "t_step opt", "speedup",
               "useful base→opt", "roofline frac base→opt", "mem/dev base→opt"]
    rows = []
    for key in sorted(base):
        b, o = base[key], opt.get(key)
        if b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        su = b["t_step"] / o["t_step"] if o["t_step"] else float("nan")
        rows.append([
            key[0], key[1], fmt_time(b["t_step"]), fmt_time(o["t_step"]),
            f"{su:.2f}x",
            f"{b.get('useful_flops_ratio') or 0:.3f}→{o.get('useful_flops_ratio') or 0:.3f}",
            (f"{(b.get('roofline_fraction') or 0)*100:.3f}%"
             f"→{(o.get('roofline_fraction') or 0)*100:.3f}%"),
            f"{b['peak_memory_per_device']/2**30:.1f}→{o['peak_memory_per_device']/2**30:.1f}GiB",
        ])
    return markdown_table(headers, rows)


def inject():
    path = "EXPERIMENTS.md"
    text = open(path).read()

    def repl(tag, content):
        nonlocal text
        b, e = f"<!-- BEGIN GENERATED {tag} -->", f"<!-- END GENERATED {tag} -->"
        i, j = text.index(b), text.index(e)
        text = text[: i + len(b)] + "\n" + content + "\n" + text[j:]

    repl("DRYRUN", dryrun_section())
    repl("ROOFLINE", roofline_section())
    try:
        repl("PERF", perf_section())
    except Exception as ex:
        print(f"(perf table skipped: {ex})", file=sys.stderr)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    if "--inject" in sys.argv:
        inject()
    else:
        print("## §Dry-run\n")
        print(dryrun_section())
        print("\n## §Roofline\n")
        print(roofline_section())
