"""Benchmark regression gate: compare a freshly produced benchmark JSON
(``BENCH_fleet.json`` or ``BENCH_tuner.json``) against the committed baseline
and fail when SLO attainment drops, $/hr rises, or a headline invariant
breaks. The benchmark kind is read off the file's ``"benchmark"`` field.

The benchmarks are fully seeded, so fresh and baseline numbers are expected
to match almost exactly; the tolerances only absorb float/platform drift.

Fleet gate (``benchmark == "fleet_scaling"``):

* every baseline record (policy, discipline, trace, shapes) still exists,
  its ``slo_attainment`` has not dropped more than ``--attain-tol`` (absolute)
  and its ``usd_per_hour`` has not risen more than ``--cost-tol`` (relative);
* the tiered-SLA sweep still finds a feasible fleet per discipline, no
  costlier than baseline beyond tolerance, meeting the attainment bar;
* the headline invariant holds: EDF or strict priority meets the tiered SLOs
  at strictly lower cost than FIFO.

Tuner gate (``benchmark == "controller_tuning"``):

* the headline invariant holds: the tuned predictive policy dominates the
  hand-set default (attainment >= at <= the cost, one strict) on the
  flash-crowd scenario, and no worse than the baseline beyond tolerance;
* the controller response surface keeps r2 >= 0.8 over the surviving region;
* racing spends <= 40% of the naive sweep budget and returns the same winner
  as the exhaustive grid sweep;
* the joint-optimum case holds: on the tiered-SLA scenario the joint
  (discipline x n_replicas) optimum differs from the config greedy
  per-dimension search assembles, and scores strictly better — scoping
  dimensions one at a time provably overpays;
* tuner wall clock stays within ``--wall-mult`` (2x) of the baseline.

Simulator-backend gate (``benchmark == "sim_perf"``):

* the compiled (JAX) batched candidate evaluation beats the sequential
  numpy loop by >= 5x warm on the headline flash-crowd tuning round —
  unless the JAX path is already under the absolute wall-clock grace floor
  (both too fast to time meaningfully);
* the backends agree: per-seed scores within tolerance, same winner;
* the sub-bin (fine-Δt) core keeps the >= 5x compiled speedup on its own
  preemptive n_substeps=4 cell, and its numpy/jax engines return *exactly*
  equal candidate scores (max score delta 0);
* the fidelity section's physics holds at the >= 90%-utilization operating
  point: the coarse bin-granular core understates p99 vs the fine core, and
  preemptive EDF meets the gold SLO bar at strictly lower $/hr than
  non-preemptive FIFO;
* telemetry stays cheap: the headline round with a telemetry session active
  runs <= 5% slower than with telemetry off — unless the absolute slowdown
  is under the timing-noise grace floor.

Closed-loop control gate (``benchmark == "closed_loop_control"``):

* the incumbent config really breaks under the injected service drift
  (post-drift worst-class attainment below the bar), and the controller
  both alarms and hot-swaps a re-tuned policy mid-trace;
* the closed loop recovers: post-swap worst-class attainment >= the bar
  (0.95), no worse than the baseline beyond ``--attain-tol``;
* it recovers cheaper than the cheapest bar-restoring static fleet, and
  its $/hr has not risen past ``--cost-tol`` vs the baseline;
* the warm re-tune is backend-exact: numpy and jax return the same winner
  with scores within tolerance (reported, not gated, where jax is absent);
* drift detection has not slowed by more than one control segment.

Scoping-oracle gate (``benchmark == "scoping_oracle"``):

* the oracle answers in <= 1 ms median query latency (featurization
  included);
* on the held-out flash-crowd trace the oracle's config simulates within
  10% regret of a fresh ``tune()`` at the same attainment bar, and meets
  the bar itself;
* the offline build amortizes: total sweep simulations <= one fresh-tune
  equivalent per grid cell (racing must pay for the table);
* the spot-check verifier passes: no refusals inside the hull, cost
  prediction error within its bound;
* the closed loop with ``oracle=`` recovers from the headline drift case
  no later than warm re-tune alone — and when it swaps at the same
  segment, no costlier — while spending a fraction of the re-tune's
  simulations; numpy and jax agree on the held-out evaluation.

Portfolio gate (``benchmark == "portfolio_tuning"``):

* the 4-trace x >= 512-candidate evaluation round runs as one compiled
  dispatch per candidate tile (exactly ``n_tiles`` dispatches, all warm on
  the measured round, one cold after a cache flush) and beats the
  sequential per-trace numpy path by >= 5x on per-trajectory throughput
  (with the wall-clock grace floor);
* robustness dominance: the portfolio winner's worst-trace score is no
  worse than EVERY single-trace winner's worst-trace score, and no worse
  than the baseline's beyond tolerance;
* numpy and jax agree on the robust score bit-for-bit (delta 0) and on
  the round winner;
* the warm persistent-compile-cache rebuild registers disk hits and spends
  measurably less cold-dispatch wall-clock than the cold build (unless the
  cold build is already under the grace floor);
* headline wall clock stays within ``--wall-mult`` of the baseline.

Usage (CI runs exactly this):

    python tools/check_bench.py BENCH_fleet.json \\
        --baseline benchmarks/baselines/fleet.json
    python tools/check_bench.py BENCH_tuner.json \\
        --baseline benchmarks/baselines/tuner.json
    python tools/check_bench.py BENCH_sim.json \\
        --baseline benchmarks/baselines/sim.json
    python tools/check_bench.py BENCH_control.json \\
        --baseline benchmarks/baselines/control.json
    python tools/check_bench.py BENCH_oracle.json \\
        --baseline benchmarks/baselines/oracle.json
    python tools/check_bench.py BENCH_portfolio.json \\
        --baseline benchmarks/baselines/portfolio.json

After an intentional perf/cost change, refresh the baseline with
``--write-baseline`` and commit the result.
"""
from __future__ import annotations

import argparse
import json
import sys

RECORD_KEY = ("policy", "discipline", "trace", "shapes")
VOLATILE = ("wall_clock_s", "total_wall_clock_s")


def _key(rec: dict) -> tuple:
    return tuple(rec.get(k) for k in RECORD_KEY)


def _normalize(bench: dict) -> dict:
    """Strip wall-clock timings (machine-dependent) before writing/storing."""
    out = {k: v for k, v in bench.items() if k not in VOLATILE}
    out["records"] = [{k: v for k, v in rec.items() if k not in VOLATILE}
                      for rec in bench.get("records", [])]
    return out


def compare(fresh: dict, base: dict, attain_tol: float,
            cost_tol: float) -> list:
    """Return a list of human-readable regression strings (empty = green)."""
    problems = []
    fresh_by_key = {_key(r): r for r in fresh.get("records", [])}
    for brec in base.get("records", []):
        k = _key(brec)
        frec = fresh_by_key.get(k)
        label = "/".join(str(x) for x in k)
        if frec is None:
            problems.append(f"missing record: {label} (present in baseline)")
            continue
        da = brec["slo_attainment"] - frec["slo_attainment"]
        if da > attain_tol:
            problems.append(
                f"{label}: SLO attainment dropped "
                f"{brec['slo_attainment']:.4f} -> "
                f"{frec['slo_attainment']:.4f} (tol {attain_tol})")
        floor = max(brec["usd_per_hour"], 1e-9)
        if frec["usd_per_hour"] > floor * (1.0 + cost_tol):
            problems.append(
                f"{label}: $/hr rose {brec['usd_per_hour']:.2f} -> "
                f"{frec['usd_per_hour']:.2f} (tol {cost_tol * 100:.0f}%)")

    btier = base.get("tiered_sla", {})
    ftier = fresh.get("tiered_sla", {})
    bar = btier.get("attainment_bar", 0.99)
    bcheap = btier.get("cheapest_feasible", {})
    fcheap = ftier.get("cheapest_feasible", {})
    for disc, brec in bcheap.items():
        frec = fcheap.get(disc)
        if frec is None:
            problems.append(f"tiered-sla: no feasible {disc} fleet anymore "
                            f"(baseline: {brec['replicas']} replicas)")
            continue
        if frec["worst_class_attainment"] < bar - attain_tol:
            problems.append(
                f"tiered-sla/{disc}: worst class attainment "
                f"{frec['worst_class_attainment']:.4f} below the "
                f"{bar:.2f} bar")
        if frec["usd_per_hour"] > brec["usd_per_hour"] * (1.0 + cost_tol):
            problems.append(
                f"tiered-sla/{disc}: cheapest feasible $/hr rose "
                f"{brec['usd_per_hour']:.2f} -> {frec['usd_per_hour']:.2f} "
                f"(tol {cost_tol * 100:.0f}%)")
    # the headline result this PR pins: a deadline-aware discipline beats
    # capacity-equivalent FIFO on cost while meeting every tier's SLO
    if {"fifo", "edf", "priority"} <= set(fcheap):
        fifo_usd = fcheap["fifo"]["usd_per_hour"]
        best = min(fcheap["edf"]["usd_per_hour"],
                   fcheap["priority"]["usd_per_hour"])
        if not best < fifo_usd:
            problems.append(
                "tiered-sla: EDF/priority no longer beat FIFO on cost "
                f"(fifo ${fifo_usd:.2f}/hr, best deadline-aware "
                f"${best:.2f}/hr)")
    elif bcheap:
        problems.append("tiered-sla: fresh results missing a discipline "
                        f"(have {sorted(fcheap)})")
    return problems


MIN_SURFACE_R2 = 0.8            # trustworthy-fit bar (ISSUE 4 acceptance)
MAX_BUDGET_FRAC = 0.4           # racing must beat 40% of the naive sweep


WALL_FLOOR_S = 30.0             # grace floor: CI runners are slower than the
#                                 dev machines baselines get recorded on; only
#                                 flag wall clock when it exceeds BOTH 2x the
#                                 baseline AND this absolute floor


def compare_tuner(fresh: dict, base: dict, attain_tol: float,
                  cost_tol: float, wall_mult: float) -> list:
    """Regression strings for a controller-tuning benchmark (empty=green)."""
    problems = []
    head = fresh.get("headline", {})
    tuned, default = head.get("tuned"), head.get("default")
    if not tuned or not default:
        return [f"tuner: headline missing (have {sorted(head)})"]
    if not head.get("tuned_dominates_default"):
        problems.append(
            "tuner: tuned policy no longer dominates the hand-set default "
            f"(tuned ${tuned['usd_per_hour']:.2f}/hr @ "
            f"{tuned['worst_class_attainment']:.4f}, default "
            f"${default['usd_per_hour']:.2f}/hr @ "
            f"{default['worst_class_attainment']:.4f})")
    r2 = fresh.get("surface_r2")
    if r2 is None or not r2 >= MIN_SURFACE_R2:
        problems.append(f"tuner: controller surface r2 {r2} below "
                        f"{MIN_SURFACE_R2} — the fit is not trustworthy")
    frac = fresh.get("budget", {}).get("frac")
    if frac is None or not frac <= MAX_BUDGET_FRAC:
        problems.append(f"tuner: racing spent {frac} of the naive sweep "
                        f"budget (bar {MAX_BUDGET_FRAC})")
    rve = fresh.get("race_vs_exhaustive", {})
    if not rve.get("same_winner"):
        problems.append(
            "tuner: racing and the exhaustive grid sweep disagree on the "
            f"winner ({rve.get('race_winner')} vs "
            f"{rve.get('exhaustive_winner')})")
    gfrac = rve.get("race_frac")
    if gfrac is None or not gfrac <= MAX_BUDGET_FRAC:
        problems.append(
            f"tuner: the grid race spent {gfrac} of the exhaustive sweep "
            f"budget (bar {MAX_BUDGET_FRAC}) — the <= 40%-with-same-winner "
            "invariant must hold on one and the same race")
    btuned = base.get("headline", {}).get("tuned")
    if btuned:
        da = btuned["worst_class_attainment"] - tuned["worst_class_attainment"]
        if da > attain_tol:
            problems.append(
                f"tuner: tuned attainment dropped "
                f"{btuned['worst_class_attainment']:.4f} -> "
                f"{tuned['worst_class_attainment']:.4f} (tol {attain_tol})")
        floor = max(btuned["usd_per_hour"], 1e-9)
        if tuned["usd_per_hour"] > floor * (1.0 + cost_tol):
            problems.append(
                f"tuner: tuned $/hr rose {btuned['usd_per_hour']:.2f} -> "
                f"{tuned['usd_per_hour']:.2f} (tol {cost_tol * 100:.0f}%)")
    bwall = base.get("tuner_wall_clock_s")
    fwall = fresh.get("tuner_wall_clock_s")
    if bwall and fwall and fwall > max(wall_mult * bwall, WALL_FLOOR_S):
        problems.append(
            f"tuner: wall clock regressed {bwall:.1f}s -> {fwall:.1f}s "
            f"(> {wall_mult:g}x baseline and > {WALL_FLOOR_S:g}s floor)")
    jo = fresh.get("joint_optimum")
    if jo is None:
        problems.append("tuner: joint_optimum section missing — "
                        "tune_controller.py should run the tiered-SLA "
                        "greedy-vs-joint case")
    else:
        joint, greedy = jo.get("joint"), jo.get("greedy")
        if not joint or not greedy:
            problems.append(f"tuner: joint_optimum incomplete "
                            f"(have {sorted(jo)})")
        else:
            if joint["params"] == greedy["params"]:
                problems.append(
                    "tuner: greedy per-dim search found the joint optimum "
                    f"({joint['params']}) — the scenario no longer "
                    "demonstrates cross-dimension coupling")
            if not joint["score"] < greedy["score"]:
                problems.append(
                    "tuner: joint optimum no longer strictly beats the "
                    f"greedy per-dim config (joint {joint['score']:.2f} vs "
                    f"greedy {greedy['score']:.2f})")
    return problems


MIN_SIM_SPEEDUP = 5.0           # compiled path vs numpy loop (ISSUE 5)
SIM_WALL_FLOOR_S = 0.5          # grace floor: below this the JAX wall clock
#                                 is timing noise, not a regression signal
SIM_SCORE_TOL = 1e-6            # backend-agreement bar on per-seed scores
MAX_TELEMETRY_OVERHEAD = 0.05   # telemetry-on <= 5% slower (ISSUE 6)
TELEMETRY_FLOOR_S = 0.2         # ...unless the absolute slowdown is under
#                                 this (relative % on a fast round is noise)
FIDELITY_MIN_UTIL = 0.9         # the fidelity claims are pinned to a
#                                 high-utilization operating point (ISSUE 7)


def compare_sim(fresh: dict, base: dict) -> list:
    """Regression strings for a simulator-backend benchmark (empty=green).
    The speedup bar is an invariant of the fresh run (machine-relative, so
    no baseline arithmetic); the baseline pins which grid cells must keep
    existing."""
    problems = []
    head = fresh.get("headline", {})
    speedup = head.get("speedup")
    jax_s = head.get("jax_warm_s")
    if speedup is None or jax_s is None:
        return [f"sim: headline missing (have {sorted(head)})"]
    if speedup < MIN_SIM_SPEEDUP and jax_s > SIM_WALL_FLOOR_S:
        problems.append(
            f"sim: compiled path only {speedup:.1f}x the numpy loop on the "
            f"headline round ({head.get('grid')}) — bar {MIN_SIM_SPEEDUP}x "
            f"(jax {jax_s:.3f}s > {SIM_WALL_FLOOR_S}s grace floor)")
    agree = fresh.get("agreement", {})
    delta = agree.get("max_score_delta")
    if delta is None or not delta <= SIM_SCORE_TOL:
        problems.append(f"sim: backends disagree — max per-seed score delta "
                        f"{delta} (tol {SIM_SCORE_TOL})")
    if not agree.get("same_winner"):
        problems.append("sim: backends disagree on the round winner")
    ov = fresh.get("telemetry_overhead")
    if ov is None:
        problems.append("sim: telemetry_overhead section missing — "
                        "sim_perf.py should measure on-vs-off wall clock")
    else:
        off, on = ov.get("disabled_s"), ov.get("enabled_s")
        if off is None or on is None:
            problems.append("sim: telemetry_overhead incomplete "
                            f"(have {sorted(ov)})")
        elif (on > off * (1.0 + MAX_TELEMETRY_OVERHEAD)
              and on - off > TELEMETRY_FLOOR_S):
            problems.append(
                f"sim: telemetry session costs "
                f"{(on / off - 1.0) * 100:.1f}% on the {ov.get('grid')} "
                f"round ({off:.2f}s off vs {on:.2f}s on) — bar "
                f"{MAX_TELEMETRY_OVERHEAD * 100:.0f}% "
                f"(slowdown {on - off:.2f}s > {TELEMETRY_FLOOR_S}s "
                "grace floor)")
    problems += _sim_substep_problems(fresh)
    problems += _sim_fidelity_problems(fresh)
    # n_substeps is part of the cell identity: the fine-core cell reuses the
    # coarse grid dims and would otherwise collide with its n=1 twin
    fresh_cells = {(r["n_candidates"], r["n_seeds"], r["n_bins"],
                    r.get("n_substeps", 1))
                   for r in fresh.get("records", [])}
    for brec in base.get("records", []):
        cell = (brec["n_candidates"], brec["n_seeds"], brec["n_bins"],
                brec.get("n_substeps", 1))
        if cell not in fresh_cells:
            problems.append(f"sim: missing grid cell {cell} "
                            "(present in baseline)")
    return problems


def _sim_substep_problems(fresh: dict) -> list:
    """The fine-Δt core's own bars: compiled speedup and exact backend
    score agreement on the preemptive substep cell."""
    sub = fresh.get("substep_headline")
    if sub is None:
        return ["sim: substep_headline missing — sim_perf.py should bench "
                "the preemptive fine-core cell"]
    problems = []
    speedup, jax_s = sub.get("speedup"), sub.get("jax_warm_s")
    if speedup is None or jax_s is None:
        return [f"sim: substep_headline incomplete (have {sorted(sub)})"]
    if speedup < MIN_SIM_SPEEDUP and jax_s > SIM_WALL_FLOOR_S:
        problems.append(
            f"sim: fine core only {speedup:.1f}x the numpy loop on the "
            f"{sub.get('grid')} substep cell — bar {MIN_SIM_SPEEDUP}x "
            f"(jax {jax_s:.3f}s > {SIM_WALL_FLOOR_S}s grace floor)")
    delta = sub.get("max_score_delta")
    if delta != 0.0:
        problems.append(f"sim: fine-core backends not exactly equal — max "
                        f"candidate score delta {delta} (bar: 0.0)")
    return problems


def _sim_fidelity_problems(fresh: dict) -> list:
    """Fidelity physics at the high-utilization operating point: coarse
    understates the tail, preemption buys the gold SLO cheaper than
    replicas, and the fine core's backends are bit-exact."""
    fid = fresh.get("fidelity")
    if fid is None:
        return ["sim: fidelity section missing — sim_perf.py should run "
                "the coarse-vs-fine high-utilization comparison"]
    problems = []
    hu = fid.get("high_util", {})
    util = hu.get("utilization")
    coarse, fine = hu.get("coarse_p99_s"), hu.get("fine_p99_s")
    if util is None or coarse is None or fine is None:
        problems.append(f"sim: fidelity high_util incomplete "
                        f"(have {sorted(hu)})")
    else:
        if util < FIDELITY_MIN_UTIL:
            problems.append(
                f"sim: fidelity operating point at {util:.2f} utilization — "
                f"the claims are only meaningful >= {FIDELITY_MIN_UTIL}")
        if not fine > coarse:
            problems.append(
                f"sim: coarse core no longer understates p99 at high "
                f"utilization (coarse {coarse:.2f}s vs fine {fine:.2f}s)")
    hl = fid.get("headline", {})
    edf, fifo = hl.get("edf_preemptive"), hl.get("fifo")
    bar = fid.get("gold_bar")
    if not edf or not fifo or bar is None:
        problems.append("sim: fidelity headline incomplete — need the "
                        "cheapest gold-bar fleet for preemptive EDF and "
                        "non-preemptive FIFO")
    else:
        if edf["gold_attainment"] < bar:
            problems.append(
                f"sim: preemptive EDF misses the gold bar "
                f"({edf['gold_attainment']:.3f} < {bar})")
        if not edf["usd_per_hour"] < fifo["usd_per_hour"]:
            problems.append(
                f"sim: preemptive EDF no longer meets the gold SLO cheaper "
                f"than FIFO (${edf['usd_per_hour']:.2f}/h vs "
                f"${fifo['usd_per_hour']:.2f}/h)")
    agree = fid.get("agreement", {})
    if agree.get("error"):
        pass   # no jax in this environment: reported, not gated
    elif not agree.get("bit_exact") or agree.get("max_field_delta") != 0.0:
        problems.append(
            f"sim: fine core numpy vs jax not bit-exact at the operating "
            f"point — max field delta {agree.get('max_field_delta')}")
    return problems


CONTROL_SCORE_TOL = 1e-6        # backend-agreement bar on the re-tune score


def compare_control(fresh: dict, base: dict, attain_tol: float,
                    cost_tol: float) -> list:
    """Regression strings for a closed-loop control benchmark (empty=green).

    The headline bars are invariants of the fresh run: the incumbent must
    break under the injected drift, the closed loop must detect it and
    recover worst-class attainment over the bar at a lower $/hr than the
    cheapest bar-restoring static fleet, and the warm re-tune must agree
    across simulator backends. The baseline pins recovery attainment and
    cost against silent erosion."""
    head = fresh.get("headline", {})
    needed = ("attainment_bar", "incumbent_breaks", "recovered",
              "recovery_attainment", "closed_loop_usd_per_hour",
              "static_usd_per_hour", "cheaper_than_static")
    if any(head.get(k) is None for k in needed):
        return [f"control: headline incomplete (have {sorted(head)})"]
    problems = []
    bar = head["attainment_bar"]
    cl = fresh.get("closed_loop", {})
    if not head["incumbent_breaks"]:
        inc_post = fresh.get("incumbent", {}).get("post_drift", {})
        problems.append(
            "control: the incumbent no longer breaks under the injected "
            "drift — the scenario demonstrates nothing (post-drift "
            f"attainment {inc_post.get('worst_class_attainment')})")
    if not cl.get("n_alarms", 0) >= 1:
        problems.append("control: the probe never alarmed on the drifted "
                        "trace — detection is broken")
    if not cl.get("n_swaps", 0) >= 1:
        problems.append("control: the controller never hot-swapped a "
                        "re-tuned policy — actuation is broken")
    if not (head["recovered"] and head["recovery_attainment"] >= bar):
        problems.append(
            f"control: closed loop failed to recover — post-swap "
            f"worst-class attainment {head['recovery_attainment']:.4f} "
            f"< bar {bar}")
    if not head["cheaper_than_static"]:
        problems.append(
            f"control: closed loop no longer cheaper than the static "
            f"recovery (${head['closed_loop_usd_per_hour']:.2f}/hr vs "
            f"${head['static_usd_per_hour']}/hr)")
    agree = fresh.get("agreement", {})
    if agree.get("error"):
        pass   # no jax in this environment: reported, not gated
    else:
        if not agree.get("same_winner"):
            problems.append(
                "control: numpy and jax disagree on the warm re-tune winner "
                f"({agree.get('numpy_winner')} vs {agree.get('jax_winner')})")
        delta = agree.get("max_score_delta")
        if delta is None or not delta <= CONTROL_SCORE_TOL:
            problems.append(
                f"control: backends disagree on the re-tune score — delta "
                f"{delta} (tol {CONTROL_SCORE_TOL})")
    bhead = base.get("headline", {})
    if bhead.get("recovery_attainment") is not None:
        da = bhead["recovery_attainment"] - head["recovery_attainment"]
        if da > attain_tol:
            problems.append(
                f"control: recovery attainment dropped "
                f"{bhead['recovery_attainment']:.4f} -> "
                f"{head['recovery_attainment']:.4f} (tol {attain_tol})")
    if bhead.get("closed_loop_usd_per_hour"):
        floor = max(bhead["closed_loop_usd_per_hour"], 1e-9)
        if head["closed_loop_usd_per_hour"] > floor * (1.0 + cost_tol):
            problems.append(
                f"control: closed-loop $/hr rose "
                f"{bhead['closed_loop_usd_per_hour']:.2f} -> "
                f"{head['closed_loop_usd_per_hour']:.2f} "
                f"(tol {cost_tol * 100:.0f}%)")
    bdelay = base.get("closed_loop", {}).get("detection_delay_bins")
    fdelay = cl.get("detection_delay_bins")
    seg = fresh.get("drift", {}).get("segment_bins", 0)
    if bdelay is not None and (fdelay is None or fdelay > bdelay + seg):
        problems.append(
            f"control: drift detection slowed — {bdelay} -> {fdelay} bins "
            f"(tol one segment = {seg} bins)")
    return problems


ORACLE_MAX_MEDIAN_LATENCY_US = 1000.0   # <= 1 ms median query (ISSUE 9)
ORACLE_MAX_REGRET = 0.10                # held-out score within 10% of tune()
ORACLE_MAX_TUNE_EQUIV_PER_CELL = 1.0    # build amortization bar
ORACLE_MAX_VERIFY_COST_ERR = 0.25       # spot-check |prediction| error: the
                                        # interpolated point cost between
                                        # cells with unlike winners skews
                                        # conservative (over-predicts)
ORACLE_MAX_VERIFY_OVERRUN = 0.05        # simulated cost vs answered bound —
                                        # the direction that mis-scopes
ORACLE_SCORE_TOL = 1e-6                 # backend-agreement bar
ORACLE_CL_COST_TOL = 0.10               # same-segment recovery cost slack
                                        # (mirrors the 10% regret bar: the
                                        # consult picks from ~5 precomputed
                                        # configs, not a fresh sweep)


def compare_oracle(fresh: dict, base: dict, attain_tol: float,
                   cost_tol: float) -> list:
    """Regression strings for a scoping-oracle benchmark (empty=green).

    The latency, regret, amortization, verifier and closed-loop bars are
    invariants of the fresh run; the baseline pins the held-out answer's
    cost and attainment against silent erosion."""
    problems = []
    lat = fresh.get("latency", {})
    med = lat.get("median_us")
    if med is None:
        problems.append("oracle: latency section missing")
    elif not med <= ORACLE_MAX_MEDIAN_LATENCY_US:
        problems.append(
            f"oracle: median query latency {med:.0f}us over the "
            f"{ORACLE_MAX_MEDIAN_LATENCY_US:.0f}us bar — no longer a "
            "constant-time lookup")
    ho = fresh.get("heldout", {})
    orc, fr = ho.get("oracle"), ho.get("fresh")
    bar = ho.get("attainment_bar")
    if not orc or not fr or bar is None:
        problems.append(f"oracle: heldout section incomplete "
                        f"(have {sorted(ho)})")
    else:
        if orc["attainment"] < bar:
            problems.append(
                f"oracle: held-out answer misses the attainment bar "
                f"({orc['attainment']:.4f} < {bar})")
        regret = ho.get("regret")
        if regret is None or not regret <= ORACLE_MAX_REGRET:
            problems.append(
                f"oracle: held-out regret {regret} vs fresh tune() over the "
                f"{ORACLE_MAX_REGRET * 100:.0f}% bar (oracle score "
                f"{orc.get('score')}, tune score {fr.get('score')})")
    build = fresh.get("build", {})
    teq, ncells = build.get("tune_equivalents"), build.get("n_cells")
    if teq is None or ncells is None:
        problems.append("oracle: build section incomplete "
                        f"(have {sorted(build)})")
    elif not teq <= ncells * ORACLE_MAX_TUNE_EQUIV_PER_CELL:
        problems.append(
            f"oracle: build spent {teq:.1f} fresh-tune equivalents for "
            f"{ncells} cells (bar {ORACLE_MAX_TUNE_EQUIV_PER_CELL:g} per "
            "cell) — the sweep no longer amortizes")
    ver = fresh.get("verify", {})
    if not ver.get("n", 0) >= 1:
        problems.append("oracle: verifier ran no spot-checks")
    else:
        if ver.get("refused", 0) != 0:
            problems.append(
                f"oracle: verifier hit {ver['refused']} refusal(s) inside "
                "the gridded region — the hull check is broken")
        err = ver.get("max_cost_err")
        if err is None or not err <= ORACLE_MAX_VERIFY_COST_ERR:
            problems.append(
                f"oracle: verifier max cost error {err} over the "
                f"{ORACLE_MAX_VERIFY_COST_ERR * 100:.0f}% bound")
        over = ver.get("max_cost_overrun")
        if over is None or not over <= ORACLE_MAX_VERIFY_OVERRUN:
            problems.append(
                f"oracle: simulated cost busts the answered bound by "
                f"{over} (tol {ORACLE_MAX_VERIFY_OVERRUN * 100:.0f}%) — "
                "the oracle under-promises capacity")
    problems += _oracle_closed_loop_problems(fresh)
    agree = fresh.get("agreement", {})
    if agree.get("error"):
        pass   # no jax in this environment: reported, not gated
    else:
        delta = agree.get("max_score_delta")
        if delta is None or not delta <= ORACLE_SCORE_TOL:
            problems.append(
                f"oracle: backends disagree on the held-out evaluation — "
                f"max score delta {delta} (tol {ORACLE_SCORE_TOL})")
    bho = base.get("heldout", {}).get("oracle")
    if bho and orc:
        da = bho["attainment"] - orc["attainment"]
        if da > attain_tol:
            problems.append(
                f"oracle: held-out attainment dropped "
                f"{bho['attainment']:.4f} -> {orc['attainment']:.4f} "
                f"(tol {attain_tol})")
        floor = max(bho["cost_usd_hr"], 1e-9)
        if orc["cost_usd_hr"] > floor * (1.0 + cost_tol):
            problems.append(
                f"oracle: held-out $/hr rose {bho['cost_usd_hr']:.2f} -> "
                f"{orc['cost_usd_hr']:.2f} (tol {cost_tol * 100:.0f}%)")
    return problems


def _oracle_closed_loop_problems(fresh: dict) -> list:
    """The oracle-vs-retune drift-recovery bars: never later, and when
    swapping at the same segment boundary, not meaningfully costlier."""
    cl = fresh.get("closed_loop", {})
    orc, rt = cl.get("oracle"), cl.get("retune")
    bar = cl.get("attainment_bar")
    if not orc or not rt or bar is None:
        return [f"oracle: closed_loop section incomplete (have "
                f"{sorted(cl)})"]
    problems = []
    if not orc.get("hits", 0) >= 1:
        problems.append(
            "oracle: the controller's drift consultation never hit — the "
            f"closed loop fell back to re-tune ({orc.get('misses', 0)} "
            "miss(es))")
    ob, rb = orc.get("swap_bin"), rt.get("swap_bin")
    if ob is None or rb is None:
        problems.append(
            f"oracle: a closed-loop arm never swapped (oracle bin {ob}, "
            f"retune bin {rb})")
    else:
        if ob > rb:
            problems.append(
                f"oracle: oracle-assisted recovery swapped LATER than warm "
                f"re-tune (bin {ob} vs {rb})")
        if (ob == rb and orc["post_drift_usd_per_hour"]
                > rt["post_drift_usd_per_hour"]
                * (1.0 + ORACLE_CL_COST_TOL)):
            problems.append(
                f"oracle: same-segment recovery costs more than re-tune "
                f"(${orc['post_drift_usd_per_hour']:.2f}/hr vs "
                f"${rt['post_drift_usd_per_hour']:.2f}/hr, tol "
                f"{ORACLE_CL_COST_TOL * 100:.0f}%)")
    if orc.get("recovery_attainment", 0.0) < bar:
        problems.append(
            f"oracle: oracle-assisted recovery misses the bar "
            f"({orc.get('recovery_attainment'):.4f} < {bar})")
    osims, rsims = orc.get("consult_sims"), rt.get("tune_sims")
    if osims is None or rsims is None or not osims < rsims:
        problems.append(
            f"oracle: consultation no longer cheaper than re-tune "
            f"({osims} vs {rsims} candidate-replicates)")
    return problems


PORTFOLIO_SCORE_TOL = 0.0       # robust-score agreement is exact: the trace
#                                 reduction runs host-side on both backends
PCACHE_FLOOR_S = 0.5            # grace floor: a cold build compiling for
#                                 less than this can't show a measurable
#                                 warm-cache saving above timing noise


def _portfolio_dispatch_problems(head: dict) -> list:
    """The one-dispatch-per-tile invariant: a >= 512-candidate x 4-trace
    round is exactly ``n_tiles`` compiled dispatches — 1 cold + warm
    repeats after a cache flush, all warm once compiled — never a
    per-trace or per-candidate Python loop."""
    problems = []
    n_tiles = head.get("n_tiles")
    cold = head.get("cold_round_dispatches") or []
    warm = head.get("warm_round_dispatches") or []
    if not n_tiles or not cold or not warm:
        return [f"portfolio: dispatch accounting missing "
                f"(have {sorted(head)})"]
    if len(warm) != n_tiles or any(d["kind"] != "warm" for d in warm):
        problems.append(
            f"portfolio: measured round is not one warm dispatch per tile "
            f"({len(warm)} dispatches for {n_tiles} tiles, kinds "
            f"{[d['kind'] for d in warm]})")
    n_cold = sum(1 for d in cold if d["kind"] == "cold")
    if len(cold) != n_tiles or n_cold != 1:
        problems.append(
            f"portfolio: post-flush round should compile once and reuse "
            f"({len(cold)} dispatches, {n_cold} cold, for {n_tiles} tiles)")
    return problems


def compare_portfolio(fresh: dict, base: dict, attain_tol: float,
                      cost_tol: float, wall_mult: float) -> list:
    """Regression strings for a portfolio-tuning benchmark (empty=green).

    The speedup, dispatch-accounting, dominance, agreement and compile-cache
    bars are invariants of the fresh run; the baseline pins the portfolio
    winner's worst-trace score/attainment and the warm wall clock against
    silent erosion."""
    if fresh.get("error"):
        return [f"portfolio: benchmark did not run ({fresh['error']})"]
    problems = []
    head = fresh.get("headline", {})
    speedup, jax_s = head.get("speedup"), head.get("jax_warm_s")
    if speedup is None or jax_s is None:
        return [f"portfolio: headline missing (have {sorted(head)})"]
    if speedup < MIN_SIM_SPEEDUP and jax_s > SIM_WALL_FLOOR_S:
        problems.append(
            f"portfolio: tiled compiled round only {speedup:.1f}x the "
            f"sequential numpy path ({head.get('n_candidates')} cands x "
            f"{head.get('n_traces')} traces x {head.get('n_seeds')} seeds) "
            f"— bar {MIN_SIM_SPEEDUP}x (jax {jax_s:.3f}s > "
            f"{SIM_WALL_FLOOR_S}s grace floor)")
    problems += _portfolio_dispatch_problems(head)
    sub_delta = head.get("subset_max_score_delta")
    if sub_delta is None or not sub_delta <= PORTFOLIO_SCORE_TOL:
        problems.append(
            f"portfolio: numpy subset disagrees with the tiled round — max "
            f"robust score delta {sub_delta} (bar {PORTFOLIO_SCORE_TOL})")

    rob = fresh.get("robustness", {})
    pw = rob.get("portfolio_winner", {})
    singles = rob.get("single_trace_winners", [])
    if not pw or not singles:
        problems.append(f"portfolio: robustness section incomplete "
                        f"(have {sorted(rob)})")
    else:
        if not rob.get("portfolio_dominates"):
            worst = max(singles, key=lambda r: -r["worst_trace_score"])
            problems.append(
                "portfolio: the robustness headline broke — portfolio "
                f"winner's worst-trace score ${pw.get('worst_trace_score'):.2f} "
                "is beaten by the single-trace winner tuned on "
                f"{worst['tuned_on']} (${worst['worst_trace_score']:.2f})")
        bpw = base.get("robustness", {}).get("portfolio_winner", {})
        if bpw.get("worst_trace_score") is not None:
            floor = max(bpw["worst_trace_score"], 1e-9)
            if pw["worst_trace_score"] > floor * (1.0 + cost_tol):
                problems.append(
                    f"portfolio: winner's worst-trace score rose "
                    f"{bpw['worst_trace_score']:.2f} -> "
                    f"{pw['worst_trace_score']:.2f} "
                    f"(tol {cost_tol * 100:.0f}%)")
        if bpw.get("worst_trace_attainment") is not None:
            da = (bpw["worst_trace_attainment"]
                  - pw.get("worst_trace_attainment", 0.0))
            if da > attain_tol:
                problems.append(
                    f"portfolio: winner's worst-trace attainment dropped "
                    f"{bpw['worst_trace_attainment']:.4f} -> "
                    f"{pw.get('worst_trace_attainment'):.4f} "
                    f"(tol {attain_tol})")

    agree = fresh.get("agreement", {})
    delta = agree.get("max_robust_score_delta")
    if delta is None or not delta <= PORTFOLIO_SCORE_TOL:
        problems.append(
            f"portfolio: backends disagree on the robust score — max delta "
            f"{delta} (bar {PORTFOLIO_SCORE_TOL}: the trace reduction is "
            "host-side numpy on both paths)")
    if not agree.get("same_winner"):
        problems.append(
            "portfolio: backends disagree on the round winner "
            f"({agree.get('numpy_winner')} vs {agree.get('jax_winner')})")

    cache = fresh.get("compile_cache", {})
    coldb, warmb = cache.get("cold_build", {}), cache.get("warm_build", {})
    if not coldb or not warmb:
        problems.append(f"portfolio: compile_cache section incomplete "
                        f"(have {sorted(cache)})")
    else:
        if not coldb.get("disk_misses", 0) >= 1:
            problems.append(
                "portfolio: cold build registered no persistent-cache disk "
                "misses — the on-disk cache is not wired")
        if not warmb.get("disk_hits", 0) >= 1:
            problems.append(
                "portfolio: warm rebuild registered no persistent-cache "
                f"disk hits ({warmb.get('disk_misses', 0)} miss(es)) — "
                "the rebuild recompiled from scratch")
        cold_s = coldb.get("cold_dispatch_s", 0.0)
        warm_s = warmb.get("cold_dispatch_s", 0.0)
        if cold_s > PCACHE_FLOOR_S and not warm_s < cold_s:
            problems.append(
                f"portfolio: warm-cache rebuild not faster than the cold "
                f"build ({warm_s:.2f}s vs {cold_s:.2f}s cold-dispatch "
                f"wall; floor {PCACHE_FLOOR_S}s)")
        if cache.get("max_score_delta") != 0.0:
            problems.append(
                "portfolio: cache-deserialized executables disagree with "
                f"freshly compiled ones — max score delta "
                f"{cache.get('max_score_delta')}")

    bwall = base.get("headline", {}).get("jax_warm_s")
    if bwall and jax_s > max(wall_mult * bwall, WALL_FLOOR_S):
        problems.append(
            f"portfolio: warm round wall clock regressed {bwall:.1f}s -> "
            f"{jax_s:.1f}s (> {wall_mult:g}x baseline and > "
            f"{WALL_FLOOR_S:g}s floor)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when benchmark results regress vs baseline")
    ap.add_argument("fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("--baseline", default="benchmarks/baselines/fleet.json")
    ap.add_argument("--attain-tol", type=float, default=0.02,
                    help="max absolute SLO-attainment drop (default 0.02)")
    ap.add_argument("--cost-tol", type=float, default=0.08,
                    help="max relative $/hr increase (default 8%%)")
    ap.add_argument("--wall-mult", type=float, default=2.0,
                    help="max tuner wall-clock multiple vs baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the baseline from the fresh results "
                         "(after an intentional perf/cost change)")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    if args.write_baseline:
        with open(args.baseline, "w") as f:
            json.dump(_normalize(fresh), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote baseline {args.baseline} "
              f"({len(fresh.get('records', []))} records)")
        return 0
    try:
        with open(args.baseline) as f:
            base = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {args.baseline}; run with --write-baseline "
              "to create one", file=sys.stderr)
        return 2
    if base.get("benchmark") != fresh.get("benchmark"):
        # comparing against the wrong kind of baseline would skip every
        # baseline-relative check and report a hollow green
        print(f"baseline kind {base.get('benchmark')!r} does not match "
              f"fresh results {fresh.get('benchmark')!r} — wrong --baseline "
              "file?", file=sys.stderr)
        return 2

    if fresh.get("benchmark") == "sim_perf":
        problems = compare_sim(fresh, base)
        if problems:
            print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
            for p in problems:
                print(f"  - {p}")
            return 1
        head = fresh["headline"]
        ov = fresh.get("telemetry_overhead", {})
        sub = fresh.get("substep_headline", {})
        hu = fresh.get("fidelity", {}).get("high_util", {})
        print(f"sim gate green: compiled backend {head['speedup']:.1f}x the "
              f"numpy loop on the {head['grid']} headline round "
              f"(bar {MIN_SIM_SPEEDUP}x), backends agree "
              f"(max score delta "
              f"{fresh['agreement']['max_score_delta']:.2e}), telemetry "
              f"overhead {ov.get('overhead_frac', 0.0) * 100:+.1f}% "
              f"(bar {MAX_TELEMETRY_OVERHEAD * 100:.0f}%)")
        print(f"  fine core: {sub.get('speedup', 0.0):.1f}x on the "
              f"{sub.get('grid')} substep cell, score delta "
              f"{sub.get('max_score_delta')}; fidelity at util "
              f"{hu.get('utilization', 0.0):.2f}: coarse p99 "
              f"{hu.get('coarse_p99_s', 0.0):.1f}s vs fine "
              f"{hu.get('fine_p99_s', 0.0):.1f}s, preemptive EDF meets the "
              "gold bar cheaper than FIFO")
        return 0

    if fresh.get("benchmark") == "closed_loop_control":
        problems = compare_control(fresh, base, args.attain_tol,
                                   args.cost_tol)
        if problems:
            print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
            for p in problems:
                print(f"  - {p}")
            return 1
        head = fresh["headline"]
        cl = fresh.get("closed_loop", {})
        agree = fresh.get("agreement", {})
        agree_note = (f"agreement skipped ({agree['error']})"
                      if agree.get("error") else
                      f"backends agree on the re-tune winner (score delta "
                      f"{agree.get('max_score_delta'):.2e})")
        print(f"control gate green: incumbent breaks under drift, closed "
              f"loop recovers {head['recovery_attainment']:.4f} "
              f">= {head['attainment_bar']} within "
              f"{cl.get('detection_delay_bins')} bins at "
              f"${head['closed_loop_usd_per_hour']:.2f}/hr vs static "
              f"${head['static_usd_per_hour']:.2f}/hr; {agree_note}")
        return 0

    if fresh.get("benchmark") == "scoping_oracle":
        problems = compare_oracle(fresh, base, args.attain_tol,
                                  args.cost_tol)
        if problems:
            print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
            for p in problems:
                print(f"  - {p}")
            return 1
        lat = fresh.get("latency", {})
        ho = fresh.get("heldout", {})
        cl = fresh.get("closed_loop", {})
        agree = fresh.get("agreement", {})
        agree_note = (f"agreement skipped ({agree['error']})"
                      if agree.get("error") else
                      f"backends agree (score delta "
                      f"{agree.get('max_score_delta'):.2e})")
        print(f"oracle gate green: {lat.get('median_us', 0):.0f}us median "
              f"query (bar {ORACLE_MAX_MEDIAN_LATENCY_US:.0f}us), held-out "
              f"regret {ho.get('regret', 0) * 100:.1f}% vs fresh tune "
              f"(bar {ORACLE_MAX_REGRET * 100:.0f}%), build "
              f"{fresh.get('build', {}).get('tune_equivalents', 0):.1f} "
              f"tune-equivalents for "
              f"{fresh.get('build', {}).get('n_cells')} cells; drift "
              f"recovery: oracle swap at bin "
              f"{cl.get('oracle', {}).get('swap_bin')} vs re-tune "
              f"{cl.get('retune', {}).get('swap_bin')} with "
              f"{cl.get('oracle', {}).get('consult_sims')} vs "
              f"{cl.get('retune', {}).get('tune_sims')} sims; {agree_note}")
        return 0

    if fresh.get("benchmark") == "portfolio_tuning":
        problems = compare_portfolio(fresh, base, args.attain_tol,
                                     args.cost_tol, args.wall_mult)
        if problems:
            print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
            for p in problems:
                print(f"  - {p}")
            return 1
        head = fresh["headline"]
        pw = fresh["robustness"]["portfolio_winner"]
        singles = fresh["robustness"]["single_trace_winners"]
        cache = fresh["compile_cache"]
        print(f"portfolio gate green: {head['n_candidates']} candidates x "
              f"{head['n_traces']} traces x {head['n_seeds']} seeds in "
              f"{head['n_tiles']} tiled dispatches at {head['speedup']:.1f}x "
              f"the numpy path (bar {MIN_SIM_SPEEDUP}x), backends exact "
              f"(robust score delta "
              f"{fresh['agreement']['max_robust_score_delta']:.1e})")
        print(f"  robustness: portfolio winner worst-trace "
              f"${pw['worst_trace_score']:.2f} dominates "
              f"{len(singles)} single-trace winners (best of those "
              f"${min(r['worst_trace_score'] for r in singles):.2f}); "
              f"compile cache: {cache['warm_build']['disk_hits']} disk "
              f"hit(s) saved {cache['compile_seconds_saved']:.2f}s "
              "compiling on the rebuild")
        return 0

    if fresh.get("benchmark") == "controller_tuning":
        problems = compare_tuner(fresh, base, args.attain_tol, args.cost_tol,
                                 args.wall_mult)
        if problems:
            print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
            for p in problems:
                print(f"  - {p}")
            return 1
        print("tuner gate green: tuned dominates default, surface r2 "
              f"{fresh.get('surface_r2'):.3f} >= {MIN_SURFACE_R2}, racing at "
              f"{fresh.get('budget', {}).get('frac', 0) * 100:.0f}% of the "
              "naive budget with the exhaustive winner")
        return 0

    problems = compare(fresh, base, args.attain_tol, args.cost_tol)
    n_new = len({_key(r) for r in fresh.get("records", [])}
                - {_key(r) for r in base.get("records", [])})
    if n_new:
        print(f"note: {n_new} new record(s) not in the baseline — refresh it "
              "with --write-baseline to start gating them")
    if problems:
        print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"bench gate green: {len(base.get('records', []))} records and the "
          "tiered-SLA sweep within tolerance "
          f"(attain {args.attain_tol}, cost {args.cost_tol * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
