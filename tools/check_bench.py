"""Benchmark regression gate: compare a freshly produced ``BENCH_fleet.json``
against the committed baseline and fail when SLO attainment drops or $/hr
rises beyond tolerance.

The fleet benchmark is fully seeded, so fresh and baseline numbers are
expected to match almost exactly; the tolerances only absorb float/platform
drift. Gated invariants:

* every baseline record (policy, discipline, trace, shapes) still exists,
  its ``slo_attainment`` has not dropped more than ``--attain-tol`` (absolute)
  and its ``usd_per_hour`` has not risen more than ``--cost-tol`` (relative);
* the tiered-SLA sweep still finds a feasible fleet per discipline, no
  costlier than baseline beyond tolerance, meeting the attainment bar;
* the headline invariant holds: EDF or strict priority meets the tiered SLOs
  at strictly lower cost than FIFO.

Usage (CI runs exactly this):

    python tools/check_bench.py BENCH_fleet.json \\
        --baseline benchmarks/baselines/fleet.json

After an intentional perf/cost change, refresh the baseline with
``--write-baseline`` and commit the result.
"""
from __future__ import annotations

import argparse
import json
import sys

RECORD_KEY = ("policy", "discipline", "trace", "shapes")
VOLATILE = ("wall_clock_s", "total_wall_clock_s")


def _key(rec: dict) -> tuple:
    return tuple(rec.get(k) for k in RECORD_KEY)


def _normalize(bench: dict) -> dict:
    """Strip wall-clock timings (machine-dependent) before writing/storing."""
    out = {k: v for k, v in bench.items() if k not in VOLATILE}
    out["records"] = [{k: v for k, v in rec.items() if k not in VOLATILE}
                      for rec in bench.get("records", [])]
    return out


def compare(fresh: dict, base: dict, attain_tol: float,
            cost_tol: float) -> list:
    """Return a list of human-readable regression strings (empty = green)."""
    problems = []
    fresh_by_key = {_key(r): r for r in fresh.get("records", [])}
    for brec in base.get("records", []):
        k = _key(brec)
        frec = fresh_by_key.get(k)
        label = "/".join(str(x) for x in k)
        if frec is None:
            problems.append(f"missing record: {label} (present in baseline)")
            continue
        da = brec["slo_attainment"] - frec["slo_attainment"]
        if da > attain_tol:
            problems.append(
                f"{label}: SLO attainment dropped "
                f"{brec['slo_attainment']:.4f} -> "
                f"{frec['slo_attainment']:.4f} (tol {attain_tol})")
        floor = max(brec["usd_per_hour"], 1e-9)
        if frec["usd_per_hour"] > floor * (1.0 + cost_tol):
            problems.append(
                f"{label}: $/hr rose {brec['usd_per_hour']:.2f} -> "
                f"{frec['usd_per_hour']:.2f} (tol {cost_tol * 100:.0f}%)")

    btier = base.get("tiered_sla", {})
    ftier = fresh.get("tiered_sla", {})
    bar = btier.get("attainment_bar", 0.99)
    bcheap = btier.get("cheapest_feasible", {})
    fcheap = ftier.get("cheapest_feasible", {})
    for disc, brec in bcheap.items():
        frec = fcheap.get(disc)
        if frec is None:
            problems.append(f"tiered-sla: no feasible {disc} fleet anymore "
                            f"(baseline: {brec['replicas']} replicas)")
            continue
        if frec["worst_class_attainment"] < bar - attain_tol:
            problems.append(
                f"tiered-sla/{disc}: worst class attainment "
                f"{frec['worst_class_attainment']:.4f} below the "
                f"{bar:.2f} bar")
        if frec["usd_per_hour"] > brec["usd_per_hour"] * (1.0 + cost_tol):
            problems.append(
                f"tiered-sla/{disc}: cheapest feasible $/hr rose "
                f"{brec['usd_per_hour']:.2f} -> {frec['usd_per_hour']:.2f} "
                f"(tol {cost_tol * 100:.0f}%)")
    # the headline result this PR pins: a deadline-aware discipline beats
    # capacity-equivalent FIFO on cost while meeting every tier's SLO
    if {"fifo", "edf", "priority"} <= set(fcheap):
        fifo_usd = fcheap["fifo"]["usd_per_hour"]
        best = min(fcheap["edf"]["usd_per_hour"],
                   fcheap["priority"]["usd_per_hour"])
        if not best < fifo_usd:
            problems.append(
                "tiered-sla: EDF/priority no longer beat FIFO on cost "
                f"(fifo ${fifo_usd:.2f}/hr, best deadline-aware "
                f"${best:.2f}/hr)")
    elif bcheap:
        problems.append("tiered-sla: fresh results missing a discipline "
                        f"(have {sorted(fcheap)})")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when fleet benchmark results regress vs baseline")
    ap.add_argument("fresh", help="freshly produced BENCH_fleet.json")
    ap.add_argument("--baseline", default="benchmarks/baselines/fleet.json")
    ap.add_argument("--attain-tol", type=float, default=0.02,
                    help="max absolute SLO-attainment drop (default 0.02)")
    ap.add_argument("--cost-tol", type=float, default=0.08,
                    help="max relative $/hr increase (default 8%%)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the baseline from the fresh results "
                         "(after an intentional perf/cost change)")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    if args.write_baseline:
        with open(args.baseline, "w") as f:
            json.dump(_normalize(fresh), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote baseline {args.baseline} "
              f"({len(fresh.get('records', []))} records)")
        return 0
    try:
        with open(args.baseline) as f:
            base = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {args.baseline}; run with --write-baseline "
              "to create one", file=sys.stderr)
        return 2

    problems = compare(fresh, base, args.attain_tol, args.cost_tol)
    n_new = len({_key(r) for r in fresh.get("records", [])}
                - {_key(r) for r in base.get("records", [])})
    if n_new:
        print(f"note: {n_new} new record(s) not in the baseline — refresh it "
              "with --write-baseline to start gating them")
    if problems:
        print(f"BENCH REGRESSION ({len(problems)} problem(s)):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"bench gate green: {len(base.get('records', []))} records and the "
          "tiered-SLA sweep within tolerance "
          f"(attain {args.attain_tol}, cost {args.cost_tol * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
