"""Scoping as a service: build an oracle table offline, answer online.

The tuner (`tune()`) scopes one workload in seconds of simulation; the
oracle amortizes that cost across *every future workload*: sweep the tuner
once over a declarative (mean rate x burstiness x SLO) grid of canonical
traces, compile the winners + Pareto frontiers into a versioned JSON table,
and answer each new customer's "what shape + controller config, and what
will it cost?" by featurizing their trace and interpolating the table — in
microseconds, without touching the simulator. Queries outside the gridded
region are refused with a reason instead of extrapolated.

    PYTHONPATH=src python examples/oracle_query.py
"""
from repro.fleet import (Objective, OracleGrid, OracleTable, PIPolicy,
                         ScopingOracle, TuningBudget, build_oracle,
                         flash_crowd_trace, mset_scenario, tuning_scenario,
                         verify_oracle)


def main():
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=2.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    mt = svc.max_throughput
    probe = flash_crowd_trace(3.0 * mt, 900.0, dt_s=10.0, n_seeds=2, seed=0)
    ts = tuning_scenario(scenario, probe, PIPolicy, cold_start_s=60.0)
    objective = Objective(min_attainment=0.95, penalty_usd_per_hour=2000.0)

    # --- offline: sweep the tuner over the grid, once ----------------------
    grid = OracleGrid(mean_rates=(1.5 * mt, 3.0 * mt, 6.0 * mt),
                      burstiness=(1.0, 1.6, 2.2), slos=(1.0, 2.0, 4.0),
                      duration_s=900.0, dt_s=10.0, n_seeds=3, seed=0)
    table = build_oracle(grid, ts.fleet, PIPolicy, PIPolicy.param_space(),
                         objective=objective,
                         budget=TuningBudget(n_candidates=10, init_seeds=2),
                         context=ts.context, max_queue=ts.max_queue)
    print(table.summary())
    table.save("oracle_table.json")

    # --- online: microsecond answers from the reloaded artifact ------------
    oracle = ScopingOracle(OracleTable.load("oracle_table.json"))
    customer = flash_crowd_trace(2.4 * mt, 1800.0, dt_s=10.0, peak_mult=2.5,
                                 burst_width_s=150.0, n_seeds=4, seed=99)
    ans = oracle.query(customer, slo_s=2.0)
    print(f"\nanswer in {ans.latency_us:.0f}us: {ans.params}")
    print(f"  predicted ${ans.cost_usd_hr:.2f}/hr "
          f"(bound ${ans.cost_bound_usd_hr:.2f}/hr) "
          f"at {ans.attainment * 100:.1f}% attainment "
          f"[cell {ans.cell_idx}, exact={ans.exact}]")

    # a query beyond the sweep is refused, never guessed
    wild = oracle.query(customer, slo_s=0.05)
    print(f"\nout-of-grid query refused: {wild.reason}")

    # --- trust, then verify: spot-check answers against fresh simulation ---
    report = verify_oracle(table, ts.fleet, PIPolicy, n_samples=3,
                           context=ts.context, max_queue=ts.max_queue)
    print(f"\n{report.summary()}")


if __name__ == "__main__":
    main()
