"""Quickstart: the paper's prognostic pipeline end to end on one box.

TPSS-synthesized telemetry -> MSET2 training -> streaming surveillance ->
SPRT anomaly alarming, for a simulated pump with an incipient bearing drift.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.mset import SPRTParams, estimate, sprt, train
from repro.tpss import TPSSParams, inject_anomaly, synthesize


def main():
    key = jax.random.PRNGKey(0)
    print("=== 1. synthesize 24 sensors x 8192 observations (TPSS) ===")
    p = TPSSParams(n_signals=24, n_obs=8192, ar1=0.88, cross_weight=0.5)
    X = synthesize(key, p)
    print(f"telemetry: {X.shape}, per-signal std ~ {float(jnp.std(X, 0).mean()):.2f}")

    X_train, X_val, X_live = X[:5120], X[5120:6144], X[6144:]

    print("\n=== 2. train MSET2 (memory vectors + similarity + pinv) ===")
    model = train(X_train, n_memvec=256)
    _, res_val = estimate(model, X_val)
    sigma, mu = jnp.std(res_val, 0), jnp.mean(res_val, 0)
    acc = float(jnp.sqrt(jnp.mean(res_val**2)) / jnp.std(X_val))
    print(f"memory matrix D: {model.D.shape}, gamma={model.gamma:.3f}, "
          f"residual/signal ratio: {acc:.3%}")

    print("\n=== 3. live surveillance with an injected incipient fault ===")
    t_fault, sig_fault = 600, 7
    X_live = inject_anomaly(X_live, start=t_fault, signal=sig_fault,
                            drift_per_step=0.02)
    _, res = estimate(model, X_live)

    print("\n=== 4. SPRT alarming ===")
    alarms, _, _ = sprt(res, sigma, SPRTParams(alpha=1e-4, beta=1e-4, m_shift=4.0),
                        mu=mu)
    a = np.asarray(alarms)
    pre = a[:t_fault].mean()
    post = np.argwhere(a[t_fault:, sig_fault]).ravel()
    print(f"pre-fault alarm rate: {pre:.4%}")
    if len(post):
        drift_sigmas = 0.02 * post[0] / float(sigma[sig_fault])
        print(f"FAULT DETECTED on sensor {sig_fault}: {post[0]} samples after "
              f"onset (drift magnitude at detection ~{drift_sigmas:.1f} residual sigmas)")
    else:
        print("fault missed (unexpected)")


if __name__ == "__main__":
    main()
