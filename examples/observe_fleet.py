"""Fleet observability end to end: metrics dashboard, tuner span tracing,
exporters, and the MSET+SPRT drift probe.

Everything runs inside one ``telemetry.session()``: the simulator records
per-bin metric streams (arrival rate, utilization, queue depth, observed
service time), ``tune()`` wraps its phases in wall-clock spans (with the
compiled backend's cold/warm dispatches nested inside), and the session
exports to an ASCII sparkline dashboard, Prometheus text, and a JSONL event
log. The finale is the paper's prognostic loop in miniature: a DriftProbe
learns the healthy fleet's telemetry envelope, stays quiet on a fresh
replicate, and alarms on a fleet whose service times silently degraded 30%.

    PYTHONPATH=src python examples/observe_fleet.py
"""
from repro.fleet import (FleetConfig, Objective, PredictivePolicy,
                         QueueProportionalPolicy, TuningBudget, diurnal_trace,
                         flash_crowd_trace, mset_scenario, simulate_fleet,
                         telemetry, tune, tuning_scenario)


def main():
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    trace = flash_crowd_trace(3.5 * svc.max_throughput, 1800.0, dt_s=5.0,
                              peak_mult=4.0, burst_width_s=60.0,
                              n_seeds=8, seed=2)

    with telemetry.session() as tel:
        ts = tuning_scenario(scenario, trace, PredictivePolicy,
                             cold_start_s=60.0)      # backend="auto"
        report = tune(ts, PredictivePolicy.param_space(),
                      Objective(min_attainment=1.0,
                                penalty_usd_per_hour=1e5),
                      TuningBudget(n_candidates=12), seed=0)

    print("=== metric streams (sparkline dashboard) ===")
    print(tel.dashboard())

    print("\n=== tuner timing breakdown (span tree) ===")
    print(report.timing_breakdown())

    print("\n=== Prometheus exposition (first 12 lines) ===")
    print("\n".join(tel.prometheus().splitlines()[:12]))

    n = tel.export_jsonl("observe_fleet_events.jsonl")
    print(f"\nwrote observe_fleet_events.jsonl ({n} records)")

    # --- drift probe: learn the healthy envelope, catch silent degradation --
    fleet = FleetConfig((scenario.pool_for(scenario.cheapest_shape(),
                                           cold_start_s=30.0),))
    day = diurnal_trace(2.0 * svc.max_throughput, 3600.0, dt_s=10.0,
                        n_seeds=6, seed=0)
    probe = telemetry.DriftProbe().fit(
        simulate_fleet(day, fleet, QueueProportionalPolicy(), slo_s=2.0))

    day2 = diurnal_trace(2.0 * svc.max_throughput, 3600.0, dt_s=10.0,
                         n_seeds=6, seed=7)
    fresh = simulate_fleet(day2, fleet, QueueProportionalPolicy(), slo_s=2.0)
    print("\n=== drift probe ===")
    print(f"fresh replicate:  {probe.check(fresh).summary()}")

    degraded = telemetry.degrade_fleet(fleet, 1.3)   # 30% slower service
    bad = simulate_fleet(day2, degraded, QueueProportionalPolicy(), slo_s=2.0)
    print(f"degraded fleet:   {probe.check(bad).summary()}")


if __name__ == "__main__":
    main()
