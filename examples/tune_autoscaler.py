"""Autonomously scope the autoscaler: ``tune()`` quickstart.

The scoping stack picks a cloud shape; the fleet simulator says what a
policy costs on it; ``tune()`` closes the last loop and picks the *policy's
own knobs* — here the predictive autoscaler's (horizon_s, window_bins,
headroom) on a flash-crowd MSET scenario, then the reactive autoscaler's
rule thresholds on the same traffic for comparison.

Candidates are raced on paired Monte Carlo replicates (identical arrival
draws), dominated configs are culled early (successive halving + SPRT), and
the surviving region gets a fitted response surface — the paper's Figs. 4-8
methodology with controller parameters as the design variables.

    PYTHONPATH=src python examples/tune_autoscaler.py
"""
from repro.fleet import (Objective, PredictivePolicy, ReactivePolicy,
                         TuningBudget, flash_crowd_trace, mset_scenario,
                         simulate_fleet, summarize, tune, tuning_scenario)


def main():
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    trace = flash_crowd_trace(3.5 * svc.max_throughput, 3600.0, dt_s=5.0,
                              peak_mult=4.0, burst_width_s=120.0,
                              n_seeds=12, seed=2)
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)

    # --- tune the predictive policy, compare against the hand-set default --
    ts = tuning_scenario(scenario, trace, PredictivePolicy,
                         cold_start_s=60.0)
    report = tune(ts, PredictivePolicy.param_space(), objective,
                  TuningBudget(n_candidates=24), seed=0,
                  baseline={"horizon_s": 120.0, "window_bins": 12,
                            "headroom": 0.85})
    print(report.summary())

    # the tuned policy is one call away from serving traffic
    policy = report.build_policy()
    rep = summarize(simulate_fleet(trace, ts.fleet, policy,
                                   slo_s=scenario.slo_s))
    print(f"\ntuned policy re-simulated: {rep.slo_attainment * 100:.2f}% SLO "
          f"at ${rep.usd_per_hour:.2f}/hr\n")

    # --- same machinery, different policy family: reactive rule thresholds --
    ts_r = tuning_scenario(scenario, trace, ReactivePolicy, cold_start_s=60.0)
    rep_r = tune(ts_r, ReactivePolicy.param_space(), objective,
                 TuningBudget(n_candidates=24), seed=0,
                 baseline={"upper": 0.8, "lower_frac": 0.375,
                           "scale_up_frac": 0.5, "scale_down_frac": 0.25,
                           "cooldown_s": 120.0})
    print(rep_r.summary())


if __name__ == "__main__":
    main()
