"""Capacity planning for live traffic: run the autoscaling policies over
synthetic traces for both serving scenarios and compare SLO vs dollar cost.

The scoping stack picks the shape (the predictive policy calls ``recommend()``
over roofline rows); the fleet simulator then answers what that choice costs
under steady, diurnal, flash-crowd, and ramp arrivals. A mixed-shape fleet
(fine-grained baseline pool + coarse burst pool, driven by the heterogeneous
predictive policy) rides along in the same table — latencies are exact
per-request sojourns from the cohort model, not fluid estimates.

The last section serves a tiered-SLA *multi-class* workload (gold/silver/
bronze SLOs) under all three scheduling disciplines — FIFO, strict priority,
EDF — at the same capacity, showing discipline choice doing what extra
replicas otherwise would.

    PYTHONPATH=src python examples/simulate_fleet.py
"""
from repro.fleet import (HeterogeneousPredictivePolicy, StaticPolicy,
                         class_table, comparison_table, default_policies,
                         lm_decode_scenario, mset_scenario, simulate,
                         simulate_fleet, standard_traces, summarize,
                         tiered_sla_workload)


def run_scenario(scenario, mean_rate: float, duration_s: float = 3600.0,
                 dt_s: float = 5.0, cold_start_s: float = 60.0,
                 n_seeds: int = 8):
    print(f"\n=== {scenario.name}: {scenario.description} "
          f"(SLO {scenario.slo_s * 1e3:.0f} ms) ===")
    rows = scenario.rows
    constraint = scenario.constraint()
    policies = default_policies(rows, constraint, scenario.units_per_step,
                                static_replicas=0, cold_start_s=cold_start_s)
    predictive = policies[-1]
    shape_name = predictive.recommendation.shape.name
    service = scenario.service_for(shape_name)
    print(f"recommend() picked {shape_name} "
          f"({predictive.recommendation.reason}); one replica serves "
          f"{service.max_throughput:.0f} req/s at batch {service.max_batch}")

    # size the static fleet for the mean rate at 85% target utilization — the
    # one-shot scoping answer, blind to bursts
    import math
    policies[0].n = max(math.ceil(mean_rate / (service.max_throughput * 0.85)), 1)

    # mixed fleet: baseline pool of the cheapest shape, burst pool two rungs up
    shapes = sorted({r.shape_name for r in scenario.rows_at()},
                    key=lambda s: scenario.service_for(s).shape.chips)
    mixed_names = [shapes[0], shapes[min(2, len(shapes) - 1)]]
    fleet = scenario.fleet_for(mixed_names, cold_start_s=cold_start_s)
    hetero = HeterogeneousPredictivePolicy(rows, constraint,
                                           scenario.units_per_step, fleet,
                                           horizon_s=2 * cold_start_s)
    print(f"mixed fleet: {fleet.shape_label()} (drain order "
          f"{[fleet.pools[i].label for i in fleet.drain_order()]})")

    reports = []
    for trace in standard_traces(mean_rate, duration_s, dt_s, n_seeds=n_seeds):
        for policy in policies:
            sim = simulate(trace, service, policy, slo_s=scenario.slo_s,
                           cold_start_s=cold_start_s)
            reports.append(summarize(sim))
        reports.append(summarize(
            simulate_fleet(trace, fleet, hetero, slo_s=scenario.slo_s)))
    print(comparison_table(reports))
    return reports


def run_disciplines(scenario, n_replicas: int = 10, duration_s: float = 3600.0,
                    n_seeds: int = 4):
    """Same fleet, same trace, three scheduling disciplines: the per-class
    table shows FIFO leaking bronze's queueing delay into gold's latency."""
    service = scenario.service_for(scenario.cheapest_shape())
    wl = tiered_sla_workload(6.0 * service.max_throughput, duration_s,
                             dt_s=5.0, n_seeds=n_seeds, seed=3)
    print(f"\n=== {wl.name}: {n_replicas} x {service.shape.name}, classes "
          + ", ".join(f"{c.name}({c.slo_s:g}s)" for c in wl.classes)
          + " ===")
    reports = [summarize(simulate(wl, service, StaticPolicy(n_replicas),
                                  discipline=d, initial_replicas=n_replicas))
               for d in ("fifo", "priority", "edf")]
    print(class_table(reports))
    return reports


def main():
    # drive each scenario at ~70% of an 8-replica fleet of the smallest shape,
    # so bursts genuinely outrun the cold start
    mset = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8, slo_s=1.0)
    svc = mset.service_for(mset.rows_at()[0].shape_name)
    run_scenario(mset, mean_rate=5.6 * svc.max_throughput)

    lm = lm_decode_scenario("minitron-4b", ctx=512, slo_s=0.25)
    svc = lm.service_for(lm.rows_at()[0].shape_name)
    run_scenario(lm, mean_rate=5.6 * svc.max_throughput)

    run_disciplines(mset)


if __name__ == "__main__":
    main()
