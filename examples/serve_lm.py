"""Batched LM serving demo: prefill a batch of prompts, decode with the KV/state
cache, report throughput — across three architecture families (attention, MoE,
SSM) through one API.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import generate


def main():
    for arch in ["minitron-4b", "olmoe-1b-7b", "mamba2-130m"]:
        r = generate(arch, smoke=True, batch=4, prompt_len=32, gen_tokens=16)
        print(f"{arch:22s} prefill={r.prefill_s*1e3:7.1f}ms "
              f"decode={r.decode_s*1e3:7.1f}ms  {r.tokens_per_s:7.1f} tok/s  "
              f"sample={r.tokens[0][:8].tolist()}")


if __name__ == "__main__":
    main()
