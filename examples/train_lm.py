"""End-to-end driver: train the FULL mamba2-130m (~130M params) for a few
hundred steps on this box, with checkpointing, fault tolerance, and resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(Ctrl-C and re-run: it resumes from the last checkpoint.)
"""
import argparse

from repro.launch.train import TrainJob, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", help="tiny config instead")
    args = ap.parse_args()

    job = TrainJob(
        arch="mamba2-130m",
        smoke=args.smoke,              # full 130M config by default
        steps=args.steps,
        seq_len=args.seq_len,
        global_batch=args.batch,
        n_microbatches=2,
        peak_lr=6e-4,
        warmup=50,
        ckpt_dir="checkpoints/train_lm",
        ckpt_every=50,
        log_every=10,
    )
    metrics = train(job)
    print(f"\nfinal: {metrics}")
    print("loss curve (every 25 steps):")
    for h in job.history[::25]:
        print(f"  step {h['step']:4d}: {h['loss']:.4f}")


if __name__ == "__main__":
    main()
