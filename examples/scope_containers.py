"""The paper's headline workflow: autonomously scope a cloud container for a
customer's ML use case, from tiny (customer A) to fleet-scale (customer B).

Nested-loop Monte Carlo scoping (measured on this box) -> response surface ->
extrapolated cost for each catalog TPU shape (analytic roofline) -> cheapest
feasible shape + elasticity growth plan.

    PYTHONPATH=src python examples/scope_containers.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

import jax

from benchmarks.common import mset_surveil_flops_bytes, tpu_roofline_time
from repro.core import (CATALOG, CellResult, Constraint, ContainerStress,
                        RooflineTerms, fit_response_surface, grid_to_matrix,
                        recommend, render_ascii_surface)
from repro.configs.mset_paper import CUSTOMER_A, CUSTOMER_B
from repro.mset import estimate, train
from repro.tpss import TPSSParams, synthesize


def measured_scoping():
    print("=== 1. nested-loop Monte Carlo scoping (measured, this box) ===")

    def workload(params):
        key = jax.random.PRNGKey(params["n_signals"] * 7 + params["n_memvec"])
        X = synthesize(key, TPSSParams(n_signals=params["n_signals"], n_obs=2048))

        def run():
            m = train(X[:1536], n_memvec=params["n_memvec"])
            return estimate(m, X[1536:])[1]
        return run

    cs = ContainerStress()
    res = cs.run_measured(
        workload,
        {"n_signals": [8, 16, 32, 64], "n_memvec": [64, 128, 256, 512]},
        reps=2, constraint=lambda p: p["n_memvec"] >= 2 * p["n_signals"],
        verbose=False)
    names, X, y = res.to_arrays()
    surf = fit_response_surface(names, X, y)
    print(f"fitted response surface over (n_signals, n_memvec): r^2={surf.r2:.3f}")
    xs, ys, Z = grid_to_matrix(res.rows, "n_memvec", "n_signals")
    print(render_ascii_surface(xs, ys, Z, "n_memvec", "n_signals",
                               "measured train+surveil cost ('·' = infeasible)"))
    return surf


def analytic_recommendation(use_case, sample_rate_hz: float, fleet: int = 1,
                            window_s: float = 60.0):
    """Roofline cost of the MSET surveillance service on each catalog shape.

    fleet assets, each with its own (D, Ginv) model; one surveillance window of
    `window_s` seconds of observations per asset must finish within the window
    (real-time constraint) and all models must fit aggregate HBM.
    """
    print(f"\n=== scoping '{use_case.name}': {use_case.n_signals} signals x "
          f"{fleet} assets, memvec={use_case.n_memvec} @ {sample_rate_hz} Hz ===")
    rows = []
    n_obs = max(int(sample_rate_hz * window_s), 1)
    model_bytes = 4.0 * (use_case.n_memvec**2
                         + 2 * use_case.n_memvec * use_case.n_signals)
    for shape in CATALOG:
        f, b = mset_surveil_flops_bytes(use_case.n_signals, use_case.n_memvec, n_obs)
        f, b = f * fleet, b * fleet
        t = tpu_roofline_time(f, b, chips=shape.chips)
        rows.append(CellResult(params={"chips": shape.chips}, shape_name=shape.name,
                               terms=RooflineTerms(t, t * 0.8, 0.0),
                               analysis={"peak_memory_per_device":
                                         fleet * model_bytes / shape.chips}))
    cons = Constraint(max_step_latency_s=window_s)
    rec = recommend(rows, cons)
    for name, t, price, ok in rec.ranking:
        print(f"  {name:12s} t_window={t*1e3:10.2f}ms  ${price:8.2f}/hr  "
              f"{'OK' if ok else 'infeasible (latency or HBM)'}")
    print(f"--> {rec.shape.name if rec.shape else 'NO SHAPE'} ({rec.reason})")
    return rec


def main():
    measured_scoping()
    # Customer A: 20 signals @ 1/hr (paper §I) — anything works; cheapest wins.
    analytic_recommendation(CUSTOMER_A, sample_rate_hz=1 / 3600)
    # Customer B: fleet of 200 Airbus A320s, 75k sensors @ 1 Hz each — per-plane
    # MSET models must fit aggregate HBM; scoping finds the smallest slice.
    analytic_recommendation(CUSTOMER_B, sample_rate_hz=1.0, fleet=200)


if __name__ == "__main__":
    main()
