"""Closing the loop: drift-triggered re-scope + warm re-tune + hot-swap.

The paper's "autonomous" promise, end to end: a PI autoscaler is tuned for
the nominal MSET serving fleet, then serves a fresh diurnal trace on which
every node silently slows down by 2x mid-trace (the degrading-node scenario
the paper's prognostic engine watches for). The ``ClosedLoopController``
sees only telemetry; when its MSET+SPRT probe alarms it estimates the
degradation, re-checks the shape recommendation under the degraded service
model, warm re-tunes the PI on the remaining workload (seeded from the
incumbent's surviving region), and hot-swaps the winner into the running
simulation — one continuous trace, no restart.

    PYTHONPATH=src python examples/closed_loop.py
"""
from repro.core.recommender import recommend
from repro.fleet import (ClosedLoopController, FleetConfig, Objective,
                         PIPolicy, SegmentedSimulation, TuningBudget,
                         diurnal_trace, mset_scenario, tune, tuning_scenario,
                         window_metrics)
from repro.fleet.control import service_degradation_case
from repro.fleet.telemetry.drift import degrade_fleet
from repro.fleet.workload import Workload

DRIFT_FACTOR = 2.0
DT_S = 10.0


def main():
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=2.0)
    shape = recommend(scenario.rows_at(), scenario.constraint()).shape.name
    svc = scenario.service_for(shape)
    mean_rate = 3.0 * svc.max_throughput
    mc = diurnal_trace(mean_rate, 3600.0, dt_s=DT_S, amplitude=0.4,
                       period_s=3600.0, n_seeds=4, seed=1)
    live = diurnal_trace(mean_rate, 3600.0, dt_s=DT_S, amplitude=0.4,
                         period_s=3600.0, n_seeds=3, seed=101)
    fleet = FleetConfig((scenario.pool_for(shape, cold_start_s=60.0,
                                           max_replicas=24),),
                        max_queue=2.0 * mean_rate * DT_S)

    # --- scope the incumbent on the nominal world --------------------------
    ts = tuning_scenario(scenario, mc, PIPolicy, fleet=fleet,
                         cold_start_s=60.0, name="mset-diurnal/pi")
    objective = Objective(min_attainment=0.96, penalty_usd_per_hour=2000.0)
    incumbent = tune(ts, PIPolicy.param_space(), objective,
                     TuningBudget(n_candidates=10, init_seeds=2), seed=0)
    print(f"incumbent PI config: {incumbent.winner.params}\n")

    # --- the world drifts: every node silently 2x slower at the peak -------
    case = service_degradation_case(Workload.from_trace(live, scenario.slo_s),
                                    fleet, factor=DRIFT_FACTOR,
                                    t_drift_frac=0.25)
    td = case.drift_bins()[0]
    T = case.n_bins

    # counterfactual: the incumbent rides through unchanged
    ride = SegmentedSimulation(case.workload, fleet,
                               ts.make_policy(incumbent.winner.params),
                               cold_start_seed=ts.cold_start_seed)
    ride.run_until(td).swap(fleet=degrade_fleet(fleet, DRIFT_FACTOR))
    ride_post = window_metrics(ride.run_until(T).result(), td, T)

    # --- the closed loop observes, decides, acts ---------------------------
    ctl = ClosedLoopController(ts, incumbent, segment_bins=15,
                               retune_budget=TuningBudget(n_candidates=10,
                                                          init_seeds=2),
                               objective=objective)
    res = ctl.run(case)
    print(res.timeline())

    post = window_metrics(res.sim, td, T)
    print(f"\npost-drift worst-class attainment: incumbent ride-through "
          f"{ride_post.worst_class_attainment:.4f} at "
          f"${ride_post.usd_per_hour:.2f}/hr -> closed loop "
          f"{post.worst_class_attainment:.4f} at ${post.usd_per_hour:.2f}/hr")
    print(f"degradation estimate {res.est_factor:.2f} (true {DRIFT_FACTOR}); "
          f"active config {res.active_params}")
    if res.rescopes:
        rec = res.rescopes[0]
        print(f"re-scope under degraded service model: "
              f"{'shape ' + rec.shape.name if rec.shape else 'infeasible'}")


if __name__ == "__main__":
    main()
