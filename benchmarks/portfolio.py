"""Portfolio-robust tuning benchmark: 4 demand futures x >= 512 candidates
in one tiled compiled dispatch chain, plus the robustness headline.

Four traces — flash crowd, diurnal, an Azure-replay window, an adversarial
cooling ramp — ride ONE jitted candidate x (seed x trace) lattice per
candidate tile (`TuningScenario(workload=[...], tile=...)`): no per-trace
Python loop, every tile after the first a warm dispatch. The headlines this
benchmark pins (and ``tools/check_bench.py`` gates against
``benchmarks/baselines/portfolio.json``):

* a 4-trace x 512-candidate evaluation round executes one dispatch per
  candidate tile (span-verified: 1 cold + warm repeats after a flush, all
  warm once compiled) and beats the per-trace sequential numpy path by
  >= 5x on per-trajectory throughput;
* numpy and jax agree on the robust score to the last bit (delta 0) and on
  the round winner;
* robustness dominance: the portfolio winner's worst-trace score is at
  least as good as EVERY single-trace winner's worst-trace score — tuning
  on one trace overfits, the portfolio does not;
* a second build with a warm persistent compile cache spends measurably
  less wall-clock compiling than the cold build (disk-hit counter-verified,
  with a timing-noise grace floor).

Results land in ``BENCH_portfolio.json`` (CI artifact).

    PYTHONPATH=src python benchmarks/portfolio.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.recommender import recommend
from repro.fleet import (FleetConfig, Objective, PredictivePolicy, Trace,
                         TuningBudget, diurnal_trace, evaluate_candidates,
                         flash_crowd_trace, load_trace_csv, mset_scenario,
                         ramp_trace, resample_trace, telemetry, tune,
                         tuning_scenario)
from repro.fleet import jaxsim

QUOTA = 16
COLD_START_S = 60.0
SEED = 0
DT_S = 5.0
TILE = 128
DATA_CSV = os.path.join(os.path.dirname(__file__), "data",
                        "azure_functions_day.csv")


def build_portfolio(svc, duration: float, n_seeds: int):
    """The pinned 4-member demand portfolio, every member sharing
    (dt, bins, seeds): flash crowd (burst), diurnal (one full cycle),
    the busiest same-length window of the Azure functions replay, and an
    adversarial cooling ramp (starts hot — punishes slow scale-up the
    other members never probe)."""
    mt = svc.max_throughput
    flash = flash_crowd_trace(3.5 * mt, duration, dt_s=DT_S, peak_mult=4.0,
                              burst_width_s=duration / 30,
                              n_seeds=n_seeds, seed=SEED + 2)
    diurnal = diurnal_trace(3.5 * mt, duration, dt_s=DT_S, amplitude=0.7,
                            period_s=duration, n_seeds=n_seeds, seed=SEED + 3)
    day = load_trace_csv(DATA_CSV, rate_col=1, dt_s=60.0,
                         mean_rate_per_s=3.5 * mt, n_seeds=n_seeds,
                         seed=SEED + 4)
    k = int(round(duration / 60.0))          # busiest duration-long window
    means = np.convolve(day.rate, np.ones(k) / k, mode="valid")
    b0 = int(np.argmax(means))
    window = Trace("azure-window", 60.0, day.rate[b0:b0 + k],
                   day.arrivals[:, b0:b0 + k])
    azure = resample_trace(window, DT_S, seed=SEED + 4)
    ramp = ramp_trace(6.0 * mt, 1.0 * mt, duration, dt_s=DT_S,
                      n_seeds=n_seeds, seed=SEED + 5)
    return [flash, diurnal, azure, ramp]


def build_scenario(full: bool = False, backend: str = "auto", *,
                   robust: str = "worst_case", tile: int = TILE,
                   workload=None):
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    duration = 2400.0 if full else 1200.0
    n_seeds = 6 if full else 4
    if workload is None:
        workload = build_portfolio(svc, duration, n_seeds)
    shape = recommend(scenario.rows_at(), scenario.constraint()).shape.name
    fleet = FleetConfig((scenario.pool_for(shape, cold_start_s=COLD_START_S,
                                           max_replicas=QUOTA),))
    return tuning_scenario(scenario, workload, PredictivePolicy, fleet=fleet,
                           cold_start_s=COLD_START_S, backend=backend,
                           robust=robust, tile=tile), svc


def _objective():
    return Objective(min_attainment=0.99, penalty_usd_per_hour=1e4)


def _dispatch_spans(tel):
    def walk(spans):
        for s in spans:
            if s.name == "jaxsim.dispatch":
                yield s
            yield from walk(s.children)
    return [{"kind": s.attrs.get("kind"), "tile": s.attrs.get("tile"),
             "padded": s.attrs.get("padded"),
             "candidates": s.attrs.get("candidates")}
            for s in walk(tel.tracer.roots)]


def run_headline(ts, objective, n_candidates: int, numpy_subset: int):
    """One full-replicate evaluation round over the whole slate — exactly
    what a racing round dispatches — timed compiled-tiled vs the per-trace
    sequential numpy reference on a subset, compared on per-trajectory
    throughput (each of the ``n x seeds x traces`` trajectories is the same
    amount of physics on either path)."""
    space = PredictivePolicy.param_space()
    cands = space.sample_lhs(n_candidates, seed=SEED)
    K, S = ts.n_traces, ts.n_seeds

    held = jaxsim.clear_compiled()           # hold refs: id()-reuse hazard
    with telemetry.session() as tel:
        evaluate_candidates(ts, cands, objective)
    cold_round = _dispatch_spans(tel)
    with telemetry.session() as tel:
        t0 = time.perf_counter()
        evals = evaluate_candidates(ts, cands, objective)
        jax_warm_s = time.perf_counter() - t0
    warm_round = _dispatch_spans(tel)
    del held

    ts_np, _ = build_scenario(backend="numpy",
                              workload=list(ts.portfolio))
    t0 = time.perf_counter()
    np_evals = evaluate_candidates(ts_np, cands[:numpy_subset], objective)
    numpy_s = time.perf_counter() - t0

    jax_per_sim_us = jax_warm_s / (len(cands) * K * S) * 1e6
    numpy_per_sim_us = numpy_s / (numpy_subset * K * S) * 1e6
    winner = min(evals, key=lambda e: e.mean_score())
    sub_delta = float(max(
        np.abs(a.score - b.score).max()
        for a, b in zip(np_evals, evals[:numpy_subset])))
    n_tiles = int(np.ceil(len(cands) / TILE))
    return evals, {
        "n_candidates": len(cands),
        "n_traces": K, "n_seeds": S, "tile": TILE, "n_tiles": n_tiles,
        "n_bins": ts.workload.n_bins,
        "jax_warm_s": jax_warm_s,
        "jax_per_sim_us": jax_per_sim_us,
        "numpy_subset_candidates": numpy_subset,
        "numpy_s": numpy_s,
        "numpy_per_sim_us": numpy_per_sim_us,
        "speedup": numpy_per_sim_us / max(jax_per_sim_us, 1e-12),
        "cold_round_dispatches": cold_round,
        "warm_round_dispatches": warm_round,
        "subset_max_score_delta": sub_delta,
        "winner": dict(winner.params),
    }


def run_robustness(ts, objective, budget):
    """The overfit table: tune on each trace alone, tune on the portfolio,
    then score every winner on the full portfolio. A single-trace winner's
    worst trace is its blind spot; the portfolio winner must have none
    worse."""
    space = PredictivePolicy.param_space()
    port_report = tune(ts, space, objective, budget, seed=SEED)

    rows, winners = [], []
    for k, member in enumerate(ts.portfolio):
        ts_k, _ = build_scenario(workload=[member])
        rep = tune(ts_k, space, objective, budget, seed=SEED)
        winners.append((member.name, dict(rep.winner.params)))
    # score each single-trace winner ON the portfolio (full replicates,
    # same paired draws as the portfolio tune)
    evals = evaluate_candidates(ts, [w for _, w in winners]
                                + [dict(port_report.winner.params)],
                                objective)
    for (name, params), ev in zip(winners, evals[:-1]):
        rows.append({
            "tuned_on": name, "params": params,
            "own_trace_score": min(t.mean_score() for t in ev.per_trace),
            "worst_trace_score": ev.worst_trace_score(),
            "worst_trace_attainment": ev.worst_trace_attainment(),
        })
    pev = evals[-1]
    port = {
        "robust": ts.robust, "params": dict(pev.params),
        "worst_trace_score": pev.worst_trace_score(),
        "worst_trace_attainment": pev.worst_trace_attainment(),
        "per_trace_scores": {m.name: t.mean_score()
                             for m, t in zip(ts.portfolio, pev.per_trace)},
        "sims_used": port_report.sims_used,
        "full_budget": port_report.full_budget,
    }
    dominance = all(port["worst_trace_score"] <= r["worst_trace_score"] + 1e-9
                    for r in rows)
    return {"portfolio_winner": port, "single_trace_winners": rows,
            "portfolio_dominates": bool(dominance)}


def run_agreement(ts, objective):
    """numpy and jax must agree on the robust score bit-for-bit."""
    space = PredictivePolicy.param_space()
    cands = space.sample_lhs(8, seed=SEED + 9)
    ts_np, _ = build_scenario(backend="numpy",
                              workload=list(ts.portfolio))
    ej = evaluate_candidates(ts, cands, objective)
    en = evaluate_candidates(ts_np, cands, objective)
    delta = float(max(np.abs(a.score - b.score).max()
                      for a, b in zip(en, ej)))
    wj = min(ej, key=lambda e: e.mean_score()).params
    wn = min(en, key=lambda e: e.mean_score()).params
    return {"n_candidates": len(cands),
            "max_robust_score_delta": delta,
            "same_winner": wj == wn,
            "jax_winner": dict(wj), "numpy_winner": dict(wn)}


def run_compile_cache(ts, objective, cache_dir: str):
    """Cold build vs disk-warm rebuild: flush the in-memory jit caches, pay
    XLA compilation once into the persistent cache, flush again, and verify
    the rebuild deserializes from disk (hit counters) with measurably less
    cold-dispatch wall-clock."""
    jaxsim.enable_persistent_compile_cache(cache_dir)
    cands = PredictivePolicy.param_space().sample_lhs(12, seed=SEED + 7)

    def cold_build():
        held = jaxsim.clear_compiled()
        with telemetry.session() as tel:
            t0 = time.perf_counter()
            evals = evaluate_candidates(ts, cands, objective, s1=2)
            wall = time.perf_counter() - t0
        del held
        snap = tel.metrics.snapshot()["counter"]
        cold_s = snap.get("jaxsim_dispatch_seconds_total",
                          {}).get("kind=cold", 0.0)
        return evals, wall, cold_s

    before = jaxsim.persistent_cache_stats()
    e1, wall1, cold1 = cold_build()
    mid = jaxsim.persistent_cache_stats()
    e2, wall2, cold2 = cold_build()
    after = jaxsim.persistent_cache_stats()
    delta = float(max(np.abs(a.score - b.score).max()
                      for a, b in zip(e1, e2)))
    return {
        "cache_dir_entries": sum(len(f) for _, _, f in os.walk(cache_dir)),
        "cold_build": {"wall_s": wall1, "cold_dispatch_s": cold1,
                       "disk_misses": mid["misses"] - before["misses"],
                       "disk_hits": mid["hits"] - before["hits"]},
        "warm_build": {"wall_s": wall2, "cold_dispatch_s": cold2,
                       "disk_misses": after["misses"] - mid["misses"],
                       "disk_hits": after["hits"] - mid["hits"]},
        "compile_seconds_saved": cold1 - cold2,
        "max_score_delta": delta,
    }


def run(full: bool = False):
    if not jaxsim.available():
        return {"benchmark": "portfolio_tuning", "full": full,
                "error": "jax not installed — the portfolio benchmark "
                         "measures the compiled tiled dispatch path"}
    ts, svc = build_scenario(full)
    objective = _objective()
    n_candidates = 1024 if full else 512
    budget = TuningBudget(n_candidates=32 if full else 24)

    t0 = time.perf_counter()
    _, headline = run_headline(ts, objective, n_candidates,
                               numpy_subset=64 if full else 48)
    robustness = run_robustness(ts, objective, budget)
    agreement = run_agreement(ts, objective)
    with tempfile.TemporaryDirectory(prefix="jaxcache-") as d:
        cache = run_compile_cache(ts, objective, d)
    return {
        "benchmark": "portfolio_tuning",
        "full": full,
        "scenario": ts.name,
        "policy_family": "predictive",
        "portfolio": [{"trace": m.name,
                       "mean_rate_per_s": float(m.total_trace().rate.mean()),
                       "peak_rate_per_s": float(m.total_trace().rate.max())}
                      for m in ts.portfolio],
        "service_max_throughput": svc.max_throughput,
        "headline": headline,
        "robustness": robustness,
        "agreement": agreement,
        "compile_cache": cache,
        "total_wall_clock_s": time.perf_counter() - t0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_portfolio.json",
                    help="JSON results path (CI uploads this artifact)")
    args = ap.parse_args()
    bench = run(full=args.full)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    if "error" in bench:
        print(f"SKIPPED: {bench['error']}")
        return
    h, r, c = bench["headline"], bench["robustness"], bench["compile_cache"]
    print(f"headline: {h['n_candidates']} candidates x {h['n_traces']} "
          f"traces x {h['n_seeds']} seeds in {h['jax_warm_s']:.2f}s warm "
          f"({h['n_tiles']} tiled dispatches, "
          f"{h['jax_per_sim_us']:.0f}us/sim) — "
          f"{h['speedup']:.1f}x the sequential numpy path "
          f"({h['numpy_per_sim_us']:.0f}us/sim)")
    pw = r["portfolio_winner"]
    print(f"robustness: portfolio winner worst-trace score "
          f"${pw['worst_trace_score']:.2f} vs single-trace winners "
          + ", ".join(f"{row['tuned_on']} ${row['worst_trace_score']:.2f}"
                      for row in r["single_trace_winners"])
          + f" — dominates={r['portfolio_dominates']}")
    print(f"agreement: max robust score delta "
          f"{bench['agreement']['max_robust_score_delta']:.1e}, same winner "
          f"= {bench['agreement']['same_winner']}")
    print(f"compile cache: cold build {c['cold_build']['cold_dispatch_s']:.2f}s"
          f" compiling ({c['cold_build']['disk_misses']} disk misses), warm "
          f"rebuild {c['warm_build']['cold_dispatch_s']:.2f}s "
          f"({c['warm_build']['disk_hits']} disk hits) — saved "
          f"{c['compile_seconds_saved']:.2f}s")
    print(f"wrote {args.out} "
          f"(total wall clock {bench['total_wall_clock_s']:.1f}s)")


if __name__ == "__main__":
    main()
