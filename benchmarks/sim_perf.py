"""Simulator-backend benchmark: sequential numpy loop vs compiled JAX
candidate x seed batching, at a grid of (candidates, seeds, bins) sizes.

Each cell scores one full racing-round slate — ``evaluate_candidates`` over a
Latin-hypercube of predictive-policy configs on the flash-crowd tuning
scenario (the same build as ``tune_controller.py``). The numpy number is the
reference per-candidate loop; the JAX numbers are the one-dispatch batched
path, reported both cold (first call, includes XLA compile) and warm (the
steady state racing actually runs in — every round after the first reuses
the compiled program). The two backends are also cross-checked: per-seed
scores must agree to float tolerance and pick the same winner.

The headline ``tools/check_bench.py`` gates (``BENCH_sim.json`` vs
``benchmarks/baselines/sim.json``): on the tune_controller-sized round
(24 candidates x 12 seeds x 720 bins) the warm JAX path must beat the numpy
loop by at least 5x (with an absolute wall-clock grace floor for machines
where both are too fast to time), and the backends must agree.

The grid runs inside a telemetry session, so ``BENCH_sim.json`` also
records the compiled backend's jit-cache hit rate and its
compile-vs-dispatch seconds split, and the session's event log lands next
to the JSON (``*_events.jsonl``, a CI artifact). A separate
``telemetry_overhead`` section times the headline flash-crowd round with
telemetry enabled vs disabled — ``check_bench.py`` gates the enabled run at
<= 5% slower.

    PYTHONPATH=src python benchmarks/sim_perf.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.fleet import (Objective, PredictivePolicy, evaluate_candidates,
                         telemetry)

# the scenario IS tune_controller's (one shared builder, so the gated
# "tune_controller-sized round" claim cannot drift out of lockstep)
from tune_controller import SEED, build_scenario as _tuner_scenario

HEADLINE = (24, 12, 3600.0)     # candidates x seeds x 720 bins (dt = 5 s)
GRID = ((8, 8, 720.0), HEADLINE)
GRID_FULL = GRID + ((48, 16, 3600.0),)
WARM_REPS = 3
OVERHEAD_REPS = 3               # telemetry on-vs-off repetitions (median)


def build_scenario(n_seeds: int, duration_s: float, backend: str):
    return _tuner_scenario(backend=backend, n_seeds=n_seeds,
                           duration_s=duration_s)


def bench_cell(n_candidates: int, n_seeds: int, duration_s: float) -> dict:
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    candidates = PredictivePolicy.param_space().sample_lhs(n_candidates,
                                                          seed=SEED)
    ts_np = build_scenario(n_seeds, duration_s, "numpy")
    ts_jx = build_scenario(n_seeds, duration_s, "jax")
    n_bins = ts_np.workload.n_bins
    sims = n_candidates * n_seeds

    t0 = time.perf_counter()
    ev_np = evaluate_candidates(ts_np, candidates, objective)
    numpy_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ev_jx = evaluate_candidates(ts_jx, candidates, objective)
    jax_cold_s = time.perf_counter() - t0
    warm = []
    for _ in range(WARM_REPS):
        t0 = time.perf_counter()
        ev_jx = evaluate_candidates(ts_jx, candidates, objective)
        warm.append(time.perf_counter() - t0)
    jax_warm_s = float(np.median(warm))

    score_delta = max(float(np.abs(a.score - b.score).max())
                      for a, b in zip(ev_np, ev_jx))
    same_winner = (min(ev_np, key=lambda e: e.mean_score()).params
                   == min(ev_jx, key=lambda e: e.mean_score()).params)
    return {
        "n_candidates": n_candidates, "n_seeds": n_seeds, "n_bins": n_bins,
        "sims": sims,
        "numpy_s": numpy_s, "jax_cold_s": jax_cold_s,
        "jax_warm_s": jax_warm_s,
        "numpy_sims_per_s": sims / max(numpy_s, 1e-9),
        "jax_sims_per_s": sims / max(jax_warm_s, 1e-9),
        "speedup_warm": numpy_s / max(jax_warm_s, 1e-9),
        "speedup_cold": numpy_s / max(jax_cold_s, 1e-9),
        "max_score_delta": score_delta, "same_winner": bool(same_winner),
    }


def _jit_cache_stats(tel) -> dict:
    """Compiled-backend cache behaviour over the whole grid: jit-program
    cache hit rate and the compile-vs-dispatch wall-clock split (a cold
    dispatch pays XLA compilation on top of the steady-state dispatch cost
    its warm siblings measure)."""
    snap = tel.metrics.snapshot()
    core = snap["counter"].get("jaxsim_core_cache_total", {})
    disp = snap["counter"].get("jaxsim_dispatch_total", {})
    secs = snap["counter"].get("jaxsim_dispatch_seconds_total", {})
    hits = core.get("result=hit", 0.0)
    misses = core.get("result=miss", 0.0)
    n_cold = disp.get("kind=cold", 0.0)
    n_warm = disp.get("kind=warm", 0.0)
    cold_s = secs.get("kind=cold", 0.0)
    warm_s = secs.get("kind=warm", 0.0)
    warm_mean = warm_s / n_warm if n_warm else 0.0
    # compile_s: cold seconds beyond what those dispatches would have cost
    # at the steady-state (warm) rate
    compile_s = max(cold_s - n_cold * warm_mean, 0.0)
    return {
        "core_cache_hits": hits, "core_cache_misses": misses,
        "core_cache_hit_rate": hits / max(hits + misses, 1.0),
        "cold_dispatches": n_cold, "warm_dispatches": n_warm,
        "cold_dispatch_s": cold_s, "warm_dispatch_s": warm_s,
        "compile_s": compile_s, "dispatch_s": cold_s + warm_s - compile_s,
    }


def bench_telemetry_overhead(n_candidates: int, n_seeds: int,
                             duration_s: float,
                             reps: int = OVERHEAD_REPS) -> dict:
    """Median wall clock of the headline flash-crowd round with telemetry
    disabled vs enabled (fresh session per enabled rep) — the <= 5% bar
    ``check_bench.py`` gates. Runs on the numpy backend: every candidate
    sim records its streams there, so it bounds the per-``SimResult``
    recording cost the jax path shares."""
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    candidates = PredictivePolicy.param_space().sample_lhs(n_candidates,
                                                          seed=SEED)
    ts = build_scenario(n_seeds, duration_s, "numpy")

    def once(enabled: bool) -> float:
        if enabled:
            with telemetry.session():
                t0 = time.perf_counter()
                evaluate_candidates(ts, candidates, objective)
                return time.perf_counter() - t0
        t0 = time.perf_counter()
        evaluate_candidates(ts, candidates, objective)
        return time.perf_counter() - t0

    once(False)                         # warm caches before timing
    off = float(np.median([once(False) for _ in range(reps)]))
    on = float(np.median([once(True) for _ in range(reps)]))
    return {
        "grid": f"{n_candidates}x{n_seeds}", "reps": reps,
        "disabled_s": off, "enabled_s": on,
        "overhead_frac": on / max(off, 1e-9) - 1.0,
    }


def run(full: bool = False) -> tuple:
    # the whole grid runs under one telemetry session: jit-cache hit/miss
    # and cold/warm dispatch-seconds accumulate for the report, and the
    # session's JSONL event log is the CI artifact. (Recording adds the very
    # overhead the telemetry_overhead section bounds at <= 5%, identically
    # to both backends' timings.)
    with telemetry.session() as tel:
        records = [bench_cell(*cell) for cell in (GRID_FULL if full else GRID)]
    head = next(r for r in records
                if (r["n_candidates"], r["n_seeds"]) == HEADLINE[:2])
    overhead = bench_telemetry_overhead(*HEADLINE)
    bench = {
        "benchmark": "sim_perf",
        "full": full,
        "scenario": "mset-surveil/flash-crowd (tune_controller build)",
        "policy_family": "predictive",
        "records": records,
        "headline": {
            "grid": f"{head['n_candidates']}x{head['n_seeds']}"
                    f"x{head['n_bins']}",
            "speedup": head["speedup_warm"],
            "speedup_cold": head["speedup_cold"],
            "numpy_s": head["numpy_s"],
            "jax_warm_s": head["jax_warm_s"],
            "jax_cold_s": head["jax_cold_s"],
            "compile_s": max(head["jax_cold_s"] - head["jax_warm_s"], 0.0),
        },
        "jit_cache": _jit_cache_stats(tel),
        "telemetry_overhead": overhead,
        "agreement": {
            "max_score_delta": max(r["max_score_delta"] for r in records),
            "same_winner": all(r["same_winner"] for r in records),
        },
    }
    return bench, tel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="add the 48x16x720 cell")
    ap.add_argument("--out", default="BENCH_sim.json",
                    help="JSON results path (CI uploads this artifact)")
    args = ap.parse_args()
    bench, tel = run(full=args.full)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    events_path = os.path.splitext(args.out)[0] + "_events.jsonl"
    n_events = tel.export_jsonl(events_path)
    hdr = (f"{'cands':>6} {'seeds':>6} {'bins':>6} {'numpy':>9} "
           f"{'jax cold':>9} {'jax warm':>9} {'speedup':>8}")
    print(hdr)
    for r in bench["records"]:
        print(f"{r['n_candidates']:>6} {r['n_seeds']:>6} {r['n_bins']:>6} "
              f"{r['numpy_s']:>8.2f}s {r['jax_cold_s']:>8.2f}s "
              f"{r['jax_warm_s']:>8.3f}s {r['speedup_warm']:>7.1f}x")
    h = bench["headline"]
    print(f"\nheadline ({h['grid']}): {h['speedup']:.1f}x warm "
          f"({h['numpy_s']:.2f}s numpy vs {h['jax_warm_s']:.3f}s jax; "
          f"cold {h['jax_cold_s']:.2f}s, ~{h['compile_s']:.2f}s compile), "
          f"max score delta {bench['agreement']['max_score_delta']:.2e}")
    jc = bench["jit_cache"]
    print(f"jit cache: {jc['core_cache_hit_rate'] * 100:.0f}% hit rate "
          f"({jc['core_cache_hits']:.0f} hits / "
          f"{jc['core_cache_misses']:.0f} misses), "
          f"{jc['cold_dispatches']:.0f} cold + "
          f"{jc['warm_dispatches']:.0f} warm dispatches, "
          f"compile {jc['compile_s']:.2f}s vs dispatch "
          f"{jc['dispatch_s']:.2f}s")
    ov = bench["telemetry_overhead"]
    print(f"telemetry overhead ({ov['grid']} numpy round): "
          f"{ov['disabled_s']:.2f}s off vs {ov['enabled_s']:.2f}s on "
          f"({ov['overhead_frac'] * 100:+.1f}%)")
    print(f"wrote {args.out} and {events_path} ({n_events} events)")


if __name__ == "__main__":
    main()
