"""Simulator-backend benchmark: sequential numpy loop vs compiled JAX
candidate x seed batching, at a grid of (candidates, seeds, bins) sizes.

Each cell scores one full racing-round slate — ``evaluate_candidates`` over a
Latin-hypercube of predictive-policy configs on the flash-crowd tuning
scenario (the same build as ``tune_controller.py``). The numpy number is the
reference per-candidate loop; the JAX numbers are the one-dispatch batched
path, reported both cold (first call, includes XLA compile) and warm (the
steady state racing actually runs in — every round after the first reuses
the compiled program). The two backends are also cross-checked: per-seed
scores must agree to float tolerance and pick the same winner.

The headline ``tools/check_bench.py`` gates (``BENCH_sim.json`` vs
``benchmarks/baselines/sim.json``): on the tune_controller-sized round
(24 candidates x 12 seeds x 720 bins) the warm JAX path must beat the numpy
loop by at least 5x (with an absolute wall-clock grace floor for machines
where both are too fast to time), and the backends must agree.

The grid runs inside a telemetry session, so ``BENCH_sim.json`` also
records the compiled backend's jit-cache hit rate and its
compile-vs-dispatch seconds split, and the session's event log lands next
to the JSON (``*_events.jsonl``, a CI artifact). A separate
``telemetry_overhead`` section times the headline flash-crowd round with
telemetry enabled vs disabled — ``check_bench.py`` gates the enabled run at
<= 5% slower.

    PYTHONPATH=src python benchmarks/sim_perf.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.core import get_shape
from repro.fleet import (Objective, PredictivePolicy, StaticPolicy,
                         evaluate_candidates, simulate, summarize, telemetry,
                         tiered_sla_workload)
from repro.fleet.workload import ServiceModel

# the scenario IS tune_controller's (one shared builder, so the gated
# "tune_controller-sized round" claim cannot drift out of lockstep)
from tune_controller import SEED, build_scenario as _tuner_scenario

HEADLINE = (24, 12, 3600.0)     # candidates x seeds x 720 bins (dt = 5 s)
GRID = ((8, 8, 720.0), HEADLINE)
GRID_FULL = GRID + ((48, 16, 3600.0),)
SUBSTEP_CELL = (8, 8, 720.0)    # fine-core cell: ~4x the per-bin work, so a
#                                 smaller slate keeps the numpy side timeable
N_SUBSTEPS = 4                  # the fidelity knob the fine-core gates run at
WARM_REPS = 3
OVERHEAD_REPS = 3               # telemetry on-vs-off repetitions (median)


def build_scenario(n_seeds: int, duration_s: float, backend: str,
                   n_substeps: int = 1, preemptive: bool = False):
    ts = _tuner_scenario(backend=backend, n_seeds=n_seeds,
                         duration_s=duration_s)
    if n_substeps != 1 or preemptive:
        ts = dataclasses.replace(ts, n_substeps=n_substeps,
                                 preemptive=preemptive)
    return ts


def bench_cell(n_candidates: int, n_seeds: int, duration_s: float,
               n_substeps: int = 1, preemptive: bool = False) -> dict:
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    candidates = PredictivePolicy.param_space().sample_lhs(n_candidates,
                                                          seed=SEED)
    ts_np = build_scenario(n_seeds, duration_s, "numpy", n_substeps,
                           preemptive)
    ts_jx = build_scenario(n_seeds, duration_s, "jax", n_substeps,
                           preemptive)
    n_bins = ts_np.workload.n_bins
    sims = n_candidates * n_seeds

    t0 = time.perf_counter()
    ev_np = evaluate_candidates(ts_np, candidates, objective)
    numpy_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ev_jx = evaluate_candidates(ts_jx, candidates, objective)
    jax_cold_s = time.perf_counter() - t0
    warm = []
    for _ in range(WARM_REPS):
        t0 = time.perf_counter()
        ev_jx = evaluate_candidates(ts_jx, candidates, objective)
        warm.append(time.perf_counter() - t0)
    jax_warm_s = float(np.median(warm))

    score_delta = max(float(np.abs(a.score - b.score).max())
                      for a, b in zip(ev_np, ev_jx))
    same_winner = (min(ev_np, key=lambda e: e.mean_score()).params
                   == min(ev_jx, key=lambda e: e.mean_score()).params)
    return {
        "n_candidates": n_candidates, "n_seeds": n_seeds, "n_bins": n_bins,
        "n_substeps": n_substeps, "preemptive": preemptive,
        "sims": sims,
        "numpy_s": numpy_s, "jax_cold_s": jax_cold_s,
        "jax_warm_s": jax_warm_s,
        "numpy_sims_per_s": sims / max(numpy_s, 1e-9),
        "jax_sims_per_s": sims / max(jax_warm_s, 1e-9),
        "speedup_warm": numpy_s / max(jax_warm_s, 1e-9),
        "speedup_cold": numpy_s / max(jax_cold_s, 1e-9),
        "max_score_delta": score_delta, "same_winner": bool(same_winner),
    }


# --------------------------- fidelity section -------------------------------

FIDELITY_GOLD_BAR = 0.95        # gold-class attainment bar for the sweep
FIDELITY_MAX_REPLICAS = 10

# service with a long fixed term relative to dt_sub (batches genuinely span
# substeps, so head-of-line blocking and preemption are visible) but a full
# batch still under the gold SLO: 0.5 + 16 * 0.0125 = 0.7 s vs 1.0 s gold
_FID_SERVICE = ("v5e-4", 0.5, 0.0125, 16)
_FID_RATE, _FID_DURATION, _FID_DT = 60.0, 600.0, 2.0
_FID_SEEDS, _FID_SEED = 4, 3

# the SimResult arrays the fine-core backend-agreement check compares; the
# substep engines are mirrored float-op-for-float-op, so the bar is 0.0
_FID_FIELDS = ("served", "queue", "latency_s", "ok_served", "utilization",
               "class_served", "class_ok", "class_queue", "preemptions",
               "preempted_work", "residue_work")


def _fidelity_workload():
    return tiered_sla_workload(_FID_RATE, _FID_DURATION, dt_s=_FID_DT,
                               n_seeds=_FID_SEEDS, seed=_FID_SEED)


def _fidelity_service():
    shape, t_fixed, t_unit, max_batch = _FID_SERVICE
    return ServiceModel("fidelity", get_shape(shape), t_fixed, t_unit,
                        max_batch)


def _fid_sim(wl, svc, replicas, disc, n_substeps, preemptive,
             backend="numpy"):
    return simulate(wl, svc, StaticPolicy(replicas), discipline=disc,
                    initial_replicas=replicas, backend=backend,
                    n_substeps=n_substeps, preemptive=preemptive)


def _fid_row(sim, replicas) -> dict:
    rep = summarize(sim)
    gold = rep.class_reports[0]
    return {
        "replicas": replicas,
        "gold_attainment": gold.attainment,
        "gold_p99_s": gold.p99_s,
        "p99_s": rep.p99_s,
        "worst_class_attainment": rep.worst_class_attainment(),
        "utilization": rep.mean_utilization,
        "usd_per_hour": rep.usd_per_hour,
        "preemptions": (float(sim.preemptions.sum())
                        if sim.preemptions is not None else 0.0),
    }


def bench_fidelity() -> dict:
    """Coarse-vs-fine fidelity at high utilization (the regime heavy traffic
    lives in), on a tiered-SLA flash crowd over a static fleet.

    Three pinned claims (gated by ``check_bench.py``):

    * at the >= 90%-utilization operating point the coarse bin-granular core
      *understates* p99 — the fine core's explicit head-of-line blocking
      pushes the tail out;
    * preemptive EDF meets the gold SLO bar at strictly lower $/hr than
      non-preemptive FIFO needs (FIFO must buy replicas to stop bronze's
      batches from blocking gold; EDF just interrupts them);
    * the fine core's numpy and jax engines agree *bit-exactly* (max field
      delta 0.0) on the operating-point run.
    """
    wl = _fidelity_workload()
    svc = _fidelity_service()

    # cheapest static fleet meeting the gold bar, per scheduling config
    def cheapest(disc, preemptive):
        for r in range(1, FIDELITY_MAX_REPLICAS + 1):
            sim = _fid_sim(wl, svc, r, disc, N_SUBSTEPS, preemptive)
            row = _fid_row(sim, r)
            if row["gold_attainment"] >= FIDELITY_GOLD_BAR:
                return row
        return None

    edf = cheapest("edf", True)
    fifo = cheapest("fifo", False)
    # the high-utilization operating point: the preemptive-EDF choice
    op_replicas = edf["replicas"] if edf else 3
    coarse = _fid_row(_fid_sim(wl, svc, op_replicas, "fifo", 1, False),
                      op_replicas)
    fine = _fid_row(_fid_sim(wl, svc, op_replicas, "fifo", N_SUBSTEPS, False),
                    op_replicas)

    # fine-core backend agreement at the operating point, bit-exact bar
    a = _fid_sim(wl, svc, op_replicas, "edf", N_SUBSTEPS, True,
                 backend="numpy")
    try:
        b = _fid_sim(wl, svc, op_replicas, "edf", N_SUBSTEPS, True,
                     backend="jax")
        max_delta = max(
            float(np.abs(np.asarray(getattr(a, f), float)
                         - np.asarray(getattr(b, f), float)).max())
            for f in _FID_FIELDS)
        agreement = {"max_field_delta": max_delta,
                     "bit_exact": max_delta == 0.0}
    except Exception as exc:          # no jax in this env: report, don't gate
        agreement = {"max_field_delta": None, "bit_exact": False,
                     "error": str(exc)}

    return {
        "scenario": (f"tiered-sla flash-crowd {_FID_RATE:g} req/s x "
                     f"{_FID_DURATION:g}s @ dt={_FID_DT:g}s, "
                     f"service {_FID_SERVICE}"),
        "n_substeps": N_SUBSTEPS,
        "gold_bar": FIDELITY_GOLD_BAR,
        "high_util": {
            "replicas": op_replicas,
            "utilization": fine["utilization"],
            "coarse_p99_s": coarse["p99_s"],
            "fine_p99_s": fine["p99_s"],
        },
        "headline": {
            "edf_preemptive": edf,
            "fifo": fifo,
            "fifo_at_edf_replicas": fine,
        },
        "agreement": agreement,
    }


def _jit_cache_stats(tel) -> dict:
    """Compiled-backend cache behaviour over the whole grid: jit-program
    cache hit rate and the compile-vs-dispatch wall-clock split (a cold
    dispatch pays XLA compilation on top of the steady-state dispatch cost
    its warm siblings measure)."""
    snap = tel.metrics.snapshot()
    core = snap["counter"].get("jaxsim_core_cache_total", {})
    disp = snap["counter"].get("jaxsim_dispatch_total", {})
    secs = snap["counter"].get("jaxsim_dispatch_seconds_total", {})
    hits = core.get("result=hit", 0.0)
    misses = core.get("result=miss", 0.0)
    n_cold = disp.get("kind=cold", 0.0)
    n_warm = disp.get("kind=warm", 0.0)
    cold_s = secs.get("kind=cold", 0.0)
    warm_s = secs.get("kind=warm", 0.0)
    warm_mean = warm_s / n_warm if n_warm else 0.0
    # compile_s: cold seconds beyond what those dispatches would have cost
    # at the steady-state (warm) rate
    compile_s = max(cold_s - n_cold * warm_mean, 0.0)
    return {
        "core_cache_hits": hits, "core_cache_misses": misses,
        "core_cache_hit_rate": hits / max(hits + misses, 1.0),
        "cold_dispatches": n_cold, "warm_dispatches": n_warm,
        "cold_dispatch_s": cold_s, "warm_dispatch_s": warm_s,
        "compile_s": compile_s, "dispatch_s": cold_s + warm_s - compile_s,
    }


def bench_telemetry_overhead(n_candidates: int, n_seeds: int,
                             duration_s: float,
                             reps: int = OVERHEAD_REPS) -> dict:
    """Best-of-``reps`` wall clock of the headline flash-crowd round with
    telemetry disabled vs enabled (fresh session per enabled rep, arms
    interleaved) — the <= 5% bar ``check_bench.py`` gates. Runs on the
    numpy backend: every candidate sim records its streams there, so it
    bounds the per-``SimResult`` recording cost the jax path shares."""
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    candidates = PredictivePolicy.param_space().sample_lhs(n_candidates,
                                                          seed=SEED)
    ts = build_scenario(n_seeds, duration_s, "numpy")

    def once(enabled: bool) -> float:
        if enabled:
            with telemetry.session():
                t0 = time.perf_counter()
                evaluate_candidates(ts, candidates, objective)
                return time.perf_counter() - t0
        t0 = time.perf_counter()
        evaluate_candidates(ts, candidates, objective)
        return time.perf_counter() - t0

    once(False)                         # warm caches before timing
    # interleave the arms and keep each arm's best rep: back-to-back pairs
    # see the same machine state, and min discards scheduler jitter that a
    # median over separated blocks folds into the ratio
    offs, ons = [], []
    for _ in range(reps):
        offs.append(once(False))
        ons.append(once(True))
    off, on = float(np.min(offs)), float(np.min(ons))
    return {
        "grid": f"{n_candidates}x{n_seeds}", "reps": reps,
        "disabled_s": off, "enabled_s": on,
        "overhead_frac": on / max(off, 1e-9) - 1.0,
    }


def run(full: bool = False) -> tuple:
    # the whole grid runs under one telemetry session: jit-cache hit/miss
    # and cold/warm dispatch-seconds accumulate for the report, and the
    # session's JSONL event log is the CI artifact. (Recording adds the very
    # overhead the telemetry_overhead section bounds at <= 5%, identically
    # to both backends' timings.)
    with telemetry.session() as tel:
        records = [bench_cell(*cell) for cell in (GRID_FULL if full else GRID)]
        records.append(bench_cell(*SUBSTEP_CELL, n_substeps=N_SUBSTEPS,
                                  preemptive=True))
    head = next(r for r in records
                if (r["n_candidates"], r["n_seeds"]) == HEADLINE[:2]
                and r["n_substeps"] == 1)
    sub = next(r for r in records if r["n_substeps"] == N_SUBSTEPS)
    overhead = bench_telemetry_overhead(*HEADLINE)
    fidelity = bench_fidelity()
    bench = {
        "benchmark": "sim_perf",
        "full": full,
        "scenario": "mset-surveil/flash-crowd (tune_controller build)",
        "policy_family": "predictive",
        "records": records,
        "headline": {
            "grid": f"{head['n_candidates']}x{head['n_seeds']}"
                    f"x{head['n_bins']}",
            "speedup": head["speedup_warm"],
            "speedup_cold": head["speedup_cold"],
            "numpy_s": head["numpy_s"],
            "jax_warm_s": head["jax_warm_s"],
            "jax_cold_s": head["jax_cold_s"],
            "compile_s": max(head["jax_cold_s"] - head["jax_warm_s"], 0.0),
        },
        "substep_headline": {
            "grid": f"{sub['n_candidates']}x{sub['n_seeds']}x{sub['n_bins']}"
                    f"@n={sub['n_substeps']}",
            "n_substeps": sub["n_substeps"],
            "preemptive": sub["preemptive"],
            "speedup": sub["speedup_warm"],
            "numpy_s": sub["numpy_s"],
            "jax_warm_s": sub["jax_warm_s"],
            "max_score_delta": sub["max_score_delta"],
        },
        "fidelity": fidelity,
        "jit_cache": _jit_cache_stats(tel),
        "telemetry_overhead": overhead,
        "agreement": {
            "max_score_delta": max(r["max_score_delta"] for r in records
                                   if r["n_substeps"] == 1),
            "same_winner": all(r["same_winner"] for r in records),
        },
    }
    return bench, tel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="add the 48x16x720 cell")
    ap.add_argument("--out", default="BENCH_sim.json",
                    help="JSON results path (CI uploads this artifact)")
    args = ap.parse_args()
    bench, tel = run(full=args.full)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    events_path = os.path.splitext(args.out)[0] + "_events.jsonl"
    n_events = tel.export_jsonl(events_path)
    hdr = (f"{'cands':>6} {'seeds':>6} {'bins':>6} {'numpy':>9} "
           f"{'jax cold':>9} {'jax warm':>9} {'speedup':>8}")
    print(hdr)
    for r in bench["records"]:
        print(f"{r['n_candidates']:>6} {r['n_seeds']:>6} {r['n_bins']:>6} "
              f"{r['numpy_s']:>8.2f}s {r['jax_cold_s']:>8.2f}s "
              f"{r['jax_warm_s']:>8.3f}s {r['speedup_warm']:>7.1f}x")
    h = bench["headline"]
    print(f"\nheadline ({h['grid']}): {h['speedup']:.1f}x warm "
          f"({h['numpy_s']:.2f}s numpy vs {h['jax_warm_s']:.3f}s jax; "
          f"cold {h['jax_cold_s']:.2f}s, ~{h['compile_s']:.2f}s compile), "
          f"max score delta {bench['agreement']['max_score_delta']:.2e}")
    jc = bench["jit_cache"]
    print(f"jit cache: {jc['core_cache_hit_rate'] * 100:.0f}% hit rate "
          f"({jc['core_cache_hits']:.0f} hits / "
          f"{jc['core_cache_misses']:.0f} misses), "
          f"{jc['cold_dispatches']:.0f} cold + "
          f"{jc['warm_dispatches']:.0f} warm dispatches, "
          f"compile {jc['compile_s']:.2f}s vs dispatch "
          f"{jc['dispatch_s']:.2f}s")
    s = bench["substep_headline"]
    print(f"substep ({s['grid']}, preemptive): {s['speedup']:.1f}x warm "
          f"({s['numpy_s']:.2f}s numpy vs {s['jax_warm_s']:.3f}s jax), "
          f"max score delta {s['max_score_delta']:.2e}")
    fid = bench["fidelity"]
    hu, hl = fid["high_util"], fid["headline"]
    edf, fifo = hl["edf_preemptive"], hl["fifo"]
    print(f"fidelity ({fid['scenario']}, n_substeps={fid['n_substeps']}): "
          f"coarse p99 {hu['coarse_p99_s']:.1f}s vs fine "
          f"{hu['fine_p99_s']:.1f}s at util {hu['utilization']:.2f}")
    print(f"  gold bar {fid['gold_bar']:.2f}: preemptive EDF "
          f"{edf['replicas']} replicas ${edf['usd_per_hour']:.1f}/h "
          f"(attain {edf['gold_attainment']:.3f}) vs FIFO "
          f"{fifo['replicas']} replicas ${fifo['usd_per_hour']:.1f}/h")
    ag = fid["agreement"]
    if ag.get("error"):
        print(f"  fine-core backend agreement skipped: {ag['error']}")
    else:
        print(f"  fine-core numpy vs jax: max field delta "
              f"{ag['max_field_delta']:.2e} "
              f"(bit exact: {ag['bit_exact']})")
    ov = bench["telemetry_overhead"]
    print(f"telemetry overhead ({ov['grid']} numpy round): "
          f"{ov['disabled_s']:.2f}s off vs {ov['enabled_s']:.2f}s on "
          f"({ov['overhead_frac'] * 100:+.1f}%)")
    print(f"wrote {args.out} and {events_path} ({n_events} events)")


if __name__ == "__main__":
    main()
