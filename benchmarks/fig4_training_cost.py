"""Paper Figure 4: 3-D compute-cost contours of MSET2 TRAINING vs (n_memvec,
n_observations, n_signals). Measured wall-clock (XLA:CPU), response surface per
signal count, ASCII contour rendering."""
from __future__ import annotations

import numpy as np

from benchmarks.common import measured_training
from repro.core import fit_response_surface, grid_to_matrix, render_ascii_surface
from repro.core.scoping import CellResult


def run(full: bool = False):
    sigs = [10, 20, 30, 40] if full else [10, 20]
    mvs = [128, 256, 512, 1024] if full else [64, 128, 256]
    obs = [2048, 4096, 8192] if full else [1024, 2048]
    rows = []
    for ns in sigs:
        for mv in mvs:
            if mv < 2 * ns:
                continue
            for no in obs:
                t = measured_training(ns, mv, no)
                rows.append(CellResult(params={"n_signals": ns, "n_memvec": mv,
                                               "n_observations": no}, mean_s=t))
                print(f"fig4,train_cost,n_sig={ns},n_mv={mv},n_obs={no},"
                      f"{t*1e6:.0f}us")
    names, X, y = _arrays(rows)
    surf = fit_response_surface(names, X, y)
    print(f"# fig4 response surface r^2 = {surf.r2:.4f} "
          "(training cost ~ memvec^a * signals^b, paper: dominated by memvec+signals)")
    sub = [r for r in rows if r.params["n_observations"] == obs[0]]
    xs, ys, Z = grid_to_matrix(sub, "n_memvec", "n_signals")
    print(render_ascii_surface(xs, ys, Z, "n_memvec", "n_signals",
                               f"Fig4-style: training cost @ n_obs={obs[0]}"))
    return rows, surf


def _arrays(rows):
    names = ["n_signals", "n_memvec", "n_observations"]
    X = np.array([[r.params[n] for n in names] for r in rows], float)
    y = np.array([r.mean_s for r in rows], float)
    return names, X, y


if __name__ == "__main__":
    run()
