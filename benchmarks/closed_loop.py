"""Closed-loop autonomous control benchmark: drift-triggered re-scope +
warm re-tune + mid-trace policy hot-swap, pinned end to end.

The experiment: a PI autoscaler is autonomously tuned for the nominal MSET
serving fleet (the incumbent), then serves a fresh diurnal trace on which
every pool's service times silently inflate by ``DRIFT_FACTOR`` at the
midpoint — the paper's degrading-node scenario. Three deployments ride the
same drifted world:

* **incumbent (static config)** — the tuned PI rides through unchanged; its
  anti-windup clamp bounds its authority, so it cannot re-center and the
  worst-class attainment collapses below the bar;
* **closed loop** — ``ClosedLoopController`` detects the drift from
  telemetry (MSET+SPRT probe), re-scopes the shape choice under the
  degraded service model, warm re-tunes the PI on the remaining workload
  (seeded from the incumbent report, compiled backend), and hot-swaps the
  winner mid-trace;
* **static-after-drift** — the counterfactual ops response: the cheapest
  ``StaticPolicy`` fleet that restores the attainment bar over the
  post-drift window (peak-provisioned, since a static fleet cannot follow
  the diurnal valleys).

Headline (gated by ``tools/check_bench.py`` against
``benchmarks/baselines/control.json``):

* the incumbent really breaks: post-drift worst-class attainment < bar;
* the closed loop recovers: worst-class attainment >= ``ATTAIN_BAR`` (0.95)
  over the post-swap window;
* it recovers *cheaper* than the static response: closed-loop post-drift
  $/hr < the cheapest bar-restoring static fleet's $/hr;
* the warm re-tune is backend-exact: numpy and jax agree on the re-tune
  winner and its score.

Results land in ``BENCH_control.json`` (CI artifact).

    PYTHONPATH=src python benchmarks/closed_loop.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.recommender import recommend
from repro.fleet import (ClosedLoopController, FleetConfig, Objective,
                         PIPolicy, SegmentedSimulation, StaticPolicy,
                         TuningBudget, diurnal_trace, mset_scenario,
                         simulate_fleet, tune, tuning_scenario,
                         window_metrics)
from repro.fleet.control import service_degradation_case, tail_workload
from repro.fleet.telemetry.drift import degrade_fleet
from repro.fleet.workload import Workload

SEED = 0
COLD_START_S = 60.0
DT_S = 10.0
COLD_BINS = int(COLD_START_S / DT_S)    # actuation dead time, in bins
TUNE_BAR = 0.96         # tune with margin above the gated bar: the live
#                         trace is a fresh draw the tuner never saw
QUOTA = 24
DRIFT_FACTOR = 2.0
ATTAIN_BAR = 0.95
SEGMENT_BINS = 15       # control cadence: probe needs >= its min_alarm_bins
MEAN_MULT = 3.0         # mean arrival rate, in single-replica throughputs
AMPLITUDE = 0.4         # diurnal swing; trough stays above 1 replica's worth
T_DRIFT_FRAC = 0.25     # drift lands at the diurnal peak: the incumbent
#                         breaks immediately, the static recovery must hold
#                         the degraded peak for the whole window, and the
#                         closed loop rides the valley back down


def build(full: bool = False, backend: str = "auto"):
    """Nominal tuning scenario + the drifted live case. The diurnal trace is
    the honest feedback-vs-static setting: the PI follows the valleys while
    a static fleet must hold the peak."""
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=2.0)
    shape = recommend(scenario.rows_at(), scenario.constraint()).shape.name
    svc = scenario.service_for(shape)
    duration = 7200.0 if full else 3600.0
    n_seeds = 8 if full else 6
    mean_rate = MEAN_MULT * svc.max_throughput
    mc = diurnal_trace(mean_rate, duration, dt_s=DT_S, amplitude=AMPLITUDE,
                       period_s=duration, n_seeds=n_seeds, seed=SEED + 1)
    live = diurnal_trace(mean_rate, duration, dt_s=DT_S, amplitude=AMPLITUDE,
                         period_s=duration, n_seeds=4, seed=SEED + 101)
    # admission control: bound the backlog at ~2 bins of mean demand so an
    # under-provisioned fleet sheds (SLO misses) instead of queueing forever
    fleet = FleetConfig((scenario.pool_for(shape, cold_start_s=COLD_START_S,
                                           max_replicas=QUOTA),),
                        max_queue=2.0 * mean_rate * DT_S)
    ts = tuning_scenario(scenario, mc, PIPolicy, fleet=fleet,
                         cold_start_s=COLD_START_S, backend=backend,
                         name="mset-diurnal/pi")
    case = service_degradation_case(
        Workload.from_trace(live, scenario.slo_s), fleet,
        factor=DRIFT_FACTOR, t_drift_frac=T_DRIFT_FRAC)
    return ts, case


def _window_record(wm):
    return {"t0": wm.t0, "t1": wm.t1,
            "worst_class_attainment": wm.worst_class_attainment,
            "usd_per_hour": wm.usd_per_hour,
            "mean_replicas": wm.mean_replicas}


def cheapest_static_recovery(ts, case, td: int):
    """The counterfactual ops response: smallest (cheapest) static fleet
    restoring the attainment bar on the degraded post-drift tail."""
    wl = tail_workload(case.workload, td)
    fleet = degrade_fleet(case.fleet, DRIFT_FACTOR)
    for n in range(1, QUOTA + 1):
        sim = simulate_fleet(wl, fleet, StaticPolicy(n),
                             cold_start_seed=ts.cold_start_seed)
        wm = window_metrics(sim, 0)
        if wm.worst_class_attainment >= ATTAIN_BAR:
            return n, wm
    return None, None


def retune_agreement(ctl, res, td: int):
    """Backend agreement on the drift response itself: re-run the first
    warm re-tune on both simulator backends and compare winner + score."""
    try:
        import jax  # noqa: F401
    except Exception as exc:            # pragma: no cover - no-jax machines
        return {"error": f"jax unavailable: {exc}"}
    if not res.retunes:
        return {"error": "closed loop never re-tuned"}
    t1 = next(e.t_bin for e in res.events if e.kind == "retune")
    factor = next(e.detail["est_factor"] for e in res.events
                  if e.kind == "drift-alarm")
    out = {}
    for backend in ("numpy", "jax"):
        scen = ctl._tail_scenario(t1, factor)
        scen.backend = backend
        report = tune(scen, ctl.incumbent.space, ctl.objective,
                      ctl.retune_budget, seed=ctl.retune_seed,
                      warm_start=ctl.incumbent, warm_jitter=ctl.retune_jitter,
                      baseline=dict(ctl.incumbent_params))
        out[backend] = report
    wn = out["numpy"].winner
    wj = out["jax"].winner
    return {
        "backends": ["numpy", "jax"],
        "same_winner": wn.params == wj.params,
        "numpy_winner": wn.params,
        "jax_winner": wj.params,
        "max_score_delta": abs(wn.mean_score() - wj.mean_score()),
    }


def run(full: bool = False, backend: str = "auto"):
    t_start = time.perf_counter()
    ts, case = build(full, backend=backend)
    objective = Objective(min_attainment=TUNE_BAR,
                          penalty_usd_per_hour=2000.0)
    incumbent = tune(ts, PIPolicy.param_space(), objective,
                     TuningBudget(n_candidates=16 if full else 12,
                                  init_seeds=2), seed=SEED)
    td = case.drift_bins()[0]
    T = case.n_bins

    # the incumbent riding through the drift unchanged (no controller)
    ride_sim = SegmentedSimulation(case.workload, case.fleet,
                                   ts.make_policy(incumbent.winner.params),
                                   cold_start_seed=ts.cold_start_seed)
    ride_sim.run_until(td)
    ride_sim.swap(fleet=degrade_fleet(case.fleet, DRIFT_FACTOR))
    ride = ride_sim.run_until(T).result()
    inc_pre = window_metrics(ride, 0, td)
    inc_post = window_metrics(ride, td, T)

    ctl = ClosedLoopController(
        ts, incumbent, segment_bins=SEGMENT_BINS,
        retune_budget=TuningBudget(n_candidates=16 if full else 14,
                                   init_seeds=2),
        objective=objective)
    res = ctl.run(case)
    cl_pre = window_metrics(res.sim, 0, td)
    cl_post = window_metrics(res.sim, td, T)
    # recovery is judged once the swapped-in config's ordered capacity has
    # landed: swap bin + the cold-start dead time (physical actuation lag)
    swaps = [e.t_bin for e in res.events if e.kind == "swap"]
    t_rec = min(swaps[0] + COLD_BINS, T - 1) if swaps else td
    cl_rec = window_metrics(res.sim, t_rec, T)
    first_alarm = next((e.t_bin for e in res.events
                        if e.kind == "drift-alarm"), -1)

    n_static, static_wm = cheapest_static_recovery(ts, case, td)
    agreement = retune_agreement(ctl, res, td)

    recovered = cl_rec.worst_class_attainment >= ATTAIN_BAR
    incumbent_breaks = inc_post.worst_class_attainment < ATTAIN_BAR
    cheaper = (static_wm is not None
               and cl_post.usd_per_hour < static_wm.usd_per_hour)
    bench = {
        "benchmark": "closed_loop_control",
        "full": full,
        "backend": backend,
        "scenario": ts.name,
        "drift": {"factor": DRIFT_FACTOR, "t_bin": td, "n_bins": T,
                  "segment_bins": SEGMENT_BINS},
        "incumbent": {
            "params": incumbent.winner.params,
            "pre_drift": _window_record(inc_pre),
            "post_drift": _window_record(inc_post),
        },
        "closed_loop": {
            "n_alarms": res.n_alarms,
            "n_swaps": res.n_swaps,
            "est_factor": res.est_factor,
            "first_alarm_bin": first_alarm,
            "detection_delay_bins": (first_alarm - td if first_alarm >= 0
                                     else None),
            "active_params": res.active_params,
            "pre_drift": _window_record(cl_pre),
            "post_drift": _window_record(cl_post),
            "recovery": _window_record(cl_rec),
            "rescoped_feasible": bool(res.rescopes
                                      and res.rescopes[0].shape is not None),
            "timeline": [{"t_bin": e.t_bin, "kind": e.kind}
                         for e in res.events],
        },
        "static_after_drift": (
            dict(_window_record(static_wm), n_replicas=n_static)
            if static_wm is not None else None),
        "headline": {
            "attainment_bar": ATTAIN_BAR,
            "incumbent_breaks": bool(incumbent_breaks),
            "recovered": bool(recovered),
            "recovery_attainment": cl_rec.worst_class_attainment,
            "closed_loop_usd_per_hour": cl_post.usd_per_hour,
            "static_usd_per_hour": (static_wm.usd_per_hour
                                    if static_wm else None),
            "cheaper_than_static": bool(cheaper),
        },
        "agreement": agreement,
        "wall_clock_s": time.perf_counter() - t_start,
    }
    return res, bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_control.json",
                    help="JSON results path (CI uploads this artifact)")
    ap.add_argument("--backend", default="auto",
                    choices=("numpy", "jax", "auto"))
    args = ap.parse_args()
    res, bench = run(full=args.full, backend=args.backend)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    h = bench["headline"]
    print(res.timeline())
    print(f"\nincumbent post-drift attainment "
          f"{bench['incumbent']['post_drift']['worst_class_attainment']:.4f}"
          f" (breaks: {h['incumbent_breaks']}); closed loop recovers to "
          f"{h['recovery_attainment']:.4f} (bar {h['attainment_bar']}) at "
          f"${h['closed_loop_usd_per_hour']:.2f}/hr vs static recovery "
          f"${h['static_usd_per_hour']}/hr "
          f"(cheaper: {h['cheaper_than_static']})")
    print(f"wrote {args.out} (wall clock {bench['wall_clock_s']:.1f}s)")


if __name__ == "__main__":
    main()
