"""Controller-scoping benchmark: autonomously tune the predictive autoscaler
on the flash-crowd MSET scenario and pin the tuned-vs-default headline.

``tune()`` races Latin-hypercube candidates over (horizon_s, window_bins,
headroom) through the fleet simulator (paired Monte Carlo replicates,
successive-halving + SPRT culling), fits the controller response surface,
and returns the winner. The headline this benchmark pins (and
``tools/check_bench.py`` gates against ``benchmarks/baselines/tuner.json``):

* the tuned policy dominates the hand-set ``default_policies`` counterpart
  (attainment >=, $/hr <=, at least one strict) on the same paired draws;
* the fitted response surface reports r2 >= 0.8 over the surviving region;
* racing spends <= 40% of the naive grid x seed budget and returns the same
  winner as the exhaustive sweep;
* tuner wall clock stays within 2x the committed baseline.

Results land in ``BENCH_tuner.json`` (CI artifact).

    PYTHONPATH=src python benchmarks/tune_controller.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.recommender import recommend
from repro.fleet import (FleetConfig, Objective, PredictivePolicy,
                         TuningBudget, exhaustive, flash_crowd_trace,
                         mset_scenario, race, tune, tuning_scenario)

QUOTA = 16              # per-pool replica quota, matching fleet_scaling.py
COLD_START_S = 60.0
SEED = 0
# the hand-set config default_policies() ships (PR 1..3's controller knobs)
DEFAULT_PARAMS = {"horizon_s": 2 * COLD_START_S, "window_bins": 12,
                  "headroom": 0.85}


def _eval_record(ev):
    return {
        "params": {k: (round(v, 6) if isinstance(v, float) else v)
                   for k, v in sorted(ev.params.items())},
        "usd_per_hour": ev.mean_cost(),
        "usd_per_hour_ci95": ev.cost_ci(),
        "worst_class_attainment": ev.mean_attainment(),
        "attainment_ci95": ev.attainment_ci(),
        "p99_s": ev.p99_s(),
        "drop_rate": ev.mean_drop_rate(),
        "n_seeds": ev.n_seeds,
    }


def build_scenario(full: bool = False, backend: str = "auto", *,
                   n_seeds: int = None, duration_s: float = None):
    """The flash-crowd predictive-tuning scenario. ``sim_perf.py`` builds
    its grid cells through this same function (overriding only
    ``n_seeds``/``duration_s``), so its gated headline really is this
    benchmark's round at this benchmark's scale."""
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    duration = duration_s if duration_s is not None \
        else (7200.0 if full else 3600.0)
    n_seeds = n_seeds if n_seeds is not None else (16 if full else 12)
    # size the flash crowd so the quota CAN hold the peak (~14 of 16
    # replicas): the SLO is achievable and the controller's knobs — not raw
    # capacity — decide cost and attainment
    base_rate = 3.5 * svc.max_throughput
    trace = flash_crowd_trace(base_rate, duration, dt_s=5.0, peak_mult=4.0,
                              burst_width_s=duration / 30,
                              n_seeds=n_seeds, seed=SEED + 2)
    shape = recommend(scenario.rows_at(), scenario.constraint()).shape.name
    fleet = FleetConfig((scenario.pool_for(shape, cold_start_s=COLD_START_S,
                                           max_replicas=QUOTA),))
    return tuning_scenario(scenario, trace, PredictivePolicy, fleet=fleet,
                           cold_start_s=COLD_START_S, backend=backend)


def run(full: bool = False, backend: str = "auto"):
    ts = build_scenario(full, backend=backend)
    space = PredictivePolicy.param_space()
    # the quota can hold the whole burst, so demand full attainment and make
    # any shortfall unprofitable: the race is then purely about who meets the
    # SLO cheapest — the headline the gate pins
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    budget = TuningBudget(n_candidates=32 if full else 24)

    t0 = time.perf_counter()
    report = tune(ts, space, objective, budget, seed=SEED,
                  baseline=DEFAULT_PARAMS)
    tune_wall = time.perf_counter() - t0

    # racing-vs-exhaustive on a small grid: same winner, fraction of budget
    grid = space.grid(2)
    rr = race(ts, grid, objective, init_seeds=budget.init_seeds,
              eta=budget.eta)
    ex = exhaustive(ts, grid, objective)
    same_winner = rr.winner.params == ex.winner.params

    bench = {
        "benchmark": "controller_tuning",
        "full": full,
        "backend": backend,
        "scenario": ts.name,
        "policy_family": report.policy_family,
        "space": {d.name: type(d).__name__ for d in space.dims},
        "n_candidates": budget.n_candidates,
        "n_seed_replicates": ts.n_seeds,
        "headline": {
            "tuned": _eval_record(report.winner),
            "default": _eval_record(report.baseline),
            "tuned_dominates_default": report.dominates_baseline(),
        },
        "surface_r2": report.surface_r2,
        "surface_dims": list(report.surface_names),
        "budget": {
            "sims_used": report.sims_used,
            "full_budget": report.full_budget,
            "frac": report.budget_frac,
        },
        "race_vs_exhaustive": {
            "grid_size": len(grid),
            "same_winner": bool(same_winner),
            "race_frac": rr.budget_frac,
            "race_winner": rr.winner.params,
            "exhaustive_winner": ex.winner.params,
        },
        "frontier": [_eval_record(e) for e in report.frontier],
        "tuner_wall_clock_s": tune_wall,
    }
    return report, bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_tuner.json",
                    help="JSON results path (CI uploads this artifact)")
    ap.add_argument("--backend", default="auto",
                    choices=("numpy", "jax", "auto"),
                    help="simulator backend candidates are scored on "
                         "(default auto: compiled batched rounds when the "
                         "family has a kernel — see sim_perf.py; numpy = "
                         "the reference per-candidate loop)")
    args = ap.parse_args()
    report, bench = run(full=args.full, backend=args.backend)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    print(report.summary())
    rv = bench["race_vs_exhaustive"]
    print(f"\nracing vs exhaustive on the {rv['grid_size']}-config grid: "
          f"same winner = {rv['same_winner']} at "
          f"{rv['race_frac'] * 100:.0f}% of the sweep budget")
    print(f"wrote {args.out} (tune wall clock "
          f"{bench['tuner_wall_clock_s']:.1f}s)")


if __name__ == "__main__":
    main()
