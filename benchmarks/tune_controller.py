"""Controller-scoping benchmark: autonomously tune the predictive autoscaler
on the flash-crowd MSET scenario and pin the tuned-vs-default headline.

``tune()`` races Latin-hypercube candidates over (horizon_s, window_bins,
headroom) through the fleet simulator (paired Monte Carlo replicates,
successive-halving + SPRT culling), fits the controller response surface,
and returns the winner. The headline this benchmark pins (and
``tools/check_bench.py`` gates against ``benchmarks/baselines/tuner.json``):

* the tuned policy dominates the hand-set ``default_policies`` counterpart
  (attainment >=, $/hr <=, at least one strict) on the same paired draws;
* the fitted response surface reports r2 >= 0.8 over the surviving region;
* racing spends <= 40% of the naive grid x seed budget and returns the same
  winner as the exhaustive sweep;
* tuner wall clock stays within 2x the committed baseline.

Results land in ``BENCH_tuner.json`` (CI artifact).

    PYTHONPATH=src python benchmarks/tune_controller.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.recommender import recommend
from repro.fleet import (FleetConfig, Objective, PredictivePolicy,
                         StaticPolicy, TuningBudget, evaluate_candidates,
                         exhaustive, flash_crowd_trace, mset_scenario, race,
                         tiered_sla_workload, tune, tuning_scenario)

QUOTA = 16              # per-pool replica quota, matching fleet_scaling.py
COLD_START_S = 60.0
SEED = 0
# the hand-set config default_policies() ships (PR 1..3's controller knobs)
DEFAULT_PARAMS = {"horizon_s": 2 * COLD_START_S, "window_bins": 12,
                  "headroom": 0.85}


def _eval_record(ev):
    return {
        "params": {k: (round(v, 6) if isinstance(v, float) else v)
                   for k, v in sorted(ev.params.items())},
        "usd_per_hour": ev.mean_cost(),
        "usd_per_hour_ci95": ev.cost_ci(),
        "worst_class_attainment": ev.mean_attainment(),
        "attainment_ci95": ev.attainment_ci(),
        "p99_s": ev.p99_s(),
        "drop_rate": ev.mean_drop_rate(),
        "n_seeds": ev.n_seeds,
    }


def build_scenario(full: bool = False, backend: str = "auto", *,
                   n_seeds: int = None, duration_s: float = None):
    """The flash-crowd predictive-tuning scenario. ``sim_perf.py`` builds
    its grid cells through this same function (overriding only
    ``n_seeds``/``duration_s``), so its gated headline really is this
    benchmark's round at this benchmark's scale."""
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    duration = duration_s if duration_s is not None \
        else (7200.0 if full else 3600.0)
    n_seeds = n_seeds if n_seeds is not None else (16 if full else 12)
    # size the flash crowd so the quota CAN hold the peak (~14 of 16
    # replicas): the SLO is achievable and the controller's knobs — not raw
    # capacity — decide cost and attainment
    base_rate = 3.5 * svc.max_throughput
    trace = flash_crowd_trace(base_rate, duration, dt_s=5.0, peak_mult=4.0,
                              burst_width_s=duration / 30,
                              n_seeds=n_seeds, seed=SEED + 2)
    shape = recommend(scenario.rows_at(), scenario.constraint()).shape.name
    fleet = FleetConfig((scenario.pool_for(shape, cold_start_s=COLD_START_S,
                                           max_replicas=QUOTA),))
    return tuning_scenario(scenario, trace, PredictivePolicy, fleet=fleet,
                           cold_start_s=COLD_START_S, backend=backend)


def _jo_record(ev):
    return {"params": dict(ev.params), "score": ev.mean_score(),
            "usd_per_hour": ev.mean_cost(),
            "worst_class_attainment": ev.mean_attainment()}


def run_joint_optimum(full: bool = False, *, n_seeds: int = None,
                      duration_s: float = None, backend: str = "auto"):
    """The why-scope-jointly case: on the tiered-SLA workload, search
    (discipline x n_replicas) one dimension at a time the way a manual
    scoping pass would — size the fleet under the default FIFO discipline,
    then pick the discipline at that size — and compare against the joint
    exhaustive optimum on the same paired draws.

    The dimensions couple: a deadline-aware discipline meets the tiers with
    FEWER replicas than FIFO needs (see fleet_scaling.py's gated headline),
    so greedy locks in FIFO's fleet size and overpays for it. The gate pins
    that the joint optimum differs from the greedy assembly and scores
    strictly better."""
    scenario = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8,
                             slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    duration = duration_s if duration_s is not None \
        else (1800.0 if full else 900.0)
    seeds = n_seeds if n_seeds is not None else (8 if full else 6)
    # 6x the per-replica throughput, like fleet_scaling's tiered sweep: the
    # gold tier's deadline is tight enough that ordering — not just capacity
    # — decides feasibility
    workload = tiered_sla_workload(6.0 * svc.max_throughput, duration,
                                   dt_s=5.0, n_seeds=seeds, seed=3)
    shape = recommend(scenario.rows_at(), scenario.constraint()).shape.name
    fleet = FleetConfig((scenario.pool_for(shape, cold_start_s=COLD_START_S,
                                           max_replicas=QUOTA),))
    ts = tuning_scenario(scenario, workload, StaticPolicy, fleet=fleet,
                         cold_start_s=COLD_START_S, discipline="fifo",
                         backend=backend)
    objective = Objective(min_attainment=0.99, penalty_usd_per_hour=1e5)
    disciplines = ("fifo", "priority", "edf")
    sizes = range(2, QUOTA + 1)
    grid = [{"discipline": d, "n_replicas": n}
            for d in disciplines for n in sizes]
    evals = {(e.params["discipline"], e.params["n_replicas"]): e
             for e in evaluate_candidates(ts, grid, objective)}

    # greedy pass 1: fleet size under the default discipline
    n_fifo = min(sizes, key=lambda n: evals[("fifo", n)].mean_score())
    # greedy pass 2: discipline at that size
    disc = min(disciplines,
               key=lambda d: evals[(d, n_fifo)].mean_score())
    greedy = evals[(disc, n_fifo)]
    joint = min(evals.values(), key=lambda e: e.mean_score())
    return {
        "scenario": workload.name,
        "attainment_bar": objective.min_attainment,
        "grid_size": len(grid),
        "n_seed_replicates": ts.n_seeds,
        "per_dim": {"n_under_fifo": n_fifo, "discipline_at_that_n": disc},
        "greedy": _jo_record(greedy),
        "joint": _jo_record(joint),
        "joint_beats_greedy": bool(joint.mean_score()
                                   < greedy.mean_score()),
    }


def run(full: bool = False, backend: str = "auto"):
    ts = build_scenario(full, backend=backend)
    space = PredictivePolicy.param_space()
    # the quota can hold the whole burst, so demand full attainment and make
    # any shortfall unprofitable: the race is then purely about who meets the
    # SLO cheapest — the headline the gate pins
    objective = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    budget = TuningBudget(n_candidates=32 if full else 24)

    t0 = time.perf_counter()
    report = tune(ts, space, objective, budget, seed=SEED,
                  baseline=DEFAULT_PARAMS)
    tune_wall = time.perf_counter() - t0

    # racing-vs-exhaustive on a small grid: same winner, fraction of budget
    grid = space.grid(2)
    rr = race(ts, grid, objective, init_seeds=budget.init_seeds,
              eta=budget.eta)
    ex = exhaustive(ts, grid, objective)
    same_winner = rr.winner.params == ex.winner.params

    bench = {
        "benchmark": "controller_tuning",
        "full": full,
        "backend": backend,
        "scenario": ts.name,
        "policy_family": report.policy_family,
        "space": {d.name: type(d).__name__ for d in space.dims},
        "n_candidates": budget.n_candidates,
        "n_seed_replicates": ts.n_seeds,
        "headline": {
            "tuned": _eval_record(report.winner),
            "default": _eval_record(report.baseline),
            "tuned_dominates_default": report.dominates_baseline(),
        },
        "surface_r2": report.surface_r2,
        "surface_dims": list(report.surface_names),
        "budget": {
            "sims_used": report.sims_used,
            "full_budget": report.full_budget,
            "frac": report.budget_frac,
        },
        "race_vs_exhaustive": {
            "grid_size": len(grid),
            "same_winner": bool(same_winner),
            "race_frac": rr.budget_frac,
            "race_winner": rr.winner.params,
            "exhaustive_winner": ex.winner.params,
        },
        "frontier": [_eval_record(e) for e in report.frontier],
        "joint_optimum": run_joint_optimum(full, backend=backend),
        "tuner_wall_clock_s": tune_wall,
    }
    return report, bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_tuner.json",
                    help="JSON results path (CI uploads this artifact)")
    ap.add_argument("--backend", default="auto",
                    choices=("numpy", "jax", "auto"),
                    help="simulator backend candidates are scored on "
                         "(default auto: compiled batched rounds when the "
                         "family has a kernel — see sim_perf.py; numpy = "
                         "the reference per-candidate loop)")
    args = ap.parse_args()
    report, bench = run(full=args.full, backend=args.backend)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    print(report.summary())
    rv = bench["race_vs_exhaustive"]
    print(f"\nracing vs exhaustive on the {rv['grid_size']}-config grid: "
          f"same winner = {rv['same_winner']} at "
          f"{rv['race_frac'] * 100:.0f}% of the sweep budget")
    jo = bench["joint_optimum"]
    print(f"joint optimum on {jo['scenario']}: greedy per-dim picks "
          f"{jo['greedy']['params']} (${jo['greedy']['usd_per_hour']:.2f}/hr)"
          f", joint picks {jo['joint']['params']} "
          f"(${jo['joint']['usd_per_hour']:.2f}/hr) — joint beats greedy = "
          f"{jo['joint_beats_greedy']}")
    print(f"wrote {args.out} (tune wall clock "
          f"{bench['tuner_wall_clock_s']:.1f}s)")


if __name__ == "__main__":
    main()
