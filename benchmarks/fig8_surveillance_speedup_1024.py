"""Paper Figure 8: surveillance speedup at 1024 signals (the big-IoT use case —
paper reports the speedup exceeding 9000x as use cases grow)."""
from __future__ import annotations

from benchmarks.fig7_surveillance_speedup_64 import run as run7


def run(full: bool = False):
    # full grids use n_memvec in 2^11..2^13 (paper Fig. 8); reduced uses smaller
    return run7(full=full, n_signals=1024 if full else 256)


if __name__ == "__main__":
    run()
