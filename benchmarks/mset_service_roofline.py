"""Roofline dry-run of the MSET2 surveillance SERVICE on the production pod —
the paper's own workload as a pjit'd cloud service (DESIGN.md §2).

Run inside a 512-fake-device process (the dry-run owns XLA_FLAGS):
    PYTHONPATH=src python -m benchmarks.mset_service_roofline
"""
from __future__ import annotations

import os


def main():
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    import jax
    from repro.core.cost_model import roofline
    from repro.core.hlo_analysis import analyze_compiled
    from repro.launch.mesh import make_production_mesh
    from repro.mset.service import _estimate_sharded
    from jax.sharding import NamedSharding, PartitionSpec as P
    import jax.numpy as jnp
    from functools import partial

    mesh = make_production_mesh()
    chips = mesh.devices.size
    print("name,us_per_call,derived")
    # customer-B-like service shard: 4096 signals, 8192 memvecs, 16384-obs window
    for (n_sig, n_mv, batch) in [(64, 512, 4096), (1024, 4096, 8192),
                                 (4096, 8192, 16384)]:
        s_D = NamedSharding(mesh, P("model", None))
        s_G = NamedSharding(mesh, P("model", None))
        s_v = NamedSharding(mesh, P(None))
        s_X = NamedSharding(mesh, P("data", None))
        fn = jax.jit(partial(_estimate_sharded, gamma=1.0, kind="inverse_distance"),
                     in_shardings=(s_D, s_G, s_v, s_v, s_X),
                     out_shardings=(s_X, s_X))
        args = (jax.ShapeDtypeStruct((n_mv, n_sig), jnp.float32),
                jax.ShapeDtypeStruct((n_mv, n_mv), jnp.float32),
                jax.ShapeDtypeStruct((n_sig,), jnp.float32),
                jax.ShapeDtypeStruct((n_sig,), jnp.float32),
                jax.ShapeDtypeStruct((batch, n_sig), jnp.float32))
        with mesh:
            compiled = fn.lower(*args).compile()
        cost = analyze_compiled(compiled, n_devices=chips)
        t = roofline(cost.flops, cost.bytes_accessed, cost.collective_bytes, chips)
        print(f"mset_service_{n_sig}sig_{n_mv}mv_{batch}obs,"
              f"{t.t_step*1e6:.1f},dom={t.dominant};"
              f"mem={cost.peak_memory_per_device/2**30:.2f}GiB;"
              f"coll={cost.collective_bytes/1e9:.2f}GB")


if __name__ == "__main__":
    main()
