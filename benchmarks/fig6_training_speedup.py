"""Paper Figure 6: training speedup surface vs (n_signals, n_memvec), with the
MSET constraint n_memvec >= 2*n_signals (the paper's missing surface region).

Paper: CPU vs CUDA-GPU measured. Here: XLA:CPU measured vs TPU-v5e roofline
(analytic, 1 chip) — labelled 'roofline-derived' per DESIGN.md §3.
"""
from __future__ import annotations


from benchmarks.common import (measured_training, mset_training_flops_bytes,
                               tpu_roofline_time)
from repro.core import grid_to_matrix, render_ascii_surface
from repro.core.scoping import CellResult


def run(full: bool = False):
    sigs = [32, 64, 128, 256, 512, 1024] if full else [32, 64, 128]
    mvs = [128, 512, 2048, 8192] if full else [128, 256, 512]
    rows = []
    for ns in sigs:
        for mv in mvs:
            if mv < 2 * ns:
                continue  # paper's training constraint -> missing surface region
            t_cpu = measured_training(ns, mv, n_obs=max(2 * mv, 1024))
            f, b = mset_training_flops_bytes(ns, mv, max(2 * mv, 1024))
            t_tpu = tpu_roofline_time(f, b)
            su = t_cpu / t_tpu
            rows.append(CellResult(params={"n_signals": ns, "n_memvec": mv},
                                   mean_s=su))
            print(f"fig6,train_speedup,n_sig={ns},n_mv={mv},"
                  f"cpu={t_cpu*1e3:.1f}ms,tpu_roofline={t_tpu*1e6:.1f}us,"
                  f"speedup={su:.0f}x")
    xs, ys, Z = grid_to_matrix(rows, "n_memvec", "n_signals")
    print(render_ascii_surface(xs, ys, Z, "n_memvec", "n_signals",
                               "Fig6-style: training speedup factor "
                               "(measured CPU / TPU roofline); '·' = constraint"))
    return rows


if __name__ == "__main__":
    run()
