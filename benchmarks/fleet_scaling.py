"""Fleet-scaling sweep: policies x traces x fleet configurations (homogeneous
per-shape fleets AND mixed-shape fleets), under per-instance-type cloud
quotas, plus a tiered-SLA multi-class sweep across scheduling disciplines.

For each homogeneous candidate shape, replicas of that shape serve the same
traces — the four synthetic ``standard_traces`` plus the bundled
Azure-Functions-style day replayed via ``load_trace_csv`` — under each
autoscaling policy; a mixed v5e-4+v5e-16 fleet runs the
heterogeneous predictive policy against the same traces. Every pool is capped
at ``QUOTA`` replicas (clouds limit instance counts per type), which is what
makes the comparison honest: a flash crowd can outgrow the small shape's
quota, and a big-shape-only fleet overpays at baseline — the mixed fleet
splits the difference.

The tiered-SLA sweep serves a gold/silver/bronze mixed-class flash-crowd
workload under FIFO, strict priority, and EDF, sweeping static fleet sizes to
the cheapest one meeting *every* class's SLO: the headline is that
EDF/priority meet the tiered SLOs at measurably lower cost than
capacity-equivalent FIFO (which must be provisioned for the peak because gold
queues behind bronze backlog).

Results land in ``BENCH_fleet.json`` (CI artifact); ``tools/check_bench.py``
gates PRs against the committed baseline in ``benchmarks/baselines/``.

    PYTHONPATH=src python benchmarks/fleet_scaling.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.fleet import (HeterogeneousPredictivePolicy, StaticPolicy,
                         class_table, comparison_table,
                         cost_efficiency_table, default_policies,
                         load_trace_csv, mset_scenario, simulate,
                         simulate_fleet, standard_traces, summarize,
                         tiered_sla_workload)

QUOTA = 16              # max replicas per pool (per-instance-type quota)
COLD_START_S = 60.0
MIXED_SHAPES = ("v5e-4", "v5e-16")
DISCIPLINE_SWEEP = ("fifo", "priority", "edf")
TIERED_ATTAINMENT_BAR = 0.99    # every class must clear this
REPLAY_CSV = os.path.join(os.path.dirname(__file__), "data",
                          "azure_functions_day.csv")


def replay_traces(mean_rate: float, n_seeds: int):
    """The bundled Azure-Functions-style day, rescaled so its mean matches
    the synthetic traces' sustained rate — the ROADMAP's first real-trace
    step, riding the same policy x shape sweep as ``standard_traces``."""
    return [load_trace_csv(REPLAY_CSV, rate_col="requests_per_s", dt_s=300.0,
                           mean_rate_per_s=mean_rate, n_seeds=n_seeds,
                           seed=11, name="azure-day")]


def _record(report, sim, wall_s):
    return {
        "policy": report.policy,
        "discipline": report.discipline,
        "trace": report.trace,
        "shapes": report.shape,
        "pools": [{"shape": p.service.shape.name,
                   "cold_start_s": p.cold_start_s,
                   "max_replicas": p.max_replicas}
                  for p in sim.fleet.pools],
        "slo_s": report.slo_s,
        "slo_attainment": report.slo_attainment,
        "p50_s": report.p50_s,
        "p99_s": report.p99_s,
        "drop_rate": report.drop_rate,
        "mean_billed_replicas": report.mean_replicas,
        "usd_per_hour": report.usd_per_hour,
        "wall_clock_s": wall_s,
    }


def run(full: bool = False, scenario=None):
    scenario = scenario or mset_scenario(n_signals=1024, n_memvec=4096,
                                         fleet=8, slo_s=1.0)
    shape_names = [r.shape_name for r in scenario.rows_at()]
    if not full:
        shape_names = shape_names[:4]
    # standard_traces scales the flash-crowd burst width as duration/30; keep
    # it a few cold-start periods wide, or no policy can outrun the burst
    duration = 7200.0 if full else 3600.0
    n_seeds = 16 if full else 8
    base_thr = scenario.service_for(scenario.cheapest_shape()).max_throughput
    # ~9 small-shape replicas of sustained demand: the flash-crowd peak
    # (4x mean) then needs ~36 — past the small shapes' quota
    mean_rate = 9.0 * base_thr
    reports, records = [], []

    def _run(trace, make_sim):
        t0 = time.perf_counter()
        sim = make_sim(trace)
        wall = time.perf_counter() - t0
        rep = summarize(sim)
        reports.append(rep)
        records.append(_record(rep, sim, wall))

    for shape_name in shape_names:
        service = scenario.service_for(shape_name)
        # restrict scoping rows to the swept shape so the predictive policy's
        # recommend() call sizes against it
        rows = [r for r in scenario.rows if r.shape_name == shape_name]
        try:
            policies = default_policies(
                rows, scenario.constraint(), scenario.units_per_step,
                static_replicas=min(
                    int(mean_rate / (0.85 * service.max_throughput)) + 1,
                    QUOTA),
                cold_start_s=COLD_START_S)
        except ValueError:            # shape infeasible for the SLO
            continue
        for trace in (standard_traces(mean_rate, duration, dt_s=5.0,
                                      n_seeds=n_seeds)
                      + replay_traces(mean_rate, n_seeds)):
            for policy in policies:   # simulate() resets policy state
                _run(trace, lambda tr, p=policy, s=service: simulate(
                    tr, s, p, slo_s=scenario.slo_s,
                    cold_start_s=COLD_START_S, max_replicas=QUOTA))

    # the mixed fleet: fine-grained baseline + coarse burst capacity
    fleet = scenario.fleet_for(list(MIXED_SHAPES), cold_start_s=COLD_START_S,
                               max_replicas=QUOTA)
    hetero = HeterogeneousPredictivePolicy(
        scenario.rows, scenario.constraint(), scenario.units_per_step, fleet,
        horizon_s=2 * COLD_START_S)
    for trace in (standard_traces(mean_rate, duration, dt_s=5.0,
                                  n_seeds=n_seeds)
                  + replay_traces(mean_rate, n_seeds)):
        _run(trace, lambda tr: simulate_fleet(tr, fleet, hetero,
                                              slo_s=scenario.slo_s))
    return reports, records


def _class_record(report, n_replicas):
    return {
        "discipline": report.discipline,
        "replicas": n_replicas,
        "usd_per_hour": report.usd_per_hour,
        "worst_class_attainment": report.worst_class_attainment(),
        "class_attainment": {c.name: c.attainment
                             for c in report.class_reports},
        "class_p99_s": {c.name: c.p99_s for c in report.class_reports},
    }


def run_tiered(full: bool = False, scenario=None):
    """Tiered-SLA mixed-class sweep: for each discipline, the cheapest static
    fleet meeting every class SLO at >= ``TIERED_ATTAINMENT_BAR``; plus FIFO
    evaluated at the EDF winner's capacity (the capacity-equivalent
    comparison the headline rests on)."""
    scenario = scenario or mset_scenario(n_signals=1024, n_memvec=4096,
                                         fleet=8, slo_s=1.0)
    service = scenario.service_for(scenario.cheapest_shape())
    duration = 7200.0 if full else 3600.0
    n_seeds = 16 if full else 8
    wl = tiered_sla_workload(6.0 * service.max_throughput, duration,
                             dt_s=5.0, n_seeds=n_seeds, seed=3)
    cheapest = {}                 # discipline -> (n, report)
    by_n = {}                     # (discipline, n) -> report
    for disc in DISCIPLINE_SWEEP:
        for n in range(2, QUOTA + 1):
            rep = summarize(simulate(wl, service, StaticPolicy(n),
                                     discipline=disc, initial_replicas=n,
                                     max_replicas=QUOTA))
            by_n[(disc, n)] = rep
            if rep.worst_class_attainment() >= TIERED_ATTAINMENT_BAR:
                cheapest[disc] = (n, rep)
                break
    summary = {
        "workload": {
            "tiers": [{"name": c.name, "slo_s": c.slo_s,
                       "priority": c.priority} for c in wl.classes],
            "base_rate_per_s": 6.0 * service.max_throughput,
            "duration_s": duration,
            "n_seeds": n_seeds,
        },
        "shape": service.shape.name,
        "attainment_bar": TIERED_ATTAINMENT_BAR,
        "cheapest_feasible": {d: _class_record(rep, n)
                              for d, (n, rep) in cheapest.items()},
    }
    # capacity-equivalent FIFO: what FIFO does with the EDF winner's fleet
    if "edf" in cheapest:
        n_edf = cheapest["edf"][0]
        rep = by_n.get(("fifo", n_edf))
        if rep is None:
            rep = summarize(simulate(wl, service, StaticPolicy(n_edf),
                                     discipline="fifo",
                                     initial_replicas=n_edf,
                                     max_replicas=QUOTA))
        summary["fifo_at_edf_capacity"] = _class_record(rep, n_edf)
    return summary, cheapest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="JSON results path (CI uploads this artifact)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    reports, records = run(full=args.full)
    tiered, cheapest = run_tiered(full=args.full)
    bench = {
        "benchmark": "fleet_scaling",
        "full": args.full,
        "quota_per_pool": QUOTA,
        "cold_start_s": COLD_START_S,
        "total_wall_clock_s": time.perf_counter() - t0,
        "records": records,
        "tiered_sla": tiered,
    }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    print(comparison_table(reports))
    print("\ncheapest fleet meeting >=99% SLO per trace "
          f"(quota {QUOTA} replicas/pool):")
    print(cost_efficiency_table(reports))
    print("\ntiered-SLA mixed-class sweep (cheapest feasible fleet per "
          "discipline, every class >= "
          f"{TIERED_ATTAINMENT_BAR * 100:.0f}%):")
    print(class_table([rep for _, rep in cheapest.values()]))
    if "fifo_at_edf_capacity" in tiered:
        eq = tiered["fifo_at_edf_capacity"]
        print(f"\nFIFO at the EDF winner's capacity ({eq['replicas']} "
              "replicas): worst class attainment "
              f"{eq['worst_class_attainment'] * 100:.1f}% "
              f"(bar {TIERED_ATTAINMENT_BAR * 100:.0f}%)")
    print(f"\nwrote {len(records)} records + tiered summary to {args.out}")


if __name__ == "__main__":
    main()
