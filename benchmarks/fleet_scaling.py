"""Fleet-scaling sweep: policies x traces x fleet configurations (homogeneous
per-shape fleets AND mixed-shape fleets), under per-instance-type cloud quotas.

For each homogeneous candidate shape, replicas of that shape serve the same
trace under each autoscaling policy; a mixed v5e-4+v5e-16 fleet runs the
heterogeneous predictive policy against the same traces. Every pool is capped
at ``QUOTA`` replicas (clouds limit instance counts per type), which is what
makes the comparison honest: a flash crowd can outgrow the small shape's
quota, and a big-shape-only fleet overpays at baseline — the mixed fleet
splits the difference. Results land in ``BENCH_fleet.json`` (CI artifact) so
the perf/cost trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/fleet_scaling.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.fleet import (HeterogeneousPredictivePolicy, comparison_table,
                         cost_efficiency_table, default_policies,
                         mset_scenario, simulate, simulate_fleet,
                         standard_traces, summarize)

QUOTA = 16              # max replicas per pool (per-instance-type quota)
COLD_START_S = 60.0
MIXED_SHAPES = ("v5e-4", "v5e-16")


def _record(report, sim, wall_s):
    return {
        "policy": report.policy,
        "trace": report.trace,
        "shapes": report.shape,
        "pools": [{"shape": p.service.shape.name,
                   "cold_start_s": p.cold_start_s,
                   "max_replicas": p.max_replicas}
                  for p in sim.fleet.pools],
        "slo_s": report.slo_s,
        "slo_attainment": report.slo_attainment,
        "p50_s": report.p50_s,
        "p99_s": report.p99_s,
        "drop_rate": report.drop_rate,
        "mean_billed_replicas": report.mean_replicas,
        "usd_per_hour": report.usd_per_hour,
        "wall_clock_s": wall_s,
    }


def run(full: bool = False, scenario=None):
    scenario = scenario or mset_scenario(n_signals=1024, n_memvec=4096,
                                         fleet=8, slo_s=1.0)
    shape_names = [r.shape_name for r in scenario.rows_at()]
    if not full:
        shape_names = shape_names[:4]
    # standard_traces scales the flash-crowd burst width as duration/30; keep
    # it a few cold-start periods wide, or no policy can outrun the burst
    duration = 7200.0 if full else 3600.0
    n_seeds = 16 if full else 8
    base_thr = scenario.service_for(scenario.cheapest_shape()).max_throughput
    # ~9 small-shape replicas of sustained demand: the flash-crowd peak
    # (4x mean) then needs ~36 — past the small shapes' quota
    mean_rate = 9.0 * base_thr
    reports, records = [], []

    def _run(trace, make_sim):
        t0 = time.perf_counter()
        sim = make_sim(trace)
        wall = time.perf_counter() - t0
        rep = summarize(sim)
        reports.append(rep)
        records.append(_record(rep, sim, wall))

    for shape_name in shape_names:
        service = scenario.service_for(shape_name)
        # restrict scoping rows to the swept shape so the predictive policy's
        # recommend() call sizes against it
        rows = [r for r in scenario.rows if r.shape_name == shape_name]
        try:
            policies = default_policies(
                rows, scenario.constraint(), scenario.units_per_step,
                static_replicas=min(
                    int(mean_rate / (0.85 * service.max_throughput)) + 1,
                    QUOTA),
                cold_start_s=COLD_START_S)
        except ValueError:            # shape infeasible for the SLO
            continue
        for trace in standard_traces(mean_rate, duration, dt_s=5.0,
                                     n_seeds=n_seeds):
            for policy in policies:   # simulate() resets policy state
                _run(trace, lambda tr, p=policy, s=service: simulate(
                    tr, s, p, slo_s=scenario.slo_s,
                    cold_start_s=COLD_START_S, max_replicas=QUOTA))

    # the mixed fleet: fine-grained baseline + coarse burst capacity
    fleet = scenario.fleet_for(list(MIXED_SHAPES), cold_start_s=COLD_START_S,
                               max_replicas=QUOTA)
    hetero = HeterogeneousPredictivePolicy(
        scenario.rows, scenario.constraint(), scenario.units_per_step, fleet,
        horizon_s=2 * COLD_START_S)
    for trace in standard_traces(mean_rate, duration, dt_s=5.0,
                                 n_seeds=n_seeds):
        _run(trace, lambda tr: simulate_fleet(tr, fleet, hetero,
                                              slo_s=scenario.slo_s))
    return reports, records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="JSON results path (CI uploads this artifact)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    reports, records = run(full=args.full)
    bench = {
        "benchmark": "fleet_scaling",
        "full": args.full,
        "quota_per_pool": QUOTA,
        "cold_start_s": COLD_START_S,
        "total_wall_clock_s": time.perf_counter() - t0,
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    print(comparison_table(reports))
    print(f"\ncheapest fleet meeting >=99% SLO per trace "
          f"(quota {QUOTA} replicas/pool):")
    print(cost_efficiency_table(reports))
    print(f"\nwrote {len(records)} records to {args.out}")


if __name__ == "__main__":
    main()
