"""Fleet-scaling sweep: policies x traces x catalog shapes.

For each candidate shape, replicas of that shape serve the same trace under
each autoscaling policy; the sweep surfaces which (shape, policy) pair meets
the SLO cheapest — the fleet-level extension of the paper's per-shape scoping
tables.

    PYTHONPATH=src python benchmarks/fleet_scaling.py [--full]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.report import markdown_table
from repro.fleet import (default_policies, mset_scenario, simulate,
                         standard_traces, summarize)


def run(full: bool = False, scenario=None):
    scenario = scenario or mset_scenario(n_signals=1024, n_memvec=4096,
                                         fleet=8, slo_s=1.0)
    shape_names = [r.shape_name for r in scenario.rows_at()]
    if not full:
        shape_names = shape_names[:4]
    duration = 7200.0 if full else 1800.0
    cold_start_s = 60.0
    reports = []
    for shape_name in shape_names:
        service = scenario.service_for(shape_name)
        # restrict scoping rows to the swept shape so the predictive policy's
        # recommend() call sizes against it
        rows = [r for r in scenario.rows if r.shape_name == shape_name]
        mean_rate = 5.6 * service.max_throughput      # ~8 replicas at 70%
        try:
            policies = default_policies(
                rows, scenario.constraint(), scenario.units_per_step,
                static_replicas=7, cold_start_s=cold_start_s)
        except ValueError:            # shape infeasible for the SLO
            continue
        for trace in standard_traces(mean_rate, duration, dt_s=5.0,
                                     n_seeds=16 if full else 8):
            for policy in policies:   # simulate() resets policy state
                sim = simulate(trace, service, policy, slo_s=scenario.slo_s,
                               cold_start_s=cold_start_s)
                reports.append(summarize(sim))
    return reports


def best_per_trace(reports, min_attainment: float = 0.99) -> list:
    best = {}
    for r in reports:
        if r.slo_attainment < min_attainment:
            continue
        if r.trace not in best or r.usd_per_hour < best[r.trace].usd_per_hour:
            best[r.trace] = r
    return [best[k] for k in sorted(best)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    reports = run(full=args.full)
    from repro.fleet import REPORT_HEADERS, comparison_table
    print(comparison_table(reports))
    print("\ncheapest (shape, policy) meeting >=99% SLO per trace:")
    print(markdown_table(REPORT_HEADERS,
                         [r.row() for r in best_per_trace(reports)]))


if __name__ == "__main__":
    main()
