"""Scoping-oracle benchmark: offline tuner sweeps compiled into a
microsecond-latency lookup service, pinned end to end.

The experiment reuses the closed-loop benchmark's world (tuned PI
autoscaler on the MSET serving fleet, diurnal live trace, 2x mid-trace
service degradation) and adds the oracle on top:

* **build** — sweep ``tune()`` over a (mean rate x burstiness x SLO) grid
  of canonical traces on the nominal fleet and compile the winners into an
  ``OracleTable`` (the CI artifact);
* **query** — answer a held-out flash-crowd trace the sweep never saw;
  gate the measured latency (median <= 1 ms) and the *regret*: the
  oracle's config, freshly simulated, must score within 10% of a from-
  scratch ``tune()`` on that trace at the same attainment bar;
* **verify** — spot-check interior query points against fresh simulation
  (``verify_oracle``), pinning the oracle's cost-prediction error bound;
* **closed loop** — run the PR 8 headline drift case twice, warm re-tune
  alone vs oracle-first, and gate that the oracle arm recovers no later
  (and at the same segment, no costlier) while spending a fraction of the
  re-tune's simulations.

Results land in ``BENCH_oracle.json``; the compiled table in
``oracle_table.json`` (both CI artifacts).

    PYTHONPATH=src python benchmarks/oracle.py [--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from closed_loop import (ATTAIN_BAR, COLD_BINS, DRIFT_FACTOR, DT_S, SEED,
                         TUNE_BAR, build)

from repro.fleet import (ClosedLoopController, Objective, PIPolicy,
                         TuningBudget, TuningScenario, Workload,
                         evaluate_candidates, flash_crowd_trace, tune,
                         window_metrics)
from repro.fleet.oracle import (OracleGrid, OracleTable, ScopingOracle,
                                build_oracle, featurize, query_latency_us,
                                verify_oracle)

ORACLE_SEED = 7
HELDOUT_SEED = 4242         # trace seed the sweep never saw
GRID_DURATION_S = 1800.0
BURST_AXIS = (1.0, 1.6, 2.2)    # brackets the diurnal tail (~1.5) and the
#                                 held-out flash crowd (~1.8)
SLO_AXIS = (1.0, 2.0, 4.0)


def build_table(ts: TuningScenario, objective: Objective, *,
                full: bool, backend: str) -> OracleTable:
    """Sweep the grid on the *nominal* fleet: the closed loop maps a
    degraded world onto it by inflating the query's rate axis."""
    svc = ts.fleet.pools[0].service
    mt = svc.max_throughput
    grid = OracleGrid(
        mean_rates=(1.5 * mt, 3.0 * mt, 6.0 * mt, 12.0 * mt),
        burstiness=BURST_AXIS, slos=SLO_AXIS,
        duration_s=GRID_DURATION_S, dt_s=DT_S,
        n_seeds=4 if full else 3, seed=ORACLE_SEED)
    return build_oracle(
        grid, ts.fleet, PIPolicy, PIPolicy.param_space(),
        objective=objective,
        budget=TuningBudget(n_candidates=14 if full else 10, init_seeds=2),
        context=ts.context, max_queue=ts.max_queue, backend=backend,
        name="mset-oracle")


def heldout_flash_crowd(ts: TuningScenario, *, full: bool):
    """A flash-crowd trace strictly interior to the grid: mean rate between
    columns, burstiness ~1.8 between rows, fresh Monte Carlo seeds."""
    svc = ts.fleet.pools[0].service
    mt = svc.max_throughput
    tr = flash_crowd_trace(
        3.1 * mt, GRID_DURATION_S, dt_s=DT_S, peak_mult=2.4,
        burst_width_s=GRID_DURATION_S / 14, n_seeds=6 if full else 4,
        seed=HELDOUT_SEED)
    return Workload.from_trace(tr, float(ts.context["slo_s"]))


def heldout_regret(ts: TuningScenario, oracle: ScopingOracle,
                   objective: Objective, *, full: bool,
                   backend: str) -> dict:
    """Oracle answer vs a from-scratch tune() on the held-out trace, both
    freshly simulated on the same paired draws."""
    wl = heldout_flash_crowd(ts, full=full)
    ans = oracle.query(wl)
    if not ans.ok:
        return {"error": f"oracle refused the held-out trace: {ans.reason}",
                "features": ans.features.as_dict() if ans.features else None}
    scen = TuningScenario(
        name="heldout/flash-crowd", workload=wl, fleet=ts.fleet,
        policy_cls=PIPolicy, context=ts.context, max_queue=ts.max_queue,
        backend=backend)
    fresh = tune(scen, PIPolicy.param_space(), objective,
                 TuningBudget(n_candidates=14 if full else 12,
                              init_seeds=2), seed=SEED)
    evs = evaluate_candidates(scen, [dict(ans.params),
                                     dict(fresh.winner.params)], objective)
    o_ev, f_ev = evs
    regret = max(0.0, (o_ev.mean_score() - f_ev.mean_score())
                 / max(f_ev.mean_score(), 1e-9))
    return {
        "attainment_bar": ATTAIN_BAR,
        "features": ans.features.as_dict(),
        "oracle": {"params": dict(ans.params),
                   "cost_usd_hr": o_ev.mean_cost(),
                   "attainment": o_ev.mean_attainment(),
                   "score": o_ev.mean_score(),
                   "predicted_cost_usd_hr": ans.cost_usd_hr,
                   "latency_us": ans.latency_us, "exact": ans.exact},
        "fresh": {"params": dict(fresh.winner.params),
                  "cost_usd_hr": f_ev.mean_cost(),
                  "attainment": f_ev.mean_attainment(),
                  "score": f_ev.mean_score(),
                  "sims_used": fresh.sims_used},
        "regret": regret,
        "scenario": scen,         # reused by the agreement check (popped)
    }


def backend_agreement(ts: TuningScenario, heldout: dict,
                      objective: Objective) -> dict:
    """numpy vs jax on the held-out oracle evaluation: the answer the
    oracle ships must score the same on both simulator backends."""
    try:
        import jax  # noqa: F401
    except Exception as exc:            # pragma: no cover - no-jax machines
        return {"error": f"jax unavailable: {exc}"}
    scen = heldout.get("scenario")
    params = heldout.get("oracle", {}).get("params")
    if scen is None or params is None:
        return {"error": "held-out evaluation unavailable"}
    scores = {}
    for backend in ("numpy", "jax"):
        scen.backend = backend
        scores[backend] = evaluate_candidates(
            scen, [dict(params)], objective)[0].mean_score()
    return {"backends": ["numpy", "jax"],
            "numpy_score": scores["numpy"], "jax_score": scores["jax"],
            "max_score_delta": abs(scores["numpy"] - scores["jax"])}


def _arm_record(res, td: int, T: int) -> dict:
    swaps = [e.t_bin for e in res.events if e.kind == "swap"]
    post = window_metrics(res.sim, td, T)
    t_rec = min(swaps[0] + COLD_BINS, T - 1) if swaps else td
    rec = window_metrics(res.sim, t_rec, T)
    return {
        "swap_bin": swaps[0] if swaps else None,
        "n_alarms": res.n_alarms, "n_swaps": res.n_swaps,
        "post_drift_attainment": post.worst_class_attainment,
        "post_drift_usd_per_hour": post.usd_per_hour,
        "recovery_attainment": rec.worst_class_attainment,
        "active_params": res.active_params,
    }


def closed_loop_comparison(ts, case, incumbent, oracle: ScopingOracle,
                           objective: Objective, *, full: bool) -> dict:
    """The same drift case through both drift-response arms: warm re-tune
    alone (PR 8 behaviour) vs oracle-first with re-tune fallback."""
    td = case.drift_bins()[0]
    T = case.n_bins
    kw = dict(segment_bins=15,
              retune_budget=TuningBudget(n_candidates=16 if full else 14,
                                         init_seeds=2),
              objective=objective)
    res_rt = ClosedLoopController(ts, incumbent, **kw).run(case)
    res_or = ClosedLoopController(ts, incumbent, oracle=oracle, **kw).run(case)
    rt, orc = _arm_record(res_rt, td, T), _arm_record(res_or, td, T)
    rt["tune_sims"] = sum(r.sims_used for r in res_rt.retunes)
    # an oracle consultation costs one paired <= 3-candidate evaluation at
    # the live workload's full replicate budget per hit, plus any fallback
    # re-tunes on misses
    orc["hits"] = res_or.oracle_hits
    orc["misses"] = res_or.oracle_misses
    orc["consult_sims"] = (
        sum(e.detail.get("eval_sims", 0) for e in res_or.events
            if e.kind == "oracle-hit")
        + sum(r.sims_used for r in res_or.retunes))
    orc["query_latency_us"] = [round(a.latency_us, 1)
                               for a in res_or.oracle_answers]
    return {"attainment_bar": ATTAIN_BAR, "segment_bins": 15,
            "drift_bin": td, "n_bins": T,
            "retune": rt, "oracle": orc}


def run(full: bool = False, backend: str = "auto",
        table_out: str = None):
    t_start = time.perf_counter()
    ts, case = build(full, backend=backend)
    objective = Objective(min_attainment=TUNE_BAR,
                          penalty_usd_per_hour=2000.0)
    incumbent = tune(ts, PIPolicy.param_space(), objective,
                     TuningBudget(n_candidates=16 if full else 12,
                                  init_seeds=2), seed=SEED)

    t0 = time.perf_counter()
    table = build_table(ts, objective, full=full, backend=backend)
    build_wall = time.perf_counter() - t0
    if table_out:
        table.save(table_out)
    oracle = ScopingOracle(table)

    latency = query_latency_us(
        oracle, featurize(case.workload.total_trace()),
        float(ts.context["slo_s"]), n=200)
    heldout = heldout_regret(ts, oracle, objective, full=full,
                             backend=backend)
    agreement = backend_agreement(ts, heldout, objective)
    heldout.pop("scenario", None)
    verify = verify_oracle(table, ts.fleet, PIPolicy,
                           n_samples=5 if full else 3, seed=ORACLE_SEED,
                           context=ts.context, max_queue=ts.max_queue,
                           backend=backend)
    cl = closed_loop_comparison(ts, case, incumbent, oracle, objective,
                                full=full)

    bench = {
        "benchmark": "scoping_oracle",
        "full": full,
        "backend": backend,
        "scenario": ts.name,
        "build": dict(table.build_info,
                      grid_shape=list(table.grid.shape),
                      wall_clock_s=build_wall),
        "latency": latency,
        "heldout": heldout,
        "agreement": agreement,
        "verify": verify.to_json(),
        "closed_loop": cl,
        "drift": {"factor": DRIFT_FACTOR, "dt_s": DT_S},
        "wall_clock_s": time.perf_counter() - t_start,
    }
    return table, bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_oracle.json",
                    help="JSON results path (CI uploads this artifact)")
    ap.add_argument("--table-out", default="oracle_table.json",
                    help="compiled OracleTable artifact path")
    ap.add_argument("--backend", default="auto",
                    choices=("numpy", "jax", "auto"))
    args = ap.parse_args()
    table, bench = run(full=args.full, backend=args.backend,
                       table_out=args.table_out)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print(table.summary())
    lat, ho, cl = bench["latency"], bench["heldout"], bench["closed_loop"]
    print(f"query latency {lat['median_us']:.0f}us median / "
          f"{lat['p99_us']:.0f}us p99; held-out regret "
          f"{ho.get('regret', float('nan')) * 100:.1f}% "
          f"(oracle ${ho.get('oracle', {}).get('cost_usd_hr', 0):.2f}/hr @ "
          f"{ho.get('oracle', {}).get('attainment', 0):.4f})")
    print(f"drift recovery: oracle swap bin "
          f"{cl['oracle']['swap_bin']} ({cl['oracle']['consult_sims']} "
          f"sims) vs re-tune bin {cl['retune']['swap_bin']} "
          f"({cl['retune']['tune_sims']} sims)")
    print(bench["verify"] and
          f"verify: {bench['verify']['n']} spot-checks, max cost err "
          f"{bench['verify']['max_cost_err'] * 100:.1f}%")
    print(f"wrote {args.out} (wall clock {bench['wall_clock_s']:.1f}s)")


if __name__ == "__main__":
    main()
