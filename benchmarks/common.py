"""Shared benchmark utilities: measured-vs-roofline MSET cost probes."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.cost_model import V5E
from repro.mset import estimate, train
from repro.tpss import TPSSParams, synthesize


def time_call(fn, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts))


def mset_training_flops_bytes(n_sig: int, n_mv: int, n_obs: int):
    """Analytic FLOPs/bytes of MSET2 training (similarity + eigh + pinv)."""
    f_sim = 2.0 * n_mv * n_mv * n_sig
    f_eig = 10.0 * n_mv**3                 # eigh ~ O(10 m^3)
    f_pinv = 2.0 * n_mv**3
    flops = f_sim + f_eig + f_pinv
    bytes_ = 4.0 * (n_obs * n_sig + 2 * n_mv * n_sig + 3 * n_mv * n_mv)
    return flops, bytes_


def mset_surveil_flops_bytes(n_sig: int, n_mv: int, n_obs: int):
    """Analytic FLOPs/bytes of streaming surveillance over n_obs observations."""
    f_sim = 2.0 * n_mv * n_obs * n_sig
    f_w = 2.0 * n_mv * n_mv * n_obs
    f_rec = 2.0 * n_mv * n_obs * n_sig
    flops = f_sim + f_w + f_rec
    bytes_ = 4.0 * (n_obs * n_sig * 3 + n_mv * n_sig + n_mv * n_mv + n_mv * n_obs)
    return flops, bytes_


def tpu_roofline_time(flops: float, bytes_: float, chips: int = 1) -> float:
    return max(flops / (chips * V5E.peak_flops), bytes_ / (chips * V5E.hbm_bw))


def measured_training(n_sig: int, n_mv: int, n_obs: int, reps: int = 2) -> float:
    X = synthesize(jax.random.PRNGKey(n_sig * 131 + n_mv), TPSSParams(n_signals=n_sig, n_obs=n_obs))

    def run():
        m = train(X, n_memvec=n_mv)
        return m.Ginv
    return time_call(run, reps=reps)


def measured_surveillance(n_sig: int, n_mv: int, n_obs: int, reps: int = 2) -> float:
    key = jax.random.PRNGKey(n_sig * 17 + n_mv)
    X = synthesize(key, TPSSParams(n_signals=n_sig, n_obs=max(n_mv * 2, 512)))
    model = train(X, n_memvec=n_mv)
    Xs = synthesize(jax.random.PRNGKey(1), TPSSParams(n_signals=n_sig, n_obs=n_obs))

    def run():
        return estimate(model, Xs)[1]
    return time_call(run, reps=reps)
