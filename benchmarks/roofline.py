"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline feedstock).

Reads artifacts/dryrun/<mesh>/*.json and prints the per-cell three-term roofline,
dominant bottleneck, MODEL_FLOPS ratio, and roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.report import fmt_time, markdown_table


def load(mesh: str = "pod16x16", art_dir: str = "artifacts/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, mesh, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(mesh: str = "pod16x16", art_dir: str = "artifacts/dryrun") -> str:
    recs = load(mesh, art_dir)
    headers = ["arch", "shape", "t_comp", "t_mem", "t_coll", "t_step", "dominant",
               "mem/dev", "useful", "roofline_frac"]
    rows = []
    for r in recs:
        if r["status"] == "skip":
            rows.append([r["arch"], r["shape"], "SKIP", "", "", "", r["reason"][:40],
                         "", "", ""])
            continue
        if r["status"] == "error":
            rows.append([r["arch"], r["shape"], "ERROR", "", "", "",
                         r.get("error", "")[:40], "", "", ""])
            continue
        rows.append([
            r["arch"], r["shape"],
            fmt_time(r["t_compute"]), fmt_time(r["t_memory"]),
            fmt_time(r["t_collective"]), fmt_time(r["t_step"]), r["dominant"],
            f"{r['peak_memory_per_device']/2**30:.2f}GiB",
            f"{r['useful_flops_ratio']:.3f}" if r.get("useful_flops_ratio") else "—",
            f"{r['roofline_fraction']*100:.1f}%" if r.get("roofline_fraction") else "—",
        ])
    return markdown_table(headers, rows)


def csv(mesh: str = "pod16x16", art_dir: str = "artifacts/dryrun"):
    lines = []
    for r in load(mesh, art_dir):
        if r["status"] != "ok":
            lines.append(f"roofline,{r['arch']}__{r['shape']},0,{r['status']}")
            continue
        lines.append(f"roofline,{r['arch']}__{r['shape']},"
                     f"{r['t_step']*1e6:.0f},dom={r['dominant']};"
                     f"frac={(r.get('roofline_fraction') or 0)*100:.1f}%")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())
