"""Paper Figure 5: 3-D compute-cost contours of MSET2 streaming SURVEILLANCE vs
(n_memvec, n_observations, n_signals). Measured wall-clock + response surface."""
from __future__ import annotations

import numpy as np

from benchmarks.common import measured_surveillance
from repro.core import fit_response_surface, grid_to_matrix, render_ascii_surface
from repro.core.scoping import CellResult


def run(full: bool = False):
    sigs = [10, 20, 30, 40] if full else [10, 20]
    mvs = [128, 256, 512] if full else [64, 128]
    obs = [2048, 8192, 32768] if full else [1024, 4096]
    rows = []
    for ns in sigs:
        for mv in mvs:
            if mv < 2 * ns:
                continue
            for no in obs:
                t = measured_surveillance(ns, mv, no)
                rows.append(CellResult(params={"n_signals": ns, "n_memvec": mv,
                                               "n_observations": no}, mean_s=t))
                print(f"fig5,surveil_cost,n_sig={ns},n_mv={mv},n_obs={no},"
                      f"{t*1e6:.0f}us")
    names = ["n_signals", "n_memvec", "n_observations"]
    X = np.array([[r.params[n] for n in names] for r in rows], float)
    y = np.array([r.mean_s for r in rows], float)
    surf = fit_response_surface(names, X, y)
    print(f"# fig5 response surface r^2 = {surf.r2:.4f} "
          "(paper: surveillance cost dominated by observations+signals)")
    sub = [r for r in rows if r.params["n_memvec"] == (128 if not full else 256)]
    xs, ys, Z = grid_to_matrix(sub, "n_observations", "n_signals")
    print(render_ascii_surface(xs, ys, Z, "n_observations", "n_signals",
                               "Fig5-style: surveillance cost"))
    return rows, surf


if __name__ == "__main__":
    run()
