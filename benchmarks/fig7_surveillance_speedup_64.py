"""Paper Figure 7: surveillance speedup vs (n_observations, n_memvec) at 64
signals. Measured XLA:CPU vs TPU-v5e roofline."""
from __future__ import annotations

from benchmarks.common import (measured_surveillance, mset_surveil_flops_bytes,
                               tpu_roofline_time)
from repro.core import grid_to_matrix, render_ascii_surface
from repro.core.scoping import CellResult

N_SIGNALS = 64


def run(full: bool = False, n_signals: int = N_SIGNALS):
    mvs = [128, 512, 2048, 8192] if full else [128, 256, 512]
    obs = [1024, 4096, 16384, 65536] if full else [1024, 4096]
    rows = []
    for mv in mvs:
        if mv < 2 * n_signals:
            continue
        for no in obs:
            t_cpu = measured_surveillance(n_signals, mv, no)
            f, b = mset_surveil_flops_bytes(n_signals, mv, no)
            t_tpu = tpu_roofline_time(f, b)
            su = t_cpu / t_tpu
            rows.append(CellResult(params={"n_memvec": mv, "n_observations": no},
                                   mean_s=su))
            print(f"fig7,surveil_speedup_{n_signals},n_mv={mv},n_obs={no},"
                  f"cpu={t_cpu*1e3:.1f}ms,tpu_roofline={t_tpu*1e6:.1f}us,"
                  f"speedup={su:.0f}x")
    xs, ys, Z = grid_to_matrix(rows, "n_observations", "n_memvec")
    print(render_ascii_surface(xs, ys, Z, "n_observations", "n_memvec",
                               f"Fig7-style: surveillance speedup @ {n_signals} signals"))
    return rows


if __name__ == "__main__":
    run()
