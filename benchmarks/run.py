# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized grids (slow); default is reduced grids")
    ap.add_argument("--skip-roofline", action="store_true")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")

    from benchmarks import (fig4_training_cost, fig5_surveillance_cost,
                            fig6_training_speedup, fig7_surveillance_speedup_64,
                            fig8_surveillance_speedup_1024)

    rows4, surf4 = fig4_training_cost.run(full=args.full)
    print(f"fig4_surface_fit,0,r2={surf4.r2:.4f}")
    rows5, surf5 = fig5_surveillance_cost.run(full=args.full)
    print(f"fig5_surface_fit,0,r2={surf5.r2:.4f}")
    rows6 = fig6_training_speedup.run(full=args.full)
    smax = max(r.mean_s for r in rows6)
    print(f"fig6_max_training_speedup,0,{smax:.0f}x")
    rows7 = fig7_surveillance_speedup_64.run(full=args.full)
    print(f"fig7_max_surveil_speedup_64sig,0,{max(r.mean_s for r in rows7):.0f}x")
    rows8 = fig8_surveillance_speedup_1024.run(full=args.full)
    print(f"fig8_max_surveil_speedup_bigsig,0,{max(r.mean_s for r in rows8):.0f}x")

    if not args.skip_roofline and os.path.isdir("artifacts/dryrun/pod16x16"):
        from benchmarks import roofline
        print(roofline.csv())


if __name__ == "__main__":
    main()
