"""Sub-bin preemptive simulator core: the fine-Δt substep engine.

Covers the fidelity contract end to end: ``n_substeps=1`` (non-preemptive)
routes to the coarse core byte-identically on both backends; the substep
numpy engine and the compiled substep scan agree bit-for-bit; conservation
(served + dropped + terminal backlog == arrivals per class and seed) holds
across disciplines, substep counts, and preemption; the serve-order tables
(``table_pour`` / ``table_head_key``) and the full engine are validated
against brute-force per-request replays; ``resample_trace`` refines a trace
without changing its realization; the p95 report columns; and the substep
telemetry counters (off by default, bit-exact when off).
"""
import numpy as np
import pytest

from repro.core import get_shape
from repro.fleet import (CLASS_HEADERS, REPORT_HEADERS, FleetConfig,
                         PoolConfig, ReactivePolicy, StaticPolicy, class_table,
                         cohort_tables, interactive_batch_workload,
                         poisson_trace, resample_trace, simulate,
                         simulate_fleet, summarize, telemetry,
                         tiered_sla_workload)
from repro.fleet.discipline import (get_discipline, table_head_key,
                                    table_pour)
from repro.fleet.workload import ServiceModel

DISCIPLINES = ("fifo", "priority", "edf")

# every per-(seed, bin) array on SimResult — the bit-exactness surface
FIELDS = ("arrivals", "admitted", "served", "dropped", "queue", "replicas",
          "billed_replicas", "latency_s", "ok_served", "pool_replicas",
          "pool_served", "pool_billed", "utilization", "class_admitted",
          "class_served", "class_dropped", "class_queue", "class_ok")


def _service(t_fixed=3.0, t_unit=0.2, max_batch=8):
    # long fixed batch time relative to dt: batches genuinely span substeps,
    # so checkpoint-resume and preemption actually engage
    return ServiceModel("svc", get_shape("v5e-4"), t_fixed, t_unit, max_batch)


def _fleet(svc, replicas=2):
    return FleetConfig((PoolConfig(svc, cold_start_s=2.0, min_replicas=1,
                                   max_replicas=4,
                                   initial_replicas=replicas),))


def _workload(n_seeds=3, seed=7):
    return interactive_batch_workload(3.0, 60.0, dt_s=2.0, n_seeds=n_seeds,
                                      seed=seed)


def _policy():
    return ReactivePolicy(upper=0.7, lower=0.3, cooldown_s=4.0)


def _run(disc, backend, n_substeps, preemptive, **kw):
    return simulate_fleet(_workload(), _fleet(_service()), _policy(),
                          discipline=disc, backend=backend,
                          n_substeps=n_substeps, preemptive=preemptive, **kw)


def _assert_bitexact(a, b, label):
    for f in FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert np.array_equal(x, y), f"{label}: field {f!r} differs"
    assert np.array_equal(a.sojourn_values, b.sojourn_values), label
    assert np.array_equal(a.sojourn_weights, b.sojourn_weights), label


# ----------------- n_substeps=1 routes to the coarse core -------------------

@pytest.mark.parametrize("disc", DISCIPLINES)
def test_n1_nonpreemptive_is_coarse_core_numpy(disc):
    """``n_substeps=1, preemptive=False`` must be the *same code path* as the
    defaults — byte-identical results, no substep extras."""
    base = simulate_fleet(_workload(), _fleet(_service()), _policy(),
                          discipline=disc, backend="numpy")
    pinned = _run(disc, "numpy", 1, False)
    _assert_bitexact(base, pinned, f"{disc} numpy n=1")
    assert pinned.n_substeps == 1 and not pinned.preemptive
    assert pinned.preemptions is None and pinned.residue_work is None


@pytest.mark.parametrize("disc", DISCIPLINES)
def test_n1_nonpreemptive_is_coarse_core_jax(disc):
    pytest.importorskip("jax")
    base = simulate_fleet(_workload(), _fleet(_service()), _policy(),
                          discipline=disc, backend="jax")
    pinned = _run(disc, "jax", 1, False)
    _assert_bitexact(base, pinned, f"{disc} jax n=1")
    assert pinned.preemptions is None


# ----------------- substep numpy == substep jax, bit for bit ----------------

@pytest.mark.parametrize("disc", DISCIPLINES)
@pytest.mark.parametrize("n_substeps,preemptive",
                         [(1, True), (2, False), (2, True), (4, True)])
def test_substep_backends_bit_exact(disc, n_substeps, preemptive):
    """The numpy substep engine and the compiled substep scan mirror each
    other's float operation order one-for-one — results must be identical to
    the last bit, preemption accounting included."""
    pytest.importorskip("jax")
    a = _run(disc, "numpy", n_substeps, preemptive)
    b = _run(disc, "jax", n_substeps, preemptive)
    label = f"{disc} n={n_substeps} pre={preemptive}"
    _assert_bitexact(a, b, label)
    assert np.array_equal(a.preemptions, b.preemptions), label
    assert np.array_equal(a.preempted_work, b.preempted_work), label
    assert np.array_equal(a.residue_work, b.residue_work), label


# ----------------- conservation ---------------------------------------------

def _assert_conserved(sim):
    arrived = sim.class_admitted + sim.class_dropped       # (S, T, C)
    served = sim.class_served.sum(axis=1)
    dropped = sim.class_dropped.sum(axis=1)
    terminal = sim.class_queue[:, -1, :]
    lhs = served + dropped + terminal
    rhs = arrived.sum(axis=1)
    np.testing.assert_allclose(lhs, rhs, atol=1e-6, rtol=1e-9)


@pytest.mark.parametrize("disc", DISCIPLINES)
@pytest.mark.parametrize("n_substeps", [1, 2, 4, 8])
@pytest.mark.parametrize("preemptive", [False, True])
def test_conservation_seeded(disc, n_substeps, preemptive):
    """served + dropped + terminal backlog == arrivals per (class, seed) —
    the checkpoint-resume residue never loses or invents mass."""
    _assert_conserved(_run(disc, "numpy", n_substeps, preemptive,
                           max_queue=40.0))


def test_conservation_property():
    """Hypothesis sweep over workload shape, service terms, discipline and
    fidelity knobs (skipped where hypothesis isn't installed; the seeded
    sweep above always runs)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(
        rate=st.floats(0.5, 6.0),
        t_fixed=st.floats(0.1, 4.0),
        t_unit=st.floats(0.01, 0.5),
        disc=st.sampled_from(DISCIPLINES),
        n_substeps=st.sampled_from([1, 2, 4, 8]),
        preemptive=st.booleans(),
        seed=st.integers(0, 50),
        max_queue=st.one_of(st.none(), st.floats(5.0, 60.0)))
    def check(rate, t_fixed, t_unit, disc, n_substeps, preemptive, seed,
              max_queue):
        wl = interactive_batch_workload(rate, 40.0, dt_s=2.0, n_seeds=2,
                                        seed=seed)
        svc = _service(t_fixed=t_fixed, t_unit=t_unit)
        sim = simulate_fleet(wl, _fleet(svc), _policy(), discipline=disc,
                             backend="numpy", n_substeps=n_substeps,
                             preemptive=preemptive, max_queue=max_queue)
        _assert_conserved(sim)

    check()


# ----------------- preemption semantics -------------------------------------

def test_fifo_never_preempts():
    """Under FIFO the head-of-queue key can never undercut a running batch's
    key (keys are non-decreasing in arrival order), so even with
    ``preemptive=True`` no preemption ever fires."""
    sim = _run("fifo", "numpy", 4, True)
    assert sim.preemptions is not None
    assert not sim.preemptions.any()
    assert not sim.preempted_work.any()


def test_preemptive_disciplines_preempt_long_batches():
    for disc in ("priority", "edf"):
        on = _run(disc, "numpy", 4, True)
        off = _run(disc, "numpy", 4, False)
        assert on.preemptions.sum() > 0, disc
        assert on.preempted_work.sum() > 0, disc
        # non-preemptive runs never populate the checkpoint slot
        assert not off.preemptions.any(), disc


def test_preemption_helps_urgent_class_latency():
    """The point of preempting: light urgent traffic over long batch jobs —
    interrupting the running batch must not hurt (and typically improves)
    the urgent class's latency."""
    wl = interactive_batch_workload(2.0, 120.0, dt_s=2.0,
                                    interactive_frac=0.2, n_seeds=3, seed=5)
    svc = _service(t_fixed=4.0, t_unit=0.1, max_batch=16)

    def run(pre):
        return summarize(simulate(wl, svc, StaticPolicy(3),
                                  discipline="priority", initial_replicas=3,
                                  n_substeps=4, preemptive=pre))

    on, off = run(True), run(False)
    urgent_on, urgent_off = on.class_reports[0], off.class_reports[0]
    assert urgent_on.name == urgent_off.name == "interactive"
    assert urgent_on.p50_s <= urgent_off.p50_s + 1e-9
    assert urgent_on.p99_s <= urgent_off.p99_s + 1e-9


# ----------------- brute-force validation: serve-order tables ---------------

def _brute_tables(disc, classes, T, dt, masses):
    """Explicit per-cohort serve order for a (C, T) mass grid: cohorts
    sorted by (key, class, bin) — the per-request order every discipline
    reduces to at cohort granularity."""
    keys = get_discipline(disc).keys(classes, T, dt)
    C = len(classes)
    return sorted(((keys[c, t], c, t) for c in range(C) for t in range(T)))


def _brute_pour(mass, order, amt):
    """Serve ``amt`` from explicit cohort masses in key order; returns the
    per-class split and the largest key touched (-inf when nothing poured)."""
    C = mass.shape[0]
    split = np.zeros(C)
    last = -np.inf
    rem = float(amt)
    for k, c, tb in order:
        if rem <= 0.0:
            break
        m = mass[c, tb]
        if m <= 0.0:
            continue
        take = min(m, rem)
        mass[c, tb] = m - take
        split[c] += take
        rem -= take
        last = k
    return split, last


def _brute_head_key(mass, order):
    for k, c, tb in order:
        if mass[c, tb] > 0.0:
            return k
    return np.inf


@pytest.mark.parametrize("disc", DISCIPLINES)
def test_table_pour_and_head_key_match_bruteforce(disc):
    """The covering-prefix tables (what both substep engines pour through)
    against a literal walk of the cohort list in (key, class, bin) order:
    per-class splits, the preemption key of each pour, and the head-of-queue
    key, over many random partially-drained queue states."""
    wl = tiered_sla_workload(4.0, 60.0, dt_s=5.0, n_seeds=1, seed=5)
    classes = wl.classes
    C = len(classes)
    T = wl.total_trace().n_bins
    dt = wl.total_trace().dt_s
    tables = cohort_tables(disc, classes, T, dt)
    order = _brute_tables(disc, classes, T, dt, None)
    rng = np.random.default_rng(42)
    for trial in range(40):
        t_now = int(rng.integers(0, T))
        grid = rng.random((C, T)) * 5.0
        grid[:, t_now + 1:] = 0.0                  # not yet arrived
        # random partial drain, applied in serve order (any reachable state
        # of the engine's queue is a prefix-drained one)
        cum = np.zeros((1, C, T + 1))
        cum[0, :, 1:] = np.cumsum(grid, axis=1)
        cum[0, :, t_now + 1:] = cum[0, :, t_now + 1][:, None]
        done = np.zeros((1, C))
        mass = grid.copy()
        pre_drain = rng.random() * grid.sum()
        ds, _ = _brute_pour(mass, order, pre_drain)
        done[0] = ds
        # head key
        hk = table_head_key(cum, done, tables)
        assert hk[0] == pytest.approx(_brute_head_key(mass, order), abs=0), \
            f"{disc} trial {trial}: head key"
        # pour
        amt = rng.random() * (mass.sum() * 1.2)    # sometimes over-asks
        split, key = table_pour(cum, done, np.array([amt]), tables)
        bsplit, bkey = _brute_pour(mass.copy(), order, amt)
        np.testing.assert_allclose(split[0], bsplit, atol=1e-9,
                                   err_msg=f"{disc} trial {trial}: split")
        assert key[0] == bkey or (np.isneginf(key[0]) and np.isneginf(bkey)), \
            f"{disc} trial {trial}: pour key {key[0]} != {bkey}"


# ----------------- brute-force validation: the full engine ------------------

def _brute_engine(workload, svc, R, n, preemptive, disc):
    """Scalar per-seed replay of the substep engine on a constant-replica
    single pool, serving an explicit cohort list in (key, class, bin) order —
    no cumulative curves, no prefix tables. Returns per-(seed, bin, class)
    served mass and per-(seed, bin) preemption counts."""
    classes = workload.classes
    C = len(classes)
    trace = workload.total_trace()
    S, T = trace.arrivals.shape
    dt = trace.dt_s
    dt_sub = dt / n
    order = _brute_tables(disc, classes, T, dt, None)
    t_fixed, t_unit = svc.t_fixed, svc.t_per_unit
    max_b = float(svc.max_batch)
    arr_c = workload.arrivals.astype(float)
    served = np.zeros((S, T, C))
    pre_n = np.zeros((S, T))

    def progress(busy, busy_w, busy_k, tau, comp):
        w = busy_w
        if 0.0 < w <= tau:
            comp += busy
            return np.zeros(C), 0.0, -np.inf, tau - w
        if w > tau:
            return busy, w - tau, busy_k, 0.0
        return busy, busy_w, busy_k, tau

    for s in range(S):
        mass = np.zeros((C, T))
        new_total = np.zeros(C)
        busy, busy_w, busy_k = np.zeros(C), 0.0, -np.inf
        held, held_w, held_k = np.zeros(C), 0.0, -np.inf
        for t in range(T):
            mass[:, t] += arr_c[s, t]
            new_total += arr_c[s, t]
            for _ in range(n):
                tau = dt_sub
                comp = np.zeros(C)
                hk = _brute_head_key(mass, order)
                if preemptive and busy_w > 0.0 and hk < busy_k:
                    held = held + busy
                    held_w += busy_w
                    held_k = max(held_k, busy_k)
                    pre_n[s, t] += 1
                    busy, busy_w, busy_k = np.zeros(C), 0.0, -np.inf
                busy, busy_w, busy_k, tau = progress(busy, busy_w, busy_k,
                                                     tau, comp)
                if busy_w == 0.0:
                    if held_w > 0.0 and hk >= held_k:
                        busy, busy_w, busy_k = held, held_w, held_k
                        held, held_w, held_k = np.zeros(C), 0.0, -np.inf
                    else:
                        backlog = mass.sum()
                        if backlog > 0.0 and tau > 0.0 and R > 0:
                            b = min(max(np.ceil(backlog / R), 1.0), max_b)
                            bt = max(t_fixed + b * t_unit, 1e-12)
                            amt = min(backlog, R * b)
                            busy, _ = _brute_pour(mass, order, amt)
                            busy_w = bt
                            busy_k = hk    # rank by the most urgent cohort
                busy, busy_w, busy_k, tau = progress(busy, busy_w, busy_k,
                                                     tau, comp)
                pour2 = np.zeros(C)
                if busy_w == 0.0 and tau > 0.0 and R > 0:
                    backlog2 = mass.sum()
                    b2 = min(max(np.ceil(backlog2 / R), 1.0), max_b)
                    bt2 = max(t_fixed + b2 * t_unit, 1e-12)
                    cap = R * b2 / bt2 * tau
                    pour2, _ = _brute_pour(mass, order,
                                           min(max(backlog2, 0.0), cap))
                served[s, t] += comp + pour2
                # the engine's per-substep sub-eps fold of a drained class
                for c in range(C):
                    if mass[c].sum() <= 1e-9 + 1e-12 * new_total[c]:
                        mass[c] = 0.0
    return served, pre_n


@pytest.mark.parametrize("disc", DISCIPLINES)
@pytest.mark.parametrize("preemptive", [False, True])
def test_engine_matches_bruteforce_replay(disc, preemptive):
    """The full substep engine (prefix tables, vectorized over seeds) against
    the scalar brute-force replay: per-(seed, bin, class) served mass and
    exact preemption counts, on a constant-replica pool with long batches."""
    wl = interactive_batch_workload(2.0, 40.0, dt_s=2.0, n_seeds=2, seed=11)
    svc = _service()
    R = 2
    sim = simulate(wl, svc, StaticPolicy(R), discipline=disc,
                   initial_replicas=R, backend="numpy", n_substeps=4,
                   preemptive=preemptive)
    bserved, bpre = _brute_engine(wl, svc, R, 4, preemptive, disc)
    np.testing.assert_allclose(sim.class_served, bserved, atol=1e-9,
                               rtol=1e-9)
    if preemptive:
        np.testing.assert_array_equal(sim.preemptions, bpre)
    _assert_conserved(sim)


# ----------------- resample_trace -------------------------------------------

def test_resample_trace_conserves_arrivals():
    tr = poisson_trace(5.0, 120.0, dt_s=6.0, n_seeds=4, seed=3)
    fine = resample_trace(tr, 2.0, seed=9)
    k = 3
    assert fine.dt_s == 2.0
    assert fine.n_bins == tr.n_bins * k
    assert fine.duration_s == tr.duration_s
    # per-seed, per-coarse-bin totals conserved to the request
    regrouped = fine.arrivals.reshape(tr.n_seeds, tr.n_bins, k).sum(axis=2)
    np.testing.assert_array_equal(regrouped, tr.arrivals)
    # rate profile carries over unchanged (requests/s is grid-invariant)
    np.testing.assert_array_equal(fine.rate, np.repeat(tr.rate, k))


def test_resample_trace_seed_stable_and_identity():
    tr = poisson_trace(5.0, 60.0, dt_s=4.0, n_seeds=3, seed=0)
    a = resample_trace(tr, 1.0, seed=4)
    b = resample_trace(tr, 1.0, seed=4)
    np.testing.assert_array_equal(a.arrivals, b.arrivals)
    c = resample_trace(tr, 1.0, seed=5)
    assert not np.array_equal(a.arrivals, c.arrivals)
    assert resample_trace(tr, 4.0) is tr          # k == 1: unchanged
    with pytest.raises(ValueError, match="does not divide"):
        resample_trace(tr, 1.5)


def test_resampled_trace_drives_the_simulator():
    tr = poisson_trace(4.0, 60.0, dt_s=6.0, n_seeds=2, seed=1)
    fine = resample_trace(tr, 2.0)
    sim = simulate(fine, _service(t_fixed=0.5), StaticPolicy(2),
                   slo_s=5.0, initial_replicas=2, n_substeps=2)
    assert sim.served.shape == (2, fine.n_bins)
    np.testing.assert_array_equal(sim.arrivals.sum(axis=1),
                                  tr.arrivals.sum(axis=1))


# ----------------- p95 report columns ---------------------------------------

def test_report_p95_everywhere():
    assert REPORT_HEADERS.index("p95") == REPORT_HEADERS.index("p50") + 1
    assert REPORT_HEADERS.index("p99") == REPORT_HEADERS.index("p95") + 1
    assert CLASS_HEADERS.index("p95") == CLASS_HEADERS.index("p50") + 1
    rep = summarize(_run("priority", "numpy", 2, True))
    assert len(rep.row()) == len(REPORT_HEADERS)
    assert rep.p50_s <= rep.p95_s + 1e-12 <= rep.p99_s + 2e-12
    for c in rep.class_reports:
        assert c.p50_s <= c.p95_s + 1e-12 <= c.p99_s + 2e-12
    table = class_table([rep])
    assert "p95" in table.splitlines()[0]
    # single-class fallback row also carries p95
    single = summarize(simulate(poisson_trace(3.0, 60.0, dt_s=5.0, n_seeds=2),
                                _service(t_fixed=0.5), StaticPolicy(2),
                                slo_s=5.0, initial_replicas=2))
    assert "p95" in class_table([single]).splitlines()[0]
    assert len(class_table([single]).splitlines()) >= 3


# ----------------- telemetry ------------------------------------------------

def test_substep_telemetry_counters():
    with telemetry.session() as tel:
        sim = _run("edf", "numpy", 4, True)
    S = sim.arrivals.shape[0]
    pre = tel.metrics.get("fleet_preemptions_total")
    res = tel.metrics.get("fleet_residue_bins")
    work = tel.metrics.get("fleet_preempted_work")
    assert pre is not None and res is not None and work is not None
    assert pre.value == pytest.approx(float(sim.preemptions.sum()) / S)
    assert res.value == pytest.approx(
        float((sim.residue_work > 0.0).sum()) / S)
    np.testing.assert_allclose(work.array(),
                               sim.preempted_work.mean(axis=0))
    assert len(work.values) == sim.arrivals.shape[1]


def test_coarse_runs_emit_no_preemption_metrics():
    with telemetry.session() as tel:
        simulate_fleet(_workload(), _fleet(_service()), _policy(),
                       discipline="fifo", backend="numpy")
    assert tel.metrics.get("fleet_preemptions_total") is None
    assert tel.metrics.get("fleet_residue_bins") is None
    assert tel.metrics.get("fleet_preempted_work") is None


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_substep_bit_exact_under_telemetry(backend):
    """The opt-in contract extends to the substep core: recording must not
    perturb a single bit of the simulation."""
    if backend == "jax":
        pytest.importorskip("jax")
    off = _run("priority", backend, 2, True)
    with telemetry.session():
        on = _run("priority", backend, 2, True)
    _assert_bitexact(off, on, f"{backend} telemetry on/off")
    np.testing.assert_array_equal(off.preemptions, on.preemptions)
    np.testing.assert_array_equal(off.residue_work, on.residue_work)
