"""Sharding rules resolution + an 8-fake-device dry-run in a subprocess."""
import json
import os
import subprocess
import sys

import pytest

from repro.distributed.sharding import ShardingRules


class FakeMesh:
    """Duck-typed mesh for rule resolution (no jax devices needed)."""
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as np
        self.devices = np.zeros(tuple(sizes.values()))


def rules(sizes):
    return ShardingRules(FakeMesh(sizes))


def test_basic_resolution():
    r = rules({"data": 4, "model": 4})
    spec = r.spec_for(("embed", "mlp"), (512, 2048))
    assert tuple(spec) == ("data", "model")


def test_indivisible_dim_falls_back_to_replicated():
    r = rules({"data": 4, "model": 16})
    # kv_heads=1 can't shard over model=16 -> replicated
    spec = r.spec_for(("embed", "kv_heads", "head_dim"), (512, 1, 128))
    assert tuple(spec) == ("data",)


def test_mesh_axis_used_once():
    r = rules({"data": 4, "model": 4})
    spec = r.spec_for(("heads", "mlp"), (16, 2048))  # both map to model
    assert tuple(spec) == ("model",)


def test_pod_axis_tuple():
    r = rules({"pod": 2, "data": 4, "model": 4})
    spec = r.spec_for(("batch", None, None), (64, 128, 256))
    assert spec[0] == ("pod", "data")


def test_missing_pod_axis_dropped():
    r = rules({"data": 4, "model": 4})
    spec = r.spec_for(("batch",), (64,))
    assert spec[0] == "data"


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    r = ShardingRules(None)
    x = jnp.ones((4, 4))
    assert r.constrain(x, ("batch", None)) is x


DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.launch.dryrun import lower_cell
from repro.core.hlo_analysis import analyze_compiled
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.configs import get_config
from repro.configs import base as cfgbase
cfgbase.SHAPES["train_4k"] = cfgbase.ShapeSpec("train_4k", "train", 256, 8)
with mesh:
    lowered, aux = lower_cell("{arch}", "train_4k", mesh, n_microbatches=2,
                              cfg_base=get_config("{arch}", smoke=True))
    compiled = lowered.compile()
    cost = analyze_compiled(compiled, n_devices=8)
    print(json.dumps({{"flops": cost.flops, "coll": cost.collective_bytes,
                       "mem": cost.peak_memory_per_device,
                       "kinds": cost.collectives.bytes_by_kind}}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["minitron-4b", "olmoe-1b-7b", "mamba2-130m"])
def test_dryrun_8device_subprocess(arch):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET.format(arch=arch)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["coll"] > 0, "SPMD lowering must produce collectives"
    assert rec["mem"] > 0
