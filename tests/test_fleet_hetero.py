"""Heterogeneous fleets + exact request-cohort latency accounting.

The cohort model is validated against a brute-force per-request FIFO replay
(exact match on integer traces); billing fixes (launch-bin billing, scale-down
cancelling pending cold starts) are pinned by scripted-policy scenarios."""
from collections import deque

import numpy as np
import pytest

from repro.core import CellResult, RooflineTerms, get_shape
from repro.fleet import (FleetConfig, HeterogeneousPredictivePolicy,
                         PoolConfig, Policy, QueueProportionalPolicy,
                         StaticPolicy, cohort_metrics, flash_crowd_trace,
                         mset_scenario, poisson_trace, replay_trace,
                         service_model_from_cell, simulate, simulate_fleet,
                         summarize)


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch, "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw), units_per_step=kw.get("batch", 64))


class ScriptPolicy(Policy):
    """Replays a fixed target schedule (scalar per bin, or per-pool rows)."""
    name = "script"

    def __init__(self, targets, per_pool=False):
        self.targets = [np.asarray(t, float) for t in targets]
        self.per_pool = per_pool

    def decide(self, t, obs):
        tg = self.targets[min(t, len(self.targets) - 1)]
        if tg.ndim == 0:
            return np.full_like(obs.queue, float(tg))
        return np.tile(tg, (len(obs.queue), 1))


# ------------------- cohort model vs brute-force FIFO ------------------------

def _bruteforce_fifo(admitted, served, slot_bin, slot_bt, dt, slo):
    """Per-request FIFO replay with explicit Python loops (integer masses)."""
    S, T = admitted.shape
    K = served.shape[1]
    ok = np.zeros((S, K))
    mean = np.zeros((S, K))
    sojourns = []
    for s in range(S):
        fifo = deque()
        for t in range(T):
            fifo.extend([t] * int(admitted[s, t]))
        for k in range(K):
            batch = [fifo.popleft() for _ in range(int(served[s, k]))]
            sojs = [(slot_bin[k] - t_arr) * dt + slot_bt[s, k]
                    for t_arr in batch]
            sojourns.extend(sojs)
            ok[s, k] = sum(1 for x in sojs if x <= slo + 1e-12)
            mean[s, k] = float(np.mean(sojs)) if sojs else 0.0
    return ok, mean, np.sort(sojourns)


def _random_integer_case(rng, S=3, T=12, P=1):
    admitted = rng.integers(0, 7, size=(S, T)).astype(float)
    slot_bin = np.repeat(np.arange(T), P)
    served = np.zeros((S, T * P))
    for s in range(S):
        backlog = 0.0
        for t in range(T):
            backlog += admitted[s, t]
            for p in range(P):
                k = t * P + p
                take = float(rng.integers(0, int(backlog) + 1))
                served[s, k] = take
                backlog -= take
    slot_bt = rng.uniform(0.05, 0.6, size=(S, T * P))
    return admitted, served, slot_bin, slot_bt


@pytest.mark.parametrize("pools", [1, 3])
def test_cohort_matches_bruteforce_reference(pools):
    rng = np.random.default_rng(42 + pools)
    dt, slo = 1.0, 2.5
    for _ in range(25):
        adm, srv, sbin, sbt = _random_integer_case(rng, P=pools)
        cm = cohort_metrics(adm, srv, sbin, sbt, dt, slo)
        ok_ref, mean_ref, soj_ref = _bruteforce_fifo(adm, srv, sbin, sbt,
                                                     dt, slo)
        np.testing.assert_allclose(cm.ok_served, ok_ref, atol=1e-9)
        np.testing.assert_allclose(cm.mean_sojourn, mean_ref, atol=1e-9)
        # the pooled distribution expands to exactly the per-request multiset
        expand = np.repeat(cm.sojourn_values,
                           np.round(cm.sojourn_weights).astype(int))
        np.testing.assert_allclose(np.sort(expand), soj_ref, atol=1e-9)


def test_cohort_rejects_non_causal_service():
    admitted = np.array([[1.0, 1.0]])
    served = np.array([[2.0, 0.0]])      # serves bin-1's arrival during bin 0
    with pytest.raises(ValueError):
        cohort_metrics(admitted, served, np.arange(2), np.full((1, 2), 0.1),
                       1.0, 1.0)


def test_simulator_latency_uses_exact_cohorts():
    # 1 replica, capacity 2 req/bin, 6 requests up front: cohorts drain over
    # 3 bins with sojourns bt, bt+dt, bt+2dt — checkable by hand
    svc = _service(t_comp=0.0, t_mem=1.0, t_coll=0.0, batch=2)  # bt=1s, cap 2/bin
    tr = replay_trace(np.array([6.0, 0, 0, 0]), dt_s=1.0, n_seeds=1, seed=0)
    tr.arrivals[:] = np.array([[6, 0, 0, 0]])
    sim = simulate(tr, svc, StaticPolicy(1), slo_s=1.5, initial_replicas=1)
    assert np.allclose(sim.served[0], [2, 2, 2, 0])
    assert np.allclose(sim.latency_s[0], [1.0, 2.0, 3.0, 0.0])
    # only the first bin's 2 requests meet the 1.5 s SLO
    assert np.allclose(sim.ok_served[0], [2, 0, 0, 0])
    assert summarize(sim).slo_attainment == pytest.approx(2 / 6)


# ------------------- billing bugfixes ----------------------------------------

def test_launch_billed_in_launch_bin():
    svc = _service()
    tr = poisson_trace(0.0, 8.0, dt_s=1.0, n_seeds=2, seed=0)
    pol = ScriptPolicy([1, 5, 5, 5, 5, 5, 5, 5])
    sim = simulate(tr, svc, pol, slo_s=1.0, cold_start_s=2.0,
                   initial_replicas=1)
    # t=1: target 5 -> 4 launches, billed immediately though not ready
    assert np.allclose(sim.billed_replicas[:, 0], 1)
    assert np.allclose(sim.billed_replicas[:, 1], 5)
    assert np.allclose(sim.replicas[:, 1], 1)
    assert np.allclose(sim.replicas[:, 4], 5)       # ready after 2-bin cold start


def test_scale_down_cancels_pending_and_stops_billing():
    svc = _service()
    tr = poisson_trace(0.0, 10.0, dt_s=1.0, n_seeds=2, seed=0)
    pol = ScriptPolicy([9] + [1] * 9)
    sim = simulate(tr, svc, pol, slo_s=1.0, cold_start_s=4.0,
                   initial_replicas=1)
    assert np.allclose(sim.billed_replicas[:, 0], 9)   # launch bin billed
    # cancelled at t=1: pending never matures, never bills again
    assert np.allclose(sim.billed_replicas[:, 1:], 1)
    assert sim.replicas.max() == 1


def test_scale_down_cancels_newest_launches_first():
    svc = _service()
    tr = poisson_trace(0.0, 8.0, dt_s=1.0, n_seeds=1, seed=0)
    # t=0: +4 (ready at bin 3); t=1: +3 (ready at bin 4); t=2: trim to 6
    pol = ScriptPolicy([5, 8, 6, 6, 6, 6, 6, 6])
    sim = simulate(tr, svc, pol, slo_s=1.0, cold_start_s=2.0,
                   initial_replicas=1)
    assert np.allclose(sim.replicas[0, 3], 5)   # older launch batch intact
    assert np.allclose(sim.replicas[0, 4], 6)   # newest batch lost 2 of 3
    assert np.allclose(sim.billed_replicas[0, 2:], 6)


# ------------------- admission control ordering ------------------------------

def test_drops_do_not_inflate_served_latency():
    # capacity 2/bin, queue bound 4, one giant burst: dropped requests must
    # not contribute to the sojourn of the 4 admitted + served ones
    svc = _service(t_comp=0.0, t_mem=1.0, t_coll=0.0, batch=2)
    tr = replay_trace(np.array([100.0, 0, 0, 0]), dt_s=1.0, n_seeds=1, seed=0)
    tr.arrivals[:] = np.array([[100, 0, 0, 0]])
    sim = simulate(tr, svc, StaticPolicy(1), slo_s=10.0, max_queue=4.0,
                   initial_replicas=1)
    assert sim.dropped[0, 0] == pytest.approx(96.0)
    assert sim.admitted[0, 0] == pytest.approx(4.0)
    # worst admitted request waits one bin then pays the 1 s batch: 2 s
    assert sim.sojourn_values.max() <= 2.0 + 1e-9
    assert sim.queue.max() <= 4.0 + 1e-9


# ------------------- heterogeneous fleets ------------------------------------

def _mixed_fleet(sc, quota=16, cold_start_s=60.0):
    return sc.fleet_for(["v5e-4", "v5e-16"], cold_start_s=cold_start_s,
                        max_replicas=quota)


def test_single_pool_fleet_matches_homogeneous_simulator():
    svc = _service()
    tr = poisson_trace(5 * svc.max_throughput, 900.0, dt_s=5.0, n_seeds=4,
                       seed=3)
    hom = simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                   cold_start_s=30.0, max_queue=1e4)
    pool = PoolConfig(service=svc, cold_start_s=30.0)
    het = simulate_fleet(tr, FleetConfig((pool,), max_queue=1e4),
                         QueueProportionalPolicy(), slo_s=2.0)
    for k in ("served", "dropped", "billed_replicas", "latency_s",
              "ok_served"):
        np.testing.assert_array_equal(getattr(hom, k), getattr(het, k))
    # golden pins (seeded trace): guard the drain/billing loop against silent
    # drift — simulate() wraps simulate_fleet(), so equality alone is vacuous
    assert hom.served.sum() == pytest.approx(2306702.0)
    assert hom.dropped.sum() == pytest.approx(0.0)
    assert hom.billed_replicas.sum() == pytest.approx(4428.0)
    assert hom.ok_served.sum() == pytest.approx(2305054.0)


def test_drain_order_prefers_cheapest_per_request():
    cheap = _service(shape="v5e-4")
    # same shape price, but slower service => worse $/request
    slow = service_model_from_cell(
        _cell(shape="v5e-16", t_comp=8.0, t_mem=2.0), units_per_step=64)
    fleet = FleetConfig((PoolConfig(service=slow), PoolConfig(service=cheap)))
    assert fleet.drain_order()[0] == 1
    assert fleet.shape_label() == "v5e-16+v5e-4"
    # per-pool outputs stay in POOL order even though slots drain rank-first:
    # light traffic is absorbed entirely by the cheap pool (index 1)
    tr = poisson_trace(0.5 * cheap.max_throughput, 300.0, dt_s=5.0,
                       n_seeds=2, seed=0)
    sim = simulate_fleet(tr, fleet, ScriptPolicy([np.array([1.0, 1.0])],
                                                 per_pool=True), slo_s=20.0)
    assert sim.pool_served[:, :, 0].sum() == 0
    assert sim.pool_served[:, :, 1].sum() == sim.served.sum()


def test_multi_pool_fleet_rejects_scalar_policies():
    sc = mset_scenario(n_signals=256, n_memvec=1024, slo_s=1.0)
    fleet = _mixed_fleet(sc)
    tr = poisson_trace(10.0, 60.0, dt_s=5.0, n_seeds=2, seed=0)
    with pytest.raises(ValueError):
        simulate_fleet(tr, fleet, QueueProportionalPolicy(), slo_s=1.0)


def test_hetero_predictive_splits_baseline_and_burst():
    sc = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8, slo_s=1.0)
    fleet = _mixed_fleet(sc, quota=16, cold_start_s=60.0)
    pol = HeterogeneousPredictivePolicy(sc.rows, sc.constraint(),
                                        sc.units_per_step, fleet,
                                        horizon_s=120.0)
    # baseline = cheapest feasible shape in recommend()'s ranking
    assert fleet.pools[pol.base_idx].service.shape.name == "v5e-4"
    base = sc.service_for("v5e-4")
    tr = flash_crowd_trace(6 * base.max_throughput, 3600.0, dt_s=5.0,
                           peak_mult=6.0, burst_width_s=240.0, n_seeds=4,
                           seed=7)
    sim = simulate_fleet(tr, fleet, pol, slo_s=sc.slo_s)
    burst = sim.pool_replicas[:, :, 1]
    assert burst.max() > 0                       # burst pool engaged the crowd
    assert burst[:, :30].max() == 0              # ...but not at baseline load
    assert burst[:, -30:].max() == 0             # ...and released it after
    rep = summarize(sim)
    assert rep.shape == "v5e-4+v5e-16"
    assert rep.slo_attainment > 0.99


def test_hetero_predictive_requires_feasible_pool_shape():
    from repro.core import Constraint
    sc = mset_scenario(n_signals=256, n_memvec=1024)
    with pytest.raises(ValueError):
        HeterogeneousPredictivePolicy(sc.rows,
                                      Constraint(max_step_latency_s=1e-15),
                                      sc.units_per_step, _mixed_fleet(sc))


def test_benchmark_mixed_fleet_wins_flash_crowd():
    """The fleet_scaling acceptance invariant: under per-pool quotas, the
    mixed v5e-4+v5e-16 predictive fleet is the cheapest configuration meeting
    >=99% SLO attainment on the flash-crowd trace."""
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "fleet_scaling", os.path.join(os.path.dirname(__file__), "..",
                                      "benchmarks", "fleet_scaling.py"))
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)
    reports, records = bench.run(full=False)
    flash = [r for r in reports
             if r.trace == "flash-crowd" and r.slo_attainment >= 0.99]
    assert flash, "no fleet met the SLO bar on flash-crowd"
    winner = min(flash, key=lambda r: r.usd_per_hour)
    assert winner.shape == "v5e-4+v5e-16"
    assert winner.policy == "hetero-predictive"
    # JSON records mirror the reports (what CI uploads)
    assert len(records) == len(reports)
    assert all("usd_per_hour" in r and "wall_clock_s" in r for r in records)


def test_mixed_fleet_conserves_requests():
    sc = mset_scenario(n_signals=1024, n_memvec=4096, fleet=8, slo_s=1.0)
    fleet = _mixed_fleet(sc, quota=12)
    base = sc.service_for("v5e-4")
    tr = flash_crowd_trace(4 * base.max_throughput, 1800.0, dt_s=5.0,
                           n_seeds=3, seed=2)
    pol = HeterogeneousPredictivePolicy(sc.rows, sc.constraint(),
                                        sc.units_per_step, fleet)
    sim = simulate_fleet(tr, fleet, pol, slo_s=sc.slo_s, max_queue=1e6)
    tot = sim.served.sum(axis=1) + sim.dropped.sum(axis=1) + sim.queue[:, -1]
    assert np.allclose(tot, sim.arrivals.sum(axis=1))
    # pool bookkeeping is self-consistent
    assert np.allclose(sim.pool_served.sum(axis=2), sim.served)
    assert np.allclose(sim.pool_billed.sum(axis=2), sim.billed_replicas)
