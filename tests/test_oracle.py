"""Scoping oracle subsystem: trace featurization invariants, the canonical
trace solve, offline sweep build + versioned serialization, interpolated
microsecond queries with principled refusals, the spot-check verifier, the
closed-loop oracle consult, and the CI gate for the oracle benchmark."""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core import (CellResult, RooflineTerms, fit_response_surface,
                        get_shape)
from repro.fleet import (FleetConfig, Objective, OracleGrid, OracleTable,
                         PIPolicy, PoolConfig, ScopingOracle, TraceFeatures,
                         TuningBudget, TuningReport, TuningScenario, Workload,
                         build_oracle, canonical_trace, featurize,
                         flash_crowd_trace, load_trace_csv, poisson_trace,
                         query_latency_us, resample_trace,
                         service_model_from_cell, tune, verify_oracle,
                         warm_start_candidates)
from repro.fleet.control import (ClosedLoopController,
                                 service_degradation_case)


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch,
                              "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw),
                                   units_per_step=kw.get("batch", 64))


def _fleet(svc, initial=8, max_replicas=24, cold_start_s=30.0):
    return FleetConfig((PoolConfig(service=svc, cold_start_s=cold_start_s,
                                   initial_replicas=initial,
                                   max_replicas=max_replicas),))


@pytest.fixture(scope="module")
def small_oracle():
    """One tiny 2x2x2 table shared across query/verify tests (building is
    the expensive part; queries are microseconds)."""
    svc = _service()
    fleet = _fleet(svc)
    mt = svc.max_throughput
    grid = OracleGrid(mean_rates=(2.0 * mt, 4.0 * mt),
                      burstiness=(1.0, 1.8), slos=(1.0, 3.0),
                      duration_s=400.0, dt_s=5.0, n_seeds=2, seed=3)
    table = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(),
                         objective=Objective(min_attainment=0.9),
                         budget=TuningBudget(n_candidates=5, init_seeds=1),
                         backend="numpy")
    return table, fleet, svc


# ------------------------- featurization invariants -------------------------

def test_featurize_seed_invariant():
    """Features read the rate *profile*, never the sampled arrivals: any
    seed / replicate count yields identical features."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed_a=st.integers(0, 2**31), seed_b=st.integers(0, 2**31),
           peak=st.floats(1.1, 6.0, allow_nan=False),
           rate=st.floats(10.0, 1e6, allow_nan=False))
    def prop(seed_a, seed_b, peak, rate):
        kw = dict(duration_s=300.0, dt_s=5.0, peak_mult=peak,
                  burst_width_s=40.0)
        fa = featurize(flash_crowd_trace(rate, n_seeds=2, seed=seed_a, **kw))
        fb = featurize(flash_crowd_trace(rate, n_seeds=5, seed=seed_b, **kw))
        assert fa == fb

    prop()


def test_featurize_rescale_equivariant():
    """Rescaling traffic c-fold multiplies mean_rate by c and leaves the
    shape features (burstiness, ramp, mix) untouched."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(c=st.floats(0.1, 50.0, allow_nan=False),
           peak=st.floats(1.1, 6.0, allow_nan=False))
    def prop(c, peak):
        kw = dict(duration_s=300.0, dt_s=5.0, peak_mult=peak,
                  burst_width_s=40.0, n_seeds=2, seed=0)
        f1 = featurize(flash_crowd_trace(1000.0, **kw))
        fc = featurize(flash_crowd_trace(1000.0 * c, **kw))
        assert fc.mean_rate == pytest.approx(c * f1.mean_rate, rel=1e-9)
        assert fc.burstiness == pytest.approx(f1.burstiness, rel=1e-9)
        assert fc.ramp == pytest.approx(f1.ramp, rel=1e-9)
        sc = f1.scaled(c)
        assert fc.burstiness == sc.burstiness and fc.ramp == sc.ramp

    prop()


def test_featurize_resample_invariant():
    tr = flash_crowd_trace(500.0, 300.0, dt_s=10.0, peak_mult=3.0,
                           burst_width_s=40.0, n_seeds=2, seed=1)
    f0, f1 = featurize(tr), featurize(resample_trace(tr, 2.5))
    assert f1.burstiness == pytest.approx(f0.burstiness, rel=1e-9)
    assert f1.mean_rate == pytest.approx(f0.mean_rate, rel=1e-9)


def test_csv_rescale_keeps_shape_profile(tmp_path):
    """Regression pin: ``load_trace_csv(..., mean_rate_per_s=)`` must
    featurize identically to the unrescaled recording (modulo mean_rate) —
    the rescale used to overwrite the profile the shape stats read."""
    p = tmp_path / "trace.csv"
    rates = [100.0, 120.0, 400.0, 150.0, 90.0, 140.0]
    p.write_text("t,rate\n" + "\n".join(f"{i},{r}"
                                        for i, r in enumerate(rates)) + "\n")
    raw = load_trace_csv(p, rate_col="rate", dt_s=30.0, n_seeds=2)
    scaled = load_trace_csv(p, rate_col="rate", dt_s=30.0, n_seeds=2,
                            mean_rate_per_s=5000.0)
    f_raw, f_scaled = featurize(raw), featurize(scaled)
    assert f_scaled.mean_rate == pytest.approx(5000.0, rel=1e-9)
    assert f_scaled.burstiness == pytest.approx(f_raw.burstiness, rel=1e-12)
    assert f_scaled.ramp == pytest.approx(f_raw.ramp, rel=1e-12)
    np.testing.assert_allclose(scaled.shape_profile, raw.rate)


# ------------------------------ canonical trace -----------------------------

@pytest.mark.parametrize("target", [1.0, 1.4, 2.5, 4.0])
def test_canonical_trace_realizes_features(target):
    tr = canonical_trace(2000.0, target, duration_s=600.0, dt_s=5.0,
                         n_seeds=2, seed=7)
    f = featurize(tr)
    assert f.mean_rate == pytest.approx(2000.0, rel=1e-9)
    assert f.burstiness == pytest.approx(target, rel=1e-6)


def test_canonical_trace_infeasible_burstiness_raises():
    with pytest.raises(ValueError, match="burstiness"):
        canonical_trace(2000.0, 50.0, duration_s=600.0, dt_s=5.0)


# ----------------------- table build + serialization ------------------------

def test_oracle_grid_validation():
    with pytest.raises(ValueError):
        OracleGrid(mean_rates=(100.0, 50.0), burstiness=(1.0,), slos=(1.0,))
    with pytest.raises(ValueError):
        OracleGrid(mean_rates=(100.0,), burstiness=(0.5,), slos=(1.0,))


def test_table_roundtrip_and_version_check(small_oracle, tmp_path):
    table, _, _ = small_oracle
    path = tmp_path / "oracle.json"
    table.save(path)
    loaded = OracleTable.load(path)
    assert set(loaded.cells) == set(table.cells)
    for idx, cell in table.cells.items():
        assert loaded.cells[idx].winner == cell.winner
        assert loaded.cells[idx].score == pytest.approx(cell.score)
    d = json.loads(path.read_text())
    d["version"] = 999
    with pytest.raises(ValueError, match="version"):
        OracleTable.from_json(d)
    d = json.loads(path.read_text())
    d["format"] = "something-else"
    with pytest.raises(ValueError, match="format"):
        OracleTable.from_json(d)


# ----------------------------------- queries --------------------------------

def test_exact_grid_point_is_verbatim(small_oracle):
    table, _, _ = small_oracle
    oracle = ScopingOracle(table)
    for idx, cell in table.cells.items():
        ans = oracle.query(TraceFeatures(cell.mean_rate, cell.burstiness,
                                         0.0), cell.slo_s)
        assert ans.ok and ans.exact
        assert ans.cell_idx == idx
        assert ans.params == cell.winner
        assert ans.cost_usd_hr == pytest.approx(cell.cost_usd_hr)


def test_interpolated_query_bounds_and_corners(small_oracle):
    table, _, _ = small_oracle
    g = table.grid
    oracle = ScopingOracle(table)
    q = TraceFeatures(float(np.sqrt(g.mean_rates[0] * g.mean_rates[1])),
                      0.5 * (g.burstiness[0] + g.burstiness[1]), 0.0)
    ans = oracle.query(q, float(np.sqrt(g.slos[0] * g.slos[1])))
    assert ans.ok and not ans.exact
    assert len(ans.corner_idx) == 8
    assert sum(ans.corner_weights) == pytest.approx(1.0)
    costs = [table.cells[c].cost_usd_hr for c in ans.corner_idx]
    assert min(costs) - 1e-9 <= ans.cost_usd_hr <= max(costs) + 1e-9
    assert ans.cost_bound_usd_hr == pytest.approx(max(
        c for c, w in zip(costs, ans.corner_weights) if w > 1e-12))
    # interpolated params stay inside each dim's range
    for dim in table.space.dims:
        v = ans.params[dim.name]
        assert dim.lo <= v <= dim.hi


def test_refusal_outside_hull_names_axis(small_oracle):
    table, _, _ = small_oracle
    g = table.grid
    oracle = ScopingOracle(table)
    ans = oracle.query(TraceFeatures(g.mean_rates[-1] * 100.0, 1.2, 0.0), 2.0)
    assert not ans.ok and "mean_rate" in ans.reason
    ans = oracle.query(TraceFeatures(g.mean_rates[0], 50.0, 0.0), 2.0)
    assert not ans.ok and "burstiness" in ans.reason
    ans = oracle.query(TraceFeatures(g.mean_rates[0], 1.2, 0.0),
                       g.slos[-1] * 100.0)
    assert not ans.ok and "slo" in ans.reason
    # refusals are answers, not exceptions — and falsy
    assert bool(ans) is False


def test_query_latency_is_fast(small_oracle):
    table, _, _ = small_oracle
    oracle = ScopingOracle(table)
    g = table.grid
    stats = query_latency_us(
        oracle, TraceFeatures(g.mean_rates[0] * 1.3, 1.2, 0.0), 2.0, n=50)
    assert stats["n"] == 50
    # generous CI bound; the bench gate pins the real (<=1ms) bar
    assert stats["median_us"] < 50_000


def test_slo_monotone_interpolated_score():
    """Looser deadline can only help: with racing disabled every SLO tier
    in a column scores the same candidate set, so the per-cell winner score
    is non-increasing in slo — and piecewise-linear interpolation between
    those nodes preserves the monotonicity."""
    svc = _service()
    fleet = _fleet(svc)
    mt = svc.max_throughput
    grid = OracleGrid(mean_rates=(3.0 * mt,), burstiness=(1.5,),
                      slos=(1.0, 2.0, 4.0), duration_s=400.0, dt_s=5.0,
                      n_seeds=2, seed=11)
    table = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(),
                         objective=Objective(min_attainment=0.9),
                         budget=TuningBudget(n_candidates=4, racing=False),
                         backend="numpy")
    scores = [table.cells[(0, 0, k)].score for k in range(3)]
    assert scores[0] >= scores[1] - 1e-9 >= scores[2] - 2e-9
    oracle = ScopingOracle(table)
    q = TraceFeatures(3.0 * mt, 1.5, 0.0)
    interp = [oracle.query(q, s).score
              for s in np.geomspace(1.0, 4.0, 9)]
    assert all(a >= b - 1e-9 for a, b in zip(interp, interp[1:]))


# ------------------------------- verification -------------------------------

def test_verify_oracle_spot_checks(small_oracle):
    table, fleet, _ = small_oracle
    report = verify_oracle(table, fleet, PIPolicy, n_samples=2, seed=5,
                           backend="numpy")
    assert report.n + report.refused == 2
    d = report.to_json()
    assert "max_cost_overrun" in d and "max_cost_err" in d
    for c in report.checks:
        assert np.isfinite(c.simulated_cost)
        assert c.cost_overrun >= 0.0
    # within-bound simulations report zero overrun
    if report.n:
        assert report.max_cost_overrun <= max(
            0.0, max(c.cost_overrun for c in report.checks))


# -------------------- TuningReport round-trip (satellite) -------------------

def test_tuning_report_json_roundtrip():
    svc = _service()
    tr = poisson_trace(2.0 * svc.max_throughput, 300.0, dt_s=5.0, n_seeds=2,
                       seed=0)
    scen = TuningScenario(name="rt", workload=Workload.from_trace(tr, 2.0),
                          fleet=_fleet(svc), policy_cls=PIPolicy,
                          context={"slo_s": 2.0}, backend="numpy")
    space = PIPolicy.param_space()
    report = tune(scen, space, Objective(min_attainment=0.9),
                  TuningBudget(n_candidates=4, init_seeds=1), seed=1)
    back = TuningReport.from_json(json.loads(json.dumps(report.to_json())))
    assert back.winner.params == report.winner.params
    assert back.winner.mean_score() == pytest.approx(
        report.winner.mean_score())
    assert back.scenario_name == report.scenario_name
    # a deserialized report can warm-start a re-tune
    cands = warm_start_candidates(back, space, 4, seed=2)
    assert cands[0] == report.winner.params
    assert len(cands) == 4


# ------------------ ResponseSurface hull clamp (satellite) ------------------

def test_response_surface_clamps_and_flags():
    rng = np.random.default_rng(0)
    X = rng.uniform(1.0, 10.0, size=(40, 2))
    y = 3.0 * X[:, 0] ** 1.5 * X[:, 1] ** 0.5
    surf = fit_response_surface(["a", "b"], X, y)
    inside = surf.predict({"a": 5.0, "b": 5.0})
    assert not surf.extrapolated
    far = surf.predict({"a": 1e6, "b": 5.0})
    assert surf.extrapolated
    # clamped to the hull: identical to evaluating at the box edge
    edge = surf.predict({"a": float(np.exp(surf.box_hi[0])), "b": 5.0})
    assert far == pytest.approx(edge)
    assert np.isfinite(inside) and np.isfinite(far)


# ------------------------- closed-loop oracle consult -----------------------

def _drift_setup(slo_s=2.0, rate_mult=2.5):
    svc = _service()
    fleet = _fleet(svc)
    tr = poisson_trace(rate_mult * svc.max_throughput, 600.0, dt_s=5.0,
                       n_seeds=2, seed=0)
    wl = Workload.from_trace(tr, slo_s)
    case = service_degradation_case(wl, fleet, factor=1.6, t_drift_frac=0.4)
    scen = TuningScenario(name="cl", workload=wl, fleet=fleet,
                          policy_cls=PIPolicy, context={"slo_s": slo_s},
                          backend="numpy")
    incumbent = tune(scen, PIPolicy.param_space(),
                     Objective(min_attainment=0.9),
                     TuningBudget(n_candidates=4, init_seeds=1), seed=0)
    return svc, fleet, case, scen, incumbent


def test_controller_oracle_hit_swaps_without_retune():
    svc, fleet, case, scen, incumbent = _drift_setup()
    mt = svc.max_throughput
    # hull wide enough that the degradation-inflated query lands inside
    grid = OracleGrid(mean_rates=(1.5 * mt, 8.0 * mt), burstiness=(1.0, 1.6),
                      slos=(1.0, 4.0), duration_s=400.0, dt_s=5.0,
                      n_seeds=2, seed=3)
    table = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(),
                         objective=Objective(min_attainment=0.9),
                         budget=TuningBudget(n_candidates=5, init_seeds=1),
                         backend="numpy")
    ctl = ClosedLoopController(scen, incumbent, segment_bins=30,
                               oracle=ScopingOracle(table),
                               objective=Objective(min_attainment=0.9))
    res = ctl.run(case)
    assert res.oracle_hits >= 1
    assert res.oracle_misses == 0
    hit = next(e for e in res.events if e.kind == "oracle-hit")
    assert hit.detail["latency_us"] > 0
    assert hit.detail["eval_sims"] > 0
    assert len(res.oracle_answers) == res.oracle_hits
    # an oracle hit answers the alarm without spending a warm re-tune
    assert all(e.kind != "retune" for e in res.events)
    assert not res.retunes


def test_controller_oracle_miss_falls_back_to_retune():
    svc, fleet, case, scen, incumbent = _drift_setup()
    mt = svc.max_throughput
    # hull deliberately excludes the inflated query -> refusal -> re-tune
    grid = OracleGrid(mean_rates=(0.1 * mt, 0.2 * mt), burstiness=(1.0, 1.1),
                      slos=(1.0, 4.0), duration_s=400.0, dt_s=5.0,
                      n_seeds=2, seed=3)
    table = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(),
                         objective=Objective(min_attainment=0.9),
                         budget=TuningBudget(n_candidates=3, init_seeds=1),
                         backend="numpy")
    ctl = ClosedLoopController(scen, incumbent, segment_bins=30,
                               oracle=ScopingOracle(table),
                               objective=Objective(min_attainment=0.9))
    res = ctl.run(case)
    assert res.oracle_misses >= 1 and res.oracle_hits == 0
    miss = next(e for e in res.events if e.kind == "oracle-miss")
    assert "mean_rate" in miss.detail["reason"]
    # the miss did not disable recovery: the warm re-tune path still ran
    assert res.retunes


def test_controller_accepts_bare_table():
    """oracle= accepts an OracleTable directly (wrapped internally)."""
    svc, fleet, case, scen, incumbent = _drift_setup()
    mt = svc.max_throughput
    grid = OracleGrid(mean_rates=(1.5 * mt, 8.0 * mt), burstiness=(1.0, 1.6),
                      slos=(1.0, 4.0), duration_s=400.0, dt_s=5.0,
                      n_seeds=2, seed=3)
    table = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(),
                         objective=Objective(min_attainment=0.9),
                         budget=TuningBudget(n_candidates=3, init_seeds=1),
                         backend="numpy")
    ctl = ClosedLoopController(scen, incumbent, segment_bins=30, oracle=table,
                               objective=Objective(min_attainment=0.9))
    assert isinstance(ctl.oracle, ScopingOracle)


# --------------------------------- CI gate ----------------------------------

def _load_check_bench():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench_oracle", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _green_oracle():
    return {
        "benchmark": "scoping_oracle",
        "build": {"n_cells": 36, "sims_used": 700,
                  "tune_equivalents": 20.0, "wall_s": 30.0},
        "latency": {"median_us": 200.0, "p99_us": 400.0, "max_us": 900.0,
                    "n": 200},
        "heldout": {"attainment_bar": 0.95, "regret": 0.02,
                    "oracle": {"attainment": 0.97, "cost_usd_hr": 28.0,
                               "score": 28.0},
                    "fresh": {"attainment": 0.98, "cost_usd_hr": 27.5,
                              "score": 27.5}},
        "verify": {"n": 3, "refused": 0, "max_cost_err": 0.12,
                   "max_cost_overrun": 0.0, "mean_cost_err": 0.06,
                   "max_attainment_err": 0.01},
        "agreement": {"max_score_delta": 0.0},
        "closed_loop": {
            "attainment_bar": 0.95,
            "retune": {"swap_bin": 105, "post_drift_usd_per_hour": 32.0,
                       "recovery_attainment": 0.98, "tune_sims": 32},
            "oracle": {"swap_bin": 105, "post_drift_usd_per_hour": 33.0,
                       "recovery_attainment": 0.98, "hits": 1, "misses": 0,
                       "consult_sims": 30},
        },
    }


def test_compare_oracle_green():
    cb = _load_check_bench()
    fresh = _green_oracle()
    assert cb.compare_oracle(fresh, _green_oracle(), 0.02, 0.08) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d["latency"].__setitem__("median_us", 5000.0), "latency"),
    (lambda d: d["heldout"].__setitem__("regret", 0.5), "regret"),
    (lambda d: d["heldout"]["oracle"].__setitem__("attainment", 0.5),
     "attainment"),
    (lambda d: d["build"].__setitem__("tune_equivalents", 500.0),
     "amortize"),
    (lambda d: d["verify"].__setitem__("max_cost_overrun", 0.5), "bound"),
    (lambda d: d["verify"].__setitem__("refused", 1), "refusal"),
    (lambda d: d["closed_loop"]["oracle"].__setitem__("swap_bin", 150),
     "LATER"),
    (lambda d: d["closed_loop"]["oracle"].__setitem__(
        "recovery_attainment", 0.5), "bar"),
    (lambda d: d["closed_loop"]["oracle"].__setitem__("consult_sims", 999),
     "cheaper"),
    (lambda d: d["closed_loop"]["oracle"].__setitem__("hits", 0), "hit"),
    (lambda d: d["agreement"].__setitem__("max_score_delta", 1.0),
     "disagree"),
])
def test_compare_oracle_red(mutate, needle):
    cb = _load_check_bench()
    fresh = _green_oracle()
    mutate(fresh)
    problems = cb.compare_oracle(fresh, _green_oracle(), 0.02, 0.08)
    assert problems, f"expected a problem mentioning {needle!r}"
    assert any(needle.lower() in p.lower() for p in problems), problems
