"""Optimizer, gradient accumulation, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression
from repro.optim import AdamWConfig, adamw, microbatched_value_and_grad


def test_adamw_first_step_matches_closed_form():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=None)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = adamw.init(p)
    new_p, st2, m = adamw.update(cfg, g, st, p)
    # bias-corrected first step: mhat = g, vhat = g^2 -> step = g/|g| = sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(p["w"]) - 0.1 * np.sign([0.5, 0.5]),
                               atol=1e-5)
    assert int(st2.step) == 1


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    p = {"w": jnp.array([5.0, -3.0, 2.0])}
    st = adamw.init(p)
    target = jnp.array([1.0, 1.0, 1.0])
    for _ in range(300):
        g = {"w": 2 * (p["w"] - target)}
        p, st, _ = adamw.update(cfg, g, st, p)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target), atol=1e-2)


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = adamw.init(p)
    _, _, m = adamw.update(cfg, g, st, p)
    assert float(m["grad_norm"]) > 100  # reported norm is pre-clip


def test_microbatched_grads_match_full_batch():
    def loss(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"l": l}

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8,))}
    batch = {"x": jax.random.normal(key, (16, 8)),
             "y": jax.random.normal(jax.random.PRNGKey(1), (16,))}
    (l1, _), g1 = jax.value_and_grad(loss, has_aux=True)(params, batch)
    (l4, _), g4 = microbatched_value_and_grad(loss, 4)(params, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]), rtol=1e-4)


def test_compression_roundtrip_error_bounded():
    key = jax.random.PRNGKey(2)
    g = {"a": jax.random.normal(key, (256,)), "b": jax.random.normal(key, (32, 32))}
    st = compression.init(g)
    q, st2 = compression.compress_grads(g, st)
    deq = compression.decompress_grads(q)
    for k in g:
        scale = float(jnp.max(jnp.abs(g[k]))) / 127
        err = float(jnp.max(jnp.abs(deq[k] - g[k])))
        assert err <= scale * 0.51 + 1e-6


def test_error_feedback_sgd_converges():
    """EF-int8-compressed SGD still reaches the optimum (error feedback works)."""
    target = jnp.array([2.0, -1.0, 0.5, 3.0])
    w = jnp.zeros(4)
    st = compression.init({"w": w})
    lr = 0.05
    for _ in range(400):
        g = {"w": 2 * (w - target)}
        q, st = compression.compress_grads(g, st)
        deq = compression.decompress_grads(q)
        w = w - lr * deq["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=5e-2)


def test_warmup_cosine_schedule():
    from repro.optim import warmup_cosine
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.array(0))) == 0.0
    assert abs(float(s(jnp.array(10))) - 1.0) < 1e-6
    assert float(s(jnp.array(100))) <= 0.11
    assert float(s(jnp.array(55))) < float(s(jnp.array(20)))
