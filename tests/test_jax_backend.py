"""Compiled (JAX) simulator backend: bin-by-bin equivalence with the numpy
reference across scenarios/disciplines/policy families, batched candidate
evaluation == the sequential loop, racing/tune() winner parity, cold-start
tensor hoisting, and the auto/jax fallback contract."""
import numpy as np
import pytest

from repro.core import CellResult, RooflineTerms, get_shape
from repro.fleet import (FleetConfig, HeterogeneousPredictivePolicy,
                         Objective, ParamSpace, PolicyKernel, PoolConfig,
                         PredictivePolicy, QueueProportionalPolicy,
                         ReactivePolicy, StaticPolicy, TuningBudget,
                         TuningScenario, discipline_dim, evaluate_candidates,
                         flash_crowd_trace, make_kernel, mset_scenario,
                         poisson_trace, quota_dims, race, simulate,
                         simulate_fleet, tiered_sla_workload, tune,
                         tuning_scenario)
from repro.fleet.simulator import draw_cold_start_delays

jax = pytest.importorskip("jax")

# bin-by-bin SimResult fields both backends must agree on
TRACE_FIELDS = ("served", "queue", "billed_replicas", "latency_s",
                "ok_served", "utilization", "dropped", "admitted",
                "replicas", "pool_billed", "pool_served", "pool_replicas",
                "class_ok", "class_queue", "class_served", "class_admitted",
                "class_dropped")


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch,
                              "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    from repro.fleet import service_model_from_cell
    return service_model_from_cell(_cell(**kw),
                                   units_per_step=kw.get("batch", 64))


def _assert_equivalent(a, b, atol=1e-8):
    for k in TRACE_FIELDS:
        np.testing.assert_allclose(getattr(a, k), getattr(b, k), atol=atol,
                                   rtol=1e-9, err_msg=f"field {k!r}")
    # the pooled exact sojourn distributions agree (as distributions)
    from repro.fleet import weighted_percentile
    assert a.sojourn_weights.sum() == pytest.approx(b.sojourn_weights.sum())
    for q in (50, 90, 99):
        assert weighted_percentile(a.sojourn_values, a.sojourn_weights, q) \
            == pytest.approx(weighted_percentile(b.sojourn_values,
                                                 b.sojourn_weights, q),
                             abs=1e-9)
    assert a.discipline == b.discipline
    assert a.policy_name == b.policy_name


# ----------------------- golden scenario equivalence ------------------------

def test_flash_crowd_queue_prop_matches_numpy():
    svc = _service()
    tr = flash_crowd_trace(5 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=4, seed=0)
    kw = dict(slo_s=2.0, cold_start_s=60.0)
    a = simulate(tr, svc, QueueProportionalPolicy(), **kw)
    b = simulate(tr, svc, QueueProportionalPolicy(), backend="jax", **kw)
    _assert_equivalent(a, b)


def test_tiered_sla_all_disciplines_match_numpy():
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    wl = tiered_sla_workload(3.0 * svc.max_throughput, 1500.0, dt_s=5.0,
                             n_seeds=3, seed=0)
    for disc in ("fifo", "priority", "edf"):
        a = simulate(wl, svc, StaticPolicy(8), cold_start_s=30.0,
                     discipline=disc)
        b = simulate(wl, svc, StaticPolicy(8), cold_start_s=30.0,
                     discipline=disc, backend="jax")
        _assert_equivalent(a, b)


def test_hetero_fleet_jittered_cold_start_matches_numpy():
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    fleet = scn.fleet_for(["v5e-4", "v5e-16"], cold_start_s=(45.0, 0.5),
                          max_replicas=16)
    from repro.fleet import interactive_batch_workload
    wl = interactive_batch_workload(4.0 * svc.max_throughput, 1500.0,
                                    dt_s=5.0, n_seeds=3, seed=1)

    def pol():
        return HeterogeneousPredictivePolicy(
            scn.rows, scn.constraint(), scn.units_per_step, fleet)

    a = simulate_fleet(wl, fleet, pol(), discipline="edf", cold_start_seed=3)
    b = simulate_fleet(wl, fleet, pol(), discipline="edf", cold_start_seed=3,
                       backend="jax")
    _assert_equivalent(a, b)


def test_predictive_and_admission_control_match_numpy():
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    tr = flash_crowd_trace(3.5 * svc.max_throughput, 1500.0, dt_s=5.0,
                           peak_mult=4.0, n_seeds=3, seed=2)
    pol = PredictivePolicy(scn.rows, scn.constraint(), scn.units_per_step,
                           horizon_s=120.0)
    a = simulate(tr, svc, pol, slo_s=1.0, cold_start_s=60.0,
                 max_queue=4000.0)
    pol2 = PredictivePolicy(scn.rows, scn.constraint(), scn.units_per_step,
                            horizon_s=120.0)
    b = simulate(tr, svc, pol2, slo_s=1.0, cold_start_s=60.0,
                 max_queue=4000.0, backend="jax")
    _assert_equivalent(a, b)


# ----------------------- hypothesis property --------------------------------

def test_backends_agree_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    svc = _service()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=1000),
           disc=st.sampled_from(["fifo", "priority", "edf"]),
           jitter=st.floats(min_value=0.0, max_value=0.8),
           rate_mult=st.floats(min_value=1.0, max_value=6.0),
           drain_s=st.floats(min_value=5.0, max_value=90.0),
           headroom=st.floats(min_value=0.6, max_value=0.95))
    def prop(seed, disc, jitter, rate_mult, drain_s, headroom):
        # fixed shapes (T, C, P) so the compiled program is traced once;
        # everything else — rates, discipline tables, jitter, knobs — is data
        wl = tiered_sla_workload(rate_mult * svc.max_throughput, 600.0,
                                 dt_s=5.0, n_seeds=3, seed=seed)
        pol = QueueProportionalPolicy(drain_s=drain_s, headroom=headroom)
        kw = dict(cold_start_s=(30.0, jitter), discipline=disc,
                  cold_start_seed=seed)
        a = simulate(wl, svc, QueueProportionalPolicy(drain_s, headroom),
                     **kw)
        b = simulate(wl, svc, pol, backend="jax", **kw)
        # aggregate per-seed metrics agree within float tolerance
        from repro.fleet.tuning.evaluate import per_seed_metrics
        ca, aa, da = per_seed_metrics(a)
        cb, ab, db = per_seed_metrics(b)
        np.testing.assert_allclose(ca, cb, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(aa, ab, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(da, db, rtol=1e-9, atol=1e-9)
        for q in (50, 99):
            from repro.fleet import weighted_percentile
            pa = weighted_percentile(a.sojourn_values, a.sojourn_weights, q)
            pb = weighted_percentile(b.sojourn_values, b.sojourn_weights, q)
            assert pa == pytest.approx(pb, abs=1e-9)

    prop()


# ----------------------- batched candidate evaluation -----------------------

def _flash_scenario(n_seeds=8, backend="numpy"):
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    tr = flash_crowd_trace(3.5 * svc.max_throughput, 1500.0, dt_s=5.0,
                           peak_mult=4.0, burst_width_s=60.0,
                           n_seeds=n_seeds, seed=2)
    return tuning_scenario(scn, tr, PredictivePolicy, cold_start_s=30.0,
                           backend=backend)


def test_batched_round_equals_sequential_loop():
    ts = _flash_scenario()
    obj = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    cands = PredictivePolicy.param_space().sample_lhs(6, seed=0)
    seq = evaluate_candidates(ts, cands, obj, backend="numpy")
    bat = evaluate_candidates(ts, cands, obj, backend="jax")
    for a, b in zip(seq, bat):
        assert a.params == b.params
        np.testing.assert_allclose(a.score, b.score, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(a.cost_usd_hr, b.cost_usd_hr, rtol=1e-9)
        np.testing.assert_allclose(a.attainment, b.attainment, atol=1e-9)
        assert a.p99_s() == pytest.approx(b.p99_s(), abs=1e-9)


def test_batched_cross_cutting_dims_equal_sequential():
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    fleet = scn.fleet_for(["v5e-4", "v5e-16"], cold_start_s=(45.0, 0.4),
                          max_replicas=16)
    wl = tiered_sla_workload(3.0 * svc.max_throughput, 1200.0, dt_s=5.0,
                             n_seeds=4, seed=0)
    ts = tuning_scenario(scn, wl, HeterogeneousPredictivePolicy, fleet=fleet)
    space = (HeterogeneousPredictivePolicy.param_space()
             + ParamSpace((discipline_dim(),)) + quota_dims(fleet, hi=16))
    cands = space.sample_lhs(5, seed=3)
    obj = Objective(min_attainment=0.95)
    seq = evaluate_candidates(ts, cands, obj, backend="numpy")
    bat = evaluate_candidates(ts, cands, obj, backend="jax")
    for a, b in zip(seq, bat):
        np.testing.assert_allclose(a.score, b.score, rtol=1e-9, atol=1e-9)


def test_tune_same_winner_and_budget_both_backends():
    """The regression the compiled path must never introduce: racing on the
    jax backend returns the numpy winner and spends the same sims_used."""
    obj = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    budget = TuningBudget(n_candidates=12)
    space = PredictivePolicy.param_space()
    reports = {}
    for backend in ("numpy", "jax"):
        rep = tune(_flash_scenario(backend=backend), space, obj, budget,
                   seed=0, baseline={"horizon_s": 60.0, "window_bins": 12,
                                     "headroom": 0.85})
        reports[backend] = rep
    a, b = reports["numpy"], reports["jax"]
    assert a.winner.params == b.winner.params
    assert a.sims_used == b.sims_used
    np.testing.assert_allclose(a.winner.score, b.winner.score, rtol=1e-12)
    assert a.dominates_baseline() == b.dominates_baseline()


def test_batched_rejects_single_target_policy_on_multipool_fleet():
    """The batched path must enforce simulate_fleet's contract, not silently
    broadcast a single-pool target across pools."""
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    tr = flash_crowd_trace(3.0 * svc.max_throughput, 600.0, dt_s=5.0,
                           n_seeds=3, seed=1)
    ts = tuning_scenario(scn, tr, PredictivePolicy,
                         fleet=scn.fleet_for(["v5e-4", "v5e-16"]),
                         backend="jax")
    cands = PredictivePolicy.param_space().sample_lhs(2, seed=0)
    with pytest.raises(ValueError, match="per-pool policy"):
        evaluate_candidates(ts, cands, Objective())


def test_race_sims_accounting_unchanged_on_jax():
    ts = _flash_scenario(backend="jax")
    obj = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    grid = [{"horizon_s": h, "window_bins": 12, "headroom": 0.85}
            for h in (20.0, 60.0, 180.0, 420.0)]
    rr = race(ts, grid, obj, init_seeds=2)
    assert rr.full_budget == len(grid) * ts.n_seeds
    assert 0 < rr.sims_used <= rr.full_budget


# ----------------------- cold-start tensor hoisting -------------------------

def test_hoisted_cold_start_tensor_matches_per_call_draws():
    svc = _service()
    tr = flash_crowd_trace(5 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=6, seed=0)
    pool = PoolConfig(service=svc, cold_start_s=(60.0, 0.7))
    fleet = FleetConfig((pool,))
    # the tensor the scenario hoists == what simulate_fleet draws internally
    ts = TuningScenario(name="h", workload=tr, fleet=fleet,
                        policy_cls=QueueProportionalPolicy,
                        context={"slo_s": 2.0}, cold_start_seed=3)
    cs = ts.cold_start_delays()
    ref = draw_cold_start_delays(fleet.pools, 6, tr.n_bins, tr.dt_s, 3,
                                 np.arange(6))
    assert np.array_equal(cs, ref)
    # a sliced evaluation reproduces a direct simulate_fleet byte for byte
    sim_h = ts.simulate({"drain_s": 30.0, "headroom": 0.85}, 2, 5)
    direct = simulate_fleet(
        type(tr)(tr.name, tr.dt_s, tr.rate, tr.arrivals[2:5]), fleet,
        QueueProportionalPolicy(30.0, 0.85), slo_s=2.0, cold_start_seed=3,
        seed_indices=np.arange(2, 5))
    assert np.array_equal(sim_h.billed_replicas, direct.billed_replicas)
    assert np.array_equal(sim_h.served, direct.served)
    # and it is drawn once: the cache object is reused
    assert ts.cold_start_delays() is cs


def test_unjittered_scenario_has_no_tensor():
    svc = _service()
    tr = poisson_trace(2 * svc.max_throughput, 300.0, dt_s=5.0, n_seeds=2)
    ts = TuningScenario(name="n", workload=tr,
                        fleet=FleetConfig((PoolConfig(service=svc),)),
                        policy_cls=StaticPolicy, context={"slo_s": 2.0})
    assert ts.cold_start_delays() is None
    ev = evaluate_candidates(ts, [{"n_replicas": 4}], Objective())
    assert ev[0].n_seeds == 2


# ----------------------- backend contract -----------------------------------

class _CustomPolicy(StaticPolicy):
    """A user-defined subclass: no compiled kernel."""
    name = "custom"


def test_auto_falls_back_and_jax_raises_for_custom_policy():
    svc = _service()
    tr = poisson_trace(2 * svc.max_throughput, 300.0, dt_s=5.0, n_seeds=2)
    a = simulate(tr, svc, _CustomPolicy(4), slo_s=2.0, backend="auto")
    b = simulate(tr, svc, _CustomPolicy(4), slo_s=2.0, backend="numpy")
    np.testing.assert_array_equal(a.served, b.served)
    with pytest.raises(ValueError, match="no compiled kernel"):
        simulate(tr, svc, _CustomPolicy(4), slo_s=2.0, backend="jax")
    with pytest.raises(ValueError, match="backend"):
        simulate(tr, svc, StaticPolicy(4), slo_s=2.0, backend="pallas")


def test_auto_uses_kernel_for_builtin_families():
    svc = _service()
    fleet = FleetConfig((PoolConfig(service=svc),))
    from repro.fleet.workload import RequestClass
    classes = (RequestClass("default", 2.0),)
    for pol in (StaticPolicy(4), ReactivePolicy(),
                QueueProportionalPolicy()):
        k = make_kernel(pol, fleet, classes)
        assert isinstance(k, PolicyKernel)
        # cached: same config returns the same object (a jit-cache key)
        assert make_kernel(pol, fleet, classes) is k
        params = k.params_of(pol)
        assert set(params) == set(k.param_names)
    assert make_kernel(_CustomPolicy(4), fleet, classes) is None
