"""TPSS synthesis: the statistics the paper says matter (serial correlation,
cross-correlation, moments)."""
import jax
import numpy as np

from repro.tpss import TPSSParams, inject_anomaly, synthesize

KEY = jax.random.PRNGKey(7)


def _np(x):
    return np.asarray(x)


def test_shapes_and_determinism():
    p = TPSSParams(n_signals=8, n_obs=512)
    a = _np(synthesize(KEY, p))
    b = _np(synthesize(KEY, p))
    c = _np(synthesize(jax.random.PRNGKey(8), p))
    assert a.shape == (512, 8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_serial_correlation_present():
    p = TPSSParams(n_signals=4, n_obs=4096, ar1=0.9, ar2=-0.05, harmonic_amp=0.0)
    x = _np(synthesize(KEY, p))
    x = (x - x.mean(0)) / x.std(0)
    lag1 = np.mean([np.corrcoef(x[:-1, i], x[1:, i])[0, 1] for i in range(4)])
    assert lag1 > 0.5, lag1


def test_cross_correlation_controlled():
    base = dict(n_signals=6, n_obs=4096, harmonic_amp=0.0)
    x_ind = _np(synthesize(KEY, TPSSParams(**base, cross_weight=0.0)))
    x_cor = _np(synthesize(KEY, TPSSParams(**base, cross_weight=0.9, cross_rank=1)))

    def mean_offdiag(x):
        c = np.corrcoef(x.T)
        return np.abs(c[~np.eye(len(c), dtype=bool)]).mean()

    assert mean_offdiag(x_cor) > mean_offdiag(x_ind) + 0.2


def _skew(x):
    x = x - x.mean(0)
    return (np.mean(x**3, 0) / np.mean(x**2, 0) ** 1.5).mean()


def _kurt(x):
    x = x - x.mean(0)
    return (np.mean(x**4, 0) / np.mean(x**2, 0) ** 2).mean()


def test_moment_shaping():
    base = dict(n_signals=4, n_obs=8192, harmonic_amp=0.0, mean_scale=0.0,
                std_scale=1.0, cross_weight=0.0)
    x_sym = _np(synthesize(KEY, TPSSParams(**base, skew=0.0, tailweight=1.0)))
    x_skw = _np(synthesize(KEY, TPSSParams(**base, skew=0.5, tailweight=1.0)))
    x_hvy = _np(synthesize(KEY, TPSSParams(**base, skew=0.0, tailweight=1.4)))
    assert abs(_skew(x_sym)) < 0.25
    assert _skew(x_skw) > _skew(x_sym) + 0.4
    assert _kurt(x_hvy) > _kurt(x_sym) + 0.8


def test_anomaly_injection():
    p = TPSSParams(n_signals=4, n_obs=1000)
    x = synthesize(KEY, p)
    xa = inject_anomaly(x, start=500, signal=1, drift_per_step=0.01)
    d = _np(xa - x)
    assert np.allclose(d[:500], 0)
    assert np.allclose(d[:, [0, 2, 3]], 0)
    assert d[999, 1] > d[600, 1] > 0
