"""Autonomous controller-scoping subsystem (`repro.fleet.tuning`): param
spaces, paired evaluation, racing soundness, Pareto/report invariants, the
response-surface underdetermined-fit fix, CSV trace ingestion, and stochastic
cold starts."""
import os

import numpy as np
import pytest

from repro.core import CellResult, RooflineTerms, get_shape
from repro.core.surfaces import fit_response_surface
from repro.fleet import (Categorical, Continuous, Integer, Objective,
                         ParamSpace, PoolConfig, FleetConfig,
                         PredictivePolicy, QueueProportionalPolicy,
                         ReactivePolicy, StaticPolicy, TuningBudget,
                         TuningScenario, discipline_dim, evaluate_candidates,
                         exhaustive, flash_crowd_trace, load_trace_csv,
                         mset_scenario, poisson_trace, quota_dims, race,
                         replay_trace, service_model_from_cell, simulate,
                         tune, tuning_scenario)

DATA_CSV = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "data", "azure_functions_day.csv")


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch, "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw), units_per_step=kw.get("batch", 64))


def _static_scenario(rate_mult=3.0, duration=600.0, n_seeds=8, seed=0,
                     slo_s=2.0, cold_start_s=30.0):
    """StaticPolicy tuning on a steady trace: cost is monotone in n, so the
    cheapest n meeting the SLO is the known optimum."""
    svc = _service()
    tr = poisson_trace(rate_mult * svc.max_throughput, duration, dt_s=5.0,
                       n_seeds=n_seeds, seed=seed)
    fleet = FleetConfig((PoolConfig(service=svc, cold_start_s=cold_start_s,
                                    initial_replicas=8),))
    return TuningScenario(
        name="static-steady", workload=tr, fleet=fleet,
        policy_cls=StaticPolicy, context={"slo_s": slo_s})


# ---------------------------- param spaces ----------------------------------

def test_lhs_deterministic_in_bounds_and_stratified():
    space = ParamSpace((Continuous("a", 1.0, 10.0, log=True),
                        Integer("b", 2, 9),
                        Categorical("c", ("x", "y"))))
    s1 = space.sample_lhs(16, seed=3)
    s2 = space.sample_lhs(16, seed=3)
    assert s1 == s2
    assert s1 != space.sample_lhs(16, seed=4)
    for cfg in s1:
        assert 1.0 <= cfg["a"] <= 10.0
        assert 2 <= cfg["b"] <= 9 and isinstance(cfg["b"], int)
        assert cfg["c"] in ("x", "y")
    # latin-hypercube stratification: one sample per n-quantile bin per dim
    a = sorted(np.log(c["a"]) for c in s1)
    edges = np.linspace(np.log(1.0), np.log(10.0), 17)
    assert all(edges[i] <= a[i] <= edges[i + 1] for i in range(16))


def test_grid_is_full_factorial_and_spaces_compose():
    space = ParamSpace((Continuous("a", 1.0, 4.0),)) \
        + ParamSpace((Categorical("d", ("p", "q", "r")),))
    g = space.grid(3)
    assert len(g) == 9
    assert {(c["a"], c["d"]) for c in g} == {
        (a, d) for a in (1.0, 2.5, 4.0) for d in ("p", "q", "r")}
    with pytest.raises(ValueError):
        ParamSpace((Continuous("a", 0, 1), Integer("a", 1, 2)))
    with pytest.raises(ValueError):
        Continuous("bad", 5.0, 1.0)


def test_policy_param_spaces_build_valid_policies():
    rows = [_cell()]
    ctx = {"rows": rows, "constraint": None, "units_per_step": 64}
    from repro.core.recommender import Constraint
    ctx["constraint"] = Constraint(max_step_latency_s=1.0)
    for cls, kw in ((StaticPolicy, {}), (ReactivePolicy, {}),
                    (QueueProportionalPolicy, {}), (PredictivePolicy, ctx)):
        space = cls.param_space()
        for params in space.sample_lhs(8, seed=1):
            pol = cls.from_params(params, **kw)
            assert isinstance(pol, cls)
    # the reactive reparameterization keeps every sample constructor-legal
    for params in ReactivePolicy.param_space().sample_lhs(64, seed=2):
        pol = ReactivePolicy.from_params(params)
        assert 0.0 <= pol.lower < pol.upper <= 1.0


def test_cross_cutting_dims_route_to_simulation():
    ts = _static_scenario()
    space = (StaticPolicy.param_space() + ParamSpace((discipline_dim(),))
             + quota_dims(ts.fleet, hi=8))
    label = ts.fleet.pools[0].label
    params = dict(space.sample_lhs(1, seed=0)[0])
    params.update({"discipline": "edf", f"quota:{label}": 3,
                   "n_replicas": 64})
    policy_params, discipline, fleet = ts.split_params(params)
    assert policy_params == {"n_replicas": 64}
    assert discipline == "edf"
    assert fleet.pools[0].max_replicas == 3
    sim = ts.simulate(params, 0, 2)
    assert sim.discipline == "edf"
    assert sim.replicas.max() <= 3        # quota binds the 64-replica ask
    # quota dims never exceed the pool's own cloud quota, tolerate lo=0
    # (scale-to-zero search), and skip unsearchable pools
    capped = FleetConfig((PoolConfig(service=ts.fleet.pools[0].service,
                                     max_replicas=16),))
    qd = quota_dims(capped, lo=0)
    assert [d.hi for d in qd.dims] == [16]
    assert all(v <= 16 for c in qd.sample_lhs(16, seed=0)
               for v in c.values())
    tiny = FleetConfig((PoolConfig(service=ts.fleet.pools[0].service,
                                   max_replicas=1),))
    assert len(quota_dims(tiny, lo=1)) == 0


# ---------------------------- paired evaluation -----------------------------

def test_paired_evaluation_matches_direct_simulation():
    ts = _static_scenario(n_seeds=4)
    obj = Objective(min_attainment=0.99)
    ev = evaluate_candidates(ts, [{"n_replicas": 6}], obj)[0]
    assert ev.n_seeds == 4
    from repro.fleet import summarize
    rep = summarize(simulate(ts.workload.traces[0], ts.fleet.pools[0].service,
                             StaticPolicy(6), slo_s=2.0, cold_start_s=30.0,
                             initial_replicas=8))
    assert ev.mean_cost() == pytest.approx(rep.usd_per_hour)
    assert ev.mean_attainment() == pytest.approx(rep.slo_attainment)
    assert ev.p99_s() == pytest.approx(rep.p99_s)


# ---------------------------- racing ----------------------------------------

def test_known_optimum_never_culled_at_any_budget():
    ts = _static_scenario(n_seeds=8)
    obj = Objective(min_attainment=0.99)
    grid = StaticPolicy.param_space().grid(8)
    best = exhaustive(ts, grid, obj).winner.params
    for init_seeds in (1, 2, 4, 8):
        rr = race(ts, grid, obj, init_seeds=init_seeds)
        assert rr.winner.params == best
        assert best in [e.params for e in rr.survivors]


def test_racing_beats_40pct_budget_with_exhaustive_winner():
    ts = _static_scenario(n_seeds=16)
    obj = Objective(min_attainment=0.99)
    grid = [{"n_replicas": n} for n in range(1, 19)]
    ex = exhaustive(ts, grid, obj)
    rr = race(ts, grid, obj, init_seeds=2)
    assert rr.winner.params == ex.winner.params
    assert rr.sims_used <= 0.4 * ex.sims_used
    assert rr.full_budget == ex.sims_used


def test_sprt_culls_dominated_configs_early():
    ts = _static_scenario(n_seeds=16)
    obj = Objective(min_attainment=0.99)
    rr = race(ts, [{"n_replicas": n} for n in (4, 16)], obj, init_seeds=2)
    # 16 replicas cost 4x the feasible 4-replica config every seed: the SPRT
    # should dismiss it long before the full 16-replicate budget
    loser = next(e for e in rr.evals if e.params == {"n_replicas": 16})
    assert loser.n_seeds < 16


# ---------------------------- tune() ----------------------------------------

def test_tune_seeded_determinism():
    ts = _static_scenario(n_seeds=8)
    space = StaticPolicy.param_space()
    budget = TuningBudget(n_candidates=10)
    reps = [tune(_static_scenario(n_seeds=8), space, Objective(), budget,
                 seed=7) for _ in range(2)]
    assert reps[0].winner.params == reps[1].winner.params
    assert [e.params for e in reps[0].frontier] == \
        [e.params for e in reps[1].frontier]
    assert reps[0].sims_used == reps[1].sims_used
    diff = tune(ts, space, Objective(), budget, seed=8)
    assert diff.sims_used > 0   # different seed still runs; winner may agree


def test_pareto_frontier_invariants():
    ts = _static_scenario(n_seeds=6)
    rep = tune(ts, StaticPolicy.param_space(), Objective(),
               TuningBudget(n_candidates=12), seed=0)
    costs = [e.mean_cost() for e in rep.frontier]
    atts = [e.mean_attainment() for e in rep.frontier]
    assert costs == sorted(costs)
    assert all(a2 > a1 for a1, a2 in zip(atts, atts[1:]))
    for e in rep.evals:        # no frontier member is dominated by anyone
        for f in rep.frontier:
            dominated = (e.mean_cost() <= f.mean_cost()
                         and e.mean_attainment() > f.mean_attainment()
                         and e.mean_cost() < f.mean_cost())
            assert not dominated


def test_tune_report_builds_runnable_policy():
    scenario = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scenario.service_for(scenario.cheapest_shape())
    tr = flash_crowd_trace(3.5 * svc.max_throughput, 1200.0, dt_s=5.0,
                           peak_mult=4.0, burst_width_s=60.0, n_seeds=8,
                           seed=2)
    ts = tuning_scenario(scenario, tr, PredictivePolicy, cold_start_s=30.0)
    rep = tune(ts, PredictivePolicy.param_space(),
               Objective(min_attainment=1.0, penalty_usd_per_hour=1e5),
               TuningBudget(n_candidates=16), seed=0,
               baseline={"horizon_s": 60.0, "window_bins": 12,
                         "headroom": 0.85})
    # the winner is the best full-budget survivor by construction
    assert rep.winner.n_seeds == ts.n_seeds
    assert isinstance(rep.dominates_baseline(), bool)
    assert rep.baseline.n_seeds == ts.n_seeds
    pol = rep.build_policy()
    assert isinstance(pol, PredictivePolicy)
    sim = ts.simulate(rep.winner.params, 0, ts.n_seeds)
    assert sim.policy_name == "predictive"
    assert "Pareto" in rep.summary() or "frontier" in rep.summary()


def test_paired_vs_independent_evaluation_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=50),
           n=st.integers(min_value=3, max_value=10))
    def prop(seed, n):
        obj = Objective(min_attainment=0.99)
        a = _static_scenario(n_seeds=8, seed=seed)
        b = _static_scenario(n_seeds=8, seed=seed + 1000)
        cand = {"n_replicas": n}
        ea = evaluate_candidates(a, [cand], obj)[0]
        eb = evaluate_candidates(b, [cand], obj)[0]
        # paired and independent-seed evaluation estimate the same expected
        # cost: their means agree within the sum of CI widths (plus float
        # slack for the zero-variance deterministic regime)
        tol = 3 * (ea.cost_ci() + eb.cost_ci()) + 0.02 * ea.mean_cost()
        assert abs(ea.mean_cost() - eb.mean_cost()) <= tol

    prop()


# ---------------------------- surfaces bugfix -------------------------------

def test_underdetermined_quadratic_falls_back_to_linear():
    # 4 points, 2 dims: quadratic needs 6 columns -> must degrade to linear
    X = np.array([[1.0, 1.0], [2.0, 1.0], [1.0, 2.0], [2.0, 2.0]])
    y = 3.0 * X[:, 0] * X[:, 1]
    surf = fit_response_surface(["a", "b"], X, y, degree=2)
    assert surf.degree == 1
    assert 0.0 <= surf.r2 <= 1.0 + 1e-12
    assert surf.predict({"a": 1.5, "b": 1.5}) > 0


def test_underdetermined_linear_raises():
    with pytest.raises(ValueError, match="degree-1"):
        fit_response_surface(["a", "b"], [[1.0, 2.0], [2.0, 1.0]],
                             [1.0, 2.0], degree=2)
    # nonpositive rows are dropped BEFORE the count check: 3 raw points but
    # only 1 usable -> even degree-1 (2 columns) is underdetermined
    with pytest.raises(ValueError):
        fit_response_surface(["a"], [[1.0], [-2.0], [-1.0]],
                             [1.0, 2.0, 3.0], degree=1)


def test_determined_fits_unchanged():
    rng = np.random.default_rng(0)
    X = rng.uniform(8, 512, size=(40, 2))
    y = 1e-6 * X[:, 0] ** 2 * X[:, 1]
    surf = fit_response_surface(["m", "n"], X, y, degree=2)
    assert surf.degree == 2 and surf.r2 > 0.999


# ---------------------------- CSV trace ingestion ---------------------------

def test_load_trace_csv_header_comments_and_named_column(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("# a comment\nminute,rps\n# another\n0,10\n5,20\n10,30\n")
    tr = load_trace_csv(p, rate_col="rps", dt_s=300.0, n_seeds=3, seed=1)
    assert tr.n_bins == 3 and tr.n_seeds == 3
    assert np.allclose(tr.rate, [10.0, 20.0, 30.0])
    assert tr.name == "trace"
    # deterministic + equals replay_trace on the same rates
    ref = replay_trace([10.0, 20.0, 30.0], 300.0, 3, 1, name="trace")
    assert np.array_equal(tr.arrivals, ref.arrivals)


def test_load_trace_csv_index_column_and_rescale(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("0,4\n1,8\n2,12\n")
    tr = load_trace_csv(p, rate_col=1, dt_s=60.0, mean_rate_per_s=16.0,
                        n_seeds=2)
    assert tr.mean_rate == pytest.approx(16.0)
    assert np.allclose(tr.rate, [8.0, 16.0, 24.0])


def test_load_trace_csv_corrupt_first_row_is_not_a_header(tmp_path):
    # a data row whose *other* column is corrupt must not be swallowed as a
    # header (that would drop the bin and shift the whole trace in time)
    p = tmp_path / "c.csv"
    p.write_text("n/a,5.0\n1,6.0\n2,7.0\n")
    tr = load_trace_csv(p, rate_col=1, dt_s=60.0, n_seeds=1)
    assert np.allclose(tr.rate, [5.0, 6.0, 7.0])
    # but a corrupt rate cell in the first row IS an error, not a header
    q = tmp_path / "d.csv"
    q.write_text("0,oops\n1,6.0\n")
    with pytest.raises(ValueError, match="not a number"):
        load_trace_csv(q, rate_col=1)


def test_load_trace_csv_rejects_bad_rows(tmp_path):
    bad_nan = tmp_path / "nan.csv"
    bad_nan.write_text("t,r\n0,1.0\n5,nan\n")
    with pytest.raises(ValueError, match="non-finite"):
        load_trace_csv(bad_nan, rate_col="r")
    bad_txt = tmp_path / "txt.csv"
    bad_txt.write_text("0,1.0\n5,oops\n")
    with pytest.raises(ValueError, match="not a number"):
        load_trace_csv(bad_txt, rate_col=1)
    short = tmp_path / "short.csv"
    short.write_text("0,1.0\n5\n")
    with pytest.raises(ValueError, match="column"):
        load_trace_csv(short, rate_col=1)
    with pytest.raises(ValueError, match="no column"):
        load_trace_csv(bad_nan, rate_col="missing")


def test_bundled_azure_day_trace_loads():
    tr = load_trace_csv(DATA_CSV, rate_col="requests_per_s", dt_s=300.0,
                        n_seeds=2)
    assert tr.n_bins == 288                     # one day of 5-minute bins
    assert tr.duration_s == pytest.approx(86400.0)
    assert 0 < tr.mean_rate < tr.peak_rate


# ---------------------------- stochastic cold starts ------------------------

def test_zero_jitter_cold_start_byte_identical():
    svc = _service()
    tr = flash_crowd_trace(5 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=3, seed=0)
    a = simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                 cold_start_s=60.0)
    b = simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                 cold_start_s=(60.0, 0.0), cold_start_seed=123)
    for k in ("served", "queue", "billed_replicas", "latency_s", "ok_served"):
        assert np.array_equal(getattr(a, k), getattr(b, k))


def test_jittered_cold_start_seeded_and_material():
    svc = _service()
    tr = flash_crowd_trace(5 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=3, seed=0)
    kw = dict(slo_s=2.0, cold_start_s=(60.0, 0.8))
    a = simulate(tr, svc, QueueProportionalPolicy(), cold_start_seed=1, **kw)
    b = simulate(tr, svc, QueueProportionalPolicy(), cold_start_seed=1, **kw)
    c = simulate(tr, svc, QueueProportionalPolicy(), cold_start_seed=2, **kw)
    d = simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                 cold_start_s=60.0)
    assert np.array_equal(a.billed_replicas, b.billed_replicas)
    assert not np.array_equal(a.billed_replicas, c.billed_replicas)
    assert not np.array_equal(a.billed_replicas, d.billed_replicas)
    # conservation still holds under jittered spin-ups
    total = a.served.sum(axis=1) + a.dropped.sum(axis=1) + a.queue[:, -1]
    assert np.allclose(total, a.arrivals.sum(axis=1))


def test_jittered_cold_start_slice_paired_with_full_run():
    """A seed slice simulated with its absolute ``seed_indices`` must
    reproduce exactly the rows of a full-workload simulation — the paired
    property racing's incremental slices rely on under jitter."""
    svc = _service()
    tr = flash_crowd_trace(5 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=6, seed=0)
    kw = dict(slo_s=2.0, cold_start_s=(60.0, 0.7), cold_start_seed=3)
    full = simulate(tr, svc, QueueProportionalPolicy(), **kw)
    from repro.fleet import Trace
    part = simulate(Trace(tr.name, tr.dt_s, tr.rate, tr.arrivals[2:5]), svc,
                    QueueProportionalPolicy(), seed_indices=np.arange(2, 5),
                    **kw)
    assert np.array_equal(full.billed_replicas[2:5], part.billed_replicas)
    assert np.array_equal(full.served[2:5], part.served)


def test_cold_start_spec_validation():
    svc = _service()
    with pytest.raises(ValueError):
        PoolConfig(service=svc, cold_start_s=(30.0, -0.1))
    with pytest.raises(ValueError):
        PoolConfig(service=svc, cold_start_s=(-5.0, 0.2))
    with pytest.raises(ValueError):        # 1-element typo of the pair spec
        PoolConfig(service=svc, cold_start_s=(30.0,))
    with pytest.raises(ValueError):
        PoolConfig(service=svc, cold_start_s=(30.0, 0.2, 1.0))
    assert PoolConfig(service=svc,
                      cold_start_s=(30.0, 0.2)).cold_start_mean_s == 30.0


def test_jittered_cold_start_mean_delay_tracks_mean():
    """Launch one big scale-up and measure when capacity matures: the mean
    maturation delay over many seeds must track cold_start_mean_s."""
    svc = _service()
    rates = np.concatenate([np.zeros(2), np.full(58, 3 * svc.max_throughput)])
    tr = replay_trace(rates, dt_s=5.0, n_seeds=64, seed=4)
    sim = simulate(tr, svc, StaticPolicy(6), slo_s=2.0,
                   cold_start_s=(30.0, 0.5), initial_replicas=0,
                   min_replicas=0, cold_start_seed=9)
    # replicas requested at bin 0 mature ~30s later on average
    t_ready = (sim.replicas[:, :] >= 3).argmax(axis=1) * 5.0
    assert 15.0 <= t_ready.mean() <= 50.0


# ---------------------------- benchmark headline ----------------------------

def test_tuner_benchmark_headline_invariants():
    """The acceptance headline, at the benchmark's own CI budget: tuned
    dominates default, surface r2 >= 0.8, racing <= 40% of the sweep with
    the exhaustive winner."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import tune_controller
    report, bench = tune_controller.run(full=False)
    head = bench["headline"]
    assert head["tuned_dominates_default"]
    assert head["tuned"]["worst_class_attainment"] >= \
        head["default"]["worst_class_attainment"] - 1e-9
    assert head["tuned"]["usd_per_hour"] <= \
        head["default"]["usd_per_hour"] + 1e-9
    assert bench["surface_r2"] >= 0.8
    assert bench["budget"]["frac"] <= 0.4
    assert bench["race_vs_exhaustive"]["same_winner"]
    assert bench["race_vs_exhaustive"]["race_frac"] <= 0.4


def test_joint_optimum_differs_from_greedy_per_dim():
    """The why-scope-jointly pin: on the tiered-SLA scenario the greedy
    pass (size the fleet under FIFO, then pick the discipline at that size)
    locks in FIFO's replica count, while the joint (discipline x
    n_replicas) sweep finds a deadline-aware discipline meeting the tiers
    with fewer replicas — different params, strictly better score."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import tune_controller
    jo = tune_controller.run_joint_optimum(n_seeds=4, duration_s=600.0)
    assert jo["joint_beats_greedy"]
    assert jo["joint"]["params"] != jo["greedy"]["params"]
    assert jo["joint"]["score"] < jo["greedy"]["score"]
    # the coupling is the point: joint meets the bar with FEWER replicas
    # on a deadline-aware discipline than greedy's FIFO-sized fleet
    assert jo["joint"]["params"]["n_replicas"] \
        < jo["greedy"]["params"]["n_replicas"]
    assert jo["joint"]["params"]["discipline"] != "fifo"
    assert jo["joint"]["worst_class_attainment"] >= jo["attainment_bar"]
