"""MSET2 + memory-vector selection + pluggable algorithms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.mset import estimate, get_plugin, train
from repro.mset.memory_vectors import select_memory_vectors
from repro.tpss import TPSSParams, synthesize

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def telemetry():
    return synthesize(KEY, TPSSParams(n_signals=16, n_obs=2048))


def test_memory_vector_selection_covers_envelope(telemetry):
    X = telemetry
    idx = select_memory_vectors(X, 64)
    assert idx.shape == (64,)
    sel = X[idx]
    # min-max algorithm guarantees the envelope is represented
    assert np.allclose(np.asarray(sel.min(0)), np.asarray(X.min(0)))
    assert np.allclose(np.asarray(sel.max(0)), np.asarray(X.max(0)))


def test_mset2_reconstructs_clean_data(telemetry):
    X = telemetry
    model = train(X[:1536], n_memvec=128)
    xhat, res = estimate(model, X[1536:])
    ratio = float(jnp.sqrt(jnp.mean(res**2)) / jnp.std(X[1536:]))
    assert ratio < 0.15, f"residual ratio {ratio}"


def test_mset2_estimate_shapes(telemetry):
    model = train(telemetry[:1024], n_memvec=64)
    xhat, res = estimate(model, telemetry[1024:1100])
    assert xhat.shape == (76, 16)
    assert res.shape == (76, 16)
    assert not bool(jnp.any(jnp.isnan(xhat)))


def test_mset2_memvec_interpolation(telemetry):
    """Estimating the memory vectors themselves must be near-exact."""
    model = train(telemetry[:1024], n_memvec=64)
    D_raw = model.D * model.std + model.mean
    xhat, res = estimate(model, D_raw)
    rel = float(jnp.mean(jnp.abs(res)) / jnp.std(D_raw))
    assert rel < 0.05, rel


def test_mset2_detects_structural_change(telemetry):
    model = train(telemetry[:1536], n_memvec=128)
    clean = telemetry[1536:]
    _, res_clean = estimate(model, clean)
    broken = clean.at[:, 3].set(clean[:, 3] + 8 * float(jnp.std(clean[:, 3])))
    _, res_broken = estimate(model, broken)
    assert float(jnp.mean(jnp.abs(res_broken[:, 3]))) > \
        5 * float(jnp.mean(jnp.abs(res_clean[:, 3])))


@pytest.mark.parametrize("name", ["mset2", "aakr", "ridge"])
def test_pluggable_algorithms(name, telemetry):
    plug = get_plugin(name)
    model = plug.train(telemetry[:1024], 64)
    xhat, res = plug.estimate(model, telemetry[1024:1200])
    assert xhat.shape == (176, 16)
    ratio = float(jnp.sqrt(jnp.mean(res**2)) / jnp.std(telemetry))
    assert ratio < 0.5, f"{name}: {ratio}"
