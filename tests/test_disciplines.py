"""Multi-class scheduling disciplines (FIFO / strict priority / EDF).

The vectorized cohort engine (``repro.fleet.discipline``) is validated
against a brute-force per-request replay for all three disciplines, then
hypothesis property tests pin the structural invariants:

(a) per-class served + dropped + backlog conservation under every discipline,
(b) EDF never misses a deadline on any trace FIFO can schedule feasibly
    (EDF optimality — the classical Liu & Layland / Dertouzos result),
(c) single-class (and identical-class) EDF/priority degenerate exactly to
    FIFO, and
(d) the top-priority class is never worse off under strict priority than
    under FIFO.
"""
import numpy as np
import pytest

from repro.fleet import (DISCIPLINES, RequestClass, StaticPolicy, Workload,
                         get_discipline, interactive_batch_workload,
                         multiclass_cohort_metrics, poisson_trace,
                         replay_trace, simulate, simulate_fleet,
                         split_service, summarize, tiered_sla_workload)
from repro.fleet.workload import ServiceModel

from repro.core import get_shape

DT = 1.0


def _classes(slos=(1.5, 4.0), prios=None):
    prios = prios or range(len(slos))
    return tuple(RequestClass(f"c{i}", s, priority=p)
                 for i, (s, p) in enumerate(zip(slos, prios)))


def _service(t_fixed=0.5, t_unit=0.25, max_batch=2, shape="v5e-4"):
    return ServiceModel("svc", get_shape(shape), t_fixed, t_unit, max_batch)


def _random_case(rng, S=2, T=10, C=2, max_arr=4, max_cap=6):
    admitted = rng.integers(0, max_arr + 1, size=(S, T, C)).astype(float)
    capacity = rng.integers(0, max_cap + 1, size=(S, T)).astype(float)
    slot_bin = np.arange(T)
    slot_bt = rng.uniform(0.05, 0.8, size=(S, T))
    return admitted, capacity, slot_bin, slot_bt


# ------------------ brute-force per-request replay ---------------------------

def _bruteforce_split(discipline, classes, admitted, capacity, slot_bin,
                      dt_s=DT):
    """Per-request replay with explicit Python loops: serve the smallest
    (key, class, arrival) requests among those already arrived."""
    disc = get_discipline(discipline)
    S, T, C = admitted.shape
    keys = disc.keys(classes, T, dt_s)
    K = len(slot_bin)
    served = np.zeros((S, K, C))
    for s in range(S):
        queue = []                       # (key, class, arrival_bin) requests
        t_next = 0
        for k in range(K):
            while t_next <= slot_bin[k]:
                for c in range(C):
                    queue += [(keys[c, t_next], c, t_next)] * \
                        int(admitted[s, t_next, c])
                t_next += 1
            queue.sort()
            n = int(min(capacity[s, k], len(queue)))
            for key, c, t_arr in queue[:n]:
                served[s, k, c] += 1
            del queue[:n]
    return served


@pytest.mark.parametrize("disc", sorted(DISCIPLINES))
def test_split_matches_bruteforce(disc):
    rng = np.random.default_rng(hash(disc) % 2 ** 16)
    classes = _classes(slos=(1.5, 4.0, 9.0), prios=(2, 0, 1))
    for _ in range(20):
        adm, cap, sbin, _ = _random_case(rng, C=3)
        got = split_service(disc, classes, adm, cap, sbin, DT)
        want = _bruteforce_split(disc, classes, adm, cap, sbin, DT)
        np.testing.assert_allclose(got, want, atol=1e-9)


@pytest.mark.parametrize("disc", sorted(DISCIPLINES))
def test_split_sojourns_match_bruteforce(disc):
    """End to end: the engine split + per-class cohort arithmetic reproduces
    the brute-force per-request sojourn multiset and deadline misses."""
    from collections import deque
    rng = np.random.default_rng(1 + hash(disc) % 2 ** 16)
    classes = _classes(slos=(1.5, 4.0), prios=(1, 0))
    for _ in range(15):
        adm, cap, sbin, sbt = _random_case(rng, C=2)
        served = split_service(disc, classes, adm, cap, sbin, DT)
        cms = multiclass_cohort_metrics(adm, served, sbin, sbt, DT,
                                        [c.slo_s for c in classes])
        S, T, C = adm.shape
        for c, cm in enumerate(cms):
            ok_ref = np.zeros((S, T))
            soj_ref = []
            for s in range(S):
                fifo = deque()
                for t in range(T):
                    fifo.extend([t] * int(adm[s, t, c]))
                for k in range(T):
                    batch = [fifo.popleft()
                             for _ in range(int(served[s, k, c]))]
                    sojs = [(sbin[k] - t_arr) * DT + sbt[s, k]
                            for t_arr in batch]
                    soj_ref.extend(sojs)
                    ok_ref[s, k] = sum(
                        1 for x in sojs if x <= classes[c].slo_s + 1e-12)
            np.testing.assert_allclose(cm.ok_served, ok_ref, atol=1e-9)
            expand = np.repeat(cm.sojourn_values,
                               np.round(cm.sojourn_weights).astype(int))
            np.testing.assert_allclose(np.sort(expand), np.sort(soj_ref),
                                       atol=1e-9)


# ------------------ structural behaviour -------------------------------------

def test_priority_preempts_fifo_order():
    # one low-priority request queued first, then a high-priority burst:
    # priority serves the burst first, FIFO the old request
    classes = _classes(slos=(5.0, 5.0), prios=(1, 0))
    adm = np.zeros((1, 3, 2))
    adm[0, 0, 0] = 1.0          # low-prio arrives at t=0
    adm[0, 1, 1] = 1.0          # high-prio arrives at t=1
    cap = np.array([[0.0, 1.0, 1.0]])
    fifo = split_service("fifo", classes, adm, cap, np.arange(3), DT)
    prio = split_service("priority", classes, adm, cap, np.arange(3), DT)
    assert fifo[0, 1, 0] == 1.0 and fifo[0, 2, 1] == 1.0
    assert prio[0, 1, 1] == 1.0 and prio[0, 2, 0] == 1.0


def test_edf_orders_by_absolute_deadline():
    # tight-deadline class arriving later still jumps a queued loose cohort
    classes = _classes(slos=(1.0, 10.0), prios=(0, 0))
    adm = np.zeros((1, 3, 2))
    adm[0, 0, 1] = 1.0          # loose (deadline 10) at t=0
    adm[0, 2, 0] = 1.0          # tight (deadline 2+1=3) at t=2
    cap = np.array([[0.0, 0.0, 1.0]])
    edf = split_service("edf", classes, adm, cap, np.arange(3), DT)
    assert edf[0, 2, 0] == 1.0 and edf[0, 2, 1] == 0.0


def test_unknown_discipline_rejected():
    with pytest.raises(ValueError):
        get_discipline("lifo")


def test_simulator_single_class_identical_under_all_disciplines():
    svc = _service(t_fixed=0.1, t_unit=0.4 / 64, max_batch=64)
    tr = poisson_trace(3 * svc.max_throughput, 600.0, dt_s=5.0, n_seeds=3,
                       seed=9)
    sims = {d: simulate(tr, svc, StaticPolicy(4), slo_s=2.0, discipline=d,
                        initial_replicas=4, max_queue=5e4)
            for d in ("fifo", "priority", "edf")}
    ref = sims["fifo"]
    for d in ("priority", "edf"):
        for k in ("served", "dropped", "queue", "latency_s", "ok_served",
                  "billed_replicas"):
            np.testing.assert_array_equal(getattr(ref, k),
                                          getattr(sims[d], k))


def test_simulator_multiclass_conservation_with_drops():
    svc = _service(t_fixed=0.1, t_unit=0.4 / 64, max_batch=64)
    classes = _classes(slos=(1.0, 30.0))
    traces = [poisson_trace(2 * svc.max_throughput, 600.0, dt_s=5.0,
                            n_seeds=3, seed=s) for s in (0, 1)]
    wl = Workload("mix", classes, traces)
    for d in ("fifo", "priority", "edf"):
        sim = simulate(wl, svc, StaticPolicy(2), discipline=d,
                       initial_replicas=2, max_queue=200.0)
        tot = (sim.class_served.sum(axis=1) + sim.class_dropped.sum(axis=1)
               + sim.class_queue[:, -1, :])
        np.testing.assert_allclose(tot, wl.arrivals.sum(axis=1), rtol=1e-9,
                                   atol=1e-6)
        assert sim.dropped.sum() > 0          # the bound actually bound
        # aggregate records equal the class sums
        np.testing.assert_allclose(sim.class_served.sum(axis=2), sim.served,
                                   atol=1e-6)
        np.testing.assert_allclose(sim.class_dropped.sum(axis=2), sim.dropped,
                                   atol=1e-6)
        rep = summarize(sim)
        assert len(rep.class_reports) == 2
        assert rep.discipline == d


def test_drops_shed_least_critical_class_first():
    # queue bound 2, burst of both classes at t=0: the overflow comes out of
    # the class the discipline serves last
    svc = _service(t_fixed=1.0, t_unit=0.0, max_batch=1)   # 1 req/s/replica
    classes = _classes(slos=(1.0, 30.0), prios=(0, 1))
    tr0 = replay_trace(np.array([6.0, 0, 0]), dt_s=1.0, n_seeds=1, seed=0)
    tr1 = replay_trace(np.array([6.0, 0, 0]), dt_s=1.0, n_seeds=1, seed=0)
    tr0.arrivals[:] = np.array([[6, 0, 0]])
    tr1.arrivals[:] = np.array([[6, 0, 0]])
    wl = Workload("burst", classes, (tr0, tr1))
    sim = simulate(wl, svc, StaticPolicy(1), discipline="edf",
                   initial_replicas=1, max_queue=2.0)
    # 12 arrive, 2 admitted; all drops land on the loose class first
    assert sim.class_dropped[0, 0, 1] == pytest.approx(6.0)
    assert sim.class_dropped[0, 0, 0] == pytest.approx(4.0)


def test_benchmark_tiered_sla_deadline_disciplines_beat_fifo():
    """The fleet_scaling acceptance invariant: on the tiered-SLA mixed-class
    flash crowd, EDF and strict priority meet every class SLO at lower cost
    than FIFO, and FIFO at the EDF winner's capacity misses the bar."""
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "fleet_scaling", os.path.join(os.path.dirname(__file__), "..",
                                      "benchmarks", "fleet_scaling.py"))
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)
    summary, cheapest = bench.run_tiered(full=False)
    assert set(cheapest) == {"fifo", "priority", "edf"}
    bar = bench.TIERED_ATTAINMENT_BAR
    for disc, (n, rep) in cheapest.items():
        assert rep.worst_class_attainment() >= bar
    fifo_usd = cheapest["fifo"][1].usd_per_hour
    for disc in ("priority", "edf"):
        assert cheapest[disc][1].usd_per_hour < fifo_usd
    # capacity-equivalent FIFO fails the bar — discipline, not capacity, is
    # what meets the tiered SLOs
    eq = summary["fifo_at_edf_capacity"]
    assert eq["worst_class_attainment"] < bar
    assert eq["replicas"] == cheapest["edf"][0]
    # the JSON summary mirrors the reports CI gates on
    for disc, (n, rep) in cheapest.items():
        rec = summary["cheapest_feasible"][disc]
        assert rec["replicas"] == n
        assert rec["usd_per_hour"] == pytest.approx(rep.usd_per_hour)


def test_workload_builders():
    wl = interactive_batch_workload(100.0, 600.0, dt_s=5.0, n_seeds=3, seed=1)
    assert [c.name for c in wl.classes] == ["interactive", "batch"]
    assert wl.classes[0].slo_s < wl.classes[1].slo_s
    assert wl.classes[0].priority < wl.classes[1].priority
    assert wl.arrivals.shape == (3, 120, 2)
    total = wl.total_trace()
    assert total.arrivals.shape == (3, 120)
    np.testing.assert_array_equal(total.arrivals, wl.arrivals.sum(axis=2))
    tiers = tiered_sla_workload(100.0, 600.0, dt_s=5.0, n_seeds=2, seed=0)
    assert [c.name for c in tiers.classes] == ["gold", "silver", "bronze"]
    assert list(tiers.slos()) == [1.0, 4.0, 60.0]
    # coincident bursts: every tier peaks at the same bin
    peaks = [tr.rate.argmax() for tr in tiers.traces]
    assert len(set(peaks)) == 1


def test_workload_validation():
    classes = _classes()
    a = poisson_trace(5.0, 100.0, dt_s=5.0, n_seeds=2, seed=0)
    b = poisson_trace(5.0, 100.0, dt_s=1.0, n_seeds=2, seed=1)
    with pytest.raises(ValueError):
        Workload("bad", classes, (a, b))              # dt mismatch
    with pytest.raises(ValueError):
        Workload("bad", classes, (a,))                # count mismatch
    with pytest.raises(ValueError):
        Workload("bad", (classes[0], classes[0]), (a, a))   # dup names
    with pytest.raises(ValueError):
        RequestClass("neg", -1.0)
    with pytest.raises(ValueError):
        simulate_fleet(a, None, None)                 # Trace needs slo_s
    wl = Workload.from_trace(a, 2.0)
    with pytest.raises(ValueError):                   # Workload carries SLOs
        simulate(wl, _service(), StaticPolicy(1), slo_s=2.0)
