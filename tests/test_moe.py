"""MoE dispatch: gather path exactness, EP shard_map path equivalence (8 fake
devices, subprocess), capacity/dropping semantics."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import make_rules
from repro.models import moe

RULES = make_rules(None)


def _setup(E=8, k=2, T=32, d=16, ff=32, cap=64.0):
    cfg = get_config("olmoe-1b-7b", smoke=True).replace(
        n_experts=E, n_experts_per_tok=k, moe_d_ff=ff, d_model=d,
        capacity_factor=cap)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    from repro.distributed.sharding import unbox_values
    return cfg, unbox_values(p)


def _dense_reference(cfg, p, x):
    """Compute-every-expert reference (exact, no dropping)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, cfg.n_experts_per_tok)
    topv = topv / topv.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        h = xf @ p["w_up"][e]
        g = xf @ p["w_gate"][e]
        o = (jax.nn.silu(g) * h) @ p["w_down"][e]
        w_e = jnp.where(topi == e, topv, 0.0).sum(-1)
        y = y + o * w_e[:, None]
    return y.reshape(B, S, d)


def test_gather_path_matches_dense_reference():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe._moe_gather(cfg, p, x, RULES)
    ref = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5, rtol=1e-4)
    assert float(aux) > 0


def test_gather_path_drops_over_capacity():
    cfg, p = _setup(cap=0.25)  # tiny capacity -> drops
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe._moe_gather(cfg, p, x, RULES)
    ref = _dense_reference(cfg, p, x)
    # some tokens dropped -> outputs differ, but remain finite
    assert np.isfinite(np.asarray(y)).all()
    assert not np.allclose(np.asarray(y), np.asarray(ref))


def test_expert_padding():
    cfg = get_config("granite-moe-3b-a800m", smoke=True).replace(n_experts=10)
    assert moe.padded_experts(cfg, 4) == 12
    assert moe.padded_experts(cfg, None) == 10
    p = moe.init_moe(cfg, jax.random.PRNGKey(0), ep_size=4)
    from repro.distributed.sharding import unbox_values
    pv = unbox_values(p)
    assert pv["w_up"].shape[0] == 12
    assert pv["router"].shape[1] == 10       # router never selects pads


EP_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.distributed import make_rules
from repro.distributed.sharding import unbox_values
from repro.models import moe

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = make_rules(mesh)
cfg = get_config("olmoe-1b-7b", smoke=True).replace(
    n_experts=8, n_experts_per_tok=2, moe_d_ff=32, d_model=16,
    capacity_factor=64.0)
p = unbox_values(moe.init_moe(cfg, jax.random.PRNGKey(0), ep_size=4))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
with mesh:
    y_ep, aux_ep = jax.jit(lambda p, x: moe._moe_ep(cfg, p, x, rules))(p, x)
y_ref, aux_ref = moe._moe_gather(cfg, p, x, make_rules(None))
err = float(jnp.max(jnp.abs(y_ep - y_ref)))
print("ERR", err)
assert err < 1e-4, err
"""


@pytest.mark.slow
def test_ep_shard_map_matches_gather_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", EP_SNIPPET], capture_output=True,
                         text=True, env=env, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ERR" in out.stdout
