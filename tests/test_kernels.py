"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.attention import flash_attention, gqa_attention, mha_ref
from repro.kernels.similarity import similarity_pallas, similarity_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------ similarity --------------------------------

@pytest.mark.parametrize("m,b,n", [(64, 32, 16), (256, 256, 256), (130, 70, 33),
                                   (8, 8, 4), (512, 128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", ["inverse_distance", "gaussian"])
def test_similarity_kernel_matches_ref(m, b, n, dtype, kind):
    x = jax.random.normal(KEY, (m, n), dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), (b, n), dtype)
    ref = similarity_ref(x, y, 1.7, kind)
    out = similarity_pallas(x, y, 1.7, kind, interpret=True)
    tol = 5e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(4, 80), b=st.integers(4, 80), n=st.integers(2, 64),
       gamma=st.floats(0.5, 4.0))
def test_similarity_kernel_hypothesis(m, b, n, gamma):
    x = jax.random.normal(jax.random.PRNGKey(m * 7 + n), (m, n))
    y = jax.random.normal(jax.random.PRNGKey(b * 13 + n), (b, n))
    ref = similarity_ref(x, y, gamma)
    out = similarity_pallas(x, y, gamma, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_similarity_properties():
    x = jax.random.normal(KEY, (32, 8))
    s = similarity_ref(x, x, 1.0)
    # self-similarity: ~1 up to fp32 cancellation in the ||x||^2+||y||^2-2xy trick
    assert np.allclose(np.asarray(jnp.diag(s)), 1.0, atol=5e-3)
    assert np.allclose(np.asarray(s), np.asarray(s.T), atol=1e-5)  # symmetry
    assert float(s.min()) > 0 and float(s.max()) <= 1.0 + 1e-6     # range


# ------------------------------ attention ---------------------------------

@pytest.mark.parametrize("B,S,H,hd", [(2, 128, 2, 64), (1, 256, 4, 32),
                                      (2, 200, 2, 64), (1, 64, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, hd, causal):
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    ref = mha_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, bq=64, bkv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 128, 2, 32), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 32), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32), dtype)
    ref = mha_ref(q, k, v)
    out = flash_attention(q, k, v, bq=64, bkv=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_gqa_wrapper_expands_kv():
    q = jax.random.normal(KEY, (2, 64, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 32))
    out = gqa_attention(q, k, v, impl="interpret")
    kx = jnp.repeat(k, 4, 2)
    vx = jnp.repeat(v, 4, 2)
    ref = mha_ref(q, kx, vx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(S=st.integers(16, 160), hd=st.sampled_from([16, 32, 64]))
def test_flash_attention_hypothesis(S, hd):
    q = jax.random.normal(jax.random.PRNGKey(S), (1, S, 2, hd))
    k = jax.random.normal(jax.random.PRNGKey(S + 1), (1, S, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(S + 2), (1, S, 2, hd))
    ref = mha_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, bq=32, bkv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)
