"""Fleet subsystem: traces, service models, simulator, policies, reports."""
import numpy as np
import pytest

from repro.core import (CellResult, CloudShape, Constraint, RooflineTerms,
                        get_shape, recommend, register_shape)
from repro.fleet import (PredictivePolicy, QueueProportionalPolicy,
                         ReactivePolicy, StaticPolicy, comparison_table,
                         flash_crowd_trace, mset_scenario, poisson_trace,
                         ramp_trace, replay_trace, service_model_from_cell,
                         simulate, standard_traces, summarize,
                         weighted_percentile)


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch, "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw), units_per_step=kw.get("batch", 64))


# ---------------------------- traces ----------------------------------------

def test_trace_determinism_under_fixed_seed():
    a = poisson_trace(100.0, 600.0, dt_s=5.0, n_seeds=4, seed=7)
    b = poisson_trace(100.0, 600.0, dt_s=5.0, n_seeds=4, seed=7)
    assert np.array_equal(a.arrivals, b.arrivals)
    c = poisson_trace(100.0, 600.0, dt_s=5.0, n_seeds=4, seed=8)
    assert not np.array_equal(a.arrivals, c.arrivals)


def test_trace_shapes_and_rates():
    tr = ramp_trace(10.0, 100.0, 300.0, dt_s=5.0, n_seeds=3, seed=0)
    assert tr.arrivals.shape == (3, 60)
    assert tr.rate[0] < tr.rate[-1]
    assert tr.peak_rate <= 100.0 and tr.mean_rate > 10.0
    fl = flash_crowd_trace(10.0, 600.0, peak_mult=8.0, n_seeds=2, seed=0)
    assert fl.peak_rate > 5 * 10.0
    assert len(standard_traces(50.0, 300.0, n_seeds=2)) == 4
    assert all(t.n_seeds == 2 for t in standard_traces(50.0, 300.0, n_seeds=2))


# ---------------------------- service model ---------------------------------

def test_service_model_amortizes_batching():
    svc = _service()
    # fixed term = max(t_mem, t_coll), unit term = t_comp / batch
    assert svc.t_fixed == pytest.approx(0.1)
    assert svc.t_per_unit == pytest.approx(0.4 / 64)
    assert svc.batch_time(64) == pytest.approx(0.5)
    # throughput strictly improves with batch size
    th = svc.throughput(np.array([1, 8, 64]))
    assert th[0] < th[1] < th[2]
    assert svc.max_throughput == pytest.approx(64 / 0.5)


def test_service_terms_measured_cell_and_validation():
    measured = CellResult(params={}, mean_s=0.2)
    assert measured.service_terms(10) == (0.0, pytest.approx(0.02))
    with pytest.raises(ValueError):
        measured.service_terms(0)


# ---------------------------- simulator -------------------------------------

def test_simulator_deterministic_and_conserves_requests():
    tr = poisson_trace(500.0, 600.0, dt_s=5.0, n_seeds=4, seed=3)
    svc = _service()
    sims = [simulate(tr, svc, StaticPolicy(8), slo_s=2.0, cold_start_s=30.0,
                     max_queue=1e4) for _ in range(2)]
    for k in ("served", "dropped", "queue", "replicas", "latency_s"):
        assert np.array_equal(getattr(sims[0], k), getattr(sims[1], k))
    s = sims[0]
    total = s.served.sum(axis=1) + s.dropped.sum(axis=1) + s.queue[:, -1]
    assert np.allclose(total, s.arrivals.sum(axis=1))


def test_underprovisioned_static_fleet_misses_slo():
    svc = _service()
    rate = 6 * svc.max_throughput
    tr = poisson_trace(rate, 900.0, dt_s=5.0, n_seeds=2, seed=0)
    good = summarize(simulate(tr, svc, StaticPolicy(8), slo_s=2.0))
    bad = summarize(simulate(tr, svc, StaticPolicy(3), slo_s=2.0))
    assert good.slo_attainment > 0.95
    assert bad.slo_attainment < 0.5          # overloaded: queue diverges
    assert bad.p99_s > good.p99_s
    assert bad.usd_per_hour < good.usd_per_hour


def test_cold_start_delays_scale_up():
    svc = _service()
    tr = poisson_trace(6 * svc.max_throughput, 600.0, dt_s=5.0, n_seeds=2, seed=1)
    pol = QueueProportionalPolicy()
    fast = simulate(tr, svc, pol, slo_s=2.0, cold_start_s=0.0,
                    initial_replicas=1)
    slow = simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                    cold_start_s=120.0, initial_replicas=1)
    # with a long cold start the backlog peak is strictly worse
    assert slow.queue.max() > fast.queue.max()


def test_reactive_recovers_from_zero_replicas():
    svc = _service()
    # an idle trough lets the down rule reach zero replicas; the starvation
    # override must bring the fleet back once traffic returns
    rates = np.concatenate([np.zeros(100), np.full(100, 4 * svc.max_throughput)])
    tr = replay_trace(rates, dt_s=5.0, n_seeds=2, seed=0)
    sim = simulate(tr, svc, ReactivePolicy(cooldown_s=30.0), slo_s=2.0,
                   cold_start_s=30.0, initial_replicas=2)
    assert sim.served[:, -50:].sum() > 0
    assert sim.replicas[:, -1].min() >= 1


def test_cold_starting_replicas_are_billed():
    svc = _service()
    tr = poisson_trace(8 * svc.max_throughput, 600.0, dt_s=5.0, n_seeds=2, seed=5)
    sim = simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                   cold_start_s=120.0, initial_replicas=1)
    # scale-ups spend bins in cold start: billed strictly exceeds ready
    assert sim.billed_replicas.sum() > sim.replicas.sum()
    assert sim.replica_bins() == pytest.approx(
        sim.billed_replicas.sum(axis=1).mean())


def test_reactive_policy_scales_with_load():
    svc = _service()
    base = 2 * svc.max_throughput
    tr = flash_crowd_trace(base, 1800.0, dt_s=5.0, peak_mult=6.0,
                           n_seeds=2, seed=2)
    sim = simulate(tr, svc, ReactivePolicy(cooldown_s=30.0), slo_s=2.0,
                   cold_start_s=30.0)
    assert sim.replicas.max() > sim.replicas[:, 0].max()   # grew into the burst
    assert sim.replicas[:, -1].max() < sim.replicas.max()  # shrank after


# ---------------------------- predictive + recommend ------------------------

def test_predictive_policy_shape_comes_from_recommend():
    sc = mset_scenario(n_signals=256, n_memvec=1024, slo_s=1.0)
    pol = PredictivePolicy(sc.rows, sc.constraint(), sc.units_per_step)
    rec = recommend(sc.rows_at(), sc.constraint())
    assert pol.recommendation.shape.name == rec.shape.name
    assert pol.service.shape.name == rec.shape.name
    assert pol.surface is not None           # t_step(batch) surface fitted
    svc = sc.service_for(rec.shape.name)
    tr = poisson_trace(3 * svc.max_throughput, 600.0, dt_s=5.0,
                       n_seeds=2, seed=4)
    rep = summarize(simulate(tr, svc, pol, slo_s=sc.slo_s))
    assert rep.shape == rec.shape.name
    assert rep.slo_attainment > 0.9


def test_predictive_policy_raises_without_feasible_shape():
    sc = mset_scenario(n_signals=256, n_memvec=1024)
    with pytest.raises(ValueError):
        PredictivePolicy(sc.rows, Constraint(max_step_latency_s=1e-15),
                         sc.units_per_step)


# ---------------------------- report ----------------------------------------

def test_weighted_percentile():
    v = np.array([1.0, 2.0, 10.0])
    w = np.array([98.0, 1.0, 1.0])
    assert weighted_percentile(v, w, 50) == 1.0
    assert weighted_percentile(v, w, 99.5) == 10.0
    assert np.isnan(weighted_percentile(v, np.zeros(3), 50))


def test_weighted_percentile_edge_cases():
    v = np.array([3.0, 1.0, 2.0])
    w = np.array([1.0, 2.0, 1.0])
    assert weighted_percentile(v, w, 0) == 1.0      # q=0 is the min
    assert weighted_percentile(v, w, 100) == 3.0    # q=100 is the max
    # a single value is every percentile
    for q in (0, 50, 100):
        assert weighted_percentile(np.array([7.0]), np.array([2.0]), q) == 7.0
    # zero-weight entries are invisible, even at the extremes
    wz = np.array([0.0, 2.0, 1.0])
    assert weighted_percentile(v, wz, 0) == 1.0
    assert weighted_percentile(v, wz, 100) == 2.0


def test_comparison_table_renders():
    svc = _service()
    tr = poisson_trace(2 * svc.max_throughput, 300.0, dt_s=5.0, n_seeds=2, seed=0)
    reps = [summarize(simulate(tr, svc, StaticPolicy(4), slo_s=2.0)),
            summarize(simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0))]
    txt = comparison_table(reps)
    assert "| policy |" in txt and "static" in txt and "queue-prop" in txt


# ---------------------------- catalog registration --------------------------

def test_register_shape_roundtrip():
    s = CloudShape("test-fleet-2", (1, 2), ("data", "model"))
    register_shape(s)
    try:
        assert get_shape("test-fleet-2") is s
        with pytest.raises(ValueError):
            register_shape(CloudShape("test-fleet-2", (2, 1), ("data", "model")))
        register_shape(CloudShape("test-fleet-2", (2, 1), ("data", "model")),
                       overwrite=True)
        assert get_shape("test-fleet-2").mesh_shape == (2, 1)
    finally:
        from repro.core import catalog
        catalog.CATALOG[:] = [c for c in catalog.CATALOG
                              if c.name != "test-fleet-2"]
        catalog._BY_NAME.pop("test-fleet-2", None)
    with pytest.raises(KeyError):
        get_shape("test-fleet-2")
