"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.models import layers
from repro.models.mamba import ssd_chunked


# ---------------- SSD: chunked algorithm == naive recurrence ----------------

def _ssd_naive(xh, dt, A, Bm, Cm):
    """O(S·N·P) reference recurrence for SSD."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    state = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                       # (B, H)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, t], Bh[:, t], xh[:, t])
        state = state * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state))
    return jnp.stack(ys, axis=1), state


@settings(max_examples=10, deadline=None)
@given(S=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       H=st.sampled_from([2, 4]), N=st.sampled_from([4, 8]))
def test_ssd_chunked_equals_naive(S, chunk, H, N):
    cfg = get_config("mamba2-130m", smoke=True).replace(ssd_chunk=chunk)
    key = jax.random.PRNGKey(S * 31 + chunk)
    ks = jax.random.split(key, 4)
    B, P, G = 2, 8, 1
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[0], (B, S, G, N)) * 0.5
    y_ref, s_ref = _ssd_naive(xh, dt, A, Bm, Cm)
    y, s = ssd_chunked(cfg, xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-4, rtol=2e-3)


def test_ssd_grads_finite_at_scale():
    """Regression: masked-exp NaN gradients only appeared at realistic dims
    (chunk 128, long decays) — exercise a mid-size config through value_and_grad."""
    from repro.distributed import make_rules
    from repro.models import build_model

    cfg = get_config("mamba2-130m").replace(
        n_layers=2, vocab_size=512, ssd_chunk=128)
    m = build_model(cfg)
    params = m.init_values(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 256), 0, 512)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    (_, _), grads = jax.value_and_grad(
        lambda p: m.loss(p, batch, make_rules(None)), has_aux=True)(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


# ---------------- RoPE invariants ----------------

@settings(max_examples=10, deadline=None)
@given(S=st.integers(2, 32), frac=st.sampled_from([0.5, 1.0]))
def test_rope_preserves_norm_and_relative_positions(S, frac):
    key = jax.random.PRNGKey(S)
    x = jax.random.normal(key, (1, S, 2, 16))
    pos = jnp.arange(S)
    y = layers.apply_rope(x, pos, 10_000.0, frac)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               atol=1e-4, rtol=1e-4)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(S + 1), (1, 1, 1, 16))
    def dot_at(p, d):
        qr = layers.apply_rope(q, jnp.array([p]), 1e4, frac)
        kr = layers.apply_rope(k, jnp.array([p + d]), 1e4, frac)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(0, 3) - dot_at(11, 3)) < 1e-3


# ---------------- MoE routing conservation ----------------

@settings(max_examples=10, deadline=None)
@given(T=st.integers(4, 64), E=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_moe_group_conserves_tokens(T, E, k):
    from repro.models.moe import _group
    key = jax.random.PRNGKey(T * 3 + E)
    token_e = jax.random.randint(key, (T * k,), 0, E)
    token_w = jnp.ones((T * k,))
    C = T  # ample capacity: nothing dropped
    idx, w = _group(token_e, token_w, T, E, C)
    # every (token, slot) pair appears exactly once across the expert buffers
    counts = np.zeros(T + 1)
    for t in np.asarray(idx).ravel():
        counts[t] += 1
    assert counts[:T].sum() == T * k
    assert float(w.sum()) == T * k


@settings(max_examples=6, deadline=None)
@given(cap=st.sampled_from([1, 2, 4]))
def test_moe_group_respects_capacity(cap):
    from repro.models.moe import _group
    T, E, k = 32, 4, 2
    token_e = jnp.zeros((T * k,), jnp.int32)  # all tokens to expert 0
    token_w = jnp.ones((T * k,))
    idx, w = _group(token_e, token_w, T, E, cap)
    kept = (np.asarray(idx)[0] < T).sum()
    assert kept == cap                         # capacity enforced, rest dropped


# ---------------- norm / numerics ----------------

@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([8, 64, 256]))
def test_rmsnorm_scale_invariance(d):
    cfg = get_config("chatglm3-6b", smoke=True)
    p = {"scale": jnp.ones(d)}
    x = jax.random.normal(jax.random.PRNGKey(d), (2, 3, d))
    y1 = layers.apply_norm(cfg, p, x)
    y2 = layers.apply_norm(cfg, p, x * 100.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)


# ---------------- scoping cost-model invariants ----------------

@settings(max_examples=20, deadline=None)
@given(flops=st.floats(1e9, 1e18), b=st.floats(1e6, 1e15), c=st.floats(0, 1e13),
       chips=st.sampled_from([8, 64, 256, 512]))
def test_roofline_monotone_and_dominant(flops, b, c, chips):
    from repro.core import roofline
    t = roofline(flops, b, c, chips)
    t2 = roofline(flops * 2, b, c, chips)
    assert t2.t_compute >= t.t_compute
    assert t.t_step == max(t.t_compute, t.t_memory, t.t_collective)
    assert t.dominant in ("compute", "memory", "collective")
    half = roofline(flops, b, c, chips * 2)
    assert half.t_compute <= t.t_compute + 1e-12
