"""Data pipeline determinism + shard disjointness."""
import numpy as np

from repro.data import TokenPipeline


def test_determinism_per_step():
    p = TokenPipeline(vocab_size=1000, seq_len=32, global_batch=4, seed=3)
    a = p.batch(5)
    b = p.batch(5)
    c = p.batch(6)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_targets_are_shifted_tokens():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=2)
    b = p.batch(0)
    assert b["tokens"].shape == (2, 16)
    assert b["targets"].shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_host_shards_are_disjoint_and_deterministic():
    full = [TokenPipeline(1000, 16, 8, seed=1, n_hosts=2, host_id=h) for h in (0, 1)]
    b0 = np.asarray(full[0].batch(3)["tokens"])
    b1 = np.asarray(full[1].batch(3)["tokens"])
    assert b0.shape == (4, 16)
    assert not np.array_equal(b0, b1)
    # re-instantiation reproduces the same shard
    again = TokenPipeline(1000, 16, 8, seed=1, n_hosts=2, host_id=0)
    np.testing.assert_array_equal(b0, np.asarray(again.batch(3)["tokens"]))


def test_tokens_in_vocab_range():
    p = TokenPipeline(vocab_size=128, seq_len=64, global_batch=4)
    t = np.asarray(p.batch(0)["tokens"])
    assert t.min() >= 0 and t.max() < 128
