"""Constraint / recommender edge cases (degenerate probes, HBM bound,
elasticity plans with infeasible regions)."""
import numpy as np
import pytest

from repro.core import (CellResult, CloudShape, Constraint, RooflineTerms,
                        elasticity_plan, get_shape)
from repro.core.surfaces import fit_response_surface

SHAPE = get_shape("v5e-4")


def test_feasible_rejects_degenerate_step_times():
    c = Constraint(max_step_latency_s=10.0)
    assert not c.feasible(0.0, SHAPE)
    assert not c.feasible(-1.0, SHAPE)
    assert not c.feasible(float("nan"), SHAPE)
    assert not c.feasible(float("inf"), SHAPE)
    assert c.feasible(1e-9, SHAPE)


def test_feasible_hbm_bound():
    c = Constraint()
    at_limit = SHAPE.hw.hbm_per_chip
    assert c.feasible(0.1, SHAPE, hbm_used=at_limit)
    assert not c.feasible(0.1, SHAPE, hbm_used=at_limit * 1.001)
    assert c.feasible(0.1, SHAPE, hbm_used=None)


def test_feasible_throughput_and_price():
    c = Constraint(min_throughput_per_s=100.0, units_per_step=50.0)
    assert c.feasible(0.4, SHAPE)           # 125 units/s
    assert not c.feasible(1.0, SHAPE)       # 50 units/s
    cp = Constraint(max_usd_per_hour=SHAPE.price_per_hour - 0.01)
    assert not cp.feasible(0.1, SHAPE)


def test_elasticity_plan_marks_infeasible_growth_values():
    # surface: t grows linearly with n; only small n meets the latency bound
    X = np.array([[n] for n in (1.0, 2.0, 4.0, 8.0, 16.0)])
    y = X[:, 0] * 0.1
    shapes = [get_shape("v5e-4"), get_shape("v5e-8")]
    surfaces = {s.name: fit_response_surface(["n"], X, y, degree=1)
                for s in shapes}
    plan = elasticity_plan(surfaces, shapes, "n", [2.0, 4.0, 1e6],
                           base_params={}, constraint=Constraint(
                               max_step_latency_s=0.5))
    assert plan[0][1] == "v5e-4"            # cheapest feasible
    assert plan[-1][1] is None and plan[-1][2] is None   # no feasible shape
    assert [v for v, *_ in plan] == [2.0, 4.0, 1e6]
