"""Constraint / recommender edge cases (degenerate probes, HBM bound,
duplicate-cost ties, elasticity plans with infeasible regions)."""
import numpy as np

from repro.core import (CellResult, CloudShape, Constraint, RooflineTerms,
                        elasticity_plan, feasible_ranking, get_shape,
                        recommend, register_shape)
from repro.core.surfaces import fit_response_surface

SHAPE = get_shape("v5e-4")


def test_feasible_rejects_degenerate_step_times():
    c = Constraint(max_step_latency_s=10.0)
    assert not c.feasible(0.0, SHAPE)
    assert not c.feasible(-1.0, SHAPE)
    assert not c.feasible(float("nan"), SHAPE)
    assert not c.feasible(float("inf"), SHAPE)
    assert c.feasible(1e-9, SHAPE)


def test_feasible_hbm_bound():
    c = Constraint()
    at_limit = SHAPE.hw.hbm_per_chip
    assert c.feasible(0.1, SHAPE, hbm_used=at_limit)
    assert not c.feasible(0.1, SHAPE, hbm_used=at_limit * 1.001)
    assert c.feasible(0.1, SHAPE, hbm_used=None)


def test_feasible_throughput_and_price():
    c = Constraint(min_throughput_per_s=100.0, units_per_step=50.0)
    assert c.feasible(0.4, SHAPE)           # 125 units/s
    assert not c.feasible(1.0, SHAPE)       # 50 units/s
    cp = Constraint(max_usd_per_hour=SHAPE.price_per_hour - 0.01)
    assert not cp.feasible(0.1, SHAPE)


def test_recommend_survives_duplicate_cost_ties():
    # two distinct shapes with identical price AND step time: the feasible
    # sort must not fall through to comparing (unorderable) CloudShapes
    alt = CloudShape("v5e-4-tie", (4, 1), ("data", "model"))
    register_shape(alt)
    try:
        rows = [
            CellResult(params={}, shape_name=name,
                       terms=RooflineTerms(0.1, 0.02, 0.01))
            for name in ("v5e-4-tie", "v5e-4")
        ]
        c = Constraint(max_step_latency_s=1.0)
        rec = recommend(rows, c)
        # deterministic winner: ties break by chips then name
        assert rec.shape.name == "v5e-4"
        assert rec.usd_per_hour == SHAPE.price_per_hour
        ranking = feasible_ranking(rows, c)
        assert [s.name for _, _, s in ranking] == ["v5e-4", "v5e-4-tie"]
    finally:
        from repro.core import catalog
        catalog.CATALOG[:] = [s for s in catalog.CATALOG
                              if s.name != "v5e-4-tie"]
        catalog._BY_NAME.pop("v5e-4-tie", None)


def test_elasticity_plan_marks_infeasible_growth_values():
    # surface: t grows linearly with n; only small n meets the latency bound
    X = np.array([[n] for n in (1.0, 2.0, 4.0, 8.0, 16.0)])
    y = X[:, 0] * 0.1
    shapes = [get_shape("v5e-4"), get_shape("v5e-8")]
    surfaces = {s.name: fit_response_surface(["n"], X, y, degree=1)
                for s in shapes}
    plan = elasticity_plan(surfaces, shapes, "n", [2.0, 4.0, 1e6],
                           base_params={}, constraint=Constraint(
                               max_step_latency_s=0.5))
    assert plan[0][1] == "v5e-4"            # cheapest feasible
    assert plan[-1][1] is None and plan[-1][2] is None   # no feasible shape
    assert [v for v, *_ in plan] == [2.0, 4.0, 1e6]
