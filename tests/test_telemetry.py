"""Fleet telemetry layer: the metrics registry and span tracer, the opt-in
contract (off by default, bit-identical results, no-op helpers), stream
determinism and numpy==jax stream equality, the exporters (JSONL / Prometheus
text / ASCII dashboard), and the MSET+SPRT drift probe's headline behaviour —
quiet on a fresh baseline replicate, alarmed on an injected service-time
degradation."""
import json

import numpy as np
import pytest

from repro.core import CellResult, RooflineTerms, get_shape
from repro.fleet import (FleetConfig, Objective, PoolConfig, PredictivePolicy,
                         QueueProportionalPolicy, TuningBudget, diurnal_trace,
                         flash_crowd_trace, load_trace_csv, mset_scenario,
                         poisson_trace, service_model_from_cell, simulate,
                         simulate_fleet, telemetry, telemetry_dashboard,
                         tune, tuning_scenario)
from repro.fleet.telemetry import (MetricsRegistry, SpanTracer, export,
                                   record_sim, render_spans)

# bin-by-bin SimResult fields the off-vs-on runs must match byte for byte
BITEXACT_FIELDS = ("served", "queue", "billed_replicas", "latency_s",
                   "ok_served", "utilization", "dropped", "admitted",
                   "replicas", "pool_billed", "pool_served", "pool_replicas")


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch,
                              "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw),
                                   units_per_step=kw.get("batch", 64))


def _sim(seed=0, n_seeds=3, backend="numpy"):
    svc = _service()
    tr = flash_crowd_trace(4 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=n_seeds, seed=seed)
    return simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                    cold_start_s=30.0, backend=backend)


# ----------------------- registry instruments -------------------------------

def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    c = reg.counter("fleet_served_total", cls="interactive")
    c.inc(3)
    assert reg.counter("fleet_served_total", cls="interactive") is c
    assert reg.counter("fleet_served_total", cls="batch") is not c
    assert c.value == 3.0
    reg.gauge("fleet_depth").set(7.0)
    with pytest.raises(ValueError, match="already registered"):
        reg.series("fleet_depth")
    snap = reg.snapshot()
    assert snap["counter"]["fleet_served_total"]["cls=interactive"] == 3.0
    assert snap["gauge"]["fleet_depth"][""] == 7.0


def test_histogram_buckets_quantiles_and_weights():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0, float("inf")))
    h.observe([0.05, 0.5, 2.0], weights=[1.0, 2.0, 1.0])
    h.observe([0.5], weights=[0.0])            # zero weight: dropped
    np.testing.assert_allclose(h.counts, [1.0, 2.0, 1.0])
    assert h.count == 4.0
    assert h.sum == pytest.approx(0.05 + 1.0 + 2.0)
    assert h.quantile(0.5) == 1.0              # covering-bucket upper bound
    assert h.quantile(0.99) == float("inf")
    with pytest.raises(ValueError, match="sorted"):
        reg.histogram("bad_seconds", buckets=(1.0, 0.1, float("inf")))
    with pytest.raises(ValueError, match="inf"):
        reg.histogram("bad2_seconds", buckets=(0.1, 1.0))


def test_span_tracer_nesting_and_render():
    fake = iter(np.arange(0.0, 10.0, 0.5))
    tr = SpanTracer(clock=lambda: float(next(fake)))
    with tr.span("tune", scenario="flash"):
        with tr.span("tune.sample"):
            pass
        with tr.span("tune.race", rounds=3):
            with tr.span("jaxsim.dispatch", kind="cold"):
                pass
    assert len(tr.roots) == 1
    root = tr.roots[0]
    assert [c.name for c in root.children] == ["tune.sample", "tune.race"]
    assert root.duration_s > 0
    assert root.find("jaxsim.dispatch").attrs["kind"] == "cold"
    text = render_spans(tr.roots)
    for name in ("tune", "tune.sample", "tune.race", "jaxsim.dispatch"):
        assert name in text
    events = tr.to_events()
    paths = {e["path"] for e in events}
    assert "tune/tune.race/jaxsim.dispatch" in paths
    assert all(e["type"] == "span" for e in events)


# ----------------------- opt-in contract ------------------------------------

def test_helpers_are_noops_without_session():
    assert telemetry.active() is None
    with telemetry.span("anything", k=1) as s:
        assert s is None
    telemetry.counter("nope_total")
    telemetry.gauge("nope", 1.0)
    telemetry.event("nope")
    assert telemetry.active() is None


def test_session_nesting_records_to_innermost():
    with telemetry.session() as outer:
        telemetry.counter("outer_total")
        with telemetry.session() as inner:
            telemetry.counter("inner_total")
            assert telemetry.active() is inner
        assert telemetry.active() is outer
    assert outer.metrics.get("outer_total") is not None
    assert outer.metrics.get("inner_total") is None
    assert inner.metrics.get("inner_total").value == 1.0
    assert telemetry.active() is None


def test_disabled_session_is_bit_exact_per_backend():
    """Running under a telemetry session must not perturb results: the hook
    only reads the assembled SimResult."""
    for backend in ("numpy", "jax"):
        if backend == "jax":
            pytest.importorskip("jax")
        off = _sim(backend=backend)
        with telemetry.session():
            on = _sim(backend=backend)
        for k in BITEXACT_FIELDS:
            assert np.array_equal(getattr(off, k), getattr(on, k)), \
                f"{backend}: field {k!r} changed under telemetry"


def test_tune_output_identical_with_and_without_session():
    scn = mset_scenario(n_signals=256, n_memvec=512, fleet=1, slo_s=1.0)
    svc = scn.service_for(scn.cheapest_shape())
    tr = flash_crowd_trace(3.5 * svc.max_throughput, 900.0, dt_s=5.0,
                           n_seeds=3, seed=2)
    obj = Objective(min_attainment=1.0, penalty_usd_per_hour=1e5)
    budget = TuningBudget(n_candidates=6)
    space = PredictivePolicy.param_space()

    def run():
        ts = tuning_scenario(scn, tr, PredictivePolicy, cold_start_s=30.0,
                             backend="numpy")
        return tune(ts, space, obj, budget, seed=0)

    off = run()
    with telemetry.session() as tel:
        on = run()
    assert off.winner.params == on.winner.params
    np.testing.assert_array_equal(off.winner.score, on.winner.score)
    assert off.sims_used == on.sims_used
    # spans land on the report only when a session was active
    assert off.spans is None and off.timing_breakdown() == ""
    assert on.spans is not None and "tune.race" in on.timing_breakdown()
    assert "timing breakdown" in on.summary()
    assert tel.metrics.get("tuning_sims_total", backend="numpy") is not None


# ----------------------- stream determinism + backend equality --------------

def _snapshot_allclose(a: dict, b: dict, atol=1e-8):
    assert set(a["counter"]) == set(b["counter"])
    for name, slots in a["counter"].items():
        assert set(slots) == set(b["counter"][name]), name
        for ls, v in slots.items():
            assert v == pytest.approx(b["counter"][name][ls], abs=atol), \
                f"counter {name}{{{ls}}}"
    assert set(a["series"]) == set(b["series"])
    for name, slots in a["series"].items():
        for ls, vals in slots.items():
            np.testing.assert_allclose(vals, b["series"][name][ls],
                                       atol=atol, rtol=1e-9,
                                       err_msg=f"series {name}{{{ls}}}")
    assert set(a["histogram"]) == set(b["histogram"])
    for name, slots in a["histogram"].items():
        for ls, h in slots.items():
            np.testing.assert_allclose(h["counts"],
                                       b["histogram"][name][ls]["counts"],
                                       atol=atol,
                                       err_msg=f"histogram {name}{{{ls}}}")


def test_streams_deterministic_across_runs():
    snaps = []
    for _ in range(2):
        with telemetry.session() as tel:
            _sim()
        snaps.append(tel.metrics.snapshot())
    assert snaps[0] == snaps[1]


def test_numpy_and_jax_emit_equal_streams():
    pytest.importorskip("jax")
    snaps = {}
    for backend in ("numpy", "jax"):
        with telemetry.session() as tel:
            _sim(backend=backend)
        snaps[backend] = tel.metrics.snapshot()
    # the jax path additionally counts its dispatch/cache metrics; restrict
    # the comparison to the record_sim catalog both backends share
    jax_only = ("jaxsim_dispatch_total", "jaxsim_dispatch_seconds_total",
                "jaxsim_core_cache_total", "fleet_kernel_cache_total")
    for snap in snaps.values():
        for kind in snap:
            for name in [n for n in snap[kind] if n in jax_only]:
                del snap[kind][name]
    _snapshot_allclose(snaps["numpy"], snaps["jax"])


def test_backend_stream_equality_property():
    pytest.importorskip("jax")
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    svc = _service()

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=500),
           rate_mult=st.floats(min_value=1.0, max_value=5.0))
    def prop(seed, rate_mult):
        # fixed (T, C, P) so the compiled program is traced once; rates and
        # seeds are data
        tr = poisson_trace(rate_mult * svc.max_throughput, 600.0, dt_s=5.0,
                           n_seeds=2, seed=seed)
        snaps = {}
        for backend in ("numpy", "jax"):
            with telemetry.session() as tel:
                simulate(tr, svc, QueueProportionalPolicy(), slo_s=2.0,
                         cold_start_s=30.0, backend=backend)
            snaps[backend] = tel.metrics.snapshot()
        for name in ("fleet_service_time_s", "fleet_utilization",
                     "fleet_arrival_rate"):
            np.testing.assert_allclose(
                snaps["numpy"]["series"][name][""],
                snaps["jax"]["series"][name][""],
                atol=1e-8, rtol=1e-9, err_msg=name)
        np.testing.assert_allclose(
            snaps["numpy"]["histogram"]["fleet_sojourn_seconds"]
            ["cls=default"]["counts"],
            snaps["jax"]["histogram"]["fleet_sojourn_seconds"]
            ["cls=default"]["counts"], atol=1e-8)

    prop()


def test_jax_backend_emits_cache_and_dispatch_metrics():
    pytest.importorskip("jax")
    with telemetry.session() as tel:
        _sim(backend="jax")
        _sim(backend="jax")
    snap = tel.metrics.snapshot()
    disp = snap["counter"]["jaxsim_dispatch_total"]
    assert sum(disp.values()) == 2.0
    secs = snap["counter"]["jaxsim_dispatch_seconds_total"]
    assert all(v >= 0.0 for v in secs.values())
    core = snap["counter"]["jaxsim_core_cache_total"]
    assert sum(core.values()) == 2.0
    # the second identical run must reuse the cached jit program
    assert core.get("result=hit", 0.0) >= 1.0


# ----------------------- exporters ------------------------------------------

def test_prometheus_text_format():
    reg = MetricsRegistry()
    record_sim(reg, _sim())
    text = export.prometheus_text(reg)
    assert "# TYPE fleet_served_total counter" in text
    assert "# TYPE fleet_sojourn_seconds histogram" in text
    assert 'fleet_sojourn_seconds_bucket{cls="default",le="+Inf"}' in text
    assert "fleet_sojourn_seconds_count" in text
    assert "# TYPE fleet_utilization gauge" in text  # series: last value
    assert "fleet_utilization_bins" in text
    # every non-comment line is "name{labels} number"
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, val = line.rsplit(" ", 1)
        assert name and (val in ("NaN", "+Inf", "-Inf")
                         or float(val) == float(val))


def test_jsonl_export_round_trips(tmp_path):
    with telemetry.session() as tel:
        with telemetry.span("outer", k=1):
            _sim()
        telemetry.event("marker", note="hello")
    path = tmp_path / "events.jsonl"
    n = tel.export_jsonl(path)
    lines = path.read_text().strip().splitlines()
    assert len(lines) == n > 0
    records = [json.loads(ln) for ln in lines]
    kinds = {r["type"] for r in records}
    assert {"event", "counter", "series", "histogram", "span"} <= kinds
    assert records[0] == {"type": "event", "name": "marker", "note": "hello"}
    spans = [r for r in records if r["type"] == "span"]
    assert any(s["name"] == "outer" and s["attr_k"] == 1 for s in spans)


def test_sparkline_and_dashboard():
    assert export.sparkline([]) == ""
    assert len(export.sparkline(np.arange(200.0), width=40)) == 40
    flat = export.sparkline([5.0, 5.0, 5.0])
    assert len(set(flat)) == 1
    ramp = export.sparkline([0.0, 1.0, 2.0, 3.0])
    assert ramp[0] != ramp[-1]
    with telemetry.session() as tel:
        _sim()
    dash = tel.dashboard(width=40)
    assert "fleet_service_time_s" in dash
    assert "fleet_sim_runs_total" in dash
    assert "fleet_sojourn_seconds" in dash


def test_report_telemetry_dashboard_on_bare_result():
    dash = telemetry_dashboard(_sim(), width=40)
    assert "fleet_utilization" in dash
    assert "policy=queue_prop" in dash or "fleet_sim_runs_total" in dash


# ----------------------- trace-ingest event ---------------------------------

def test_load_trace_csv_emits_event(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("# recorded rates\ntimestamp,rate\n0,10\n60,30\n120,20\n")
    with telemetry.session() as tel:
        tr = load_trace_csv(p, rate_col="rate", dt_s=60.0,
                            mean_rate_per_s=40.0, n_seeds=2)
    assert tr.n_bins == 3
    evs = [e for e in tel.events if e["name"] == "trace_csv_loaded"]
    assert len(evs) == 1
    ev = evs[0]
    assert ev["rows"] == 3
    assert ev["skipped_rows"] == 2          # comment + header
    assert ev["rescale_factor"] == pytest.approx(2.0)   # mean 20 -> 40
    assert ev["mean_rate_per_s"] == pytest.approx(40.0)


# ----------------------- drift probe ----------------------------------------

@pytest.fixture(scope="module")
def drift_setup():
    pytest.importorskip("jax")
    from repro.fleet.telemetry import DriftProbe

    svc = _service()
    fleet = FleetConfig((PoolConfig(svc, cold_start_s=30.0),))

    def run_trace(seed, fl=fleet):
        tr = diurnal_trace(2.0 * svc.max_throughput, 3600.0, dt_s=10.0,
                           n_seeds=6, seed=seed)
        return simulate_fleet(tr, fl, QueueProportionalPolicy(), slo_s=2.0)

    probe = DriftProbe().fit(run_trace(0))
    return probe, fleet, run_trace


def test_drift_probe_quiet_on_fresh_baseline(drift_setup):
    probe, _, run_trace = drift_setup
    rep = probe.check(run_trace(7))
    assert not rep.drifted
    assert rep.alarm_bins < probe.min_alarm_bins
    assert "[ok]" in rep.summary()


def test_drift_probe_flags_degraded_service(drift_setup):
    from repro.fleet.telemetry import degrade_fleet

    probe, fleet, run_trace = drift_setup
    rep = probe.check(run_trace(7, fl=degrade_fleet(fleet, 1.3)))
    assert rep.drifted
    assert rep.first_alarm_bin >= 0
    assert rep.alarm_bins > rep.n_bins // 2     # sustained, not a blip
    assert "[DRIFT]" in rep.summary()
    assert rep.per_signal_alarms["service_time_s"] > 0


def test_drift_probe_emits_telemetry_and_validates(drift_setup):
    from repro.fleet.telemetry import telemetry_matrix

    probe, _, run_trace = drift_setup
    sim = run_trace(11)
    X = telemetry_matrix(sim)
    assert X.shape == (sim.arrivals.shape[1], 3)
    with pytest.raises(ValueError, match="unknown drift signal"):
        telemetry_matrix(sim, signals=("bogus",))
    with telemetry.session() as tel:
        rep = probe.check(X)                    # raw-matrix path
    assert not rep.drifted
    snap = tel.metrics.snapshot()
    assert snap["counter"]["fleet_drift_checks_total"]["verdict=ok"] == 1.0
    assert any(e["name"] == "drift_check" for e in tel.events)


def test_degrade_fleet_identity_and_scaling():
    from repro.fleet.telemetry import degrade_fleet

    svc = _service()
    fleet = FleetConfig((PoolConfig(svc, cold_start_s=30.0),))
    same = degrade_fleet(fleet, 1.0)
    assert same.pools[0].service.t_fixed == svc.t_fixed
    slow = degrade_fleet(fleet, 1.5)
    assert slow.pools[0].service.t_fixed == pytest.approx(1.5 * svc.t_fixed)
    assert slow.pools[0].service.t_per_unit == \
        pytest.approx(1.5 * svc.t_per_unit)
    # original untouched (frozen dataclasses are replaced, not mutated)
    assert fleet.pools[0].service.t_fixed == svc.t_fixed
