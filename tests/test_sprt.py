"""SPRT detector: false-alarm bound + detection latency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.mset import SPRTParams, empirical_false_alarm_rate, sprt


def test_false_alarm_rate_on_clean_noise():
    key = jax.random.PRNGKey(1)
    r = jax.random.normal(key, (20_000, 8))
    alarms, _, _ = sprt(r, jnp.ones(8), SPRTParams(alpha=1e-3, beta=1e-3, m_shift=4.0))
    far = float(empirical_false_alarm_rate(alarms))
    assert far < 5e-3, far


def test_detects_mean_shift_quickly():
    key = jax.random.PRNGKey(2)
    r = jax.random.normal(key, (2000, 4))
    r = r.at[1000:, 2].add(3.0)  # 3-sigma shift on signal 2
    alarms, _, _ = sprt(r, jnp.ones(4), SPRTParams(m_shift=3.0))
    a = np.asarray(alarms)
    post = np.argwhere(a[1000:, 2]).ravel()
    assert len(post) > 0 and post[0] < 50, post[:3]
    # other signals stay mostly quiet
    assert a[:, [0, 1, 3]].mean() < 0.01


def test_detects_negative_shift():
    key = jax.random.PRNGKey(3)
    r = jax.random.normal(key, (1000, 2))
    r = r.at[500:, 0].add(-3.0)
    alarms, _, _ = sprt(r, jnp.ones(2))
    post = np.argwhere(np.asarray(alarms)[500:, 0]).ravel()
    assert len(post) > 0 and post[0] < 50
