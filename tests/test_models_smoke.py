"""Per-architecture smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes + no NaNs — plus cache-consistency and MoE-path checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.distributed import is_box, make_rules
from repro.models import build_model

RULES = make_rules(None)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(KEY, (B, 64, cfg.d_model)).astype(cfg.dtype)
        dec_len = 16
        batch["tokens"] = toks[:, :dec_len]
        batch["targets"] = jnp.roll(toks[:, :dec_len], -1, 1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_values(KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        l, m = model.loss(p, batch, RULES)
        return l

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32))**0 + jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)), f"{arch}: grad norm {gn}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_logit_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_values(KEY)
    batch = _batch(cfg)
    from repro.models.transformer import forward_train
    logits, aux = forward_train(cfg, params, batch, RULES)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["nemotron-4-15b", "chatglm3-6b", "granite-20b",
                                  "olmoe-1b-7b", "mamba2-130m", "jamba-v0.1-52b",
                                  "seamless-m4t-large-v2", "chameleon-34b"])
def test_decode_matches_prefill(arch):
    """Property: decode(prefill(x[:-1]), x[-1]) == prefill(x) at the last token."""
    cfg = get_config(arch, smoke=True).replace(dtype="float32", remat="none",
                                               capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init_values(jax.random.PRNGKey(1))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(KEY, (B, cfg.enc_memory_len, cfg.d_model))

    _, logits_full = model.prefill(params, batch, RULES)
    cache, _ = model.prefill(params, {**batch, "tokens": toks[:, :S - 1]}, RULES)
    specs = model.cache_specs(B, S)

    def pad(c, sp):
        pads = [(0, t - s) for s, t in zip(c.shape, sp.value.shape)]
        return jnp.pad(c, pads)

    cache = jax.tree.map(pad, cache, specs, is_leaf=is_box)
    _, logits_dec = model.decode_step(params, cache, toks[:, S - 1:], S - 1, RULES)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(logits_dec, np.float32),
                               atol=2e-4, rtol=2e-3)


def test_moe_aux_loss_nonzero():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    model = build_model(cfg)
    params = model.init_values(KEY)
    _, metrics = model.loss(params, _batch(cfg), RULES)
    assert float(metrics["moe_aux"]) > 0.5  # ~1.0 for balanced router


def test_param_counts_match_published_sizes():
    expected = {"nemotron-4-15b": 15.6e9, "minitron-4b": 4.2e9, "chatglm3-6b": 6.2e9,
                "granite-20b": 20.3e9, "olmoe-1b-7b": 6.9e9, "chameleon-34b": 34.3e9,
                "mamba2-130m": 0.13e9, "jamba-v0.1-52b": 51.5e9}
    for arch, n in expected.items():
        got = get_config(arch).param_counts()["total"]
        assert abs(got - n) / n < 0.08, f"{arch}: {got/1e9:.2f}B vs {n/1e9:.2f}B"


def test_active_params_moe():
    assert get_config("olmoe-1b-7b").param_counts()["active"] < 1.5e9
    assert get_config("jamba-v0.1-52b").param_counts()["active"] < 13e9
