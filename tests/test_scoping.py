"""ContainerStress engine: cost model, HLO parsing, surfaces, recommender."""
import numpy as np

from repro.core import (CATALOG, CellResult, Constraint, ContainerStress,
                        RooflineTerms, dollar_cost, fit_response_surface,
                        get_shape, grid_to_matrix, mfu, parse_collectives,
                        recommend, render_ascii_surface, roofline)

HLO = """
ENTRY %main {
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8]
  %ar = f32[512,512]{1,0} all-reduce(%x), to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[4,128]{1,0} all-to-all(%z)
  %cp = f32[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[512,512]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO)
    assert st.bytes_by_kind["all-gather"] == 16 * 1024 * 2
    assert st.bytes_by_kind["all-reduce"] == 512 * 512 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 64 * 4
    assert st.bytes_by_kind["all-to-all"] == 4 * 128 * 2
    assert st.bytes_by_kind["collective-permute"] == 32 * 32 * 4
    assert st.total_count == 5
    assert "dot" not in st.bytes_by_kind


def test_roofline_terms():
    t = roofline(flops_global=197e12 * 256, bytes_global=0, coll_bytes_global=0,
                 chips=256)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert t.dominant == "compute"
    t2 = roofline(1e12, 819e9 * 8, 0, chips=8)
    assert abs(t2.t_memory - 1.0) < 1e-9


def test_dollar_cost():
    # 1 s/step x 3600 steps x 256 chips @ $1.20 -> $307.2
    assert abs(dollar_cost(1.0, 3600, 256) - 256 * 1.2) < 1e-6


def test_mfu_bounds():
    assert 0.49 < mfu(197e12 * 0.5, 1.0, 1) < 0.51


def test_response_surface_recovers_power_law():
    rng = np.random.default_rng(0)
    X = rng.uniform(8, 512, size=(60, 2))
    y = 1e-6 * X[:, 0] ** 2 * X[:, 1] * np.exp(rng.normal(0, 0.01, 60))
    surf = fit_response_surface(["m", "n"], X, y)
    assert surf.r2 > 0.99
    pred = surf.predict({"m": 100.0, "n": 50.0})
    assert abs(pred - 1e-6 * 100**2 * 50) / (1e-6 * 100**2 * 50) < 0.1


def test_recommender_picks_cheapest_feasible():
    rows = []
    for name, t in [("v5e-64", 0.5), ("v5e-128", 0.25), ("v5e-256", 0.12)]:
        rows.append(CellResult(params={}, shape_name=name,
                               terms=RooflineTerms(t, t / 2, t / 3),
                               analysis={"peak_memory_per_device": 8e9}))
    rec = recommend(rows, Constraint(max_step_latency_s=0.3))
    assert rec.shape.name == "v5e-128"      # cheapest that meets 0.3 s
    rec2 = recommend(rows, Constraint(max_step_latency_s=0.01))
    assert rec2.shape is None


def test_recommender_memory_constraint():
    rows = [CellResult(params={}, shape_name="v5e-64",
                       terms=RooflineTerms(0.1, 0.1, 0.1),
                       analysis={"peak_memory_per_device": 64e9})]  # > 16 GiB
    rec = recommend(rows, Constraint(max_step_latency_s=10))
    assert rec.shape is None


def test_measured_scoping_and_render():
    import jax.numpy as jnp

    def workload(params):
        n = params["n"]
        x = jnp.ones((n, n))
        import jax
        f = jax.jit(lambda a: (a @ a).sum())
        return lambda: f(x)

    cs = ContainerStress()
    res = cs.run_measured(workload, {"n": [32, 64], "m": [1, 2]}, reps=2)
    assert len(res.rows) == 4
    xs, ys, Z = grid_to_matrix(res.rows, "n", "m")
    txt = render_ascii_surface(xs, ys, Z, "n", "m")
    assert "rows: m" in txt
    names, X, y = res.to_arrays()
    assert X.shape == (4, 2) and (y > 0).all()


def test_catalog_shapes():
    s = get_shape("v5e-256")
    assert s.chips == 256
    assert get_shape("2x-v5e-256").chips == 512
    assert all(c.price_per_hour > 0 for c in CATALOG)
