"""Documents the XLA:CPU quirk the dry-run probes exist for, and checks the
collective-byte parser against a real SPMD lowering."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.hlo_analysis import (analyze_compiled, cost_analysis_dict,
                                     parse_collectives)


def test_xla_cpu_counts_loop_body_once():
    """cost_analysis FLOPs for a scanned loop == ONE body, not trip_count bodies.
    This is why launch/dryrun.py uses unrolled probe compiles for cost extraction
    (see DESIGN.md)."""
    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = lax.scan(body, x, None, length=10)
        return c.sum()

    def unrolled(x, w):
        c = x
        for _ in range(10):
            c = jnp.tanh(c @ w)
        return c.sum()

    f_scan = cost_analysis_dict(jax.jit(scanned).lower(x, w).compile())["flops"]
    f_unroll = cost_analysis_dict(jax.jit(unrolled).lower(x, w).compile())["flops"]
    assert f_unroll > 8 * f_scan, (f_scan, f_unroll)


def test_analyze_compiled_single_device():
    f = jax.jit(lambda a, b: (a @ b).sum())
    a = jnp.ones((64, 64))
    compiled = f.lower(a, a).compile()
    cost = analyze_compiled(compiled, n_devices=1)
    assert cost.flops >= 2 * 64**3 * 0.9
    assert cost.collective_bytes == 0
    assert cost.peak_memory_per_device > 0


def test_parser_ignores_non_collectives():
    st = parse_collectives("%d = f32[8,8]{1,0} dot(%a, %b)\n%r = f32[] reduce(%x)")
    assert st.total_bytes == 0 and st.total_count == 0


def test_parser_handles_tuple_shapes():
    txt = "%ar = (f32[16]{0}, f32[32]{0}) all-reduce(%a, %b), to_apply=%sum"
    st = parse_collectives(txt)
    assert st.bytes_by_kind["all-reduce"] == 16 * 4 + 32 * 4
