"""Portfolio-robust tuning (`repro.fleet.tuning` portfolio axis): robust
reduction invariants, numpy==jax agreement on the robust score, single-trace
identity with the pre-portfolio path, racing/sims accounting on portfolios,
candidate tiling, the persistent compile cache, and SLO-column racing."""
import json
import os

import numpy as np
import pytest

from repro.core import CellResult, RooflineTerms, get_shape
from repro.fleet import (FleetConfig, Objective, OracleGrid, PIPolicy,
                         PoolConfig, StaticPolicy, TuningBudget,
                         TuningScenario, TuningReport, ParamSpace, Integer,
                         evaluate_candidates, exhaustive, flash_crowd_trace,
                         poisson_trace, race, ramp_trace, robust_m,
                         robust_weights, service_model_from_cell, telemetry,
                         tune)
from repro.fleet import jaxsim
from repro.fleet.tuning.evaluate import _reduce_portfolio
from repro.fleet.tuning.racing import race_column

needs_jax = pytest.mark.skipif(not jaxsim.available(),
                               reason="jax not installed")


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch, "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw),
                                   units_per_step=kw.get("batch", 64))


def _fleet(svc, initial=8, cold_start_s=30.0, **kw):
    return FleetConfig((PoolConfig(service=svc, cold_start_s=cold_start_s,
                                   initial_replicas=initial, **kw),))


def _traces(svc, duration=400.0, n_seeds=4):
    """Three demand futures sharing dt/bins/seeds: steady, flash crowd,
    ramp-down — distinct enough that per-trace winners differ."""
    mt = svc.max_throughput
    return [poisson_trace(3.0 * mt, duration, dt_s=5.0, n_seeds=n_seeds,
                          seed=0),
            flash_crowd_trace(2.0 * mt, duration, dt_s=5.0, n_seeds=n_seeds,
                              seed=1, peak_mult=4.0),
            ramp_trace(4.0 * mt, 1.0 * mt, duration, dt_s=5.0,
                       n_seeds=n_seeds, seed=2)]


def _portfolio_scenario(svc=None, robust="worst_case", backend="auto",
                        n_traces=3, **kw):
    svc = svc or _service()
    return TuningScenario(
        name="portfolio", workload=_traces(svc, **kw)[:n_traces],
        fleet=_fleet(svc), policy_cls=StaticPolicy,
        context={"slo_s": 2.0}, robust=robust, backend=backend)


SPACE = ParamSpace((Integer("n_replicas", 1, 16),))


# -------------------------- robust reduction --------------------------------

def test_robust_m_specs():
    assert robust_m("worst_case", 5) == 1
    assert robust_m("mean", 5) == 5
    assert robust_m("cvar(0.4)", 5) == 2
    assert robust_m("cvar(1.0)", 5) == 5
    assert robust_m("cvar(1e-6)", 5) == 1
    for bad in ("median", "cvar(0)", "cvar(1.5)", "cvar(-0.2)", "worstcase"):
        with pytest.raises(ValueError):
            robust_m(bad, 5)


def test_robust_weights_invariants_hypothesis():
    """For any per-trace score matrix: weights are a per-seed probability
    simplex supported on the m worst traces; worst_case reduces to the
    column max; cvar interpolates monotonically between worst_case and mean
    and is bounded by both."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    @settings(max_examples=40, deadline=None)
    @given(hnp.arrays(np.float64, hnp.array_shapes(min_dims=2, max_dims=2,
                                                   min_side=1, max_side=6),
                      elements=st.floats(-1e6, 1e6)),
           st.floats(1e-3, 1.0))
    def check(scores, alpha):
        K = scores.shape[0]
        for spec in ("worst_case", "mean", f"cvar({alpha})"):
            w = robust_weights(scores, spec)
            assert w.shape == scores.shape
            np.testing.assert_allclose(w.sum(axis=0), 1.0)
            assert ((w == 0) | np.isclose(w, 1.0 / robust_m(spec, K))).all()
        red = {spec: (robust_weights(scores, spec) * scores).sum(axis=0)
               for spec in ("worst_case", "mean", f"cvar({alpha})",
                            "cvar(1.0)")}
        np.testing.assert_allclose(red["worst_case"], scores.max(axis=0))
        np.testing.assert_allclose(red["mean"], scores.mean(axis=0))
        np.testing.assert_allclose(red["cvar(1.0)"], red["mean"])
        cv = red[f"cvar({alpha})"]
        assert (cv <= red["worst_case"] + 1e-9).all()
        assert (cv >= red["mean"] - 1e-6 * np.abs(red["mean"]) - 1e-9).all()

    check()


def test_cvar_monotone_in_alpha():
    rng = np.random.default_rng(7)
    scores = rng.normal(size=(6, 5)) * 100
    alphas = np.linspace(0.05, 1.0, 12)
    reds = [(robust_weights(scores, f"cvar({a})") * scores).sum(axis=0)
            for a in alphas]
    for hi, lo in zip(reds, reds[1:]):   # averaging over more traces can
        assert (lo <= hi + 1e-9).all()   # only soften the tail


def test_reduced_score_permutation_invariant():
    """The robust *score* never depends on trace order (stable tie-break
    changes which trace's cost rides along, never the score)."""
    rng = np.random.default_rng(3)

    def ev(seed):
        r = np.random.default_rng(seed)
        return _fake_eval(r.uniform(1, 9, 5), r.uniform(0.8, 1.0, 5))

    per = [ev(i) for i in range(4)]
    for spec in ("worst_case", "mean", "cvar(0.5)"):
        base = _reduce_portfolio(per, spec).score
        for _ in range(5):
            perm = rng.permutation(4)
            got = _reduce_portfolio([per[i] for i in perm], spec).score
            if spec == "worst_case":     # m=1: the worst row verbatim
                np.testing.assert_array_equal(got, base)
            else:                        # m>1 sums m rows: order-of-addition
                np.testing.assert_allclose(got, base, rtol=1e-12)


def _fake_eval(cost, att, objective=Objective()):
    from repro.fleet.tuning.evaluate import CandidateEval
    cost, att = np.asarray(cost, float), np.asarray(att, float)
    return CandidateEval(params={"n_replicas": 3}, cost_usd_hr=cost,
                         attainment=att, drop_rate=np.zeros_like(cost),
                         score=np.asarray(objective.score(cost, att)),
                         sojourns=[])


def test_worst_case_reduction_picks_worst_trace_rows():
    a = _fake_eval([1.0, 9.0], [1.0, 1.0])
    b = _fake_eval([5.0, 2.0], [1.0, 1.0])
    red = _reduce_portfolio([a, b], "worst_case")
    np.testing.assert_array_equal(red.score, [5.0, 9.0])
    np.testing.assert_array_equal(red.cost_usd_hr, [5.0, 9.0])
    assert red.worst_trace_score() == max(a.mean_score(), b.mean_score())
    assert red.per_trace[0] is a and red.per_trace[1] is b


# ----------------------- scenario construction ------------------------------

def test_portfolio_member_validation():
    svc = _service()
    t1 = poisson_trace(100.0, 400.0, dt_s=5.0, n_seeds=4, seed=0)
    bad_seeds = poisson_trace(100.0, 400.0, dt_s=5.0, n_seeds=8, seed=1)
    bad_dt = poisson_trace(100.0, 400.0, dt_s=10.0, n_seeds=4, seed=1)
    kw = dict(name="p", fleet=_fleet(svc), policy_cls=StaticPolicy)
    with pytest.raises(ValueError, match="seeds"):
        TuningScenario(workload=[t1, bad_seeds], context={"slo_s": 2.0}, **kw)
    with pytest.raises(ValueError, match="match the primary"):
        TuningScenario(workload=[t1, bad_dt], context={"slo_s": 2.0}, **kw)
    with pytest.raises(ValueError, match="slo_s"):
        TuningScenario(workload=[t1], context={}, **kw)
    with pytest.raises(ValueError, match="empty"):
        TuningScenario(workload=[], context={"slo_s": 2.0}, **kw)
    with pytest.raises(ValueError, match="robust"):
        TuningScenario(workload=[t1], context={"slo_s": 2.0},
                       robust="median", **kw)


def test_single_trace_portfolio_identical_to_plain():
    """A one-member portfolio is byte-identical to passing the trace
    directly — same winner, same per-seed evidence, same report numbers."""
    svc = _service()
    tr = _traces(svc)[0]
    kw = dict(fleet=_fleet(svc), policy_cls=StaticPolicy,
              context={"slo_s": 2.0})
    plain = tune(TuningScenario(name="s", workload=tr, **kw), SPACE, seed=0)
    port = tune(TuningScenario(name="s", workload=[tr], **kw), SPACE, seed=0)
    assert plain.winner.params == port.winner.params
    np.testing.assert_array_equal(plain.winner.score, port.winner.score)
    np.testing.assert_array_equal(plain.winner.cost_usd_hr,
                                  port.winner.cost_usd_hr)
    assert plain.sims_used == port.sims_used
    assert plain.full_budget == port.full_budget
    assert port.n_traces == 1 and port.robust is None
    assert port.winner.per_trace is None


# ------------------------- backend agreement --------------------------------

@needs_jax
@pytest.mark.parametrize("robust", ["worst_case", "cvar(0.67)", "mean"])
def test_numpy_jax_robust_score_exact(robust):
    """The compiled portfolio dispatch and the numpy per-member loop agree
    on the robust score to the last bit (same host-side reduction on
    bit-identical dynamics), hence on the winner."""
    svc = _service()
    cands = [{"n_replicas": n} for n in (2, 5, 9, 14)]
    evs = {}
    for backend in ("numpy", "jax"):
        sc = _portfolio_scenario(svc, robust=robust, backend=backend)
        evs[backend] = evaluate_candidates(sc, cands, Objective())
    for a, b in zip(evs["numpy"], evs["jax"]):
        np.testing.assert_array_equal(a.score, b.score)
        np.testing.assert_array_equal(a.attainment, b.attainment)
        for ta, tb in zip(a.per_trace, b.per_trace):
            np.testing.assert_array_equal(ta.score, tb.score)
    pick = {k: min(v, key=lambda e: e.mean_score()).params
            for k, v in evs.items()}
    assert pick["numpy"] == pick["jax"]


# --------------------------- racing on portfolios ----------------------------

def test_portfolio_known_optimum_never_culled():
    """Racing a portfolio must return the exhaustive robust winner (the
    paired SPRT operates on the reduced score, so the known optimum under
    the robust objective survives every cull)."""
    sc = _portfolio_scenario()
    cands = SPACE.grid(16)
    ex = exhaustive(sc, cands, Objective())
    for init_seeds in (1, 2):
        rr = race(sc, cands, Objective(), init_seeds=init_seeds)
        assert rr.winner.params == ex.winner.params
        assert rr.sims_used <= ex.sims_used


def test_portfolio_sims_accounting():
    """sims_used / full_budget count candidate x seed x TRACE trajectories:
    one replicate of a K-trace portfolio costs K sims whichever backend
    dispatches it."""
    sc = _portfolio_scenario(n_traces=3)
    cands = SPACE.sample_lhs(6, seed=1)
    ex = exhaustive(sc, cands, Objective())
    assert ex.sims_used == ex.full_budget == 6 * sc.n_seeds * 3
    rr = race(sc, cands, Objective())
    assert rr.full_budget == 6 * sc.n_seeds * 3
    assert rr.sims_used % 3 == 0
    assert rr.sims_used < ex.sims_used
    rep = tune(sc, SPACE, seed=0)
    assert rep.n_traces == 3 and rep.robust == "worst_case"
    assert rep.full_budget == len(SPACE.sample_lhs(24, seed=0)) \
        * sc.n_seeds * 3
    assert "portfolio: 3 traces" in rep.summary()


def test_portfolio_report_roundtrip():
    rep = tune(_portfolio_scenario(), SPACE,
               budget=TuningBudget(n_candidates=5), seed=2)
    back = TuningReport.from_json(rep.to_json())
    assert back.n_traces == rep.n_traces and back.robust == rep.robust
    assert len(back.winner.per_trace) == 3
    np.testing.assert_array_equal(back.winner.score, rep.winner.score)
    np.testing.assert_array_equal(back.winner.per_trace[1].score,
                                  rep.winner.per_trace[1].score)
    assert back.winner.worst_trace_score() == rep.winner.worst_trace_score()


# ----------------------------- candidate tiling ------------------------------

@needs_jax
def test_tiled_dispatch_bit_exact_and_warm_after_first():
    """A slate wider than the tile streams through fixed-shape chunks: every
    tile after the first reuses the compiled program (warm), the padded tail
    included, and the results are bit-identical to one wide dispatch."""
    svc = _service()
    tr = poisson_trace(3.0 * svc.max_throughput, 300.0, dt_s=5.0, n_seeds=3,
                       seed=0)
    kw = dict(name="t", workload=tr, fleet=_fleet(svc),
              policy_cls=StaticPolicy, context={"slo_s": 2.0}, backend="jax")
    cands = [{"n_replicas": 1 + (i % 16)} for i in range(40)]
    jaxsim.clear_compiled()
    with telemetry.session() as tel:
        tiled = evaluate_candidates(TuningScenario(tile=16, **kw), cands,
                                    Objective())
    spans = [s for s in _walk_spans(tel.tracer.roots)
             if s.name == "jaxsim.dispatch"]
    assert len(spans) == 3                       # ceil(40 / 16) tiles
    assert [s.attrs["kind"] for s in spans] == ["cold", "warm", "warm"]
    assert all(s.attrs["padded"] == 16 for s in spans)
    assert [s.attrs["tile"] for s in spans] == [0, 1, 2]
    assert spans[-1].attrs["candidates"] == 8    # tail padded to the tile
    flat = evaluate_candidates(TuningScenario(tile=None, **kw), cands,
                               Objective())
    for a, b in zip(tiled, flat):
        np.testing.assert_array_equal(a.score, b.score)


def _walk_spans(spans):
    for s in spans:
        yield s
        yield from _walk_spans(s.children)


@needs_jax
def test_telemetry_off_is_bit_exact():
    sc = _portfolio_scenario(backend="jax")
    cands = [{"n_replicas": 4}, {"n_replicas": 11}]
    off = evaluate_candidates(sc, cands, Objective())
    with telemetry.session():
        on = evaluate_candidates(sc, cands, Objective())
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a.score, b.score)


# ------------------------ persistent compile cache ---------------------------

@needs_jax
def test_persistent_compile_cache_disk_hits(tmp_path):
    """With an on-disk compile cache, re-tracing after an in-memory flush
    loads the compiled program from disk (counter-verified hits) and the
    results stay bit-identical."""
    cache = tmp_path / "jaxcache"
    jaxsim.enable_persistent_compile_cache(str(cache))
    try:
        svc = _service(t_comp=0.37)  # fresh shape -> fresh compiled core
        tr = poisson_trace(3.0 * svc.max_throughput, 300.0, dt_s=5.0,
                           n_seeds=3, seed=0)
        sc = TuningScenario(name="c", workload=tr, fleet=_fleet(svc),
                            policy_cls=StaticPolicy, context={"slo_s": 2.0},
                            backend="jax")
        cands = [{"n_replicas": 5}]
        before = jaxsim.persistent_cache_stats()
        cold = evaluate_candidates(sc, cands, Objective())
        mid = jaxsim.persistent_cache_stats()
        assert mid["misses"] > before["misses"]  # compiled + written to disk
        assert any(cache.rglob("*"))
        evicted = jaxsim.clear_compiled()        # keep cores alive: a fresh
        assert evicted                           # core must not reuse an id()
        with telemetry.session() as tel:
            warm = evaluate_candidates(sc, cands, Objective())
        after = jaxsim.persistent_cache_stats()
        assert after["hits"] > mid["hits"]
        snap = tel.metrics.snapshot()["counter"]
        assert snap["jaxsim_compile_cache_disk_total"]["result=hit"] >= 1
        for a, b in zip(cold, warm):
            np.testing.assert_array_equal(a.score, b.score)
    finally:
        # cache config is process-global: later tests in this pytest process
        # must not keep serializing every jit through the reaped tmp dir
        jaxsim.disable_persistent_compile_cache()
        jaxsim.clear_compiled()


# ----------------------------- SLO-column racing -----------------------------

@needs_jax
def test_race_column_matches_per_tier_race():
    """One shared-dispatch column race returns, per SLO tier, exactly the
    winner/evidence/spend a standalone per-tier race produces, while the
    physical trajectory count covers the column once, not once per tier."""
    svc = _service()
    tr = poisson_trace(3.0 * svc.max_throughput, 400.0, dt_s=5.0, n_seeds=4,
                       seed=0)
    slos = (1.0, 2.5, 6.0)
    cands = PIPolicy.param_space().sample_lhs(6, seed=3)

    def scen(slo):
        from repro.fleet.workload import Workload
        return TuningScenario(name=f"tier{slo}",
                              workload=Workload.from_trace(tr, slo),
                              fleet=_fleet(svc, max_replicas=24),
                              policy_cls=PIPolicy, context={"slo_s": slo},
                              backend="jax")

    got = race_column(scen(slos[0]), cands, Objective(), slos)
    assert got is not None
    results, sims_shared = got
    per_tier_total = 0
    for slo, rr in zip(slos, results):
        solo = race(scen(slo), cands, Objective())
        assert rr.winner.params == solo.winner.params
        np.testing.assert_array_equal(rr.winner.score, solo.winner.score)
        assert rr.sims_used == solo.sims_used
        assert rr.full_budget == solo.full_budget
        assert rr.culled_at_round == solo.culled_at_round
        per_tier_total += rr.sims_used
    assert sims_shared <= per_tier_total
    assert sims_shared >= max(r.sims_used for r in results)


@needs_jax
def test_race_column_declines_multiclass():
    """Multi-class tiers have SLO-dependent dynamics (EDF keys, hetero
    critical demand); the column path must refuse rather than share."""
    from repro.fleet.scenarios import tiered_sla_workload
    svc = _service()
    wl = tiered_sla_workload(3.0 * svc.max_throughput, 400.0, dt_s=5.0,
                             n_seeds=2)
    sc = TuningScenario(name="m", workload=wl, fleet=_fleet(svc),
                        policy_cls=PIPolicy, context={"slo_s": 1.0},
                        backend="jax")
    assert race_column(sc, PIPolicy.param_space().sample_lhs(3, seed=0),
                       Objective(), (1.0, 2.0)) is None


@needs_jax
def test_oracle_column_batch_matches_per_cell():
    """build_oracle's shared-column path: identical winners, scores and
    frontiers to the per-cell sweep, at a fraction of the physical sims."""
    from repro.fleet.oracle import build_oracle
    svc = _service()
    fleet = _fleet(svc, max_replicas=24)
    mt = svc.max_throughput
    grid = OracleGrid(mean_rates=(3.0 * mt,), burstiness=(1.4,),
                      slos=(1.0, 3.0), duration_s=400.0, dt_s=5.0,
                      n_seeds=2, seed=3)
    kw = dict(objective=Objective(min_attainment=0.9),
              budget=TuningBudget(n_candidates=4, init_seeds=1),
              backend="jax")
    t_col = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(), **kw)
    t_cell = build_oracle(grid, fleet, PIPolicy, PIPolicy.param_space(),
                          column_batch=False, **kw)
    for k in t_cell.cells:
        assert t_col.cells[k].winner == t_cell.cells[k].winner
        assert t_col.cells[k].score == t_cell.cells[k].score
        assert t_col.cells[k].frontier == t_cell.cells[k].frontier
    assert t_col.build_info["sims_used"] < t_cell.build_info["sims_used"]


# --------------------------------- CI gate ----------------------------------

def _load_check_bench():
    import importlib.util
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench_portfolio",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _green_portfolio():
    tiles = [{"kind": "warm", "tile": i, "padded": 128, "candidates": 128}
             for i in range(4)]
    cold = [dict(t) for t in tiles]
    cold[0]["kind"] = "cold"
    return {
        "benchmark": "portfolio_tuning",
        "headline": {
            "n_candidates": 512, "n_traces": 4, "n_seeds": 4,
            "tile": 128, "n_tiles": 4, "jax_warm_s": 4.7, "speedup": 22.9,
            "cold_round_dispatches": cold, "warm_round_dispatches": tiles,
            "subset_max_score_delta": 0.0,
        },
        "robustness": {
            "portfolio_winner": {"worst_trace_score": 1067.0,
                                 "worst_trace_attainment": 0.89},
            "single_trace_winners": [
                {"tuned_on": "flash", "worst_trace_score": 1337.0},
                {"tuned_on": "ramp", "worst_trace_score": 4807.0},
            ],
            "portfolio_dominates": True,
        },
        "agreement": {"max_robust_score_delta": 0.0, "same_winner": True},
        "compile_cache": {
            "cold_build": {"cold_dispatch_s": 1.3, "disk_misses": 2,
                           "disk_hits": 0},
            "warm_build": {"cold_dispatch_s": 0.5, "disk_misses": 0,
                           "disk_hits": 2},
            "max_score_delta": 0.0,
        },
    }


def test_compare_portfolio_green():
    cb = _load_check_bench()
    assert cb.compare_portfolio(_green_portfolio(), _green_portfolio(),
                                0.02, 0.08, 2.0) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d["headline"].__setitem__("speedup", 1.2), "bar 5.0x"),
    (lambda d: d["headline"]["warm_round_dispatches"][1].__setitem__(
        "kind", "cold"), "warm dispatch per tile"),
    (lambda d: d["headline"].__setitem__(
        "warm_round_dispatches",
        d["headline"]["warm_round_dispatches"] * 4), "warm dispatch per tile"),
    (lambda d: d["headline"]["cold_round_dispatches"][0].__setitem__(
        "kind", "warm"), "compile once"),
    (lambda d: d["headline"].__setitem__("subset_max_score_delta", 1e-9),
     "subset"),
    (lambda d: d["robustness"].__setitem__("portfolio_dominates", False),
     "robustness headline"),
    (lambda d: d["robustness"]["portfolio_winner"].__setitem__(
        "worst_trace_score", 5000.0), "rose"),
    (lambda d: d["agreement"].__setitem__("max_robust_score_delta", 1e-12),
     "disagree"),
    (lambda d: d["agreement"].__setitem__("same_winner", False), "winner"),
    (lambda d: d["compile_cache"]["warm_build"].__setitem__("disk_hits", 0),
     "disk hits"),
    (lambda d: d["compile_cache"]["cold_build"].__setitem__("disk_misses", 0),
     "not wired"),
    (lambda d: d["compile_cache"]["warm_build"].__setitem__(
        "cold_dispatch_s", 2.0), "not faster"),
    (lambda d: d["compile_cache"].__setitem__("max_score_delta", 1e-9),
     "deserialized"),
    (lambda d: d.__setitem__("error", "no jax"), "did not run"),
])
def test_compare_portfolio_red(mutate, needle):
    cb = _load_check_bench()
    fresh = _green_portfolio()
    mutate(fresh)
    problems = cb.compare_portfolio(fresh, _green_portfolio(), 0.02, 0.08,
                                    2.0)
    assert problems, f"expected a problem mentioning {needle!r}"
    assert any(needle.lower() in p.lower() for p in problems), problems


def test_compare_tuner_joint_optimum_red():
    """compare_tuner flags a missing/broken joint_optimum section."""
    cb = _load_check_bench()
    base = {"headline": {}}
    green = {
        "headline": {"tuned": {"usd_per_hour": 25.0,
                               "worst_class_attainment": 1.0},
                     "default": {"usd_per_hour": 29.0,
                                 "worst_class_attainment": 1.0},
                     "tuned_dominates_default": True},
        "surface_r2": 0.85,
        "budget": {"frac": 0.2},
        "race_vs_exhaustive": {"same_winner": True, "race_frac": 0.27},
        "joint_optimum": {
            "greedy": {"params": {"discipline": "fifo", "n_replicas": 11},
                       "score": 52.8},
            "joint": {"params": {"discipline": "priority", "n_replicas": 8},
                      "score": 38.4},
        },
    }
    assert cb.compare_tuner(dict(green), base, 0.02, 0.08, 2.0) == []
    broken = json.loads(json.dumps(green))
    del broken["joint_optimum"]
    assert any("joint_optimum" in p
               for p in cb.compare_tuner(broken, base, 0.02, 0.08, 2.0))
    tied = json.loads(json.dumps(green))
    tied["joint_optimum"]["joint"] = dict(
        tied["joint_optimum"]["greedy"])
    problems = cb.compare_tuner(tied, base, 0.02, 0.08, 2.0)
    assert any("greedy" in p for p in problems)
