"""Hypothesis property tests for the scheduling disciplines.

(a) conservation/causality/work-conservation under every discipline,
(b) EDF feasibility dominance over FIFO (EDF optimality),
(c) single-/identical-class degeneracy to FIFO,
(d) strict priority never hurts the top-priority class vs FIFO.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.fleet import (DISCIPLINES, RequestClass, multiclass_cohort_metrics,
                         split_service)

DT = 1.0


@st.composite
def _case(draw, max_T=12, max_C=3, max_arr=4, max_cap=8):
    T = draw(st.integers(2, max_T))
    C = draw(st.integers(1, max_C))
    S = 2
    adm = draw(st.lists(st.integers(0, max_arr), min_size=S * T * C,
                        max_size=S * T * C))
    cap = draw(st.lists(st.integers(0, max_cap), min_size=S * T,
                        max_size=S * T))
    slos = draw(st.lists(st.sampled_from([1.0, 2.0, 3.5, 8.0]), min_size=C,
                         max_size=C))
    prios = draw(st.permutations(list(range(C))))
    classes = tuple(RequestClass(f"c{i}", slos[i], priority=prios[i])
                    for i in range(C))
    return (np.array(adm, float).reshape(S, T, C),
            np.array(cap, float).reshape(S, T), classes)


@settings(max_examples=60, deadline=None)
@given(_case(), st.sampled_from(sorted(DISCIPLINES)))
def test_property_conservation_and_causality(case, disc):
    adm, cap, classes = case
    S, T, C = adm.shape
    served = split_service(disc, classes, adm, cap, np.arange(T), DT)
    assert (served >= -1e-9).all()
    # conservation: total served per class never exceeds admitted, and the
    # leftover backlog is exactly admitted - served
    tot_served = served.sum(axis=1)
    tot_adm = adm.sum(axis=1)
    assert (tot_served <= tot_adm + 1e-9).all()
    # causality: cumulative served by slot k <= cumulative admitted by bin k
    cum_s = np.cumsum(served, axis=1)
    cum_a = np.cumsum(adm, axis=1)
    assert (cum_s <= cum_a + 1e-9).all()
    # work conservation: each slot serves min(capacity, backlog before it)
    tot_s = served.sum(axis=2)
    prev = np.concatenate([np.zeros((S, 1)),
                           np.cumsum(tot_s, axis=1)[:, :-1]], axis=1)
    backlog = cum_a.sum(axis=2) - prev
    np.testing.assert_allclose(tot_s, np.minimum(cap, backlog), atol=1e-9)


def _misses(disc, classes, adm, cap, T):
    """Deadline misses = requests served past their SLO + never served.
    Service itself is instantaneous (bt ~ 0): the property is about
    *queueing* misses, which the discipline controls."""
    served = split_service(disc, classes, adm, cap, np.arange(T), DT)
    bt = np.full(cap.shape, 1e-9)
    cms = multiclass_cohort_metrics(adm, served, np.arange(T), bt, DT,
                                    [c.slo_s for c in classes])
    late = sum(float((served[:, :, c] - cm.ok_served).sum())
               for c, cm in enumerate(cms))
    unserved = float(adm.sum() - served.sum())
    return late + unserved


@settings(max_examples=60, deadline=None,
          suppress_health_check=[hypothesis.HealthCheck.filter_too_much,
                                 hypothesis.HealthCheck.too_slow])
@given(_case(max_C=2, max_T=8, max_arr=2, max_cap=10))
def test_property_edf_feasibility_dominance(case):
    """If FIFO schedules a trace with zero deadline misses, EDF does too
    (EDF optimality). The converse is false — that asymmetry is the whole
    point of the discipline. Generation is biased toward ample capacity so
    FIFO-feasible traces are common enough to sample."""
    adm, cap, classes = case
    T = adm.shape[1]
    hypothesis.assume(_misses("fifo", classes, adm, cap, T) < 1e-6)
    assert _misses("edf", classes, adm, cap, T) < 1e-6


@settings(max_examples=60, deadline=None)
@given(_case())
def test_property_single_and_identical_class_degenerate_to_fifo(case):
    adm, cap, classes = case
    S, T, C = adm.shape
    # identical SLOs and priorities: every discipline must split identically
    same = tuple(RequestClass(c.name, 2.0, priority=0) for c in classes)
    ref = split_service("fifo", same, adm, cap, np.arange(T), DT)
    for d in ("priority", "edf"):
        np.testing.assert_allclose(
            split_service(d, same, adm, cap, np.arange(T), DT), ref,
            atol=1e-9)


@settings(max_examples=60, deadline=None)
@given(_case(max_C=3))
def test_property_top_priority_class_never_worse_under_priority(case):
    """Strict priority dominates FIFO for the most critical class: its
    cumulative served curve (and hence every request's sojourn) can only
    improve when it always goes first."""
    adm, cap, classes = case
    T = adm.shape[1]
    top = int(np.argmin([c.priority for c in classes]))
    fifo = split_service("fifo", classes, adm, cap, np.arange(T), DT)
    prio = split_service("priority", classes, adm, cap, np.arange(T), DT)
    assert (np.cumsum(prio[:, :, top], axis=1)
            >= np.cumsum(fifo[:, :, top], axis=1) - 1e-9).all()
