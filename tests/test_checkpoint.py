"""Checkpointer: atomic save/restore, keep-N GC, async, corruption fallback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.optim import adamw


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step_count": jnp.array(7)}


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step, extra = ck.restore(like)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_latest_and_keep_n(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4, 5]:
        ck.save(s, tree)
    assert ck.all_steps() == [4, 5]
    assert ck.latest_step() == 5


def test_async_save(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(9, tree, extra={"loss": 1.25})
    ck.wait()
    _, step, extra = ck.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 9 and extra["loss"] == 1.25


def test_corrupted_checkpoint_falls_back(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, tree)
    ck.save(2, tree)
    # corrupt the newest
    leaf = os.path.join(str(tmp_path), "step_0000000002", "leaf_00000.npy")
    with open(leaf, "wb") as f:
        f.write(b"garbage")
    restored, step, _ = ck.restore_latest_valid(jax.tree.map(jnp.zeros_like, tree))
    assert step == 1


def test_optimizer_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((4, 4))}
    st = adamw.init(params)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, (params, st))
    like = (jax.tree.map(jnp.zeros_like, params), adamw.init(params))
    (p2, st2), step, _ = ck.restore(like)
    assert step == 3
    assert int(st2.step) == 0
    np.testing.assert_array_equal(np.asarray(p2["w"]), 1.0)


def test_interrupted_write_is_invisible(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    # simulate a crash mid-write: leave a .tmp dir behind
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert ck.latest_step() == 1
    ck.save(3, tree)
    assert ck.latest_step() == 3
