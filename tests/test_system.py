"""End-to-end behaviour tests: training convergence, fault-tolerant restart,
straggler detection, serving, and the full paper workflow (TPSS -> MSET2 ->
SPRT -> scoping -> recommendation)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault import FaultInjector, StepWatchdog
from repro.launch.train import TrainJob, train


def test_training_loss_decreases(tmp_path):
    job = TrainJob(arch="mamba2-130m", steps=30, seq_len=128, global_batch=4,
                   ckpt_dir=str(tmp_path), log_every=100)
    m = train(job, verbose=False)
    assert m["final_loss"] < m["first_loss"] - 0.5, m
    assert m["restarts"] == 0


def test_training_recovers_from_nan(tmp_path):
    inj = FaultInjector(nan_steps={12})
    job = TrainJob(arch="mamba2-130m", steps=25, seq_len=64, global_batch=4,
                   ckpt_dir=str(tmp_path), ckpt_every=5, injector=inj,
                   log_every=100)
    m = train(job, verbose=False)
    assert m["restarts"] == 1
    assert m["steps"] >= 25
    assert np.isfinite(m["final_loss"])


def test_training_resumes_from_checkpoint(tmp_path):
    job1 = TrainJob(arch="mamba2-130m", steps=10, seq_len=64, global_batch=4,
                    ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    train(job1, verbose=False)
    job2 = TrainJob(arch="mamba2-130m", steps=20, seq_len=64, global_batch=4,
                    ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    m = train(job2, verbose=False)
    first_resumed_step = job2.history[0]["step"]
    assert first_resumed_step >= 10          # did not restart from scratch
    assert m["final_loss"] < 7.0


def test_straggler_watchdog_flags_slow_steps(tmp_path):
    inj = FaultInjector(slow_steps={15}, slow_s=0.5)
    job = TrainJob(arch="mamba2-130m", steps=20, seq_len=64, global_batch=4,
                   ckpt_dir=str(tmp_path), injector=inj, log_every=100)
    m = train(job, verbose=False)
    assert m["straggler_events"] >= 1


def test_watchdog_unit():
    wd = StepWatchdog(threshold=3.0, warmup_steps=2)
    for i in range(10):
        assert not wd.observe(i, 0.1)
    assert wd.observe(10, 1.0)               # 10x the EWMA
    assert not wd.observe(11, 0.1)           # baseline not poisoned


def test_serving_generates_tokens():
    from repro.launch.serve import generate
    r = generate("minitron-4b", smoke=True, batch=2, prompt_len=16, gen_tokens=6)
    assert r.tokens.shape == (2, 6)
    assert r.tokens_per_s > 0


def test_paper_workflow_end_to_end():
    """TPSS synth -> MSET2 train/surveil -> SPRT alarm -> measured scoping ->
    surface fit -> shape recommendation (the whole Figure-1 loop)."""
    from repro.core import (CellResult, Constraint, ContainerStress,
                            RooflineTerms, fit_response_surface, recommend)
    from repro.mset import SPRTParams, estimate, sprt, train as mset_train
    from repro.tpss import TPSSParams, inject_anomaly, synthesize

    key = jax.random.PRNGKey(0)
    X = synthesize(key, TPSSParams(n_signals=12, n_obs=2048))
    model = mset_train(X[:1536], n_memvec=96)
    _, res_clean = estimate(model, X[1536:])
    sigma = jnp.std(res_clean, 0)
    mu = jnp.mean(res_clean, 0)

    Xa = inject_anomaly(X[1536:], start=100, signal=5, drift_per_step=0.05)
    _, res_a = estimate(model, Xa)
    alarms, _, _ = sprt(res_a, sigma, SPRTParams(alpha=1e-4, beta=1e-4, m_shift=4.0),
                        mu=mu)
    post = np.argwhere(np.asarray(alarms)[100:, 5]).ravel()
    assert len(post) > 0 and post[0] < 200

    # measured scoping over a small grid + recommendation
    def workload(params):
        Xg = synthesize(jax.random.PRNGKey(1), TPSSParams(
            n_signals=params["n_signals"], n_obs=512))
        def run():
            m = mset_train(Xg[:384], n_memvec=params["n_memvec"])
            return estimate(m, Xg[384:])[1]
        return run

    cs = ContainerStress()
    res = cs.run_measured(workload, {"n_signals": [8, 16], "n_memvec": [32, 64]},
                          reps=1)
    names, Xs, y = res.to_arrays()
    surf = fit_response_surface(names, Xs, y, degree=1)
    assert surf.predict({"n_signals": 12, "n_memvec": 48}) > 0

    rows = [CellResult(params={}, shape_name="v5e-64",
                       terms=RooflineTerms(0.01, 0.02, 0.005),
                       analysis={"peak_memory_per_device": 1e9})]
    rec = recommend(rows, Constraint(max_step_latency_s=0.1))
    assert rec.shape is not None
