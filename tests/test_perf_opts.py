"""Correctness of the §Perf optimization paths (they change numerics paths, so
they get their own equivalence tests)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import make_rules
from repro.models import build_model
from repro.models.layers import _sdpa

RULES = make_rules(None)
KEY = jax.random.PRNGKey(0)


def test_bucketed_block_causal_matches_full():
    cfg0 = get_config("minitron-4b", smoke=True)
    B, S, H, K, hd = 2, 128, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd))
    ref = _sdpa(cfg0, q, k, v, causal=True, q_chunk=16)
    for unroll in (False, True):
        cfg = cfg0.replace(causal_block_skip=True, unroll=unroll)
        out = _sdpa(cfg, q, k, v, causal=True, q_chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6, rtol=2e-6)


def test_bucketed_skip_nondivisible_chunks():
    cfg = get_config("minitron-4b", smoke=True).replace(causal_block_skip=True)
    B, S, H, K, hd = 1, 96, 2, 2, 16   # 6 chunks of 16 -> nb falls back to 6
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd))
    ref = _sdpa(get_config("minitron-4b", smoke=True), q, k, v, causal=True,
                q_chunk=16)
    out = _sdpa(cfg, q, k, v, causal=True, q_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_seq_layout_decode_matches_heads_layout():
    cfg = get_config("nemotron-4-15b", smoke=True)
    B, Skv, H, K, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(KEY, (B, 1, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, K, Skv, hd))   # (B,K,S,hd)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, K, Skv, hd))
    out_seq = _sdpa(cfg, q, kc, vc, causal=False, kv_valid_len=40, layout="seq")
    # heads layout expects (B, S, K, hd)
    out_heads = _sdpa(cfg, q, kc.swapaxes(1, 2), vc.swapaxes(1, 2), causal=False,
                      kv_valid_len=40, layout="heads")
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(out_heads),
                               atol=2e-6, rtol=2e-6)


def test_bf16_loss_close_to_f32_loss():
    cfg = get_config("minitron-4b", smoke=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init_values(KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    l32, _ = model.loss(params, batch, RULES)
    cfg16 = cfg.replace(softmax_dtype="bfloat16")
    m16 = build_model(cfg16)
    l16, _ = m16.loss(params, batch, RULES)
    assert abs(float(l32) - float(l16)) < 0.05 * float(l32)


def test_bf16_loss_gradients_finite():
    cfg = get_config("minitron-4b", smoke=True).replace(softmax_dtype="bfloat16")
    model = build_model(cfg)
    params = model.init_values(KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    g = jax.grad(lambda p: model.loss(p, batch, RULES)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_elasticity_plan():
    from repro.core import Constraint
    from repro.core.catalog import CATALOG
    from repro.core.recommender import elasticity_plan
    import numpy as np

    # synthetic per-shape surfaces: t = C * n_signals / chips
    surfaces = {}
    for s in CATALOG:
        X = np.array([[8.0], [64.0], [512.0]])
        y = 1e-3 * X[:, 0] / s.chips
        from repro.core.surfaces import fit_response_surface
        surfaces[s.name] = fit_response_surface(["n_signals"], X, y, degree=1)
    plan = elasticity_plan(surfaces, CATALOG, "n_signals",
                           [8, 128, 2048, 32768], {},
                           Constraint(max_step_latency_s=5e-3))
    feasible = [p[1] for p in plan if p[1] is not None]
    chips = [[s.chips for s in CATALOG if s.name == n][0] for n in feasible]
    assert chips == sorted(chips), f"growth plan must be monotone: {plan}"
    assert chips[0] <= 8 and chips[-1] >= 32
    # infeasible values (beyond the catalog) may only appear at the tail
    none_idx = [i for i, p in enumerate(plan) if p[1] is None]
    assert none_idx == list(range(len(plan) - len(none_idx), len(plan)))
