"""Closed-loop control subsystem: segmented checkpoint-resume simulation,
feedback (PI/PID, fit-to-usage) policy families, warm-started re-tuning,
window metrics, the drift-triggered ClosedLoopController, and the CI gate
for the closed-loop benchmark."""
import importlib.util
import os

import numpy as np
import pytest

from repro.core import CellResult, RooflineTerms, get_shape
from repro.fleet import (FitToUsagePolicy, FleetConfig, Integer, Objective,
                         ParamSpace, PIDPolicy, PIPolicy, PoolConfig,
                         SegmentedSimulation, StaticPolicy, TuningBudget,
                         TuningScenario, poisson_trace,
                         service_model_from_cell, simulate, simulate_fleet,
                         tune, warm_start_candidates, window_metrics)
from repro.fleet.control import (ClosedLoopController,
                                 service_degradation_case, tail_workload)
from repro.fleet.simulator import FleetObs
from repro.fleet.telemetry.drift import (DriftProbe, degrade_fleet,
                                         telemetry_matrix)
from repro.fleet.workload import Trace, Workload


def _cell(shape="v5e-4", t_comp=0.4, t_mem=0.1, t_coll=0.05, batch=64):
    return CellResult(params={"batch": batch,
                              "chips": get_shape(shape).chips},
                      shape_name=shape,
                      terms=RooflineTerms(t_comp, t_mem, t_coll),
                      analysis={"peak_memory_per_device": 1e9})


def _service(**kw):
    return service_model_from_cell(_cell(**kw),
                                   units_per_step=kw.get("batch", 64))


def _obs(svc, *, queue=0.0, util=0.7, rate=0.0, replicas=4.0, n_seeds=3,
         dt=5.0, t_s=0.0):
    full = np.full
    return FleetObs(t_s=t_s, dt_s=dt,
                    arrival_rate=full(n_seeds, float(rate)),
                    queue=full(n_seeds, float(queue)),
                    replicas=full(n_seeds, float(replicas)),
                    in_flight=np.zeros(n_seeds),
                    utilization=full(n_seeds, float(util)),
                    service=svc)


def _workload(rate_mult=3.0, duration=600.0, n_seeds=3, seed=0, slo_s=2.0):
    svc = _service()
    tr = poisson_trace(rate_mult * svc.max_throughput, duration, dt_s=5.0,
                       n_seeds=n_seeds, seed=seed)
    return Workload.from_trace(tr, slo_s), svc


def _fleet(svc, initial=8, max_replicas=24, cold_start_s=30.0,
           max_queue=None):
    return FleetConfig((PoolConfig(service=svc, cold_start_s=cold_start_s,
                                   initial_replicas=initial,
                                   max_replicas=max_replicas),),
                       max_queue=max_queue)


# ------------------- PI / PID / fit-to-usage policy families ----------------

def test_pi_zero_gains_is_static_decide_sweep():
    """kp == ki == 0 makes PIPolicy decide exactly like StaticPolicy on any
    observation stream (seeded random sweep)."""
    svc = _service()
    rng = np.random.default_rng(0)
    for _ in range(50):
        n_base = int(rng.integers(1, 48))
        pi = PIPolicy(n_base, kp=0.0, ki=0.0,
                      setpoint=float(rng.uniform(0.35, 0.9)),
                      windup=float(rng.uniform(2.0, 64.0)))
        st = StaticPolicy(n_base)
        pi.reset(4)
        st.reset(4)
        for t in range(8):
            obs = _obs(svc, queue=float(rng.uniform(0, 1e4)),
                       util=float(rng.uniform(0, 1)),
                       rate=float(rng.uniform(0, 1e3)),
                       replicas=float(rng.integers(0, 32)), n_seeds=4)
            np.testing.assert_array_equal(pi.decide(t, obs),
                                          st.decide(t, obs))


def test_pi_zero_gains_is_static_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    svc = _service()
    finite = dict(allow_nan=False, allow_infinity=False)

    @settings(max_examples=60, deadline=None)
    @given(n_base=st.integers(1, 48),
           setpoint=st.floats(0.35, 0.9, **finite),
           queue=st.floats(0.0, 1e6, **finite),
           util=st.floats(0.0, 1.0, **finite),
           rate=st.floats(0.0, 1e4, **finite),
           replicas=st.integers(0, 64))
    def prop(n_base, setpoint, queue, util, rate, replicas):
        pi = PIPolicy(n_base, kp=0.0, ki=0.0, setpoint=setpoint)
        pi.reset(2)
        static = StaticPolicy(n_base)
        obs = _obs(svc, queue=queue, util=util, rate=rate,
                   replicas=replicas, n_seeds=2)
        np.testing.assert_array_equal(pi.decide(0, obs),
                                      static.decide(0, obs))

    prop()


def test_pi_zero_gains_is_static_end_to_end():
    """Full-simulation equivalence, both utilization and queue signals."""
    svc = _service()
    tr = poisson_trace(3.0 * svc.max_throughput, 400.0, dt_s=5.0,
                       n_seeds=3, seed=2)
    kw = dict(slo_s=2.0, cold_start_s=30.0, initial_replicas=4)
    ref = simulate(tr, svc, StaticPolicy(6), **kw)
    for signal in ("utilization", "queue"):
        got = simulate(tr, svc, PIPolicy(6, kp=0.0, ki=0.0, signal=signal),
                       **kw)
        for f in ("served", "queue", "replicas", "billed_replicas",
                  "ok_served", "dropped"):
            np.testing.assert_array_equal(getattr(got, f), getattr(ref, f),
                                          err_msg=f"field {f!r}")


def test_pid_zero_kd_matches_pi():
    svc = _service()
    rng = np.random.default_rng(1)
    pi = PIPolicy(4, kp=6.0, ki=0.8, setpoint=0.6, windup=12.0)
    pid = PIDPolicy(4, kp=6.0, ki=0.8, kd=0.0, setpoint=0.6, windup=12.0)
    pi.reset(3)
    pid.reset(3)
    for t in range(20):
        obs = _obs(svc, queue=float(rng.uniform(0, 500)),
                   util=float(rng.uniform(0, 1)),
                   rate=float(rng.uniform(0, 100)))
        np.testing.assert_array_equal(pi.decide(t, obs), pid.decide(t, obs))


def test_pi_starvation_floor_and_scale_to_zero():
    """Zero utilization pins the error negative; the floor keeps one replica
    while work is queued or arriving, and only a truly idle system may sit
    at zero replicas."""
    svc = _service()
    pi = PIPolicy(1, kp=8.0, ki=2.0, setpoint=0.9, windup=32.0)
    pi.reset(2)
    # drive the integrator hard negative on an idle, dead fleet
    for t in range(30):
        dead = _obs(svc, queue=0.0, util=0.0, rate=0.0, replicas=0.0,
                    n_seeds=2)
        assert (pi.decide(t, dead) == 0).all()     # idle: scale-to-zero is ok
    starved = _obs(svc, queue=5.0, util=0.0, rate=0.0, replicas=0.0,
                   n_seeds=2)
    assert (pi.decide(30, starved) >= 1).all()     # backlog: floor kicks in
    arriving = _obs(svc, queue=0.0, util=0.0, rate=3.0, replicas=0.0,
                    n_seeds=2)
    assert (pi.decide(31, arriving) >= 1).all()


def test_pi_windup_clamp_bounds_authority():
    """Anti-windup: after an arbitrarily long saturated excursion the target
    stays within n_base + kp*e + ki*windup."""
    svc = _service()
    pi = PIPolicy(2, kp=4.0, ki=1.0, setpoint=0.5, windup=8.0)
    pi.reset(1)
    sat = _obs(svc, queue=1e6, util=1.0, rate=100.0, replicas=4.0,
               n_seeds=1)
    targets = [float(pi.decide(t, sat)[0]) for t in range(200)]
    cap = 2 + 4.0 * 0.5 + 1.0 * 8.0
    assert max(targets) <= np.rint(cap)
    assert targets[-1] == targets[-50]             # settled, not still banking


def test_fit_to_usage_follows_observed_usage():
    svc = _service()
    pol = FitToUsagePolicy(headroom=0.5, window_bins=3)
    pol.reset(2)
    busy = _obs(svc, queue=10.0, util=0.8, rate=5.0, replicas=10.0,
                n_seeds=2)
    t0 = pol.decide(0, busy)
    np.testing.assert_array_equal(t0, np.ceil(0.8 * 10.0 * 1.5))
    # idle bins age the peak out of the window; starvation guard still holds
    idle = _obs(svc, queue=0.0, util=0.0, rate=1.0, replicas=12.0, n_seeds=2)
    for t in range(1, 5):
        tgt = pol.decide(t, idle)
    assert (tgt == 1).all()
    quiet = _obs(svc, queue=0.0, util=0.0, rate=0.0, replicas=1.0, n_seeds=2)
    assert (pol.decide(5, quiet) == 0).all()


def test_feedback_param_spaces_build_valid_policies():
    for cls in (PIPolicy, PIDPolicy, FitToUsagePolicy):
        space = cls.param_space()
        for params in space.sample_lhs(16, seed=3):
            pol = cls.from_params(params)
            assert isinstance(pol, cls)
            for d in space.dims:
                assert d.lo <= params[d.name] <= d.hi
    # the PI signal is context, not a dim
    p = PIPolicy.param_space().sample_lhs(1, seed=0)[0]
    assert PIPolicy.from_params(p, signal="queue").signal == "queue"
    with pytest.raises(ValueError):
        PIPolicy(2, signal="latency")
    with pytest.raises(ValueError):
        PIPolicy(2, windup=-1.0)
    with pytest.raises(ValueError):
        FitToUsagePolicy(headroom=-0.5)


def test_feedback_families_jax_kernels_match_numpy():
    pytest.importorskip("jax")
    svc = _service()
    tr = poisson_trace(4.0 * svc.max_throughput, 500.0, dt_s=5.0,
                       n_seeds=3, seed=4)
    kw = dict(slo_s=2.0, cold_start_s=30.0, initial_replicas=4)
    for pol in (PIPolicy(3, kp=6.0, ki=0.5, setpoint=0.7),
                PIPolicy(3, kp=4.0, ki=0.5, setpoint=0.4, signal="queue"),
                PIDPolicy(3, kp=6.0, ki=0.5, kd=1.5, setpoint=0.7),
                FitToUsagePolicy(headroom=0.4, window_bins=4)):
        a = simulate(tr, svc, pol, **kw)
        b = simulate(tr, svc, pol, backend="jax", **kw)
        for f in ("served", "queue", "replicas", "billed_replicas",
                  "ok_served", "dropped", "latency_s"):
            np.testing.assert_allclose(
                getattr(a, f), getattr(b, f), atol=1e-8, rtol=1e-9,
                err_msg=f"{pol.name}: field {f!r}")


# ------------------------- segmented simulation -----------------------------

def test_segmented_chunking_is_invisible():
    """One run_until(T) and many small segments produce identical results,
    and both match the one-shot substep engine."""
    wl, svc = _workload(duration=500.0)
    fleet = _fleet(svc)
    kw = dict(n_substeps=2, cold_start_seed=0)

    one = SegmentedSimulation(wl, fleet, StaticPolicy(6), **kw)
    one.run_until(one.n_bins)
    a = one.result()

    many = SegmentedSimulation(wl, fleet, StaticPolicy(6), **kw)
    for t1 in (1, 7, 30, 31, 64, many.n_bins):
        many.run_until(t1)
    b = many.result()

    c = simulate_fleet(wl, fleet, StaticPolicy(6), **kw)
    for f in ("served", "queue", "replicas", "billed_replicas", "ok_served",
              "dropped", "latency_s", "utilization"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"chunked: field {f!r}")
        np.testing.assert_array_equal(getattr(a, f), getattr(c, f),
                                      err_msg=f"one-shot: field {f!r}")


def test_segmented_partial_result_is_a_prefix():
    wl, svc = _workload(duration=400.0)
    sim = SegmentedSimulation(wl, _fleet(svc), StaticPolicy(5))
    with pytest.raises(ValueError):
        sim.partial_result()               # nothing simulated yet
    sim.run_until(20)
    part = sim.partial_result()
    assert part.served.shape[1] == 20
    sim.run_until(sim.n_bins)
    full = sim.result()
    np.testing.assert_array_equal(part.served, full.served[:, :20])
    np.testing.assert_array_equal(part.queue, full.queue[:, :20])


def test_segmented_policy_swap_takes_effect_at_boundary():
    wl, svc = _workload(duration=500.0)
    fleet = _fleet(svc, initial=2, cold_start_s=5.0)
    sim = SegmentedSimulation(wl, fleet, StaticPolicy(2))
    sim.run_until(40)
    q_mid = sim.partial_result().queue[:, 39].copy()
    sim.swap(policy=StaticPolicy(16))
    res = sim.run_until(sim.n_bins).result()
    # the trace is continuous: the backlog at the boundary is carried, and
    # the new policy's bigger fleet drains it
    np.testing.assert_array_equal(res.queue[:, 39], q_mid)
    assert res.replicas[:, :40].max() <= 2
    assert res.replicas[:, 45:].max() >= 15
    assert res.queue[:, -1].sum() < q_mid.sum() + 1


def test_segmented_swap_guards():
    wl, svc = _workload(duration=200.0)
    fleet = _fleet(svc)
    sim = SegmentedSimulation(wl, fleet, StaticPolicy(4))
    # fleet swaps must preserve pool identity and pricing
    other = _fleet(_service(shape="v5e-8"))
    with pytest.raises(ValueError):
        sim.swap(fleet=other)
    two_pools = FleetConfig(fleet.pools + fleet.pools)
    with pytest.raises(ValueError):
        sim.swap(fleet=two_pools)
    # a degraded fleet (same identity, slower service) is the allowed move
    sim.swap(fleet=degrade_fleet(fleet, 2.0))
    res = sim.run_until(sim.n_bins).result()
    assert res.served.shape[1] == sim.n_bins
    with pytest.raises(ValueError):
        sim.swap(policy=StaticPolicy(2))   # after the final bin
    with pytest.raises(ValueError):
        sim.run_until(1)                   # cannot run backwards


# --------------------------- warm-started tuning ----------------------------

def _tuned_static(objective=None, space=None, workload=None, svc=None,
                  budget=None, name="warm-seed"):
    if workload is None:
        workload, svc = _workload()
    ts = TuningScenario(name=name, workload=workload, fleet=_fleet(svc),
                        policy_cls=StaticPolicy, context={"slo_s": 2.0},
                        backend="numpy")
    space = space or ParamSpace((Integer("n_replicas", 1, 24, log=True),))
    report = tune(ts, space, objective or Objective(0.95, 2000.0),
                  budget or TuningBudget(n_candidates=5, init_seeds=1),
                  seed=0)
    return ts, space, report


def test_warm_start_candidates_anchor_and_perturb():
    _, space, report = _tuned_static()
    n = 8
    cands = warm_start_candidates(report, space, n, seed=0, jitter=0.2)
    assert len(cands) == n
    # the incumbent winner comes in verbatim, first
    assert cands[0] == {k: report.winner.params[k] for k in space.names}
    # deterministic; a different seed moves the perturbed tail
    assert cands == warm_start_candidates(report, space, n, seed=0,
                                          jitter=0.2)
    assert cands != warm_start_candidates(report, space, n, seed=1,
                                          jitter=0.2)
    for cfg in cands:
        for d in space.dims:
            assert d.lo <= cfg[d.name] <= d.hi
        assert isinstance(StaticPolicy.from_params(cfg), StaticPolicy)
    with pytest.raises(ValueError):
        warm_start_candidates(report, space, 0)


def test_warm_start_untouched_dim_falls_back_to_fresh_draw():
    """A re-tune may add a knob the incumbent never searched: those dims get
    stratified fresh draws, and the incumbent cannot anchor (its configs
    are incomplete in the wider space)."""
    _, _, report = _tuned_static()
    wider = ParamSpace((Integer("n_replicas", 1, 24, log=True),
                        Integer("extra", 2, 9)))
    cands = warm_start_candidates(report, wider, 6, seed=0)
    assert len(cands) == 6
    extras = {c["extra"] for c in cands}
    assert all(2 <= e <= 9 for e in extras)
    assert len(extras) > 1          # stratified, not one repeated value


def test_tune_warm_start_never_loses_to_incumbent():
    ts, space, report = _tuned_static()
    warm = tune(ts, space, report.objective,
                TuningBudget(n_candidates=4, init_seeds=1), seed=5,
                warm_start=report)
    # the incumbent winner is an anchor candidate, so a warm re-tune on the
    # same scenario can at worst re-race it
    assert warm.winner.mean_score() \
        <= report.winner.mean_score() + 1e-9


# ------------------------------ window metrics ------------------------------

def test_window_metrics_windows_partition_the_trace():
    wl, svc = _workload(duration=500.0)
    res = simulate_fleet(wl, _fleet(svc), StaticPolicy(6))
    T = res.served.shape[1]
    full = window_metrics(res, 0)
    assert full.t1 == T
    a, b = window_metrics(res, 0, 40), window_metrics(res, 40, T)
    assert a.usd + b.usd == pytest.approx(full.usd)
    for wm in (full, a, b):
        assert 0.0 <= wm.slo_attainment <= 1.0
        assert wm.worst_class_attainment <= wm.slo_attainment + 1e-12
        hours = (wm.t1 - wm.t0) * res.dt_s / 3600.0
        assert wm.usd_per_hour == pytest.approx(wm.usd / hours)
    with pytest.raises(ValueError):
        window_metrics(res, 40, 40)
    with pytest.raises(ValueError):
        window_metrics(res, -1, 10)
    with pytest.raises(ValueError):
        window_metrics(res, 0, T + 1)


# ------------------------------- drift probe --------------------------------

def test_drift_probe_false_alarm_rate_on_fresh_seeds():
    """The probe fit on the model's predicted telemetry must stay quiet on
    replicate traces it has never seen (fresh arrival seeds, same world)."""
    pytest.importorskip("jax")
    wl, svc = _workload(duration=600.0, n_seeds=4, seed=0)
    fleet = _fleet(svc)
    probe = DriftProbe()
    probe.fit(simulate_fleet(wl, fleet, StaticPolicy(6)))
    for seed in range(7):
        fresh, _ = _workload(duration=600.0, n_seeds=2, seed=100 + seed)
        res = simulate_fleet(fresh, fleet, StaticPolicy(6))
        rep = probe.check(telemetry_matrix(res, probe.signals))
        assert not rep.drifted, f"false alarm on fresh seed {100 + seed}"


# --------------------------- closed-loop controller -------------------------

def _controller(**kw):
    wl, svc = _workload(duration=600.0)
    ts, space, report = _tuned_static(workload=wl, svc=svc)
    ctl = ClosedLoopController(
        ts, report, segment_bins=15,
        retune_budget=TuningBudget(n_candidates=6, init_seeds=1),
        objective=Objective(0.95, 2000.0), **kw)
    return ctl, wl, ts


def test_closed_loop_quiet_run_never_acts():
    pytest.importorskip("jax")
    ctl, _, ts = _controller()
    res = ctl.run()
    assert res.n_alarms == 0 and res.n_swaps == 0
    assert not res.swapped
    assert res.active_params == res.incumbent_params
    assert res.est_factor == 1.0
    assert res.retunes == () and res.rescopes == ()
    assert res.timeline() == "(quiet run)"
    assert res.sim.served.shape[1] == ts.workload.n_bins


def test_closed_loop_detects_and_recovers_from_drift():
    """The full observe->decide->act loop on an injected service
    degradation: alarm, warm re-tune, hot-swap, and a post-swap tail that
    beats riding the incumbent through the same drift."""
    pytest.importorskip("jax")
    ctl, wl, ts = _controller()
    fleet0 = _fleet(_service())
    case = service_degradation_case(wl, fleet0, factor=3.0, t_drift=60)
    assert case.drift_bins() == [60]
    res = ctl.run(case)

    assert res.n_alarms >= 1
    assert res.est_factor > 1.5            # factor-3 drift, estimated
    assert res.n_swaps >= 1 and res.swapped
    assert res.active_params != res.incumbent_params
    assert res.active_params["n_replicas"] \
        > res.incumbent_params["n_replicas"]
    kinds = [e.kind for e in res.events]
    assert kinds.count("world-change") == 1
    assert "drift-alarm" in kinds and "retune" in kinds and "swap" in kinds
    # events are chronological and the swap lands on a segment boundary
    assert [e.t_bin for e in res.events] == sorted(e.t_bin
                                                   for e in res.events)
    swap_bin = next(e.t_bin for e in res.events if e.kind == "swap")

    # ride-through reference: same world, incumbent never reacts
    ride = SegmentedSimulation(wl, fleet0,
                               ts.make_policy(res.incumbent_params))
    ride.run_until(60)
    ride.swap(fleet=degrade_fleet(fleet0, 3.0))
    ride_res = ride.run_until(ride.n_bins).result()

    t_rec = min(swap_bin + 8, ts.workload.n_bins - 1)
    closed = window_metrics(res.sim, t_rec)
    static = window_metrics(ride_res, t_rec)
    assert closed.worst_class_attainment > static.worst_class_attainment


def test_closed_loop_rejects_misaligned_worlds():
    ctl, wl, _ = _controller()
    short, _ = _workload(duration=300.0)
    with pytest.raises(ValueError):
        ctl.run(workload=short)
    case = service_degradation_case(wl, _fleet(_service()), factor=2.0)
    with pytest.raises(ValueError):
        ctl.run(case, inject={10: 2.0})    # case and inject are exclusive
    with pytest.raises(ValueError):
        service_degradation_case(wl, _fleet(_service()), factor=1.0)
    with pytest.raises(ValueError):
        service_degradation_case(wl, _fleet(_service()), factor=2.0,
                                 t_drift=0)


def test_tail_workload_slices_remaining_bins():
    wl, _ = _workload(duration=400.0)
    tail = tail_workload(wl, 30)
    assert tail.n_bins == wl.n_bins - 30
    np.testing.assert_array_equal(tail.traces[0].arrivals,
                                  wl.traces[0].arrivals[:, 30:])
    assert tail.classes == wl.classes
    with pytest.raises(ValueError):
        tail_workload(wl, wl.n_bins)
    with pytest.raises(ValueError):
        tail_workload(wl, -1)


# ------------------------------- the CI gate --------------------------------

def _check_bench():
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _green_control():
    return {
        "benchmark": "closed_loop_control",
        "drift": {"segment_bins": 15},
        "headline": {
            "attainment_bar": 0.95, "incumbent_breaks": True,
            "recovered": True, "recovery_attainment": 0.98,
            "closed_loop_usd_per_hour": 32.0, "static_usd_per_hour": 43.0,
            "cheaper_than_static": True},
        "closed_loop": {"n_alarms": 1, "n_swaps": 1,
                        "detection_delay_bins": 15},
        "incumbent": {"post_drift": {"worst_class_attainment": 0.5}},
        "agreement": {"same_winner": True, "max_score_delta": 0.0},
    }


def test_compare_control_green_on_matching_runs():
    cb = _check_bench()
    fresh = _green_control()
    assert cb.compare_control(fresh, _green_control(), 0.02, 0.08) == []
    # no baseline yet (first run): headline invariants still gate
    assert cb.compare_control(fresh, {}, 0.02, 0.08) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d["headline"].update(incumbent_breaks=False), "breaks"),
    (lambda d: d["closed_loop"].update(n_alarms=0), "alarmed"),
    (lambda d: d["closed_loop"].update(n_swaps=0), "swapped"),
    (lambda d: d["headline"].update(recovered=False,
                                    recovery_attainment=0.90), "recover"),
    (lambda d: d["headline"].update(cheaper_than_static=False), "cheaper"),
    (lambda d: d["agreement"].update(same_winner=False), "winner"),
    (lambda d: d["agreement"].update(max_score_delta=1.0), "score"),
    (lambda d: d["headline"].pop("attainment_bar"), "incomplete"),
])
def test_compare_control_flags_each_regression(mutate, needle):
    cb = _check_bench()
    fresh = _green_control()
    mutate(fresh)
    problems = cb.compare_control(fresh, _green_control(), 0.02, 0.08)
    assert problems, f"expected a problem containing {needle!r}"
    assert any(needle in p for p in problems), problems


def test_compare_control_baseline_relative_checks():
    cb = _check_bench()
    base = _green_control()
    # attainment erosion beyond tolerance
    fresh = _green_control()
    fresh["headline"]["recovery_attainment"] = 0.955
    assert any("attainment dropped" in p for p in
               cb.compare_control(fresh, base, 0.02, 0.08))
    # cost creep beyond tolerance
    fresh = _green_control()
    fresh["headline"]["closed_loop_usd_per_hour"] = 40.0
    assert any("/hr rose" in p for p in
               cb.compare_control(fresh, base, 0.02, 0.08))
    # detection slower than one extra control segment
    fresh = _green_control()
    fresh["closed_loop"]["detection_delay_bins"] = 45
    assert any("detection slowed" in p for p in
               cb.compare_control(fresh, base, 0.02, 0.08))
    # missing jax: agreement reported, not gated
    fresh = _green_control()
    fresh["agreement"] = {"error": "jax not installed"}
    assert cb.compare_control(fresh, base, 0.02, 0.08) == []
