"""Scan-based microbatch gradient accumulation: cuts activation memory by
n_microbatches while keeping one optimizer step per global batch (and letting
XLA overlap the per-microbatch DP reduce-scatter with the next microbatch's
compute under --xla_tpu_enable_async_collective_fusion).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


def microbatched_value_and_grad(loss_fn: Callable, n_microbatches: int):
    """loss_fn(params, batch) -> (loss, metrics). Batch leaves have leading
    global-batch dim divisible by n_microbatches. Returns fn(params, batch) ->
    ((loss, metrics), grads) averaged over microbatches."""
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    if n_microbatches <= 1:
        return vg

    def split(x):
        b = x.shape[0]
        assert b % n_microbatches == 0, (b, n_microbatches)
        return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

    def fn(params, batch):
        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            (loss_acc, grad_acc, metrics_acc) = carry
            (loss, metrics), grads = vg(params, mbatch)
            grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
            metrics_acc = jax.tree.map(jnp.add, metrics_acc, metrics)
            return (loss_acc + loss, grad_acc, metrics_acc), None

        (l0, m0), g0 = vg(params, jax.tree.map(lambda x: x[0], mb))
        rest = jax.tree.map(lambda x: x[1:], mb)
        (loss, grads, metrics), _ = lax.scan(body, (l0, g0, m0), rest)
        inv = 1.0 / n_microbatches
        return ((loss * inv, jax.tree.map(lambda m: m * inv, metrics)),
                jax.tree.map(lambda g: g * inv, grads))

    return fn
