"""AdamW with decoupled weight decay and global-norm clipping (no optax dep)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=F32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(F32))), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(F32), state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(F32)),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
