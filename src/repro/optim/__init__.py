from repro.optim.adamw import AdamWConfig, AdamWState, global_norm, init, update
from repro.optim.grad_accum import microbatched_value_and_grad
from repro.optim.schedules import constant, warmup_cosine

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "global_norm",
           "microbatched_value_and_grad", "warmup_cosine", "constant"]
