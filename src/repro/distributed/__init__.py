from repro.distributed.sharding import (
    Box, ShardingRules, DEFAULT_RULES, is_box, make_rules, unbox_axes, unbox_values,
)

__all__ = ["Box", "ShardingRules", "DEFAULT_RULES", "is_box", "make_rules",
           "unbox_axes", "unbox_values"]
