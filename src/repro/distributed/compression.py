"""Error-feedback int8 gradient compression for the DP all-reduce.

Classic EF-SGD/1-bit-Adam style: quantize (grad + residual) to int8 with a
per-tensor scale before the cross-pod reduction, keep the quantization error as
local residual for the next step. Cuts DP all-reduce bytes 4x (f32) / 2x (bf16);
the residual guarantees the accumulated error stays bounded (tested for
convergence in tests/test_optim.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class EFState(NamedTuple):
    residual: Any


def init(grads_like) -> EFState:
    return EFState(jax.tree.map(lambda g: jnp.zeros_like(g, F32), grads_like))


def quantize(x):
    """f32 -> (int8, scale). Symmetric per-tensor."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(F32) * scale


def compress_grads(grads, state: EFState):
    """Returns (quantized_tree [(q, scale) per leaf], new_state)."""
    def one(g, r):
        x = g.astype(F32) + r
        q, s = quantize(x)
        err = x - dequantize(q, s)
        return (q, s), err

    flat, treedef = jax.tree.flatten(grads)
    res_flat = jax.tree.leaves(state.residual)
    qs, errs = zip(*[one(g, r) for g, r in zip(flat, res_flat)])
    return (jax.tree.unflatten(treedef, list(qs)),
            EFState(jax.tree.unflatten(treedef, list(errs))))


def decompress_grads(qtree):
    return jax.tree.map(lambda qs: dequantize(*qs), qtree,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and not isinstance(x[0], dict))
