"""Logical-axis sharding: params/activations carry *logical* axis names; a rules
table maps them onto mesh axes (MaxText-style), with automatic fallback when a
dimension is not divisible by the assigned mesh axes (e.g. kv_heads=1 under TP=16).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@jax.tree_util.register_pytree_node_class
class Box:
    """A parameter leaf bundled with its logical axis names (one per dim).
    Registered as a pytree node with `axes` as static aux data, so Box trees
    pass through eval_shape/vmap/jit transparently."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Box(shape={shape}, axes={self.axes})"


def is_box(x) -> bool:
    return isinstance(x, Box)


def unbox_values(tree):
    return jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)


def unbox_axes(tree):
    return jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)


# Mapping: logical axis -> mesh axis (str), tuple of mesh axes, or None.
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "act_seq": None,
    "sp_seq": "model",        # sequence-parallel fallback (heads % TP != 0)
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    # weights
    "embed": "data",          # FSDP axis
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "state": None,
    "conv": None,
    "stack": None,            # scan-stacked layer dim
    # kv / ssm caches (serving)
    "cache_batch": ("pod", "data"),
    "cache_heads": None,
    "cache_seq": "model",     # sequence-sharded KV cache (SP) — fits 32k..500k
    "cache_dim": None,
}


class ShardingRules:
    """Resolve logical axes -> PartitionSpec for a given mesh (or no-op w/o mesh)."""

    def __init__(self, mesh: Optional[Mesh] = None, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    # -- resolution ---------------------------------------------------------
    def _mesh_axis_sizes(self) -> dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def spec_for(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> PartitionSpec:
        """Build a PartitionSpec, dropping assignments that do not divide the dim
        or that reuse an already-used mesh axis."""
        sizes = self._mesh_axis_sizes()
        used: set[str] = set()
        entries = []
        for dim, logical in zip(shape, axes):
            assignment = self.rules.get(logical) if logical else None
            if assignment is None:
                entries.append(None)
                continue
            axes_tuple = assignment if isinstance(assignment, tuple) else (assignment,)
            # keep only mesh axes that exist and are unused
            axes_tuple = tuple(a for a in axes_tuple if a in sizes and a not in used)
            # drop trailing axes until the product divides the dim
            while axes_tuple and dim % math.prod(sizes[a] for a in axes_tuple) != 0:
                axes_tuple = axes_tuple[:-1]
            if not axes_tuple:
                entries.append(None)
                continue
            used.update(axes_tuple)
            entries.append(axes_tuple if len(axes_tuple) > 1 else axes_tuple[0])
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    def sharding_for(self, axes: Sequence[Optional[str]], shape: Sequence[int]):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(axes, shape))

    # -- use sites ----------------------------------------------------------
    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
        """with_sharding_constraint if a mesh is configured, else identity."""
        if self.mesh is None:
            return x
        spec = self.spec_for(axes, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def tree_shardings(self, boxed_tree):
        """NamedSharding pytree for a Box-tree (params or cache specs)."""
        def one(b: Box):
            shape = b.value.shape
            return self.sharding_for(b.axes, shape)
        return jax.tree.map(one, boxed_tree, is_leaf=is_box)


def make_rules(mesh: Optional[Mesh], overrides: Optional[dict] = None) -> ShardingRules:
    return ShardingRules(mesh, overrides)
