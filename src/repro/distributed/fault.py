"""Fault tolerance & straggler mitigation for the training supervisor.

* NaN/Inf loss -> restore last good checkpoint, skip the poisoned data window.
* Stalled/slow steps (EWMA watchdog) -> straggler event; on real pods the policy
  hook would trigger re-slicing / hot-spare swap; here it logs and (optionally)
  aborts so the supervisor restarts from the latest checkpoint.
* Elastic restart is handled by the checkpointer (host-layout arrays re-shard
  onto any mesh).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass
class StepWatchdog:
    """EWMA step-time tracker; flags steps slower than `threshold` x the EWMA."""
    alpha: float = 0.1
    threshold: float = 3.0
    warmup_steps: int = 5
    ewma: Optional[float] = None
    seen: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.seen += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = self.seen > self.warmup_steps and dt > self.threshold * self.ewma
        if slow:
            self.events.append((step, dt, self.ewma))
        else:
            # don't fold straggler outliers into the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class FaultInjector:
    """Test hook: schedule NaN-loss / slow-step faults at given steps."""

    def __init__(self, nan_steps=(), slow_steps=(), slow_s: float = 0.0):
        self.nan_steps = set(nan_steps)
        self.slow_steps = set(slow_steps)
        self.slow_s = slow_s

    def corrupt_loss(self, step: int, loss):
        if step in self.nan_steps:
            return loss * jnp.nan
        return loss

    def maybe_stall(self, step: int):
        if step in self.slow_steps and self.slow_s > 0:
            time.sleep(self.slow_s)


def loss_is_bad(loss) -> bool:
    v = float(loss)
    return not (v == v) or v in (float("inf"), float("-inf"))
