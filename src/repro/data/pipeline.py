"""Deterministic synthetic data pipelines, shard-aware.

* ``TokenPipeline`` — seeded LM token stream: each (step, host-shard) generates
  its slice independently (no cross-host IO), so restarts and elastic re-slicing
  reproduce the same global batch for a given step. Targets are next-token
  shifted from the same stream (structured Zipf-ish draws so losses are
  meaningful, not uniform noise).
* ``TelemetryPipeline`` — TPSS-driven sensor streams for MSET surveillance.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.tpss import TPSSParams, synthesize_batch


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts

    def _host_slice(self, step: int) -> np.ndarray:
        """(host_batch, seq_len + 1) int32, deterministic in (step, host)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        # Zipf-ish marginal + short-range repetition structure
        base = rng.zipf(1.3, size=(self.host_batch, self.seq_len + 1))
        toks = (base % self.vocab_size).astype(np.int32)
        # inject copy structure: every 8th token repeats 4 back (learnable signal)
        toks[:, 8::8] = toks[:, 4:-4:8] if toks.shape[1] > 12 else toks[:, 8::8]
        return toks

    def batch(self, step: int) -> dict:
        toks = self._host_slice(step)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}

    def sharded_batch(self, step: int, sharding) -> dict:
        """Place the host batch with the given NamedSharding (single-process:
        host==global)."""
        b = self.batch(step)
        if sharding is None:
            return b
        return {k: jax.device_put(v, sharding) for k, v in b.items()}


@dataclass
class TelemetryPipeline:
    params: TPSSParams
    n_assets: int
    seed: int = 0

    def window(self, step: int) -> jax.Array:
        key = jax.random.PRNGKey(self.seed + step * 7919)
        return synthesize_batch(key, self.params, self.n_assets)
