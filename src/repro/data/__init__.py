from repro.data.pipeline import TelemetryPipeline, TokenPipeline

__all__ = ["TokenPipeline", "TelemetryPipeline"]
