"""Memory-vector selection for MSET2 training.

Classic two-stage procedure: (1) the min-max algorithm keeps every observation
that realizes the minimum or maximum of some signal (guarantees coverage of the
operating envelope), then (2) the remaining budget is filled by vector-ordering —
observations sorted by their vector norm and sampled equidistantly.
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def select_memory_vectors(X, n_memvec: int):
    """X: (n_obs, n_signals) -> indices (n_memvec,) into X.

    jit-compatible (fixed output size). If 2*n_signals >= n_memvec, min-max
    indices are truncated deterministically.
    """
    n_obs, n_sig = X.shape
    xf = X.astype(F32)
    mins = jnp.argmin(xf, axis=0)                       # (n_sig,)
    maxs = jnp.argmax(xf, axis=0)
    envelope = jnp.concatenate([mins, maxs])            # (2*n_sig,)

    # vector-ordering: sort all observations by norm, take equidistant samples
    norms = jnp.linalg.norm(xf, axis=1)
    order = jnp.argsort(norms)
    take = jnp.linspace(0, n_obs - 1, n_memvec).astype(jnp.int32)
    ordered = order[take]                               # (n_memvec,)

    # prefer envelope vectors, fill the rest with ordered samples, dedup by
    # position overwrite (duplicates are harmless for MSET but wasteful; the
    # equidistant fill makes collisions rare).
    n_env = min(2 * n_sig, n_memvec)
    idx = jnp.concatenate([envelope[:n_env], ordered[: n_memvec - n_env]])
    return idx


def build_memory_matrix(X, n_memvec: int):
    idx = select_memory_vectors(X, n_memvec)
    return X[idx], idx
