from repro.mset.mset2 import MSETModel, estimate, surveil, train
from repro.mset.pluggable import REGISTRY, get_plugin
from repro.mset.sprt import SPRTParams, empirical_false_alarm_rate, sprt

__all__ = ["MSETModel", "train", "estimate", "surveil", "sprt", "SPRTParams",
           "empirical_false_alarm_rate", "REGISTRY", "get_plugin"]
