"""MSET2 — Multivariate State Estimation Technique (nonlinear nonparametric
regression for prognostic surveillance), the paper's pluggable ML workload.

Training (paper Fig. 4 cost driver):
    D     = memory matrix, (m, n) selected from training data
    G     = D (x) D  — the nonlinear similarity operator (the CUDA/Pallas hot spot)
    Ginv  = regularized pseudo-inverse of G (eigendecomposition)

Surveillance (paper Fig. 5 cost driver), streamed over observations x:
    w     = Ginv · (D (x) x)
    x_hat = w^T · D
residuals x - x_hat feed the SPRT detector (sprt.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.similarity import similarity
from repro.mset.memory_vectors import build_memory_matrix

F32 = jnp.float32


@dataclass
class MSETModel:
    D: jax.Array          # (m, n) memory matrix
    Ginv: jax.Array       # (m, m)
    gamma: float
    kind: str
    mean: jax.Array       # (n,) standardization
    std: jax.Array        # (n,)

    def tree_flatten(self):
        return (self.D, self.Ginv, self.mean, self.std), (self.gamma, self.kind)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        D, Ginv, mean, std = leaves
        gamma, kind = aux
        return cls(D, Ginv, gamma, kind, mean, std)


jax.tree_util.register_pytree_node(
    MSETModel, MSETModel.tree_flatten, MSETModel.tree_unflatten)


def _bandwidth(D) -> jax.Array:
    """Median-distance heuristic for gamma, from a subsample of D."""
    s = D[: min(256, D.shape[0])]
    x2 = jnp.sum(s * s, axis=1)
    d2 = jnp.maximum(x2[:, None] + x2[None, :] - 2 * s @ s.T, 0.0)
    med = jnp.median(jnp.sqrt(d2 + jnp.eye(s.shape[0]) * 1e9 * 0.0))
    return jnp.maximum(med, 1e-3)


def train(X, n_memvec: int, *, kind: str = "inverse_distance",
          gamma: Optional[float] = None, reg: float = 1e-6,
          impl: str = "auto") -> MSETModel:
    """X: (n_obs, n_signals) raw training telemetry."""
    Xf = X.astype(F32)
    mean = jnp.mean(Xf, axis=0)
    std = jnp.std(Xf, axis=0) + 1e-6
    Xs = (Xf - mean) / std

    D, _ = build_memory_matrix(Xs, n_memvec)
    g = float(gamma) if gamma is not None else float(_bandwidth(D))

    G = similarity(D, D, gamma=g, kind=kind, impl=impl)          # (m, m)
    # regularized pseudo-inverse via eigendecomposition (cuSOLVER -> jnp.eigh)
    m = G.shape[0]
    evals, evecs = jnp.linalg.eigh(G + reg * jnp.eye(m, dtype=F32))
    inv_evals = jnp.where(evals > reg, 1.0 / evals, 0.0)
    Ginv = (evecs * inv_evals[None, :]) @ evecs.T
    return MSETModel(D=D, Ginv=Ginv, gamma=g, kind=kind, mean=mean, std=std)


@partial(jax.jit, static_argnames=("impl",))
def estimate(model: MSETModel, X, impl: str = "auto"):
    """X: (b, n) observations -> (x_hat (b, n), residuals (b, n))."""
    Xs = (X.astype(F32) - model.mean) / model.std
    K = similarity(model.D, Xs, gamma=model.gamma, kind=model.kind, impl=impl)
    W = model.Ginv @ K                                           # (m, b)
    Xhat_s = W.T @ model.D                                       # (b, n)
    Xhat = Xhat_s * model.std + model.mean
    return Xhat, X - Xhat


def surveil(model: MSETModel, X_stream, impl: str = "auto"):
    """Convenience: full-stream estimation. X_stream: (T, n)."""
    return estimate(model, X_stream, impl=impl)
