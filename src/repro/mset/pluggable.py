"""Pluggable prognostic-algorithm registry (paper §II.B: the framework must
accommodate other nonlinear-nonparametric-regression techniques — NN, SVM, AAKR).

Each plugin implements  train(X, n_memvec, **kw) -> model  and
estimate(model, X) -> (x_hat, residuals). ContainerStress scopes any of them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels.similarity import similarity
from repro.mset import mset2
from repro.mset.memory_vectors import build_memory_matrix

F32 = jnp.float32


@dataclass(frozen=True)
class Plugin:
    name: str
    train: Callable
    estimate: Callable


# --------------------------- AAKR ------------------------------------------

@dataclass
class AAKRModel:
    D: jax.Array
    gamma: float
    mean: jax.Array
    std: jax.Array


jax.tree_util.register_pytree_node(
    AAKRModel,
    lambda m: ((m.D, m.mean, m.std), (m.gamma,)),
    lambda aux, l: AAKRModel(l[0], aux[0], l[1], l[2]))


def aakr_train(X, n_memvec: int, *, gamma=None, impl="auto", **_):
    Xf = X.astype(F32)
    mean, std = jnp.mean(Xf, 0), jnp.std(Xf, 0) + 1e-6
    Xs = (Xf - mean) / std
    D, _ = build_memory_matrix(Xs, n_memvec)
    g = float(gamma) if gamma is not None else 1.0
    return AAKRModel(D, g, mean, std)


def aakr_estimate(model: AAKRModel, X, impl="auto"):
    Xs = (X.astype(F32) - model.mean) / model.std
    K = similarity(model.D, Xs, gamma=model.gamma, kind="gaussian", impl=impl)  # (m, b)
    w = K / (jnp.sum(K, axis=0, keepdims=True) + 1e-9)
    Xhat = (w.T @ model.D) * model.std + model.mean
    return Xhat, X - Xhat


# --------------------------- ridge (linear baseline) ------------------------

@dataclass
class RidgeModel:
    W: jax.Array          # (n, n) auto-associative map
    mean: jax.Array
    std: jax.Array


jax.tree_util.register_pytree_node(
    RidgeModel,
    lambda m: ((m.W, m.mean, m.std), ()),
    lambda aux, l: RidgeModel(*l))


def ridge_train(X, n_memvec: int = 0, *, reg: float = 1e-3, **_):
    """Auto-associative ridge regression x -> x (leave-one-in linear baseline)."""
    Xf = X.astype(F32)
    mean, std = jnp.mean(Xf, 0), jnp.std(Xf, 0) + 1e-6
    Xs = (Xf - mean) / std
    n = Xs.shape[1]
    G = Xs.T @ Xs / Xs.shape[0] + reg * jnp.eye(n, dtype=F32)
    W = jnp.linalg.solve(G, Xs.T @ Xs / Xs.shape[0])
    return RidgeModel(W, mean, std)


def ridge_estimate(model: RidgeModel, X, **_):
    Xs = (X.astype(F32) - model.mean) / model.std
    Xhat = (Xs @ model.W) * model.std + model.mean
    return Xhat, X - Xhat


REGISTRY: dict[str, Plugin] = {
    "mset2": Plugin("mset2", mset2.train, mset2.estimate),
    "aakr": Plugin("aakr", aakr_train, aakr_estimate),
    "ridge": Plugin("ridge", ridge_train, ridge_estimate),
}


def get_plugin(name: str) -> Plugin:
    return REGISTRY[name]
