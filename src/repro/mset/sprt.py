"""SPRT (Sequential Probability Ratio Test) fault detection on MSET residuals —
the alarming stage that gives MSET2 its "ultra-low false/missed-alarm
probabilities" (paper §II.B). Two-sided mean-shift test, vectorized over signals.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


@dataclass(frozen=True)
class SPRTParams:
    alpha: float = 1e-3      # false-alarm probability
    beta: float = 1e-3       # missed-alarm probability
    m_shift: float = 3.0     # magnitude of mean shift to detect, in sigmas

    @property
    def upper(self) -> float:
        return float(jnp.log((1 - self.beta) / self.alpha))

    @property
    def lower(self) -> float:
        return float(jnp.log(self.beta / (1 - self.alpha)))


def sprt(residuals, sigma, p: SPRTParams = SPRTParams(), mu=None):
    """residuals: (T, n); sigma/mu: (n,) residual std/mean from clean validation
    data (mu defaults to 0). Returns (alarms (T, n), llr_pos, llr_neg)."""
    r = residuals.astype(F32)
    if mu is not None:
        r = r - mu[None, :].astype(F32)
    r = r / sigma[None, :].astype(F32)
    M = p.m_shift
    # log-likelihood ratio increments for H1: mean=+M vs H0: mean=0 (unit var)
    inc_pos = M * r - 0.5 * M * M
    inc_neg = -M * r - 0.5 * M * M
    hi, lo = p.upper, p.lower

    def step(carry, inc):
        sp, sn = carry
        ip, in_ = inc
        sp = jnp.clip(sp + ip, lo, None)
        sn = jnp.clip(sn + in_, lo, None)
        alarm = (sp >= hi) | (sn >= hi)
        # reset after decision (classic SPRT restart)
        sp = jnp.where(sp >= hi, 0.0, sp)
        sn = jnp.where(sn >= hi, 0.0, sn)
        return (sp, sn), (alarm, sp, sn)

    n = r.shape[1]
    z = jnp.zeros(n, F32)
    _, (alarms, sp, sn) = lax.scan(step, (z, z), (inc_pos, inc_neg))
    return alarms, sp, sn


def empirical_false_alarm_rate(alarms) -> jax.Array:
    return jnp.mean(alarms.astype(F32))
