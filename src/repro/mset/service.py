"""MSET2 as a sharded cloud service: batched fleet surveillance under pjit.

The estimation math shards naturally: memory vectors (m) over the ``model`` axis,
the observation batch over (pod, data). GSPMD inserts one all-reduce for the
x_hat contraction over m — this is the service the paper deploys in containers,
here mapped onto a TPU slice.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.similarity import similarity_ref
from repro.mset.mset2 import MSETModel

F32 = jnp.float32


def _estimate_sharded(D, Ginv, mean, std, X, *, gamma, kind):
    Xs = (X.astype(F32) - mean) / std
    K = similarity_ref(D, Xs, gamma, kind)      # (m, b)
    W = Ginv @ K                                 # (m, b)
    Xhat = W.T @ D                               # (b, n)
    Xhat = Xhat * std + mean
    return Xhat, X - Xhat


def make_service(model: MSETModel, mesh: Mesh, kind: Optional[str] = None):
    """Returns a jitted estimate(X (b, n)) with production shardings."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mvec = "model" if "model" in mesh.axis_names else None
    s_D = NamedSharding(mesh, P(mvec, None))
    s_G = NamedSharding(mesh, P(mvec, None))
    s_v = NamedSharding(mesh, P(None))
    s_X = NamedSharding(mesh, P(batch_axes, None))

    fn = jax.jit(
        partial(_estimate_sharded, gamma=model.gamma, kind=kind or model.kind),
        in_shardings=(s_D, s_G, s_v, s_v, s_X),
        out_shardings=(s_X, s_X),
        static_argnames=(),
    )

    def estimate(X):
        return fn(model.D, model.Ginv, model.mean, model.std, X)

    estimate.lower = lambda X: fn.lower(model.D, model.Ginv, model.mean, model.std, X)
    return estimate


def service_flops_bytes(n_signals: int, n_memvec: int, batch: int):
    """Analytic per-call cost of ``_estimate_sharded`` on a batch of
    observations: similarity kernel (K = sim(D, X)), weight solve (W = Ginv K),
    reconstruction (Xhat = W^T D). Feeds the fleet scenario's roofline rows."""
    m, n, b = n_memvec, n_signals, batch
    flops = 2.0 * m * b * n + 2.0 * m * m * b + 2.0 * b * m * n
    bytes_ = 4.0 * (m * n + m * m        # D, Ginv (weight streaming)
                    + 3 * b * n          # X in, Xhat + residual out
                    + 2 * m * b)         # K, W intermediates
    return flops, bytes_


def service_collective_bytes(n_signals: int, batch: int) -> float:
    """All-reduce traffic of the x_hat contraction over the sharded m axis."""
    return 2.0 * 4.0 * batch * n_signals   # ring all-reduce ~ 2x payload


def abstract_service_inputs(n_signals: int, n_memvec: int, batch: int):
    """ShapeDtypeStructs for dry-run scoping of the MSET service."""
    return {
        "D": jax.ShapeDtypeStruct((n_memvec, n_signals), F32),
        "Ginv": jax.ShapeDtypeStruct((n_memvec, n_memvec), F32),
        "mean": jax.ShapeDtypeStruct((n_signals,), F32),
        "std": jax.ShapeDtypeStruct((n_signals,), F32),
        "X": jax.ShapeDtypeStruct((batch, n_signals), F32),
    }
