"""Step-function builders shared by the trainer, the server, and the dry-run.

Everything here is mesh-agnostic: the callables close over an ArchConfig and a
ShardingRules; jit in/out shardings are derived from the logical axes of the
abstract param/cache trees.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.sharding import ShardingRules, unbox_values
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim import adamw


def batch_sharding(rules: ShardingRules, specs: dict):
    """NamedSharding tree for an input-spec dict: dim0 = batch, rest replicated."""
    if rules.mesh is None:
        return None
    out = {}
    for k, v in specs.items():
        if v.shape == ():
            out[k] = NamedSharding(rules.mesh, P())
        else:
            out[k] = rules.sharding_for(("batch",) + (None,) * (len(v.shape) - 1),
                                        v.shape)
    return out


def cast_tree(tree, dtype):
    def one(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            if isinstance(x, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(x.shape, jnp.dtype(dtype))
            return x.astype(dtype)
        return x
    return jax.tree.map(one, tree)


class StepBuilder:
    def __init__(self, cfg: ArchConfig, rules: ShardingRules,
                 n_microbatches: int = 1, opt: Optional[AdamWConfig] = None):
        self.cfg = cfg
        self.rules = rules
        self.model = build_model(cfg, ep_size=self._ep_size())
        self.n_microbatches = n_microbatches
        self.opt = opt or AdamWConfig()

    def _ep_size(self) -> Optional[int]:
        if self.rules.mesh is None:
            return None
        sizes = dict(zip(self.rules.mesh.axis_names, self.rules.mesh.devices.shape))
        return sizes.get("model")

    # -------------------- abstract trees + shardings --------------------
    def abstract_params(self, dtype=None):
        boxed = self.model.abstract_params()
        vals = unbox_values(boxed)
        if dtype is not None:
            vals = cast_tree(vals, dtype)
        return vals, boxed

    def param_shardings(self, boxed):
        return self.rules.tree_shardings(boxed)

    def abstract_opt_state(self, params_abs):
        return jax.eval_shape(adamw.init, params_abs)

    def opt_shardings(self, param_shardings):
        zero = NamedSharding(self.rules.mesh, P()) if self.rules.mesh else None
        return adamw.AdamWState(step=zero, mu=param_shardings, nu=param_shardings)

    def cache_abstract(self, shape: ShapeSpec):
        boxed = self.model.cache_specs(shape.global_batch, shape.seq_len)
        return unbox_values(boxed), boxed

    def cache_shardings(self, boxed):
        return self.rules.tree_shardings(boxed)

    # -------------------------- step functions --------------------------
    def train_step_fn(self):
        cfg, rules, model = self.cfg, self.rules, self.model
        from repro.optim.grad_accum import microbatched_value_and_grad

        def loss(params, batch):
            l, metrics = model.loss(params, batch, rules)
            return l, metrics

        vg = microbatched_value_and_grad(loss, self.n_microbatches)
        optc = self.opt

        def train_step(params, opt_state, batch):
            (l, metrics), grads = vg(params, batch)
            new_params, new_opt, om = adamw.update(optc, grads, opt_state, params)
            return new_params, new_opt, dict(metrics, loss=l, **om)

        return train_step

    def prefill_fn(self):
        cfg, rules, model = self.cfg, self.rules, self.model

        def prefill(params, batch):
            return model.prefill(params, batch, rules)

        return prefill

    def decode_fn(self):
        cfg, rules, model = self.cfg, self.rules, self.model

        def decode(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos, rules)

        return decode

    # ------------------------ jitted + sharded forms --------------------
    def jit_train_step(self, donate: bool = True):
        _, boxed = self.abstract_params()
        ps = self.param_shardings(boxed)
        os_ = self.opt_shardings(ps)
        rep = NamedSharding(self.rules.mesh, P()) if self.rules.mesh else None
        metrics_sh = None if rep is None else jax.tree.map(
            lambda _: rep, {"nll": 0, "z_loss": 0, "moe_aux": 0, "loss": 0,
                            "grad_norm": 0, "lr": 0})
        kw = {}
        if self.rules.mesh is not None:
            kw = dict(in_shardings=(ps, os_, None),
                      out_shardings=(ps, os_, metrics_sh))
        return jax.jit(self.train_step_fn(),
                       donate_argnums=(0, 1) if donate else (), **kw)

    def jit_grad_step(self):
        """value_and_grad only (no optimizer) — used by the dry-run cost probes."""
        _, boxed = self.abstract_params()
        ps = self.param_shardings(boxed)
        model, rules = self.model, self.rules

        def grad_step(params, batch):
            def loss(p, b):
                return model.loss(p, b, rules)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
            return grads, l

        kw = {}
        if self.rules.mesh is not None:
            rep = NamedSharding(self.rules.mesh, P())
            kw = dict(in_shardings=(ps, None), out_shardings=(ps, rep))
        return jax.jit(grad_step, **kw)

    def jit_decode_step(self, shape: ShapeSpec, donate: bool = True):
        _, cboxed = self.cache_abstract(shape)
        cs = self.cache_shardings(cboxed)
        _, pboxed = self.abstract_params()
        ps = self.param_shardings(pboxed)
        kw = {}
        if self.rules.mesh is not None:
            rep = NamedSharding(self.rules.mesh, P())
            logits_sh = self.rules.sharding_for(
                ("batch", None, "act_vocab"),
                (shape.global_batch, 1, self.cfg.vocab_size))
            kw = dict(in_shardings=(ps, cs, None, rep),
                      out_shardings=(cs, logits_sh))
        return jax.jit(self.decode_fn(),
                       donate_argnums=(1,) if donate else (), **kw)

    def jit_prefill(self, shape: ShapeSpec):
        _, pboxed = self.abstract_params()
        ps = self.param_shardings(pboxed)
        kw = {}
        if self.rules.mesh is not None:
            # cache out-shardings resolved from the PREFILL-length cache tree
            pre_len = shape.seq_len
            cboxed = self.model.cache_specs(shape.global_batch, pre_len)
            cs = self.rules.tree_shardings(cboxed)
            logits_sh = self.rules.sharding_for(
                ("batch", None, "act_vocab"),
                (shape.global_batch, 1, self.cfg.vocab_size))
            kw = dict(in_shardings=(ps, None), out_shardings=(cs, logits_sh))
        return jax.jit(self.prefill_fn(), **kw)
