"""Fault-tolerant training driver.

Runs on anything from this CPU dev box (smoke configs) to the production mesh:
data pipeline -> jitted sharded train_step -> watchdog -> checkpoints -> restart.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke --steps 50
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.distributed.fault import FaultInjector, StepWatchdog, loss_is_bad
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_dev_mesh
from repro.launch.steps import StepBuilder
from repro.optim import AdamWConfig, adamw, warmup_cosine


@dataclass
class TrainJob:
    arch: str
    smoke: bool = True
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    n_microbatches: int = 1
    peak_lr: float = 3e-3
    warmup: int = 20
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 25
    keep: int = 3
    seed: int = 0
    use_mesh: bool = True
    log_every: int = 10
    max_restarts: int = 3
    injector: Optional[FaultInjector] = None
    history: list = field(default_factory=list)


def build(job: TrainJob):
    cfg = get_config(job.arch, smoke=job.smoke)
    mesh = make_dev_mesh() if job.use_mesh and len(jax.devices()) > 1 else None
    rules = make_rules(mesh)
    opt = AdamWConfig(lr=warmup_cosine(job.peak_lr, job.warmup, job.steps))
    sb = StepBuilder(cfg, rules, n_microbatches=job.n_microbatches, opt=opt)
    pipe = TokenPipeline(cfg.vocab_size, job.seq_len, job.global_batch, seed=job.seed)
    return cfg, mesh, rules, sb, pipe


def train(job: TrainJob, verbose: bool = True) -> dict:
    cfg, mesh, rules, sb, pipe = build(job)
    ckpt = Checkpointer(os.path.join(job.ckpt_dir, cfg.name), keep=job.keep)
    watchdog = StepWatchdog()

    params = sb.model.init_values(jax.random.PRNGKey(job.seed))
    opt_state = adamw.init(params)
    start_step = 0

    # resume if checkpoints exist (elastic: works across device counts)
    _, pboxed = sb.abstract_params()
    shardings = (sb.param_shardings(pboxed), sb.opt_shardings(sb.param_shardings(pboxed))) \
        if mesh is not None else (None, None)
    if ckpt.latest_step() is not None:
        (params, opt_state), start_step, _ = ckpt.restore_latest_valid(
            (params, opt_state), shardings=shardings if mesh is not None else None)
        if verbose:
            print(f"[train] resumed from step {start_step}")

    step_fn = sb.jit_train_step(donate=True)
    restarts = 0
    step = start_step
    poisoned: set[int] = set()        # data windows that produced bad losses
    metrics_out: dict[str, Any] = {}
    t_train0 = time.time()
    while step < job.steps:
        if step in poisoned:          # skip bad data windows after a restore
            step += 1
            continue
        batch = pipe.batch(step)
        t0 = time.perf_counter()
        if job.injector:
            job.injector.maybe_stall(step)   # simulated straggler device
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = metrics["loss"]
        if job.injector:
            loss = job.injector.corrupt_loss(step, loss)
        loss_v = float(loss)
        dt = time.perf_counter() - t0

        if loss_is_bad(loss_v):
            restarts += 1
            poisoned.add(step)
            if restarts > job.max_restarts:
                raise RuntimeError(f"too many restarts ({restarts}) at step {step}")
            if verbose:
                print(f"[train] BAD LOSS at step {step}; restoring last checkpoint "
                      f"(restart {restarts}/{job.max_restarts})")
            if ckpt.latest_step() is not None:
                (params, opt_state), step, _ = ckpt.restore_latest_valid(
                    (params, opt_state),
                    shardings=shardings if mesh is not None else None)
            else:
                params = sb.model.init_values(jax.random.PRNGKey(job.seed))
                opt_state = adamw.init(params)
                step = 0
            continue

        slow = watchdog.observe(step, dt) if step > start_step else False
        job.history.append({"step": step, "loss": loss_v, "dt": dt, "slow": slow})
        if verbose and (step % job.log_every == 0 or slow):
            print(f"[train] step {step:5d} loss {loss_v:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms"
                  + ("  <-- straggler" if slow else ""))
        step += 1
        if step % job.ckpt_every == 0:
            ckpt.save_async(step, (params, opt_state), extra={"loss": loss_v})
    ckpt.wait()
    ckpt.save(job.steps, (params, opt_state))
    metrics_out = {
        "final_loss": job.history[-1]["loss"] if job.history else float("nan"),
        "first_loss": job.history[0]["loss"] if job.history else float("nan"),
        "steps": step,
        "restarts": restarts,
        "straggler_events": len(watchdog.events),
        "wall_s": time.time() - t_train0,
    }
    if verbose:
        print(f"[train] done: {metrics_out}")
    return metrics_out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    args = ap.parse_args()
    job = TrainJob(arch=args.arch, smoke=not args.full, steps=args.steps,
                   seq_len=args.seq_len, global_batch=args.batch,
                   n_microbatches=args.microbatches, peak_lr=args.lr,
                   ckpt_dir=args.ckpt_dir)
    train(job)


if __name__ == "__main__":
    main()
