"""Batched serving driver: prefill prompts into a KV/state cache, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --tokens 32
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import is_box, make_rules
from repro.launch.mesh import make_dev_mesh
from repro.launch.steps import StepBuilder


def pad_cache(model, cache, batch: int, from_len: int, to_len: int):
    """Pad prefill-length caches out to the serving window."""
    specs = model.cache_specs(batch, to_len)

    def pad(c, sp):
        tgt = sp.value.shape
        pads = [(0, t - s) for s, t in zip(c.shape, tgt)]
        return jnp.pad(c, pads)

    return jax.tree.map(pad, cache, specs, is_leaf=is_box)


def decode_flops_bytes(cfg, batch: int, ctx: int = 512):
    """Analytic per-decode-step cost of batched serving (one token for each of
    ``batch`` sequences at context ``ctx``) — roofline feedstock for the fleet
    scenarios.

    FLOPs: 2 FLOPs/param on the *active* params per token, plus attention
    against the KV cache. Bytes: every weight streamed once per step (the
    decode-bandwidth wall) plus the KV cache read.
    """
    counts = cfg.param_counts()
    dt_bytes = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    q_dim = max(cfg.n_heads, 0) * max(cfg.head_dim, 0)       # query heads
    kv_dim = max(cfg.n_kv_heads, 0) * max(cfg.head_dim, 0)   # cached heads
    flops = 2.0 * counts["active"] * batch
    flops += 4.0 * batch * cfg.n_layers * q_dim * ctx        # QK^T + AV
    bytes_ = counts["total"] * dt_bytes
    bytes_ += 2.0 * batch * cfg.n_layers * kv_dim * ctx * dt_bytes
    return flops, bytes_


@dataclass
class GenResult:
    tokens: np.ndarray
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def generate(arch: str, *, smoke: bool = True, batch: int = 4, prompt_len: int = 32,
             gen_tokens: int = 16, seed: int = 0, greedy: bool = True) -> GenResult:
    cfg = get_config(arch, smoke=smoke)
    mesh = make_dev_mesh() if len(jax.devices()) > 1 else None
    rules = make_rules(mesh)
    sb = StepBuilder(cfg, rules)
    model = sb.model

    key = jax.random.PRNGKey(seed)
    params = model.init_values(key)
    max_len = prompt_len + gen_tokens
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    batch_in = {"tokens": prompts}
    if cfg.encdec:
        batch_in["frames"] = jax.random.normal(
            key, (batch, cfg.enc_memory_len, cfg.d_model)).astype(cfg.dtype)

    t0 = time.perf_counter()
    cache, logits = model.prefill(params, batch_in, rules)
    cache = pad_cache(model, cache, batch, prompt_len, max_len)
    jax.block_until_ready(cache)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, rules))
    out = [jnp.argmax(logits[:, -1, :], axis=-1)]
    t0 = time.perf_counter()
    for i in range(gen_tokens - 1):
        tok = out[-1][:, None]
        cache, logits = decode(params, cache, tok, prompt_len + i)
        out.append(jnp.argmax(logits[:, -1, :], axis=-1))
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    return GenResult(toks, t_prefill, t_decode,
                     batch * (gen_tokens - 1) / max(t_decode, 1e-9))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    r = generate(args.arch, smoke=not args.full, batch=args.batch,
                 prompt_len=args.prompt_len, gen_tokens=args.tokens)
    print(f"[serve] prefill {r.prefill_s*1e3:.1f}ms decode {r.decode_s*1e3:.1f}ms "
          f"({r.tokens_per_s:.1f} tok/s) sample: {r.tokens[0][:12]}")


if __name__ == "__main__":
    main()
