"""Mesh construction. Functions only — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = n_devices or len(jax.devices())
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    model = 1
    for m in (4, 2):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
