import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device count
# on first init). Everything below is ordinary.
#
# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell on
# the production meshes and extract the roofline terms.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch nemotron-4-15b --shape train_4k
#     PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
#
# Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json (read by benchmarks/
# roofline.py and EXPERIMENTS.md generation).

import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs, model_flops,
                           shape_applicable)
from repro.core.cost_model import V5E, roofline
from repro.core.hlo_analysis import analyze_compiled
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import StepBuilder


# --- optimization knobs for the §Perf hillclimb (all default-off) -----------
# decode_tp_params : serve params TP-only (drop the FSDP 'embed'->data rule for
#                    decode, killing the per-step weight all-gather)
# causal_skip      : block-causal attention — q-chunk i only reads kv[0:(i+1)Qc]
#                    (unrolled loop; halves attention flops+bytes)
# bf16_loss        : bf16 softmax-xent with f32 reductions (no f32 logits
#                    materialization)
# moe_dense        : force dense-gather MoE (vs EP shard_map)
KNOWN_OPTS = ("decode_tp_params", "causal_skip", "bf16_loss", "moe_dense")


def tune_cfg(cfg, shape, moe_impl: str | None = None, opts: tuple = ()):
    """Per-cell config adjustments (the dry-run knobs the perf loop turns)."""
    kw = {}
    if cfg.moe:
        kw["moe_impl"] = moe_impl or ("ep" if shape.kind != "decode" else "dense")
        if "moe_dense" in opts:
            kw["moe_impl"] = "dense"
    if "causal_skip" in opts:
        kw["causal_block_skip"] = True
    if "bf16_loss" in opts:
        kw["softmax_dtype"] = "bfloat16"
    if kw:
        cfg = cfg.replace(**kw)
    return cfg


def rule_overrides_for(shape, opts: tuple = ()):
    if "decode_tp_params" in opts and shape.kind == "decode":
        return {"embed": None}       # TP-only serving params; no FSDP gather
    return None


def lower_cell(arch: str, shape_name: str, mesh, *, n_microbatches: int = 8,
               moe_impl: str | None = None, cfg_override: dict | None = None,
               grad_only: bool = False, cfg_base=None, opts: tuple = ()):
    """Returns (lowered, aux_info dict)."""
    cfg = cfg_base or get_config(arch)
    shape = SHAPES[shape_name]
    cfg = tune_cfg(cfg, shape, moe_impl, opts)
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    rules = make_rules(mesh, rule_overrides_for(shape, opts))
    sb = StepBuilder(cfg, rules, n_microbatches=n_microbatches)
    specs = input_specs(cfg, shape)
    if n_microbatches > 1 and shape.kind == "train" and grad_only:
        raise ValueError("probes must use n_microbatches=1")

    if shape.kind == "train":
        params_abs, boxed = sb.abstract_params()
        if grad_only:
            step = sb.jit_grad_step()
            args = (params_abs, specs)
        else:
            opt_abs = sb.abstract_opt_state(params_abs)
            step = sb.jit_train_step(donate=True)
            args = (params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        params_abs, boxed = sb.abstract_params(dtype="bfloat16")
        step = sb.jit_prefill(shape)
        args = (params_abs, specs)
    else:  # decode
        params_abs, boxed = sb.abstract_params(dtype="bfloat16")
        cache_abs, _ = sb.cache_abstract(shape)
        step = sb.jit_decode_step(shape, donate=True)
        args = (params_abs, cache_abs, specs["tokens"], specs["pos"])

    lowered = step.lower(*args)
    return lowered, {"cfg": cfg, "shape": shape, "sb": sb, "params_abs": params_abs}


# ---------------------------------------------------------------------------
# Compositional cost probes.
#
# XLA:CPU cost_analysis counts a while-loop body ONCE (verified in
# tests/test_hlo_analysis.py), so the scanned production executable under-counts
# FLOPs/bytes by the trip counts. The probes lower loop-free (unrolled) graphs at
# 1x and 2x the block period; the difference is the exact per-block cost, scaled
# by the stack depth and microbatch count, plus a separate optimizer probe.
# ---------------------------------------------------------------------------

def _scale_cost(c, s: float):
    from repro.core.hlo_analysis import CollectiveStats, CompiledCost
    return CompiledCost(
        n_devices=c.n_devices,
        flops=c.flops * s,
        bytes_accessed=c.bytes_accessed * s,
        collective_bytes=c.collective_bytes * s,
        collectives=CollectiveStats(
            {k: v * s for k, v in c.collectives.bytes_by_kind.items()},
            {k: v * s for k, v in c.collectives.count_by_kind.items()}),
        peak_memory_per_device=c.peak_memory_per_device,
        argument_bytes_per_device=c.argument_bytes_per_device,
        temp_bytes_per_device=c.temp_bytes_per_device,
        output_bytes_per_device=c.output_bytes_per_device,
    )


def _add_cost(a, b, sb: float = 1.0):
    from repro.core.hlo_analysis import CollectiveStats, CompiledCost
    keys = set(a.collectives.bytes_by_kind) | set(b.collectives.bytes_by_kind)
    return CompiledCost(
        n_devices=a.n_devices,
        flops=max(a.flops + sb * b.flops, 0.0),
        bytes_accessed=max(a.bytes_accessed + sb * b.bytes_accessed, 0.0),
        collective_bytes=max(a.collective_bytes + sb * b.collective_bytes, 0.0),
        collectives=CollectiveStats(
            {k: max(a.collectives.bytes_by_kind.get(k, 0)
                    + sb * b.collectives.bytes_by_kind.get(k, 0), 0.0) for k in keys},
            {k: max(a.collectives.count_by_kind.get(k, 0)
                    + sb * b.collectives.count_by_kind.get(k, 0), 0.0) for k in keys}),
        peak_memory_per_device=a.peak_memory_per_device,
        argument_bytes_per_device=a.argument_bytes_per_device,
        temp_bytes_per_device=a.temp_bytes_per_device,
        output_bytes_per_device=a.output_bytes_per_device,
    )


def probe_cost(arch: str, shape_name: str, mesh, *, n_microbatches: int = 8,
               moe_impl: str | None = None, cfg_base=None, verbose: bool = False,
               opts: tuple = ()):
    """Exact (trip-count-aware) global cost for the cell, from unrolled probes."""
    from repro.configs import base as cfgbase
    from repro.models.transformer import block_period

    cfg = cfg_base or get_config(arch)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    P = block_period(tune_cfg(cfg, shape, moe_impl, opts))
    n_stack = cfg.n_layers // P
    is_train = shape.kind == "train"
    mb = n_microbatches if is_train else 1

    # thread the microbatch-sized batch through input_specs via a scoped SHAPES
    # patch (lower_cell reads SHAPES[shape_name])
    orig = cfgbase.SHAPES[shape_name]
    probe_shape = orig
    if is_train and mb > 1:
        probe_shape = cfgbase.ShapeSpec(orig.name, orig.kind, orig.seq_len,
                                        orig.global_batch // mb)
    c = {}
    try:
        cfgbase.SHAPES[shape_name] = probe_shape
        for mult in (1, 2):
            over = {"unroll": True, "n_layers": mult * P}
            if cfg.encdec:
                over["n_enc_layers"] = mult * (cfg.n_enc_layers * P // cfg.n_layers)
            t0 = time.time()
            lowered, _ = lower_cell(arch, shape_name, mesh, n_microbatches=1,
                                    moe_impl=moe_impl, cfg_override=over,
                                    grad_only=is_train, cfg_base=cfg_base,
                                    opts=opts)
            c[mult] = analyze_compiled(lowered.compile(), n_devices=chips)
            if verbose:
                print(f"[probe] {arch} {shape_name} x{mult}: {time.time()-t0:.0f}s")
    finally:
        cfgbase.SHAPES[shape_name] = orig

    block = _add_cost(c[2], c[1], sb=-1.0)           # per extra block
    per_mb = _add_cost(c[1], block, sb=float(n_stack - 1))
    total = _scale_cost(per_mb, float(mb))

    if is_train:  # optimizer probe on full-size params, once per step
        rules = make_rules(mesh, rule_overrides_for(shape, opts))
        sb_full = StepBuilder(tune_cfg(cfg, shape, moe_impl, opts), rules, 1)
        total = _add_cost(total, _optimizer_probe(sb_full, chips))
    return total


def _optimizer_probe(sb: StepBuilder, chips: int):
    from repro.optim import AdamWConfig
    from repro.optim.adamw import update as adamw_update

    params_abs, boxed = sb.abstract_params()
    ps = sb.param_shardings(boxed)
    opt_abs = sb.abstract_opt_state(params_abs)
    os_ = sb.opt_shardings(ps)
    oc = AdamWConfig(lr=1e-4)
    fn = jax.jit(lambda g, s, p: adamw_update(oc, g, s, p),
                 in_shardings=(ps, os_, ps), donate_argnums=(1,))
    lowered = fn.lower(params_abs, opt_abs, params_abs)
    return analyze_compiled(lowered.compile(), n_devices=chips)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             n_microbatches: int = 8, moe_impl: str | None = None,
             out_dir: str = "artifacts/dryrun", verbose: bool = True,
             probes: bool = True, opts: tuple = ()) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skip", reason=why)
        _save(rec, out_dir, mesh_name, arch, shape_name)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            # (1) full production-structured compile: proves lowering/sharding,
            # gives the real memory picture + collective schedule
            lowered, aux = lower_cell(arch, shape_name, mesh,
                                      n_microbatches=n_microbatches,
                                      moe_impl=moe_impl, opts=opts)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            sched = analyze_compiled(compiled, n_devices=chips)
            if probes:
                # (2) trip-count-aware cost probes (XLA:CPU counts loop bodies
                # once; see tests/test_hlo_analysis.py)
                cost = probe_cost(arch, shape_name, mesh,
                                  n_microbatches=n_microbatches, moe_impl=moe_impl,
                                  opts=opts)
                # memory picture comes from the production executable
                cost.peak_memory_per_device = sched.peak_memory_per_device
                cost.argument_bytes_per_device = sched.argument_bytes_per_device
                cost.temp_bytes_per_device = sched.temp_bytes_per_device
                cost.output_bytes_per_device = sched.output_bytes_per_device
            else:
                cost = sched  # schedule/memory only (multi-pod compile proof)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        _save(rec, out_dir, mesh_name, arch, shape_name)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAILED {e}")
        return rec

    terms = roofline(cost.flops, cost.bytes_accessed, cost.collective_bytes, chips)
    mflops = model_flops(aux["cfg"], aux["shape"])
    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops=cost.flops,
        bytes_accessed=cost.bytes_accessed,
        collective_bytes=cost.collective_bytes,
        collective_bytes_by_kind=cost.collectives.bytes_by_kind,
        collective_count_by_kind=cost.collectives.count_by_kind,
        peak_memory_per_device=cost.peak_memory_per_device,
        argument_bytes_per_device=cost.argument_bytes_per_device,
        temp_bytes_per_device=cost.temp_bytes_per_device,
        t_compute=terms.t_compute,
        t_memory=terms.t_memory,
        t_collective=terms.t_collective,
        t_step=terms.t_step,
        dominant=terms.dominant,
        model_flops=mflops,
        useful_flops_ratio=(mflops / cost.flops) if cost.flops else None,
        roofline_fraction=(mflops / (terms.t_step * chips * V5E.peak_flops))
        if terms.t_step > 0 else None,
        n_microbatches=n_microbatches,
        opts=list(opts),
    )
    _save(rec, out_dir, mesh_name, arch, shape_name)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"t_step={terms.t_step*1e3:.2f}ms dom={terms.dominant} "
              f"mem/dev={cost.peak_memory_per_device/2**30:.2f}GiB "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def _save(rec: dict, out_dir: str, mesh_name: str, arch: str, shape_name: str):
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--moe-impl", choices=["ep", "dense", "gather"])
    ap.add_argument("--no-probes", action="store_true",
                    help="compile-proof only (no cost probes); used for multi-pod")
    ap.add_argument("--opt", action="append", default=[], choices=list(KNOWN_OPTS),
                    help="perf knobs (repeatable); results tagged in the artifact")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = ([(args.arch, args.shape)] if args.arch and args.shape else
             [(a, s) for a in ([args.arch] if args.arch else ARCH_IDS)
              for s in ([args.shape] if args.shape else list(SHAPES))])
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    n_ok = n_fail = 0
    for mp in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, multi_pod=mp,
                           n_microbatches=args.microbatches,
                           moe_impl=args.moe_impl, out_dir=args.out,
                           probes=not args.no_probes, opts=tuple(args.opt))
            if rec["status"] == "error":
                n_fail += 1
            else:
                n_ok += 1
    print(f"[dryrun] done: {n_ok} ok/skip, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
