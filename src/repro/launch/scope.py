import os
if "--analytic" in os.sys.argv or "--lm" in os.sys.argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ContainerStress CLI — the paper's workflow end to end.
#
#   measured MSET2 scoping (paper Figs. 4-5, CPU wall-clock Monte Carlo):
#     PYTHONPATH=src python -m repro.launch.scope --mset --grid small
#   analytic LM scoping across the catalog (TPU roofline dry-run):
#     PYTHONPATH=src python -m repro.launch.scope --lm mamba2-130m --shape train_4k

import argparse
import json



def run_mset(grid_name: str, reps: int, out: str):
    import jax
    from repro.core import (ContainerStress, fit_response_surface, grid_to_matrix,
                            render_ascii_surface)
    from repro.mset import estimate, train
    from repro.tpss import TPSSParams, synthesize

    grids = {
        "small": {"n_signals": [8, 16, 32], "n_memvec": [64, 128, 256],
                  "n_observations": [1024]},
        "paper": {"n_signals": [32, 64, 128, 256], "n_memvec": [128, 256, 512, 1024],
                  "n_observations": [4096]},
    }
    grid = grids[grid_name]

    def workload(params):
        key = jax.random.PRNGKey(hash(tuple(sorted(params.items()))) % 2**31)
        X = synthesize(key, TPSSParams(n_signals=params["n_signals"],
                                       n_obs=params["n_observations"]))
        n_tr = int(params["n_observations"] * 0.75)

        def run():
            m = train(X[:n_tr], n_memvec=params["n_memvec"])
            _, r = estimate(m, X[n_tr:])
            return r
        return run

    cs = ContainerStress()
    res = cs.run_measured(workload, grid, reps=reps, verbose=True,
                          constraint=lambda p: p["n_memvec"] >= 2 * p["n_signals"])
    names, X, y = res.to_arrays()
    surf = fit_response_surface(names, X, y)
    print(f"\nresponse surface fit: r^2 = {surf.r2:.4f}")
    xs, ys, Z = grid_to_matrix(res.rows, "n_memvec", "n_signals")
    print(render_ascii_surface(xs, ys, Z, "n_memvec", "n_signals",
                               "MSET2 train+surveil compute cost (measured)"))
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump([{**r.params, "mean_s": r.mean_s, "std_s": r.std_s}
                       for r in res.rows], f, indent=1)
        print(f"saved {out}")


def run_lm(arch: str, shape_name: str, out: str):
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.core import CATALOG, Constraint, recommend
    from repro.launch.dryrun import probe_cost
    from repro.core.cost_model import roofline, dollar_cost
    from repro.core.scoping import CellResult

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        print(f"skip: {why}")
        return
    rows = []
    for cshape in CATALOG:
        if cshape.chips < 64:
            continue  # big-model scoping starts at v5e-64
        mesh = cshape.make_mesh()
        try:
            with mesh:
                cost = probe_cost(arch, shape_name, mesh, n_microbatches=8)
        except Exception as e:
            print(f"{cshape.name}: infeasible ({type(e).__name__})")
            continue
        terms = roofline(cost.flops, cost.bytes_accessed, cost.collective_bytes,
                         cshape.chips)
        usd = dollar_cost(terms.t_step, 1000, cshape.chips)
        rows.append(CellResult(params={"shape": cshape.chips},
                               shape_name=cshape.name, terms=terms,
                               analysis=cost.as_dict(), usd_per_1k_steps=usd))
        print(f"{cshape.name:12s} t_step={terms.t_step*1e3:9.2f}ms "
              f"dom={terms.dominant:10s} ${usd:8.2f}/1k-steps")
    cons = Constraint(max_step_latency_s=60.0)
    rec = recommend(rows, cons)
    print(f"\nrecommendation: {rec.shape.name if rec.shape else None} — {rec.reason}")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump([{**r.params, "shape_name": r.shape_name,
                        "t_step": r.terms.t_step, "usd": r.usd_per_1k_steps}
                       for r in rows], f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mset", action="store_true")
    ap.add_argument("--grid", default="small")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--lm")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if args.mset:
        run_mset(args.grid, args.reps, args.out)
    elif args.lm:
        run_lm(args.lm, args.shape, args.out)
    else:
        ap.error("pick --mset or --lm <arch>")


if __name__ == "__main__":
    main()
