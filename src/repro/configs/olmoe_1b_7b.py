"""olmoe-1b-7b — MoE 64 experts top-8, d_ff/expert=1024, MHA. [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=50304,
    mlp_type="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    qk_norm=True,
    moe=True,
    n_experts=64,
    n_experts_per_tok=8,
    moe_d_ff=1024,
)

SMOKE = CONFIG.replace(
    name="olmoe-1b-7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    vocab_size=512, n_experts=8, n_experts_per_tok=2, moe_d_ff=64,
)
