"""chameleon-34b — early-fusion VLM; image VQ tokens share the 65536 vocab, so the
backbone consumes plain token ids (VQ tokenizer stubbed). qk-norm. [arXiv:2405.09818]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    mlp_type="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    qk_norm=True,
    frontend="vision",
)

SMOKE = CONFIG.replace(
    name="chameleon-34b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=224, vocab_size=512,
)
