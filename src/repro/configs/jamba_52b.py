"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2 every 2nd
layer. Mamba sublayers use our SSD block with d_state=16 (Jamba v0.1 is Mamba-1;
SSD is the TPU-efficient equivalent — noted in DESIGN.md). [arXiv:2403.19887; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    mlp_type="swiglu",
    norm="rmsnorm",
    pos_emb="none",          # jamba uses no positional encoding on attention
    moe=True,
    n_experts=16,
    n_experts_per_tok=2,
    moe_period=2,
    moe_offset=1,
    moe_d_ff=14336,
    ssm=True,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    conv_width=4,
    attn_period=8,
    attn_offset=4,
)

SMOKE = CONFIG.replace(
    name="jamba-v0.1-52b-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    n_experts=4, n_experts_per_tok=2, moe_d_ff=128,
    ssm_state=16, ssm_headdim=16, ssd_chunk=16,
    attn_period=8, attn_offset=4,
)
