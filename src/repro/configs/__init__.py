from repro.configs.base import (
    ArchConfig,
    SHAPES,
    ShapeSpec,
    input_specs,
    model_flops,
    shape_applicable,
)
from repro.configs.registry import ARCH_IDS, all_cells, get_config

__all__ = [
    "ArchConfig", "SHAPES", "ShapeSpec", "input_specs", "model_flops",
    "shape_applicable", "ARCH_IDS", "all_cells", "get_config",
]
