"""seamless-m4t-large-v2 — encoder-decoder backbone, audio frontend STUB.

Backbone only per the brief: 24 encoder + 24 decoder layers, d=1024, 16H MHA,
d_ff=8192, vocab 256206. ``input_specs`` supplies precomputed frame embeddings
(B, S, d_model) for the encoder. [arXiv:2308.11596; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,            # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_type="gelu",
    norm="layernorm",
    pos_emb="sinusoidal",
    encdec=True,
    n_enc_layers=24,
    frontend="audio",
)

SMOKE = CONFIG.replace(
    name="seamless-m4t-large-v2-smoke",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, enc_memory_len=64,
)
