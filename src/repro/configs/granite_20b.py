"""granite-20b — code model, MQA (kv=1). [arXiv:2405.04324; hf]

Note: the assignment line says "llama-arch"; with a 3-matmul SwiGLU MLP the listed
dims give 28B params, but granite-20b-code is a 20B gpt-bigcode-style model with a
2-matmul GELU MLP. We keep RoPE+RMSNorm (llama-style) and use the GELU MLP so the
parameter count matches the published 20B (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    norm="rmsnorm",
    pos_emb="rope",
)

SMOKE = CONFIG.replace(
    name="granite-20b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=256, vocab_size=512,
)
