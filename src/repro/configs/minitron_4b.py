"""minitron-4b — width-pruned nemotron. [arXiv:2407.14679; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="relu2",
    norm="layernorm",
    pos_emb="rope",
)

SMOKE = CONFIG.replace(
    name="minitron-4b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512,
)
