"""mamba2-130m — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    ssm=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    conv_width=4,
)

SMOKE = CONFIG.replace(
    name="mamba2-130m-smoke",
    n_layers=2, d_model=64, vocab_size=512,
    ssm_state=16, ssm_headdim=16, ssd_chunk=16,
)
