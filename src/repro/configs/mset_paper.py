"""The paper's own use case: MSET2 prognostic surveillance as a cloud service.

The three "conventional ML design parameters" (paper §I):
  n_signals      — sensors per asset
  n_observations — training observations (sampling rate × window)
  n_memvec       — memory vectors retained in the MSET2 memory matrix D

``PAPER_GRID`` mirrors the sweep ranges of Figures 4-8 (powers of two, with the
MSET constraint n_memvec >= 2 * n_signals).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class MSETUseCase:
    name: str
    n_signals: int
    n_observations: int
    n_memvec: int

    def valid(self) -> bool:
        # Paper: "the number of memory vectors is at least twice the number of
        # signals required by MSET2" (Fig. 6 caption).
        return self.n_memvec >= 2 * self.n_signals


# Figure 6 axes: signals 2^5..2^10, memvec 2^7..2^13
TRAINING_GRID = {
    "n_signals": [2**k for k in range(5, 11)],
    "n_memvec": [2**k for k in range(7, 14)],
    "n_observations": [4096],
}

# Figures 7/8 axes: observations x memvec at fixed 64 / 1024 signals
SURVEILLANCE_GRID_64 = {
    "n_signals": [64],
    "n_memvec": [2**k for k in range(7, 14)],
    "n_observations": [2**k for k in range(10, 17)],
}
SURVEILLANCE_GRID_1024 = {
    "n_signals": [1024],
    "n_memvec": [2**k for k in range(11, 14)],
    "n_observations": [2**k for k in range(10, 17)],
}

# Customer archetypes from §I of the paper.
CUSTOMER_A = MSETUseCase("customer-A-small", n_signals=20, n_observations=8760, n_memvec=128)
CUSTOMER_B = MSETUseCase("customer-B-airbus-fleet", n_signals=75_000,
                         n_observations=2_592_000, n_memvec=8192)
