"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, SHAPES

_ARCH_MODULES = {
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "minitron-4b": "repro.configs.minitron_4b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "granite-20b": "repro.configs.granite_20b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "jamba-v0.1-52b": "repro.configs.jamba_52b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.SMOKE if smoke else mod.CONFIG


def all_cells() -> list[tuple[str, str]]:
    """All 40 (arch x shape) cells, including inapplicable ones (caller filters)."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
