"""Architecture + input-shape configuration system.

Every assigned architecture is an ``ArchConfig``; every benchmark input shape is a
``ShapeSpec``.  ``input_specs(cfg, shape)`` produces ``jax.ShapeDtypeStruct``
stand-ins for the dry-run (no allocation), and the same shapes drive the real
train/serve paths.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    """One architecture, fully specified (no runtime defaults hidden in model code)."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int                 # 0 => attention-free
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp_type: str = "swiglu"     # swiglu | relu2 | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    pos_emb: str = "rope"        # rope | sinusoidal | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0   # chatglm3: 0.5 ("RoPE 2d" == partial rotary)
    qk_norm: bool = False        # chameleon
    tie_embeddings: bool = False

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_period: int = 1          # MoE FFN every `period` layers (jamba: 2)
    moe_offset: int = 0          # first MoE layer index within a period (jamba: 1)
    moe_d_ff: int = 0            # per-expert hidden size
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 128

    # --- hybrid (jamba): attention layer at index `attn_offset` of every
    # `attn_period` layers; all other layers are SSM. ---
    attn_period: int = 0
    attn_offset: int = 0

    # --- encoder-decoder ---
    encdec: bool = False
    n_enc_layers: int = 0
    dec_len_fraction: int = 8     # decoder_len = seq_len // this (train/prefill)
    enc_memory_len: int = 4096    # encoder memory length for pure-decode shapes

    # --- modality frontend (stubbed per brief) ---
    frontend: str = "none"       # none | audio | vision

    # --- numerics / perf knobs ---
    dtype: str = "bfloat16"
    remat: str = "full"          # full | none  (activation checkpointing per layer)
    use_flash_kernel: bool = False   # Pallas attention on real TPU
    scan_layers: bool = True
    # unroll all internal loops (layer stack, q-chunks). Used by the dry-run cost
    # probes: XLA:CPU cost_analysis counts while-loop bodies ONCE, so accurate
    # FLOP/byte extraction needs loop-free HLO (see DESIGN.md §Dry-run).
    unroll: bool = False
    # block-causal attention: q-chunk i attends only kv[0:(i+1)*Qc] (static
    # slices, unrolled q loop) — halves attention FLOPs+bytes vs the full
    # rectangle. §Perf knob 'causal_skip'.
    causal_block_skip: bool = False
    # MoE dispatch implementation: "dense" (one-hot einsum; exact, for small token
    # counts / smoke) or "ep" (shard_map all-to-all expert parallelism).
    moe_impl: str = "dense"
    # logits softmax accumulation dtype
    softmax_dtype: str = "float32"

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---------- derived quantities ----------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm else 0

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_period:
            return i % self.attn_period == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe:
            return False
        return i % self.moe_period == self.moe_offset

    # ---------- parameter counting (for MODEL_FLOPS = 6·N·D) ----------
    def param_counts(self) -> dict[str, float]:
        """Return total and active parameter counts (floats to avoid overflow)."""
        d, V = self.d_model, self.vocab_size
        embed = d * V
        out_head = 0 if self.tie_embeddings else d * V

        def attn_params() -> float:
            q = d * self.n_heads * self.head_dim
            kv = 2 * d * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.head_dim * d
            return q + kv + o

        def dense_ffn(dff: int) -> float:
            mult = 3 if self.mlp_type == "swiglu" else 2
            return mult * d * dff

        total = float(embed + out_head)
        active = float(embed + out_head)
        n_layers = self.n_layers + (self.n_enc_layers if self.encdec else 0)
        for i in range(n_layers):
            is_enc = self.encdec and i >= self.n_layers
            li = i if not is_enc else i - self.n_layers
            layer_t = 0.0
            layer_a = 0.0
            if self.family == "ssm" or (self.attn_period and not self.is_attn_layer(li)):
                # SSD block params
                din, H, G, N = self.d_inner, self.ssm_nheads, self.ssm_ngroups, self.ssm_state
                p = d * (2 * din + 2 * G * N + H)          # in_proj (z,x,B,C,dt)
                p += self.conv_width * (din + 2 * G * N)   # conv
                p += H * 2 + din                            # A_log, D, norm
                p += din * d                                # out_proj
                layer_t += p
                layer_a += p
            else:
                layer_t += attn_params()
                layer_a += attn_params()
                if is_enc:
                    pass
                if (not is_enc) and self.encdec:
                    layer_t += attn_params()               # cross-attention
                    layer_a += attn_params()
            # FFN
            if self.is_moe_layer(li) and not is_enc:
                ep = dense_ffn(self.moe_d_ff)
                layer_t += self.n_experts * ep + d * self.n_experts
                layer_a += self.n_experts_per_tok * ep + d * self.n_experts
            elif self.family == "ssm" or (self.attn_period
                                          and not self.is_attn_layer(li)
                                          and self.d_ff == 0):
                pass                                        # pure SSM block, no FFN
            elif self.d_ff > 0:
                layer_t += dense_ffn(self.d_ff)
                layer_a += dense_ffn(self.d_ff)
            total += layer_t
            active += layer_a
        return {"total": total, "active": active}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic sequence handling => SSM/hybrid only."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins) — shared by dry-run and real paths.
# ---------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of the given step kind.

    train  -> {tokens, targets[, frames]}          (token ids / stub embeddings)
    prefill-> {tokens[, frames]}
    decode -> {tokens (B,1), pos ()}  (+ cache specs are produced by the model)
    """
    B, S = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}
    if cfg.encdec:
        dec_len = max(S // cfg.dec_len_fraction, 16)
        if shape.kind in ("train", "prefill"):
            if cfg.frontend == "audio":
                specs["frames"] = _sds((B, S, cfg.d_model), cfg.dtype)
            else:
                specs["src_tokens"] = _sds((B, S), "int32")
            specs["tokens"] = _sds((B, dec_len), "int32")
            if shape.kind == "train":
                specs["targets"] = _sds((B, dec_len), "int32")
        else:  # decode: decoder cache of length S, fixed encoder memory
            specs["enc_out"] = _sds((B, cfg.enc_memory_len, cfg.d_model), cfg.dtype)
            specs["tokens"] = _sds((B, 1), "int32")
            specs["pos"] = _sds((), "int32")
        return specs

    if shape.kind in ("train", "prefill"):
        specs["tokens"] = _sds((B, S), "int32")
        if shape.kind == "train":
            specs["targets"] = _sds((B, S), "int32")
    else:  # decode
        specs["tokens"] = _sds((B, 1), "int32")
        specs["pos"] = _sds((), "int32")
    return specs


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), D = processed tokens.

    For decode shapes D = global_batch tokens (one step). Train counts fwd+bwd (6x);
    prefill/decode count forward only (2x). Attention FLOPs are *excluded* by this
    convention (it is the 'useful model FLOPs' yardstick, per the brief).
    """
    counts = cfg.param_counts()
    n = counts["active"]
    if shape.kind == "train":
        toks = shape.tokens
        if cfg.encdec:
            toks = shape.global_batch * (
                shape.seq_len + max(shape.seq_len // cfg.dec_len_fraction, 16))
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.tokens
        if cfg.encdec:
            toks = shape.global_batch * (
                shape.seq_len + max(shape.seq_len // cfg.dec_len_fraction, 16))
        return 2.0 * n * toks
    # decode: one token per sequence; params touched = active (non-embedding lookup
    # cost dominated by matmuls) — keep the simple 2·N·B convention.
    return 2.0 * n * shape.global_batch
