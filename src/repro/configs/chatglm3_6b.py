"""chatglm3-6b — GQA kv=2, partial ("2d") RoPE. [arXiv:2406.12793; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    mlp_type="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_fraction=0.5,
)

SMOKE = CONFIG.replace(
    name="chatglm3-6b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=224, vocab_size=512,
)
