"""granite-moe-3b-a800m — MoE 40 experts top-8, d_ff/expert=512.

[hf:ibm-granite/granite-3.0-*; spec field "MoE 40e top-8" followed — see DESIGN.md]
40 experts are padded to 48 for expert-parallel sharding over 16 model shards.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=49155,
    mlp_type="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    moe=True,
    n_experts=40,
    n_experts_per_tok=8,
    moe_d_ff=512,
)

SMOKE = CONFIG.replace(
    name="granite-moe-3b-a800m-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    vocab_size=512, n_experts=8, n_experts_per_tok=2, moe_d_ff=64,
)
