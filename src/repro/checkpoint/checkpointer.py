"""Fault-tolerant checkpointing.

* Atomic: write to ``step_XXXX.tmp/`` then ``os.rename`` (crash-safe).
* Layout: one ``.npy`` per leaf + a JSON manifest (pytree structure, shapes,
  dtypes, step, config fingerprint). Arrays are saved in HOST layout
  (fully-replicated values), so a checkpoint taken on N devices restores onto M
  devices — this is the elasticity path (tested 1 -> 8 fake devices).
* Async: ``save_async`` snapshots to host then writes on a worker thread.
* Keep-N GC + latest-step resume + corrupted-checkpoint fallback.

On a real multi-host pod each host would write only its addressable shards; the
manifest format already records per-leaf paths, so swapping the writer for a
per-shard one is local to ``_write_leaf``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # ----------------------------- save ---------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now

        def work():
            self._write(step, host_tree, extra or {})

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree, extra: dict):
        with self._lock:
            final = os.path.join(self.directory, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat, treedef = _leaf_paths(host_tree)
            manifest = {
                "step": step,
                "n_leaves": len(flat),
                "leaf_shapes": [list(np.shape(l)) for l in flat],
                "extra": extra,
            }
            for i, leaf in enumerate(flat):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ----------------------------- load ---------------------------------
    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int, dict]:
        """Restore into the structure of `tree_like`. If `shardings` (same-
        structure NamedSharding tree) is given, leaves are placed sharded —
        works for ANY device count (elastic restart)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = jax.tree.flatten(tree_like)
        assert manifest["n_leaves"] == len(flat_like), \
            f"checkpoint has {manifest['n_leaves']} leaves, model expects {len(flat_like)}"
        leaves = [np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
                  for i in range(len(flat_like))]
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
                tree, shardings)
        return tree, step, manifest.get("extra", {})

    def restore_latest_valid(self, tree_like: Any, shardings: Any = None):
        """Walk checkpoints newest-first, skipping corrupted ones."""
        for step in reversed(self.all_steps()):
            try:
                return self.restore(tree_like, step, shardings)
            except Exception:
                continue
        raise FileNotFoundError(f"no valid checkpoint in {self.directory}")
