"""Closed-loop experiment cases: a live workload plus a scheduled mid-trace
world change the controller must detect and recover from.

The simulation *is* the world here: the controller only sees telemetry, so a
drift case injects its degradation by swapping a service-degraded fleet into
the running :class:`~repro.fleet.simulator.SegmentedSimulation` at a scheduled
bin (``SegmentedSimulation.swap(fleet=...)``) — exactly the silently-decaying
node the paper's prognostic engine watches for, landing mid-trace under the
incumbent policy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.fleet.simulator import FleetConfig
from repro.fleet.telemetry.drift import degrade_fleet
from repro.fleet.workload import Trace, Workload


def tail_workload(wl: Workload, t0: int) -> Workload:
    """The workload's remaining bins ``[t0, T)`` — what a drift response
    re-tunes against (the past is sunk; only the rest of the trace is
    actionable)."""
    if not 0 <= t0 < wl.n_bins:
        raise ValueError(f"bad tail start {t0} for {wl.n_bins} bins")
    traces = tuple(Trace(tr.name, tr.dt_s, tr.rate[t0:],
                         tr.arrivals[:, t0:]) for tr in wl.traces)
    return Workload(wl.name, wl.classes, traces)


@dataclass(frozen=True)
class DriftCase:
    """One closed-loop experiment: the live trace, the nominal fleet the
    incumbent was scoped for, and the scheduled world-side fleet swaps
    (``{t_bin: degraded FleetConfig}``) the controller must survive."""
    workload: Workload
    fleet: FleetConfig               # nominal (pre-drift) fleet
    inject: dict = field(default_factory=dict)
    description: str = ""

    @property
    def n_bins(self) -> int:
        return self.workload.n_bins

    def drift_bins(self) -> list:
        return sorted(self.inject)


def service_degradation_case(workload, fleet: FleetConfig, *,
                             factor: float = 1.5,
                             t_drift: int = None,
                             t_drift_frac: float = 0.5,
                             slo_s: float = None) -> DriftCase:
    """The canonical injected-drift case: at ``t_drift`` (default: halfway
    through the trace) every pool's service times inflate by ``factor`` —
    same hardware, same prices, silently slower — and stay degraded to the
    end. ``factor <= 1`` is rejected: that is not a degradation."""
    if isinstance(workload, Trace):
        if slo_s is None:
            raise ValueError("a bare Trace needs slo_s for its request class")
        workload = Workload.from_trace(workload, float(slo_s))
    if factor <= 1.0:
        raise ValueError(f"degradation factor must be > 1, got {factor}")
    T = workload.n_bins
    t = int(round(T * t_drift_frac)) if t_drift is None else int(t_drift)
    if not 0 < t < T:
        raise ValueError(f"drift bin {t} must lie strictly inside (0, {T})")
    return DriftCase(
        workload=workload, fleet=fleet,
        inject={t: degrade_fleet(fleet, factor)},
        description=f"service x{factor:g} degradation at bin {t}/{T}")
