"""Closed-loop autonomous control: drift-triggered re-scope, warm re-tune,
and mid-trace policy hot-swap over one continuous simulated trace."""
from repro.fleet.control.loop import (ClosedLoopController, ControlEvent,
                                      ControlResult)
from repro.fleet.control.scenario import (DriftCase,
                                          service_degradation_case,
                                          tail_workload)

__all__ = [
    "ClosedLoopController", "ControlEvent", "ControlResult", "DriftCase",
    "service_degradation_case", "tail_workload",
]
