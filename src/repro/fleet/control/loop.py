"""Closed-loop autonomous control: observe -> decide -> act, mid-trace.

This closes the ROADMAP loop the drift probe left open. A
:class:`ClosedLoopController` runs one continuous fleet trace in segments
(:class:`~repro.fleet.simulator.SegmentedSimulation`, full queue/in-flight
state carried across boundaries) and after each segment feeds the observed
telemetry window (per-bin service time, utilization, queue depth) into a
:class:`~repro.fleet.telemetry.DriftProbe`. When the probe alarms it:

1. estimates the service degradation factor from the observed service-time
   stream against the fitted baseline,
2. **re-scopes**: re-runs the analytic shape recommendation
   (``repro.core.recommender.recommend``) with every roofline term inflated
   by the estimate — validating whether the deployed shape is still the
   right one under the degraded service model (hardware is never exchanged
   mid-trace: billing pins pool identity and prices, so a shape downgrade
   is advice for the next deploy, recorded in the result),
3. **consults the scoping oracle** (when one is attached): featurizes the
   *remaining* workload, inflates the rate axis by the degradation estimate
   (a fleet serving f-times slower is scoped as f-times the traffic), and
   looks the regime up in the precompiled :class:`ScopingOracle` table — a
   microsecond answer. A hit is confirmed with one cheap paired evaluation
   (active config vs interpolated answer vs nearest-cell winner on the
   degraded tail) before swapping; only a *miss* (query outside the gridded
   region) falls through to the expensive path:
4. **re-tunes**: a budgeted warm-started ``tune()`` over the remaining
   workload under the degraded service model, seeded from the incumbent
   ``TuningReport``'s surviving region (``warm_start_candidates``) on the
   compiled backend, with the incumbent config as the racing baseline,
5. **acts**: if the chosen winner beats the incumbent on the degraded
   tail, hot-swaps the winning policy at the next segment boundary
   (``SegmentedSimulation.swap``) — the finished trace is still one
   continuous run — then re-fits the probe on the model-predicted post-swap
   telemetry and holds a cooldown before checking again.

The simulation is the world: the controller sees only telemetry, and drift
cases (:mod:`repro.fleet.control.scenario`) inject degradation by swapping a
service-degraded fleet into the live run at a scheduled bin.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.cost_model import RooflineTerms
from repro.core.recommender import recommend
from repro.fleet import telemetry
from repro.fleet.control.scenario import DriftCase, tail_workload
from repro.fleet.simulator import SegmentedSimulation, SimResult
from repro.fleet.telemetry.drift import (DriftProbe, degrade_fleet,
                                         telemetry_matrix)
from repro.fleet.tuning.evaluate import (Objective, TuningScenario,
                                         evaluate_candidates)
from repro.fleet.tuning.tuner import TuningBudget, tune
from repro.fleet.workload import Trace, Workload

_MIN_RETUNE_BINS = 4        # no point re-tuning with nothing left to run
_MAX_CONSULT_CANDIDATES = 5  # active + interp + top corner winners; keeps
                             # an oracle consult well under a re-tune's cost


@dataclass(frozen=True)
class ControlEvent:
    """One timeline entry of a closed-loop run."""
    t_bin: int
    kind: str               # world-change | drift-alarm | rescope |
    #                         oracle-hit | oracle-miss | retune | swap
    detail: dict = field(default_factory=dict)

    def line(self) -> str:
        parts = ", ".join(f"{k}={v}" for k, v in self.detail.items())
        return f"bin {self.t_bin:>5}: {self.kind:<12} {parts}"


@dataclass
class ControlResult:
    """Outcome of one closed-loop run: the single continuous trace plus the
    controller's decision record."""
    sim: SimResult
    events: list
    n_alarms: int
    n_swaps: int
    incumbent_params: dict
    active_params: dict      # params serving at end of trace
    est_factor: float        # last degradation estimate (1.0: never alarmed)
    retunes: tuple = ()      # TuningReport per drift response
    rescopes: tuple = ()     # Recommendation per drift response
    oracle_hits: int = 0     # drift responses answered by the oracle
    oracle_misses: int = 0   # oracle refusals that fell back to re-tune
    oracle_answers: tuple = ()   # OracleAnswer per consultation

    @property
    def swapped(self) -> bool:
        return self.n_swaps > 0

    def timeline(self) -> str:
        return "\n".join(e.line() for e in self.events) or "(quiet run)"


class ClosedLoopController:
    """Drift-triggered re-scope + warm re-tune + mid-trace policy hot-swap.

    ``scenario`` is the tuning recipe the incumbent came from (policy
    family, context rows/constraint for re-scoping, Monte Carlo workload
    for re-tuning, backend); ``incumbent`` is its ``TuningReport`` — the
    currently-deployed config and the warm-start seed for drift responses.

    ``segment_bins`` sets the control cadence (the probe needs at least its
    ``min_alarm_bins`` per window to alarm); ``cooldown_segments`` holds
    checks after a response while the re-fitted envelope settles;
    ``min_improvement`` is the score margin ($/hr-equivalent) a re-tuned
    winner must clear before the controller swaps it in. Scheduling
    discipline is pinned for the whole trace (serve-order tables are
    per-run static), so a ``discipline`` dim in a re-tuned winner is
    ignored at swap time.

    ``oracle`` (optional) is a :class:`~repro.fleet.oracle.ScopingOracle`
    (or a bare :class:`~repro.fleet.oracle.OracleTable`) consulted *before*
    re-tuning on every drift alarm: a hit replaces the warm re-tune's
    simulation budget with one paired three-candidate evaluation, a miss
    (refusal) falls back to the re-tune unchanged.
    """

    def __init__(self, scenario: TuningScenario, incumbent, *,
                 probe: DriftProbe = None, segment_bins: int = 45,
                 cooldown_segments: int = 1,
                 retune_budget: TuningBudget = None,
                 objective: Objective = None,
                 min_improvement: float = 0.0, retune_seed: int = 1,
                 retune_jitter: float = 0.35, oracle=None):
        if int(segment_bins) < 1:
            raise ValueError("segment_bins must be >= 1")
        self.scenario = scenario
        self.incumbent = incumbent
        self.incumbent_params = dict(incumbent.winner.params)
        self._probe0 = probe if probe is not None else DriftProbe()
        self.segment_bins = int(segment_bins)
        self.cooldown_segments = int(cooldown_segments)
        self.retune_budget = retune_budget or TuningBudget(n_candidates=12,
                                                           init_seeds=2)
        self.objective = objective or incumbent.objective
        self.min_improvement = float(min_improvement)
        self.retune_seed = int(retune_seed)
        # wider than tune()'s default: a drift response must be able to
        # leave the incumbent's neighborhood (the degraded world may need
        # several times the nominal fleet), while the anchors still keep
        # the incumbent region covered
        self.retune_jitter = float(retune_jitter)
        if oracle is not None and not hasattr(oracle, "query"):
            from repro.fleet.oracle import ScopingOracle
            oracle = ScopingOracle(oracle)
        self.oracle = oracle

    # ---- observe/decide helpers --------------------------------------------

    def _fresh_probe(self) -> DriftProbe:
        return replace(self._probe0, model=None, sigma=None, mu=None)

    def _capacity_ratio(self, observed, reference, t0: int, t1: int,
                        ref_off: int) -> float:
        """Degradation estimate from busy-time efficiency — units served per
        replica-busy-second — observed window over the reference's matching
        bins. Sojourn-based estimates saturate once a backlog forms
        (queueing delay swamps service time and pegs any ratio at its
        clip); serving efficiency stays intrinsic to the node even when
        the fleet is drowning. The reference is the *current* model's
        predicted telemetry, so the absolute degradation estimate compounds
        this ratio onto the factor already modeled — an over-estimate
        self-corrects at the next alarm instead of resetting to nominal."""
        def eff(res, a, b):
            served = np.asarray(res.served, float)[:, a:b].sum()
            busy = (np.asarray(res.utilization, float)
                    * np.asarray(res.replicas, float))[:, a:b].sum()
            return served / busy if busy > 0 else 0.0
        e_obs = eff(observed, t0, t1)
        e_ref = eff(reference, t0 - ref_off, t1 - ref_off)
        if e_obs <= 0 or e_ref <= 0:
            return 1.0
        return float(np.clip(e_ref / e_obs, 0.1, 10.0))

    def _rescope(self, factor: float):
        """Re-run the analytic shape recommendation with every roofline term
        inflated by the degradation estimate. ``None`` when the scenario
        context carries no scoping rows."""
        rows = self.scenario.context.get("rows")
        constraint = self.scenario.context.get("constraint")
        if not rows or constraint is None:
            return None
        inflated = [
            replace(r, terms=RooflineTerms(r.terms.t_compute * factor,
                                           r.terms.t_memory * factor,
                                           r.terms.t_collective * factor))
            if r.terms is not None else r for r in rows]
        rec = recommend(inflated, constraint)
        telemetry.event("control_rescope", factor=factor,
                        shape=rec.shape.name if rec.shape else None,
                        feasible=rec.shape is not None)
        return rec

    def _tail_scenario(self, t1: int, factor: float) -> TuningScenario:
        scen = self.scenario
        return TuningScenario(
            name=f"{scen.name}/retune@{t1}",
            workload=tail_workload(scen.workload, t1),
            fleet=degrade_fleet(scen.fleet, factor),
            policy_cls=scen.policy_cls, context=scen.context,
            discipline=scen.discipline, max_queue=scen.max_queue,
            cold_start_seed=scen.cold_start_seed,
            build_policy=scen.build_policy, backend=scen.backend,
            n_substeps=scen.n_substeps, preemptive=scen.preemptive)

    def _retune(self, t1: int, factor: float, warm_report, active: dict,
                round_i: int):
        """Budgeted warm re-tune over the remaining workload under the
        degraded service model; the active config races as the baseline."""
        tail_scen = self._tail_scenario(t1, factor)
        report = tune(tail_scen, warm_report.space, self.objective,
                      self.retune_budget, seed=self.retune_seed + round_i,
                      warm_start=warm_report, warm_jitter=self.retune_jitter,
                      baseline=dict(active))
        inc, win = report.baseline.mean_score(), report.winner.mean_score()
        improved = (win < inc - self.min_improvement
                    and report.winner.params != active)
        return report, improved

    def _consult_oracle(self, t1: int, factor: float, workload,
                        active: dict):
        """Oracle-first drift response: featurize the remaining workload
        inflated by the degradation estimate, look it up, and on a hit
        confirm with ONE paired evaluation on the degraded tail — the
        active config, the oracle's interpolated answer, and the verbatim
        winners of the contributing grid corners, strongest weight first
        (interpolating autoscaler gains between corners can land between
        two basins; the corner winners are the sweep's actually-validated
        configs, and under a shape mismatch a lower-weight corner often
        generalizes where the nearest one does not). A handful of
        candidates instead of a re-tune's dozens, and the never-worse
        guarantee survives: the active config races in the same paired
        draws, so an oracle config only ships if it measurably wins there.
        Returns (answer, winning params or None, replicates spent)."""
        tail = tail_workload(workload, t1)
        ans = self.oracle.query(tail, rate_factor=factor)
        if not ans.ok:
            return ans, None, 0
        cands = [dict(active), dict(ans.params)]
        ranked = sorted(zip(ans.corner_weights, ans.corner_idx),
                        key=lambda t: -t[0])
        for _, ci in ranked:
            cell = self.oracle.table.cells.get(ci)
            if cell is None:
                continue
            p = dict(cell.winner)
            if p not in cands:
                cands.append(p)
            if len(cands) >= _MAX_CONSULT_CANDIDATES:
                break
        evs = evaluate_candidates(self._tail_scenario(t1, factor), cands,
                                  self.objective)
        best = min(range(1, len(evs)), key=lambda i: evs[i].mean_score())
        improved = (evs[best].mean_score()
                    < evs[0].mean_score() - self.min_improvement
                    and cands[best] != cands[0])
        sims = len(cands) * evs[0].n_seeds
        return ans, (cands[best] if improved else None), sims

    def _reference_run(self, workload, fleet, params: dict,
                       discipline) -> SimResult:
        """Model-predicted telemetry: the probe's baseline must come from the
        same segmented engine as the live run (the coarse core defines
        utilization differently, which would read as instant drift)."""
        scen = self.scenario
        sim = SegmentedSimulation(
            workload, fleet, scen.make_policy(params),
            discipline=discipline, max_queue=scen.max_queue,
            cold_start_seed=scen.cold_start_seed,
            n_substeps=scen.n_substeps, preemptive=scen.preemptive)
        return sim.run_until(sim.n_bins).result()

    # ---- the loop ----------------------------------------------------------

    def run(self, case: DriftCase = None, *, workload=None,
            inject: dict = None) -> ControlResult:
        """Run one closed-loop trace. Pass a :class:`DriftCase` (live
        workload + scheduled world-side fleet swaps), or ``workload`` with an
        optional ``inject`` map ``{t_bin: FleetConfig | factor}`` (float
        factors degrade the nominal fleet). Defaults to the tuning
        scenario's own workload on the nominal fleet — a quiet run the
        controller should ride out without a single alarm."""
        scen = self.scenario
        if case is not None:
            if workload is not None or inject is not None:
                raise ValueError("pass a DriftCase or workload/inject, "
                                 "not both")
            workload, inject, fleet0 = (case.workload, dict(case.inject),
                                        case.fleet)
        else:
            workload = scen.workload if workload is None else workload
            inject = dict(inject or {})
            _, _, fleet0 = scen.split_params(self.incumbent_params)
        if isinstance(workload, Trace):
            workload = Workload.from_trace(workload,
                                           float(scen.context["slo_s"]))
        if workload.n_bins != scen.workload.n_bins:
            raise ValueError(
                f"live workload has {workload.n_bins} bins but the tuning "
                f"scenario has {scen.workload.n_bins}; re-tune windows "
                "must align bin-for-bin")
        inject = {int(t): (degrade_fleet(fleet0, float(f))
                           if isinstance(f, (int, float)) else f)
                  for t, f in inject.items()}
        _, discipline, _ = scen.split_params(self.incumbent_params)

        sim = SegmentedSimulation(
            workload, fleet0, scen.make_policy(self.incumbent_params),
            discipline=discipline, max_queue=scen.max_queue,
            cold_start_seed=scen.cold_start_seed,
            n_substeps=scen.n_substeps, preemptive=scen.preemptive)
        T = sim.n_bins

        probe = self._fresh_probe()
        base = self._reference_run(workload, fleet0, self.incumbent_params,
                                   discipline)
        probe.fit(base)
        ref_res, ref_off = base, 0

        events, retunes, rescopes = [], [], []
        oracle_answers = []
        n_alarms = n_swaps = cooldown = 0
        oracle_hits = oracle_misses = 0
        est_factor = 1.0        # degradation the controller currently models
        warm_report = self.incumbent
        active = dict(self.incumbent_params)

        with telemetry.span("control.run", scenario=scen.name, n_bins=T):
            t = 0
            while t < T:
                t1 = min(t + self.segment_bins, T)
                for tb in sorted(inject):
                    if t < tb < t1:
                        t1 = tb        # land world changes exactly on a
                        break          # boundary; the controller can't see
                #                        this, only its telemetry
                with telemetry.span("control.segment", t0=t, t1=t1):
                    sim.run_until(t1)
                if t1 in inject:
                    sim.swap(fleet=inject.pop(t1))
                    events.append(ControlEvent(t1, "world-change", {}))
                part = sim.partial_result()
                window = telemetry_matrix(part, probe.signals)[t:t1]
                if cooldown > 0:
                    cooldown -= 1
                    t = t1
                    continue
                rep = probe.check(window)
                if not rep.drifted:
                    t = t1
                    continue

                n_alarms += 1
                telemetry.counter("fleet_control_alarms_total")
                ratio = self._capacity_ratio(part, ref_res, t, t1, ref_off)
                est_factor = max(est_factor * ratio, 1.0)
                events.append(ControlEvent(t1, "drift-alarm", {
                    "alarm_bins": rep.alarm_bins, "n_bins": rep.n_bins,
                    "est_factor": round(est_factor, 3)}))
                rec = self._rescope(est_factor)
                if rec is not None:
                    rescopes.append(rec)
                    events.append(ControlEvent(t1, "rescope", {
                        "shape": rec.shape.name if rec.shape else None,
                        "feasible": rec.shape is not None}))
                if T - t1 < _MIN_RETUNE_BINS:
                    t = t1
                    continue
                new_params, report = None, None
                answered = False
                if self.oracle is not None:
                    with telemetry.span("control.oracle", t_bin=t1,
                                        factor=est_factor):
                        ans, chosen, eval_sims = self._consult_oracle(
                            t1, est_factor, workload, active)
                    oracle_answers.append(ans)
                    if ans.ok:
                        oracle_hits += 1
                        answered = True
                        telemetry.counter("fleet_control_oracle_hits_total")
                        events.append(ControlEvent(t1, "oracle-hit", {
                            "params": dict(ans.params),
                            "cell": ans.cell_idx,
                            "latency_us": round(ans.latency_us, 1),
                            "eval_sims": eval_sims,
                            "improved": chosen is not None}))
                        if chosen is not None:
                            new_params = dict(chosen)
                    else:
                        oracle_misses += 1
                        telemetry.counter(
                            "fleet_control_oracle_misses_total")
                        events.append(ControlEvent(t1, "oracle-miss", {
                            "reason": ans.reason}))
                if not answered:
                    with telemetry.span("control.retune", t_bin=t1,
                                        factor=est_factor):
                        report, improved = self._retune(
                            t1, est_factor, warm_report, active,
                            len(retunes))
                    retunes.append(report)
                    events.append(ControlEvent(t1, "retune", {
                        "winner": report.winner.params,
                        "incumbent_score":
                            round(report.baseline.mean_score(), 3),
                        "winner_score":
                            round(report.winner.mean_score(), 3),
                        "sims": report.sims_used}))
                    if improved:
                        new_params = dict(report.winner.params)
                if new_params is not None:
                    sim.swap(policy=scen.make_policy(new_params))
                    active = new_params
                    if report is not None:
                        warm_report = report
                    n_swaps += 1
                    telemetry.counter("fleet_control_swaps_total")
                    events.append(ControlEvent(t1, "swap",
                                               {"params": active}))
                # re-baseline the envelope on model-predicted telemetry for
                # the (possibly swapped) config under the estimated
                # degradation, then hold a cooldown while it settles
                ref = self._reference_run(
                    tail_workload(workload, t1),
                    degrade_fleet(fleet0, est_factor), active, discipline)
                probe = self._fresh_probe().fit(ref)
                ref_res, ref_off = ref, t1
                cooldown = self.cooldown_segments
                t = t1

        return ControlResult(
            sim=sim.result(), events=events, n_alarms=n_alarms,
            n_swaps=n_swaps, incumbent_params=dict(self.incumbent_params),
            active_params=active, est_factor=est_factor,
            retunes=tuple(retunes), rescopes=tuple(rescopes),
            oracle_hits=oracle_hits, oracle_misses=oracle_misses,
            oracle_answers=tuple(oracle_answers))
