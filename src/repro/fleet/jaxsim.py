"""Compiled fleet-simulator backend: ``lax.scan`` over time bins, ``vmap``
over Monte Carlo seeds, ``vmap`` over candidate configs.

The numpy simulator (``repro.fleet.simulator``) is the reference
implementation; its inner loop is a Python ``for t in range(T)`` with a
data-dependent cohort pour per bin, so a tuning round pays Python dispatch
``n_candidates x n_bins`` times. This module re-expresses the per-bin update
as a pure function of fixed-shape arrays and compiles the whole
(candidate, seed, bin) lattice into one XLA program:

* **time** is a ``lax.scan`` whose carry is the queue/fleet state
  (per-class cumulative admitted+served curves, ready/cold-starting replicas,
  the pending-launch ledger, policy-kernel state);
* **the cohort pour** becomes a binary search: cohort service order is a
  static permutation of (class, arrival-bin) cohorts
  (``discipline.cohort_tables``), so "pour ``amount`` in key order" is
  "find the minimal global-order prefix whose admitted mass covers
  ``amount``" — ~log2(C*T) fixed iterations instead of a while loop;
* **scale-down cancellation** (newest pending launches first) becomes a
  reverse-cumsum water-fill over the pending-launch window;
* **the policy** runs as a functional kernel (``repro.fleet.kernels``), its
  tunable knobs passed as arrays — which is what lets a whole racing round
  (every candidate x every seed) batch into ONE jitted call.

Everything runs in float64 via a scoped ``enable_x64`` so the compiled path
agrees with the numpy reference to float rounding; candidate batches are
padded to power-of-two sizes so racing's shrinking rounds reuse a handful of
compiled programs instead of recompiling per round.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.fleet import telemetry

_EPS = 1e-12


def available() -> bool:
    """True when jax is importable (the compiled backend can run)."""
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


# One compiled core per (kernel, static-shape) signature; kernels are cached
# by config (kernels._KERNEL_CACHE), so repeated rounds of one tuning run —
# and repeated simulations of one scenario — all hit the same entry.
_CORE_CACHE: dict = {}

# (core id, padded shape signature) pairs that have dispatched at least once:
# a first dispatch pays XLA compilation (cold), repeats are pure dispatch
# (warm) — the classifier behind the compile-vs-dispatch timing split.
_DISPATCHED: set = set()

# Persistent (on-disk) XLA compilation cache bookkeeping: disk hit/miss
# tallies fed by jax's monitoring events, and the wired cache directory.
_PCACHE = {"hits": 0, "misses": 0, "dir": None, "listener": False}

_PCACHE_EVENTS = {"/jax/compilation_cache/cache_hits": "hit",
                  "/jax/compilation_cache/cache_misses": "miss"}


def _pcache_event(event: str, **kw) -> None:
    result = _PCACHE_EVENTS.get(event)
    if result is None:
        return
    _PCACHE["hits" if result == "hit" else "misses"] += 1
    telemetry.counter("jaxsim_compile_cache_disk_total", result=result)


def enable_persistent_compile_cache(path: str) -> None:
    """Wire JAX's on-disk compilation cache through the compiled backend, so
    repeated tuner rounds, oracle builds and CI runs stop re-paying XLA
    compilation across *processes*: a cold dispatch whose program was
    compiled by any earlier run deserializes the executable from ``path``
    instead of recompiling. The size/compile-time admission floors are
    dropped (every program persists — fleet cores are small but each costs
    seconds of XLA time), and a monitoring listener feeds disk hit/miss
    tallies to ``persistent_cache_stats()`` plus the
    ``jaxsim_compile_cache_disk_total`` telemetry counter (a no-op unless a
    telemetry session is active, so the wiring stays bit-exact)."""
    import jax
    for opt, val in (("jax_compilation_cache_dir", str(path)),
                     ("jax_persistent_cache_min_entry_size_bytes", -1),
                     ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(opt, val)
        except Exception:   # older jax without the admission knobs
            pass
    if not _PCACHE["listener"]:
        try:
            jax.monitoring.register_event_listener(_pcache_event)
            _PCACHE["listener"] = True
        except Exception:
            pass
    # jax latches "cache in use?" per process at the FIRST compilation
    # (compilation_cache._cache_checked); enabling after any jit has run
    # would silently do nothing without a reset back to pristine state.
    try:
        from jax._src.compilation_cache import reset_cache
        reset_cache()
    except Exception:
        pass
    _PCACHE["dir"] = str(path)


def disable_persistent_compile_cache() -> None:
    """Unwire the on-disk compilation cache and restore jax's stock admission
    floors, returning the process to its pristine no-cache state. Tests that
    enable the cache against a temporary directory must call this afterwards:
    the cache config is process-global, and leaving every later jit in the
    process serializing through a (possibly reaped) tmp dir is both slow and
    unsafe. Hit/miss tallies are preserved — they are per-process history."""
    import jax
    for opt, val in (("jax_compilation_cache_dir", None),
                     ("jax_persistent_cache_min_entry_size_bytes", 0),
                     ("jax_persistent_cache_min_compile_time_secs", 1.0)):
        try:
            jax.config.update(opt, val)
        except Exception:
            pass
    try:
        from jax._src.compilation_cache import reset_cache
        reset_cache()
    except Exception:
        pass
    _PCACHE["dir"] = None


def persistent_cache_stats() -> dict:
    """Disk-cache tallies since process start: ``{hits, misses, dir}``
    (``dir`` is None until a cache is wired)."""
    return {"hits": int(_PCACHE["hits"]), "misses": int(_PCACHE["misses"]),
            "dir": _PCACHE["dir"]}


def clear_compiled() -> list:
    """Evict every compiled core and jit executable (``jax.clear_caches``),
    so the next dispatch recompiles — through the persistent on-disk cache
    when one is wired, which is how a warm-cache rebuild is measured.
    Returns the evicted core callables: a caller timing a cold rebuild must
    hold these references until it is done, otherwise a newly built core can
    reuse a freed core's ``id()`` and masquerade as already-dispatched in
    the cold/warm classifier."""
    evicted = list(_CORE_CACHE.values())
    _CORE_CACHE.clear()
    _DISPATCHED.clear()
    try:
        import jax
        jax.clear_caches()
    except Exception:
        pass
    return evicted


def _build_core(kernel, *, T, C, P, Tpad, W, dt, order, t_fixed, t_unit,
                max_b, max_queue, n_substeps=1, preemptive=False, tput=()):
    import jax
    import jax.numpy as jnp
    from jax import lax

    CT = C * T
    n_rank_iters = max(int(np.ceil(np.log2(CT + 1))), 1)
    arange_c = jnp.arange(C)

    def serve(Acum, done, amt, cnt, cls_rank):
        """Pour ``amt`` into cohorts in global key order: binary-search the
        minimal prefix rank whose admitted mass covers ``amt``, serve every
        cohort below it fully and the marginal cohort partially. ``Acum`` is
        the (C, T+1) cumulative-admitted curve (leading zero), ``done`` the
        (C,) served totals; returns the (C,) per-class split."""
        def take(r):
            j = cnt[:, r]                       # class-c cohorts in prefix r
            a = jnp.take_along_axis(Acum, j[:, None], axis=1)[:, 0]
            return jnp.clip(a - done, 0.0, None)

        full = take(CT)
        amt = jnp.minimum(jnp.maximum(amt, 0.0), full.sum())

        def bisect(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            ge = take(mid).sum() >= amt
            return (jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi))

        lo, _ = lax.fori_loop(0, n_rank_iters, bisect,
                              (jnp.int32(0), jnp.int32(CT)))
        rm1 = jnp.maximum(lo - 1, 0)
        base = take(rm1)
        marginal = cls_rank[rm1]
        served = base + jnp.maximum(amt - base.sum(), 0.0) \
            * (arange_c == marginal)
        return jnp.where(lo > 0, served, jnp.zeros(C))

    def sim_one(arr, rate, rate_sum, jb, cnt, cls_rank, drop_rank, key_rank,
                kp, min_rep, max_rep, init_ready):
        """One (candidate, seed) trajectory. arr (T, C) float arrivals;
        rate (T, C) / rate_sum (T,) are the per-class and aggregate arrival
        rates divided by dt on the HOST — XLA rewrites division by a
        constant into an inexact reciprocal multiply, which would shift
        rates by an ulp and flip policy ceil()s vs the numpy reference;
        jb (T, P) int launch-landing offsets; tables/params per candidate.
        ``key_rank`` feeds only the substep core (``sim_one_fine``)."""
        col = jnp.arange(T + 1)

        def step(carry, x):
            ready, in_flight, pend, done, Acum, pstate = carry
            arr_c, rate_c, rate_sum, jb_t, t = x
            matured = pend[t]
            ready = ready + matured
            in_flight = in_flight - matured

            total_prev = Acum[:, T]
            drop = jnp.zeros(C)
            if max_queue is not None:
                over = jnp.maximum((total_prev - done).sum() + arr_c.sum()
                                   - max_queue, 0.0)
                order_t = drop_rank[t]
                for rank in range(C):
                    c = order_t[rank]
                    d = jnp.minimum(arr_c[c], over)
                    drop = drop.at[c].add(d)
                    over = over - d
            adm_c = arr_c - drop
            new_total = total_prev + adm_c
            Acum = jnp.where(col[None, :] >= t + 1, new_total[:, None], Acum)

            remaining = (new_total - done).sum()
            capacity = 0.0
            slot_split, slot_bt, slot_served = [], [], []
            for p in order:                       # static drain order
                n = jnp.maximum(ready[p], 0.0)
                has = n > 0
                b = jnp.clip(jnp.where(
                    has, jnp.ceil(remaining / jnp.where(has, n, 1.0)), 0.0),
                    1.0, max_b[p])
                bt = jnp.maximum(t_fixed[p] + b * t_unit[p], _EPS)
                cap = jnp.where(has, n * b / bt, 0.0) * dt
                split = serve(Acum, done, jnp.minimum(remaining, cap),
                              cnt, cls_rank)
                done = done + split
                s_p = split.sum()
                remaining = remaining - s_p
                capacity = capacity + cap
                slot_split.append(split)
                slot_bt.append(bt)
                slot_served.append(s_p)

            # fold sub-eps float residue of a drained class into "empty" —
            # the numpy pour's _MASS_EPS behaviour; without it a ~1e-11
            # leftover queue can flip a policy ceil() on the next bin
            done = jnp.where(new_total - done <= 1e-9 + 1e-12 * new_total,
                             new_total, done)
            queue_c = jnp.maximum(new_total - done, 0.0)
            served = sum(slot_served)
            util = jnp.where(capacity > 0, served / capacity, 0.0)
            from repro.fleet.kernels import KernelObs
            obs = KernelObs(
                t_s=(t + 1) * dt, dt_s=dt, arrival_rate=rate_sum,
                queue=queue_c.sum(), replicas=ready.sum(),
                in_flight=in_flight.sum(), utilization=util,
                pool_replicas=ready, pool_in_flight=in_flight,
                class_queue=queue_c, class_arrival_rate=rate_c,
                min_replicas=min_rep, max_replicas=max_rep)
            pool_rep = ready                      # pre-decision (serving) fleet
            pstate, target = kernel.step(kp, pstate, obs)
            target = jnp.clip(target, min_rep, max_rep)

            # scale down: cancel pending launches newest-first (reverse
            # water-fill over the cold-start window), then shrink ready
            excess = jnp.maximum(ready + in_flight - target, 0.0)
            zero = jnp.int32(0)
            window = lax.dynamic_slice(pend, (t + 1, zero), (W, P))
            newer = jnp.cumsum(window[::-1, :], axis=0)[::-1, :] - window
            cut = jnp.clip(excess[None, :] - newer, 0.0, window)
            window = window - cut
            canceled = cut.sum(axis=0)
            pend = lax.dynamic_update_slice(pend, window, (t + 1, zero))
            in_flight = in_flight - canceled
            ready = jnp.maximum(ready - (excess - canceled), 0.0)
            grow = jnp.maximum(target - ready - in_flight, 0.0)
            pend = pend.at[t + 1 + jb_t, jnp.arange(P)].add(grow)
            in_flight = in_flight + grow
            billed = pool_rep + in_flight

            ys = {"slot_split": jnp.stack(slot_split),    # (P, C) rank order
                  "slot_bt": jnp.stack(slot_bt),          # (P,)
                  "slot_served": jnp.stack(slot_served),  # (P,)
                  "admitted_c": adm_c, "dropped_c": drop,
                  "queue_c": queue_c, "pool_rep": pool_rep,
                  "billed": billed, "util": util}
            return (ready, in_flight, pend, done, Acum, pstate), ys

        carry0 = (init_ready, jnp.zeros(P), jnp.zeros((Tpad, P)),
                  jnp.zeros(C), jnp.zeros((C, T + 1)), kernel.init())
        xs = (arr, rate, rate_sum, jb, jnp.arange(T, dtype=jnp.int32))
        _, ys = lax.scan(step, carry0, xs)
        return ys

    n_sub = int(n_substeps)
    dt_sub = dt / n_sub                     # host float, matches numpy

    def sim_one_fine(arr, rate, rate_sum, jb, cnt, cls_rank, drop_rank,
                     key_rank, kp, min_rep, max_rep, init_ready):
        """The substep (fine-Δt, checkpoint-resume, optionally preemptive)
        trajectory — the compiled twin of the numpy
        ``_simulate_fleet_substep`` engine. Substeps are unrolled inside the
        scan step (``n_substeps`` is small and static), the batch residue
        rides in the carry, and every float op mirrors the numpy engine's
        operation order so the two agree bit-for-bit."""
        col = jnp.arange(T + 1)

        def take(Acum, done, r):
            j = cnt[:, r]
            a = jnp.take_along_axis(Acum, j[:, None], axis=1)[:, 0]
            return jnp.clip(a - done, 0.0, None)

        def pour(Acum, done, amt):
            """``serve`` + the largest cohort key touched (the batch's
            preemption rank; -inf when nothing poured)."""
            full = take(Acum, done, CT)
            amt = jnp.minimum(jnp.maximum(amt, 0.0), full.sum())

            def bisect(_, lohi):
                lo, hi = lohi
                mid = (lo + hi) // 2
                ge = take(Acum, done, mid).sum() >= amt
                return (jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi))

            lo, _ = lax.fori_loop(0, n_rank_iters, bisect,
                                  (jnp.int32(0), jnp.int32(CT)))
            rm1 = jnp.maximum(lo - 1, 0)
            base = take(Acum, done, rm1)
            marginal = cls_rank[rm1]
            split = base + jnp.maximum(amt - base.sum(), 0.0) \
                * (arange_c == marginal)
            split = jnp.where(lo > 0, split, jnp.zeros(C))
            key = jnp.where(lo > 0, key_rank[rm1], -jnp.inf)
            return split, key

        def head_key(Acum, done):
            """Key of the head-of-queue cohort; +inf when empty."""
            total = take(Acum, done, CT).sum()

            def bisect(_, lohi):
                lo, hi = lohi
                mid = (lo + hi) // 2
                ge = take(Acum, done, mid).sum() > 0.0
                return (jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi))

            lo, _ = lax.fori_loop(0, n_rank_iters, bisect,
                                  (jnp.int32(0), jnp.int32(CT)))
            return jnp.where(total > 0.0, key_rank[jnp.maximum(lo - 1, 0)],
                             jnp.inf)

        def step(carry, x):
            (ready, in_flight, pend, done, Acum, busy_m, busy_w, busy_k,
             held_m, held_w, held_k, pstate) = carry
            arr_c, rate_c, rate_sum, jb_t, t = x
            matured = pend[t]
            ready = ready + matured
            in_flight = in_flight - matured

            total_prev = Acum[:, T]
            drop = jnp.zeros(C)
            if max_queue is not None:
                out_c0 = (total_prev - done) + busy_m.sum(axis=0) \
                    + held_m.sum(axis=0)
                over = jnp.maximum(out_c0.sum() + arr_c.sum() - max_queue,
                                   0.0)
                order_t = drop_rank[t]
                for rankc in range(C):
                    c = order_t[rankc]
                    d = jnp.minimum(arr_c[c], over)
                    drop = drop.at[c].add(d)
                    over = over - d
            adm_c = arr_c - drop
            new_total = total_prev + adm_c
            Acum = jnp.where(col[None, :] >= t + 1, new_total[:, None], Acum)

            served_bin = 0.0
            pre_n = 0.0
            pre_w = 0.0
            sub_split, sub_bt, sub_served = [], [], []
            for _ in range(n_sub):                 # static unroll
                slot_split_i, slot_bt_i, slot_served_i = [], [], []
                for p in order:                    # static drain order
                    n_rep = jnp.maximum(ready[p], 0.0)
                    has = n_rep > 0
                    tau = dt_sub
                    comp_m = jnp.zeros(C)
                    comp_btw = 0.0
                    hk = head_key(Acum, done)
                    bm, bw, bk = busy_m[p], busy_w[p], busy_k[p]
                    hm, hw, hkey = held_m[p], held_w[p], held_k[p]
                    if preemptive:
                        pr = (bw > 0.0) & (hk < bk)
                        hm = hm + jnp.where(pr, bm, 0.0)
                        hw = hw + jnp.where(pr, bw, 0.0)
                        hkey = jnp.where(pr, jnp.maximum(hkey, bk), hkey)
                        pre_n = pre_n + pr
                        pre_w = pre_w + jnp.where(pr, bw, 0.0)
                        bm = jnp.where(pr, 0.0, bm)
                        bw = jnp.where(pr, 0.0, bw)
                        bk = jnp.where(pr, -jnp.inf, bk)
                    # progress the in-flight batch
                    w = bw
                    tau0 = tau
                    fin = (w > 0.0) & (w <= tau0)
                    run = w > tau0
                    comp_m = comp_m + jnp.where(fin, bm, 0.0)
                    comp_btw = comp_btw + jnp.where(
                        fin, bm.sum() * ((dt_sub - tau0) + w), 0.0)
                    bw = jnp.where(run, w - tau0, 0.0)
                    bm = jnp.where(fin, jnp.zeros(C), bm)
                    bk = jnp.where(fin, -jnp.inf, bk)
                    tau = jnp.where(fin, tau0 - w,
                                    jnp.where(run, 0.0, tau0))
                    # resume a checkpoint, else form a new batch
                    idle = bw == 0.0
                    res = idle & (hw > 0.0) & (hk >= hkey)
                    bm = jnp.where(res, hm, bm)
                    bw = jnp.where(res, hw, bw)
                    bk = jnp.where(res, hkey, bk)
                    hm = jnp.where(res, jnp.zeros(C), hm)
                    hw = jnp.where(res, 0.0, hw)
                    hkey = jnp.where(res, -jnp.inf, hkey)

                    backlog = (new_total - done).sum()
                    form = idle & (~res) & (backlog > 0.0) & (tau > 0.0) \
                        & has
                    b = jnp.clip(jnp.where(has, jnp.ceil(
                        backlog / jnp.where(has, n_rep, 1.0)), 0.0),
                        1.0, max_b[p])
                    bt_b = jnp.maximum(t_fixed[p] + b * t_unit[p], _EPS)
                    amt = jnp.where(form, jnp.minimum(backlog, n_rep * b),
                                    0.0)
                    split, _ = pour(Acum, done, amt)
                    done = done + split
                    bm = jnp.where(form, split, bm)
                    bw = jnp.where(form, bt_b, bw)
                    # preemption rank = head key at formation (the numpy
                    # engine's convention: rank by the batch's most urgent
                    # cohort, so urgent mass is never checkpointed behind a
                    # max-key resume gate)
                    bk = jnp.where(form, hk, bk)
                    # progress the resumed/formed batch
                    w2 = bw
                    tau0 = tau
                    fin2 = (w2 > 0.0) & (w2 <= tau0)
                    run2 = w2 > tau0
                    comp_m = comp_m + jnp.where(fin2, bm, 0.0)
                    comp_btw = comp_btw + jnp.where(
                        fin2, bm.sum() * ((dt_sub - tau0) + w2), 0.0)
                    bw = jnp.where(run2, w2 - tau0, 0.0)
                    bm = jnp.where(fin2, jnp.zeros(C), bm)
                    bk = jnp.where(fin2, -jnp.inf, bk)
                    tau = jnp.where(fin2, tau0 - w2,
                                    jnp.where(run2, 0.0, tau0))
                    # fluid tail (the coarse within-bin convention)
                    idle2 = bw == 0.0
                    backlog2 = (new_total - done).sum()
                    b2 = jnp.clip(jnp.where(has, jnp.ceil(
                        backlog2 / jnp.where(has, n_rep, 1.0)), 0.0),
                        1.0, max_b[p])
                    bt2 = jnp.maximum(t_fixed[p] + b2 * t_unit[p], _EPS)
                    tail = idle2 & (tau > 0.0) & has
                    cap = jnp.where(tail, n_rep * b2 / bt2, 0.0) * tau
                    amt2 = jnp.minimum(jnp.maximum(backlog2, 0.0), cap)
                    split2, _ = pour(Acum, done, amt2)
                    done = done + split2
                    pour_tot = split2.sum()
                    comp_tot = comp_m.sum()
                    busy_m = busy_m.at[p].set(bm)
                    busy_w = busy_w.at[p].set(bw)
                    busy_k = busy_k.at[p].set(bk)
                    held_m = held_m.at[p].set(hm)
                    held_w = held_w.at[p].set(hw)
                    held_k = held_k.at[p].set(hkey)
                    slot_split_i.append(comp_m)
                    slot_split_i.append(split2)
                    slot_bt_i.append(jnp.where(
                        comp_tot > 0,
                        comp_btw / jnp.where(comp_tot > 0, comp_tot, 1.0),
                        0.0))
                    slot_bt_i.append(jnp.where(pour_tot > 0.0,
                                               (dt_sub - tau) + bt2, 0.0))
                    slot_served_i.append(comp_tot)
                    slot_served_i.append(pour_tot)
                    served_bin = served_bin + comp_tot
                    served_bin = served_bin + pour_tot
                # fold sub-eps float residue once per substep (the numpy
                # engine's _MASS_EPS behaviour)
                done = jnp.where(new_total - done <= 1e-9 + 1e-12 * new_total,
                                 new_total, done)
                sub_split.append(jnp.stack(slot_split_i))   # (2P, C)
                sub_bt.append(jnp.stack(slot_bt_i))
                sub_served.append(jnp.stack(slot_served_i))

            out_c = jnp.maximum(new_total - done, 0.0) + busy_m.sum(axis=0) \
                + held_m.sum(axis=0)
            queue = out_c.sum()
            capacity = 0.0
            for p in range(P):
                capacity = capacity + jnp.maximum(ready[p], 0.0) \
                    * tput[p] * dt
            util = jnp.where(capacity > 0, served_bin / capacity, 0.0)
            util = jnp.minimum(util, 1.0)
            from repro.fleet.kernels import KernelObs
            obs = KernelObs(
                t_s=(t + 1) * dt, dt_s=dt, arrival_rate=rate_sum,
                queue=queue, replicas=ready.sum(),
                in_flight=in_flight.sum(), utilization=util,
                pool_replicas=ready, pool_in_flight=in_flight,
                class_queue=out_c, class_arrival_rate=rate_c,
                min_replicas=min_rep, max_replicas=max_rep)
            pool_rep = ready
            pstate, target = kernel.step(kp, pstate, obs)
            target = jnp.clip(target, min_rep, max_rep)

            excess = jnp.maximum(ready + in_flight - target, 0.0)
            zero = jnp.int32(0)
            window = lax.dynamic_slice(pend, (t + 1, zero), (W, P))
            newer = jnp.cumsum(window[::-1, :], axis=0)[::-1, :] - window
            cut = jnp.clip(excess[None, :] - newer, 0.0, window)
            window = window - cut
            canceled = cut.sum(axis=0)
            pend = lax.dynamic_update_slice(pend, window, (t + 1, zero))
            in_flight = in_flight - canceled
            ready = jnp.maximum(ready - (excess - canceled), 0.0)
            grow = jnp.maximum(target - ready - in_flight, 0.0)
            pend = pend.at[t + 1 + jb_t, jnp.arange(P)].add(grow)
            in_flight = in_flight + grow
            billed = pool_rep + in_flight
            residue = busy_w.sum() + held_w.sum()

            ys = {"slot_split": jnp.stack(sub_split),    # (n_sub, 2P, C)
                  "slot_bt": jnp.stack(sub_bt),          # (n_sub, 2P)
                  "slot_served": jnp.stack(sub_served),  # (n_sub, 2P)
                  "served_bin": served_bin,
                  "admitted_c": adm_c, "dropped_c": drop,
                  "queue_c": out_c, "pool_rep": pool_rep,
                  "billed": billed, "util": util,
                  "pre_n": pre_n, "pre_w": pre_w, "residue": residue}
            return (ready, in_flight, pend, done, Acum, busy_m, busy_w,
                    busy_k, held_m, held_w, held_k, pstate), ys

        carry0 = (init_ready, jnp.zeros(P), jnp.zeros((Tpad, P)),
                  jnp.zeros(C), jnp.zeros((C, T + 1)),
                  jnp.zeros((P, C)), jnp.zeros(P), jnp.full(P, -jnp.inf),
                  jnp.zeros((P, C)), jnp.zeros(P), jnp.full(P, -jnp.inf),
                  kernel.init())
        xs = (arr, rate, rate_sum, jb, jnp.arange(T, dtype=jnp.int32))
        _, ys = lax.scan(step, carry0, xs)
        return ys

    core_one = sim_one if n_sub == 1 and not preemptive else sim_one_fine
    over_seeds = jax.vmap(core_one,
                          in_axes=(0, 0, 0, 0, None, None, None, None, None,
                                   None, None, None))
    over_cands = jax.vmap(over_seeds,
                          in_axes=(None, None, None, None, 0, 0, 0, 0, 0, 0,
                                   0, 0))
    return jax.jit(over_cands)


def _core_for(kernel, **statics):
    key = (id(kernel),) + tuple(sorted(
        (k, tuple(v) if isinstance(v, (list, np.ndarray)) else v)
        for k, v in statics.items()))
    core = _CORE_CACHE.get(key)
    telemetry.counter("jaxsim_core_cache_total",
                      result="hit" if core is not None else "miss")
    if core is None:
        core = _build_core(kernel, **statics)
        _CORE_CACHE[key] = core
    return core


def _pad_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


def run_dynamics(kernel, *, arrivals, jb, dt, order, t_fixed, t_unit, max_b,
                 max_queue, tables, kp, min_rep, max_rep, init_ready,
                 max_cold_bins, tput=(), n_substeps: int = 1,
                 preemptive: bool = False, tile: int = None,
                 _pad_to: int = None, _tile_idx: tuple = None) -> dict:
    """Run the compiled dynamics for a stacked batch of candidates against a
    shared seed batch; one jitted dispatch covers the whole lattice.

    arrivals (S, T, C) and jb (S, T, P) are shared across candidates (the
    paired common-random-numbers design); ``tables`` (stacked
    ``cohort_tables``), ``kp`` (stacked kernel params), quota bounds and
    initial fleets are per-candidate with leading dim N. Returns numpy
    arrays with leading dims (N, S, T). Candidate batches are padded to the
    next power of two (padding replays candidate 0) so racing's shrinking
    rounds hit a handful of compiled programs.

    ``tile`` streams candidate slates wider than the (pow2-rounded) tile
    through fixed-shape chunks: every chunk — the tail included — pads to
    the full tile width, so the whole stream shares ONE compiled program
    and every dispatch after the first is warm. That is what bounds device
    memory and compile count when a racing round carries thousands of LHS
    candidates. Results are bit-identical to the untiled dispatch (padding
    rows are discarded per chunk).
    """
    import jax
    from jax.experimental import enable_x64

    if _PCACHE["dir"] is None and os.environ.get("JAX_COMPILATION_CACHE_DIR"):
        # env-wired persistent cache (e.g. CI's actions/cache dir): jax reads
        # the env var itself, but the admission floors and the hit/miss
        # listener only attach through our wiring
        enable_persistent_compile_cache(
            os.environ["JAX_COMPILATION_CACHE_DIR"])

    arrivals = np.asarray(arrivals, np.float64)
    S, T, C = arrivals.shape
    P = len(order)
    N = len(min_rep)
    if tile is not None:
        tile_w = _pad_pow2(int(tile))
        if N > tile_w:
            n_tiles = int(np.ceil(N / tile_w))
            kp = {k: np.asarray(v) for k, v in kp.items()}
            min_rep, max_rep, init_ready = (np.asarray(min_rep),
                                            np.asarray(max_rep),
                                            np.asarray(init_ready))
            outs = []
            for i in range(n_tiles):
                sl = slice(i * tile_w, min((i + 1) * tile_w, N))
                outs.append(run_dynamics(
                    kernel, arrivals=arrivals, jb=jb, dt=dt, order=order,
                    t_fixed=t_fixed, t_unit=t_unit, max_b=max_b,
                    max_queue=max_queue,
                    tables={k: v[sl] for k, v in tables.items()},
                    kp={k: v[sl] for k, v in kp.items()},
                    min_rep=min_rep[sl], max_rep=max_rep[sl],
                    init_ready=init_ready[sl], max_cold_bins=max_cold_bins,
                    tput=tput, n_substeps=n_substeps, preemptive=preemptive,
                    _pad_to=tile_w, _tile_idx=(i, n_tiles)))
            telemetry.counter("jaxsim_tiles_total", n_tiles)
            return {k: np.concatenate([o[k] for o in outs], axis=0)
                    for k in outs[0]}
    Npad = _pad_pow2(N) if _pad_to is None else int(_pad_to)

    def pad(a):
        a = np.asarray(a)
        if Npad == N:
            return a
        reps = np.repeat(a[:1], Npad - N, axis=0)
        return np.concatenate([a, reps], axis=0)

    core = _core_for(
        kernel, T=T, C=C, P=P, Tpad=T + max_cold_bins + 2,
        W=max_cold_bins + 1, dt=float(dt), order=tuple(order),
        t_fixed=tuple(float(v) for v in t_fixed),
        t_unit=tuple(float(v) for v in t_unit),
        max_b=tuple(float(v) for v in max_b),
        max_queue=None if max_queue is None else float(max_queue),
        n_substeps=int(n_substeps), preemptive=bool(preemptive),
        tput=tuple(float(v) for v in tput))
    # host-side divisions: XLA folds constant divisors into inexact
    # reciprocal multiplies, but policy ceil()s must see the exact IEEE
    # quotients the numpy reference sees
    rate = arrivals / float(dt)
    rate_sum = arrivals.sum(axis=2) / float(dt)
    # cold = this (compiled core, input shapes) pair has never dispatched, so
    # this call pays XLA compilation; the split is what the sim benchmark and
    # the tuner timing breakdown report as compile-vs-dispatch seconds
    sig = (id(core), Npad, S, T, C, P)
    cold = sig not in _DISPATCHED
    attrs = dict(kind="cold" if cold else "warm",
                 candidates=N, padded=Npad, seeds=S, bins=T)
    if _tile_idx is not None:
        attrs.update(tile=_tile_idx[0], n_tiles=_tile_idx[1])
    t0 = time.perf_counter()
    with telemetry.span("jaxsim.dispatch", **attrs):
        with enable_x64():
            out = core(arrivals, rate, rate_sum, np.asarray(jb, np.int32),
                       pad(tables["cnt"]), pad(tables["cls_of_rank"]),
                       pad(tables["drop_rank"]), pad(tables["key_of_rank"]),
                       {k: pad(v) for k, v in kp.items()},
                       pad(np.asarray(min_rep, np.float64)),
                       pad(np.asarray(max_rep, np.float64)),
                       pad(np.asarray(init_ready, np.float64)))
            out = jax.device_get(out)
    _DISPATCHED.add(sig)
    kind = "cold" if cold else "warm"
    telemetry.counter("jaxsim_dispatch_total", kind=kind)
    telemetry.counter("jaxsim_dispatch_seconds_total",
                      time.perf_counter() - t0, kind=kind)
    return {k: np.asarray(v)[:N] for k, v in out.items()}
