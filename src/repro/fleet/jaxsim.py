"""Compiled fleet-simulator backend: ``lax.scan`` over time bins, ``vmap``
over Monte Carlo seeds, ``vmap`` over candidate configs.

The numpy simulator (``repro.fleet.simulator``) is the reference
implementation; its inner loop is a Python ``for t in range(T)`` with a
data-dependent cohort pour per bin, so a tuning round pays Python dispatch
``n_candidates x n_bins`` times. This module re-expresses the per-bin update
as a pure function of fixed-shape arrays and compiles the whole
(candidate, seed, bin) lattice into one XLA program:

* **time** is a ``lax.scan`` whose carry is the queue/fleet state
  (per-class cumulative admitted+served curves, ready/cold-starting replicas,
  the pending-launch ledger, policy-kernel state);
* **the cohort pour** becomes a binary search: cohort service order is a
  static permutation of (class, arrival-bin) cohorts
  (``discipline.cohort_tables``), so "pour ``amount`` in key order" is
  "find the minimal global-order prefix whose admitted mass covers
  ``amount``" — ~log2(C*T) fixed iterations instead of a while loop;
* **scale-down cancellation** (newest pending launches first) becomes a
  reverse-cumsum water-fill over the pending-launch window;
* **the policy** runs as a functional kernel (``repro.fleet.kernels``), its
  tunable knobs passed as arrays — which is what lets a whole racing round
  (every candidate x every seed) batch into ONE jitted call.

Everything runs in float64 via a scoped ``enable_x64`` so the compiled path
agrees with the numpy reference to float rounding; candidate batches are
padded to power-of-two sizes so racing's shrinking rounds reuse a handful of
compiled programs instead of recompiling per round.
"""
from __future__ import annotations

import time

import numpy as np

from repro.fleet import telemetry

_EPS = 1e-12


def available() -> bool:
    """True when jax is importable (the compiled backend can run)."""
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


# One compiled core per (kernel, static-shape) signature; kernels are cached
# by config (kernels._KERNEL_CACHE), so repeated rounds of one tuning run —
# and repeated simulations of one scenario — all hit the same entry.
_CORE_CACHE: dict = {}

# (core id, padded shape signature) pairs that have dispatched at least once:
# a first dispatch pays XLA compilation (cold), repeats are pure dispatch
# (warm) — the classifier behind the compile-vs-dispatch timing split.
_DISPATCHED: set = set()


def _build_core(kernel, *, T, C, P, Tpad, W, dt, order, t_fixed, t_unit,
                max_b, max_queue):
    import jax
    import jax.numpy as jnp
    from jax import lax

    CT = C * T
    n_rank_iters = max(int(np.ceil(np.log2(CT + 1))), 1)
    arange_c = jnp.arange(C)

    def serve(Acum, done, amt, cnt, cls_rank):
        """Pour ``amt`` into cohorts in global key order: binary-search the
        minimal prefix rank whose admitted mass covers ``amt``, serve every
        cohort below it fully and the marginal cohort partially. ``Acum`` is
        the (C, T+1) cumulative-admitted curve (leading zero), ``done`` the
        (C,) served totals; returns the (C,) per-class split."""
        def take(r):
            j = cnt[:, r]                       # class-c cohorts in prefix r
            a = jnp.take_along_axis(Acum, j[:, None], axis=1)[:, 0]
            return jnp.clip(a - done, 0.0, None)

        full = take(CT)
        amt = jnp.minimum(jnp.maximum(amt, 0.0), full.sum())

        def bisect(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            ge = take(mid).sum() >= amt
            return (jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi))

        lo, _ = lax.fori_loop(0, n_rank_iters, bisect,
                              (jnp.int32(0), jnp.int32(CT)))
        rm1 = jnp.maximum(lo - 1, 0)
        base = take(rm1)
        marginal = cls_rank[rm1]
        served = base + jnp.maximum(amt - base.sum(), 0.0) \
            * (arange_c == marginal)
        return jnp.where(lo > 0, served, jnp.zeros(C))

    def sim_one(arr, rate, rate_sum, jb, cnt, cls_rank, drop_rank, kp,
                min_rep, max_rep, init_ready):
        """One (candidate, seed) trajectory. arr (T, C) float arrivals;
        rate (T, C) / rate_sum (T,) are the per-class and aggregate arrival
        rates divided by dt on the HOST — XLA rewrites division by a
        constant into an inexact reciprocal multiply, which would shift
        rates by an ulp and flip policy ceil()s vs the numpy reference;
        jb (T, P) int launch-landing offsets; tables/params per candidate."""
        col = jnp.arange(T + 1)

        def step(carry, x):
            ready, in_flight, pend, done, Acum, pstate = carry
            arr_c, rate_c, rate_sum, jb_t, t = x
            matured = pend[t]
            ready = ready + matured
            in_flight = in_flight - matured

            total_prev = Acum[:, T]
            drop = jnp.zeros(C)
            if max_queue is not None:
                over = jnp.maximum((total_prev - done).sum() + arr_c.sum()
                                   - max_queue, 0.0)
                order_t = drop_rank[t]
                for rank in range(C):
                    c = order_t[rank]
                    d = jnp.minimum(arr_c[c], over)
                    drop = drop.at[c].add(d)
                    over = over - d
            adm_c = arr_c - drop
            new_total = total_prev + adm_c
            Acum = jnp.where(col[None, :] >= t + 1, new_total[:, None], Acum)

            remaining = (new_total - done).sum()
            capacity = 0.0
            slot_split, slot_bt, slot_served = [], [], []
            for p in order:                       # static drain order
                n = jnp.maximum(ready[p], 0.0)
                has = n > 0
                b = jnp.clip(jnp.where(
                    has, jnp.ceil(remaining / jnp.where(has, n, 1.0)), 0.0),
                    1.0, max_b[p])
                bt = jnp.maximum(t_fixed[p] + b * t_unit[p], _EPS)
                cap = jnp.where(has, n * b / bt, 0.0) * dt
                split = serve(Acum, done, jnp.minimum(remaining, cap),
                              cnt, cls_rank)
                done = done + split
                s_p = split.sum()
                remaining = remaining - s_p
                capacity = capacity + cap
                slot_split.append(split)
                slot_bt.append(bt)
                slot_served.append(s_p)

            # fold sub-eps float residue of a drained class into "empty" —
            # the numpy pour's _MASS_EPS behaviour; without it a ~1e-11
            # leftover queue can flip a policy ceil() on the next bin
            done = jnp.where(new_total - done <= 1e-9 + 1e-12 * new_total,
                             new_total, done)
            queue_c = jnp.maximum(new_total - done, 0.0)
            served = sum(slot_served)
            util = jnp.where(capacity > 0, served / capacity, 0.0)
            from repro.fleet.kernels import KernelObs
            obs = KernelObs(
                t_s=(t + 1) * dt, dt_s=dt, arrival_rate=rate_sum,
                queue=queue_c.sum(), replicas=ready.sum(),
                in_flight=in_flight.sum(), utilization=util,
                pool_replicas=ready, pool_in_flight=in_flight,
                class_queue=queue_c, class_arrival_rate=rate_c,
                min_replicas=min_rep, max_replicas=max_rep)
            pool_rep = ready                      # pre-decision (serving) fleet
            pstate, target = kernel.step(kp, pstate, obs)
            target = jnp.clip(target, min_rep, max_rep)

            # scale down: cancel pending launches newest-first (reverse
            # water-fill over the cold-start window), then shrink ready
            excess = jnp.maximum(ready + in_flight - target, 0.0)
            zero = jnp.int32(0)
            window = lax.dynamic_slice(pend, (t + 1, zero), (W, P))
            newer = jnp.cumsum(window[::-1, :], axis=0)[::-1, :] - window
            cut = jnp.clip(excess[None, :] - newer, 0.0, window)
            window = window - cut
            canceled = cut.sum(axis=0)
            pend = lax.dynamic_update_slice(pend, window, (t + 1, zero))
            in_flight = in_flight - canceled
            ready = jnp.maximum(ready - (excess - canceled), 0.0)
            grow = jnp.maximum(target - ready - in_flight, 0.0)
            pend = pend.at[t + 1 + jb_t, jnp.arange(P)].add(grow)
            in_flight = in_flight + grow
            billed = pool_rep + in_flight

            ys = {"slot_split": jnp.stack(slot_split),    # (P, C) rank order
                  "slot_bt": jnp.stack(slot_bt),          # (P,)
                  "slot_served": jnp.stack(slot_served),  # (P,)
                  "admitted_c": adm_c, "dropped_c": drop,
                  "queue_c": queue_c, "pool_rep": pool_rep,
                  "billed": billed, "util": util}
            return (ready, in_flight, pend, done, Acum, pstate), ys

        carry0 = (init_ready, jnp.zeros(P), jnp.zeros((Tpad, P)),
                  jnp.zeros(C), jnp.zeros((C, T + 1)), kernel.init())
        xs = (arr, rate, rate_sum, jb, jnp.arange(T, dtype=jnp.int32))
        _, ys = lax.scan(step, carry0, xs)
        return ys

    over_seeds = jax.vmap(sim_one,
                          in_axes=(0, 0, 0, 0, None, None, None, None, None,
                                   None, None))
    over_cands = jax.vmap(over_seeds,
                          in_axes=(None, None, None, None, 0, 0, 0, 0, 0, 0,
                                   0))
    return jax.jit(over_cands)


def _core_for(kernel, **statics):
    key = (id(kernel),) + tuple(sorted(
        (k, tuple(v) if isinstance(v, (list, np.ndarray)) else v)
        for k, v in statics.items()))
    core = _CORE_CACHE.get(key)
    telemetry.counter("jaxsim_core_cache_total",
                      result="hit" if core is not None else "miss")
    if core is None:
        core = _build_core(kernel, **statics)
        _CORE_CACHE[key] = core
    return core


def _pad_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


def run_dynamics(kernel, *, arrivals, jb, dt, order, t_fixed, t_unit, max_b,
                 max_queue, tables, kp, min_rep, max_rep, init_ready,
                 max_cold_bins) -> dict:
    """Run the compiled dynamics for a stacked batch of candidates against a
    shared seed batch; one jitted dispatch covers the whole lattice.

    arrivals (S, T, C) and jb (S, T, P) are shared across candidates (the
    paired common-random-numbers design); ``tables`` (stacked
    ``cohort_tables``), ``kp`` (stacked kernel params), quota bounds and
    initial fleets are per-candidate with leading dim N. Returns numpy
    arrays with leading dims (N, S, T). Candidate batches are padded to the
    next power of two (padding replays candidate 0) so racing's shrinking
    rounds hit a handful of compiled programs.
    """
    import jax
    from jax.experimental import enable_x64

    arrivals = np.asarray(arrivals, np.float64)
    S, T, C = arrivals.shape
    P = len(order)
    N = len(min_rep)
    Npad = _pad_pow2(N)

    def pad(a):
        a = np.asarray(a)
        if Npad == N:
            return a
        reps = np.repeat(a[:1], Npad - N, axis=0)
        return np.concatenate([a, reps], axis=0)

    core = _core_for(
        kernel, T=T, C=C, P=P, Tpad=T + max_cold_bins + 2,
        W=max_cold_bins + 1, dt=float(dt), order=tuple(order),
        t_fixed=tuple(float(v) for v in t_fixed),
        t_unit=tuple(float(v) for v in t_unit),
        max_b=tuple(float(v) for v in max_b),
        max_queue=None if max_queue is None else float(max_queue))
    # host-side divisions: XLA folds constant divisors into inexact
    # reciprocal multiplies, but policy ceil()s must see the exact IEEE
    # quotients the numpy reference sees
    rate = arrivals / float(dt)
    rate_sum = arrivals.sum(axis=2) / float(dt)
    # cold = this (compiled core, input shapes) pair has never dispatched, so
    # this call pays XLA compilation; the split is what the sim benchmark and
    # the tuner timing breakdown report as compile-vs-dispatch seconds
    sig = (id(core), Npad, S, T, C, P)
    cold = sig not in _DISPATCHED
    t0 = time.perf_counter()
    with telemetry.span("jaxsim.dispatch",
                        kind="cold" if cold else "warm",
                        candidates=N, padded=Npad, seeds=S, bins=T):
        with enable_x64():
            out = core(arrivals, rate, rate_sum, np.asarray(jb, np.int32),
                       pad(tables["cnt"]), pad(tables["cls_of_rank"]),
                       pad(tables["drop_rank"]),
                       {k: pad(v) for k, v in kp.items()},
                       pad(np.asarray(min_rep, np.float64)),
                       pad(np.asarray(max_rep, np.float64)),
                       pad(np.asarray(init_ready, np.float64)))
            out = jax.device_get(out)
    _DISPATCHED.add(sig)
    kind = "cold" if cold else "warm"
    telemetry.counter("jaxsim_dispatch_total", kind=kind)
    telemetry.counter("jaxsim_dispatch_seconds_total",
                      time.perf_counter() - t0, kind=kind)
    return {k: np.asarray(v)[:N] for k, v in out.items()}
