"""Scheduling disciplines: orderings over (arrival-bin, class) cohorts.

The simulator is fluid within a bin, so a discipline never needs per-request
state — it only decides how each service slot's mass splits across the queued
*cohorts* (one cohort = all admitted requests of one class in one bin). Every
discipline here assigns each cohort a static scalar key and serves eligible
(already-arrived, unfinished) cohorts in increasing key order:

* ``fifo``     — key = arrival time: one global queue, same-bin ties to the
  lower class index.
* ``priority`` — key = (priority rank, arrival time): strict priority, all
  queued mass of a more critical class (lower ``RequestClass.priority``) goes
  first; FIFO within a class.
* ``edf``      — key = arrival time + the class's SLO: earliest absolute
  deadline first.

Keys are non-decreasing in arrival bin within a class, so service within a
class is always FIFO and per-class sojourns stay recoverable by the exact
cumulative cohort arithmetic in ``repro.fleet.cohort`` (per-class cumulative
served counts, batched searchsorted). The pour loop in ``CohortQueue.serve``
iterates over cohort *segments* actually drained — amortized O(classes x bins)
per trace — never over individual requests; it is validated against a
brute-force per-request replay for all three disciplines in
``tests/test_disciplines.py``.
"""
from __future__ import annotations

import numpy as np

_MASS_EPS = 1e-9


class Discipline:
    """Base: a static key per (class, arrival bin) cohort; lower key = served
    first, ties to the lower class index. Keys must be non-decreasing in the
    arrival bin within each class (this keeps per-class service FIFO)."""
    name = "discipline"

    def keys(self, classes, n_bins: int, dt_s: float) -> np.ndarray:
        """(n_classes, n_bins) cohort keys."""
        raise NotImplementedError


class FIFODiscipline(Discipline):
    """One global queue in arrival order — the pre-multi-class behaviour."""
    name = "fifo"

    def keys(self, classes, n_bins, dt_s):
        t = np.arange(n_bins) * dt_s
        return np.tile(t, (len(classes), 1))


class PriorityDiscipline(Discipline):
    """Strict priority (at bin granularity): every queued cohort of a more
    critical class is served before any less critical mass."""
    name = "priority"

    def keys(self, classes, n_bins, dt_s):
        t = np.arange(n_bins) * dt_s
        prios = np.array([c.priority for c in classes], float)
        rank = np.searchsorted(np.unique(prios), prios).astype(float)
        # one full trace-span per priority level: any lower-priority cohort
        # keys strictly above every higher-priority cohort, FIFO within
        span = n_bins * dt_s + 1.0
        return rank[:, None] * span + t[None, :]


class EDFDiscipline(Discipline):
    """Earliest (absolute) deadline first: arrival time + the class SLO."""
    name = "edf"

    def keys(self, classes, n_bins, dt_s):
        t = np.arange(n_bins) * dt_s
        slos = np.array([c.slo_s for c in classes], float)
        return t[None, :] + slos[:, None]


DISCIPLINES = {d.name: d for d in
               (FIFODiscipline(), PriorityDiscipline(), EDFDiscipline())}


def get_discipline(discipline) -> Discipline:
    """Resolve a discipline by name (or pass a ``Discipline`` through)."""
    if isinstance(discipline, Discipline):
        return discipline
    try:
        return DISCIPLINES[discipline]
    except KeyError:
        raise ValueError(f"unknown discipline {discipline!r}; "
                         f"available: {sorted(DISCIPLINES)}") from None


class CohortQueue:
    """Key-ordered fluid multi-class queue, vectorized over Monte Carlo seeds.

    Per-class state is two cumulative counts — admitted and served — which is
    exactly what the cohort sojourn arithmetic (batched searchsorted over the
    same curves) needs afterwards. ``serve`` pours a slot's capacity into
    eligible cohorts in increasing key order; the oldest-unfinished-cohort
    pointers advance monotonically along the cumulative-admitted curves, so
    no per-request bookkeeping ever exists.
    """

    def __init__(self, discipline, classes, n_seeds: int, n_bins: int,
                 dt_s: float):
        self.discipline = get_discipline(discipline)
        self.classes = tuple(classes)
        C = len(self.classes)
        self.keys = np.asarray(
            self.discipline.keys(self.classes, n_bins, dt_s), float)
        if self.keys.shape != (C, n_bins):
            raise ValueError(f"{self.discipline.name}: keys shape "
                             f"{self.keys.shape} != {(C, n_bins)}")
        if C and np.any(np.diff(self.keys, axis=1) < 0):
            raise ValueError(f"{self.discipline.name}: cohort keys must be "
                             "non-decreasing in the arrival bin")
        self._cum = np.zeros((C, n_seeds, n_bins))   # cumulative admitted
        self.admitted_total = np.zeros((n_seeds, C))
        self.served_total = np.zeros((n_seeds, C))
        # oldest unfinished cohort per (seed, class); monotone because
        # within-class service is FIFO, so it advances incrementally —
        # amortized O(n_bins) per (seed, class) over the whole trace
        self._head = np.zeros((n_seeds, C), int)
        self._t = -1

    def backlog(self) -> np.ndarray:
        """(n_seeds, n_classes) queued mass per class."""
        return self.admitted_total - self.served_total

    def admit(self, t: int, mass: np.ndarray) -> None:
        """Bin ``t``'s post-admission arrivals join the queue (call once per
        bin, in order, even when the mass is zero)."""
        if t != self._t + 1:
            raise ValueError(f"admit() must be called once per bin: bin {t} "
                             f"after bin {self._t}")
        self._t = t
        self.admitted_total = self.admitted_total + np.maximum(mass, 0.0)
        for c in range(len(self.classes)):
            self._cum[c, :, t] = self.admitted_total[:, c]

    def drop_order(self, t: int) -> list:
        """Class indices in load-shedding order for bin ``t``'s arrivals:
        largest cohort key first, so overflow is dropped from the requests the
        discipline would have served last."""
        k = self.keys[:, t]
        return sorted(range(len(self.classes)), key=lambda c: (-k[c], -c))

    def serve(self, t: int, amount: np.ndarray) -> np.ndarray:
        """Serve up to ``amount`` (n_seeds,) total mass from the queue in key
        order; returns the (n_seeds, n_classes) per-class split."""
        C = len(self.classes)
        S = len(amount)
        rem = np.clip(np.asarray(amount, float), 0.0, None)
        served = np.zeros((S, C))
        if C == 1:      # single class: plain FIFO, no head search needed
            served[:, 0] = np.minimum(self.backlog()[:, 0], rem)
            self.served_total = self.served_total + served
            return served
        idx = np.arange(S)
        head_key = np.empty((S, C))
        head_mass = np.empty((S, C))
        # each pass drains one cohort segment per seed: iterations are
        # bounded by cohorts exhausted plus one, amortized O(C * n_bins)
        # across the whole trace
        while (rem > _MASS_EPS).any():
            for c in range(C):
                done = self.served_total[:, c] + served[:, c]
                cum = self._cum[c]
                head = self._head[:, c]
                # advance the head to the first cohort with admitted mass
                # strictly beyond what this class has served (the eps folds
                # sub-eps float residue of an exhausted cohort into its
                # successor's take)
                while True:
                    adv = (head <= t) & (cum[idx, np.minimum(head, t)]
                                         <= done + _MASS_EPS)
                    if not adv.any():
                        break
                    head = head + adv
                self._head[:, c] = head
                empty = head > t
                hc = np.minimum(head, t)
                head_key[:, c] = np.where(empty, np.inf, self.keys[c, hc])
                head_mass[:, c] = np.where(empty, 0.0, cum[idx, hc] - done)
            pick = np.argmin(head_key, axis=1)    # ties -> lower class index
            take = np.where(np.isfinite(head_key[idx, pick]),
                            np.minimum(head_mass[idx, pick], rem), 0.0)
            if not (take > _MASS_EPS).any():
                break                             # queue empty on every seed
            served[idx, pick] += take
            rem = rem - take
        self.served_total = self.served_total + served
        return served


def cohort_tables(discipline, classes, n_bins: int, dt_s: float) -> dict:
    """Static serve-order tables for the compiled (JAX) simulator backend.

    ``CohortQueue.serve`` pours capacity into cohorts in increasing
    (key, class) order, heads advancing FIFO within each class. Because
    within-class keys are non-decreasing in the arrival bin, that order is a
    *static* permutation of the ``n_classes x n_bins`` cohorts — nothing about
    it depends on the simulated masses. A compiled backend can therefore
    replace the data-dependent pour loop with a binary search over prefix
    ranks of the global order (``repro.fleet.jaxsim``). Returns plain numpy
    arrays (they are data to the compiled path, so one jitted program serves
    every discipline):

    * ``cnt`` (C, C*T+1) int32 — ``cnt[c, r]``: how many class-c cohorts sit
      among the first ``r`` cohorts of the global order; indexes the
      per-class cumulative-admitted curve to price a prefix.
    * ``cls_of_rank`` (C*T,) int32 — the class of the cohort at each global
      rank (the marginal cohort of a partial pour).
    * ``drop_rank`` (T, C) int32 — admission-shedding class order per arrival
      bin (largest key first, ties to the higher class index), matching
      ``CohortQueue.drop_order``.
    * ``key_of_rank`` (C*T,) float — the cohort key at each global rank:
      what prices the substep engine's *preemption rank*. A formed batch
      carries the head-of-queue key at formation (``table_head_key`` — the
      key of the most urgent cohort it swept up); a preemptive discipline
      interrupts it whenever the head-of-queue key drops strictly below
      that. Ranking by the head rather than the largest key touched keeps
      urgent mass inside a mixed batch from being checkpointed behind its
      own class's fresh arrivals (priority inversion).
    """
    disc = get_discipline(discipline)
    classes = tuple(classes)
    C = len(classes)
    keys = np.asarray(disc.keys(classes, n_bins, dt_s), float)
    if keys.shape != (C, n_bins):
        raise ValueError(f"{disc.name}: keys shape {keys.shape} != "
                         f"{(C, n_bins)}")
    cls_idx = np.repeat(np.arange(C), n_bins)
    bin_idx = np.tile(np.arange(n_bins), C)
    # lexsort: primary = key, then class (pour ties go to the lower class),
    # then bin (stable FIFO within a class)
    order = np.lexsort((bin_idx, cls_idx, keys.ravel()))
    cls_of_rank = cls_idx[order].astype(np.int32)
    cnt = np.zeros((C, C * n_bins + 1), np.int32)
    cnt[:, 1:] = np.cumsum(cls_of_rank[None, :] == np.arange(C)[:, None],
                           axis=1)
    drop_rank = np.empty((n_bins, C), np.int32)
    for t in range(n_bins):
        drop_rank[t] = np.lexsort((-np.arange(C), -keys[:, t]))
    return {"cnt": cnt, "cls_of_rank": cls_of_rank, "drop_rank": drop_rank,
            "key_of_rank": keys.ravel()[order]}


def table_prefix(Acum: np.ndarray, done: np.ndarray,
                 cnt: np.ndarray) -> np.ndarray:
    """(S, C*T+1) available mass in every prefix of the global serve order.

    ``Acum`` (S, C, T+1) per-class cumulative-admitted curves (leading zero,
    flat beyond the current bin), ``done`` (S, C) per-class poured totals.
    Entry ``r`` prices the first ``r`` cohorts exactly as the compiled
    backend's bisect does: per class, ``clip(cum_at_prefix - done, 0)``,
    then the sum over classes — cohorts not yet arrived sit flat on the
    curve and contribute zero."""
    S = Acum.shape[0]
    idx = np.broadcast_to(cnt[None], (S,) + cnt.shape)
    a = np.take_along_axis(Acum, idx, axis=2)
    return np.clip(a - done[:, :, None], 0.0, None).sum(axis=1)


def table_pour(Acum: np.ndarray, done: np.ndarray, amt: np.ndarray,
               tables: dict):
    """Pour ``amt`` (S,) into the queue in global key order — the vectorized
    numpy mirror of the compiled backend's covering-prefix bisect, driven by
    the same ``cohort_tables`` and the same operation order (so the substep
    engines agree bit-for-bit). Returns ``(split, key)``: the (S, C)
    per-class mass taken and the (S,) largest cohort key touched — the
    upper edge of the swept key range (``-inf`` when nothing poured). The
    substep engines rank a formed batch for preemption by its *head* key
    (``table_head_key`` before the pour), not this upper edge."""
    cnt = tables["cnt"]
    cls_of_rank = tables["cls_of_rank"]
    key_of_rank = tables["key_of_rank"]
    S, C, _ = Acum.shape
    CT = cnt.shape[1] - 1
    pre = table_prefix(Acum, done, cnt)
    amt = np.minimum(np.maximum(np.asarray(amt, float), 0.0), pre[:, CT])
    # minimal prefix rank covering amt: the prefixes are non-decreasing, so
    # counting the strictly-cheaper ones lands exactly where the compiled
    # backend's left bisect does
    lo = (pre < amt[:, None]).sum(axis=1)
    rm1 = np.maximum(lo - 1, 0)
    j = cnt[:, rm1]                                        # (C, S)
    a = np.take_along_axis(Acum, j.T[:, :, None], axis=2)[:, :, 0]
    base = np.clip(a - done, 0.0, None)
    marginal = cls_of_rank[rm1]
    split = base + np.maximum(amt - base.sum(axis=1), 0.0)[:, None] \
        * (np.arange(C)[None, :] == marginal[:, None])
    split = np.where((lo > 0)[:, None], split, 0.0)
    key = np.where(lo > 0, key_of_rank[rm1], -np.inf)
    return split, key


def table_head_key(Acum: np.ndarray, done: np.ndarray,
                   tables: dict) -> np.ndarray:
    """(S,) key of the head-of-queue cohort — the next mass a pour would
    touch; ``+inf`` when the queue is empty. The substep engine's preemption
    test compares this against a running batch's ``key`` (strictly lower
    head key interrupts), and its resume gate re-activates a checkpointed
    batch once no queued cohort outranks it."""
    cnt = tables["cnt"]
    key_of_rank = tables["key_of_rank"]
    CT = cnt.shape[1] - 1
    pre = table_prefix(Acum, done, cnt)
    hr = np.minimum((pre <= 0.0).sum(axis=1), CT)
    return np.where(pre[:, CT] > 0.0, key_of_rank[np.maximum(hr - 1, 0)],
                    np.inf)


def split_service(discipline, classes, admitted: np.ndarray,
                  capacity: np.ndarray, slot_bin: np.ndarray,
                  dt_s: float = 1.0) -> np.ndarray:
    """Replay per-slot service capacity against per-class arrival streams.

    admitted: (S, T, C) post-admission arrivals per bin and class.
    capacity: (S, K) mass each service slot can carry (clipped to backlog).
    slot_bin: (K,) bin of each slot, non-decreasing, covering bins in order.

    Returns served (S, K, C): the per-class mass each slot served under the
    discipline — the building block the property tests and the brute-force
    validation drive directly, and what ``multiclass_cohort_metrics`` turns
    into exact per-class sojourns.
    """
    admitted = np.asarray(admitted, float)
    capacity = np.asarray(capacity, float)
    slot_bin = np.asarray(slot_bin, int)
    S, T, C = admitted.shape
    K = capacity.shape[1]
    q = CohortQueue(discipline, classes, S, T, dt_s)
    served = np.zeros((S, K, C))
    k = 0
    for t in range(T):
        q.admit(t, admitted[:, t, :])
        while k < K and slot_bin[k] == t:
            amt = np.minimum(capacity[:, k], q.backlog().sum(axis=1))
            served[:, k, :] = q.serve(t, amt)
            k += 1
    return served
