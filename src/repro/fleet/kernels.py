"""Functional policy kernels: the compiled-backend counterpart of
``repro.fleet.autoscaler``'s object policies.

An object policy is a stateful Python callable (``reset``/``decide``) — fine
for the numpy simulator, but opaque to ``lax.scan``: the compiled backend
needs the policy as pure functions over arrays. A ``PolicyKernel`` is exactly
that decomposition for one policy *family*:

* ``params_of(policy)``  — extract the tunable knobs of one configured
  instance as a flat dict of scalars. Stacking these dicts across candidate
  configs gives the pytree ``jax.vmap`` batches a whole racing round over.
* ``init()``             — the per-seed controller state (forecaster ring
  buffers, cooldown clocks) as a pytree of arrays, traced inside the scan.
* ``step(params, state, obs) -> (state, target)`` — one control decision;
  ``obs`` is a per-seed :class:`KernelObs`, ``target`` the (n_pools,) replica
  ask before quota clipping.

Anything a family needs beyond its knobs (service throughputs, the
recommend()-derived capacity rate, base/burst pool split, class SLOs) is
baked into the kernel's closures at build time — it is scenario structure,
identical across the candidates of a tuning round.

Ring-buffer sizes are static: a kernel built with ``max_window=W`` masks down
to each candidate's own ``window_bins <= W``, so candidates with different
windows still batch into one jitted program. Policies with no kernel (custom
Python subclasses, ``build_policy`` overrides) simply return ``None`` from
:func:`make_kernel` and keep running on the numpy reference path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import numpy as np

from repro.fleet import telemetry
from repro.fleet.autoscaler import (FitToUsagePolicy,
                                    HeterogeneousPredictivePolicy, PIDPolicy,
                                    PIPolicy, PredictivePolicy,
                                    QueueProportionalPolicy, ReactivePolicy,
                                    StaticPolicy)

_EPS = 1e-12


class KernelObs(NamedTuple):
    """Per-seed observation handed to ``PolicyKernel.step`` — the scalar
    mirror of :class:`repro.fleet.simulator.FleetObs` (arrays noted)."""
    t_s: object                 # sim time at bin end
    dt_s: object
    arrival_rate: object        # requests/s this bin, all classes
    queue: object               # backlog after serving/drops, all classes
    replicas: object            # ready replicas this bin, all pools
    in_flight: object           # replicas still cold-starting, all pools
    utilization: object         # served / capacity, in [0, 1]
    pool_replicas: object       # (n_pools,) ready per pool
    pool_in_flight: object      # (n_pools,) cold-starting per pool
    class_queue: object         # (n_classes,) backlog per class
    class_arrival_rate: object  # (n_classes,) req/s per class
    min_replicas: object        # (n_pools,) candidate quota floor
    max_replicas: object        # (n_pools,) candidate quota ceiling


@dataclass(frozen=True)
class PolicyKernel:
    """One policy family as pure functions (see module docstring)."""
    name: str
    param_names: tuple
    params_of: Callable         # Policy instance -> {name: float}
    init: Callable              # () -> per-seed state pytree (traced)
    step: Callable              # (params, state, obs) -> (state, (P,) target)


def _queue_demand(obs: KernelObs, horizon_s, slos: np.ndarray):
    """Backlog-drain demand in req/s — ``autoscaler._queue_demand``."""
    import jax.numpy as jnp

    if len(slos) <= 1:
        return obs.queue / jnp.maximum(horizon_s, obs.dt_s)
    h = jnp.maximum(jnp.minimum(horizon_s, jnp.asarray(slos)), obs.dt_s)
    return (obs.class_queue / h).sum()


def _push(hist, value):
    import jax.numpy as jnp
    return jnp.concatenate([hist[1:], jnp.reshape(value, (1,))])


def _forecast(hist, n_obs, window_bins, horizon_s, dt_s):
    """Masked-window mirror of ``_RateForecaster.observe``'s return value:
    linear trend over the last ``min(n_obs, window_bins)`` rates, projected
    one horizon ahead (falls back to the last rate below 3 observations)."""
    import jax.numpy as jnp

    W = hist.shape[0]
    w = jnp.minimum(n_obs, window_bins)
    age = jnp.arange(W)[::-1]           # 0 = the latest observation
    mask = age < w
    x = (w - 1) / 2.0 - age             # the centered index of _RateForecaster
    sx2 = jnp.sum(jnp.where(mask, x * x, 0.0))
    # keep the numpy reference's exact arithmetic (sum of x*(H - mean), not
    # the algebraically-equal sum of x*H): the two round differently at the
    # ulp level, and an ulp on the forecast can flip a downstream ceil()
    mean = jnp.sum(jnp.where(mask, hist, 0.0)) / jnp.maximum(w, 1)
    slope = jnp.sum(jnp.where(mask, x * (hist - mean), 0.0)) \
        / jnp.maximum(sx2, _EPS)
    last = hist[-1]
    return jnp.where(w >= 3, last + slope * (horizon_s / dt_s), last)


def _mean_rate(hist, n_obs, window_bins):
    """``_RateForecaster.mean_rate`` over the masked window."""
    import jax.numpy as jnp

    W = hist.shape[0]
    w = jnp.minimum(jnp.maximum(n_obs, 1), window_bins)
    age = jnp.arange(W)[::-1]
    return jnp.sum(jnp.where(age < w, hist, 0.0)) / w


def _static_kernel(fleet, classes) -> PolicyKernel:
    import jax.numpy as jnp

    def step(kp, state, obs):
        return state, jnp.full((1,), kp["n_replicas"])

    return PolicyKernel(
        name="static", param_names=("n_replicas",),
        params_of=lambda pol: {"n_replicas": float(pol.n)},
        init=lambda: (), step=step)


def _reactive_kernel(fleet, classes) -> PolicyKernel:
    import jax.numpy as jnp

    def init():
        return {"last": jnp.asarray(-jnp.inf)}

    def step(kp, state, obs):
        total = obs.replicas + obs.in_flight
        actionable = obs.t_s - state["last"] >= kp["cooldown_s"]
        starved = (total < 1) & ((obs.queue >= 1) | (obs.arrival_rate > 0))
        up = (actionable & (obs.utilization >= kp["upper"])) | starved
        down = (actionable & ~starved & (obs.utilization <= kp["lower"])
                & (obs.queue < 1))
        t_up = jnp.maximum(
            total + jnp.maximum(jnp.ceil(total * kp["scale_up_frac"]), 1.0),
            1.0)
        t_down = total - jnp.maximum(
            jnp.ceil(total * kp["scale_down_frac"]), 1.0)
        target = jnp.where(up, t_up, jnp.where(down, t_down, total))
        last = jnp.where(up | down, obs.t_s, state["last"])
        return {"last": last}, jnp.reshape(target, (1,))

    return PolicyKernel(
        name="reactive",
        param_names=("upper", "lower", "scale_up_frac", "scale_down_frac",
                     "cooldown_s"),
        params_of=lambda pol: {
            "upper": float(pol.upper), "lower": float(pol.lower),
            "scale_up_frac": float(pol.up_frac),
            "scale_down_frac": float(pol.down_frac),
            "cooldown_s": float(pol.cooldown_s)},
        init=init, step=step)


def _queue_prop_kernel(fleet, classes) -> PolicyKernel:
    import jax.numpy as jnp

    slos = np.array([c.slo_s for c in classes], float)
    mt0 = float(fleet.pools[0].service.max_throughput)

    def step(kp, state, obs):
        demand = obs.arrival_rate + _queue_demand(obs, kp["drain_s"], slos)
        per = jnp.maximum(mt0 * kp["headroom"], _EPS)
        target = jnp.ceil(jnp.maximum(demand, 0.0) / per)
        return state, jnp.reshape(target, (1,))

    return PolicyKernel(
        name="queue-prop", param_names=("drain_s", "headroom"),
        params_of=lambda pol: {"drain_s": float(pol.drain_s),
                               "headroom": float(pol.headroom)},
        init=lambda: (), step=step)


def _predictive_kernel(fleet, classes, reference: PredictivePolicy,
                       max_window: int = None) -> PolicyKernel:
    import jax.numpy as jnp

    slos = np.array([c.slo_s for c in classes], float)
    rate = float(reference._rate)   # recommend()+surface capacity: not a knob
    W = int(max_window or reference.forecaster.window_bins)

    def init():
        return {"hist": jnp.zeros(W), "n_obs": jnp.asarray(0)}

    def step(kp, state, obs):
        hist = _push(state["hist"], obs.arrival_rate)
        n_obs = state["n_obs"] + 1
        forecast = _forecast(hist, n_obs, kp["window_bins"],
                             kp["horizon_s"], obs.dt_s)
        demand = jnp.maximum(forecast, obs.arrival_rate) \
            + _queue_demand(obs, kp["horizon_s"], slos)
        per = jnp.maximum(rate * kp["headroom"], _EPS)
        target = jnp.ceil(jnp.maximum(demand, 0.0) / per)
        return {"hist": hist, "n_obs": n_obs}, jnp.reshape(target, (1,))

    return PolicyKernel(
        name="predictive",
        param_names=("horizon_s", "window_bins", "headroom"),
        params_of=lambda pol: {
            "horizon_s": float(pol.horizon_s),
            "window_bins": float(pol.forecaster.window_bins),
            "headroom": float(pol.headroom)},
        init=init, step=step)


def _hetero_kernel(fleet, classes, reference: HeterogeneousPredictivePolicy,
                   max_window: int = None,
                   max_sustain: int = None) -> PolicyKernel:
    import jax.numpy as jnp

    P = fleet.n_pools
    C = len(classes)
    slos = np.array([c.slo_s for c in classes], float)
    mt = np.array([p.service.max_throughput for p in fleet.pools], float)
    base = int(reference.base_idx)
    burst = tuple(int(i) for i in reference.burst_idx)
    W = int(max_window or reference.forecaster.window_bins)
    Ws = int(max_sustain or reference.sustain.window_bins)
    lag = (max(fleet.pools[i].cold_start_mean_s for i in burst)
           if burst else 0.0)
    crit = slos <= lag              # classes too tight for burst cold starts

    def init():
        return {"hist": jnp.zeros(W), "sustain": jnp.zeros(Ws),
                "n_obs": jnp.asarray(0)}

    def step(kp, state, obs):
        hist = _push(state["hist"], obs.arrival_rate)
        sustain = _push(state["sustain"], obs.arrival_rate)
        n_obs = state["n_obs"] + 1
        forecast = _forecast(hist, n_obs, kp["window_bins"],
                             kp["horizon_s"], obs.dt_s)
        demand = jnp.maximum(
            jnp.maximum(forecast, obs.arrival_rate)
            + _queue_demand(obs, kp["horizon_s"], slos), 0.0)
        per = jnp.maximum(mt * kp["headroom"], _EPS)       # (P,)
        base_demand = _mean_rate(sustain, n_obs, kp["sustain_bins"])
        if C > 1 and burst and crit.any():
            h = jnp.maximum(jnp.minimum(kp["horizon_s"],
                                        jnp.asarray(slos)), obs.dt_s)
            cd = (jnp.where(crit, obs.class_arrival_rate, 0.0).sum()
                  + jnp.where(crit, obs.class_queue / h, 0.0).sum())
            base_demand = jnp.maximum(base_demand, cd)
        base_n = jnp.clip(jnp.ceil(base_demand / per[base]),
                          obs.min_replicas[base], obs.max_replicas[base])
        residual = jnp.maximum(demand - base_n * per[base], 0.0)
        target = jnp.zeros(P)
        for i in burst:
            n = jnp.clip(jnp.ceil(residual / per[i]),
                         obs.min_replicas[i], obs.max_replicas[i])
            target = target.at[i].set(n)
            residual = jnp.maximum(residual - n * per[i], 0.0)
        target = target.at[base].set(
            jnp.clip(base_n + jnp.ceil(residual / per[base]),
                     obs.min_replicas[base], obs.max_replicas[base]))
        return ({"hist": hist, "sustain": sustain, "n_obs": n_obs}, target)

    return PolicyKernel(
        name="hetero-predictive",
        param_names=("horizon_s", "window_bins", "sustain_bins", "headroom"),
        params_of=lambda pol: {
            "horizon_s": float(pol.horizon_s),
            "window_bins": float(pol.forecaster.window_bins),
            "sustain_bins": float(pol.sustain.window_bins),
            "headroom": float(pol.headroom)},
        init=init, step=step)


def _pi_error(prm, obs, use_queue: bool, mt0: float):
    """The PI(D) error term — ``PIPolicy._error``'s exact arithmetic."""
    import jax.numpy as jnp

    if use_queue:
        cap = jnp.maximum(prm["n_base"] * mt0 * obs.dt_s, _EPS)
        v = obs.queue / cap
    else:
        v = obs.utilization
    return v - prm["setpoint"]


def _pi_kernel(fleet, classes, reference: PIPolicy) -> PolicyKernel:
    import jax.numpy as jnp

    mt0 = float(fleet.pools[0].service.max_throughput)
    use_queue = reference.signal == "queue"

    def init():
        return {"i": jnp.asarray(0.0)}

    def step(prm, state, obs):
        e = _pi_error(prm, obs, use_queue, mt0)
        i = jnp.clip(state["i"] + e, -prm["windup"], prm["windup"])
        target = jnp.maximum(
            jnp.rint(prm["n_base"] + prm["kp"] * e + prm["ki"] * i), 0.0)
        starved = (obs.queue >= 1) | (obs.arrival_rate > 0)
        target = jnp.maximum(target, jnp.where(starved, 1.0, 0.0))
        return {"i": i}, jnp.reshape(target, (1,))

    return PolicyKernel(
        name="pi",
        param_names=("n_base", "kp", "ki", "setpoint", "windup"),
        params_of=lambda pol: {
            "n_base": float(pol.n_base), "kp": float(pol.kp),
            "ki": float(pol.ki), "setpoint": float(pol.setpoint),
            "windup": float(pol.windup)},
        init=init, step=step)


def _pid_kernel(fleet, classes, reference: PIDPolicy) -> PolicyKernel:
    import jax.numpy as jnp

    mt0 = float(fleet.pools[0].service.max_throughput)
    use_queue = reference.signal == "queue"

    def init():
        return {"i": jnp.asarray(0.0), "prev": jnp.asarray(0.0)}

    def step(prm, state, obs):
        e = _pi_error(prm, obs, use_queue, mt0)
        i = jnp.clip(state["i"] + e, -prm["windup"], prm["windup"])
        d = e - state["prev"]
        target = jnp.maximum(
            jnp.rint(prm["n_base"] + prm["kp"] * e + prm["ki"] * i
                     + prm["kd"] * d), 0.0)
        starved = (obs.queue >= 1) | (obs.arrival_rate > 0)
        target = jnp.maximum(target, jnp.where(starved, 1.0, 0.0))
        return {"i": i, "prev": e}, jnp.reshape(target, (1,))

    return PolicyKernel(
        name="pid",
        param_names=("n_base", "kp", "ki", "kd", "setpoint", "windup"),
        params_of=lambda pol: {
            "n_base": float(pol.n_base), "kp": float(pol.kp),
            "ki": float(pol.ki), "kd": float(pol.kd),
            "setpoint": float(pol.setpoint), "windup": float(pol.windup)},
        init=init, step=step)


def _fit_to_usage_kernel(fleet, classes, reference: FitToUsagePolicy,
                         max_window: int = None) -> PolicyKernel:
    import jax.numpy as jnp

    W = int(max_window or reference.window_bins)

    def init():
        return {"hist": jnp.zeros(W), "n_obs": jnp.asarray(0)}

    def step(prm, state, obs):
        used = obs.utilization * jnp.maximum(obs.replicas, 0.0)
        hist = _push(state["hist"], used)
        n_obs = state["n_obs"] + 1
        w = jnp.minimum(n_obs, prm["window_bins"])
        age = jnp.arange(W)[::-1]
        fit = jnp.max(jnp.where(age < w, hist, -jnp.inf))
        target = jnp.ceil(fit * (1.0 + prm["headroom"]))
        starved = (obs.queue >= 1) | (obs.arrival_rate > 0)
        target = jnp.maximum(target, jnp.where(starved, 1.0, 0.0))
        return {"hist": hist, "n_obs": n_obs}, jnp.reshape(target, (1,))

    return PolicyKernel(
        name="fit-to-usage", param_names=("headroom", "window_bins"),
        params_of=lambda pol: {"headroom": float(pol.headroom),
                               "window_bins": float(pol.window_bins)},
        init=init, step=step)


_KERNEL_CACHE: dict = {}


def _kernel_key(policy, fleet, classes, max_window, max_sustain):
    """Config tuple fully determining a kernel's closures — identical configs
    share one kernel object, so the compiled backend's jit cache keeps
    hitting across racing rounds and repeated simulations."""
    slos = tuple(float(c.slo_s) for c in classes)
    if type(policy) is StaticPolicy:
        return ("static",)
    if type(policy) is ReactivePolicy:
        return ("reactive",)
    if type(policy) is QueueProportionalPolicy:
        return ("queue-prop", float(fleet.pools[0].service.max_throughput),
                slos)
    if type(policy) is PredictivePolicy:
        W = int(max_window or policy.forecaster.window_bins)
        return ("predictive", float(policy._rate), W, slos)
    if type(policy) is PIPolicy or type(policy) is PIDPolicy:
        return (policy.name, policy.signal,
                float(fleet.pools[0].service.max_throughput))
    if type(policy) is FitToUsagePolicy:
        return ("fit-to-usage", int(max_window or policy.window_bins))
    if type(policy) is HeterogeneousPredictivePolicy:
        W = int(max_window or policy.forecaster.window_bins)
        Ws = int(max_sustain or policy.sustain.window_bins)
        mt = tuple(float(p.service.max_throughput) for p in fleet.pools)
        cs = tuple(float(p.cold_start_mean_s) for p in fleet.pools)
        return ("hetero-predictive", mt, cs, int(policy.base_idx),
                tuple(int(i) for i in policy.burst_idx), W, Ws, slos)
    return None


def make_kernel(policy, fleet, classes, *, max_window: int = None,
                max_sustain: int = None):
    """Build the :class:`PolicyKernel` for ``policy``'s family, or ``None``
    when the family has no kernel (custom Python policies run on the numpy
    reference path). ``policy`` doubles as the reference instance for the
    family's non-tunable structure (capacity rate, base/burst split);
    ``max_window``/``max_sustain`` set ring-buffer sizes when batching
    candidates with different window knobs. Kernels are cached by config, so
    equal configs return the *same* object (a jit-cache key upstream)."""
    key = _kernel_key(policy, fleet, classes, max_window, max_sustain)
    if key is None:
        return None
    kernel = _KERNEL_CACHE.get(key)
    telemetry.counter("fleet_kernel_cache_total",
                      result="hit" if kernel is not None else "miss")
    if kernel is not None:
        return kernel
    if type(policy) is StaticPolicy:
        kernel = _static_kernel(fleet, classes)
    elif type(policy) is ReactivePolicy:
        kernel = _reactive_kernel(fleet, classes)
    elif type(policy) is QueueProportionalPolicy:
        kernel = _queue_prop_kernel(fleet, classes)
    elif type(policy) is PredictivePolicy:
        kernel = _predictive_kernel(fleet, classes, policy,
                                    max_window=max_window)
    elif type(policy) is PIPolicy:
        kernel = _pi_kernel(fleet, classes, policy)
    elif type(policy) is PIDPolicy:
        kernel = _pid_kernel(fleet, classes, policy)
    elif type(policy) is FitToUsagePolicy:
        kernel = _fit_to_usage_kernel(fleet, classes, policy,
                                      max_window=max_window)
    else:
        kernel = _hetero_kernel(fleet, classes, policy,
                                max_window=max_window,
                                max_sustain=max_sustain)
    _KERNEL_CACHE[key] = kernel
    return kernel
