"""Fleet workload scenarios: scoping rows (CellResult per shape x batch) for the
two serving paths the repo models, feeding ``recommend()`` and ServiceModels.

* MSET surveillance service (``mset/service.py``): one request = one batch of
  sensor observations estimated against the memory-vector model.
* Transformer LM decode (``launch/serve.py``): one request = one decode step of
  a batched generation loop.

Rows are analytic rooflines (no compilation), so scenarios build in
milliseconds and the simulator stays CPU-cheap.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs import get_config
from repro.core.catalog import CATALOG, CloudShape
from repro.core.cost_model import roofline
from repro.core.recommender import Constraint
from repro.core.scoping import CellResult
from repro.fleet.simulator import FleetConfig, PoolConfig
from repro.fleet.traces import (diurnal_trace, flash_crowd_trace,
                                poisson_trace)
from repro.fleet.workload import (RequestClass, ServiceModel, Workload,
                                  service_model_from_cell)
from repro.launch.serve import decode_flops_bytes
from repro.mset.service import service_collective_bytes, service_flops_bytes

DEFAULT_BATCHES = (8, 32, 128, 512)


@dataclass
class Scenario:
    """A serving workload scoped across shapes and batch sizes."""
    name: str
    rows: list                       # CellResult, params include "batch"
    slo_s: float                     # per-request latency SLO
    units_per_step: float            # reference serving batch
    description: str = ""

    def rows_at(self, batch: float = None) -> list:
        b = self.units_per_step if batch is None else batch
        return [r for r in self.rows if float(r.params["batch"]) == float(b)]

    def constraint(self, service_frac: float = 0.5) -> Constraint:
        """Feasibility bound for shape picking: a full batch must clear in a
        fraction of the SLO (the rest is queueing headroom)."""
        return Constraint(max_step_latency_s=self.slo_s * service_frac)

    def service_for(self, shape_name: str, batch: float = None) -> ServiceModel:
        b = self.units_per_step if batch is None else batch
        cell = next(r for r in self.rows_at(b) if r.shape_name == shape_name)
        return service_model_from_cell(cell, b, name=f"{self.name}:{shape_name}")

    def cheapest_shape(self) -> str:
        """Smallest-chip shape present (baseline for static fleets)."""
        return min(self.rows_at(), key=lambda r: r.params["chips"]).shape_name

    def pool_for(self, shape_name: str, batch: float = None,
                 cold_start_s: float = 30.0, min_replicas: int = 0,
                 max_replicas: int = 1024,
                 initial_replicas: int = None) -> PoolConfig:
        """One replica pool of ``shape_name`` running this scenario's service."""
        return PoolConfig(service=self.service_for(shape_name, batch),
                          cold_start_s=cold_start_s,
                          min_replicas=min_replicas,
                          max_replicas=max_replicas,
                          initial_replicas=initial_replicas)

    def fleet_for(self, shape_names, batch: float = None,
                  cold_start_s: float = 30.0, min_replicas: int = 0,
                  max_replicas=1024, max_queue: float = None) -> FleetConfig:
        """A (possibly mixed) fleet over this scenario: one pool per shape
        name. ``max_replicas`` may be an int applied to every pool or a
        mapping ``shape_name -> quota`` (per-instance-type cloud quotas)."""
        quota = (max_replicas if isinstance(max_replicas, dict)
                 else {s: max_replicas for s in shape_names})
        pools = tuple(
            self.pool_for(s, batch, cold_start_s=cold_start_s,
                          min_replicas=min_replicas,
                          max_replicas=quota.get(s, 1024))
            for s in shape_names)
        return FleetConfig(pools, max_queue=max_queue)


def interactive_batch_workload(mean_rate_per_s: float, duration_s: float,
                               dt_s: float = 5.0, *,
                               interactive_frac: float = 0.4,
                               interactive_slo_s: float = 1.0,
                               batch_slo_s: float = 30.0,
                               n_seeds: int = 8, seed: int = 0) -> Workload:
    """Interactive-vs-batch mix: a diurnal interactive stream with a tight
    SLO sharing the fleet with steady batch/backfill traffic that can wait.
    The canonical case where discipline choice dominates raw capacity: FIFO
    makes interactive requests queue behind batch backlog."""
    inter = diurnal_trace(interactive_frac * mean_rate_per_s, duration_s,
                          dt_s, period_s=duration_s, n_seeds=n_seeds,
                          seed=seed)
    batch = poisson_trace((1.0 - interactive_frac) * mean_rate_per_s,
                          duration_s, dt_s, n_seeds=n_seeds, seed=seed + 1)
    return Workload(
        "interactive+batch",
        (RequestClass("interactive", interactive_slo_s, priority=0),
         RequestClass("batch", batch_slo_s, priority=1)),
        (inter, batch))


def tiered_sla_workload(mean_rate_per_s: float, duration_s: float,
                        dt_s: float = 5.0, *,
                        tiers=(("gold", 1.0, 0.2), ("silver", 4.0, 0.3),
                               ("bronze", 60.0, 0.5)),
                        peak_mult: float = 2.0, burst_width_s: float = None,
                        n_seeds: int = 8, seed: int = 0) -> Workload:
    """Tiered-SLA mix: (name, slo_s, traffic share) tiers all riding the same
    flash-crowd demand shape (independently sampled per tier), priorities in
    tier order. ``mean_rate_per_s`` is the off-peak total rate; the
    coincident bursts peak at ``peak_mult`` x that. The burst forces
    queueing, which is where the disciplines separate: EDF/priority hold
    gold's deadline through the crowd by lending bronze's slack to the
    queue, so they meet every tier's SLO at well below peak capacity, while
    FIFO must be provisioned for the peak."""
    shares = [t[2] for t in tiers]
    total = sum(shares)
    width = duration_s / 30 if burst_width_s is None else burst_width_s
    classes, traces = [], []
    for i, (name, slo_s, share) in enumerate(tiers):
        classes.append(RequestClass(name, slo_s, priority=i))
        traces.append(flash_crowd_trace(
            (share / total) * mean_rate_per_s, duration_s, dt_s,
            peak_mult=peak_mult, burst_width_s=width,
            n_seeds=n_seeds, seed=seed + i))
    return Workload("tiered-sla", tuple(classes), tuple(traces))


def _row(shape: CloudShape, params: dict, flops: float, bytes_: float,
         coll: float, hbm_per_device: float) -> CellResult:
    terms = roofline(flops, bytes_, coll if shape.chips > 1 else 0.0, shape.chips)
    return CellResult(params=dict(params, chips=shape.chips),
                      shape_name=shape.name, terms=terms,
                      analysis={"peak_memory_per_device": hbm_per_device})


def mset_scenario(n_signals: int = 1024, n_memvec: int = 4096, fleet: int = 1,
                  slo_s: float = 1.0, batches=DEFAULT_BATCHES,
                  shapes=None) -> Scenario:
    """Sensor-fleet surveillance: a request is one observation batch estimated
    against ``fleet`` per-asset MSET models."""
    shapes = CATALOG if shapes is None else shapes
    model_bytes = 4.0 * (n_memvec ** 2 + 2 * n_memvec * n_signals) * fleet
    rows = []
    for shape in shapes:
        for b in batches:
            f, by = service_flops_bytes(n_signals, n_memvec, b)
            coll = service_collective_bytes(n_signals, b)
            hbm = model_bytes / shape.chips + 4.0 * b * n_signals
            rows.append(_row(shape, {"n_signals": n_signals,
                                     "n_memvec": n_memvec, "batch": b},
                             f * fleet, by * fleet, coll * fleet, hbm))
    return Scenario("mset-surveil", rows, slo_s, units_per_step=max(batches),
                    description=f"{fleet} asset model(s), {n_signals} signals, "
                                f"{n_memvec} memory vectors")


def lm_decode_scenario(arch: str = "minitron-4b", ctx: int = 512,
                       slo_s: float = 0.25, batches=DEFAULT_BATCHES,
                       shapes=None, smoke: bool = False) -> Scenario:
    """LM serving: a request is one decode step for one sequence; replicas run
    continuous batching at up to the reference batch."""
    shapes = CATALOG if shapes is None else shapes
    cfg = get_config(arch, smoke=smoke)
    counts = cfg.param_counts()
    dt_bytes = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    rows = []
    for shape in shapes:
        for b in batches:
            f, by = decode_flops_bytes(cfg, b, ctx=ctx)
            # weights all-gathered/reduced once per step when model-sharded
            coll = counts["active"] * dt_bytes * 0.25
            kv = 2.0 * b * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * ctx * dt_bytes
            hbm = (counts["total"] * dt_bytes + kv) / shape.chips
            rows.append(_row(shape, {"arch": arch, "ctx": ctx, "batch": b},
                             f, by, coll, hbm))
    return Scenario(f"lm-{arch}", rows, slo_s, units_per_step=max(batches),
                    description=f"{arch} decode @ ctx={ctx}, "
                                f"{counts['total'] / 1e9:.1f}B params")
