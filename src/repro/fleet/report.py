"""Fleet SLO/cost reporting: percentile latency, attainment, utilization, and
dollar cost (via the core cost model) per policy, plus comparison tables."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.cost_model import dollar_cost
from repro.core.report import fmt_time, markdown_table
from repro.fleet.simulator import SimResult


def weighted_percentile(values: np.ndarray, weights: np.ndarray,
                        q: float) -> float:
    """Percentile q in [0, 100] of ``values`` where each value counts
    ``weights`` times (per-bin latency weighted by requests served)."""
    v = np.asarray(values, float).ravel()
    w = np.asarray(weights, float).ravel()
    keep = w > 0
    v, w = v[keep], w[keep]
    if len(v) == 0:
        return float("nan")
    order = np.argsort(v)
    v, w = v[order], w[order]
    cdf = np.cumsum(w) / w.sum()
    return float(v[np.searchsorted(cdf, q / 100.0, side="left").clip(0, len(v) - 1)])


@dataclass(frozen=True)
class FleetReport:
    policy: str
    trace: str
    shape: str
    slo_s: float
    p50_s: float
    p95_s: float
    p99_s: float
    slo_attainment: float       # served within SLO / all arrivals (drops violate)
    mean_utilization: float
    drop_rate: float
    mean_replicas: float
    usd_total: float            # mean over MC seeds, whole trace
    usd_per_hour: float

    def row(self) -> list:
        return [self.policy, self.trace, self.shape,
                fmt_time(self.p50_s), fmt_time(self.p99_s),
                f"{self.slo_attainment * 100:.1f}%",
                f"{self.mean_utilization * 100:.0f}%",
                f"{self.drop_rate * 100:.2f}%",
                f"{self.mean_replicas:.1f}",
                f"${self.usd_per_hour:.2f}/hr"]


REPORT_HEADERS = ["policy", "trace", "shape", "p50", "p99", "SLO", "util",
                  "drop", "replicas", "cost"]


def summarize(sim: SimResult) -> FleetReport:
    served, lat = sim.served, sim.latency_s
    total_arrived = sim.arrivals.sum()
    ok = served * (lat <= sim.slo_s)
    attainment = (float(ok.sum() / total_arrived) if total_arrived > 0
                  else 1.0)      # no traffic = vacuously met
    replica_bins = sim.replica_bins()
    usd = dollar_cost(sim.dt_s, replica_bins, sim.service.shape.chips,
                      sim.service.shape.hw)
    hours = sim.trace.duration_s / 3600.0
    util = sim.utilization[sim.replicas > 0]
    return FleetReport(
        policy=sim.policy_name,
        trace=sim.trace.name,
        shape=sim.service.shape.name,
        slo_s=sim.slo_s,
        p50_s=weighted_percentile(lat, served, 50),
        p95_s=weighted_percentile(lat, served, 95),
        p99_s=weighted_percentile(lat, served, 99),
        slo_attainment=attainment,
        mean_utilization=float(util.mean()) if util.size else 0.0,
        drop_rate=float(sim.dropped.sum() / max(total_arrived, 1.0)),
        mean_replicas=float(sim.replicas.mean()),
        usd_total=usd,
        usd_per_hour=usd / max(hours, 1e-12),
    )


def comparison_table(reports: list) -> str:
    """Markdown policy-comparison table, grouped by trace then cost."""
    rows = [r.row() for r in sorted(reports, key=lambda r: (r.trace, r.usd_per_hour))]
    return markdown_table(REPORT_HEADERS, rows)
