"""Fleet SLO/cost reporting: percentile latency, attainment, utilization, and
dollar cost (via the core cost model) per policy, plus comparison tables.

Attainment and percentiles are exact: ``simulate`` carries per-request cohort
accounting (``ok_served``, the pooled sojourn distribution), so ``summarize``
reads them off instead of re-deriving them from per-bin mean latencies."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.cost_model import dollar_cost
from repro.core.report import fmt_time, markdown_table
from repro.fleet.simulator import SimResult


def weighted_percentile(values: np.ndarray, weights: np.ndarray,
                        q: float) -> float:
    """Percentile q in [0, 100] of ``values`` where each value counts
    ``weights`` times (per-request sojourns weighted by cohort mass).
    q=0 returns the min, q=100 the max; all-zero weights give NaN."""
    v = np.asarray(values, float).ravel()
    w = np.asarray(weights, float).ravel()
    keep = w > 0
    v, w = v[keep], w[keep]
    if len(v) == 0:
        return float("nan")
    order = np.argsort(v)
    v, w = v[order], w[order]
    cdf = np.cumsum(w) / w.sum()
    return float(v[np.searchsorted(cdf, q / 100.0, side="left").clip(0, len(v) - 1)])


@dataclass(frozen=True)
class ClassReport:
    """Per-request-class slice of a ``FleetReport`` (attainment is per the
    class's own SLO; cost is the whole fleet's — capacity is shared)."""
    name: str
    slo_s: float
    share: float                # fraction of total arrivals
    p50_s: float
    p95_s: float
    p99_s: float
    attainment: float
    drop_rate: float


@dataclass(frozen=True)
class FleetReport:
    policy: str
    trace: str
    shape: str                  # "+"-joined pool shapes for mixed fleets
    slo_s: float
    p50_s: float
    p95_s: float
    p99_s: float
    slo_attainment: float       # served in-SLO / completed (drops violate;
    #                             end-of-trace backlog is excluded — those
    #                             requests never got an outcome either way)
    mean_utilization: float
    drop_rate: float
    mean_replicas: float        # billed (ready + cold-starting) — the same
    #                             quantity the cost columns integrate
    usd_total: float            # mean over MC seeds, whole trace
    usd_per_hour: float
    discipline: str = "fifo"
    class_reports: tuple = ()   # ClassReport per request class

    def worst_class_attainment(self) -> float:
        """The binding SLO: the lowest per-class attainment (multi-class
        fleets must meet *every* class's bar, not the traffic-weighted mix)."""
        if not self.class_reports:
            return self.slo_attainment
        return min(c.attainment for c in self.class_reports)

    def row(self) -> list:
        return [self.policy, self.trace, self.shape,
                fmt_time(self.p50_s), fmt_time(self.p95_s),
                fmt_time(self.p99_s),
                f"{self.slo_attainment * 100:.1f}%",
                f"{self.mean_utilization * 100:.0f}%",
                f"{self.drop_rate * 100:.2f}%",
                f"{self.mean_replicas:.1f}",
                f"${self.usd_per_hour:.2f}/hr"]


REPORT_HEADERS = ["policy", "trace", "shape", "p50", "p95", "p99", "SLO",
                  "util", "drop", "replicas", "cost"]


def _class_reports(sim: SimResult, total_arrived: float) -> tuple:
    if sim.workload is None or sim.class_served is None:
        return ()
    out = []
    for c, rc in enumerate(sim.classes):
        arrived = float(sim.class_admitted[:, :, c].sum()
                        + sim.class_dropped[:, :, c].sum())
        completed = arrived - float(sim.class_queue[:, -1, c].sum())
        vals, weights = sim.class_sojourns[c]
        out.append(ClassReport(
            name=rc.name, slo_s=rc.slo_s,
            share=arrived / max(total_arrived, 1.0),
            p50_s=weighted_percentile(vals, weights, 50),
            p95_s=weighted_percentile(vals, weights, 95),
            p99_s=weighted_percentile(vals, weights, 99),
            attainment=(float(sim.class_ok[:, :, c].sum() / completed)
                        if completed > 0 else 1.0),
            drop_rate=float(sim.class_dropped[:, :, c].sum()
                            / max(arrived, 1.0))))
    return tuple(out)


def summarize(sim: SimResult) -> FleetReport:
    total_arrived = sim.arrivals.sum()
    # completed = everything that left the system (served or dropped); the
    # terminal in-queue backlog never resolved, so it belongs to neither the
    # numerator nor the denominator of attainment
    completed = total_arrived - sim.queue[:, -1].sum()
    attainment = (float(sim.ok_served.sum() / completed) if completed > 0
                  else 1.0)      # no traffic = vacuously met
    usd = sim.billed_usd()
    hours = sim.trace.duration_s / 3600.0
    util = sim.utilization[sim.replicas > 0]
    return FleetReport(
        policy=sim.policy_name,
        trace=sim.trace.name,
        shape=sim.fleet.shape_label(),
        slo_s=sim.slo_s,
        p50_s=weighted_percentile(sim.sojourn_values, sim.sojourn_weights, 50),
        p95_s=weighted_percentile(sim.sojourn_values, sim.sojourn_weights, 95),
        p99_s=weighted_percentile(sim.sojourn_values, sim.sojourn_weights, 99),
        slo_attainment=attainment,
        mean_utilization=float(util.mean()) if util.size else 0.0,
        drop_rate=float(sim.dropped.sum() / max(total_arrived, 1.0)),
        mean_replicas=float(sim.billed_replicas.mean()),
        usd_total=usd,
        usd_per_hour=usd / max(hours, 1e-12),
        discipline=sim.discipline,
        class_reports=_class_reports(sim, float(total_arrived)),
    )


@dataclass(frozen=True)
class WindowMetrics:
    """SLO/cost scalars over one bin window of a simulation — what the
    closed-loop controller and its benchmark read per control segment.
    Attainment is window-local: served/dropped mass *within* the window
    against the ok mass within it (requests still queued at ``t1`` belong
    to a later window)."""
    t0: int
    t1: int
    slo_attainment: float            # pooled over classes
    worst_class_attainment: float
    usd: float                       # mean over MC seeds, window total
    usd_per_hour: float
    mean_utilization: float
    mean_queue: float
    mean_replicas: float             # billed


def window_metrics(sim: SimResult, t0: int, t1: int = None) -> WindowMetrics:
    """Per-window analogue of ``summarize``: attainment, utilization and
    dollar cost over bins ``[t0, t1)`` (``t1=None``: to the end). The
    closed-loop recovery gate compares pre-drift, post-drift, and
    post-recovery windows of one continuous trace with this."""
    T = sim.arrivals.shape[1]
    t1 = T if t1 is None else int(t1)
    t0 = int(t0)
    if not 0 <= t0 < t1 <= T:
        raise ValueError(f"bad window [{t0}, {t1}) for {T} bins")
    completed = float((sim.served + sim.dropped)[:, t0:t1].sum())
    pooled = (float(sim.ok_served[:, t0:t1].sum() / completed)
              if completed > 0 else 1.0)
    worst = pooled
    if sim.class_ok is not None:
        done_c = (sim.class_served + sim.class_dropped)[:, t0:t1, :].sum(
            axis=(0, 1))
        ok_c = sim.class_ok[:, t0:t1, :].sum(axis=(0, 1))
        att_c = np.divide(ok_c, done_c, out=np.ones_like(ok_c),
                          where=done_c > 0)
        worst = float(att_c.min())
    usd = 0.0
    for p, pc in enumerate(sim.fleet.pools):
        bins = float(sim.pool_billed[:, t0:t1, p].sum(axis=1).mean())
        usd += dollar_cost(sim.dt_s, bins, pc.service.shape.chips,
                           pc.service.shape.hw)
    hours = (t1 - t0) * sim.dt_s / 3600.0
    util = sim.utilization[:, t0:t1][sim.replicas[:, t0:t1] > 0]
    return WindowMetrics(
        t0=t0, t1=t1, slo_attainment=pooled, worst_class_attainment=worst,
        usd=usd, usd_per_hour=usd / max(hours, 1e-12),
        mean_utilization=float(util.mean()) if util.size else 0.0,
        mean_queue=float(sim.queue[:, t0:t1].mean()),
        mean_replicas=float(sim.billed_replicas[:, t0:t1].mean()))


def comparison_table(reports: list) -> str:
    """Markdown policy-comparison table, grouped by trace then cost."""
    rows = [r.row() for r in sorted(reports, key=lambda r: (r.trace, r.usd_per_hour))]
    return markdown_table(REPORT_HEADERS, rows)


def telemetry_dashboard(sim: SimResult, width: int = 60) -> str:
    """ASCII sparkline dashboard of one simulation's telemetry streams
    (queue depth, replicas, arrival rate, utilization, observed service
    times), rendered from a throwaway registry — works on any finished
    ``SimResult``, no active telemetry session required."""
    from repro.fleet.telemetry import MetricsRegistry, record_sim
    from repro.fleet.telemetry.export import dashboard

    reg = MetricsRegistry()
    record_sim(reg, sim)
    return dashboard(reg, width=width)


def best_per_trace(reports: list, min_attainment: float = 0.99) -> list:
    """Cheapest report per trace among those meeting ``min_attainment``."""
    best = {}
    for r in reports:
        if r.slo_attainment < min_attainment:
            continue
        if r.trace not in best or r.usd_per_hour < best[r.trace].usd_per_hour:
            best[r.trace] = r
    return [best[k] for k in sorted(best)]


def cost_efficiency_table(reports: list, min_attainment: float = 0.99) -> str:
    """Homogeneous-vs-mixed scoreboard: per trace, every (shape, policy) fleet
    meeting the attainment bar, cheapest first, with its premium over the
    winner."""
    by_trace = {}
    for r in reports:
        by_trace.setdefault(r.trace, []).append(r)
    rows = []
    for trace in sorted(by_trace):
        ok = sorted((r for r in by_trace[trace]
                     if r.slo_attainment >= min_attainment),
                    key=lambda r: r.usd_per_hour)
        for r in ok:
            premium = r.usd_per_hour / ok[0].usd_per_hour - 1.0
            rows.append([trace, r.shape, r.policy,
                         f"{r.slo_attainment * 100:.1f}%",
                         f"${r.usd_per_hour:.2f}/hr",
                         "winner" if r is ok[0] else f"+{premium * 100:.0f}%"])
        if not ok:
            rows.append([trace, "-", "-", f"<{min_attainment * 100:.0f}%",
                         "-", "no fleet met the SLO bar"])
    return markdown_table(
        ["trace", "shape", "policy", "SLO", "cost", "vs winner"], rows)


CLASS_HEADERS = ["policy", "discipline", "trace", "class", "SLO", "share",
                 "p50", "p95", "p99", "attainment", "drop", "cost"]


def class_table(reports: list) -> str:
    """Per-class attainment/cost table: one row per (fleet run, request
    class), grouped by trace then discipline. The cost column is the whole
    fleet's $/hr — capacity is shared, so a class's bill is the fleet's."""
    rows = []
    for r in sorted(reports, key=lambda r: (r.trace, r.discipline, r.policy)):
        for c in (r.class_reports
                  or (ClassReport("all", r.slo_s, 1.0, r.p50_s, r.p95_s,
                                  r.p99_s, r.slo_attainment, r.drop_rate),)):
            rows.append([r.policy, r.discipline, r.trace, c.name,
                         fmt_time(c.slo_s), f"{c.share * 100:.0f}%",
                         fmt_time(c.p50_s), fmt_time(c.p95_s),
                         fmt_time(c.p99_s),
                         f"{c.attainment * 100:.2f}%",
                         f"{c.drop_rate * 100:.2f}%",
                         f"${r.usd_per_hour:.2f}/hr"])
    return markdown_table(CLASS_HEADERS, rows)
