"""Pluggable autoscaling policies.

All policies are vectorized over Monte Carlo seeds: ``decide`` receives
(n_seeds,) observation vectors and returns an (n_seeds,) replica target.

* ``StaticPolicy``           — fixed fleet (the paper's one-shot scoping answer).
* ``ReactivePolicy``         — ServerlessContainers-style utilization rules:
  scale up above an upper bound, down below a lower bound, with a per-seed
  cooldown; pays the cold start on every burst.
* ``QueueProportionalPolicy``— targets enough replicas to absorb the current
  arrival rate plus drain the backlog within ``drain_s``.
* ``PredictivePolicy``       — forecasts the arrival rate one cold-start horizon
  ahead and provisions for it; its *shape* is pre-picked by the scoping stack
  (``recommend()`` over CellResult rows) and its capacity estimate comes from a
  ``ResponseSurface`` fitted on the service batch time over the batch grid.
"""
from __future__ import annotations

import numpy as np

from repro.core.recommender import Constraint, recommend
from repro.core.surfaces import fit_response_surface
from repro.fleet.workload import ServiceModel, service_model_from_cell

_EPS = 1e-12


class Policy:
    """Base: stateless sizing against the bound service's capacity."""
    name = "policy"
    service: ServiceModel = None     # optional shape override (predictive)

    def reset(self, n_seeds: int) -> None:
        pass

    def decide(self, t: int, obs) -> np.ndarray:
        raise NotImplementedError


def _replicas_for_rate(rate: np.ndarray, service: ServiceModel,
                       headroom: float) -> np.ndarray:
    """Replicas needed to serve ``rate`` req/s at <= ``headroom`` utilization."""
    per = max(service.max_throughput * headroom, _EPS)
    return np.ceil(np.maximum(rate, 0.0) / per)


class StaticPolicy(Policy):
    name = "static"

    def __init__(self, n_replicas: int):
        self.n = int(n_replicas)

    def decide(self, t, obs):
        return np.full_like(obs.replicas, self.n)


class ReactivePolicy(Policy):
    name = "reactive"

    def __init__(self, upper: float = 0.8, lower: float = 0.3,
                 scale_up_frac: float = 0.5, scale_down_frac: float = 0.25,
                 cooldown_s: float = 60.0):
        assert 0.0 <= lower < upper <= 1.0
        self.upper, self.lower = upper, lower
        self.up_frac, self.down_frac = scale_up_frac, scale_down_frac
        self.cooldown_s = cooldown_s
        self._last = None

    def reset(self, n_seeds):
        self._last = np.full(n_seeds, -np.inf)

    def decide(self, t, obs):
        total = obs.replicas + obs.in_flight
        target = total.copy()
        actionable = obs.t_s - self._last >= self.cooldown_s
        # a fleet scaled to zero pins utilization at 0 and the upper-bound rule
        # alone would never fire again — starvation overrides the cooldown
        starved = (total < 1) & ((obs.queue >= 1) | (obs.arrival_rate > 0))
        up = (actionable & (obs.utilization >= self.upper)) | starved
        down = actionable & ~starved & (obs.utilization <= self.lower) \
            & (obs.queue < 1)
        target[up] = np.maximum(
            total[up] + np.maximum(np.ceil(total[up] * self.up_frac), 1), 1)
        target[down] = total[down] - np.maximum(
            np.ceil(total[down] * self.down_frac), 1)
        self._last[up | down] = obs.t_s
        return target


class QueueProportionalPolicy(Policy):
    name = "queue-prop"

    def __init__(self, drain_s: float = 30.0, headroom: float = 0.85):
        self.drain_s = drain_s
        self.headroom = headroom

    def decide(self, t, obs):
        demand = obs.arrival_rate + obs.queue / max(self.drain_s, obs.dt_s)
        return _replicas_for_rate(demand, obs.service, self.headroom)


class PredictivePolicy(Policy):
    """Scoping-stack-driven: shape from ``recommend()``, capacity from a
    ``ResponseSurface`` over the service batch time, replicas from a linear
    forecast one cold-start horizon ahead."""
    name = "predictive"

    def __init__(self, rows, constraint: Constraint, units_per_step: float,
                 horizon_s: float = 60.0, window_bins: int = 12,
                 headroom: float = 0.85, max_batch: int = None):
        ref = [r for r in rows
               if float(r.params.get("batch", units_per_step)) == units_per_step]
        self.recommendation = recommend(ref, constraint)
        if self.recommendation.shape is None:
            raise ValueError("predictive policy: no feasible shape "
                             f"({self.recommendation.reason})")
        shape_name = self.recommendation.shape.name
        cell = next(r for r in ref if r.shape_name == shape_name)
        self.service = service_model_from_cell(cell, units_per_step,
                                               max_batch=max_batch)
        # Provisioning capacity from a response surface over the batch
        # dimension, fitted on the same fixed+linear service decomposition the
        # simulator bills (``CellResult.service_terms``): exact on the scoped
        # batch grid, interpolating anywhere else.
        mine = [r for r in rows if r.shape_name == shape_name
                and "batch" in r.params]
        self.surface = None
        if len({float(r.params["batch"]) for r in mine}) >= 3:
            X = np.array([[float(r.params["batch"])] for r in mine])
            y = np.array([sum(r.service_terms(1.0)) for r in mine])
            self.surface = fit_response_surface(["batch"], X, y, degree=2)
            mb = float(self.service.max_batch)
            self._rate = mb / max(self.surface.predict({"batch": mb}), _EPS)
        else:
            self._rate = self.service.max_throughput
        self.horizon_s = horizon_s
        self.window_bins = max(int(window_bins), 2)
        self.headroom = headroom
        self._hist = None

    def reset(self, n_seeds):
        self._hist = np.zeros((self.window_bins, n_seeds))
        self._n_obs = 0

    def decide(self, t, obs):
        self._hist = np.roll(self._hist, -1, axis=0)
        self._hist[-1] = obs.arrival_rate
        self._n_obs += 1
        w = min(self._n_obs, self.window_bins)
        H = self._hist[-w:]
        if w >= 3:
            x = np.arange(w) - (w - 1) / 2.0
            slope = (x[:, None] * (H - H.mean(axis=0))).sum(axis=0) / (x ** 2).sum()
            forecast = H[-1] + slope * (self.horizon_s / obs.dt_s)
        else:
            forecast = H[-1]
        demand = np.maximum(forecast, obs.arrival_rate) \
            + obs.queue / max(self.horizon_s, obs.dt_s)
        per = max(self._rate * self.headroom, _EPS)
        return np.ceil(np.maximum(demand, 0.0) / per)


def default_policies(rows, constraint: Constraint, units_per_step: float,
                     static_replicas: int, cold_start_s: float = 30.0) -> list:
    """The four canonical policies, comparably configured."""
    return [
        StaticPolicy(static_replicas),
        ReactivePolicy(cooldown_s=2 * cold_start_s),
        QueueProportionalPolicy(),
        PredictivePolicy(rows, constraint, units_per_step,
                         horizon_s=2 * cold_start_s),
    ]
