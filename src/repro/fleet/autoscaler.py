"""Pluggable autoscaling policies.

All policies are vectorized over Monte Carlo seeds: ``decide`` receives
(n_seeds,) observation vectors and returns an (n_seeds,) replica target.

* ``StaticPolicy``           — fixed fleet (the paper's one-shot scoping answer).
* ``ReactivePolicy``         — ServerlessContainers-style utilization rules:
  scale up above an upper bound, down below a lower bound, with a per-seed
  cooldown; pays the cold start on every burst.
* ``QueueProportionalPolicy``— targets enough replicas to absorb the current
  arrival rate plus drain the backlog within ``drain_s``.
* ``PredictivePolicy``       — forecasts the arrival rate one cold-start horizon
  ahead and provisions for it; its *shape* is pre-picked by the scoping stack
  (``recommend()`` over CellResult rows) and its capacity estimate comes from a
  ``ResponseSurface`` fitted on the service batch time over the batch grid.
* ``PIPolicy`` / ``PIDPolicy`` — classical feedback in the style of
  ServerlessContainers' ``PIController``: the replica target is a base count
  plus a PI(D) correction on the error between an observed signal
  (utilization or normalized queue depth) and its setpoint, with an
  anti-windup clamp on the integral term. Zero gains degenerate exactly to
  ``StaticPolicy``.
* ``FitToUsagePolicy``       — ServerlessContainers ``Guardian``-style
  fit-to-usage rule: capacity follows the rolling peak of *observed used*
  capacity plus a headroom margin, never demand forecasts.

Each built-in family also has a *functional kernel* — the pure
``init/step``-over-arrays decomposition the compiled simulator backend scans
and batches (``repro.fleet.kernels``). ``Policy.kernel()`` resolves it;
custom subclasses may override it to ride the compiled path, or leave it
returning ``None`` to stay on the numpy reference loop.
"""
from __future__ import annotations

import numpy as np

from repro.core.recommender import Constraint, feasible_ranking, recommend
from repro.core.surfaces import fit_response_surface
from repro.fleet.workload import ServiceModel, service_model_from_cell

_EPS = 1e-12


class Policy:
    """Base: stateless sizing against the bound service's capacity.

    ``per_pool = True`` marks policies whose ``decide`` returns an
    (n_seeds, n_pools) per-pool target for heterogeneous fleets; plain
    policies return (n_seeds,) and only drive single-pool fleets.

    Every policy family also declares its tunable knobs: ``param_space()``
    returns the ``repro.fleet.tuning.ParamSpace`` the autonomous tuner
    searches, and ``from_params(params, **context)`` instantiates the policy
    from one sampled point. ``context`` carries whatever the constructor
    needs beyond the tuned knobs (scoping rows, constraint, fleet...)."""
    name = "policy"
    per_pool = False
    service: ServiceModel = None     # optional shape override (predictive)

    def reset(self, n_seeds: int) -> None:
        pass

    def decide(self, t: int, obs) -> np.ndarray:
        raise NotImplementedError

    @classmethod
    def param_space(cls):
        """The tunable-knob space of this policy family (dims must match the
        keys ``from_params`` consumes)."""
        raise NotImplementedError(f"{cls.__name__} declares no param space")

    @classmethod
    def from_params(cls, params: dict, **context):
        """Build an instance from one sampled ``param_space()`` point."""
        raise NotImplementedError(f"{cls.__name__} declares no param space")

    def kernel(self, fleet, classes, **kw):
        """The functional form of this policy's family for the compiled
        simulator backend (``repro.fleet.kernels.PolicyKernel``), or ``None``
        when the family has none (the numpy reference path then runs the
        object policy as-is). ``self`` doubles as the reference instance for
        family structure that is not a tunable knob (capacity rate,
        base/burst pool split). Subclasses with their own pure
        ``init/step`` decomposition may override this; returning the SAME
        kernel object for equal configs keeps the backend's jit cache warm
        and lets candidate slates batch."""
        from repro.fleet.kernels import make_kernel
        return make_kernel(self, fleet, classes, **kw)


class _RateForecaster:
    """Shared linear-trend forecaster over a rolling arrival-rate window."""

    def __init__(self, window_bins: int, horizon_s: float):
        self.window_bins = max(int(window_bins), 2)
        self.horizon_s = horizon_s
        self._hist = None
        self._n_obs = 0

    def reset(self, n_seeds: int) -> None:
        self._hist = np.zeros((self.window_bins, n_seeds))
        self._n_obs = 0

    def observe(self, obs) -> np.ndarray:
        """Record this bin's arrival rate; return the rate forecast one
        horizon ahead (per seed)."""
        self._hist = np.roll(self._hist, -1, axis=0)
        self._hist[-1] = obs.arrival_rate
        self._n_obs += 1
        w = min(self._n_obs, self.window_bins)
        H = self._hist[-w:]
        if w >= 3:
            x = np.arange(w) - (w - 1) / 2.0
            slope = (x[:, None] * (H - H.mean(axis=0))).sum(axis=0) / (x ** 2).sum()
            return H[-1] + slope * (self.horizon_s / obs.dt_s)
        return H[-1]

    def mean_rate(self) -> np.ndarray:
        """Rolling-mean arrival rate over the observed window (the sustained
        component of demand)."""
        w = min(max(self._n_obs, 1), self.window_bins)
        return self._hist[-w:].mean(axis=0)


def _replicas_for_rate(rate: np.ndarray, service: ServiceModel,
                       headroom: float) -> np.ndarray:
    """Replicas needed to serve ``rate`` req/s at <= ``headroom`` utilization."""
    per = max(service.max_throughput * headroom, _EPS)
    return np.ceil(np.maximum(rate, 0.0) / per)


def _queue_demand(obs, drain_s: float) -> np.ndarray:
    """Backlog-drain demand in req/s. With multiple request classes each
    class's backlog must clear within its own SLO (a 30 s batch backlog is not
    the emergency a 1 s interactive backlog is), so per-class backlog is
    divided by min(drain_s, slo). Single-class observations keep the original
    aggregate rule exactly."""
    if getattr(obs, "class_queue", None) is None or len(obs.classes) <= 1:
        return obs.queue / max(drain_s, obs.dt_s)
    slos = np.array([c.slo_s for c in obs.classes])
    horizon = np.maximum(np.minimum(drain_s, slos), obs.dt_s)
    return (obs.class_queue / horizon[None, :]).sum(axis=1)


class StaticPolicy(Policy):
    name = "static"

    def __init__(self, n_replicas: int):
        self.n = int(n_replicas)

    def decide(self, t, obs):
        return np.full_like(obs.replicas, self.n)

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Integer, ParamSpace
        return ParamSpace((Integer("n_replicas", 1, 64, log=True),))

    @classmethod
    def from_params(cls, params, **context):
        return cls(int(params["n_replicas"]))


class ReactivePolicy(Policy):
    name = "reactive"

    def __init__(self, upper: float = 0.8, lower: float = 0.3,
                 scale_up_frac: float = 0.5, scale_down_frac: float = 0.25,
                 cooldown_s: float = 60.0):
        assert 0.0 <= lower < upper <= 1.0
        self.upper, self.lower = upper, lower
        self.up_frac, self.down_frac = scale_up_frac, scale_down_frac
        self.cooldown_s = cooldown_s
        self._last = None

    def reset(self, n_seeds):
        self._last = np.full(n_seeds, -np.inf)

    def decide(self, t, obs):
        total = obs.replicas + obs.in_flight
        target = total.copy()
        actionable = obs.t_s - self._last >= self.cooldown_s
        # a fleet scaled to zero pins utilization at 0 and the upper-bound rule
        # alone would never fire again — starvation overrides the cooldown
        starved = (total < 1) & ((obs.queue >= 1) | (obs.arrival_rate > 0))
        up = (actionable & (obs.utilization >= self.upper)) | starved
        down = actionable & ~starved & (obs.utilization <= self.lower) \
            & (obs.queue < 1)
        target[up] = np.maximum(
            total[up] + np.maximum(np.ceil(total[up] * self.up_frac), 1), 1)
        target[down] = total[down] - np.maximum(
            np.ceil(total[down] * self.down_frac), 1)
        self._last[up | down] = obs.t_s
        return target

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, ParamSpace
        # lower is parameterized as a fraction of upper so every sampled
        # point satisfies the constructor's 0 <= lower < upper <= 1
        return ParamSpace((
            Continuous("upper", 0.55, 0.95),
            Continuous("lower_frac", 0.1, 0.8),
            Continuous("scale_up_frac", 0.2, 1.0),
            Continuous("scale_down_frac", 0.1, 0.6),
            Continuous("cooldown_s", 10.0, 600.0, log=True),
        ))

    @classmethod
    def from_params(cls, params, **context):
        upper = float(params["upper"])
        return cls(upper=upper,
                   lower=float(params["lower_frac"]) * upper,
                   scale_up_frac=float(params["scale_up_frac"]),
                   scale_down_frac=float(params["scale_down_frac"]),
                   cooldown_s=float(params["cooldown_s"]))


class QueueProportionalPolicy(Policy):
    name = "queue-prop"

    def __init__(self, drain_s: float = 30.0, headroom: float = 0.85):
        self.drain_s = drain_s
        self.headroom = headroom

    def decide(self, t, obs):
        demand = obs.arrival_rate + _queue_demand(obs, self.drain_s)
        return _replicas_for_rate(demand, obs.service, self.headroom)

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, ParamSpace
        return ParamSpace((
            Continuous("drain_s", 5.0, 120.0, log=True),
            Continuous("headroom", 0.55, 0.98),
        ))

    @classmethod
    def from_params(cls, params, **context):
        return cls(drain_s=float(params["drain_s"]),
                   headroom=float(params["headroom"]))


class PredictivePolicy(Policy):
    """Scoping-stack-driven: shape from ``recommend()``, capacity from a
    ``ResponseSurface`` over the service batch time, replicas from a linear
    forecast one cold-start horizon ahead."""
    name = "predictive"

    def __init__(self, rows, constraint: Constraint, units_per_step: float,
                 horizon_s: float = 60.0, window_bins: int = 12,
                 headroom: float = 0.85, max_batch: int = None):
        ref = [r for r in rows
               if float(r.params.get("batch", units_per_step)) == units_per_step]
        self.recommendation = recommend(ref, constraint)
        if self.recommendation.shape is None:
            raise ValueError("predictive policy: no feasible shape "
                             f"({self.recommendation.reason})")
        shape_name = self.recommendation.shape.name
        cell = next(r for r in ref if r.shape_name == shape_name)
        self.service = service_model_from_cell(cell, units_per_step,
                                               max_batch=max_batch)
        # Provisioning capacity from a response surface over the batch
        # dimension, fitted on the same fixed+linear service decomposition the
        # simulator bills (``CellResult.service_terms``): exact on the scoped
        # batch grid, interpolating anywhere else.
        mine = [r for r in rows if r.shape_name == shape_name
                and "batch" in r.params]
        self.surface = None
        if len({float(r.params["batch"]) for r in mine}) >= 3:
            X = np.array([[float(r.params["batch"])] for r in mine])
            y = np.array([sum(r.service_terms(1.0)) for r in mine])
            self.surface = fit_response_surface(["batch"], X, y, degree=2)
            mb = float(self.service.max_batch)
            self._rate = mb / max(self.surface.predict({"batch": mb}), _EPS)
        else:
            self._rate = self.service.max_throughput
        self.horizon_s = horizon_s
        self.forecaster = _RateForecaster(window_bins, horizon_s)
        self.headroom = headroom

    def reset(self, n_seeds):
        self.forecaster.reset(n_seeds)

    def decide(self, t, obs):
        forecast = self.forecaster.observe(obs)
        demand = np.maximum(forecast, obs.arrival_rate) \
            + _queue_demand(obs, self.horizon_s)
        per = max(self._rate * self.headroom, _EPS)
        return np.ceil(np.maximum(demand, 0.0) / per)

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, Integer, ParamSpace
        return ParamSpace((
            Continuous("horizon_s", 10.0, 600.0, log=True),
            Integer("window_bins", 3, 48, log=True),
            Continuous("headroom", 0.55, 0.98),
        ))

    @classmethod
    def from_params(cls, params, *, rows, constraint, units_per_step,
                    max_batch=None, **context):
        return cls(rows, constraint, units_per_step,
                   horizon_s=float(params["horizon_s"]),
                   window_bins=int(params["window_bins"]),
                   headroom=float(params["headroom"]),
                   max_batch=max_batch)


class HeterogeneousPredictivePolicy(Policy):
    """Per-pool predictive autoscaling for mixed-shape fleets.

    ``recommend()``'s feasibility ranking splits the fleet's pools into a
    *baseline* pool (the cheapest feasible shape — head of the ranking) and
    *burst* pools (the rest, in ranking order). The baseline pool tracks the
    sustained arrival rate (rolling mean), so it only moves slowly; the burst
    pools absorb the forecast excess — coarse-grained capacity that spins up
    ahead of a flash crowd and cancels back down after it. Demand the burst
    pools cannot hold (their quota ``max_replicas``) falls back to baseline.

    With a multi-class workload, capacity is split by class criticality: a
    class whose SLO is tighter than the burst pools' cold start cannot wait
    for burst capacity to spin up, so its arrival rate and backlog-drain
    demand floor the always-ready baseline pool instead of riding the
    forecast into the burst pools.
    """
    name = "hetero-predictive"
    per_pool = True

    def __init__(self, rows, constraint: Constraint, units_per_step: float,
                 fleet, horizon_s: float = 60.0, window_bins: int = 12,
                 sustain_bins: int = 60, headroom: float = 0.85):
        self.fleet = fleet
        pool_shapes = {p.service.shape.name for p in fleet.pools}
        ref = [r for r in rows
               if float(r.params.get("batch", units_per_step)) == units_per_step
               and r.shape_name in pool_shapes]
        self.recommendation = recommend(ref, constraint)
        if self.recommendation.shape is None:
            raise ValueError("hetero-predictive policy: no feasible pool shape "
                             f"({self.recommendation.reason})")
        rank = [s.name for _, _, s in feasible_ranking(ref, constraint)]
        pos = {name: i for i, name in enumerate(rank)}
        by_rank = sorted(range(len(fleet.pools)),
                         key=lambda i: (pos.get(
                             fleet.pools[i].service.shape.name, len(rank)), i))
        self.base_idx = by_rank[0]
        self.burst_idx = by_rank[1:]
        self.horizon_s = horizon_s
        self.headroom = headroom
        self.forecaster = _RateForecaster(window_bins, horizon_s)
        self.sustain = _RateForecaster(max(int(sustain_bins), 2), horizon_s)

    def reset(self, n_seeds):
        self.forecaster.reset(n_seeds)
        self.sustain.reset(n_seeds)

    def _per_replica(self, pool) -> float:
        return max(pool.service.max_throughput * self.headroom, _EPS)

    def _critical_demand(self, obs) -> np.ndarray:
        """Demand (req/s) from classes too latency-critical for burst pools:
        their SLO is shorter than the burst cold start, so a backlog would
        miss its deadline before burst capacity comes up."""
        lag = max(self.fleet.pools[i].cold_start_mean_s
                  for i in self.burst_idx)
        crit = np.array([c.slo_s <= lag for c in obs.classes])
        if not crit.any():
            return np.zeros_like(obs.queue)
        slos = np.array([c.slo_s for c in obs.classes])
        horizon = np.maximum(np.minimum(self.horizon_s, slos), obs.dt_s)
        return (obs.class_arrival_rate[:, crit].sum(axis=1)
                + (obs.class_queue[:, crit] / horizon[crit][None, :])
                .sum(axis=1))

    def decide(self, t, obs):
        forecast = self.forecaster.observe(obs)
        self.sustain.observe(obs)
        demand = np.maximum(forecast, obs.arrival_rate) \
            + _queue_demand(obs, self.horizon_s)
        demand = np.maximum(demand, 0.0)
        pools = self.fleet.pools
        target = np.zeros((len(obs.queue), len(pools)))

        base_pool = pools[self.base_idx]
        base_cap = self._per_replica(base_pool)
        base_demand = self.sustain.mean_rate()
        if len(getattr(obs, "classes", ())) > 1 and self.burst_idx:
            base_demand = np.maximum(base_demand, self._critical_demand(obs))
        base = np.clip(np.ceil(base_demand / base_cap),
                       base_pool.min_replicas, base_pool.max_replicas)
        residual = np.maximum(demand - base * base_cap, 0.0)
        for i in self.burst_idx:
            cap = self._per_replica(pools[i])
            n = np.clip(np.ceil(residual / cap),
                        pools[i].min_replicas, pools[i].max_replicas)
            target[:, i] = n
            residual = np.maximum(residual - n * cap, 0.0)
        # overflow beyond every burst quota lands back on the baseline pool
        target[:, self.base_idx] = np.clip(base + np.ceil(residual / base_cap),
                                           base_pool.min_replicas,
                                           base_pool.max_replicas)
        return target

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, Integer, ParamSpace
        return ParamSpace((
            Continuous("horizon_s", 10.0, 600.0, log=True),
            Integer("window_bins", 3, 48, log=True),
            Integer("sustain_bins", 12, 240, log=True),
            Continuous("headroom", 0.55, 0.98),
        ))

    @classmethod
    def from_params(cls, params, *, rows, constraint, units_per_step, fleet,
                    **context):
        return cls(rows, constraint, units_per_step, fleet,
                   horizon_s=float(params["horizon_s"]),
                   window_bins=int(params["window_bins"]),
                   sustain_bins=int(params["sustain_bins"]),
                   headroom=float(params["headroom"]))


class PIPolicy(Policy):
    """Proportional-integral feedback on a utilization or queue setpoint.

    The replica target is ``n_base + round(kp * e + ki * I)`` where the
    error ``e`` is the observed signal minus its setpoint and ``I`` is the
    running error integral, clamped to ``[-windup, +windup]`` (anti-windup:
    a long saturated excursion cannot bank unbounded authority, so the
    controller's reach is bounded by ``n_base + kp * e + ki * windup`` —
    re-centering ``n_base`` is the re-tuner's job when the world shifts).

    ``signal="utilization"`` drives on ``utilization - setpoint``;
    ``signal="queue"`` drives on backlog normalized to the base capacity
    per bin (``queue / (n_base * max_throughput * dt)``), which keeps
    growing past saturation where utilization pins at 1. With
    ``kp == ki == 0`` the policy is exactly ``StaticPolicy(n_base)``.

    A starvation guard holds at least one replica while work is queued or
    arriving: at zero replicas the utilization signal is dead (nothing
    serves, so utilization reads 0), the error pins negative, and the
    integrator locks the fleet at zero forever — the guard is the one
    non-feedback escape from that death spiral."""
    name = "pi"

    def __init__(self, n_base: int, kp: float = 8.0, ki: float = 1.0,
                 setpoint: float = 0.7, signal: str = "utilization",
                 windup: float = 16.0):
        if signal not in ("utilization", "queue"):
            raise ValueError(f"signal must be 'utilization' or 'queue', "
                             f"got {signal!r}")
        if not (np.isfinite(windup) and windup >= 0):
            raise ValueError(f"windup must be >= 0, got {windup}")
        self.n_base = int(n_base)
        self.kp = float(kp)
        self.ki = float(ki)
        self.setpoint = float(setpoint)
        self.signal = signal
        self.windup = float(windup)
        self._i = None

    def reset(self, n_seeds):
        self._i = np.zeros(n_seeds)

    def _error(self, obs) -> np.ndarray:
        if self.signal == "queue":
            cap = max(self.n_base * obs.service.max_throughput * obs.dt_s,
                      _EPS)
            v = obs.queue / cap
        else:
            v = obs.utilization
        return v - self.setpoint

    def _floor(self, target, obs):
        starved = (obs.queue >= 1.0) | (obs.arrival_rate > 0.0)
        return np.maximum(target, np.where(starved, 1.0, 0.0))

    def decide(self, t, obs):
        e = self._error(obs)
        self._i = np.clip(self._i + e, -self.windup, self.windup)
        target = np.maximum(
            np.rint(self.n_base + self.kp * e + self.ki * self._i), 0.0)
        return self._floor(target, obs)

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, Integer, ParamSpace
        return ParamSpace((
            Integer("n_base", 1, 48, log=True),
            Continuous("kp", 0.25, 32.0, log=True),
            Continuous("ki", 0.02, 8.0, log=True),
            Continuous("setpoint", 0.35, 0.9),
            Continuous("windup", 2.0, 64.0, log=True),
        ))

    @classmethod
    def from_params(cls, params, *, signal: str = "utilization", **context):
        return cls(n_base=int(params["n_base"]), kp=float(params["kp"]),
                   ki=float(params["ki"]),
                   setpoint=float(params["setpoint"]), signal=signal,
                   windup=float(params["windup"]))


class PIDPolicy(PIPolicy):
    """``PIPolicy`` plus a derivative term ``kd * (e_t - e_{t-1})`` (the
    previous error starts at 0): the kick damps overshoot on sharp error
    swings. ``kd == 0`` decides identically to ``PIPolicy``."""
    name = "pid"

    def __init__(self, n_base: int, kp: float = 8.0, ki: float = 1.0,
                 kd: float = 0.0, setpoint: float = 0.7,
                 signal: str = "utilization", windup: float = 16.0):
        super().__init__(n_base, kp=kp, ki=ki, setpoint=setpoint,
                         signal=signal, windup=windup)
        self.kd = float(kd)
        self._prev = None

    def reset(self, n_seeds):
        super().reset(n_seeds)
        self._prev = np.zeros(n_seeds)

    def decide(self, t, obs):
        e = self._error(obs)
        self._i = np.clip(self._i + e, -self.windup, self.windup)
        d = e - self._prev
        self._prev = e
        target = np.maximum(
            np.rint(self.n_base + self.kp * e + self.ki * self._i
                    + self.kd * d), 0.0)
        return self._floor(target, obs)

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, ParamSpace
        return PIPolicy.param_space() + ParamSpace((
            Continuous("kd", 0.02, 16.0, log=True),))

    @classmethod
    def from_params(cls, params, *, signal: str = "utilization", **context):
        return cls(n_base=int(params["n_base"]), kp=float(params["kp"]),
                   ki=float(params["ki"]), kd=float(params["kd"]),
                   setpoint=float(params["setpoint"]), signal=signal,
                   windup=float(params["windup"]))


class FitToUsagePolicy(Policy):
    """ServerlessContainers ``Guardian``-style fit-to-usage rule: capacity
    follows *observed usage*, not demand estimates. Each bin records the
    used capacity (``utilization * ready replicas``, in replica
    equivalents); the target is the rolling peak over the last
    ``window_bins`` bins plus a multiplicative ``headroom`` margin. A
    saturated fleet (utilization pinned at 1) therefore grows
    geometrically by ``1 + headroom`` per window until headroom reappears,
    and an idle fleet decays once the peak ages out — with a starvation
    guard holding at least one replica while there is any demand."""
    name = "fit-to-usage"

    def __init__(self, headroom: float = 0.3, window_bins: int = 6):
        if not (np.isfinite(headroom) and headroom >= 0):
            raise ValueError(f"headroom must be >= 0, got {headroom}")
        self.headroom = float(headroom)
        self.window_bins = max(int(window_bins), 1)
        self._hist = None
        self._n_obs = 0

    def reset(self, n_seeds):
        self._hist = np.zeros((self.window_bins, n_seeds))
        self._n_obs = 0

    def decide(self, t, obs):
        used = obs.utilization * np.maximum(obs.replicas, 0.0)
        self._hist = np.roll(self._hist, -1, axis=0)
        self._hist[-1] = used
        self._n_obs += 1
        w = min(self._n_obs, self.window_bins)
        fit = self._hist[-w:].max(axis=0)
        target = np.ceil(fit * (1.0 + self.headroom))
        starved = (obs.queue >= 1) | (obs.arrival_rate > 0)
        return np.maximum(target, np.where(starved, 1.0, 0.0))

    @classmethod
    def param_space(cls):
        from repro.fleet.tuning.space import Continuous, Integer, ParamSpace
        return ParamSpace((
            Continuous("headroom", 0.05, 1.5, log=True),
            Integer("window_bins", 2, 24, log=True),
        ))

    @classmethod
    def from_params(cls, params, **context):
        return cls(headroom=float(params["headroom"]),
                   window_bins=int(params["window_bins"]))


def default_policies(rows, constraint: Constraint, units_per_step: float,
                     static_replicas: int, cold_start_s: float = 30.0) -> list:
    """The four canonical policies, comparably configured."""
    return [
        StaticPolicy(static_replicas),
        ReactivePolicy(cooldown_s=2 * cold_start_s),
        QueueProportionalPolicy(),
        PredictivePolicy(rows, constraint, units_per_step,
                         horizon_s=2 * cold_start_s),
    ]
