"""Service models and multi-class workloads: per-replica request-serving
behaviour derived from the scoping engine, plus the request classes a fleet
serves.

A replica is one container of a given ``CloudShape`` running the workload. Its
batch service time comes straight from a scoping ``CellResult`` via
``CellResult.service_terms`` — fixed (weight-streaming / collective) seconds plus
per-request compute seconds — so batching amortizes ``t_step`` exactly as the
roofline predicts.

A production fleet rarely serves one request stream: interactive traffic with a
sub-second SLO shares capacity with batch backfill that can wait half a minute.
``RequestClass`` names one such stream (its SLO doubles as its EDF relative
deadline); ``Workload`` bundles per-class arrival traces into the multi-class
input the simulator and scheduling disciplines consume.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np

from repro.core.catalog import CloudShape, get_shape
from repro.core.scoping import CellResult
from repro.fleet.traces import Trace


@dataclass(frozen=True)
class ServiceModel:
    """One replica's queueing behaviour: serving b requests takes
    ``t_fixed + b * t_per_unit`` seconds, up to ``max_batch`` per batch."""
    name: str
    shape: CloudShape
    t_fixed: float
    t_per_unit: float
    max_batch: int

    def batch_time(self, b) -> np.ndarray:
        """Seconds to serve a batch of b requests (scalar or array)."""
        return self.t_fixed + np.asarray(b, float) * self.t_per_unit

    def throughput(self, b) -> np.ndarray:
        """Requests/s of one replica running back-to-back batches of size b."""
        b = np.asarray(b, float)
        return b / np.maximum(self.batch_time(b), 1e-12)

    @cached_property
    def max_throughput(self) -> float:
        """Requests/s at full batch — the replica's capacity (cached: the
        simulator and policies read this every bin)."""
        return float(self.throughput(self.max_batch))

    @property
    def usd_per_replica_hour(self) -> float:
        return self.shape.price_per_hour

    @property
    def usd_per_request(self) -> float:
        """Dollars per request at full batch — the cost-efficiency key a
        heterogeneous fleet drains its shared queue by."""
        return self.shape.price_per_hour / max(self.max_throughput * 3600.0,
                                               1e-12)


@dataclass(frozen=True)
class RequestClass:
    """One request class in a multi-class workload.

    ``slo_s`` is the per-request latency SLO and doubles as the class's
    relative deadline under EDF; ``priority`` orders classes under strict
    priority (lower = more critical, FIFO within a class)."""
    name: str
    slo_s: float
    priority: int = 0

    def __post_init__(self):
        if not np.isfinite(self.slo_s) or self.slo_s <= 0:
            raise ValueError(f"class {self.name!r}: slo_s must be a positive "
                             f"finite number, got {self.slo_s}")


@dataclass(frozen=True)
class Workload:
    """Multi-class workload: one arrival ``Trace`` per ``RequestClass``, all
    aligned on the same bins and Monte Carlo seeds."""
    name: str
    classes: tuple          # RequestClass per class
    traces: tuple           # Trace per class, aligned (dt, bins, seeds)

    def __post_init__(self):
        object.__setattr__(self, "classes", tuple(self.classes))
        object.__setattr__(self, "traces", tuple(self.traces))
        if not self.classes or len(self.classes) != len(self.traces):
            raise ValueError("Workload needs one trace per class "
                             f"({len(self.classes)} classes, "
                             f"{len(self.traces)} traces)")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names: {names}")
        ref = self.traces[0]
        for tr in self.traces[1:]:
            if (tr.dt_s != ref.dt_s or tr.n_bins != ref.n_bins
                    or tr.n_seeds != ref.n_seeds):
                raise ValueError(
                    "class traces must share dt/bins/seeds: "
                    f"({ref.dt_s}, {ref.n_bins}, {ref.n_seeds}) vs "
                    f"({tr.dt_s}, {tr.n_bins}, {tr.n_seeds})")

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    @property
    def dt_s(self) -> float:
        return self.traces[0].dt_s

    @property
    def n_bins(self) -> int:
        return self.traces[0].n_bins

    @property
    def n_seeds(self) -> int:
        return self.traces[0].n_seeds

    @property
    def duration_s(self) -> float:
        return self.traces[0].duration_s

    @property
    def arrivals(self) -> np.ndarray:
        """(n_seeds, n_bins, n_classes) sampled request counts."""
        return np.stack([tr.arrivals for tr in self.traces], axis=2)

    def slos(self) -> np.ndarray:
        return np.array([c.slo_s for c in self.classes], float)

    def total_trace(self) -> Trace:
        """The aggregate arrival stream (for aggregate reporting)."""
        return Trace(name=self.name, dt_s=self.dt_s,
                     rate=np.sum([tr.rate for tr in self.traces], axis=0),
                     arrivals=np.sum([tr.arrivals for tr in self.traces],
                                     axis=0))

    @staticmethod
    def from_trace(trace: Trace, slo_s: float, name: str = None,
                   class_name: str = "default") -> "Workload":
        """Wrap a single-class trace (the pre-multi-class simulator input)."""
        return Workload(name or trace.name,
                        (RequestClass(class_name, slo_s),), (trace,))


def service_model_from_cell(cell: CellResult, units_per_step: float,
                            max_batch: int = None, name: str = None,
                            shape: CloudShape = None) -> ServiceModel:
    """Build a ServiceModel from one scoping row.

    ``units_per_step`` is how many requests the scoped step batched (the cell's
    batch dimension); ``max_batch`` defaults to it.
    """
    t_fixed, t_unit = cell.service_terms(units_per_step)
    shape = shape if shape is not None else get_shape(cell.shape_name)
    mb = int(max_batch if max_batch is not None else units_per_step)
    return ServiceModel(
        name=name or f"{cell.shape_name}",
        shape=shape,
        t_fixed=float(t_fixed),
        t_per_unit=float(max(t_unit, 1e-12)),
        max_batch=max(mb, 1),
    )
