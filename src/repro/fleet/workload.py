"""Service models: per-replica request-serving behaviour derived from the
scoping engine.

A replica is one container of a given ``CloudShape`` running the workload. Its
batch service time comes straight from a scoping ``CellResult`` via
``CellResult.service_terms`` — fixed (weight-streaming / collective) seconds plus
per-request compute seconds — so batching amortizes ``t_step`` exactly as the
roofline predicts.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np

from repro.core.catalog import CloudShape, get_shape
from repro.core.scoping import CellResult


@dataclass(frozen=True)
class ServiceModel:
    """One replica's queueing behaviour: serving b requests takes
    ``t_fixed + b * t_per_unit`` seconds, up to ``max_batch`` per batch."""
    name: str
    shape: CloudShape
    t_fixed: float
    t_per_unit: float
    max_batch: int

    def batch_time(self, b) -> np.ndarray:
        """Seconds to serve a batch of b requests (scalar or array)."""
        return self.t_fixed + np.asarray(b, float) * self.t_per_unit

    def throughput(self, b) -> np.ndarray:
        """Requests/s of one replica running back-to-back batches of size b."""
        b = np.asarray(b, float)
        return b / np.maximum(self.batch_time(b), 1e-12)

    @cached_property
    def max_throughput(self) -> float:
        """Requests/s at full batch — the replica's capacity (cached: the
        simulator and policies read this every bin)."""
        return float(self.throughput(self.max_batch))

    @property
    def usd_per_replica_hour(self) -> float:
        return self.shape.price_per_hour

    @property
    def usd_per_request(self) -> float:
        """Dollars per request at full batch — the cost-efficiency key a
        heterogeneous fleet drains its shared queue by."""
        return self.shape.price_per_hour / max(self.max_throughput * 3600.0,
                                               1e-12)


def service_model_from_cell(cell: CellResult, units_per_step: float,
                            max_batch: int = None, name: str = None,
                            shape: CloudShape = None) -> ServiceModel:
    """Build a ServiceModel from one scoping row.

    ``units_per_step`` is how many requests the scoped step batched (the cell's
    batch dimension); ``max_batch`` defaults to it.
    """
    t_fixed, t_unit = cell.service_terms(units_per_step)
    shape = shape if shape is not None else get_shape(cell.shape_name)
    mb = int(max_batch if max_batch is not None else units_per_step)
    return ServiceModel(
        name=name or f"{cell.shape_name}",
        shape=shape,
        t_fixed=float(t_fixed),
        t_per_unit=float(max(t_unit, 1e-12)),
        max_batch=max(mb, 1),
    )
