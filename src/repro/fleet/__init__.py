# Fleet layer: what happens to a recommended Shape under live traffic.
# traces -> queueing simulation -> scaling policy -> SLO/cost report, closing
# the loop from the paper's Monte Carlo cost surfaces to fleet operating cost.
from repro.fleet.autoscaler import (Policy, PredictivePolicy,
                                    QueueProportionalPolicy, ReactivePolicy,
                                    StaticPolicy, default_policies)
from repro.fleet.report import (REPORT_HEADERS, FleetReport, comparison_table,
                                summarize, weighted_percentile)
from repro.fleet.scenarios import Scenario, lm_decode_scenario, mset_scenario
from repro.fleet.simulator import FleetObs, SimResult, simulate
from repro.fleet.traces import (Trace, diurnal_trace, flash_crowd_trace,
                                poisson_trace, ramp_trace, replay_trace,
                                standard_traces)
from repro.fleet.workload import ServiceModel, service_model_from_cell

__all__ = [
    "Policy", "PredictivePolicy", "QueueProportionalPolicy", "ReactivePolicy",
    "StaticPolicy", "default_policies", "REPORT_HEADERS", "FleetReport",
    "comparison_table", "summarize", "weighted_percentile", "Scenario",
    "lm_decode_scenario", "mset_scenario", "FleetObs", "SimResult", "simulate",
    "Trace", "diurnal_trace", "flash_crowd_trace", "poisson_trace",
    "ramp_trace", "replay_trace", "standard_traces", "ServiceModel",
    "service_model_from_cell",
]
