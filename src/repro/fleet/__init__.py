# Fleet layer: what happens to a recommended Shape under live traffic.
# traces -> multi-class workloads -> queueing simulation (homogeneous or
# mixed-shape pools, FIFO/priority/EDF scheduling disciplines, exact
# per-request latency via the cohort model) -> scaling policy -> per-class
# SLO/cost report, closing the loop from the paper's Monte Carlo cost
# surfaces to fleet operating cost. The tuning subpackage turns the loop on
# the controller itself: `tune()` autonomously scopes autoscaler/fleet
# parameters by racing candidate configs through the simulator; the oracle
# subpackage compiles those tuner sweeps into a constant-time lookup service.
from repro.fleet import control, telemetry
from repro.fleet.oracle import (OracleAnswer, OracleCell, OracleGrid,
                                OracleTable, ScopingOracle, TraceFeatures,
                                VerificationReport, build_oracle,
                                canonical_trace, featurize, query_latency_us,
                                verify_oracle)
from repro.fleet.autoscaler import (FitToUsagePolicy,
                                    HeterogeneousPredictivePolicy, PIDPolicy,
                                    PIPolicy, Policy, PredictivePolicy,
                                    QueueProportionalPolicy, ReactivePolicy,
                                    StaticPolicy, default_policies)
from repro.fleet.control import (ClosedLoopController, ControlEvent,
                                 ControlResult, DriftCase,
                                 service_degradation_case, tail_workload)
from repro.fleet.cohort import (CohortMetrics, cohort_metrics,
                                multiclass_cohort_metrics, row_searchsorted)
from repro.fleet.discipline import (DISCIPLINES, CohortQueue, Discipline,
                                    EDFDiscipline, FIFODiscipline,
                                    PriorityDiscipline, cohort_tables,
                                    get_discipline, split_service)
from repro.fleet.kernels import KernelObs, PolicyKernel, make_kernel
from repro.fleet.report import (CLASS_HEADERS, REPORT_HEADERS, ClassReport,
                                FleetReport, WindowMetrics, best_per_trace,
                                class_table, comparison_table,
                                cost_efficiency_table, summarize,
                                telemetry_dashboard, weighted_percentile,
                                window_metrics)
from repro.fleet.scenarios import (Scenario, interactive_batch_workload,
                                   lm_decode_scenario, mset_scenario,
                                   tiered_sla_workload)
from repro.fleet.simulator import (FleetConfig, FleetObs, PoolConfig,
                                   SegmentedSimulation, SimResult,
                                   draw_cold_start_delays, simulate,
                                   simulate_fleet)
from repro.fleet.traces import (Trace, diurnal_trace, flash_crowd_trace,
                                load_trace_csv, poisson_trace, ramp_trace,
                                replay_trace, resample_trace, standard_traces)
from repro.fleet.tuning import (CandidateEval, Categorical, Continuous,
                                Integer, Objective, ParamSpace, RaceResult,
                                TuningBudget, TuningReport, TuningScenario,
                                discipline_dim, evaluate_candidates,
                                evaluate_candidates_column, exhaustive,
                                pareto_frontier, quota_dims, race,
                                race_column, robust_m, robust_weights, tune,
                                tuning_scenario, warm_start_candidates)
from repro.fleet.workload import (RequestClass, ServiceModel, Workload,
                                  service_model_from_cell)

__all__ = [
    "FitToUsagePolicy", "PIDPolicy", "PIPolicy",
    "ClosedLoopController", "ControlEvent", "ControlResult", "DriftCase",
    "service_degradation_case", "tail_workload", "control",
    "SegmentedSimulation", "WindowMetrics", "window_metrics",
    "warm_start_candidates",
    "HeterogeneousPredictivePolicy", "Policy", "PredictivePolicy",
    "QueueProportionalPolicy", "ReactivePolicy", "StaticPolicy",
    "default_policies", "CohortMetrics", "cohort_metrics",
    "multiclass_cohort_metrics", "row_searchsorted", "DISCIPLINES",
    "CohortQueue", "Discipline", "EDFDiscipline", "FIFODiscipline",
    "PriorityDiscipline", "cohort_tables", "get_discipline", "split_service",
    "KernelObs", "PolicyKernel", "make_kernel", "draw_cold_start_delays",
    "CLASS_HEADERS",
    "REPORT_HEADERS", "ClassReport", "FleetReport", "best_per_trace",
    "class_table", "comparison_table", "cost_efficiency_table", "summarize",
    "telemetry_dashboard", "weighted_percentile", "Scenario", "interactive_batch_workload",
    "lm_decode_scenario", "mset_scenario", "tiered_sla_workload",
    "FleetConfig", "FleetObs", "PoolConfig", "SimResult", "simulate",
    "simulate_fleet", "Trace", "diurnal_trace", "flash_crowd_trace",
    "load_trace_csv", "poisson_trace", "ramp_trace", "replay_trace",
    "resample_trace", "standard_traces", "RequestClass", "ServiceModel", "Workload",
    "service_model_from_cell", "CandidateEval", "Categorical", "Continuous",
    "Integer", "Objective", "ParamSpace", "RaceResult", "TuningBudget",
    "TuningReport", "TuningScenario", "discipline_dim",
    "evaluate_candidates", "evaluate_candidates_column", "exhaustive",
    "pareto_frontier", "quota_dims", "race", "race_column", "robust_m",
    "robust_weights", "tune", "tuning_scenario", "telemetry",
    "OracleAnswer", "OracleCell", "OracleGrid", "OracleTable",
    "ScopingOracle", "TraceFeatures", "VerificationReport", "build_oracle",
    "canonical_trace", "featurize", "query_latency_us", "verify_oracle",
]
