# Fleet layer: what happens to a recommended Shape under live traffic.
# traces -> queueing simulation (homogeneous or mixed-shape pools, exact
# per-request FIFO latency via the cohort model) -> scaling policy -> SLO/cost
# report, closing the loop from the paper's Monte Carlo cost surfaces to fleet
# operating cost.
from repro.fleet.autoscaler import (HeterogeneousPredictivePolicy, Policy,
                                    PredictivePolicy, QueueProportionalPolicy,
                                    ReactivePolicy, StaticPolicy,
                                    default_policies)
from repro.fleet.cohort import CohortMetrics, cohort_metrics, row_searchsorted
from repro.fleet.report import (REPORT_HEADERS, FleetReport, best_per_trace,
                                comparison_table, cost_efficiency_table,
                                summarize, weighted_percentile)
from repro.fleet.scenarios import Scenario, lm_decode_scenario, mset_scenario
from repro.fleet.simulator import (FleetConfig, FleetObs, PoolConfig,
                                   SimResult, simulate, simulate_fleet)
from repro.fleet.traces import (Trace, diurnal_trace, flash_crowd_trace,
                                poisson_trace, ramp_trace, replay_trace,
                                standard_traces)
from repro.fleet.workload import ServiceModel, service_model_from_cell

__all__ = [
    "HeterogeneousPredictivePolicy", "Policy", "PredictivePolicy",
    "QueueProportionalPolicy", "ReactivePolicy", "StaticPolicy",
    "default_policies", "CohortMetrics", "cohort_metrics", "row_searchsorted",
    "REPORT_HEADERS", "FleetReport", "best_per_trace", "comparison_table",
    "cost_efficiency_table", "summarize", "weighted_percentile", "Scenario",
    "lm_decode_scenario", "mset_scenario", "FleetConfig", "FleetObs",
    "PoolConfig", "SimResult", "simulate", "simulate_fleet", "Trace",
    "diurnal_trace", "flash_crowd_trace", "poisson_trace", "ramp_trace",
    "replay_trace", "standard_traces", "ServiceModel",
    "service_model_from_cell",
]
