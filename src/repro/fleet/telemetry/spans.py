"""Nested wall-clock spans: the tracing half of the telemetry layer.

A span is one timed phase of a larger operation — ``tune`` wraps sampling,
each racing round, the SPRT culls, and the surface refine; the compiled
backend wraps every jitted dispatch (tagged cold/warm, which is what splits
compile-seconds from steady-state dispatch-seconds). Spans nest: entering a
span inside another parents it, so a completed trace is a tree whose rendered
form is the timing breakdown ``TuningReport.summary()`` prints.

Unlike the metrics registry (deterministic by construction), spans carry real
``time.perf_counter`` durations — they are profiling output, never inputs to
any simulation, so telemetry's bit-exactness guarantee is untouched.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Span:
    """One timed phase. ``duration_s`` is None while the span is open."""
    name: str
    attrs: dict = field(default_factory=dict)
    t0: float = 0.0
    duration_s: float = None
    children: list = field(default_factory=list)

    def total(self, name: str) -> float:
        """Summed duration of every descendant (or self) named ``name``."""
        mine = self.duration_s or 0.0 if self.name == name else 0.0
        return mine + sum(c.total(name) for c in self.children)

    def find(self, name: str):
        """First descendant (or self) named ``name``, depth-first."""
        if self.name == name:
            return self
        for c in self.children:
            hit = c.find(name)
            if hit is not None:
                return hit
        return None

    def self_s(self) -> float:
        """Duration not attributed to any child span."""
        return max((self.duration_s or 0.0)
                   - sum(c.duration_s or 0.0 for c in self.children), 0.0)

    def walk(self, depth: int = 0, path: str = ""):
        """(span, depth, /-joined path) triples, depth-first preorder."""
        p = f"{path}/{self.name}" if path else self.name
        yield self, depth, p
        for c in self.children:
            yield from c.walk(depth + 1, p)


def _fmt_attrs(attrs: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(attrs.items(),
                                                  key=lambda kv: str(kv[0])))


def render_spans(roots, unit_s: float = None) -> str:
    """ASCII tree of one or more span trees with durations and attrs::

        tune                        4.213s
          sample                    0.002s  n=24 sampler=lhs
          race                      3.950s
            round                   1.201s  alive=24 s0=0 s1=2
    """
    lines = []
    width = max((len("  " * d + s.name) for r in roots
                 for s, d, _ in r.walk()), default=0) + 2
    for root in roots:
        for s, d, _ in root.walk():
            label = "  " * d + s.name
            dur = "   open " if s.duration_s is None \
                else f"{s.duration_s:7.3f}s"
            attrs = _fmt_attrs(s.attrs)
            lines.append(f"{label:<{width}}{dur}" + (f"  {attrs}" if attrs
                                                     else ""))
    return "\n".join(lines)


class SpanTracer:
    """Collects span trees for one telemetry session."""

    def __init__(self, clock=time.perf_counter):
        self.roots: list = []
        self._stack: list = []
        self._clock = clock

    @contextmanager
    def span(self, name: str, **attrs):
        s = Span(name=name, attrs=attrs, t0=self._clock())
        (self._stack[-1].children if self._stack else self.roots).append(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            s.duration_s = self._clock() - s.t0
            self._stack.pop()

    def current(self):
        return self._stack[-1] if self._stack else None

    def find(self, name: str):
        """Last root-level tree containing ``name`` wins (a session may run
        several tunes; callers want the one just finished)."""
        for root in reversed(self.roots):
            hit = root.find(name)
            if hit is not None:
                return hit
        return None

    def total(self, name: str) -> float:
        return sum(r.total(name) for r in self.roots)

    def render(self) -> str:
        return render_spans(self.roots)

    def to_events(self) -> list:
        """Flattened span records for the JSONL exporter."""
        out = []
        for root in self.roots:
            for s, depth, path in root.walk():
                out.append({"type": "span", "name": s.name, "path": path,
                            "depth": depth, "duration_s": s.duration_s,
                            **{f"attr_{k}": v for k, v in s.attrs.items()}})
        return out
