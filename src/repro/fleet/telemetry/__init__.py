"""Opt-in fleet telemetry: structured metrics, wall-clock span tracing, a
drift-probe substrate, and exporters (JSONL / Prometheus text / ASCII
dashboard).

The paper's autonomous loop is built on *observing* the running container —
its MSET+SPRT prognostic engine consumes telemetry streams to detect
deviation from the predicted envelope. This package is that observation
layer for the fleet pipeline: the simulator records per-bin metric streams,
the tuner and the compiled backend record timing spans, and
:mod:`repro.fleet.telemetry.drift` feeds the observed service-time stream
back into ``repro.mset`` as a residual monitor.

Usage — telemetry is **off by default**; instrumented code paths are exact
no-ops (bit-identical results, negligible overhead) until a session is
opened::

    from repro.fleet import telemetry

    with telemetry.session() as tel:
        sim = simulate_fleet(workload, fleet, policy)
        report = tune(scenario)
    print(tel.dashboard())          # ASCII sparklines
    print(tel.tracer.render())      # span tree
    tel.export_jsonl("events.jsonl")

Instrumented code calls the module-level helpers (:func:`span`,
:func:`counter`, :func:`event`, :func:`record`), which dispatch to the
innermost active session or do nothing. Sessions nest (a scoped probe inside
a long-lived session records to the inner one alone); the stack is
thread-local in spirit but process-global in fact, matching the repo's
single-threaded simulators.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field

from repro.fleet.telemetry import export
from repro.fleet.telemetry.metrics import (
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Series,
    label_str,
    record_sim,
    service_time_stream,
)
from repro.fleet.telemetry.spans import Span, SpanTracer, render_spans

__all__ = [
    "Telemetry", "session", "active", "span", "counter", "gauge", "event",
    "record",
    "MetricsRegistry", "Counter", "Gauge", "Series", "Histogram",
    "DEFAULT_TIME_BUCKETS", "label_str", "record_sim", "service_time_stream",
    "Span", "SpanTracer", "render_spans", "export",
    # lazy (see __getattr__): DriftProbe, DriftReport, telemetry_matrix,
    "drift",
]


@dataclass
class Telemetry:
    """One telemetry session: a metrics registry + a span tracer + an ad-hoc
    event list, with exporter conveniences."""
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: SpanTracer = field(default_factory=SpanTracer)
    events: list = field(default_factory=list)

    def event(self, name: str, **fields) -> dict:
        ev = {"name": name, **fields}
        self.events.append(ev)
        return ev

    def export_jsonl(self, path) -> int:
        """Write events + metrics + spans as a JSONL log; returns #lines."""
        return export.write_jsonl(path, registry=self.metrics,
                                  tracer=self.tracer, events=self.events)

    def prometheus(self) -> str:
        return export.prometheus_text(self.metrics)

    def dashboard(self, width: int = 60) -> str:
        return export.dashboard(self.metrics, width=width)


_STACK: list = []


def active() -> Telemetry:
    """The innermost active session, or ``None`` (telemetry disabled)."""
    return _STACK[-1] if _STACK else None


@contextmanager
def session(tel: Telemetry = None):
    """Enable telemetry for the dynamic extent of the block. Yields the
    :class:`Telemetry` session (a fresh one unless ``tel`` is passed)."""
    tel = tel if tel is not None else Telemetry()
    _STACK.append(tel)
    try:
        yield tel
    finally:
        _STACK.pop()


@contextmanager
def span(name: str, **attrs):
    """Time a phase in the active session's tracer; no-op when disabled.
    Yields the open :class:`Span` (or ``None``)."""
    tel = active()
    if tel is None:
        yield None
        return
    with tel.tracer.span(name, **attrs) as s:
        yield s


def counter(name: str, value: float = 1.0, **labels) -> None:
    """Increment a counter in the active session; no-op when disabled."""
    tel = active()
    if tel is not None:
        tel.metrics.counter(name, **labels).inc(value)


def gauge(name: str, value: float, **labels) -> None:
    """Set a gauge in the active session; no-op when disabled."""
    tel = active()
    if tel is not None:
        tel.metrics.gauge(name, **labels).set(value)


def event(name: str, **fields) -> None:
    """Append an ad-hoc event in the active session; no-op when disabled."""
    tel = active()
    if tel is not None:
        tel.event(name, **fields)


def record(sim, slot_bt=None, slot_served=None, order=None) -> None:
    """Record a ``SimResult``'s metric streams into the active session;
    no-op when disabled. The simulator calls this from its shared
    ``_assemble_result`` path so both backends emit identical streams."""
    tel = active()
    if tel is not None:
        record_sim(tel.metrics, sim, slot_bt=slot_bt,
                   slot_served=slot_served, order=order)


_LAZY = ("DriftProbe", "DriftReport", "DEFAULT_SIGNALS", "telemetry_matrix",
         "degrade_fleet", "drift")


def __getattr__(name: str):
    # drift pulls in jax + repro.mset; keep the core session machinery
    # importable without touching either.
    if name in _LAZY:
        import importlib
        mod = importlib.import_module("repro.fleet.telemetry.drift")
        if name == "drift":
            return mod
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
