"""Drift probe: MSET+SPRT residual monitoring over fleet telemetry streams —
the observation half of the ROADMAP "closed-loop autonomous control (drift →
re-scope → re-tune)" item.

The paper's prognostic engine watches the running container's telemetry and
alarms when it leaves the predicted envelope. Here the envelope is learned
from a *baseline* simulation's metric streams (observed per-bin service
times, queue depth, utilization): :class:`DriftProbe` trains an MSET
similarity model (``repro.mset``) on the baseline matrix, then runs a Wald
SPRT (``repro.mset.sprt``) over the standardized residuals of any later
observation window. A fleet whose service model has silently degraded (the
injected drift scenario: slower per-batch times under the same policy and
trace) produces residuals whose mean shifts by several sigma, tripping the
SPRT within a few bins — while a fresh unperturbed replicate stays quiet.

This is deliberately *probe only*: it flags drift and reports when; acting
on the flag (re-scope, re-tune) is the next ROADMAP plank.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.mset import SPRTParams, estimate, sprt, train

# Streams the probe monitors, in matrix column order.
DEFAULT_SIGNALS = ("service_time_s", "utilization", "queue_depth")

_SIGMA_FLOOR = 1e-4


def telemetry_matrix(sim, signals=DEFAULT_SIGNALS) -> np.ndarray:
    """(T, n_signals) observation matrix from a ``SimResult`` — the same
    per-bin seed-mean streams ``record_sim`` emits, assembled directly so the
    probe works on bare results without an active session."""
    from repro.fleet.telemetry.metrics import service_time_stream

    cols = []
    for sig in signals:
        if sig == "service_time_s":
            cols.append(service_time_stream(sim))
        elif sig == "utilization":
            cols.append(np.asarray(sim.utilization, float).mean(axis=0))
        elif sig == "queue_depth":
            cols.append(np.asarray(sim.queue, float).mean(axis=0))
        elif sig == "arrival_rate":
            cols.append(np.asarray(sim.arrivals, float).mean(axis=0)
                        / sim.dt_s)
        elif sig == "replicas":
            cols.append(np.asarray(sim.replicas, float).mean(axis=0))
        else:
            raise ValueError(f"unknown drift signal {sig!r}; expected one of "
                             "service_time_s, utilization, queue_depth, "
                             "arrival_rate, replicas")
    return np.stack(cols, axis=1)


def degrade_fleet(fleet, factor: float):
    """The injected-drift scenario: the same fleet with every pool's service
    times inflated by ``factor`` (slower fixed overhead *and* per-unit time —
    a node whose effective throughput has silently decayed). ``factor=1`` is
    the identity."""
    from dataclasses import replace

    pools = tuple(
        replace(p, service=replace(p.service,
                                   t_fixed=p.service.t_fixed * factor,
                                   t_per_unit=p.service.t_per_unit * factor))
        for p in fleet.pools)
    return replace(fleet, pools=pools)


@dataclass
class DriftReport:
    """Verdict of one :meth:`DriftProbe.check` window."""
    drifted: bool
    first_alarm_bin: int            # -1 when quiet
    alarm_bins: int                 # bins with >= 1 signal alarming
    alarm_fraction: float           # alarmed (bin, signal) cells / total
    per_signal_alarms: dict         # signal name -> alarmed bin count
    n_bins: int
    signals: tuple

    def summary(self) -> str:
        verdict = "DRIFT" if self.drifted else "ok"
        parts = ", ".join(f"{k}={v}" for k, v in
                          self.per_signal_alarms.items())
        where = (f" first at bin {self.first_alarm_bin}"
                 if self.first_alarm_bin >= 0 else "")
        return (f"[{verdict}] {self.alarm_bins}/{self.n_bins} bins alarmed"
                f"{where} ({parts})")


@dataclass
class DriftProbe:
    """MSET+SPRT residual monitor over fleet telemetry.

    ``fit`` learns the envelope from a baseline ``SimResult``; ``check``
    scores an observation window (another ``SimResult`` or a raw (T, n)
    matrix) and returns a :class:`DriftReport`. ``min_alarm_bins`` is the
    persistence filter: one stray SPRT trip is noise, a run of them is
    drift."""
    signals: tuple = DEFAULT_SIGNALS
    n_memvec: int = 48
    sprt_params: SPRTParams = field(
        default_factory=lambda: SPRTParams(alpha=1e-4, beta=1e-4,
                                           m_shift=4.0))
    min_alarm_bins: int = 8
    # held-out calibration rows still share the baseline's Monte Carlo
    # draws, so their residual spread underestimates the noise of a truly
    # fresh replicate window; widen the envelope by this factor
    sigma_scale: float = 2.0
    model: object = field(default=None, repr=False)
    sigma: np.ndarray = field(default=None, repr=False)
    mu: np.ndarray = field(default=None, repr=False)

    def fit(self, baseline, signals=None) -> "DriftProbe":
        """Train on a baseline ``SimResult`` (or (T, n) matrix): build the
        MSET memory matrix and calibrate the residual scale the SPRT
        standardizes against.

        Calibration is held out: MSET trains on the even-indexed bins and the
        residual mean/std come from the odd-indexed bins. In-sample residuals
        are near zero (the memory matrix reconstructs its own training data),
        so calibrating on them makes *any* fresh replicate look like a
        multi-sigma shift — the held-out split measures honest out-of-sample
        reconstruction noise across the whole operating envelope."""
        if signals is not None:
            self.signals = tuple(signals)
        X = self._matrix(baseline)
        fit_rows, cal_rows = X[0::2], X[1::2]
        if len(cal_rows) < 8:           # too short to split; fall back
            fit_rows = cal_rows = X
        self.model = train(fit_rows, min(self.n_memvec, fit_rows.shape[0]))
        _, resid = estimate(self.model, cal_rows)
        resid = np.asarray(resid, float)
        self.mu = resid.mean(axis=0)
        self.sigma = np.maximum(resid.std(axis=0) * self.sigma_scale,
                                _SIGMA_FLOOR)
        return self

    def check(self, observed) -> DriftReport:
        """Score an observation window against the fitted envelope."""
        if self.model is None:
            raise RuntimeError("DriftProbe.check before fit()")
        X = self._matrix(observed)
        import jax.numpy as jnp

        _, resid = estimate(self.model, X)
        alarms, _, _ = sprt(jnp.asarray(resid), jnp.asarray(self.sigma),
                            self.sprt_params, mu=jnp.asarray(self.mu))
        a = np.asarray(alarms, bool)            # (T, n)
        bin_alarm = a.any(axis=1)
        alarm_bins = int(bin_alarm.sum())
        drifted = alarm_bins >= self.min_alarm_bins
        first = int(np.argmax(bin_alarm)) if alarm_bins else -1
        per_sig = {sig: int(a[:, j].sum())
                   for j, sig in enumerate(self.signals)}
        report = DriftReport(
            drifted=drifted, first_alarm_bin=first, alarm_bins=alarm_bins,
            alarm_fraction=float(a.mean()), per_signal_alarms=per_sig,
            n_bins=int(a.shape[0]), signals=tuple(self.signals))
        self._emit(report)
        return report

    def _matrix(self, obj) -> np.ndarray:
        if isinstance(obj, np.ndarray):
            X = np.asarray(obj, float)
            if X.ndim != 2 or X.shape[1] != len(self.signals):
                raise ValueError(f"expected (T, {len(self.signals)}) matrix, "
                                 f"got shape {X.shape}")
            return X
        return telemetry_matrix(obj, self.signals)

    def _emit(self, report: DriftReport) -> None:
        from repro.fleet import telemetry

        telemetry.counter("fleet_drift_checks_total",
                          verdict="drift" if report.drifted else "ok")
        telemetry.event("drift_check", drifted=report.drifted,
                        first_alarm_bin=report.first_alarm_bin,
                        alarm_bins=report.alarm_bins,
                        n_bins=report.n_bins,
                        alarm_fraction=report.alarm_fraction)
