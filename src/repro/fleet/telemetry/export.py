"""Telemetry exporters: JSONL event log, Prometheus text exposition, and an
ASCII sparkline dashboard.

All three render the same :class:`~repro.fleet.telemetry.metrics.MetricsRegistry`
(plus the span tracer and ad-hoc events for JSONL), so a session exports to
whichever sink fits: JSONL for machine-readable archives (the CI bench job
uploads one as an artifact), Prometheus text for scrape endpoints, the
dashboard for terminals.
"""
from __future__ import annotations

import json

import numpy as np

from repro.fleet.telemetry.metrics import MetricsRegistry

# 8-level unicode sparkline ramp (" " for empty bins keeps rows aligned)
_SPARK = "▁▂▃▄▅▆▇█"


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    items = sorted((str(k), str(v)) for k, v in labels.items())
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


def _prom_num(v: float) -> str:
    if v != v:
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus text exposition format (version 0.0.4). Counters and gauges
    export as-is; a series exports its last value as a gauge (the "current"
    sample a scraper would see) plus a ``_bins`` gauge with its length;
    histograms export cumulative ``_bucket{le=...}`` rows, ``_sum`` and
    ``_count``."""
    by_name: dict = {}
    kinds: dict = {}
    for name, labels, m in registry.items():
        kind = type(m).__name__.lower()
        kinds[name] = kind
        by_name.setdefault(name, []).append((labels, m))
    lines = []
    for name in sorted(by_name):
        kind = kinds[name]
        if kind == "series":
            lines.append(f"# TYPE {name} gauge")
            for labels, m in by_name[name]:
                last = m.values[-1] if m.values else float("nan")
                lines.append(f"{name}{_prom_labels(labels)} "
                             f"{_prom_num(last)}")
                lines.append(f"{name}_bins{_prom_labels(labels)} "
                             f"{len(m.values)}")
            continue
        if kind == "histogram":
            lines.append(f"# TYPE {name} histogram")
            for labels, m in by_name[name]:
                cum = m.cumulative()
                for le, c in zip(m.buckets, cum):
                    lab = dict(labels)
                    lab["le"] = _prom_num(le)
                    lines.append(f"{name}_bucket{_prom_labels(lab)} "
                                 f"{_prom_num(float(c))}")
                lines.append(f"{name}_sum{_prom_labels(labels)} "
                             f"{_prom_num(m.sum)}")
                lines.append(f"{name}_count{_prom_labels(labels)} "
                             f"{_prom_num(m.count)}")
            continue
        lines.append(f"# TYPE {name} {kind}")
        for labels, m in by_name[name]:
            lines.append(f"{name}{_prom_labels(labels)} {_prom_num(m.value)}")
    return "\n".join(lines) + "\n"


def metric_events(registry: MetricsRegistry) -> list:
    """One JSON-able record per instrument (the JSONL metric dump)."""
    out = []
    for name, labels, m in registry.items():
        kind = type(m).__name__.lower()
        rec = {"type": kind, "name": name, "labels": dict(labels)}
        if kind in ("counter", "gauge"):
            rec["value"] = m.value
        elif kind == "series":
            rec["values"] = list(m.values)
        else:
            rec.update(buckets=list(m.buckets),
                       counts=[float(c) for c in m.counts],
                       sum=m.sum, count=m.count)
        out.append(rec)
    return out


def write_jsonl(path, registry: MetricsRegistry = None, tracer=None,
                events=None) -> int:
    """Write the session's telemetry as a JSONL event log — one JSON object
    per line: ad-hoc events first (in emission order), then metrics, then
    spans. Returns the number of lines written."""
    records = []
    for ev in (events or []):
        records.append({"type": "event", **ev})
    if registry is not None:
        records.extend(metric_events(registry))
    if tracer is not None:
        records.extend(tracer.to_events())
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True,
                               default=_json_default) + "\n")
    return len(records)


def _json_default(v):
    if isinstance(v, (np.floating, np.integer)):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.tolist()
    if v == float("inf"):
        return "+Inf"
    return str(v)


def sparkline(values, width: int = 60) -> str:
    """Compress a series into ``width`` sparkline chars (block ramp, scaled
    to the series' own min..max; a flat series renders mid-ramp)."""
    v = np.asarray(values, float).ravel()
    v = v[np.isfinite(v)]
    if v.size == 0:
        return ""
    if v.size > width:
        # mean-pool into `width` windows so bursts stay visible
        edges = np.linspace(0, v.size, width + 1).astype(int)
        v = np.array([v[a:b].mean() if b > a else v[min(a, v.size - 1)]
                      for a, b in zip(edges[:-1], edges[1:])])
    lo, hi = float(v.min()), float(v.max())
    if hi - lo <= 1e-12:
        return _SPARK[3] * len(v)
    idx = ((v - lo) / (hi - lo) * (len(_SPARK) - 1)).round().astype(int)
    return "".join(_SPARK[i] for i in idx)


def dashboard(registry: MetricsRegistry, width: int = 60) -> str:
    """ASCII sparkline dashboard over every series in the registry, plus a
    compact totals line per counter family and bucket-quantile summaries per
    histogram — the terminal rendering ``repro.fleet.report`` wires into
    fleet reports."""
    from repro.fleet.telemetry.metrics import label_str

    series, counters, hists = [], {}, []
    for name, labels, m in registry.items():
        kind = type(m).__name__.lower()
        if kind == "series":
            series.append((name, labels, m))
        elif kind == "counter":
            counters.setdefault(name, []).append((labels, m))
        elif kind == "histogram":
            hists.append((name, labels, m))
    lines = []
    if series:
        label_w = max(len(_series_label(n, lb)) for n, lb, _ in series) + 2
        for name, labels, m in series:
            v = m.array()
            stats = (f"min {v.min():.3g}  mean {v.mean():.3g}  "
                     f"max {v.max():.3g}" if v.size else "empty")
            lines.append(f"{_series_label(name, labels):<{label_w}}"
                         f"{sparkline(v, width):<{width}}  {stats}")
    if hists:
        lines.append("")
        for name, labels, m in hists:
            lines.append(f"{_series_label(name, labels)}: "
                         f"count {m.count:.0f}  mean "
                         f"{(m.sum / m.count if m.count else float('nan')):.3g}"
                         f"  p50<={m.quantile(0.5):g}  p99<={m.quantile(0.99):g}")
    if counters:
        lines.append("")
        for name in sorted(counters):
            parts = ", ".join(
                f"{label_str(labels) or 'total'}={m.value:g}"
                for labels, m in counters[name])
            lines.append(f"{name}: {parts}")
    return "\n".join(lines)


def _series_label(name: str, labels: dict) -> str:
    from repro.fleet.telemetry.metrics import label_str
    ls = label_str(labels)
    return f"{name}{{{ls}}}" if ls else name
