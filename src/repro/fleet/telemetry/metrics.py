"""Structured fleet metrics: counters, gauges, fixed-bucket histograms and
per-bin series, all labeled (pool, request class, policy family, ...).

The registry is the passive half of the telemetry layer: instruments are
plain accumulators with no clocks and no I/O, so recording is deterministic —
two runs of the same seeded simulation populate byte-identical registries,
and the numpy and JAX simulator backends emit *identical* streams because
both are recorded from the shared ``simulator._assemble_result`` arrays, not
from backend-internal state.

Naming follows Prometheus conventions (``snake_case``, ``_total`` suffix on
counters, ``_seconds`` units); ``repro.fleet.telemetry.export`` renders the
registry as Prometheus text exposition, JSONL events, or an ASCII sparkline
dashboard.

Metric catalog populated by :func:`record_sim` (one call per simulation):

====================================  =========  ==============================
name                                  kind       labels
====================================  =========  ==============================
``fleet_sim_runs_total``              counter    ``policy``, ``backend-shared``
``fleet_arrived_total``               counter    ``cls``
``fleet_admitted_total``              counter    ``cls``
``fleet_shed_total``                  counter    ``cls``
``fleet_served_total``                counter    ``cls``
``fleet_deadline_miss_total``         counter    ``cls``
``fleet_queue_depth``                 series     ``cls``
``fleet_replicas_ready``              series     ``pool``
``fleet_replicas_pending``            series     ``pool``
``fleet_arrival_rate``                series     —
``fleet_utilization``                 series     —
``fleet_service_time_s``              series     — (per-bin observed mean
                                                 sojourn; the drift probe's
                                                 residual-monitor input)
``fleet_sojourn_seconds``             histogram  ``cls``
``fleet_batch_time_seconds``          histogram  ``pool``
``fleet_preemptions_total``           counter    — (substep core only)
``fleet_residue_bins``                counter    — (bins ending with
                                                 in-flight/checkpointed work)
``fleet_preempted_work``              series     — (batch-seconds preempted
                                                 per bin; substep core only)
====================================  =========  ==============================

Per-seed traces are reduced over the Monte Carlo axis before recording
(counters: mean total per replicate; series: per-bin seed means) so streams
have one value per time bin regardless of the replicate budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# Latency-shaped default buckets (seconds): sub-10 ms to 5 min, +Inf.
DEFAULT_TIME_BUCKETS = (0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                        10.0, 30.0, 60.0, 120.0, 300.0, float("inf"))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def label_str(labels) -> str:
    """Canonical ``k=v,k2=v2`` rendering (sorted; '' for no labels)."""
    items = labels.items() if isinstance(labels, dict) else labels
    return ",".join(f"{k}={v}" for k, v in sorted(
        (str(k), str(v)) for k, v in items))


@dataclass
class Counter:
    """Monotone accumulator (``_total`` metrics)."""
    name: str
    labels: dict
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += float(v)


@dataclass
class Gauge:
    """Last-write-wins point value."""
    name: str
    labels: dict
    value: float = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


@dataclass
class Series:
    """A per-bin stream (one float per simulated time bin, appended in
    order). The time-indexed metric the sparkline dashboard plots and the
    drift probe consumes."""
    name: str
    labels: dict
    values: list = field(default_factory=list)

    def extend(self, vals) -> None:
        self.values.extend(float(v) for v in np.asarray(vals, float).ravel())

    def append(self, v: float) -> None:
        self.values.append(float(v))

    def array(self) -> np.ndarray:
        return np.asarray(self.values, float)


@dataclass
class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus ``le`` semantics):
    ``counts[i]`` is the mass with value <= ``buckets[i]``. ``observe``
    accepts weighted batches (per-request sojourns weighted by cohort
    mass)."""
    name: str
    labels: dict
    buckets: tuple = DEFAULT_TIME_BUCKETS
    counts: np.ndarray = None
    sum: float = 0.0
    count: float = 0.0

    def __post_init__(self):
        self.buckets = tuple(float(b) for b in self.buckets)
        if list(self.buckets) != sorted(self.buckets) or \
                self.buckets[-1] != float("inf"):
            raise ValueError(f"histogram {self.name!r}: buckets must be "
                             "sorted and end with +inf")
        if self.counts is None:
            self.counts = np.zeros(len(self.buckets))

    def observe(self, values, weights=None) -> None:
        v = np.asarray(values, float).ravel()
        w = np.ones_like(v) if weights is None \
            else np.asarray(weights, float).ravel()
        keep = w > 0
        v, w = v[keep], w[keep]
        if v.size == 0:
            return
        idx = np.searchsorted(np.asarray(self.buckets[:-1]), v, side="left")
        np.add.at(self.counts, idx, w)
        self.sum += float((v * w).sum())
        self.count += float(w.sum())

    def cumulative(self) -> np.ndarray:
        return np.cumsum(self.counts)

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the covering bucket)."""
        if self.count <= 0:
            return float("nan")
        cum = self.cumulative()
        i = int(np.searchsorted(cum, q * self.count, side="left"))
        return self.buckets[min(i, len(self.buckets) - 1)]


_KINDS = {"counter": Counter, "gauge": Gauge, "series": Series,
          "histogram": Histogram}


class MetricsRegistry:
    """Labeled metric store. ``counter/gauge/series/histogram`` get-or-create
    the instrument for (name, labels); one name maps to one kind."""

    def __init__(self):
        self._metrics: dict = {}     # (name, label_key) -> instrument
        self._kind_of: dict = {}     # name -> kind str

    def _get(self, kind: str, name: str, labels: dict, **kw):
        have = self._kind_of.setdefault(name, kind)
        if have != kind:
            raise ValueError(f"metric {name!r} already registered as {have}, "
                             f"not {kind}")
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = _KINDS[kind](name=name, labels=dict(labels), **kw)
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def series(self, name: str, **labels) -> Series:
        return self._get("series", name, labels)

    def histogram(self, name: str, buckets=DEFAULT_TIME_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, buckets=buckets)

    def get(self, name: str, **labels):
        """The instrument for (name, labels), or ``None``."""
        return self._metrics.get((name, _label_key(labels)))

    def __len__(self) -> int:
        return len(self._metrics)

    def items(self):
        """(name, labels, instrument) triples in deterministic order."""
        for key in sorted(self._metrics):
            m = self._metrics[key]
            yield m.name, m.labels, m

    def snapshot(self) -> dict:
        """Plain-python deterministic dump: ``{kind: {name: {label_str:
        value-ish}}}``. Two identically-seeded runs produce equal
        snapshots; the numpy and JAX backends produce equal snapshots."""
        out = {"counter": {}, "gauge": {}, "series": {}, "histogram": {}}
        for name, labels, m in self.items():
            kind = self._kind_of[name]
            slot = out[kind].setdefault(name, {})
            ls = label_str(labels)
            if kind == "counter" or kind == "gauge":
                slot[ls] = m.value
            elif kind == "series":
                slot[ls] = list(m.values)
            else:
                slot[ls] = {"buckets": list(m.buckets),
                            "counts": [float(c) for c in m.counts],
                            "sum": m.sum, "count": m.count}
        return out


def service_time_stream(sim) -> np.ndarray:
    """Observed per-bin mean request sojourn (seconds), served-mass-weighted
    across Monte Carlo seeds — the telemetry signal the paper's MSET+SPRT
    prognostic engine monitors for drift. Bins with no served mass carry 0."""
    served = np.asarray(sim.served, float)
    mass = np.asarray(sim.latency_s, float) * served
    tot = served.sum(axis=0)
    return np.divide(mass.sum(axis=0), tot,
                     out=np.zeros_like(tot), where=tot > 0)


def record_sim(registry: MetricsRegistry, sim, slot_bt=None, slot_served=None,
               order=None) -> None:
    """Populate the fleet metric catalog (module docstring) from one
    ``SimResult``. Called by ``simulator._assemble_result`` for every
    simulation run under an active telemetry session — both backends funnel
    through that one assembly path, so their streams are identical. Also
    callable on a bare ``SimResult`` (e.g. the report dashboard);
    ``slot_bt``/``slot_served``/``order`` add the per-pool batch-time
    histogram when the assembly-time slot arrays are at hand."""
    S = sim.arrivals.shape[0]
    registry.counter("fleet_sim_runs_total", policy=sim.policy_name).inc()

    classes = sim.classes or ()
    names = [c.name for c in classes] or ["default"]
    for c, cname in enumerate(names):
        adm = sim.class_admitted[:, :, c] if sim.class_admitted is not None \
            else sim.admitted
        drp = sim.class_dropped[:, :, c] if sim.class_dropped is not None \
            else sim.dropped
        srv = sim.class_served[:, :, c] if sim.class_served is not None \
            else sim.served
        ok = sim.class_ok[:, :, c] if sim.class_ok is not None \
            else sim.ok_served
        qd = sim.class_queue[:, :, c] if sim.class_queue is not None \
            else sim.queue
        registry.counter("fleet_arrived_total", cls=cname).inc(
            float((adm + drp).sum()) / S)
        registry.counter("fleet_admitted_total", cls=cname).inc(
            float(adm.sum()) / S)
        registry.counter("fleet_shed_total", cls=cname).inc(
            float(drp.sum()) / S)
        registry.counter("fleet_served_total", cls=cname).inc(
            float(srv.sum()) / S)
        registry.counter("fleet_deadline_miss_total", cls=cname).inc(
            float((srv - ok).sum()) / S)
        registry.series("fleet_queue_depth", cls=cname).extend(
            qd.mean(axis=0))
        if sim.class_sojourns:
            vals, wts = sim.class_sojourns[c]
            registry.histogram("fleet_sojourn_seconds", cls=cname) \
                .observe(vals, wts)

    for p, pc in enumerate(sim.fleet.pools):
        ready = sim.pool_replicas[:, :, p]
        pending = sim.pool_billed[:, :, p] - ready
        registry.series("fleet_replicas_ready", pool=pc.label).extend(
            ready.mean(axis=0))
        registry.series("fleet_replicas_pending", pool=pc.label).extend(
            pending.mean(axis=0))

    registry.series("fleet_arrival_rate").extend(
        sim.arrivals.mean(axis=0) / sim.dt_s)
    registry.series("fleet_utilization").extend(sim.utilization.mean(axis=0))
    registry.series("fleet_service_time_s").extend(service_time_stream(sim))

    if sim.preemptions is not None:
        # substep-core extras: how often the discipline interrupted a running
        # batch, and how much work was carried across bins as residue
        registry.counter("fleet_preemptions_total").inc(
            float(sim.preemptions.sum()) / S)
        registry.counter("fleet_residue_bins").inc(
            float((sim.residue_work > 0.0).sum()) / S)
        registry.series("fleet_preempted_work").extend(
            sim.preempted_work.mean(axis=0))

    if slot_bt is not None and slot_served is not None and order is not None:
        # slot arrays are drain-rank ordered; label by the pool each rank is
        for rank, p in enumerate(order):
            registry.histogram("fleet_batch_time_seconds",
                               pool=sim.fleet.pools[p].label) \
                .observe(slot_bt[:, :, rank], slot_served[:, :, rank])
