"""Seeded synthetic request traces for the fleet simulator.

Each generator produces a deterministic rate profile lambda(t) (requests/s per
time bin) and Monte Carlo-samples Poisson arrival counts over ``n_seeds``
independent seeds — the fleet-level analogue of the paper's nested-loop Monte
Carlo over workload draws. The (n_seeds, n_bins) count array is what the
vectorized simulator consumes.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass

import numpy as np

_LOG = logging.getLogger(__name__)


@dataclass(frozen=True)
class Trace:
    """Monte Carlo arrival trace: ``arrivals[s, t]`` requests in bin t, seed s.

    ``base_rate`` (optional) is the pre-rescale rate profile: when a loader
    rescales a recorded trace to a target mean (``load_trace_csv``'s
    ``mean_rate_per_s=``), the raw profile is kept here so *shape* statistics
    (peak/mean burstiness, ramp sharpness — the scoping oracle's features)
    stay bit-identical to the recording instead of drifting by float rounding
    through the multiply. ``shape_profile`` is what feature extraction reads.
    """
    name: str
    dt_s: float
    rate: np.ndarray        # (n_bins,) expected requests/s per bin
    arrivals: np.ndarray    # (n_seeds, n_bins) sampled request counts
    base_rate: np.ndarray = None   # pre-rescale profile (None: rate is raw)

    @property
    def shape_profile(self) -> np.ndarray:
        """The profile shape statistics should be computed from: the
        pre-rescale recording when one exists, else the rate itself."""
        return self.rate if self.base_rate is None else self.base_rate

    @property
    def n_seeds(self) -> int:
        return self.arrivals.shape[0]

    @property
    def n_bins(self) -> int:
        return self.arrivals.shape[1]

    @property
    def duration_s(self) -> float:
        return self.n_bins * self.dt_s

    @property
    def peak_rate(self) -> float:
        return float(self.rate.max())

    @property
    def mean_rate(self) -> float:
        return float(self.rate.mean())


def _sample(name: str, rate: np.ndarray, dt_s: float, n_seeds: int,
            seed: int) -> Trace:
    rate = np.clip(np.asarray(rate, float), 0.0, None)
    rng = np.random.default_rng(seed)
    arrivals = rng.poisson(rate[None, :] * dt_s, size=(n_seeds, len(rate)))
    return Trace(name, dt_s, rate, arrivals)


def _bins(duration_s: float, dt_s: float) -> np.ndarray:
    n = max(int(round(duration_s / dt_s)), 1)
    return (np.arange(n) + 0.5) * dt_s


def poisson_trace(rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                  n_seeds: int = 8, seed: int = 0) -> Trace:
    """Steady-state load: constant lambda."""
    t = _bins(duration_s, dt_s)
    return _sample("poisson", np.full(len(t), rate_per_s), dt_s, n_seeds, seed)


def diurnal_trace(mean_rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                  amplitude: float = 0.8, period_s: float = 86400.0,
                  phase: float = 0.0, n_seeds: int = 8, seed: int = 0) -> Trace:
    """Day/night sinusoid: lambda(t) = mean * (1 + A sin(2*pi*t/period + phase))."""
    t = _bins(duration_s, dt_s)
    rate = mean_rate_per_s * (1.0 + amplitude * np.sin(2 * np.pi * t / period_s + phase))
    return _sample("diurnal", rate, dt_s, n_seeds, seed)


def flash_crowd_trace(base_rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                      peak_mult: float = 8.0, t_burst_s: float = None,
                      burst_width_s: float = None, n_seeds: int = 8,
                      seed: int = 0) -> Trace:
    """Flash crowd: baseline with a Gaussian burst peaking at ``peak_mult`` x base."""
    t = _bins(duration_s, dt_s)
    t0 = duration_s / 2 if t_burst_s is None else t_burst_s
    w = duration_s / 12 if burst_width_s is None else burst_width_s
    rate = base_rate_per_s * (1.0 + (peak_mult - 1.0) * np.exp(-0.5 * ((t - t0) / w) ** 2))
    return _sample("flash-crowd", rate, dt_s, n_seeds, seed)


def ramp_trace(rate0_per_s: float, rate1_per_s: float, duration_s: float,
               dt_s: float = 1.0, n_seeds: int = 8, seed: int = 0) -> Trace:
    """Linear growth (e.g. a launch ramping to steady state)."""
    t = _bins(duration_s, dt_s)
    rate = rate0_per_s + (rate1_per_s - rate0_per_s) * t / duration_s
    return _sample("ramp", rate, dt_s, n_seeds, seed)


def replay_trace(rates_per_s, dt_s: float = 1.0, n_seeds: int = 8, seed: int = 0,
                 name: str = "replay", base_rate=None) -> Trace:
    """Replay a recorded per-bin rate profile (production traces, CSV columns...).
    ``base_rate`` carries the pre-rescale profile when ``rates_per_s`` was
    rescaled from a recording (see ``Trace.shape_profile``)."""
    tr = _sample(name, np.asarray(rates_per_s, float), dt_s, n_seeds, seed)
    if base_rate is None:
        return tr
    return Trace(tr.name, tr.dt_s, tr.rate, tr.arrivals,
                 base_rate=np.asarray(base_rate, float))


def resample_trace(trace: Trace, dt_s: float, seed: int = 0) -> Trace:
    """Split a recorded trace onto a finer time grid without re-sampling its
    Poisson draws.

    Each coarse bin's sampled count is distributed over ``k = trace.dt_s /
    dt_s`` fine bins by a seeded uniform multinomial — exactly the
    conditional law of a Poisson stream given its bin total, so the fine
    trace is a *refinement* of the same arrival realization, not a fresh
    draw: per-seed totals are conserved to the request, and two calls with
    the same ``seed`` split identically. This is what lets a coarse recorded
    replay (e.g. the 60-second Azure profile) drive a fine-Δt simulator core
    while staying paired with its coarse-core baseline.

    ``dt_s`` must divide ``trace.dt_s`` to a whole number of fine bins;
    ``k == 1`` returns the trace unchanged.
    """
    k_f = trace.dt_s / float(dt_s)
    k = int(round(k_f))
    if k < 1 or abs(k_f - k) > 1e-9 * max(k, 1):
        raise ValueError(f"dt_s={dt_s} does not divide the trace's bin "
                         f"width {trace.dt_s} into whole fine bins")
    if k == 1:
        return trace
    rate = np.repeat(trace.rate, k)         # requests/s: value is unchanged
    S, T = trace.arrivals.shape
    fine = np.empty((S, T * k), dtype=trace.arrivals.dtype)
    p = np.full(k, 1.0 / k)
    for s in range(S):
        rng = np.random.default_rng((seed, s))
        fine[s] = rng.multinomial(trace.arrivals[s].astype(np.int64),
                                  p).reshape(T * k)
    base = (None if trace.base_rate is None
            else np.repeat(trace.base_rate, k))
    return Trace(f"{trace.name}@{dt_s:g}s", float(dt_s), rate, fine,
                 base_rate=base)


def load_trace_csv(path, rate_col=1, dt_s: float = 60.0, *, mean_rate_per_s:
                   float = None, n_seeds: int = 8, seed: int = 0,
                   name: str = None, delimiter: str = ",") -> Trace:
    """Load a recorded rate profile from a CSV file into a ``replay_trace``.

    ``rate_col`` is a 0-based column index or a header name; a leading header
    row and ``#`` comment lines are tolerated (a header is required when
    ``rate_col`` is a name; with an index, the first row counts as the
    header only when *none* of its cells parse as numbers — a data row with
    a corrupt cell cannot masquerade as a header and is rejected instead).
    ``dt_s`` is the recording's bin width.
    Rows whose rate cell is missing, unparseable, or non-finite raise a
    ``ValueError`` naming the offending line — a silently skipped gap would
    shift every later bin in time. ``mean_rate_per_s`` (optional) rescales
    the profile so its mean matches a target rate — replaying a public
    trace's *shape* against a fleet sized in this repo's request units.
    """
    import os

    rates, header, col = [], None, None
    n_skipped = 0                   # blank / comment / header lines
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            row = line.strip()
            if not row or row.startswith("#"):
                n_skipped += 1
                continue
            cells = [c.strip() for c in row.split(delimiter)]
            if header is None:
                # resolve the rate column on the first non-comment row; with
                # an index column that row is a header only when NO cell is
                # numeric, so a data row with a corrupt label still raises
                # below instead of being swallowed as a "header"
                if isinstance(rate_col, str):
                    if rate_col not in cells:
                        raise ValueError(f"{path}: no column {rate_col!r} in "
                                         f"header {cells}")
                    header, col = cells, cells.index(rate_col)
                    n_skipped += 1
                    continue
                col = int(rate_col)

                def _numeric(c):
                    try:
                        float(c)
                        return True
                    except ValueError:
                        return False
                if cells and not any(_numeric(c) for c in cells):
                    header = cells          # label-only row: a real header
                    n_skipped += 1
                    continue
                header = []   # any numeric cell = data row; bad rate cells
                #               fall through to the named-line errors below
            if col >= len(cells):
                raise ValueError(f"{path}:{lineno}: row has {len(cells)} "
                                 f"column(s), rate column is {col}")
            try:
                r = float(cells[col])
            except ValueError:
                raise ValueError(f"{path}:{lineno}: rate cell "
                                 f"{cells[col]!r} is not a number") from None
            if not np.isfinite(r):
                raise ValueError(f"{path}:{lineno}: non-finite rate {r!r}")
            rates.append(r)
    if not rates:
        raise ValueError(f"{path}: no data rows")
    rates = np.clip(np.asarray(rates, float), 0.0, None)
    raw, rescale = None, 1.0
    if mean_rate_per_s is not None:
        mean = rates.mean()
        if mean <= 0:
            raise ValueError(f"{path}: all-zero trace cannot be rescaled "
                             f"to mean {mean_rate_per_s}")
        rescale = mean_rate_per_s / mean
        # the rescaled profile drives sampling, but shape statistics
        # (burstiness = peak/mean, ramp) must come from the recording: the
        # per-bin multiply rounds, so peak/mean on the rescaled array can
        # drift off the recording's by float ulps — enough to miss an exact
        # oracle grid cell. Keep the raw profile on the Trace.
        raw, rates = rates, rates * rescale
    stem = os.path.splitext(os.path.basename(str(path)))[0]
    # record what the loader did to the raw profile — a silently rescaled
    # trace is indistinguishable from the recording it came from
    from repro.fleet import telemetry
    telemetry.event("trace_csv_loaded", path=str(path), rows=len(rates),
                    skipped_rows=n_skipped, rescale_factor=float(rescale),
                    mean_rate_per_s=float(rates.mean()))
    if rescale != 1.0 or n_skipped:
        _LOG.info("load_trace_csv %s: %d data rows (%d non-data lines "
                  "skipped), mean-rate rescale factor %.6g",
                  path, len(rates), n_skipped, rescale)
    return replay_trace(rates, dt_s, n_seeds, seed, name=name or stem,
                        base_rate=raw)


def standard_traces(mean_rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                    n_seeds: int = 8, seed: int = 0) -> list:
    """The canonical evaluation set: steady, diurnal, flash crowd, ramp."""
    return [
        poisson_trace(mean_rate_per_s, duration_s, dt_s, n_seeds, seed),
        diurnal_trace(mean_rate_per_s, duration_s, dt_s,
                      period_s=duration_s, n_seeds=n_seeds, seed=seed + 1),
        flash_crowd_trace(mean_rate_per_s / 2, duration_s, dt_s,
                          burst_width_s=duration_s / 30,
                          n_seeds=n_seeds, seed=seed + 2),
        ramp_trace(mean_rate_per_s / 4, 2 * mean_rate_per_s, duration_s, dt_s,
                   n_seeds=n_seeds, seed=seed + 3),
    ]
