"""Seeded synthetic request traces for the fleet simulator.

Each generator produces a deterministic rate profile lambda(t) (requests/s per
time bin) and Monte Carlo-samples Poisson arrival counts over ``n_seeds``
independent seeds — the fleet-level analogue of the paper's nested-loop Monte
Carlo over workload draws. The (n_seeds, n_bins) count array is what the
vectorized simulator consumes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Trace:
    """Monte Carlo arrival trace: ``arrivals[s, t]`` requests in bin t, seed s."""
    name: str
    dt_s: float
    rate: np.ndarray        # (n_bins,) expected requests/s per bin
    arrivals: np.ndarray    # (n_seeds, n_bins) sampled request counts

    @property
    def n_seeds(self) -> int:
        return self.arrivals.shape[0]

    @property
    def n_bins(self) -> int:
        return self.arrivals.shape[1]

    @property
    def duration_s(self) -> float:
        return self.n_bins * self.dt_s

    @property
    def peak_rate(self) -> float:
        return float(self.rate.max())

    @property
    def mean_rate(self) -> float:
        return float(self.rate.mean())


def _sample(name: str, rate: np.ndarray, dt_s: float, n_seeds: int,
            seed: int) -> Trace:
    rate = np.clip(np.asarray(rate, float), 0.0, None)
    rng = np.random.default_rng(seed)
    arrivals = rng.poisson(rate[None, :] * dt_s, size=(n_seeds, len(rate)))
    return Trace(name, dt_s, rate, arrivals)


def _bins(duration_s: float, dt_s: float) -> np.ndarray:
    n = max(int(round(duration_s / dt_s)), 1)
    return (np.arange(n) + 0.5) * dt_s


def poisson_trace(rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                  n_seeds: int = 8, seed: int = 0) -> Trace:
    """Steady-state load: constant lambda."""
    t = _bins(duration_s, dt_s)
    return _sample("poisson", np.full(len(t), rate_per_s), dt_s, n_seeds, seed)


def diurnal_trace(mean_rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                  amplitude: float = 0.8, period_s: float = 86400.0,
                  phase: float = 0.0, n_seeds: int = 8, seed: int = 0) -> Trace:
    """Day/night sinusoid: lambda(t) = mean * (1 + A sin(2*pi*t/period + phase))."""
    t = _bins(duration_s, dt_s)
    rate = mean_rate_per_s * (1.0 + amplitude * np.sin(2 * np.pi * t / period_s + phase))
    return _sample("diurnal", rate, dt_s, n_seeds, seed)


def flash_crowd_trace(base_rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                      peak_mult: float = 8.0, t_burst_s: float = None,
                      burst_width_s: float = None, n_seeds: int = 8,
                      seed: int = 0) -> Trace:
    """Flash crowd: baseline with a Gaussian burst peaking at ``peak_mult`` x base."""
    t = _bins(duration_s, dt_s)
    t0 = duration_s / 2 if t_burst_s is None else t_burst_s
    w = duration_s / 12 if burst_width_s is None else burst_width_s
    rate = base_rate_per_s * (1.0 + (peak_mult - 1.0) * np.exp(-0.5 * ((t - t0) / w) ** 2))
    return _sample("flash-crowd", rate, dt_s, n_seeds, seed)


def ramp_trace(rate0_per_s: float, rate1_per_s: float, duration_s: float,
               dt_s: float = 1.0, n_seeds: int = 8, seed: int = 0) -> Trace:
    """Linear growth (e.g. a launch ramping to steady state)."""
    t = _bins(duration_s, dt_s)
    rate = rate0_per_s + (rate1_per_s - rate0_per_s) * t / duration_s
    return _sample("ramp", rate, dt_s, n_seeds, seed)


def replay_trace(rates_per_s, dt_s: float = 1.0, n_seeds: int = 8, seed: int = 0,
                 name: str = "replay") -> Trace:
    """Replay a recorded per-bin rate profile (production traces, CSV columns...)."""
    return _sample(name, np.asarray(rates_per_s, float), dt_s, n_seeds, seed)


def standard_traces(mean_rate_per_s: float, duration_s: float, dt_s: float = 1.0,
                    n_seeds: int = 8, seed: int = 0) -> list:
    """The canonical evaluation set: steady, diurnal, flash crowd, ramp."""
    return [
        poisson_trace(mean_rate_per_s, duration_s, dt_s, n_seeds, seed),
        diurnal_trace(mean_rate_per_s, duration_s, dt_s,
                      period_s=duration_s, n_seeds=n_seeds, seed=seed + 1),
        flash_crowd_trace(mean_rate_per_s / 2, duration_s, dt_s,
                          burst_width_s=duration_s / 30,
                          n_seeds=n_seeds, seed=seed + 2),
        ramp_trace(mean_rate_per_s / 4, 2 * mean_rate_per_s, duration_s, dt_s,
                   n_seeds=n_seeds, seed=seed + 3),
    ]
