"""Oracle verification: spot-check compiled answers against fresh simulation.

A lookup table is only as good as its sweep — so the verifier samples query
points inside the gridded region (off the grid points, where interpolation
actually happens), synthesizes a *fresh* canonical trace at each point with
seeds the builder never saw, simulates the oracle's answered config, and
compares the simulated cost/attainment against what the oracle predicted.
The report's error bounds are the oracle's trust certificate: the bench
gate pins them, and a drifted table (stale fleet menu, changed service
model) fails here before it mis-scopes anything in production.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field

import numpy as np

from repro.fleet.oracle.build import OracleTable, canonical_trace
from repro.fleet.oracle.oracle import ScopingOracle
from repro.fleet.tuning.evaluate import TuningScenario, evaluate_candidates
from repro.fleet.workload import Workload

_LOG = logging.getLogger(__name__)


@dataclass(frozen=True)
class SpotCheck:
    """One sampled query vs its fresh simulation."""
    mean_rate: float
    burstiness: float
    slo_s: float
    params: dict
    predicted_cost: float
    simulated_cost: float
    predicted_attainment: float
    simulated_attainment: float
    exact: bool
    predicted_bound: float = float("nan")   # answer's cost_bound_usd_hr

    @property
    def cost_err(self) -> float:
        """Relative cost error |sim - predicted| / sim."""
        return abs(self.simulated_cost - self.predicted_cost) \
            / max(self.simulated_cost, 1e-12)

    @property
    def cost_overrun(self) -> float:
        """Relative amount the simulated cost exceeds the answer's upper
        bound (0 when within bound). The directional failure that matters:
        an oracle that *under*-promises is merely conservative, one whose
        bound is busted mis-scopes budgets."""
        if not np.isfinite(self.predicted_bound):
            return 0.0
        return max(0.0, self.simulated_cost - self.predicted_bound) \
            / max(self.predicted_bound, 1e-12)

    @property
    def attainment_err(self) -> float:
        return abs(self.simulated_attainment - self.predicted_attainment)


@dataclass
class VerificationReport:
    """Error bounds over the sampled spot-checks."""
    checks: list = field(default_factory=list)
    refused: int = 0

    @property
    def n(self) -> int:
        return len(self.checks)

    @property
    def max_cost_err(self) -> float:
        return max((c.cost_err for c in self.checks), default=float("nan"))

    @property
    def mean_cost_err(self) -> float:
        if not self.checks:
            return float("nan")
        return float(np.mean([c.cost_err for c in self.checks]))

    @property
    def max_cost_overrun(self) -> float:
        return max((c.cost_overrun for c in self.checks),
                   default=float("nan"))

    @property
    def max_attainment_err(self) -> float:
        return max((c.attainment_err for c in self.checks),
                   default=float("nan"))

    def ok(self, cost_tol: float = 0.25, attainment_tol: float = 0.05,
           overrun_tol: float = 0.05) -> bool:
        """Every spot-check within tolerance and none refused. The cost
        bound is gated tight (``overrun_tol``); the symmetric point error
        looser (``cost_tol``) — interpolating between cells whose winners
        differ overestimates cost, which is the safe direction."""
        return (self.n > 0 and self.refused == 0
                and self.max_cost_err <= cost_tol
                and self.max_cost_overrun <= overrun_tol
                and self.max_attainment_err <= attainment_tol)

    def to_json(self) -> dict:
        return {"n": self.n, "refused": self.refused,
                "max_cost_err": self.max_cost_err,
                "mean_cost_err": self.mean_cost_err,
                "max_cost_overrun": self.max_cost_overrun,
                "max_attainment_err": self.max_attainment_err,
                "checks": [{
                    "mean_rate": c.mean_rate, "burstiness": c.burstiness,
                    "slo_s": c.slo_s, "params": dict(c.params),
                    "predicted_cost": c.predicted_cost,
                    "predicted_bound": c.predicted_bound,
                    "simulated_cost": c.simulated_cost,
                    "predicted_attainment": c.predicted_attainment,
                    "simulated_attainment": c.simulated_attainment,
                    "exact": c.exact} for c in self.checks]}

    def summary(self) -> str:
        if not self.checks:
            return f"oracle verify: no checks ran ({self.refused} refused)"
        return (f"oracle verify: {self.n} spot-checks, cost error "
                f"mean {self.mean_cost_err * 100:.1f}% / "
                f"max {self.max_cost_err * 100:.1f}%, attainment error "
                f"max {self.max_attainment_err * 100:.2f}pp"
                + (f", {self.refused} refused" if self.refused else ""))


def _sample_points(table: OracleTable, n: int, seed: int) -> list:
    """n query points uniform over the hull in each axis's own scale —
    strictly interior (5%..95% of each span), so interpolation is exercised
    rather than the verbatim grid-point fast path."""
    g = table.grid
    rng = np.random.default_rng(seed)
    pts = []
    for _ in range(n):
        u = rng.uniform(0.05, 0.95, size=3)
        mr = g.mean_rates[0] * (g.mean_rates[-1] / g.mean_rates[0]) ** u[0]
        b = g.burstiness[0] + u[1] * (g.burstiness[-1] - g.burstiness[0])
        slo = g.slos[0] * (g.slos[-1] / g.slos[0]) ** u[2]
        pts.append((float(mr), float(b), float(slo)))
    return pts


def verify_oracle(table: OracleTable, fleet, policy_cls, *,
                  n_samples: int = 5, seed: int = 12345,
                  context: dict = None, discipline: str = "fifo",
                  max_queue: float = None, backend: str = "auto",
                  points: list = None) -> VerificationReport:
    """Spot-check ``n_samples`` interior query points of ``table`` against
    fresh simulation on ``fleet``.

    ``fleet``/``policy_cls``/``context`` must describe the same deployment
    the table was built for — the verifier checks the *oracle's
    interpolation*, not a redefinition of the problem. Trace seeds are
    offset from the builder's (fresh Monte Carlo draws), so prediction
    error includes genuine replicate noise: a small bound certifies both
    the interpolation and the build's seed robustness. Pass ``points``
    (list of ``(mean_rate, burstiness, slo_s)``) to pin the sample."""
    oracle = ScopingOracle(table)
    g = table.grid
    pts = points if points is not None \
        else _sample_points(table, n_samples, seed)
    report = VerificationReport()
    for mr, burst, slo in pts:
        tr = canonical_trace(
            mr, burst, duration_s=g.duration_s, dt_s=g.dt_s,
            n_seeds=g.n_seeds, seed=seed + 104729,
            burst_width_frac=g.burst_width_frac)
        ans = oracle.query(tr, slo)
        if not ans.ok:
            _LOG.warning("oracle verify: refused (%.3g/s, %.2f, %.3gs): %s",
                         mr, burst, slo, ans.reason)
            report.refused += 1
            continue
        scen = TuningScenario(
            name=f"verify({mr:.3g}/s,b{burst:.2f},slo{slo:.3g}s)",
            workload=Workload.from_trace(tr, slo), fleet=fleet,
            policy_cls=policy_cls,
            context=dict(context or {}, slo_s=slo),
            discipline=discipline, max_queue=max_queue, backend=backend)
        ev = evaluate_candidates(scen, [ans.params],
                                 table.objective)[0]
        report.checks.append(SpotCheck(
            mean_rate=mr, burstiness=burst, slo_s=slo,
            params=dict(ans.params),
            predicted_cost=ans.cost_usd_hr,
            predicted_bound=ans.cost_bound_usd_hr,
            simulated_cost=ev.mean_cost(),
            predicted_attainment=ans.attainment,
            simulated_attainment=ev.mean_attainment(),
            exact=ans.exact))
    _LOG.info("%s", report.summary())
    return report
