"""Scoping oracle: offline tuner sweeps compiled into a constant-time
lookup service (featurize -> build -> query -> verify)."""
from repro.fleet.oracle.build import (OracleCell, OracleGrid, OracleTable,
                                      build_oracle, canonical_trace)
from repro.fleet.oracle.features import TraceFeatures, featurize
from repro.fleet.oracle.oracle import (OracleAnswer, ScopingOracle,
                                       query_latency_us)
from repro.fleet.oracle.verify import (SpotCheck, VerificationReport,
                                       verify_oracle)

__all__ = [
    "OracleAnswer", "OracleCell", "OracleGrid", "OracleTable",
    "ScopingOracle", "SpotCheck", "TraceFeatures", "VerificationReport",
    "build_oracle", "canonical_trace", "featurize", "query_latency_us",
    "verify_oracle",
]
