"""Trace featurization: the coordinates a scoping query is answered in.

The oracle precomputes tuner answers over a grid of workload *regimes*, not
individual traces — so a live trace must map onto a small feature vector that
(a) is **invariant under seed resampling**: features read only the expected
rate profile, never the Poisson arrival draws, so two Monte Carlo samplings
of the same profile featurize identically; and (b) is **equivariant under
rate rescale**: scaling a profile by ``c`` multiplies ``mean_rate`` by ``c``
and leaves every shape statistic (burstiness, ramp, class mix) unchanged —
a recorded trace replayed at a different traffic volume lands on the same
grid column, shifted only along the rate axis. Shape statistics come from
``Trace.shape_profile`` (the pre-rescale recording when the loader rescaled),
so a ``load_trace_csv(..., mean_rate_per_s=...)`` replay is *bit-identical*
in shape to its recording, not merely close.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.fleet.traces import Trace
from repro.fleet.workload import Workload


@dataclass(frozen=True)
class TraceFeatures:
    """The oracle's query coordinates for one workload.

    * ``mean_rate``  — expected requests/s averaged over the trace (the only
      feature that scales with traffic volume);
    * ``burstiness`` — peak/mean of the rate profile (1.0 = steady);
    * ``ramp``       — sharpest one-bin fractional rate increase,
      ``max(diff(profile)) / mean(profile)`` (0 for non-increasing profiles;
      per *bin*, so it is invariant under ``resample_trace``'s bin
      subdivision as well as under rescale);
    * ``class_mix``  — per-class share of expected traffic, in workload
      class order, summing to 1 (``(1.0,)`` for a bare trace).
    """
    mean_rate: float
    burstiness: float
    ramp: float
    class_mix: tuple = (1.0,)

    def scaled(self, rate_factor: float) -> "TraceFeatures":
        """The same regime at ``rate_factor`` x the traffic — how the
        closed loop inflates a query by its estimated degradation factor
        (a node serving f-times slower looks, for capacity purposes, like
        f-times the traffic on healthy nodes)."""
        if rate_factor <= 0:
            raise ValueError(f"rate_factor must be > 0, got {rate_factor}")
        return TraceFeatures(self.mean_rate * float(rate_factor),
                             self.burstiness, self.ramp, self.class_mix)

    def as_dict(self) -> dict:
        return {"mean_rate": self.mean_rate, "burstiness": self.burstiness,
                "ramp": self.ramp, "class_mix": list(self.class_mix)}

    @staticmethod
    def from_dict(d: dict) -> "TraceFeatures":
        return TraceFeatures(float(d["mean_rate"]), float(d["burstiness"]),
                             float(d["ramp"]),
                             tuple(float(v) for v in d.get("class_mix",
                                                           (1.0,))))


def _profile_stats(profile: np.ndarray) -> tuple:
    """(burstiness, ramp) of a rate profile; scale-invariant by construction
    (both are ratios against the profile's own mean)."""
    p = np.asarray(profile, float)
    mean = p.mean()
    if not np.isfinite(mean) or mean <= 0:
        raise ValueError("cannot featurize an all-zero or non-finite "
                         "rate profile")
    burst = float(p.max() / mean)
    ramp = float(max(np.diff(p).max(initial=0.0), 0.0) / mean)
    return burst, ramp


def featurize(workload) -> TraceFeatures:
    """Featurize a :class:`Trace` or :class:`Workload`.

    Only the deterministic rate profile is read — never the sampled
    arrivals — so featurization is exactly invariant under re-seeding the
    Monte Carlo draws. For a bare trace, shape statistics use
    ``shape_profile`` (the pre-rescale recording when one exists) while
    ``mean_rate`` uses the actual (possibly rescaled) intensity. A
    multi-class workload aggregates per-class rates and adds the class mix.
    """
    if isinstance(workload, Trace):
        tr = workload
        mean_rate = float(np.asarray(tr.rate, float).mean())
        if not np.isfinite(mean_rate) or mean_rate <= 0:
            raise ValueError(f"trace {tr.name!r}: cannot featurize an "
                             "all-zero or non-finite rate profile")
        burst, ramp = _profile_stats(tr.shape_profile)
        return TraceFeatures(mean_rate, burst, ramp, (1.0,))
    if isinstance(workload, Workload):
        rates = [np.asarray(tr.rate, float) for tr in workload.traces]
        total = np.sum(rates, axis=0)
        mean_rate = float(total.mean())
        if not np.isfinite(mean_rate) or mean_rate <= 0:
            raise ValueError(f"workload {workload.name!r}: cannot featurize "
                             "an all-zero or non-finite rate profile")
        burst, ramp = _profile_stats(total)
        mix = tuple(float(r.mean()) / mean_rate for r in rates)
        return TraceFeatures(mean_rate, burst, ramp, mix)
    raise TypeError(f"featurize expects a Trace or Workload, got "
                    f"{type(workload).__name__}")
