"""Offline oracle builder: sweep ``tune()`` over a grid of workload regimes.

"Don't train models. Build oracles!": instead of re-running the nested-loop
Monte Carlo tuner per customer, run it *once per grid cell* offline —
embarrassingly parallel on the compiled backend — over a declarative grid of
(mean rate x burstiness x SLO tier), and persist every cell's winner and
Pareto frontier into a versioned, serializable :class:`OracleTable`. Online,
scoping is then a constant-time lookup (:mod:`repro.fleet.oracle.oracle`);
the simulator is demoted to the offline builder here and the spot-check
verifier (:mod:`repro.fleet.oracle.verify`).

Each cell is tuned against a *canonical* synthetic trace realizing the
cell's features exactly: a steady Poisson stream when ``burstiness == 1``,
else a flash-crowd profile whose peak multiplier is solved so peak/mean
matches the cell's burstiness. The tuner seed is derived from the cell's
(rate, burstiness) column — distinct columns explore distinct candidate
sets, but every SLO tier within a column races the *same* candidates, which
is what makes the interpolated score provably monotone in SLO tightness (a
config's score can only improve as the deadline loosens, and the min over a
shared candidate set inherits that ordering).
"""
from __future__ import annotations

import json
import logging
from dataclasses import dataclass, field

import numpy as np

from repro.fleet import telemetry
from repro.fleet.oracle.features import TraceFeatures, featurize
from repro.fleet.traces import Trace, flash_crowd_trace, poisson_trace
from repro.fleet.tuning.evaluate import Objective, TuningScenario
from repro.fleet.tuning.space import ParamSpace
from repro.fleet.tuning.tuner import TuningBudget, tune
from repro.fleet.workload import Workload

_LOG = logging.getLogger(__name__)


@dataclass(frozen=True)
class OracleGrid:
    """Declarative sweep grid: the workload regimes the oracle will answer
    for. ``mean_rates`` and ``slos`` are treated as log-scaled axes (rates
    and deadlines span decades), ``burstiness`` as linear; every axis must
    be strictly increasing. The canonical trace per cell is generated at
    ``duration_s``/``dt_s`` with ``n_seeds`` Monte Carlo replicates."""
    mean_rates: tuple               # requests/s, > 0, strictly increasing
    burstiness: tuple               # peak/mean >= 1, strictly increasing
    slos: tuple                     # seconds, > 0, strictly increasing
    duration_s: float = 1800.0
    dt_s: float = 10.0
    n_seeds: int = 4
    seed: int = 0
    burst_width_frac: float = 1.0 / 16.0    # flash-crowd width / duration

    def __post_init__(self):
        for name, axis, lo in (("mean_rates", self.mean_rates, 0.0),
                               ("burstiness", self.burstiness, 1.0 - 1e-12),
                               ("slos", self.slos, 0.0)):
            vals = tuple(float(v) for v in axis)
            object.__setattr__(self, name, vals)
            if not vals:
                raise ValueError(f"grid axis {name} is empty")
            if any(v <= lo for v in vals) or not all(np.isfinite(vals)):
                raise ValueError(f"grid axis {name} needs finite values "
                                 f"> {lo}: {vals}")
            if any(b <= a for a, b in zip(vals, vals[1:])):
                raise ValueError(f"grid axis {name} must be strictly "
                                 f"increasing: {vals}")

    @property
    def shape(self) -> tuple:
        return (len(self.mean_rates), len(self.burstiness), len(self.slos))

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.shape))

    def cells(self):
        """((i, j, k), mean_rate, burstiness, slo_s) per grid cell."""
        for i, mr in enumerate(self.mean_rates):
            for j, b in enumerate(self.burstiness):
                for k, slo in enumerate(self.slos):
                    yield (i, j, k), mr, b, slo


def canonical_trace(mean_rate: float, burstiness: float, *,
                    duration_s: float, dt_s: float, n_seeds: int = 4,
                    seed: int = 0,
                    burst_width_frac: float = 1.0 / 16.0) -> Trace:
    """The grid cell's representative trace: exact mean rate AND exact
    peak/mean burstiness.

    ``flash_crowd_trace``'s ``peak_mult`` multiplies the *base* rate, not
    the mean — a Gaussian burst raises the mean too, so peak/mean ends up
    below ``peak_mult``. Solve for the multiplier that lands the requested
    burstiness: with ``g`` the unit burst profile and ``gm = mean(g)``,
    ``peak/mean = pm / (1 + (pm - 1) gm)`` gives
    ``pm = B (1 - gm) / (1 - B gm)``, feasible while ``B < 1/gm`` (a very
    narrow trace can realize very high burstiness; a wide one cannot)."""
    if burstiness < 1.0:
        raise ValueError(f"burstiness must be >= 1, got {burstiness}")
    if burstiness <= 1.0 + 1e-9:
        return poisson_trace(mean_rate, duration_s, dt_s,
                             n_seeds=n_seeds, seed=seed)
    width = duration_s * burst_width_frac
    # unit burst profile g (peak ~1 at center) from a peak_mult=2 probe:
    # rate = base * (1 + (pm-1) g), so the probe's (rate - 1) IS g as binned
    probe = flash_crowd_trace(1.0, duration_s, dt_s, peak_mult=2.0,
                              burst_width_s=width, n_seeds=1, seed=seed)
    g = probe.rate - 1.0
    gm, gmax = float(g.mean()), float(g.max())
    # solve peak/mean = (1 + (pm-1) gmax) / (1 + (pm-1) gm) = burstiness
    denom = gmax - burstiness * gm
    if denom <= 0:
        raise ValueError(
            f"burstiness {burstiness:g} is not realizable with a "
            f"{burst_width_frac:.3g}-duration burst (max {gmax / gm:.2f}); "
            f"narrow burst_width_frac or lower the axis")
    pm = 1.0 + (burstiness - 1.0) / denom
    rate = 1.0 + (pm - 1.0) * g
    rate *= mean_rate / rate.mean()
    arrivals = np.random.default_rng(seed).poisson(
        rate[None, :] * dt_s, size=(n_seeds, len(rate)))
    return Trace(f"canonical-b{burstiness:g}", dt_s, rate, arrivals)


@dataclass(frozen=True)
class OracleCell:
    """One precomputed answer: the tuner's winner for a workload regime."""
    idx: tuple                      # (i, j, k) into the grid axes
    mean_rate: float
    burstiness: float
    slo_s: float
    features: TraceFeatures         # of the canonical trace actually tuned
    winner: dict                    # winning params (verbatim from tune())
    cost_usd_hr: float
    attainment: float               # worst-class SLO attainment of winner
    score: float                    # objective scalarization of winner
    frontier: tuple = ()            # ({params, cost_usd_hr, attainment}, ...)

    def to_json(self) -> dict:
        return {"idx": list(self.idx), "mean_rate": self.mean_rate,
                "burstiness": self.burstiness, "slo_s": self.slo_s,
                "features": self.features.as_dict(),
                "winner": dict(self.winner),
                "cost_usd_hr": self.cost_usd_hr,
                "attainment": self.attainment, "score": self.score,
                "frontier": [dict(f) for f in self.frontier]}

    @staticmethod
    def from_json(d: dict) -> "OracleCell":
        return OracleCell(
            idx=tuple(int(v) for v in d["idx"]),
            mean_rate=float(d["mean_rate"]),
            burstiness=float(d["burstiness"]), slo_s=float(d["slo_s"]),
            features=TraceFeatures.from_dict(d["features"]),
            winner=dict(d["winner"]), cost_usd_hr=float(d["cost_usd_hr"]),
            attainment=float(d["attainment"]), score=float(d["score"]),
            frontier=tuple(dict(f) for f in d.get("frontier", ())))


@dataclass
class OracleTable:
    """The compiled artifact: every grid cell's winner + frontier, plus the
    search space and objective needed to interpolate between cells. JSON on
    disk is versioned; ``ScopingOracle`` (oracle.py) is the query engine."""
    FORMAT = "oracle-table"
    VERSION = 1

    grid: OracleGrid
    space: ParamSpace
    objective: Objective
    policy_family: str
    fleet_label: str
    cells: dict = field(default_factory=dict)    # idx tuple -> OracleCell
    build_info: dict = field(default_factory=dict)

    @property
    def n_cells(self) -> int:
        return len(self.cells)

    def cell(self, idx: tuple) -> OracleCell:
        return self.cells[tuple(idx)]

    def to_json(self) -> dict:
        return {
            "format": self.FORMAT, "version": self.VERSION,
            "grid": {"mean_rates": list(self.grid.mean_rates),
                     "burstiness": list(self.grid.burstiness),
                     "slos": list(self.grid.slos),
                     "duration_s": self.grid.duration_s,
                     "dt_s": self.grid.dt_s, "n_seeds": self.grid.n_seeds,
                     "seed": self.grid.seed,
                     "burst_width_frac": self.grid.burst_width_frac},
            "space": self.space.to_json(),
            "objective": self.objective.to_json(),
            "policy_family": self.policy_family,
            "fleet_label": self.fleet_label,
            "cells": [c.to_json() for _, c in sorted(self.cells.items())],
            "build_info": dict(self.build_info),
        }

    @staticmethod
    def from_json(d: dict) -> "OracleTable":
        if d.get("format") != OracleTable.FORMAT:
            raise ValueError(f"not an oracle table "
                             f"(format={d.get('format')!r})")
        if int(d.get("version", -1)) > OracleTable.VERSION:
            raise ValueError(f"oracle table version {d.get('version')} is "
                             f"newer than this reader "
                             f"(<= {OracleTable.VERSION})")
        g = d["grid"]
        grid = OracleGrid(
            mean_rates=tuple(g["mean_rates"]),
            burstiness=tuple(g["burstiness"]), slos=tuple(g["slos"]),
            duration_s=float(g["duration_s"]), dt_s=float(g["dt_s"]),
            n_seeds=int(g["n_seeds"]), seed=int(g["seed"]),
            burst_width_frac=float(g.get("burst_width_frac", 1.0 / 16.0)))
        cells = {}
        for cd in d.get("cells", []):
            c = OracleCell.from_json(cd)
            cells[c.idx] = c
        return OracleTable(
            grid=grid, space=ParamSpace.from_json(d["space"]),
            objective=Objective.from_json(d["objective"]),
            policy_family=d["policy_family"],
            fleet_label=d.get("fleet_label", ""),
            cells=cells, build_info=dict(d.get("build_info", {})))

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, default=float)
            f.write("\n")

    @staticmethod
    def load(path) -> "OracleTable":
        with open(path) as f:
            return OracleTable.from_json(json.load(f))

    def summary(self) -> str:
        g = self.grid
        bi = self.build_info
        lines = [
            f"oracle table: {self.policy_family} on {self.fleet_label}",
            f"  grid {g.shape[0]}x{g.shape[1]}x{g.shape[2]} = "
            f"{self.n_cells} cells "
            f"(rate {g.mean_rates[0]:g}..{g.mean_rates[-1]:g}/s, "
            f"burstiness {g.burstiness[0]:g}..{g.burstiness[-1]:g}, "
            f"slo {g.slos[0]:g}..{g.slos[-1]:g}s)",
            f"  built with {bi.get('sims_used', '?')} candidate-replicate "
            f"simulations ({bi.get('tune_equivalents', '?')} fresh-tune "
            f"equivalents)",
        ]
        return "\n".join(lines)


def _frontier_entries(report) -> tuple:
    return tuple({"params": dict(e.params),
                  "cost_usd_hr": e.mean_cost(),
                  "attainment": e.mean_attainment(),
                  "score": e.mean_score()} for e in report.frontier)


def _tune_column(scen0, candidates, space, objective, budget, slos):
    """Tune every SLO tier of one (rate, burstiness) column against the
    shared candidate slate with SHARED compiled dispatches
    (``race_column``): single-class tiers have bin-exact identical dynamics
    — the SLO only enters the host-side exact-latency accounting — so the
    column's K tiers cost one tier's simulations instead of K. Per-tier
    racing bookkeeping (SPRT, halving, full-budget winner evidence) is
    ``race``'s own, so each tier's winner, frontier and fitted surface are
    identical to a standalone per-cell ``tune()``. Returns
    ``(reports, sims_shared)`` aligned with ``slos``, or ``None`` when the
    slate cannot batch (caller tunes cells separately)."""
    from repro.fleet.tuning.racing import race_column
    from repro.fleet.tuning.result import TuningReport, pareto_frontier
    from repro.fleet.tuning.tuner import _fit_surface

    got = race_column(scen0, candidates, objective, slos,
                      init_seeds=budget.init_seeds, eta=budget.eta,
                      alpha=budget.alpha, beta=budget.beta)
    if got is None:
        return None
    results, sims_shared = got
    reports = []
    for rr in results:
        surface, names = _fit_surface(space, rr.evals)
        reports.append(TuningReport(
            scenario_name=scen0.name,
            policy_family=getattr(scen0.policy_cls, "name",
                                  scen0.policy_cls.__name__),
            objective=objective, winner=rr.winner,
            frontier=pareto_frontier(rr.evals), surface=surface,
            surface_names=names, sims_used=rr.sims_used,
            full_budget=rr.full_budget, evals=rr.evals, space=space,
            _scenario=scen0, spans=None))
    return reports, sims_shared


def build_oracle(grid: OracleGrid, fleet, policy_cls, space: ParamSpace, *,
                 objective: Objective = None, budget: TuningBudget = None,
                 context: dict = None, discipline: str = "fifo",
                 max_queue: float = None, backend: str = "auto",
                 name: str = "oracle", column_batch: bool = True
                 ) -> OracleTable:
    """Sweep ``tune()`` over every grid cell and compile the answers.

    Per cell: synthesize the canonical trace for (mean_rate, burstiness),
    wrap it into a single-class workload at the cell's SLO, tune
    ``policy_cls`` over ``space`` with the column-derived seed, and record
    the winner + Pareto frontier. Deterministic under (grid, budget, seed).

    With ``column_batch`` (the default) and a compiled backend, every SLO
    tier in a (rate, burstiness) column rides the SAME dispatches: tiers
    already race a shared candidate set on shared arrivals (the
    SLO-monotonicity invariant), and a single-class workload's dynamics
    never see the SLO, so one compiled racing round scores the whole column
    and each tier re-assembles its own accounting on the host
    (``race_column``). Winners and frontiers are identical to the per-cell
    sweep; ``build_info["sims_used"]`` counts the trajectories actually
    simulated, so the build's amortization (``tune_equivalents``) honestly
    drops by ~the column height. Cells fall back to per-cell ``tune()``
    when the slate cannot batch (numpy backend, custom families,
    exhaustive budgets).
    """
    objective = objective or Objective()
    budget = budget or TuningBudget(n_candidates=12, init_seeds=2)
    context = dict(context or {})
    fleet_label = "+".join(p.label for p in fleet.pools)
    cells, sims_total = {}, 0
    n_slos = len(grid.slos)
    with telemetry.span("oracle.build", n_cells=grid.n_cells,
                        backend=backend, column_batch=column_batch):
        for i, mr in enumerate(grid.mean_rates):
            for j, burst in enumerate(grid.burstiness):
                # Trace and tuner seeds depend only on the (rate,
                # burstiness) column, never on the SLO index: every SLO
                # tier in a column must race the same candidate set on the
                # same arrivals for the interpolated score to stay monotone
                # in SLO tightness.
                col_seed = grid.seed + 7919 * (1 + i * 31 + j)
                tr = canonical_trace(
                    mr, burst, duration_s=grid.duration_s, dt_s=grid.dt_s,
                    n_seeds=grid.n_seeds, seed=col_seed,
                    burst_width_frac=grid.burst_width_frac)
                reports = None
                if column_batch and backend != "numpy" and budget.racing \
                        and n_slos > 1:
                    scen0 = TuningScenario(
                        name=f"{name}/col({i},{j})",
                        workload=Workload.from_trace(tr, grid.slos[0]),
                        fleet=fleet, policy_cls=policy_cls,
                        context=dict(context, slo_s=grid.slos[0]),
                        discipline=discipline, max_queue=max_queue,
                        backend=backend)
                    if budget.sampler == "grid":
                        candidates = space.grid(budget.grid_levels)
                    else:
                        candidates = space.sample_lhs(budget.n_candidates,
                                                      seed=col_seed)
                    with telemetry.span("oracle.column", col=f"({i},{j})",
                                        rate=mr, burstiness=burst,
                                        tiers=n_slos):
                        got = _tune_column(scen0, candidates, space,
                                           objective, budget, grid.slos)
                    if got is not None:
                        reports, sims_shared = got
                        sims_total += sims_shared
                for k, slo in enumerate(grid.slos):
                    idx = (i, j, k)
                    if reports is not None:
                        report = reports[k]
                    else:
                        wl = Workload.from_trace(tr, slo)
                        scen = TuningScenario(
                            name=f"{name}/cell{idx}", workload=wl,
                            fleet=fleet, policy_cls=policy_cls,
                            context=dict(context, slo_s=slo),
                            discipline=discipline, max_queue=max_queue,
                            backend=backend)
                        with telemetry.span("oracle.cell", idx=str(idx),
                                            rate=mr, burstiness=burst,
                                            slo=slo):
                            report = tune(scen, space, objective, budget,
                                          seed=col_seed)
                        sims_total += report.sims_used
                    cells[idx] = OracleCell(
                        idx=idx, mean_rate=mr, burstiness=burst, slo_s=slo,
                        features=featurize(tr),
                        winner=dict(report.winner.params),
                        cost_usd_hr=report.winner.mean_cost(),
                        attainment=report.winner.mean_attainment(),
                        score=report.winner.mean_score(),
                        frontier=_frontier_entries(report))
                    _LOG.info(
                        "oracle cell %s: rate %.3g/s burst %.2f slo %.3gs "
                        "-> %s ($%.2f/hr @ %.4f)", idx, mr, burst, slo,
                        cells[idx].winner, cells[idx].cost_usd_hr,
                        cells[idx].attainment)
    per_cell = max(budget.n_candidates * grid.n_seeds, 1)
    table = OracleTable(
        grid=grid, space=space, objective=objective,
        policy_family=getattr(policy_cls, "name", policy_cls.__name__),
        fleet_label=fleet_label, cells=cells,
        build_info={"sims_used": sims_total,
                    "n_cells": grid.n_cells,
                    "tune_equivalents": sims_total / per_cell,
                    "seed": grid.seed, "backend": backend})
    telemetry.event("oracle_built", n_cells=grid.n_cells,
                    sims_used=sims_total, policy_family=table.policy_family)
    return table
