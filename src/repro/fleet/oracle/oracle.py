"""Microsecond-latency scoping: query a precompiled :class:`OracleTable`.

``ScopingOracle`` answers "what shape + controller config should this
workload run on, and what will it cost?" without touching the simulator:
the query featurizes the trace (:mod:`features`), locates the enclosing
grid cell, and multilinearly interpolates the precomputed winners — log-
space along the rate and SLO axes (they span decades), linear along
burstiness. Numeric params interpolate in each dim's own unit coordinates
(``Dim.to_unit``/``from_unit``, so a log-scaled knob interpolates
geometrically); categorical params take the dominant corner. The whole
path is a handful of array ops — microseconds, measured and reported on
every answer.

Queries outside the gridded region are *refused with a reason* rather than
extrapolated: an oracle that guesses beyond its sweep is indistinguishable
from one that knows, and the closed loop needs the distinction to decide
between a config swap (hit) and a warm re-tune (miss).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.fleet.oracle.build import OracleTable
from repro.fleet.oracle.features import TraceFeatures, featurize
from repro.fleet.traces import Trace
from repro.fleet.workload import Workload

_EXACT_RTOL = 1e-9      # relative snap tolerance for the verbatim fast path


@dataclass(frozen=True)
class OracleAnswer:
    """One oracle response. ``ok`` distinguishes an answer from a refusal;
    a refusal carries only ``reason``, ``features`` and ``latency_us``."""
    ok: bool
    reason: str = ""                 # non-empty iff refused
    features: TraceFeatures = None   # the (possibly inflated) query point
    slo_s: float = float("nan")
    params: dict = field(default_factory=dict)
    cost_usd_hr: float = float("nan")        # interpolated winner cost
    cost_bound_usd_hr: float = float("nan")  # max over contributing corners
    attainment: float = float("nan")
    score: float = float("nan")
    cell_idx: tuple = None           # nearest grid cell
    exact: bool = False              # True: verbatim grid-point answer
    corner_idx: tuple = ()           # contributing grid cells (provenance)
    corner_weights: tuple = ()       # their multilinear weights
    latency_us: float = float("nan")

    def __bool__(self) -> bool:
        return self.ok


def _axis_weight(value: float, axis: tuple, log: bool) -> tuple:
    """(lower index, upper-corner weight) for ``value`` on a sorted axis;
    the caller guarantees value is inside [axis[0], axis[-1]]."""
    a = np.asarray(axis, float)
    if len(a) == 1:
        return 0, 0.0
    i = int(np.clip(np.searchsorted(a, value, side="right") - 1,
                    0, len(a) - 2))
    lo, hi = a[i], a[i + 1]
    if log:
        w = float(np.log(value / lo) / np.log(hi / lo))
    else:
        w = float((value - lo) / (hi - lo))
    return i, float(np.clip(w, 0.0, 1.0))


class ScopingOracle:
    """Constant-time scoping answers from an offline-built table.

    >>> oracle = ScopingOracle(OracleTable.load("oracle.json"))
    >>> ans = oracle.query(trace, slo_s=2.0)
    >>> ans.ok, ans.params, ans.cost_usd_hr, ans.latency_us
    """

    def __init__(self, table: OracleTable):
        self.table = table
        g = table.grid
        self._axes = (tuple(g.mean_rates), tuple(g.burstiness),
                      tuple(g.slos))
        self._log = (True, False, True)
        self._axis_names = ("mean_rate", "burstiness", "slo_s")
        self._dims = {d.name: d for d in table.space.dims}

    # ---- query -------------------------------------------------------------

    def query(self, workload, slo_s: float = None, *,
              rate_factor: float = 1.0) -> OracleAnswer:
        """Scope ``workload`` (a Trace, Workload, or TraceFeatures).

        ``slo_s`` is required for a Trace or TraceFeatures; a Workload
        supplies its own (strictest class). ``rate_factor > 1`` inflates the
        query's rate axis — the closed loop's degradation factor: a fleet
        serving f-times slower is scoped as f-times the traffic.
        """
        t0 = time.perf_counter()
        try:
            feats = self._featurize(workload, rate_factor)
            slo = self._resolve_slo(workload, slo_s)
        except (TypeError, ValueError) as e:
            return self._refuse(str(e), None, slo_s, t0)
        point = (feats.mean_rate, feats.burstiness, slo)
        for name, v, axis in zip(self._axis_names, point, self._axes):
            if not (axis[0] - abs(axis[0]) * _EXACT_RTOL <= v
                    <= axis[-1] + abs(axis[-1]) * _EXACT_RTOL):
                return self._refuse(
                    f"{name}={v:g} outside gridded range "
                    f"[{axis[0]:g}, {axis[-1]:g}] — rebuild the table with "
                    f"a wider {name} axis or fall back to tune()",
                    feats, slo, t0)
        iw = [_axis_weight(min(max(v, axis[0]), axis[-1]), axis, lg)
              for v, axis, lg in zip(point, self._axes, self._log)]
        # verbatim fast path: the query sits on a grid point on every axis
        snapped = self._snap(iw)
        if snapped is not None:
            cell = self.table.cells.get(snapped)
            if cell is None:
                return self._refuse(f"grid cell {snapped} was not built",
                                    feats, slo, t0)
            return OracleAnswer(
                ok=True, features=feats, slo_s=slo,
                params=dict(cell.winner), cost_usd_hr=cell.cost_usd_hr,
                cost_bound_usd_hr=cell.cost_usd_hr,
                attainment=cell.attainment, score=cell.score,
                cell_idx=snapped, exact=True,
                corner_idx=(snapped,), corner_weights=(1.0,),
                latency_us=(time.perf_counter() - t0) * 1e6)
        corners, weights = self._corners(iw)
        missing = [c for c in corners if c not in self.table.cells]
        if missing:
            return self._refuse(
                f"grid cell(s) {missing} enclosing the query were not "
                f"built", feats, slo, t0)
        cells = [self.table.cells[c] for c in corners]
        params = self._blend_params(cells, weights)
        active = weights > 1e-12
        cost = float(np.dot(weights, [c.cost_usd_hr for c in cells]))
        bound = float(max(c.cost_usd_hr
                          for c, a in zip(cells, active) if a))
        att = float(np.dot(weights, [c.attainment for c in cells]))
        score = float(np.dot(weights, [c.score for c in cells]))
        nearest = corners[int(np.argmax(weights))]
        return OracleAnswer(
            ok=True, features=feats, slo_s=slo, params=params,
            cost_usd_hr=cost, cost_bound_usd_hr=bound, attainment=att,
            score=score, cell_idx=nearest, exact=False,
            corner_idx=tuple(corners),
            corner_weights=tuple(float(w) for w in weights),
            latency_us=(time.perf_counter() - t0) * 1e6)

    # ---- internals ---------------------------------------------------------

    @staticmethod
    def _featurize(workload, rate_factor: float) -> TraceFeatures:
        if isinstance(workload, TraceFeatures):
            feats = workload
        else:
            feats = featurize(workload)
        return feats if rate_factor == 1.0 else feats.scaled(rate_factor)

    @staticmethod
    def _resolve_slo(workload, slo_s) -> float:
        if slo_s is None:
            if isinstance(workload, Workload):
                slo_s = float(workload.slos().min())
            else:
                raise ValueError(
                    "slo_s is required for a Trace/TraceFeatures query")
        slo = float(slo_s)
        if not np.isfinite(slo) or slo <= 0:
            raise ValueError(f"slo_s must be finite and > 0, got {slo_s}")
        return slo

    def _snap(self, iw: list):
        """Grid index when every axis weight is ~0 or ~1, else None."""
        idx = []
        for (i, w), axis in zip(iw, self._axes):
            if w <= _EXACT_RTOL:
                idx.append(i)
            elif w >= 1.0 - _EXACT_RTOL:
                idx.append(i + 1)
            else:
                return None
        return tuple(idx)

    def _corners(self, iw: list) -> tuple:
        """(corner indices, multilinear weights) — up to 2^3 corners."""
        corners, weights = [], []
        for da in (0, 1):
            for db in (0, 1):
                for dc in (0, 1):
                    w = 1.0
                    idx = []
                    for (i, wt), d, axis in zip(iw, (da, db, dc),
                                                self._axes):
                        if len(axis) == 1:
                            if d == 1:
                                w = 0.0
                            idx.append(i)
                        else:
                            w *= wt if d else (1.0 - wt)
                            idx.append(min(i + d, len(axis) - 1))
                    if w > 0.0:
                        corners.append(tuple(idx))
                        weights.append(w)
        weights = np.asarray(weights, float)
        return corners, weights / weights.sum()

    def _blend_params(self, cells: list, weights: np.ndarray) -> dict:
        """Interpolate winners: numeric dims in their own unit space,
        categorical dims from the dominant corner."""
        dominant = cells[int(np.argmax(weights))]
        params = {}
        for name, dim in self._dims.items():
            vals = [c.winner.get(name) for c in cells]
            if any(v is None for v in vals):
                params[name] = dominant.winner.get(name)
                continue
            if hasattr(dim, "choices"):     # categorical: majority by weight
                tally = {}
                for v, w in zip(vals, weights):
                    tally[v] = tally.get(v, 0.0) + float(w)
                params[name] = max(tally, key=tally.get)
                continue
            u = float(np.dot(weights, [dim.to_unit(v) for v in vals]))
            params[name] = dim.from_unit(u)
        return params

    def _refuse(self, reason: str, feats, slo, t0: float) -> OracleAnswer:
        return OracleAnswer(
            ok=False, reason=reason, features=feats,
            slo_s=float("nan") if slo is None else float(slo),
            latency_us=(time.perf_counter() - t0) * 1e6)


def query_latency_us(oracle: ScopingOracle, workload, slo_s: float = None,
                     *, n: int = 200) -> dict:
    """Measured query latency distribution (microseconds) over ``n``
    repeated queries of the same point — the bench gate's evidence that a
    lookup is constant-time. The first call is excluded (it may fault in
    caches); featurization is included (it is part of every real query)."""
    oracle.query(workload, slo_s)
    lat = np.empty(n)
    for i in range(n):
        lat[i] = oracle.query(workload, slo_s).latency_us
    return {"median_us": float(np.median(lat)),
            "p99_us": float(np.percentile(lat, 99)),
            "max_us": float(lat.max()), "n": int(n)}
