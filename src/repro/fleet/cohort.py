"""Exact FIFO request-cohort latency accounting.

The simulator's per-bin ``served`` counts say *how much* left the queue each
bin but not *who* — yet SLO attainment is a per-request property. For FIFO
service the mapping needs no per-request state: requests are identified by
their cumulative arrival index, departures by the cumulative served index, and
every per-request quantity becomes interval arithmetic between the two
cumulative curves. One vectorized pass over (seeds, slots) replaces the fluid
``wait = backlog / rate`` estimate with exact sojourns.

Model (matches the discrete simulator): all of bin t's *admitted* arrivals
queue at the start of bin t; service happens in "slots" — (bin, pool) pairs in
drain order, so heterogeneous pools with different batch times stay FIFO-exact.
A request served in slot k of bin ``u`` waited ``u - t`` whole bins and then
pays that slot's batch service time:

    sojourn = (u - t) * dt + batch_time[k]

A request served in its arrival bin pays only the batch time — the same
convention as the fluid model this replaces. Masses may be fractional (the
simulator is fluid within a bin); on integer traces the accounting matches a
brute-force per-request replay exactly (see tests/test_fleet_hetero.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_MASS_EPS = 1e-9


def row_searchsorted(rows: np.ndarray, x: np.ndarray, side: str = "left"
                     ) -> np.ndarray:
    """Batched ``np.searchsorted``: for each row s, positions of ``x[s]`` in
    the sorted row ``rows[s]``. Implemented with one flat searchsorted by
    offsetting each row into its own disjoint value range."""
    rows = np.asarray(rows, float)
    x = np.asarray(x, float)
    S, N = rows.shape
    span = float(max(rows.max(initial=0.0), x.max(initial=0.0))) + 1.0
    off = np.arange(S)[:, None] * span
    flat = np.searchsorted((rows + off).ravel(), (x + off).ravel(), side=side)
    return flat.reshape(x.shape) - np.arange(S)[:, None] * N


@dataclass(frozen=True)
class CohortMetrics:
    """Exact per-slot FIFO accounting (all mass in requests).

    ``ok_served[s, k]``     — mass served in slot k within the SLO deadline.
    ``mean_sojourn[s, k]``  — served-mass mean sojourn of slot k (0 if empty).
    ``sojourn_values/weights`` — the exact pooled sojourn distribution across
    seeds: every (arrival-bin, slot) segment contributes its mass, so weighted
    percentiles over these are per-request exact, not per-bin means.
    """
    ok_served: np.ndarray
    mean_sojourn: np.ndarray
    sojourn_values: np.ndarray
    sojourn_weights: np.ndarray


def cohort_metrics(admitted: np.ndarray, served: np.ndarray,
                   slot_bin: np.ndarray, slot_batch_time: np.ndarray,
                   dt_s: float, slo_s: float) -> CohortMetrics:
    """Exact FIFO sojourn/deadline accounting from cumulative arithmetic.

    admitted:        (S, T) arrivals entering the queue per bin (post-drop).
    served:          (S, K) mass departing per slot, slots in FIFO drain order.
    slot_bin:        (K,) int bin index of each slot (non-decreasing).
    slot_batch_time: (S, K) batch service time paid by requests in that slot.

    Requires the FIFO invariant cum_served[:, k] <= cum_admitted[:, slot_bin[k]]
    (a queue cannot serve requests that have not arrived).
    """
    admitted = np.asarray(admitted, float)
    served = np.asarray(served, float)
    slot_bin = np.asarray(slot_bin, int)
    bt = np.asarray(slot_batch_time, float)
    S, T = admitted.shape
    K = served.shape[1]

    A = np.cumsum(admitted, axis=1)                       # (S, T)
    D = np.cumsum(served, axis=1)                         # (S, K)
    # tolerance is relative: long traces accumulate float error proportional
    # to the total mass without any request actually being served early
    if np.any(D - np.take(A, slot_bin, axis=1) > 1e-6 + 1e-9 * D):
        raise ValueError("FIFO invariant violated: served mass outruns arrivals")
    Apad = np.concatenate([np.zeros((S, 1)), A], axis=1)  # Apad[:, j] = A[:, j-1]
    Dprev = np.concatenate([np.zeros((S, 1)), D[:, :-1]], axis=1)

    # --- deadline misses per slot -------------------------------------------
    # sojourn <= slo  <=>  arrival bin t >= u - floor((slo - bt) / dt), so the
    # missing mass in slot k is the part of (Dprev, D] that lies at or below
    # the cumulative-arrival mark of the last too-early cohort.
    wait_bins = np.floor((slo_s - bt) / dt_s + 1e-9)      # may be negative
    t_min = slot_bin[None, :] - wait_bins                 # cohorts >= t_min meet SLO
    j = np.clip(t_min, 0.0, float(T)).astype(int)
    miss = np.clip(np.take_along_axis(Apad, j, axis=1) - Dprev, 0.0, served)
    ok_served = served - miss

    # --- mean sojourn per slot ----------------------------------------------
    # G(x) = sum of arrival-bin indices weighted by mass over indices (0, x]:
    # full cohorts 0..j-1 plus the partial cohort j.
    Tw = np.concatenate(
        [np.zeros((S, 1)), np.cumsum(np.arange(T) * admitted, axis=1)], axis=1)

    def G(x):
        jj = row_searchsorted(A, x, side="left")
        jc = np.clip(jj, 0, T - 1)
        return (np.take_along_axis(Tw, jc, axis=1)
                + jc * (x - np.take_along_axis(Apad, jc, axis=1)))

    mass_t = G(D) - G(Dprev)                              # sum_t t * n[t, k]
    pos = served > _MASS_EPS
    mean_t = np.divide(mass_t, served, out=np.zeros_like(mass_t), where=pos)
    mean_sojourn = np.where(pos, bt + dt_s * (slot_bin[None, :] - mean_t), 0.0)

    # --- exact pooled sojourn distribution ----------------------------------
    # Merge the arrival and departure partitions of the served mass: each
    # elementary segment has a unique (arrival bin, slot) pair, i.e. a single
    # sojourn value. At most T + K segments per seed — no per-request blowup.
    Dend = D[:, -1:]
    cuts = np.sort(np.concatenate([np.minimum(A, Dend), D], axis=1), axis=1)
    lo = np.concatenate([np.zeros((S, 1)), cuts[:, :-1]], axis=1)
    w = cuts - lo
    mid = 0.5 * (cuts + lo)
    t_idx = np.clip(row_searchsorted(A, mid, side="left"), 0, T - 1)
    k_idx = np.clip(row_searchsorted(D, mid, side="left"), 0, K - 1)
    soj = ((slot_bin[k_idx] - t_idx) * dt_s
           + np.take_along_axis(bt, k_idx, axis=1))
    keep = w > _MASS_EPS
    return CohortMetrics(ok_served=ok_served, mean_sojourn=mean_sojourn,
                         sojourn_values=soj[keep], sojourn_weights=w[keep])


def multiclass_cohort_metrics(admitted: np.ndarray, served: np.ndarray,
                              slot_bin: np.ndarray,
                              slot_batch_time: np.ndarray, dt_s: float,
                              slo_s) -> list:
    """Per-class exact sojourn recovery: one ``CohortMetrics`` per class.

    Every scheduling discipline in ``repro.fleet.discipline`` keeps cohort
    keys non-decreasing in the arrival bin within a class, so service *within*
    a class is FIFO under all of them and the single-class cumulative
    arithmetic applies class by class — the discipline only shows up through
    the per-class served-per-slot split.

    admitted: (S, T, C) post-admission arrivals; served: (S, K, C) per-slot
    per-class served mass; slo_s: per-class deadline, scalar or (C,).
    """
    admitted = np.asarray(admitted, float)
    served = np.asarray(served, float)
    C = admitted.shape[2]
    slo = np.broadcast_to(np.asarray(slo_s, float), (C,))
    return [cohort_metrics(admitted[:, :, c], served[:, :, c], slot_bin,
                           slot_batch_time, dt_s, float(slo[c]))
            for c in range(C)]
