"""Discrete-time fleet queueing simulator, numpy-vectorized over Monte Carlo
seeds, with heterogeneous per-shape replica pools and multi-class workloads
under pluggable scheduling disciplines.

Each time bin: per-class arrivals join the queue (admission control drops
overflow *at arrival*, before it can distort anyone's waiting time, shedding
the classes the discipline values least first); the backlog is drained across
the fleet's pools in cost-efficiency order — the head of the queue goes to the
cheapest capacity first — while the scheduling discipline (FIFO / strict
priority / EDF, ``repro.fleet.discipline``) decides *which class's* cohorts
that capacity serves; every ready replica drains back-to-back batches whose
service time comes from its pool's ``ServiceModel`` (roofline-derived); the
autoscaling policy observes (arrival rate, per-class queue, utilization,
per-pool replicas) and sets per-pool replica targets. Scale-downs first cancel
pending cold-starts newest-first (a cancelled launch stops billing
immediately), then shrink ready replicas; scale-ups become ready only after
the pool's cold-start delay and are billed from their launch bin — cold
capacity costs money before it serves anything.

Latency is exact, not fluid: per-slot per-class served masses feed the
request-cohort model (``repro.fleet.cohort``), which recovers per-request
sojourns and deadline misses from per-class cumulative arithmetic (service
within a class is FIFO under every discipline). All per-bin state is an
(n_seeds,) / (n_seeds, n_pools) / (n_seeds, n_classes) vector, so one pass
simulates every Monte Carlo draw of the trace at once — the fleet-level
analogue of the paper's nested-loop simulation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cost_model import dollar_cost
from repro.fleet import telemetry
from repro.fleet.cohort import multiclass_cohort_metrics
from repro.fleet.discipline import CohortQueue, get_discipline
from repro.fleet.traces import Trace
from repro.fleet.workload import ServiceModel, Workload

_EPS = 1e-12


@dataclass(frozen=True)
class PoolConfig:
    """One homogeneous replica pool inside a (possibly mixed) fleet: a shape's
    service model plus its own cold start and count bounds (cloud quotas).

    ``cold_start_s`` is either a constant (seconds) or a ``(mean_s,
    jitter_frac)`` pair: each launch event then samples its spin-up delay
    from a seeded lognormal with that mean and coefficient of variation —
    real container cold starts are long-tailed, and a cooldown tuned against
    a deterministic spin-up would be fitted to a fiction. A launch event is
    one (Monte Carlo seed, bin, pool): replicas a policy grows together in
    one bin are a batched launch and share that event's draw; draws are
    independent across bins, pools, and seeds. ``jitter_frac = 0`` is
    byte-identical to the constant path."""
    service: ServiceModel
    cold_start_s: object = 30.0     # float seconds | (mean_s, jitter_frac)
    min_replicas: int = 0
    max_replicas: int = 1024
    initial_replicas: Optional[int] = None
    name: Optional[str] = None

    def __post_init__(self):
        cs = self.cold_start_s
        bad = isinstance(cs, (tuple, list)) and len(cs) != 2
        if not bad:
            m, j = self.cold_start_mean_s, self.cold_start_jitter
            bad = not (np.isfinite(m) and m >= 0
                       and np.isfinite(j) and j >= 0)
        if bad:
            raise ValueError(f"pool {self.label!r}: cold_start_s must be "
                             "non-negative seconds or a (mean_s >= 0, "
                             f"jitter_frac >= 0) pair, got {cs!r}")

    @property
    def cold_start_mean_s(self) -> float:
        if isinstance(self.cold_start_s, (tuple, list)):
            return float(self.cold_start_s[0])
        return float(self.cold_start_s)

    @property
    def cold_start_jitter(self) -> float:
        if isinstance(self.cold_start_s, (tuple, list)):
            return float(self.cold_start_s[1])
        return 0.0

    @property
    def label(self) -> str:
        return self.name or self.service.name


@dataclass(frozen=True)
class FleetConfig:
    """A fleet = per-shape pools sharing one request queue (e.g. a cheap
    ``v5e-4`` baseline pool plus ``v5e-16`` burst capacity)."""
    pools: tuple
    max_queue: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "pools", tuple(self.pools))
        if not self.pools:
            raise ValueError("FleetConfig needs at least one pool")

    @property
    def n_pools(self) -> int:
        return len(self.pools)

    def drain_order(self) -> list:
        """Pool indices cheapest-$/request first — the order the shared queue
        is drained in, so expensive burst capacity only sees overflow. Ties
        (linear cost models price every shape identically per request) go to
        the finer-grained pool: that is the baseline capacity a deployer keeps
        busy, the coarse pool being burst overflow."""
        return sorted(range(len(self.pools)),
                      key=lambda i: (self.pools[i].service.usd_per_request,
                                     self.pools[i].service.shape.price_per_hour,
                                     self.pools[i].label))

    def shape_label(self) -> str:
        names = []
        for p in self.pools:
            if p.service.shape.name not in names:
                names.append(p.service.shape.name)
        return "+".join(names)


@dataclass
class FleetObs:
    """What a policy sees at the end of a bin (arrays are (n_seeds,) unless
    noted). Homogeneous policies read the aggregate fields; per-pool policies
    read ``pool_replicas``/``pool_in_flight``/``pools``; class-aware policies
    read ``class_queue``/``class_arrival_rate``/``classes``."""
    t_s: float                  # sim time at bin end
    dt_s: float
    arrival_rate: np.ndarray    # requests/s observed this bin (all classes)
    queue: np.ndarray           # backlog after serving/drops (all classes)
    replicas: np.ndarray        # ready replicas this bin (all pools)
    in_flight: np.ndarray       # replicas still cold-starting (all pools)
    utilization: np.ndarray     # served / capacity this bin, in [0, 1]
    service: ServiceModel       # pool 0's service (homogeneous fleets)
    pool_replicas: np.ndarray = None    # (n_seeds, n_pools) ready per pool
    pool_in_flight: np.ndarray = None   # (n_seeds, n_pools) cold-starting
    pools: tuple = ()                   # the fleet's PoolConfigs
    class_queue: np.ndarray = None      # (n_seeds, n_classes) backlog
    class_arrival_rate: np.ndarray = None  # (n_seeds, n_classes) req/s
    classes: tuple = ()                 # the workload's RequestClasses


@dataclass
class SimResult:
    trace: Trace                # aggregate stream (multi-class: the sum)
    fleet: FleetConfig
    policy_name: str
    slo_s: float                # multi-class: the tightest class SLO
    # (n_seeds, n_bins) traces:
    arrivals: np.ndarray
    admitted: np.ndarray        # arrivals minus admission-control drops
    served: np.ndarray
    dropped: np.ndarray
    queue: np.ndarray
    replicas: np.ndarray        # ready (serving) replicas, all pools
    billed_replicas: np.ndarray  # ready + cold-starting (the cloud bill)
    latency_s: np.ndarray       # per-bin mean sojourn of served reqs (exact)
    ok_served: np.ndarray       # served mass meeting its class SLO (exact)
    utilization: np.ndarray
    # (n_seeds, n_bins, n_pools) traces:
    pool_replicas: np.ndarray
    pool_billed: np.ndarray
    pool_served: np.ndarray
    # exact pooled per-request sojourn distribution (across seeds/classes):
    sojourn_values: np.ndarray = field(repr=False, default=None)
    sojourn_weights: np.ndarray = field(repr=False, default=None)
    # multi-class accounting (single-class sims carry one class):
    workload: Workload = field(repr=False, default=None)
    discipline: str = "fifo"
    # (n_seeds, n_bins, n_classes) traces:
    class_admitted: np.ndarray = field(repr=False, default=None)
    class_served: np.ndarray = field(repr=False, default=None)
    class_dropped: np.ndarray = field(repr=False, default=None)
    class_queue: np.ndarray = field(repr=False, default=None)
    class_ok: np.ndarray = field(repr=False, default=None)
    # per-class exact sojourn distributions: ((values, weights), ...):
    class_sojourns: tuple = field(repr=False, default=())
    # fidelity knobs of the run (coarse bin-granular core: 1 / False):
    n_substeps: int = 1
    preemptive: bool = False
    # substep-core extras, (n_seeds, n_bins) or None on the coarse core:
    preemptions: np.ndarray = field(repr=False, default=None)
    preempted_work: np.ndarray = field(repr=False, default=None)  # batch-s
    residue_work: np.ndarray = field(repr=False, default=None)    # batch-s

    @property
    def classes(self) -> tuple:
        return self.workload.classes if self.workload is not None else ()

    @property
    def service(self) -> ServiceModel:
        return self.fleet.pools[0].service

    @property
    def cold_start_s(self) -> float:
        return self.fleet.pools[0].cold_start_mean_s

    @property
    def dt_s(self) -> float:
        return self.trace.dt_s

    def replica_bins(self) -> float:
        """Mean (over seeds) total billed replica-bins — the billing integral.
        Cold-starting replicas cost money before they serve anything."""
        return float(self.billed_replicas.sum(axis=1).mean())

    def billed_usd(self) -> float:
        """Mean (over seeds) dollar bill, summed over pools at each pool's own
        shape price."""
        usd = 0.0
        for p, pc in enumerate(self.fleet.pools):
            bins = float(self.pool_billed[:, :, p].sum(axis=1).mean())
            usd += dollar_cost(self.dt_s, bins, pc.service.shape.chips,
                               pc.service.shape.hw)
        return usd


def _initial_replicas(pool: PoolConfig, rate0: float, provision: bool) -> int:
    n0 = pool.initial_replicas
    if n0 is None:
        if provision:   # provision for the trace's initial rate (a deployer's
            n0 = int(np.ceil(rate0 / max(pool.service.max_throughput, _EPS)))
        else:           # move); secondary pools start at their floor
            n0 = pool.min_replicas
    if provision:
        n0 = max(n0, 1)
    return int(np.clip(n0, max(pool.min_replicas, 1) if provision
                       else pool.min_replicas, pool.max_replicas))


def _cold_start_plan(pools, dt: float):
    """Per-pool cold-start discretization: (cold_bins, scan_bins, jittered,
    cs_mu, cs_sigma). ``scan_bins`` bounds how far ahead a jittered launch
    can land (the ~99.9th-percentile delay, longer draws clipped there)."""
    cold_bins = [max(int(round(p.cold_start_mean_s / dt)), 0) for p in pools]
    # lognormal jitter: sigma^2 = ln(1 + jitter^2) keeps the sampled mean at
    # exactly cold_start_mean_s; pend/scan slack covers the ~99.9th-percentile
    # delay (longer draws are clipped there)
    cs_sigma = [np.sqrt(np.log1p(p.cold_start_jitter ** 2)) for p in pools]
    cs_mu = [np.log(max(p.cold_start_mean_s, _EPS)) - sg * sg / 2
             for p, sg in zip(pools, cs_sigma)]
    scan_bins = [cb if p.cold_start_jitter == 0 or p.cold_start_mean_s == 0
                 else max(int(np.ceil(np.exp(m + 3.1 * sg) / dt)), cb, 1)
                 for p, cb, m, sg in zip(pools, cold_bins, cs_mu, cs_sigma)]
    jittered = [p.cold_start_jitter > 0 and p.cold_start_mean_s > 0
                for p in pools]
    return cold_bins, scan_bins, jittered, cs_mu, cs_sigma


def draw_cold_start_delays(pools, n_seeds: int, n_bins: int, dt_s: float,
                           cold_start_seed: int, seed_ids) -> np.ndarray:
    """Pre-draw every (seed row, bin, jittered pool) spin-up delay, one
    substream per (cold_start_seed, absolute seed, pool): the draws a row
    sees depend only on its absolute identity, never on which slice of the
    workload it is simulated in or on the policy — the paired-replicate
    property candidate tuning relies on. Returns the (n_seeds, n_bins,
    n_pools) tensor, or ``None`` when no pool is jittered. A tuning scenario
    hoists this tensor out of the per-candidate loop
    (``TuningScenario.cold_start_delays``)."""
    _, _, jittered, cs_mu, cs_sigma = _cold_start_plan(pools, dt_s)
    if not any(jittered):
        return None
    P = len(pools)
    cs_delay = np.zeros((n_seeds, n_bins, P))
    for p in range(P):
        if not jittered[p]:
            continue
        for i, g in enumerate(seed_ids):
            row_rng = np.random.default_rng((cold_start_seed, int(g), p))
            cs_delay[i, :, p] = row_rng.lognormal(cs_mu[p], cs_sigma[p],
                                                  size=n_bins)
    return cs_delay


def _assemble_result(workload, fleet: FleetConfig, disc, policy_name: str,
                     order, slos, admitted, cls, rec, pool_rep, pool_billed,
                     slot_served, slot_class, slot_bt, *,
                     n_substeps: int = 1, preemptive: bool = False,
                     slot_order=None, admitted_fine=None,
                     extras=None, record_telemetry: bool = True) -> SimResult:
    """Exact per-request latency + SimResult from the dynamics arrays — the
    post-loop half of the simulation, shared by the numpy and JAX backends
    (the compiled path reproduces the *dynamics*; this accounting is common).

    Slots are (substep, drain-rank) pairs, time-ordered, matching how the
    queue head was assigned; within a class every discipline serves FIFO, so
    the per-class cumulative served counts recover exact sojourns. On the
    coarse core a substep is a whole bin and slots are (bin, pool drain
    rank); the substep core subdivides each bin into ``n_substeps``
    micro-steps of ``M = slot_served.shape[2]`` slots each (a completion +
    a fluid-pour slot per pool), with ``slot_order`` naming each slot rank's
    pool and ``admitted_fine`` placing admissions at substep granularity."""
    trace = workload.total_trace()
    S, T = admitted.shape
    P = fleet.n_pools
    n = int(n_substeps)
    M = slot_served.shape[2]        # slots per substep (P coarse, 2P fine)
    U = T * n                       # total substeps
    dt = trace.dt_s
    dt_sub = dt / n
    if slot_order is None:
        slot_order = list(order)
    adm_fine = cls["admitted"] if admitted_fine is None else admitted_fine
    slot_bin = np.repeat(np.arange(U), M)
    flat_bt = slot_bt.reshape(S, U * M)
    cms = multiclass_cohort_metrics(adm_fine, slot_class, slot_bin,
                                    flat_bt, dt_sub, slos)
    class_ok = np.stack([cm.ok_served.reshape(S, T, n * M).sum(axis=2)
                         for cm in cms], axis=2)
    C = len(cms)
    class_served = slot_class.reshape(S, T, n * M, C).sum(axis=2)
    # per-bin mean sojourn pooled over classes and slots
    mass_soj = sum((cm.mean_sojourn * slot_class[:, :, c])
                   .reshape(S, T, n * M).sum(axis=2)
                   for c, cm in enumerate(cms))
    served_all = rec["served"]
    lat = np.divide(mass_soj, served_all,
                    out=np.zeros((S, T)), where=served_all > 0)
    # slots are drain-rank-ordered; report per-pool served in pool order
    su = slot_served.reshape(S, T, n * M)
    pool_served = np.stack(
        [su[:, :, [i * M + r for i in range(n)
                   for r, q in enumerate(slot_order) if q == p]].sum(axis=2)
         for p in range(P)], axis=2)
    extras = extras or {}

    result = SimResult(
        trace=trace, fleet=fleet, policy_name=policy_name,
        slo_s=float(slos.min()),
        arrivals=trace.arrivals.astype(float), admitted=admitted,
        served=served_all, dropped=rec["dropped"], queue=rec["queue"],
        replicas=rec["replicas"], billed_replicas=rec["billed"],
        latency_s=lat, ok_served=class_ok.sum(axis=2),
        utilization=rec["util"], pool_replicas=pool_rep,
        pool_billed=pool_billed, pool_served=pool_served,
        sojourn_values=np.concatenate([cm.sojourn_values for cm in cms]),
        sojourn_weights=np.concatenate([cm.sojourn_weights for cm in cms]),
        workload=workload, discipline=disc.name,
        class_admitted=cls["admitted"], class_served=class_served,
        class_dropped=cls["dropped"], class_queue=cls["queue"],
        class_ok=class_ok,
        class_sojourns=tuple((cm.sojourn_values, cm.sojourn_weights)
                             for cm in cms),
        n_substeps=n, preemptive=bool(preemptive),
        preemptions=extras.get("preemptions"),
        preempted_work=extras.get("preempted_work"),
        residue_work=extras.get("residue_work"))
    # Both backends funnel their dynamics through this one assembly path, so
    # an active telemetry session sees identical streams from either; the
    # hook only *reads* the finished result (no-op when disabled).
    # ``record_telemetry=False`` marks an interim prefix assembly of a
    # segmented run — the closed-loop controller peeks at the trace-so-far
    # without double-counting it in an active session.
    if record_telemetry:
        telemetry.record(result, slot_bt=slot_bt, slot_served=slot_served,
                         order=slot_order)
    return result


def _resolve_backend(backend: str, fleet: FleetConfig, policy, classes):
    """Map backend="numpy"|"jax"|"auto" to ("numpy", None) or
    ("jax", kernel). "auto" prefers the compiled path and silently falls
    back to numpy for policies with no kernel (custom Python subclasses);
    an explicit "jax" raises instead of silently changing semantics."""
    if backend not in ("numpy", "jax", "auto"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "expected 'numpy', 'jax' or 'auto'")
    if backend == "numpy":
        return "numpy", None
    from repro.fleet import jaxsim
    if not jaxsim.available():
        if backend == "jax":
            raise ValueError("backend='jax' requires jax to be installed "
                             "(use backend='auto' to fall back to numpy)")
        return "numpy", None
    kernel = policy.kernel(fleet, classes) \
        if hasattr(policy, "kernel") else None
    if kernel is None:
        if backend == "jax":
            raise ValueError(
                f"backend='jax': policy {getattr(policy, 'name', policy)!r} "
                "has no compiled kernel (custom Python policies run on the "
                "numpy reference path; use backend='auto' to fall back)")
        return "numpy", None
    return "jax", kernel


def simulate_fleet(workload, fleet: FleetConfig, policy, *,
                   slo_s: float = None, max_queue: float = None,
                   discipline="fifo", cold_start_seed: int = 0,
                   seed_indices=None, backend: str = "numpy",
                   cold_start_delays=None, n_substeps: int = 1,
                   preemptive: bool = False) -> SimResult:
    """Run ``policy`` against a ``Workload`` (or bare ``Trace``) on a
    heterogeneous ``fleet``.

    ``workload`` is either a multi-class ``Workload`` (per-class SLOs come
    from its ``RequestClass``es; ``slo_s`` must be omitted) or a single-class
    ``Trace`` (``slo_s`` required, the pre-multi-class calling convention).
    ``discipline`` picks the scheduling order across classes — ``"fifo"``,
    ``"priority"``, ``"edf"`` or a ``Discipline`` instance; single-class
    workloads behave identically under all of them.

    ``max_queue`` bounds the backlog (admission control): overflow is dropped
    on arrival — shedding the classes the discipline values least first — and
    counted as an SLO violation. ``None`` = unbounded (or the fleet's own
    ``max_queue``). Per-pool policies (``policy.per_pool``) return
    (n_seeds, n_pools) targets; plain policies require a single-pool fleet.

    ``cold_start_seed`` seeds the per-launch spin-up jitter of pools whose
    ``cold_start_s`` is a (mean, jitter) pair; with only constant cold starts
    it is unused and the simulation path is byte-identical to earlier
    revisions. Each Monte Carlo row draws from its own substream keyed by
    (``cold_start_seed``, absolute seed index, pool), so simulating a seed
    *slice* of a workload reproduces exactly the draws the full workload
    would give those rows — ``seed_indices`` (default ``arange(n_seeds)``)
    names the absolute indices of the rows being simulated.
    ``cold_start_delays`` (optional) supplies that (n_seeds, n_bins,
    n_pools) jitter tensor pre-drawn (``draw_cold_start_delays``), so a
    tuning round stops re-drawing identical values per candidate.

    ``backend`` selects the implementation: ``"numpy"`` (the reference
    Python loop), ``"jax"`` (the compiled ``lax.scan`` path,
    ``repro.fleet.jaxsim`` — requires the policy family to have a functional
    kernel), or ``"auto"`` (compiled when possible, numpy otherwise). Both
    backends produce the same ``SimResult`` up to float rounding; the exact
    per-request latency accounting is shared.

    ``n_substeps`` / ``preemptive`` pick the simulator fidelity.
    ``n_substeps=1`` with ``preemptive=False`` (the default) is the coarse
    bin-granular fluid core — byte-identical to earlier revisions on both
    backends. ``n_substeps > 1`` subdivides every bin into that many
    micro-steps and switches to the substep engine: batch service becomes an
    explicit checkpoint-resume residue (in-flight work survives bin
    boundaries and scale-downs), and with ``preemptive=True`` a strictly
    lower-keyed head-of-queue cohort interrupts a running batch at substep
    boundaries (EDF / strict priority; FIFO keys never outrank a running
    batch, so FIFO is unaffected). Policies observe bin-aggregated signals
    either way.
    """
    if isinstance(workload, Trace):
        if slo_s is None:
            raise ValueError("slo_s is required when simulating a bare Trace")
        workload = Workload.from_trace(workload, slo_s)
    elif slo_s is not None:
        raise ValueError("slo_s comes from the Workload's RequestClasses; "
                         "pass one or the other, not both")
    n_substeps = int(n_substeps)
    if n_substeps < 1:
        raise ValueError(f"n_substeps must be >= 1, got {n_substeps}")
    disc = get_discipline(discipline)
    classes = workload.classes
    C = len(classes)
    slos = workload.slos()
    trace = workload.total_trace()
    pools = fleet.pools
    P = len(pools)
    per_pool = bool(getattr(policy, "per_pool", False))
    if P > 1 and not per_pool:
        raise ValueError(f"policy {policy.name!r} returns a single target; "
                         f"a {P}-pool fleet needs a per-pool policy "
                         "(e.g. HeterogeneousPredictivePolicy)")
    if max_queue is None:
        max_queue = fleet.max_queue
    order = fleet.drain_order()
    S, T = trace.arrivals.shape
    dt = trace.dt_s
    cold_bins, scan_bins, jittered, _, _ = _cold_start_plan(pools, dt)
    max_cb = max(scan_bins)
    seed_ids = (np.arange(S) if seed_indices is None
                else np.asarray(seed_indices, int))
    if len(seed_ids) != S:
        raise ValueError(f"seed_indices names {len(seed_ids)} rows for "
                         f"a {S}-seed workload")
    if cold_start_delays is not None:
        cs_delay = np.asarray(cold_start_delays, float)
        if cs_delay.shape != (S, T, P):
            raise ValueError(f"cold_start_delays shape {cs_delay.shape} != "
                             f"{(S, T, P)}")
    else:
        cs_delay = draw_cold_start_delays(pools, S, T, dt, cold_start_seed,
                                          seed_ids)
    backend, kernel = _resolve_backend(backend, fleet, policy, classes)
    if backend == "jax":
        return _simulate_fleet_jax(workload, fleet, policy, kernel, disc,
                                   order, slos, max_queue, cs_delay,
                                   n_substeps, preemptive)
    if n_substeps > 1 or preemptive:
        return _simulate_fleet_substep(workload, fleet, policy, disc, order,
                                       slos, max_queue, cs_delay, n_substeps,
                                       preemptive)
    svc_terms = [(p.service.t_fixed, p.service.t_per_unit,
                  float(p.service.max_batch)) for p in pools]

    policy.reset(S)
    ready = np.zeros((S, P))
    for p, pc in enumerate(pools):
        ready[:, p] = _initial_replicas(pc, trace.rate[0], p == order[0])
    cq = CohortQueue(disc, classes, S, T, dt)   # per-class queue state
    arrivals_c = workload.arrivals.astype(float)  # (S, T, C)
    pend = np.zeros((S, T + max_cb + 2, P))   # scale-ups maturing per bin
    in_flight = np.zeros((S, P))              # running sum of future pend

    slot_served = np.zeros((S, T, P))         # per (bin, drain-rank) mass
    slot_class = np.zeros((S, T * P, C))      # ...split across classes
    slot_bt = np.zeros((S, T, P))             # batch time of that slot
    admitted = np.zeros((S, T))
    cls = {k: np.zeros((S, T, C)) for k in
           ("admitted", "dropped", "queue")}
    rec = {k: np.zeros((S, T)) for k in
           ("served", "dropped", "queue", "replicas", "billed", "util")}
    pool_rep = np.zeros((S, T, P))
    pool_billed = np.zeros((S, T, P))

    for t in range(T):
        matured = pend[:, t, :]
        ready += matured
        in_flight -= matured
        arr_c = arrivals_c[:, t, :]
        arr = arr_c.sum(axis=1)
        # admission control happens at arrival: a dropped request never queues,
        # so it cannot inflate the sojourn of requests that are actually
        # served; overflow is shed from the arriving cohorts the discipline
        # would have served last (largest key first)
        drop_c = np.zeros((S, C))
        if max_queue is not None:
            over = np.maximum(cq.backlog().sum(axis=1) + arr - max_queue, 0.0)
            for c in cq.drop_order(t):
                d = np.minimum(arr_c[:, c], over)
                drop_c[:, c] = d
                over = over - d
        adm_c = arr_c - drop_c
        cq.admit(t, adm_c)
        admitted[:, t] = adm_c.sum(axis=1)
        cls["admitted"][:, t, :] = adm_c
        cls["dropped"][:, t, :] = drop_c
        drop = drop_c.sum(axis=1)

        # drain the shared queue across pools, cheapest capacity first; the
        # discipline decides which class's cohorts each slot's mass comes from
        remaining = cq.backlog().sum(axis=1)
        capacity = np.zeros(S)
        for rank, p in enumerate(order):
            t_fixed, t_unit, max_b = svc_terms[p]
            n = np.maximum(ready[:, p], 0.0)
            has = n > 0
            # per-replica batch: split the backlog, clipped to the batch window
            b = np.clip(np.ceil(np.divide(remaining, n, out=np.zeros(S),
                                          where=has)), 1.0, max_b)
            bt = np.maximum(t_fixed + b * t_unit, _EPS)
            cap = np.where(has, n * b / bt, 0.0) * dt
            split = cq.serve(t, np.minimum(remaining, cap))
            s_p = split.sum(axis=1)
            slot_class[:, t * P + rank, :] = split
            remaining = remaining - s_p
            capacity += cap
            slot_served[:, t, rank] = s_p
            slot_bt[:, t, rank] = bt
        queue_c = cq.backlog()
        queue = queue_c.sum(axis=1)
        cls["queue"][:, t, :] = queue_c
        served = slot_served[:, t, :].sum(axis=1)

        pool_rep[:, t, :] = ready
        n_ready = ready.sum(axis=1)
        obs = FleetObs(
            t_s=(t + 1) * dt, dt_s=dt, arrival_rate=arr / dt, queue=queue,
            replicas=n_ready, in_flight=in_flight.sum(axis=1),
            utilization=np.divide(served, capacity, out=np.zeros(S),
                                  where=capacity > 0),
            service=pools[0].service, pool_replicas=pool_rep[:, t, :],
            pool_in_flight=in_flight.copy(), pools=pools,
            class_queue=queue_c, class_arrival_rate=arr_c / dt,
            classes=classes)
        target = np.asarray(policy.decide(t, obs), float)
        if target.ndim == 1:
            target = target[:, None]

        for p, pc in enumerate(pools):
            tg = np.clip(target[:, p], pc.min_replicas, pc.max_replicas)
            excess = np.maximum(ready[:, p] + in_flight[:, p] - tg, 0.0)
            if excess.any():
                # scale down: cancel pending cold-starts newest-first (they
                # stop billing now), then shrink ready replicas
                for j in range(min(t + 1 + scan_bins[p], T + max_cb + 1),
                               t, -1):
                    col = pend[:, j, p]
                    if not col.any():
                        continue
                    cut = np.minimum(col, excess)
                    pend[:, j, p] = col - cut
                    in_flight[:, p] -= cut
                    excess -= cut
                    if not excess.any():
                        break
                ready[:, p] = np.maximum(ready[:, p] - excess, 0.0)
            grow = np.maximum(tg - ready[:, p] - in_flight[:, p], 0.0)
            if jittered[p]:
                jb = np.clip(np.rint(cs_delay[:, t, p] / dt).astype(int), 0,
                             scan_bins[p])
                idx = np.minimum(t + 1 + jb, T + max_cb + 1)
                pend[np.arange(S), idx, p] += grow
            else:
                pend[:, min(t + 1 + cold_bins[p], T + max_cb + 1), p] += grow
            in_flight[:, p] += grow
            # the bill: replicas that served this bin (even if torn down at
            # its end) plus everything cold-starting after this bin's
            # decisions — a launch is billed in its launch bin, a cancelled
            # launch is not
            pool_billed[:, t, p] = obs.pool_replicas[:, p] + in_flight[:, p]

        rec["served"][:, t] = served
        rec["dropped"][:, t] = drop
        rec["queue"][:, t] = queue
        rec["replicas"][:, t] = n_ready
        rec["billed"][:, t] = pool_billed[:, t, :].sum(axis=1)
        rec["util"][:, t] = obs.utilization

    return _assemble_result(workload, fleet, disc, policy.name, order, slos,
                            admitted, cls, rec, pool_rep, pool_billed,
                            slot_served, slot_class, slot_bt)


@dataclass
class FleetState:
    """Checkpoint of the substep engine's carried state at a bin boundary —
    everything a resumed segment needs so the stitched trace is one
    continuous run: ready/cold-starting replicas, the pending-launch ledger,
    the cumulative-admitted queue curves, and the in-flight / preempted
    batch residue (PR 7's checkpoint-resume machinery, made explicit).
    All arrays are owned and mutated in place by ``_run_substep_segment``."""
    t: int                      # next bin to simulate
    ready: np.ndarray           # (S, P) ready replicas
    in_flight: np.ndarray       # (S, P) replicas still cold-starting
    pend: np.ndarray            # (S, T + max_cb + 2, P) launches maturing
    Acum: np.ndarray            # (S, C, T + 1) cumulative admitted curves
    done: np.ndarray            # (S, C) cumulative poured totals
    busy_mass: np.ndarray       # (S, P, C) in-flight batch mass split
    busy_work: np.ndarray       # (S, P) in-flight batch work remaining
    busy_key: np.ndarray        # (S, P) in-flight batch preemption key
    held_mass: np.ndarray       # (S, P, C) checkpointed (preempted) batch
    held_work: np.ndarray       # (S, P)
    held_key: np.ndarray        # (S, P)


@dataclass
class _SubstepBuffers:
    """Full-trace output arrays of a (possibly segmented) substep run; each
    segment fills its own bin range."""
    slot_served: np.ndarray     # (S, U, M) per (substep, slot) served mass
    slot_class: np.ndarray      # (S, U * M, C) ...split across classes
    slot_bt: np.ndarray         # (S, U, M) batch time of that slot
    admitted_fine: np.ndarray   # (S, U, C) admissions at substep granularity
    admitted: np.ndarray        # (S, T)
    cls: dict                   # (S, T, C) admitted / dropped / queue
    rec: dict                   # (S, T) served / dropped / ... / util
    pool_rep: np.ndarray        # (S, T, P)
    pool_billed: np.ndarray     # (S, T, P)
    pre_n: np.ndarray           # (S, T) preemption counts
    pre_w: np.ndarray           # (S, T) preempted work (batch-seconds)
    residue: np.ndarray         # (S, T) carried work at bin end


def _init_substep_state(workload, fleet: FleetConfig, order,
                        max_cb: int) -> FleetState:
    trace = workload.total_trace()
    S, T = trace.arrivals.shape
    C = len(workload.classes)
    P = fleet.n_pools
    ready = np.zeros((S, P))
    for p, pc in enumerate(fleet.pools):
        ready[:, p] = _initial_replicas(pc, trace.rate[0], p == order[0])
    return FleetState(
        t=0, ready=ready, in_flight=np.zeros((S, P)),
        pend=np.zeros((S, T + max_cb + 2, P)),
        # queue state: cumulative-admitted curves + poured totals (the
        # compiled backend's representation — both engines pour via the
        # same tables)
        Acum=np.zeros((S, C, T + 1)), done=np.zeros((S, C)),
        # in-flight batch per pool: mass split, remaining work, key
        busy_mass=np.zeros((S, P, C)), busy_work=np.zeros((S, P)),
        busy_key=np.full((S, P), -np.inf),
        # checkpointed (preempted) batch per pool
        held_mass=np.zeros((S, P, C)), held_work=np.zeros((S, P)),
        held_key=np.full((S, P), -np.inf))


def _alloc_substep_buffers(S, T, P, C, n: int) -> _SubstepBuffers:
    U = T * n
    M = 2 * P            # per substep: a completion + a pour slot per pool
    return _SubstepBuffers(
        slot_served=np.zeros((S, U, M)), slot_class=np.zeros((S, U * M, C)),
        slot_bt=np.zeros((S, U, M)), admitted_fine=np.zeros((S, U, C)),
        admitted=np.zeros((S, T)),
        cls={k: np.zeros((S, T, C))
             for k in ("admitted", "dropped", "queue")},
        rec={k: np.zeros((S, T)) for k in
             ("served", "dropped", "queue", "replicas", "billed", "util")},
        pool_rep=np.zeros((S, T, P)), pool_billed=np.zeros((S, T, P)),
        pre_n=np.zeros((S, T)), pre_w=np.zeros((S, T)),
        residue=np.zeros((S, T)))


def _run_substep_segment(workload, fleet: FleetConfig, policy, disc, order,
                         slos, max_queue, cs_delay, n: int, preemptive: bool,
                         tables, st: FleetState, buf: _SubstepBuffers,
                         t0: int, t1: int) -> None:
    """Advance the substep engine from bin ``t0`` to ``t1`` (exclusive),
    mutating ``st`` and filling ``buf[:, t0:t1]`` in place.

    The loop body is the substep engine's verbatim (see
    ``_simulate_fleet_substep``); a single ``[0, T)`` segment is
    byte-identical to the unsegmented run. Between calls the caller may
    swap ``policy`` or ``fleet`` (service behaviour only — the pend ledger
    and drain order are sized/pinned at allocation), which is how the
    closed-loop controller hot-swaps a policy mid-trace while PR 7's
    residue machinery carries the in-flight state across the boundary.
    Service terms and the cold-start plan are re-derived from ``fleet``
    here so a degraded fleet takes effect at the segment boundary."""
    from repro.fleet.discipline import table_head_key, table_pour

    trace = workload.total_trace()
    classes = workload.classes
    C = len(classes)
    pools = fleet.pools
    P = len(pools)
    S, T = trace.arrivals.shape
    dt = trace.dt_s
    dt_sub = dt / n
    cold_bins, scan_bins, jittered, _, _ = _cold_start_plan(pools, dt)
    max_cb = st.pend.shape[1] - T - 2    # pend slack fixed at allocation
    svc_terms = [(p.service.t_fixed, p.service.t_per_unit,
                  float(p.service.max_batch)) for p in pools]
    tput = [p.service.max_throughput for p in pools]
    arrivals_c = workload.arrivals.astype(float)

    ready = st.ready
    in_flight = st.in_flight
    pend = st.pend
    Acum = st.Acum
    done = st.done
    busy_mass = st.busy_mass
    busy_work = st.busy_work
    busy_key = st.busy_key
    held_mass = st.held_mass
    held_work = st.held_work
    held_key = st.held_key
    slot_served = buf.slot_served
    slot_class = buf.slot_class
    slot_bt = buf.slot_bt
    admitted_fine = buf.admitted_fine
    admitted = buf.admitted
    cls = buf.cls
    rec = buf.rec
    pool_rep = buf.pool_rep
    pool_billed = buf.pool_billed
    pre_n = buf.pre_n
    pre_w = buf.pre_w
    residue = buf.residue
    M = 2 * P

    for t in range(t0, t1):
        matured = pend[:, t, :]
        ready += matured
        in_flight -= matured
        arr_c = arrivals_c[:, t, :]
        arr = arr_c.sum(axis=1)
        total_prev = Acum[:, :, T]
        drop_c = np.zeros((S, C))
        if max_queue is not None:
            # admission control bounds *outstanding* work: waiting mass plus
            # whatever is in flight or checkpointed on the pools
            out_c = (total_prev - done) + busy_mass.sum(axis=1) \
                + held_mass.sum(axis=1)
            over = np.maximum(out_c.sum(axis=1) + arr - max_queue, 0.0)
            for c in tables["drop_rank"][t]:
                d = np.minimum(arr_c[:, c], over)
                drop_c[:, c] = d
                over = over - d
        adm_c = arr_c - drop_c
        new_total = total_prev + adm_c
        Acum[:, :, t + 1:] = new_total[:, :, None]
        admitted[:, t] = adm_c.sum(axis=1)
        admitted_fine[:, t * n, :] = adm_c
        cls["admitted"][:, t, :] = adm_c
        cls["dropped"][:, t, :] = drop_c
        drop = drop_c.sum(axis=1)

        served_bin = np.zeros(S)
        for i in range(n):
            u = t * n + i
            for rank, p in enumerate(order):
                t_fixed, t_unit, max_b = svc_terms[p]
                n_rep = np.maximum(ready[:, p], 0.0)
                has = n_rep > 0
                tau = np.full(S, dt_sub)
                comp_m = np.zeros((S, C))
                comp_btw = np.zeros(S)
                hk = table_head_key(Acum, done, tables)
                if preemptive:
                    pr = (busy_work[:, p] > 0.0) & (hk < busy_key[:, p])
                    held_mass[:, p] += np.where(pr[:, None],
                                                busy_mass[:, p], 0.0)
                    held_work[:, p] += np.where(pr, busy_work[:, p], 0.0)
                    held_key[:, p] = np.where(
                        pr, np.maximum(held_key[:, p], busy_key[:, p]),
                        held_key[:, p])
                    pre_n[:, t] += pr
                    pre_w[:, t] += np.where(pr, busy_work[:, p], 0.0)
                    busy_mass[:, p] = np.where(pr[:, None], 0.0,
                                               busy_mass[:, p])
                    busy_work[:, p] = np.where(pr, 0.0, busy_work[:, p])
                    busy_key[:, p] = np.where(pr, -np.inf, busy_key[:, p])
                # progress the in-flight batch (.copy(): the slice is a view
                # of busy_work, which is updated before tau reads w)
                w = busy_work[:, p].copy()
                tau0 = tau
                fin = (w > 0.0) & (w <= tau0)
                run = w > tau0
                comp_m += np.where(fin[:, None], busy_mass[:, p], 0.0)
                comp_btw += np.where(
                    fin,
                    busy_mass[:, p].sum(axis=1) * ((dt_sub - tau0) + w),
                    0.0)
                busy_work[:, p] = np.where(run, w - tau0, 0.0)
                busy_mass[:, p] = np.where(fin[:, None], 0.0,
                                           busy_mass[:, p])
                busy_key[:, p] = np.where(fin, -np.inf, busy_key[:, p])
                tau = np.where(fin, tau0 - w, np.where(run, 0.0, tau0))
                # resume a checkpoint, else form a new batch from the queue
                idle = busy_work[:, p] == 0.0
                res = idle & (held_work[:, p] > 0.0) & (hk >= held_key[:, p])
                busy_mass[:, p] = np.where(res[:, None], held_mass[:, p],
                                           busy_mass[:, p])
                busy_work[:, p] = np.where(res, held_work[:, p],
                                           busy_work[:, p])
                busy_key[:, p] = np.where(res, held_key[:, p],
                                          busy_key[:, p])
                held_mass[:, p] = np.where(res[:, None], 0.0,
                                           held_mass[:, p])
                held_work[:, p] = np.where(res, 0.0, held_work[:, p])
                held_key[:, p] = np.where(res, -np.inf, held_key[:, p])

                backlog = (new_total - done).sum(axis=1)
                form = idle & (~res) & (backlog > 0.0) & (tau > 0.0) & has
                b = np.clip(np.where(has, np.ceil(
                    backlog / np.where(has, n_rep, 1.0)), 0.0), 1.0, max_b)
                bt_b = np.maximum(t_fixed + b * t_unit, _EPS)
                amt = np.where(form, np.minimum(backlog, n_rep * b), 0.0)
                split, _ = table_pour(Acum, done, amt, tables)
                done = done + split
                busy_mass[:, p] = np.where(form[:, None], split,
                                           busy_mass[:, p])
                busy_work[:, p] = np.where(form, bt_b, busy_work[:, p])
                # the batch's preemption rank is its *head* key — the most
                # urgent cohort it swept up. Ranking by the largest key
                # touched would let a fresh urgent arrival preempt a batch
                # that itself carries urgent mass, checkpointing that mass
                # behind an unresumable max-key gate (priority inversion)
                busy_key[:, p] = np.where(form, hk, busy_key[:, p])
                # progress the resumed/formed batch with the leftover budget
                w2 = busy_work[:, p].copy()
                tau0 = tau
                fin2 = (w2 > 0.0) & (w2 <= tau0)
                run2 = w2 > tau0
                comp_m += np.where(fin2[:, None], busy_mass[:, p], 0.0)
                comp_btw += np.where(
                    fin2,
                    busy_mass[:, p].sum(axis=1) * ((dt_sub - tau0) + w2),
                    0.0)
                busy_work[:, p] = np.where(run2, w2 - tau0, busy_work[:, p])
                busy_work[:, p] = np.where(fin2, 0.0, busy_work[:, p])
                busy_mass[:, p] = np.where(fin2[:, None], 0.0,
                                           busy_mass[:, p])
                busy_key[:, p] = np.where(fin2, -np.inf, busy_key[:, p])
                tau = np.where(fin2, tau0 - w2, np.where(run2, 0.0, tau0))
                # fluid tail: an idle pool's leftover budget drains the
                # queue at its instantaneous rate (the coarse convention)
                idle2 = busy_work[:, p] == 0.0
                backlog2 = (new_total - done).sum(axis=1)
                b2 = np.clip(np.where(has, np.ceil(
                    backlog2 / np.where(has, n_rep, 1.0)), 0.0), 1.0, max_b)
                bt2 = np.maximum(t_fixed + b2 * t_unit, _EPS)
                tail = idle2 & (tau > 0.0) & has
                cap = np.where(tail, n_rep * b2 / bt2, 0.0) * tau
                amt2 = np.minimum(np.maximum(backlog2, 0.0), cap)
                split2, _ = table_pour(Acum, done, amt2, tables)
                done = done + split2
                pour_tot = split2.sum(axis=1)
                comp_tot = comp_m.sum(axis=1)
                k0 = u * M + 2 * rank
                slot_class[:, k0, :] = comp_m
                slot_served[:, u, 2 * rank] = comp_tot
                # completion slot bt = mass-weighted elapsed time within the
                # substep, so sojourns include pause delays exactly
                slot_bt[:, u, 2 * rank] = np.divide(
                    comp_btw, comp_tot, out=np.zeros(S),
                    where=comp_tot > 0)
                slot_class[:, k0 + 1, :] = split2
                slot_served[:, u, 2 * rank + 1] = pour_tot
                slot_bt[:, u, 2 * rank + 1] = np.where(
                    pour_tot > 0.0, (dt_sub - tau) + bt2, 0.0)
                served_bin = served_bin + comp_tot
                served_bin = served_bin + pour_tot
            # fold sub-eps float residue of a drained class (the coarse
            # loop's _MASS_EPS behaviour, applied once per substep)
            done = np.where(new_total - done <= 1e-9 + 1e-12 * new_total,
                            new_total, done)

        out_c = np.maximum(new_total - done, 0.0) + busy_mass.sum(axis=1) \
            + held_mass.sum(axis=1)
        queue = out_c.sum(axis=1)
        cls["queue"][:, t, :] = out_c
        pool_rep[:, t, :] = ready
        n_ready = ready.sum(axis=1)
        # completions are lumpy at substep granularity, so utilization is
        # served over the pools' nameplate throughput, clipped to 1
        capacity = np.zeros(S)
        for p in range(P):
            capacity = capacity + np.maximum(ready[:, p], 0.0) \
                * tput[p] * dt
        util = np.divide(served_bin, capacity, out=np.zeros(S),
                         where=capacity > 0)
        util = np.minimum(util, 1.0)
        obs = FleetObs(
            t_s=(t + 1) * dt, dt_s=dt, arrival_rate=arr / dt, queue=queue,
            replicas=n_ready, in_flight=in_flight.sum(axis=1),
            utilization=util,
            service=pools[0].service, pool_replicas=pool_rep[:, t, :],
            pool_in_flight=in_flight.copy(), pools=pools,
            class_queue=out_c, class_arrival_rate=arr_c / dt,
            classes=classes)
        target = np.asarray(policy.decide(t, obs), float)
        if target.ndim == 1:
            target = target[:, None]

        for p, pc in enumerate(pools):
            tg = np.clip(target[:, p], pc.min_replicas, pc.max_replicas)
            excess = np.maximum(ready[:, p] + in_flight[:, p] - tg, 0.0)
            if excess.any():
                for j in range(min(t + 1 + scan_bins[p], T + max_cb + 1),
                               t, -1):
                    col = pend[:, j, p]
                    if not col.any():
                        continue
                    cut = np.minimum(col, excess)
                    pend[:, j, p] = col - cut
                    in_flight[:, p] -= cut
                    excess -= cut
                    if not excess.any():
                        break
                ready[:, p] = np.maximum(ready[:, p] - excess, 0.0)
            grow = np.maximum(tg - ready[:, p] - in_flight[:, p], 0.0)
            if jittered[p]:
                jb = np.clip(np.rint(cs_delay[:, t, p] / dt).astype(int), 0,
                             scan_bins[p])
                idx = np.minimum(t + 1 + jb, T + max_cb + 1)
                pend[np.arange(S), idx, p] += grow
            else:
                pend[:, min(t + 1 + cold_bins[p], T + max_cb + 1), p] += grow
            in_flight[:, p] += grow
            pool_billed[:, t, p] = obs.pool_replicas[:, p] + in_flight[:, p]

        rec["served"][:, t] = served_bin
        rec["dropped"][:, t] = drop
        rec["queue"][:, t] = queue
        rec["replicas"][:, t] = n_ready
        rec["billed"][:, t] = pool_billed[:, t, :].sum(axis=1)
        rec["util"][:, t] = util
        residue[:, t] = busy_work.sum(axis=1) + held_work.sum(axis=1)

    st.done = done      # the one rebound (not in-place) state array
    st.t = t1


def _assemble_substep(workload, fleet: FleetConfig, disc, policy_name,
                      order, slos, buf: _SubstepBuffers, n: int,
                      preemptive: bool, *, t1: int = None,
                      record_telemetry: bool = True) -> SimResult:
    """SimResult from (a prefix of) a substep run's buffers. ``t1`` < T
    assembles the trace-so-far of a segmented run — the closed-loop
    controller's telemetry feed — and should leave ``record_telemetry``
    off so an active session only sees the finished trace once."""
    T = buf.admitted.shape[1]
    if t1 is None:
        t1 = T
    if t1 < T:
        workload = _slice_workload_time(workload, t1)
    M = buf.slot_served.shape[2]
    u1 = t1 * n
    extras = {"preemptions": buf.pre_n[:, :t1],
              "preempted_work": buf.pre_w[:, :t1],
              "residue_work": buf.residue[:, :t1]}
    slot_order = [q for q in order for _ in range(2)]
    return _assemble_result(workload, fleet, disc, policy_name, order, slos,
                            buf.admitted[:, :t1],
                            {k: v[:, :t1] for k, v in buf.cls.items()},
                            {k: v[:, :t1] for k, v in buf.rec.items()},
                            buf.pool_rep[:, :t1], buf.pool_billed[:, :t1],
                            buf.slot_served[:, :u1],
                            buf.slot_class[:, :u1 * M],
                            buf.slot_bt[:, :u1],
                            n_substeps=n, preemptive=preemptive,
                            slot_order=slot_order,
                            admitted_fine=buf.admitted_fine[:, :u1],
                            extras=extras,
                            record_telemetry=record_telemetry)


def _slice_workload_time(workload, t1: int):
    """The first ``t1`` bins of every class trace (prefix assembly of a
    segmented run keeps arrivals and buffers on the same time axis)."""
    traces = tuple(Trace(name=tr.name, dt_s=tr.dt_s, rate=tr.rate[:t1],
                         arrivals=tr.arrivals[:, :t1])
                   for tr in workload.traces)
    return Workload(workload.name, workload.classes, traces)


def _simulate_fleet_substep(workload, fleet: FleetConfig, policy, disc,
                            order, slos, max_queue, cs_delay,
                            n_substeps: int, preemptive: bool) -> SimResult:
    """Fine-Δt numpy engine: every wall-clock bin subdivided into
    ``n_substeps`` micro-steps with checkpoint-resume batch service.

    Unlike the coarse loop (fluid service: a slot's pour departs within its
    own bin), a batch here is an explicit unit of in-flight work: it is
    poured once — a covering-prefix over the discipline's static serve-order
    tables, the *same* rule the compiled backend bisects
    (``discipline.table_pour``) — then carries a work-remaining residue
    across substeps and departs only when that residue hits zero. Under
    ``preemptive=True`` a strictly lower-keyed head-of-queue cohort
    interrupts the running batch at a substep boundary: the batch
    checkpoints (mass + remaining work + key) and resumes once no queued
    cohort outranks it. Scale-downs never kill in-flight work (connection
    draining): a shrunk pool still finishes its running batch. When a batch
    completes with substep budget to spare, the leftover drains the queue
    fluidly at the pool's instantaneous rate — the coarse within-bin
    convention, so short-batch regimes keep coarse-like throughput while
    long batches get honest head-of-line blocking.

    The policy's decision cadence, the scale-down water-fill, the
    pending-launch ledger and billing are the coarse loop's verbatim; it
    observes bin-aggregated signals. The reported queue is *outstanding*
    work (admitted - departed: waiting + in-flight + checkpointed mass), so
    served + dropped + terminal queue == arrivals stays exact.

    Every per-substep float op mirrors the compiled substep core's operation
    order one-for-one; the two are pinned bit-exact in the tests. The loop
    itself lives in ``_run_substep_segment`` (state in an explicit
    ``FleetState``), so ``SegmentedSimulation`` can run the same engine in
    checkpoint-resume segments; this single-segment path is byte-identical
    to the pre-refactor function.
    """
    from repro.fleet.discipline import cohort_tables

    trace = workload.total_trace()
    classes = workload.classes
    C = len(classes)
    P = fleet.n_pools
    S, T = trace.arrivals.shape
    dt = trace.dt_s
    n = int(n_substeps)
    tables = cohort_tables(disc, classes, T, dt)
    _, scan_bins, _, _, _ = _cold_start_plan(fleet.pools, dt)

    policy.reset(S)
    st = _init_substep_state(workload, fleet, order, max(scan_bins))
    buf = _alloc_substep_buffers(S, T, P, C, n)
    _run_substep_segment(workload, fleet, policy, disc, order, slos,
                         max_queue, cs_delay, n, preemptive, tables,
                         st, buf, 0, T)
    return _assemble_substep(workload, fleet, disc, policy.name, order,
                             slos, buf, n, preemptive)


class SegmentedSimulation:
    """Checkpoint-resume driver over the substep engine: run a workload in
    bin segments, the full carried state (queues, in-flight batches,
    pending launches, batch residue) surviving every boundary, so the
    finished trace is one continuous run.

    Between segments the caller may hot-swap the policy (new params or a
    new family) and/or the fleet's *service behaviour* — the closed-loop
    controller's actuation primitive. A policy swap takes effect at the
    boundary (the incoming policy is reset; in-flight work keeps
    draining). A fleet swap models the world changing under the
    controller — e.g. ``telemetry.degrade_fleet`` inflating service times
    mid-trace — and must preserve pool count, labels and prices: hardware
    cannot be exchanged mid-trace, only how it behaves. The drain order
    and the pending-launch ledger are pinned at construction.

    ``run_until(T)`` + ``result()`` with no swaps is equivalent to
    ``simulate_fleet(..., n_substeps=n, preemptive=...)`` on the numpy
    backend (single segment: byte-identical; segmented: the same run split
    at boundaries)."""

    def __init__(self, workload, fleet: FleetConfig, policy, *,
                 slo_s: float = None, max_queue: float = None,
                 discipline="fifo", cold_start_seed: int = 0,
                 seed_indices=None, cold_start_delays=None,
                 n_substeps: int = 1, preemptive: bool = False):
        from repro.fleet.discipline import cohort_tables

        if isinstance(workload, Trace):
            if slo_s is None:
                raise ValueError("slo_s is required when simulating a "
                                 "bare Trace")
            workload = Workload.from_trace(workload, slo_s)
        elif slo_s is not None:
            raise ValueError("slo_s comes from the Workload's "
                             "RequestClasses; pass one or the other")
        n = int(n_substeps)
        if n < 1:
            raise ValueError(f"n_substeps must be >= 1, got {n}")
        self.workload = workload
        self.fleet = fleet
        self.policy = policy
        self.disc = get_discipline(discipline)
        self.n_substeps = n
        self.preemptive = bool(preemptive)
        per_pool = bool(getattr(policy, "per_pool", False))
        if fleet.n_pools > 1 and not per_pool:
            raise ValueError(f"policy {policy.name!r} returns a single "
                             f"target; a {fleet.n_pools}-pool fleet needs "
                             "a per-pool policy")
        self.max_queue = fleet.max_queue if max_queue is None else max_queue
        self.order = fleet.drain_order()
        trace = workload.total_trace()
        S, T = trace.arrivals.shape
        self.n_seeds, self.n_bins = S, T
        dt = trace.dt_s
        seed_ids = (np.arange(S) if seed_indices is None
                    else np.asarray(seed_indices, int))
        if cold_start_delays is not None:
            cs_delay = np.asarray(cold_start_delays, float)
            if cs_delay.shape != (S, T, fleet.n_pools):
                raise ValueError(
                    f"cold_start_delays shape {cs_delay.shape} != "
                    f"{(S, T, fleet.n_pools)}")
        else:
            cs_delay = draw_cold_start_delays(fleet.pools, S, T, dt,
                                              cold_start_seed, seed_ids)
        self._cs_delay = cs_delay
        self._slos = workload.slos()
        self._tables = cohort_tables(self.disc, workload.classes, T, dt)
        _, scan_bins, _, _, _ = _cold_start_plan(fleet.pools, dt)
        policy.reset(S)
        self.state = _init_substep_state(workload, fleet, self.order,
                                         max(scan_bins))
        self._buf = _alloc_substep_buffers(S, T, fleet.n_pools,
                                           len(workload.classes), n)

    @property
    def t(self) -> int:
        """Next bin to simulate (bins [0, t) are done)."""
        return self.state.t

    @property
    def done(self) -> bool:
        return self.state.t >= self.n_bins

    def run_until(self, t1: int) -> "SegmentedSimulation":
        """Advance the simulation to bin ``t1`` (exclusive)."""
        t1 = int(t1)
        if not (self.state.t <= t1 <= self.n_bins):
            raise ValueError(f"run_until({t1}): segment must lie in "
                             f"[{self.state.t}, {self.n_bins}]")
        if t1 > self.state.t:
            _run_substep_segment(self.workload, self.fleet, self.policy,
                                 self.disc, self.order, self._slos,
                                 self.max_queue, self._cs_delay,
                                 self.n_substeps, self.preemptive,
                                 self._tables, self.state, self._buf,
                                 self.state.t, t1)
        return self

    def swap(self, policy=None, fleet: FleetConfig = None) \
            -> "SegmentedSimulation":
        """Hot-swap the policy and/or the fleet's service behaviour at the
        current segment boundary. The incoming policy starts fresh
        (``reset``); carried state — queue curves, in-flight batches,
        pending launches — survives untouched."""
        if self.done:
            raise ValueError("cannot swap after the final bin")
        if fleet is not None:
            self._check_fleet_swap(fleet)
            self.fleet = fleet
        if policy is not None:
            per_pool = bool(getattr(policy, "per_pool", False))
            if self.fleet.n_pools > 1 and not per_pool:
                raise ValueError(
                    f"policy {policy.name!r} returns a single target; a "
                    f"{self.fleet.n_pools}-pool fleet needs a per-pool "
                    "policy")
            policy.reset(self.n_seeds)
            self.policy = policy
        return self

    def _check_fleet_swap(self, fleet: FleetConfig) -> None:
        old = self.fleet
        if fleet.n_pools != old.n_pools:
            raise ValueError(f"fleet swap changes pool count "
                             f"({old.n_pools} -> {fleet.n_pools})")
        for p_new, p_old in zip(fleet.pools, old.pools):
            same = (p_new.label == p_old.label
                    and p_new.service.shape.name == p_old.service.shape.name
                    and p_new.service.shape.price_per_hour
                    == p_old.service.shape.price_per_hour)
            if not same:
                raise ValueError(
                    f"fleet swap must keep pool identity/pricing (pool "
                    f"{p_old.label!r} -> {p_new.label!r}); only service "
                    "behaviour may change mid-trace")
        _, scan_bins, _, _, _ = _cold_start_plan(
            fleet.pools, self.workload.dt_s)
        max_cb = self.state.pend.shape[1] - self.n_bins - 2
        if max(scan_bins) > max_cb:
            raise ValueError(
                "fleet swap lengthens the cold-start horizon beyond the "
                f"allocated launch ledger ({max(scan_bins)} > {max_cb} "
                "bins)")

    def result(self) -> SimResult:
        """The finished continuous run (requires ``run_until(n_bins)``)."""
        if not self.done:
            raise ValueError(f"simulation at bin {self.state.t} of "
                             f"{self.n_bins}; run_until the end first")
        return _assemble_substep(self.workload, self.fleet, self.disc,
                                 self.policy.name, self.order, self._slos,
                                 self._buf, self.n_substeps,
                                 self.preemptive)

    def partial_result(self, *, record_telemetry: bool = False) -> SimResult:
        """The trace-so-far (bins [0, t)) as a SimResult — the closed-loop
        controller's telemetry feed. Telemetry recording is off by default
        so an active session sees the finished trace exactly once."""
        if self.state.t == 0:
            raise ValueError("no bins simulated yet")
        return _assemble_substep(self.workload, self.fleet, self.disc,
                                 self.policy.name, self.order, self._slos,
                                 self._buf, self.n_substeps,
                                 self.preemptive, t1=self.state.t,
                                 record_telemetry=record_telemetry)


def _dynamics_inputs(workload, fleet: FleetConfig, order, cs_delay):
    """Shared (candidate-independent) array inputs of the compiled backend:
    per-class arrivals, per-(seed, bin, pool) launch-landing offsets, and
    service terms. Launch offsets fold the jitter discretization
    (``clip(rint(delay / dt), 0, scan_bins)``) so the scan step is pure
    arithmetic."""
    pools = fleet.pools
    trace = workload.total_trace()
    S, T = trace.arrivals.shape
    P = len(pools)
    dt = trace.dt_s
    cold_bins, scan_bins, jittered, _, _ = _cold_start_plan(pools, dt)
    jb = np.empty((S, T, P), np.int32)
    for p in range(P):
        if jittered[p] and cs_delay is not None:
            jb[:, :, p] = np.clip(np.rint(cs_delay[:, :, p] / dt).astype(int),
                                  0, scan_bins[p])
        else:
            jb[:, :, p] = cold_bins[p]
    return dict(
        arrivals=workload.arrivals.astype(float), jb=jb, dt=dt,
        order=order,
        t_fixed=[p.service.t_fixed for p in pools],
        t_unit=[p.service.t_per_unit for p in pools],
        max_b=[float(p.service.max_batch) for p in pools],
        tput=[p.service.max_throughput for p in pools],
        max_cold_bins=max(scan_bins))


def _candidate_arrays(fleet: FleetConfig, order, rate0: float):
    """Per-candidate quota bounds and initial fleet for the compiled
    backend (quota dims make these differ across tuning candidates)."""
    pools = fleet.pools
    min_rep = np.array([p.min_replicas for p in pools], float)
    max_rep = np.array([p.max_replicas for p in pools], float)
    init_ready = np.array([_initial_replicas(pc, rate0, p == order[0])
                           for p, pc in enumerate(pools)], float)
    return min_rep, max_rep, init_ready


def _result_from_dynamics(workload, fleet: FleetConfig, disc, policy_name,
                          order, slos, out, n_substeps: int = 1,
                          preemptive: bool = False) -> SimResult:
    """Build a SimResult from one candidate's compiled-dynamics outputs
    (arrays with leading dims (S, T))."""
    S, T, C = out["admitted_c"].shape
    P = fleet.n_pools
    cls = {"admitted": out["admitted_c"], "dropped": out["dropped_c"],
           "queue": out["queue_c"]}
    if n_substeps == 1 and not preemptive:
        rec = {"served": out["slot_served"].sum(axis=2),
               "dropped": out["dropped_c"].sum(axis=2),
               "queue": out["queue_c"].sum(axis=2),
               "replicas": out["pool_rep"].sum(axis=2),
               "billed": out["billed"].sum(axis=2),
               "util": out["util"]}
        return _assemble_result(
            workload, fleet, disc, policy_name, order, slos,
            out["admitted_c"].sum(axis=2), cls, rec, out["pool_rep"],
            out["billed"], out["slot_served"],
            out["slot_split"].reshape(S, T * P, C), out["slot_bt"])
    n = int(n_substeps)
    M = 2 * P
    U = T * n
    rec = {"served": out["served_bin"],
           "dropped": out["dropped_c"].sum(axis=2),
           "queue": out["queue_c"].sum(axis=2),
           "replicas": out["pool_rep"].sum(axis=2),
           "billed": out["billed"].sum(axis=2),
           "util": out["util"]}
    admitted_fine = np.zeros((S, U, C))
    admitted_fine[:, ::n, :] = out["admitted_c"]
    extras = {"preemptions": out["pre_n"], "preempted_work": out["pre_w"],
              "residue_work": out["residue"]}
    return _assemble_result(
        workload, fleet, disc, policy_name, order, slos,
        out["admitted_c"].sum(axis=2), cls, rec, out["pool_rep"],
        out["billed"], out["slot_served"].reshape(S, U, M),
        out["slot_split"].reshape(S, U * M, C),
        out["slot_bt"].reshape(S, U, M),
        n_substeps=n, preemptive=preemptive,
        slot_order=[q for q in order for _ in range(2)],
        admitted_fine=admitted_fine, extras=extras)


def _simulate_fleet_jax(workload, fleet: FleetConfig, policy, kernel, disc,
                        order, slos, max_queue, cs_delay,
                        n_substeps: int = 1,
                        preemptive: bool = False) -> SimResult:
    """One policy on the compiled backend: the same batched core the tuner
    uses, with a single candidate."""
    from repro.fleet import jaxsim
    from repro.fleet.discipline import cohort_tables

    trace = workload.total_trace()
    T = trace.arrivals.shape[1]
    tables = cohort_tables(disc, workload.classes, T, trace.dt_s)
    min_rep, max_rep, init_ready = _candidate_arrays(fleet, order,
                                                     trace.rate[0])
    out = jaxsim.run_dynamics(
        kernel, **_dynamics_inputs(workload, fleet, order, cs_delay),
        max_queue=max_queue, n_substeps=n_substeps, preemptive=preemptive,
        tables={k: v[None] for k, v in tables.items()},
        kp={k: np.asarray([v]) for k, v in kernel.params_of(policy).items()},
        min_rep=min_rep[None], max_rep=max_rep[None],
        init_ready=init_ready[None])
    return _result_from_dynamics(workload, fleet, disc, policy.name, order,
                                 slos, {k: v[0] for k, v in out.items()},
                                 n_substeps=n_substeps,
                                 preemptive=preemptive)


def simulate(workload, service: ServiceModel, policy, *,
             slo_s: float = None, cold_start_s=30.0,
             max_queue: float = None, initial_replicas: int = None,
             min_replicas: int = 0, max_replicas: int = 1024,
             discipline="fifo", cold_start_seed: int = 0,
             seed_indices=None, backend: str = "numpy",
             n_substeps: int = 1, preemptive: bool = False) -> SimResult:
    """Homogeneous fleet: run ``policy`` against a ``Trace`` or ``Workload``
    on replicas of ``service``. A thin wrapper over ``simulate_fleet`` with
    one pool. ``cold_start_s`` accepts the same constant-or-(mean, jitter)
    spec as ``PoolConfig``; ``n_substeps``/``preemptive`` pick the simulator
    fidelity (see ``simulate_fleet``)."""
    # The policy may carry its own shape choice (predictive: recommend()).
    service = getattr(policy, "service", None) or service
    pool = PoolConfig(service=service, cold_start_s=cold_start_s,
                      min_replicas=min_replicas, max_replicas=max_replicas,
                      initial_replicas=initial_replicas)
    return simulate_fleet(workload, FleetConfig((pool,), max_queue=max_queue),
                          policy, slo_s=slo_s, discipline=discipline,
                          cold_start_seed=cold_start_seed,
                          seed_indices=seed_indices, backend=backend,
                          n_substeps=n_substeps, preemptive=preemptive)
