"""Discrete-time fleet queueing simulator, numpy-vectorized over Monte Carlo
seeds.

Each time bin: arrivals join a shared queue; every ready replica drains
back-to-back batches whose service time comes from the ``ServiceModel``
(roofline-derived); the autoscaling policy observes (arrival rate, queue,
utilization) and sets a replica target. Scale-downs are immediate, scale-ups
become ready only after a cold-start delay (container pull + weight load), which
is what separates reactive from predictive policies under bursts.

All per-bin state is an (n_seeds,) vector, so one pass simulates every Monte
Carlo draw of the trace at once — the fleet-level analogue of the paper's
nested-loop simulation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.fleet.traces import Trace
from repro.fleet.workload import ServiceModel

_EPS = 1e-12


@dataclass
class FleetObs:
    """What a policy sees at the end of a bin (all arrays are (n_seeds,))."""
    t_s: float                  # sim time at bin end
    dt_s: float
    arrival_rate: np.ndarray    # requests/s observed this bin
    queue: np.ndarray           # backlog after serving/drops
    replicas: np.ndarray        # ready replicas this bin
    in_flight: np.ndarray       # replicas still cold-starting
    utilization: np.ndarray     # served / capacity this bin, in [0, 1]
    service: ServiceModel       # the service model replicas run


@dataclass
class SimResult:
    trace: Trace
    service: ServiceModel
    policy_name: str
    slo_s: float
    cold_start_s: float
    # (n_seeds, n_bins) traces:
    arrivals: np.ndarray
    served: np.ndarray
    dropped: np.ndarray
    queue: np.ndarray
    replicas: np.ndarray        # ready (serving) replicas
    billed_replicas: np.ndarray  # ready + cold-starting (the cloud bill)
    latency_s: np.ndarray       # per-bin mean sojourn estimate of served reqs
    utilization: np.ndarray

    @property
    def dt_s(self) -> float:
        return self.trace.dt_s

    def replica_bins(self) -> float:
        """Mean (over seeds) total billed replica-bins — the billing integral.
        Cold-starting replicas cost money before they serve anything."""
        return float(self.billed_replicas.sum(axis=1).mean())


def simulate(trace: Trace, service: ServiceModel, policy, *,
             slo_s: float, cold_start_s: float = 30.0,
             max_queue: float = None, initial_replicas: int = None,
             min_replicas: int = 0, max_replicas: int = 1024) -> SimResult:
    """Run ``policy`` against ``trace`` on replicas of ``service``.

    ``max_queue`` bounds the backlog (admission control): overflow is dropped
    and counted as an SLO violation. ``None`` = unbounded queue.
    """
    # The policy may carry its own shape choice (predictive: recommend()).
    service = getattr(policy, "service", None) or service
    S, T = trace.arrivals.shape
    dt = trace.dt_s
    cold_bins = max(int(round(cold_start_s / dt)), 0)

    policy.reset(S)
    n0 = initial_replicas
    if n0 is None:
        # provision for the trace's initial rate (what a deployer would do)
        n0 = int(np.ceil(trace.rate[0] / max(service.max_throughput, _EPS)))
    n0 = int(np.clip(max(n0, 1), max(min_replicas, 1), max_replicas))

    queue = np.zeros(S)
    ready = np.full(S, n0, float)
    pending = np.zeros((S, T + cold_bins + 1))   # scale-ups maturing per bin

    rec = {k: np.zeros((S, T)) for k in
           ("served", "dropped", "queue", "replicas", "billed", "latency",
            "util")}

    for t in range(T):
        ready += pending[:, t]
        arr = trace.arrivals[:, t].astype(float)
        q_carry = queue.copy()          # standing backlog from earlier bins
        queue = queue + arr

        n = np.maximum(ready, 0.0)
        has = n > 0
        # per-replica batch: split the backlog, clipped to the batch window
        b = np.clip(np.ceil(np.divide(queue, n, out=np.zeros_like(queue),
                                      where=has)), 1.0, service.max_batch)
        rate = np.where(has, n * service.throughput(b), 0.0)   # requests/s
        capacity = rate * dt
        served = np.minimum(queue, capacity)
        queue = queue - served

        # mean sojourn of this bin's served work: batch service time plus the
        # delay of the standing backlog (Little's law, W = L / mu). Arrivals
        # within the bin are fluid — under capacity with no carryover they flow
        # straight through and only pay the batch time.
        wait = np.divide(0.5 * (q_carry + queue), rate,
                         out=np.full(S, np.inf), where=rate > 0)
        lat = np.where(served > 0, service.batch_time(b) + wait, 0.0)

        drop = np.zeros(S)
        if max_queue is not None:
            drop = np.maximum(queue - max_queue, 0.0)
            queue -= drop

        in_flight = pending[:, t + 1:].sum(axis=1)
        obs = FleetObs(
            t_s=(t + 1) * dt, dt_s=dt, arrival_rate=arr / dt, queue=queue,
            replicas=n, in_flight=in_flight,
            utilization=np.divide(served, capacity, out=np.zeros(S),
                                  where=capacity > 0),
            service=service)
        target = np.clip(np.asarray(policy.decide(t, obs), float),
                         min_replicas, max_replicas)

        # scale down now; scale up after the cold start
        total = ready + in_flight
        ready = np.where(target < ready, np.maximum(target, 0.0), ready)
        grow = np.maximum(target - total, 0.0)
        pending[:, min(t + 1 + cold_bins, T + cold_bins)] += grow

        rec["served"][:, t] = served
        rec["dropped"][:, t] = drop
        rec["queue"][:, t] = queue
        rec["replicas"][:, t] = n
        rec["billed"][:, t] = n + in_flight
        rec["latency"][:, t] = lat
        rec["util"][:, t] = obs.utilization

    return SimResult(
        trace=trace, service=service, policy_name=policy.name, slo_s=slo_s,
        cold_start_s=cold_start_s, arrivals=trace.arrivals.astype(float),
        served=rec["served"], dropped=rec["dropped"], queue=rec["queue"],
        replicas=rec["replicas"], billed_replicas=rec["billed"],
        latency_s=rec["latency"], utilization=rec["util"])
