"""``tune()`` — the outer autonomous loop over controller/fleet parameters.

The fleet simulator is already the paper's inner Monte Carlo loop (one
vectorized pass per config over every workload draw); ``tune`` wraps the
outer loop the paper runs over container configurations, with the
*controller's own knobs* as the design parameters:

    sample (LHS or grid from a seeded rng)
      -> race (paired successive halving + SPRT culling, ``racing.py``)
        -> refine (response surface over the surviving region, Pareto
           frontier, winner at full replicate budget)

The result is a ``TuningReport``: the framework now scopes itself — the same
sweep/race/fit methodology that picks a cloud shape picks ``horizon_s``,
``headroom``, cooldowns, quota mixes, or the scheduling discipline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.recommender import recommend
from repro.core.surfaces import _n_cols, fit_response_surface
from repro.fleet import telemetry
from repro.fleet.simulator import FleetConfig
from repro.fleet.tuning.evaluate import (Objective, TuningScenario,
                                         evaluate_candidates)
from repro.fleet.tuning.racing import exhaustive, race
from repro.fleet.tuning.result import TuningReport, pareto_frontier


@dataclass(frozen=True)
class TuningBudget:
    """How much simulation to spend and how to allocate it. The replicate
    budget itself is the scenario workload's seed axis; ``n_candidates``
    only applies to the LHS sampler (the grid's size is its levels)."""
    n_candidates: int = 24
    sampler: str = "lhs"            # "lhs" | "grid"
    grid_levels: int = 3
    init_seeds: int = 2
    eta: int = 2
    racing: bool = True
    alpha: float = 0.05
    beta: float = 0.05


def tuning_scenario(scenario, workload, policy_cls, *, shape_name: str = None,
                    fleet: FleetConfig = None, cold_start_s=60.0,
                    max_queue: float = None, discipline: str = "fifo",
                    cold_start_seed: int = 0, name: str = None,
                    backend: str = "auto") -> TuningScenario:
    """Build a ``TuningScenario`` from a fleet ``Scenario`` (scoping rows).

    Single-pool by default: the pool's shape is ``shape_name`` or the
    scoping stack's own pick (``recommend()`` under the scenario constraint),
    and the policy context's rows are restricted to that shape so predictive
    candidates size against the pool they actually run on. Pass ``fleet``
    for heterogeneous tuning (e.g. ``HeterogeneousPredictivePolicy`` with
    ``quota:*`` dims). ``backend`` picks the simulator implementation
    candidates are scored on ("numpy" reference loop, "jax" compiled
    batched, or the default "auto": compiled when the family has a kernel,
    numpy otherwise).
    """
    if fleet is None:
        if shape_name is None:
            rec = recommend(scenario.rows_at(), scenario.constraint())
            if rec.shape is None:
                raise ValueError("tuning_scenario: no feasible shape "
                                 f"({rec.reason})")
            shape_name = rec.shape.name
        fleet = FleetConfig((scenario.pool_for(shape_name,
                                               cold_start_s=cold_start_s),))
    pool_shapes = {p.service.shape.name for p in fleet.pools}
    rows = [r for r in scenario.rows if r.shape_name in pool_shapes]
    context = {"rows": rows, "constraint": scenario.constraint(),
               "units_per_step": scenario.units_per_step,
               "slo_s": scenario.slo_s}
    return TuningScenario(
        name=name or f"{scenario.name}/{getattr(workload, 'name', 'trace')}",
        workload=workload, fleet=fleet, policy_cls=policy_cls,
        context=context, discipline=discipline, max_queue=max_queue,
        cold_start_seed=cold_start_seed, backend=backend)


def _fit_surface(space, evals, min_rounds: int = 2):
    """Response surface over the surviving region: log-log polynomial of the
    mean objective score against the numeric dims, fitted on the candidates
    that survived at least one cull (the racer spent real replicates there,
    so their means are trustworthy); falls back to every evaluated candidate
    when the surviving set alone is too small.

    The fit's r2 is a trust signal (the bench gate reads it), so a pool must
    leave residual degrees of freedom: with exactly as many points as design
    columns lstsq interpolates anything with r2 == 1. Require 2 spare points
    beyond the quadratic's columns before fitting on a pool.
    """
    names = [n for n in space.numeric_names()]
    if not names:
        return None, ()
    n_needed = _n_cols(len(names), 2) + 2
    for pool in ([e for e in evals if e.n_rounds >= min_rounds], evals):
        if len(pool) < n_needed:
            continue
        X = np.array([[float(e.params[n]) for n in names] for e in pool])
        y = np.array([e.mean_score() for e in pool])
        try:
            return fit_response_surface(names, X, y, degree=2), tuple(names)
        except ValueError:
            continue
    return None, ()


def tune(scenario: TuningScenario, space, objective: Objective = None,
         budget: TuningBudget = None, *, seed: int = 0,
         baseline: dict = None) -> TuningReport:
    """Autonomously scope the controller: search ``space`` for the config of
    ``scenario.policy_cls`` minimizing ``objective`` over the scenario's
    Monte Carlo workload. Fully deterministic under (``seed``, budget,
    scenario): same inputs, same winner.

    ``baseline`` (optional) is a hand-set config evaluated at full replicate
    budget on the same paired draws — the tuned-vs-default comparison
    ``TuningReport.dominates_baseline()`` reads.
    """
    objective = objective or Objective()
    budget = budget or TuningBudget()
    with telemetry.span("tune", scenario=scenario.name,
                        backend=scenario.backend) as root:
        with telemetry.span("tune.sample", sampler=budget.sampler):
            if budget.sampler == "grid":
                candidates = space.grid(budget.grid_levels)
            elif budget.sampler == "lhs":
                candidates = space.sample_lhs(budget.n_candidates, seed=seed)
            else:
                raise ValueError(f"unknown sampler {budget.sampler!r}")

        with telemetry.span("tune.race", candidates=len(candidates),
                            racing=budget.racing):
            if budget.racing:
                rr = race(scenario, candidates, objective,
                          init_seeds=budget.init_seeds, eta=budget.eta,
                          alpha=budget.alpha, beta=budget.beta)
            else:
                rr = exhaustive(scenario, candidates, objective)

        with telemetry.span("tune.refine"):
            surface, names = _fit_surface(space, rr.evals)
            base_eval = None
            if baseline is not None:
                base_eval = evaluate_candidates(scenario, [baseline],
                                                objective)[0]

    return TuningReport(
        scenario_name=scenario.name,
        policy_family=getattr(scenario.policy_cls, "name",
                              scenario.policy_cls.__name__),
        objective=objective,
        winner=rr.winner,
        frontier=pareto_frontier(rr.evals),
        surface=surface, surface_names=names,
        sims_used=rr.sims_used, full_budget=rr.full_budget,
        baseline=base_eval, evals=rr.evals, space=space,
        _scenario=scenario, spans=root)
