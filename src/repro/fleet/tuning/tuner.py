"""``tune()`` — the outer autonomous loop over controller/fleet parameters.

The fleet simulator is already the paper's inner Monte Carlo loop (one
vectorized pass per config over every workload draw); ``tune`` wraps the
outer loop the paper runs over container configurations, with the
*controller's own knobs* as the design parameters:

    sample (LHS or grid from a seeded rng)
      -> race (paired successive halving + SPRT culling, ``racing.py``)
        -> refine (response surface over the surviving region, Pareto
           frontier, winner at full replicate budget)

The result is a ``TuningReport``: the framework now scopes itself — the same
sweep/race/fit methodology that picks a cloud shape picks ``horizon_s``,
``headroom``, cooldowns, quota mixes, or the scheduling discipline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.recommender import recommend
from repro.core.surfaces import _n_cols, fit_response_surface
from repro.fleet import telemetry
from repro.fleet.simulator import FleetConfig
from repro.fleet.tuning.evaluate import (Objective, TuningScenario,
                                         evaluate_candidates)
from repro.fleet.tuning.racing import exhaustive, race
from repro.fleet.tuning.result import TuningReport, pareto_frontier


@dataclass(frozen=True)
class TuningBudget:
    """How much simulation to spend and how to allocate it. The replicate
    budget itself is the scenario workload's seed axis; ``n_candidates``
    only applies to the LHS sampler (the grid's size is its levels)."""
    n_candidates: int = 24
    sampler: str = "lhs"            # "lhs" | "grid"
    grid_levels: int = 3
    init_seeds: int = 2
    eta: int = 2
    racing: bool = True
    alpha: float = 0.05
    beta: float = 0.05


def tuning_scenario(scenario, workload, policy_cls, *, shape_name: str = None,
                    fleet: FleetConfig = None, cold_start_s=60.0,
                    max_queue: float = None, discipline: str = "fifo",
                    cold_start_seed: int = 0, name: str = None,
                    backend: str = "auto", robust: str = "worst_case",
                    tile: int = 256) -> TuningScenario:
    """Build a ``TuningScenario`` from a fleet ``Scenario`` (scoping rows).

    Single-pool by default: the pool's shape is ``shape_name`` or the
    scoping stack's own pick (``recommend()`` under the scenario constraint),
    and the policy context's rows are restricted to that shape so predictive
    candidates size against the pool they actually run on. Pass ``fleet``
    for heterogeneous tuning (e.g. ``HeterogeneousPredictivePolicy`` with
    ``quota:*`` dims). ``backend`` picks the simulator implementation
    candidates are scored on ("numpy" reference loop, "jax" compiled
    batched, or the default "auto": compiled when the family has a kernel,
    numpy otherwise). ``workload`` may be a sequence of Workloads/Traces —
    a portfolio whose per-trace scores reduce via ``robust`` (see
    ``TuningScenario``); ``tile`` bounds the compiled backend's per-dispatch
    candidate width.
    """
    if fleet is None:
        if shape_name is None:
            rec = recommend(scenario.rows_at(), scenario.constraint())
            if rec.shape is None:
                raise ValueError("tuning_scenario: no feasible shape "
                                 f"({rec.reason})")
            shape_name = rec.shape.name
        fleet = FleetConfig((scenario.pool_for(shape_name,
                                               cold_start_s=cold_start_s),))
    pool_shapes = {p.service.shape.name for p in fleet.pools}
    rows = [r for r in scenario.rows if r.shape_name in pool_shapes]
    context = {"rows": rows, "constraint": scenario.constraint(),
               "units_per_step": scenario.units_per_step,
               "slo_s": scenario.slo_s}
    if name is None:
        if isinstance(workload, (list, tuple)):
            name = (f"{scenario.name}/portfolio"
                    f"[{','.join(getattr(w, 'name', 'trace') for w in workload)}]")
        else:
            name = f"{scenario.name}/{getattr(workload, 'name', 'trace')}"
    return TuningScenario(
        name=name,
        workload=workload, fleet=fleet, policy_cls=policy_cls,
        context=context, discipline=discipline, max_queue=max_queue,
        cold_start_seed=cold_start_seed, backend=backend, robust=robust,
        tile=tile)


def _fit_surface(space, evals, min_rounds: int = 2):
    """Response surface over the surviving region: log-log polynomial of the
    mean objective score against the numeric dims, fitted on the candidates
    that survived at least one cull (the racer spent real replicates there,
    so their means are trustworthy); falls back to every evaluated candidate
    when the surviving set alone is too small.

    The fit's r2 is a trust signal (the bench gate reads it), so a pool must
    leave residual degrees of freedom: with exactly as many points as design
    columns lstsq interpolates anything with r2 == 1. Require 2 spare points
    beyond the quadratic's columns before fitting on a pool.
    """
    names = [n for n in space.numeric_names()]
    if not names:
        return None, ()
    n_needed = _n_cols(len(names), 2) + 2
    for pool in ([e for e in evals if e.n_rounds >= min_rounds], evals):
        if len(pool) < n_needed:
            continue
        X = np.array([[float(e.params[n]) for n in names] for e in pool])
        y = np.array([e.mean_score() for e in pool])
        try:
            return fit_response_surface(names, X, y, degree=2), tuple(names)
        except ValueError:
            continue
    return None, ()


def warm_start_candidates(report: TuningReport, space, n: int, *,
                          seed: int = 0, jitter: float = 0.15) -> list:
    """Candidate configs seeded from an incumbent ``TuningReport``'s
    surviving region: the incumbent winner and the candidates that survived
    to the final racing round come in verbatim (anchors — a re-tune must
    never score worse than simply re-racing the incumbent), and the
    remaining slots are a small Latin-hypercube perturbation of the winner —
    per-dim stratified offsets of up to ``±jitter`` of each dim's unit range
    (``Dim.to_unit``/``from_unit``), so a drift re-tune explores the
    incumbent's neighbourhood instead of restarting blind. Dims of ``space``
    the incumbent never tuned (say the re-tune adds a knob) fall back to a
    fresh stratified draw."""
    if n < 1:
        raise ValueError("need n >= 1 candidates")
    rng = np.random.default_rng(seed)
    anchors, seen = [], set()
    max_rounds = max((e.n_rounds for e in report.evals), default=0)
    ranked = [report.winner] + sorted(
        (e for e in report.evals if e.n_rounds >= max_rounds),
        key=lambda e: e.mean_score())
    for e in ranked:
        if e is None:
            continue
        params = {d.name: e.params[d.name] for d in space.dims
                  if d.name in e.params}
        if len(params) != len(space.dims):
            continue        # the incumbent never tuned some dim: no anchor
        key = tuple(repr(params[k]) for k in space.names)
        if key in seen:
            continue
        seen.add(key)
        anchors.append(params)
        if len(anchors) >= n:
            break
    m = n - len(anchors)
    if m > 0:
        w = report.winner.params if report.winner is not None else {}
        configs = [dict() for _ in range(m)]
        for d in space.dims:
            strat = (rng.permutation(m) + rng.uniform(size=m)) / m
            if d.name in w:
                u0 = d.to_unit(w[d.name])
                u = np.clip(u0 + (strat - 0.5) * (2.0 * jitter), 0.0, 1.0)
            else:
                u = strat
            for i in range(m):
                configs[i][d.name] = d.from_unit(u[i])
        anchors.extend(configs)
    return anchors[:n]


def tune(scenario: TuningScenario, space, objective: Objective = None,
         budget: TuningBudget = None, *, seed: int = 0,
         baseline: dict = None,
         warm_start: TuningReport = None,
         warm_jitter: float = 0.15) -> TuningReport:
    """Autonomously scope the controller: search ``space`` for the config of
    ``scenario.policy_cls`` minimizing ``objective`` over the scenario's
    Monte Carlo workload. Fully deterministic under (``seed``, budget,
    scenario): same inputs, same winner.

    ``baseline`` (optional) is a hand-set config evaluated at full replicate
    budget on the same paired draws — the tuned-vs-default comparison
    ``TuningReport.dominates_baseline()`` reads.

    ``warm_start`` (optional) replaces the cold LHS design with
    ``warm_start_candidates``: the incumbent report's surviving region plus
    a ``±warm_jitter`` unit-space perturbation of its winner — the budgeted
    re-tune the closed-loop controller runs when the drift probe trips.
    """
    objective = objective or Objective()
    budget = budget or TuningBudget()
    with telemetry.span("tune", scenario=scenario.name,
                        backend=scenario.backend) as root:
        with telemetry.span("tune.sample", sampler=budget.sampler,
                            warm=warm_start is not None):
            if warm_start is not None:
                candidates = warm_start_candidates(
                    warm_start, space, budget.n_candidates, seed=seed,
                    jitter=warm_jitter)
            elif budget.sampler == "grid":
                candidates = space.grid(budget.grid_levels)
            elif budget.sampler == "lhs":
                candidates = space.sample_lhs(budget.n_candidates, seed=seed)
            else:
                raise ValueError(f"unknown sampler {budget.sampler!r}")

        with telemetry.span("tune.race", candidates=len(candidates),
                            racing=budget.racing):
            if budget.racing:
                rr = race(scenario, candidates, objective,
                          init_seeds=budget.init_seeds, eta=budget.eta,
                          alpha=budget.alpha, beta=budget.beta)
            else:
                rr = exhaustive(scenario, candidates, objective)

        with telemetry.span("tune.refine"):
            surface, names = _fit_surface(space, rr.evals)
            base_eval = None
            if baseline is not None:
                base_eval = evaluate_candidates(scenario, [baseline],
                                                objective)[0]

    return TuningReport(
        scenario_name=scenario.name,
        policy_family=getattr(scenario.policy_cls, "name",
                              scenario.policy_cls.__name__),
        objective=objective,
        winner=rr.winner,
        frontier=pareto_frontier(rr.evals),
        surface=surface, surface_names=names,
        sims_used=rr.sims_used, full_budget=rr.full_budget,
        baseline=base_eval, evals=rr.evals, space=space,
        robust=scenario.robust if scenario.n_traces > 1 else None,
        n_traces=scenario.n_traces,
        _scenario=scenario, spans=root)
