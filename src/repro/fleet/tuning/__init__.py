# Autonomous controller scoping: the paper's nested-loop Monte Carlo
# methodology applied to the fleet controller itself. The fleet simulator is
# the inner loop (vectorized over workload draws); `tune()` wraps the outer
# search over autoscaler/fleet parameters — declarative ParamSpaces
# (`space`), paired candidate evaluation (`evaluate`), successive-halving +
# SPRT racing (`racing`), and the response-surface/Pareto report (`result`).
from repro.fleet.tuning.evaluate import (CandidateEval, Objective,
                                         TuningScenario, evaluate_candidates,
                                         evaluate_candidates_column,
                                         per_seed_metrics, robust_m,
                                         robust_weights)
from repro.fleet.tuning.racing import (RaceResult, exhaustive, race,
                                       race_column)
from repro.fleet.tuning.result import (TuningReport, frontier_table,
                                       pareto_frontier)
from repro.fleet.tuning.space import (Categorical, Continuous, Dim, Integer,
                                      ParamSpace, discipline_dim, quota_dims)
from repro.fleet.tuning.tuner import (TuningBudget, tune, tuning_scenario,
                                      warm_start_candidates)

__all__ = [
    "CandidateEval", "Objective", "TuningScenario", "evaluate_candidates",
    "evaluate_candidates_column", "per_seed_metrics", "robust_m",
    "robust_weights", "RaceResult", "exhaustive", "race", "race_column",
    "TuningReport", "frontier_table", "pareto_frontier", "Categorical",
    "Continuous", "Dim", "Integer", "ParamSpace", "discipline_dim",
    "quota_dims", "TuningBudget", "tune", "tuning_scenario",
    "warm_start_candidates",
]
