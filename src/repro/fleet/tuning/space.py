"""Declarative controller-parameter spaces for the autonomous tuner.

A ``ParamSpace`` is an ordered tuple of named dimensions — continuous (linear
or log scale), integer, or categorical — with two seeded samplers:

* ``grid(levels)``   — full-factorial design (log-spaced where the dim says
  so), the exhaustive-sweep reference the racing loop is benchmarked against;
* ``sample_lhs(n)``  — Latin-hypercube design: every dim is stratified into n
  bins with one sample each, so n points cover every 1-D projection evenly —
  far better space-filling per simulation than iid sampling.

Policy families declare their own knob spaces (``Policy.param_space()``);
cross-cutting dims that belong to the *simulation* rather than the policy —
the scheduling discipline, per-pool quota mixes — live here and are routed by
the evaluator (``discipline`` to ``simulate_fleet``'s kwarg, ``quota:<pool>``
to the pool's ``max_replicas``). Spaces compose with ``+``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Dim:
    """One named search dimension. Subclasses map a uniform u in [0, 1) to a
    value (``from_unit``) and enumerate grid levels (``grid``)."""
    name: str

    def from_unit(self, u):
        raise NotImplementedError

    def to_unit(self, value) -> float:
        """Map a value back to [0, 1] — the inverse of ``from_unit`` (up to
        discretization), so warm-started sampling can perturb an incumbent
        configuration in unit space."""
        raise NotImplementedError

    def grid(self, levels: int) -> list:
        raise NotImplementedError

    @property
    def numeric(self) -> bool:
        """Whether the dim can enter a response-surface fit (log-space
        polynomials need strictly positive numeric coordinates)."""
        return False


@dataclass(frozen=True)
class Continuous(Dim):
    lo: float = 0.0
    hi: float = 1.0
    log: bool = False

    def __post_init__(self):
        if not (np.isfinite(self.lo) and np.isfinite(self.hi)
                and self.lo < self.hi):
            raise ValueError(f"dim {self.name!r}: need finite lo < hi, "
                             f"got [{self.lo}, {self.hi}]")
        if self.log and self.lo <= 0:
            raise ValueError(f"dim {self.name!r}: log scale needs lo > 0")

    def from_unit(self, u):
        if self.log:
            return float(self.lo * (self.hi / self.lo) ** u)
        return float(self.lo + u * (self.hi - self.lo))

    def to_unit(self, value) -> float:
        v = float(np.clip(value, self.lo, self.hi))
        if self.log:
            return float(np.log(v / self.lo) / np.log(self.hi / self.lo))
        return float((v - self.lo) / (self.hi - self.lo))

    def grid(self, levels: int) -> list:
        if self.log:
            return [float(v) for v in
                    np.geomspace(self.lo, self.hi, levels)]
        return [float(v) for v in np.linspace(self.lo, self.hi, levels)]

    @property
    def numeric(self) -> bool:
        return self.lo > 0      # log-space surface fits need positive coords


@dataclass(frozen=True)
class Integer(Dim):
    lo: int = 1
    hi: int = 16
    log: bool = False

    def __post_init__(self):
        if not self.lo < self.hi:
            raise ValueError(f"dim {self.name!r}: need lo < hi, "
                             f"got [{self.lo}, {self.hi}]")
        if self.log and self.lo <= 0:
            raise ValueError(f"dim {self.name!r}: log scale needs lo > 0")

    def from_unit(self, u):
        if self.log:
            v = self.lo * (self.hi / self.lo) ** u
        else:
            # map the unit interval onto equal-mass integer bins
            v = self.lo + u * (self.hi - self.lo + 1) - 0.5
        return int(np.clip(round(v), self.lo, self.hi))

    def to_unit(self, value) -> float:
        v = float(np.clip(value, self.lo, self.hi))
        if self.log:
            return float(np.log(v / self.lo) / np.log(self.hi / self.lo))
        return float((v - self.lo + 0.5) / (self.hi - self.lo + 1))

    def grid(self, levels: int) -> list:
        space = (np.geomspace if self.log else np.linspace)
        vals = np.clip(np.round(space(self.lo, self.hi, levels)),
                       self.lo, self.hi).astype(int)
        return sorted({int(v) for v in vals})

    @property
    def numeric(self) -> bool:
        return self.lo > 0


@dataclass(frozen=True)
class Categorical(Dim):
    choices: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "choices", tuple(self.choices))
        if not self.choices:
            raise ValueError(f"dim {self.name!r}: needs at least one choice")

    def from_unit(self, u):
        return self.choices[min(int(u * len(self.choices)),
                                len(self.choices) - 1)]

    def to_unit(self, value) -> float:
        # the center of the choice's own bin (unknown values: first choice)
        try:
            i = self.choices.index(value)
        except ValueError:
            i = 0
        return (i + 0.5) / len(self.choices)

    def grid(self, levels: int) -> list:
        return list(self.choices)


@dataclass(frozen=True)
class ParamSpace:
    """An ordered, immutable set of search dimensions."""
    dims: tuple

    def __post_init__(self):
        object.__setattr__(self, "dims", tuple(self.dims))
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dim names: {names}")

    @property
    def names(self) -> list:
        return [d.name for d in self.dims]

    def numeric_names(self) -> list:
        """Dims usable as response-surface coordinates."""
        return [d.name for d in self.dims if d.numeric]

    def __add__(self, other: "ParamSpace") -> "ParamSpace":
        return ParamSpace(self.dims + tuple(other.dims))

    def __len__(self) -> int:
        return len(self.dims)

    def sample_lhs(self, n: int, seed: int = 0) -> list:
        """n Latin-hypercube configs (dicts), deterministic under ``seed``."""
        if n < 1:
            raise ValueError("need n >= 1 samples")
        rng = np.random.default_rng(seed)
        configs = [dict() for _ in range(n)]
        for d in self.dims:
            # one stratified draw per bin, bins shuffled independently per dim
            u = (rng.permutation(n) + rng.uniform(size=n)) / n
            for i in range(n):
                configs[i][d.name] = d.from_unit(u[i])
        return configs

    def grid(self, levels: int = 4) -> list:
        """Full-factorial design: every combination of per-dim levels
        (integer dims dedupe collapsed levels; categoricals ignore
        ``levels``)."""
        configs = [dict()]
        for d in self.dims:
            configs = [dict(c, **{d.name: v})
                       for c in configs for v in d.grid(levels)]
        return configs

    def to_json(self) -> list:
        """JSON-serializable dim list (``OracleTable``/``TuningReport``
        artifacts carry their search space so a loaded table can interpolate
        winners in each dim's own unit coordinates)."""
        return [dim_to_json(d) for d in self.dims]

    @staticmethod
    def from_json(dims: list) -> "ParamSpace":
        return ParamSpace(tuple(dim_from_json(d) for d in dims))


def dim_to_json(dim: Dim) -> dict:
    """One dim as a plain JSON object (inverse: ``dim_from_json``)."""
    if isinstance(dim, Continuous):
        return {"kind": "continuous", "name": dim.name, "lo": dim.lo,
                "hi": dim.hi, "log": dim.log}
    if isinstance(dim, Integer):
        return {"kind": "integer", "name": dim.name, "lo": dim.lo,
                "hi": dim.hi, "log": dim.log}
    if isinstance(dim, Categorical):
        return {"kind": "categorical", "name": dim.name,
                "choices": list(dim.choices)}
    raise TypeError(f"cannot serialize dim type {type(dim).__name__}")


def dim_from_json(d: dict) -> Dim:
    kind = d.get("kind")
    if kind == "continuous":
        return Continuous(d["name"], float(d["lo"]), float(d["hi"]),
                          bool(d.get("log", False)))
    if kind == "integer":
        return Integer(d["name"], int(d["lo"]), int(d["hi"]),
                       bool(d.get("log", False)))
    if kind == "categorical":
        return Categorical(d["name"], tuple(d["choices"]))
    raise ValueError(f"unknown dim kind {kind!r}")


# ---- cross-cutting dims (simulation-level, routed by the evaluator) --------

def discipline_dim(choices=("fifo", "priority", "edf")) -> Categorical:
    """Scheduling discipline as a tunable categorical — the tuner can search
    it jointly with the policy knobs."""
    return Categorical("discipline", tuple(choices))


def quota_dims(fleet, lo: int = 1, hi: int = None) -> ParamSpace:
    """Per-pool quota mix: one ``quota:<pool-label>`` integer dim per pool of
    ``fleet``, never exceeding the pool's own ``max_replicas`` (that is the
    cloud's quota — a tuned config above it would be undeployable); ``hi``
    may tighten it further. Pools whose quota leaves no room to search
    (``max_replicas <= lo``) get no dim and keep their configured bound."""
    dims = []
    for p in fleet.pools:
        top = int(min(p.max_replicas, p.max_replicas if hi is None else hi))
        if top <= lo:
            continue
        dims.append(Integer(f"quota:{p.label}", lo, top,
                            log=lo > 0 and top - lo > 8))
    return ParamSpace(tuple(dims))
