"""Vectorized candidate evaluation: configs x seeded trace replicates.

The scenario pre-samples ONE Monte Carlo workload tensor (n_seeds trace
replicates) and every candidate config is simulated against slices of that
same tensor. Candidates are therefore *paired* on identical arrival draws:
the difference between two candidates' per-seed scores is free of the
arrival-sampling variance a naive sweep (fresh traces per candidate) pays —
the classic common-random-numbers variance reduction, and what lets the
racing loop compare candidates on very few replicates.

Per candidate the evaluator returns per-seed dollar cost, worst-class SLO
attainment and drop rate (the simulator is already seed-vectorized, so one
``simulate_fleet`` call covers a whole seed slice), the pooled per-request
p99, and across-seed confidence intervals.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import numpy as np

from repro.core.cost_model import dollar_cost
from repro.fleet.report import weighted_percentile
from repro.fleet.simulator import FleetConfig, SimResult, simulate_fleet
from repro.fleet.traces import Trace
from repro.fleet.workload import Workload

_Z95 = 1.959963984540054


@dataclass(frozen=True)
class Objective:
    """Scalarization of (cost, SLO attainment): dollars per hour plus a steep
    penalty per unit of worst-class attainment shortfall below the bar. The
    penalty converts "meet the SLO" into a soft constraint the tuner can
    race on — a config missing the bar by 1% pays ``penalty_usd_per_hour/100``
    extra $/hr, dwarfing any honest capacity saving."""
    min_attainment: float = 0.99
    penalty_usd_per_hour: float = 2000.0

    def score(self, cost_usd_hr, attainment):
        """Per-seed scalar score (lower is better); inputs broadcast."""
        shortfall = np.maximum(self.min_attainment - np.asarray(attainment),
                               0.0)
        return np.asarray(cost_usd_hr) + self.penalty_usd_per_hour * shortfall


@dataclass
class CandidateEval:
    """One candidate's evidence so far (arrays grow as racing adds seeds)."""
    params: dict
    cost_usd_hr: np.ndarray          # (n_seeds_seen,)
    attainment: np.ndarray           # (n_seeds_seen,) worst-class
    drop_rate: np.ndarray            # (n_seeds_seen,)
    score: np.ndarray                # (n_seeds_seen,) objective scalarization
    sojourns: list = field(repr=False, default_factory=list)  # (vals, wts)
    n_rounds: int = 0                # racing rounds survived

    @property
    def n_seeds(self) -> int:
        return len(self.score)

    def mean_cost(self) -> float:
        return float(self.cost_usd_hr.mean())

    def mean_attainment(self) -> float:
        return float(self.attainment.mean())

    def mean_drop_rate(self) -> float:
        return float(self.drop_rate.mean())

    def mean_score(self) -> float:
        return float(self.score.mean())

    def ci(self, arr: np.ndarray) -> float:
        """95% half-width of the mean (0 with a single replicate)."""
        if len(arr) < 2:
            return 0.0
        return float(_Z95 * arr.std(ddof=1) / np.sqrt(len(arr)))

    def cost_ci(self) -> float:
        return self.ci(self.cost_usd_hr)

    def attainment_ci(self) -> float:
        return self.ci(self.attainment)

    def score_ci(self) -> float:
        return self.ci(self.score)

    def p99_s(self) -> float:
        """Pooled exact per-request p99 over every seed seen."""
        if not self.sojourns:
            return float("nan")
        vals = np.concatenate([v for v, _ in self.sojourns])
        wts = np.concatenate([w for _, w in self.sojourns])
        return weighted_percentile(vals, wts, 99)

    def extend(self, other: "CandidateEval") -> None:
        """Append another seed slice's evidence (paired racing rounds)."""
        self.cost_usd_hr = np.concatenate([self.cost_usd_hr,
                                           other.cost_usd_hr])
        self.attainment = np.concatenate([self.attainment, other.attainment])
        self.drop_rate = np.concatenate([self.drop_rate, other.drop_rate])
        self.score = np.concatenate([self.score, other.score])
        self.sojourns.extend(other.sojourns)


def _slice_trace(tr: Trace, s0: int, s1: int) -> Trace:
    return Trace(tr.name, tr.dt_s, tr.rate, tr.arrivals[s0:s1])


def _slice_workload(wl: Workload, s0: int, s1: int) -> Workload:
    return Workload(wl.name, wl.classes,
                    tuple(_slice_trace(tr, s0, s1) for tr in wl.traces))


@dataclass
class TuningScenario:
    """Everything ``tune()`` needs to score a candidate config:

    * ``workload``  — the shared Monte Carlo trace tensor (a ``Workload``, or
      a bare ``Trace`` + ``slo_s``); its seed axis is the replicate budget.
    * ``fleet``     — the fleet template (``quota:<pool>`` dims override each
      pool's ``max_replicas`` per candidate).
    * ``policy_cls`` + ``context`` — the policy family under tuning;
      candidates are built with ``policy_cls.from_params(params, **context)``.
    * ``discipline``/``max_queue``/``cold_start_seed`` — simulation fixtures
      (a ``discipline`` dim in the space overrides the fixture).
    """
    name: str
    workload: Workload
    fleet: FleetConfig
    policy_cls: type
    context: dict = field(default_factory=dict)
    discipline: str = "fifo"
    max_queue: Optional[float] = None
    cold_start_seed: int = 0
    build_policy: Callable = None    # override: params -> Policy

    def __post_init__(self):
        if isinstance(self.workload, Trace):
            slo = self.context.get("slo_s")
            if slo is None:
                raise ValueError("a bare Trace workload needs context"
                                 "['slo_s'] for its request class")
            self.workload = Workload.from_trace(self.workload, float(slo))

    @property
    def n_seeds(self) -> int:
        return self.workload.n_seeds

    def split_params(self, params: dict):
        """(policy_params, discipline, fleet) for one candidate — the
        cross-cutting ``discipline``/``quota:*`` dims are simulation-level,
        everything else belongs to the policy constructor."""
        policy_params = {k: v for k, v in params.items()
                         if k != "discipline" and not k.startswith("quota:")}
        discipline = params.get("discipline", self.discipline)
        fleet = self.fleet
        quotas = {k[len("quota:"):]: int(v) for k, v in params.items()
                  if k.startswith("quota:")}
        if quotas:
            pools = tuple(
                replace(p, max_replicas=quotas[p.label],
                        min_replicas=min(p.min_replicas, quotas[p.label]))
                if p.label in quotas else p for p in fleet.pools)
            fleet = FleetConfig(pools, max_queue=fleet.max_queue)
        return policy_params, discipline, fleet

    def make_policy(self, params: dict):
        policy_params, _, fleet = self.split_params(params)
        if self.build_policy is not None:
            return self.build_policy(policy_params)
        ctx = dict(self.context)
        ctx.pop("slo_s", None)
        if "fleet" in ctx or getattr(self.policy_cls, "per_pool", False):
            ctx["fleet"] = fleet
        return self.policy_cls.from_params(policy_params, **ctx)

    def simulate(self, params: dict, s0: int, s1: int) -> SimResult:
        """Run one candidate against the shared seed slice [s0, s1).
        ``seed_indices`` pins each row's cold-start jitter substream to its
        absolute replicate id, so racing's incremental slices see exactly
        the draws a single full-budget evaluation would."""
        _, discipline, fleet = self.split_params(params)
        return simulate_fleet(
            _slice_workload(self.workload, s0, s1), fleet,
            self.make_policy(params), discipline=discipline,
            max_queue=self.max_queue, cold_start_seed=self.cold_start_seed,
            seed_indices=np.arange(s0, s1))


def per_seed_metrics(sim: SimResult):
    """(cost $/hr, worst-class attainment, drop rate), each (n_seeds,), from
    one seed-vectorized simulation — the per-seed analogues of
    ``report.summarize``'s scalars (same conventions: drops count against
    attainment, the unresolved terminal backlog counts for neither side)."""
    S = sim.arrivals.shape[0]
    usd = np.zeros(S)
    for p, pc in enumerate(sim.fleet.pools):
        bins = sim.pool_billed[:, :, p].sum(axis=1)
        usd += dollar_cost(sim.dt_s, bins, pc.service.shape.chips,
                           pc.service.shape.hw)
    cost_hr = usd / max(sim.trace.duration_s / 3600.0, 1e-12)

    arrived_c = (sim.class_admitted + sim.class_dropped).sum(axis=1)
    completed_c = arrived_c - sim.class_queue[:, -1, :]
    ok_c = sim.class_ok.sum(axis=1)
    att_c = np.divide(ok_c, completed_c, out=np.ones_like(ok_c),
                      where=completed_c > 0)
    worst_att = att_c.min(axis=1)

    arrived = sim.arrivals.sum(axis=1)
    drop = sim.dropped.sum(axis=1) / np.maximum(arrived, 1.0)
    return cost_hr, worst_att, drop


def evaluate_candidates(scenario: TuningScenario, candidates: list,
                        objective: Objective, s0: int = 0,
                        s1: int = None) -> list:
    """Score every candidate on the shared seed slice [s0, s1). One
    ``simulate_fleet`` call per candidate covers the whole slice (the
    simulator is seed-vectorized); identical slices across candidates give
    the paired comparison racing relies on."""
    s1 = scenario.n_seeds if s1 is None else s1
    if not 0 <= s0 < s1 <= scenario.n_seeds:
        raise ValueError(f"bad seed slice [{s0}, {s1}) for "
                         f"{scenario.n_seeds} replicates")
    out = []
    for params in candidates:
        sim = scenario.simulate(params, s0, s1)
        cost_hr, att, drop = per_seed_metrics(sim)
        out.append(CandidateEval(
            params=dict(params), cost_usd_hr=cost_hr, attainment=att,
            drop_rate=drop, score=np.asarray(objective.score(cost_hr, att)),
            sojourns=[(sim.sojourn_values, sim.sojourn_weights)]))
    return out
