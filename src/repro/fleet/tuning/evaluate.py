"""Vectorized candidate evaluation: configs x seeded trace replicates.

The scenario pre-samples ONE Monte Carlo workload tensor (n_seeds trace
replicates) and every candidate config is simulated against slices of that
same tensor. Candidates are therefore *paired* on identical arrival draws:
the difference between two candidates' per-seed scores is free of the
arrival-sampling variance a naive sweep (fresh traces per candidate) pays —
the classic common-random-numbers variance reduction, and what lets the
racing loop compare candidates on very few replicates.

Per candidate the evaluator returns per-seed dollar cost, worst-class SLO
attainment and drop rate (the simulator is already seed-vectorized, so one
``simulate_fleet`` call covers a whole seed slice), the pooled per-request
p99, and across-seed confidence intervals.

A scenario may also carry a *portfolio* of traces (a sequence of Workloads
sharing dt/bins/seeds/classes). The compiled backend folds the portfolio
into the same single dispatch — members stack along the seed axis, so a
racing round is still ONE jitted candidate x (seed x trace) lattice — and
per-trace scores reduce to a robust per-seed score via a pluggable
objective (``worst_case`` / ``cvar(alpha)`` / ``mean``) that racing and
SPRT culling consume directly. A winner under ``worst_case`` is the config
whose *worst* trace is cheapest-feasible: robust, not scenario-overfit.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import numpy as np

from repro.core.cost_model import dollar_cost
from repro.fleet import telemetry
from repro.fleet.report import weighted_percentile
from repro.fleet.simulator import (FleetConfig, SimResult,
                                   draw_cold_start_delays, simulate_fleet)
from repro.fleet.traces import Trace
from repro.fleet.workload import Workload

_Z95 = 1.959963984540054


@dataclass(frozen=True)
class Objective:
    """Scalarization of (cost, SLO attainment): dollars per hour plus a steep
    penalty per unit of worst-class attainment shortfall below the bar. The
    penalty converts "meet the SLO" into a soft constraint the tuner can
    race on — a config missing the bar by 1% pays ``penalty_usd_per_hour/100``
    extra $/hr, dwarfing any honest capacity saving."""
    min_attainment: float = 0.99
    penalty_usd_per_hour: float = 2000.0

    def score(self, cost_usd_hr, attainment):
        """Per-seed scalar score (lower is better); inputs broadcast."""
        shortfall = np.maximum(self.min_attainment - np.asarray(attainment),
                               0.0)
        return np.asarray(cost_usd_hr) + self.penalty_usd_per_hour * shortfall

    def to_json(self) -> dict:
        return {"min_attainment": self.min_attainment,
                "penalty_usd_per_hour": self.penalty_usd_per_hour}

    @staticmethod
    def from_json(d: dict) -> "Objective":
        return Objective(min_attainment=float(d["min_attainment"]),
                         penalty_usd_per_hour=float(d["penalty_usd_per_hour"]))


_CVAR_RE = re.compile(r"cvar\(\s*([0-9.eE+-]+)\s*\)")


def robust_m(spec: str, n_traces: int) -> int:
    """How many worst traces the robust objective averages over: 1 for
    ``worst_case``, all for ``mean``, ``ceil(alpha * K)`` (clipped to
    [1, K]) for ``cvar(alpha)`` — the discrete CVaR over K equally likely
    trace outcomes. Raises on an unknown spec (validated at scenario
    construction, so a typo fails before any simulation is spent)."""
    K = int(n_traces)
    s = str(spec).strip().lower()
    if s == "worst_case":
        return 1
    if s == "mean":
        return K
    m = _CVAR_RE.fullmatch(s)
    if m:
        alpha = float(m.group(1))
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"cvar alpha must be in (0, 1], got {alpha}")
        return int(np.clip(int(np.ceil(alpha * K)), 1, K))
    raise ValueError(f"unknown robust objective {spec!r}; expected "
                     "'worst_case', 'mean' or 'cvar(alpha)'")


def robust_weights(scores: np.ndarray, spec: str) -> np.ndarray:
    """Per-seed trace-mix weights for the robust reduction: for each seed
    column of the (K, S) per-trace score matrix, uniform mass ``1/m`` on
    the ``m = robust_m(spec, K)`` worst (highest-score) traces and 0
    elsewhere. ``worst_case`` (m=1) reduces to the exact worst-trace row,
    ``mean`` (m=K) to the plain trace average, ``cvar(alpha)`` to the
    discrete expected-shortfall in between. Ties break by trace order
    (stable sort), so the reduced *score* is deterministic and invariant
    under trace permutation."""
    scores = np.asarray(scores, float)
    K, S = scores.shape
    m = robust_m(spec, K)
    w = np.zeros((K, S))
    worst = np.argsort(-scores, axis=0, kind="stable")[:m]
    np.put_along_axis(w, worst, 1.0 / m, axis=0)
    return w


@dataclass
class CandidateEval:
    """One candidate's evidence so far (arrays grow as racing adds seeds)."""
    params: dict
    cost_usd_hr: np.ndarray          # (n_seeds_seen,)
    attainment: np.ndarray           # (n_seeds_seen,) worst-class
    drop_rate: np.ndarray            # (n_seeds_seen,)
    score: np.ndarray                # (n_seeds_seen,) objective scalarization
    sojourns: list = field(repr=False, default_factory=list)  # (vals, wts)
    n_rounds: int = 0                # racing rounds survived
    # portfolio evidence: the raw per-trace CandidateEvals the robust
    # reduction folded (None for single-trace scenarios)
    per_trace: Optional[list] = field(repr=False, default=None)

    @property
    def n_seeds(self) -> int:
        return len(self.score)

    def mean_cost(self) -> float:
        return float(self.cost_usd_hr.mean())

    def mean_attainment(self) -> float:
        return float(self.attainment.mean())

    def mean_drop_rate(self) -> float:
        return float(self.drop_rate.mean())

    def mean_score(self) -> float:
        return float(self.score.mean())

    def ci(self, arr: np.ndarray) -> float:
        """95% half-width of the mean (0 with a single replicate)."""
        if len(arr) < 2:
            return 0.0
        return float(_Z95 * arr.std(ddof=1) / np.sqrt(len(arr)))

    def cost_ci(self) -> float:
        return self.ci(self.cost_usd_hr)

    def attainment_ci(self) -> float:
        return self.ci(self.attainment)

    def score_ci(self) -> float:
        return self.ci(self.score)

    def p99_s(self) -> float:
        """Pooled exact per-request p99 over every seed seen."""
        if not self.sojourns:
            return float("nan")
        vals = np.concatenate([v for v, _ in self.sojourns])
        wts = np.concatenate([w for _, w in self.sojourns])
        return weighted_percentile(vals, wts, 99)

    def worst_trace_score(self) -> float:
        """Mean score on this candidate's worst portfolio trace (its own
        mean score for single-trace evidence) — the robustness yardstick:
        a scenario-overfit winner looks great on its tuning trace and falls
        over here."""
        if not self.per_trace:
            return self.mean_score()
        return max(ev.mean_score() for ev in self.per_trace)

    def worst_trace_attainment(self) -> float:
        """Mean worst-class attainment on the worst portfolio trace."""
        if not self.per_trace:
            return self.mean_attainment()
        return min(ev.mean_attainment() for ev in self.per_trace)

    def extend(self, other: "CandidateEval") -> None:
        """Append another seed slice's evidence (paired racing rounds)."""
        self.cost_usd_hr = np.concatenate([self.cost_usd_hr,
                                           other.cost_usd_hr])
        self.attainment = np.concatenate([self.attainment, other.attainment])
        self.drop_rate = np.concatenate([self.drop_rate, other.drop_rate])
        self.score = np.concatenate([self.score, other.score])
        self.sojourns.extend(other.sojourns)
        if self.per_trace and other.per_trace:
            for mine, new in zip(self.per_trace, other.per_trace):
                mine.extend(new)

    def to_json(self, include_sojourns: bool = False) -> dict:
        """Plain-JSON form of this candidate's evidence. Per-request sojourn
        samples are dropped by default (they dominate the payload and only
        feed ``p99_s``); pass ``include_sojourns=True`` to keep them."""
        out = {"params": dict(self.params),
               "cost_usd_hr": [float(v) for v in self.cost_usd_hr],
               "attainment": [float(v) for v in self.attainment],
               "drop_rate": [float(v) for v in self.drop_rate],
               "score": [float(v) for v in self.score],
               "n_rounds": int(self.n_rounds)}
        if include_sojourns:
            out["sojourns"] = [([float(x) for x in v], [float(x) for x in w])
                               for v, w in self.sojourns]
        if self.per_trace:
            out["per_trace"] = [ev.to_json(include_sojourns=include_sojourns)
                                for ev in self.per_trace]
        return out

    @staticmethod
    def from_json(d: dict) -> "CandidateEval":
        sojourns = [(np.asarray(v, float), np.asarray(w, float))
                    for v, w in d.get("sojourns", [])]
        per_trace = [CandidateEval.from_json(e)
                     for e in d.get("per_trace", [])] or None
        return CandidateEval(
            params=dict(d["params"]),
            cost_usd_hr=np.asarray(d["cost_usd_hr"], float),
            attainment=np.asarray(d["attainment"], float),
            drop_rate=np.asarray(d["drop_rate"], float),
            score=np.asarray(d["score"], float),
            sojourns=sojourns, n_rounds=int(d.get("n_rounds", 0)),
            per_trace=per_trace)


def _slice_trace(tr: Trace, s0: int, s1: int) -> Trace:
    return Trace(tr.name, tr.dt_s, tr.rate, tr.arrivals[s0:s1])


def _slice_workload(wl: Workload, s0: int, s1: int) -> Workload:
    return Workload(wl.name, wl.classes,
                    tuple(_slice_trace(tr, s0, s1) for tr in wl.traces))


@dataclass
class TuningScenario:
    """Everything ``tune()`` needs to score a candidate config:

    * ``workload``  — the shared Monte Carlo trace tensor (a ``Workload``, or
      a bare ``Trace`` + ``slo_s``); its seed axis is the replicate budget.
      A *sequence* of Workloads/Traces declares a portfolio: every candidate
      is scored on every member (flash-crowd + diurnal + replay + ...) and
      per-trace scores reduce via ``robust`` before racing sees them. Members
      must share dt/bins/seeds and request classes; member 0 is *primary* —
      it pins the initial provisioning every member starts from (one fleet,
      several demand futures).
    * ``fleet``     — the fleet template (``quota:<pool>`` dims override each
      pool's ``max_replicas`` per candidate).
    * ``policy_cls`` + ``context`` — the policy family under tuning;
      candidates are built with ``policy_cls.from_params(params, **context)``.
    * ``discipline``/``max_queue``/``cold_start_seed`` — simulation fixtures
      (a ``discipline`` dim in the space overrides the fixture).
    * ``backend`` — the simulator implementation candidates are scored on:
      ``"numpy"`` (reference), ``"jax"`` (compiled; a whole racing round is
      one jitted candidate x seed x trace batch), or ``"auto"`` (the default:
      compiled when the policy family has a kernel, numpy otherwise — every
      built-in family has one, and both paths agree to float rounding).
    * ``n_substeps``/``preemptive`` — simulator fidelity knobs forwarded to
      every ``simulate_fleet`` call (see the simulator docstring); the
      defaults keep the coarse bin-granular core.
    * ``robust`` — the per-seed trace reduction: ``"worst_case"`` (default),
      ``"mean"``, or ``"cvar(alpha)"`` (see ``robust_weights``). Ignored for
      single-trace scenarios, where scoring is unchanged.
    * ``tile``   — candidate tile width for the compiled backend: slates
      wider than the (pow2-rounded) tile stream through fixed-shape chunks
      sharing one compiled program, so thousands of LHS candidates cost one
      cold dispatch plus warm repeats (``None`` disables tiling).
    """
    name: str
    workload: Workload
    fleet: FleetConfig
    policy_cls: type
    context: dict = field(default_factory=dict)
    discipline: str = "fifo"
    max_queue: Optional[float] = None
    cold_start_seed: int = 0
    build_policy: Callable = None    # override: params -> Policy
    backend: str = "auto"
    n_substeps: int = 1
    preemptive: bool = False
    robust: str = "worst_case"
    tile: Optional[int] = 256

    def __post_init__(self):
        members = self.workload
        if isinstance(members, (Workload, Trace)):
            members = (members,)
        norm = []
        for m in members:
            if isinstance(m, Trace):
                slo = self.context.get("slo_s")
                if slo is None:
                    raise ValueError("a bare Trace workload needs context"
                                     "['slo_s'] for its request class")
                m = Workload.from_trace(m, float(slo))
            norm.append(m)
        if not norm:
            raise ValueError("empty trace portfolio")
        first = norm[0]
        for m in norm[1:]:
            if (m.dt_s != first.dt_s or m.n_bins != first.n_bins
                    or m.n_seeds != first.n_seeds):
                raise ValueError(
                    f"portfolio member {m.name!r} has (dt={m.dt_s}, "
                    f"bins={m.n_bins}, seeds={m.n_seeds}); members must "
                    f"match the primary's (dt={first.dt_s}, "
                    f"bins={first.n_bins}, seeds={first.n_seeds})")
            if m.classes != first.classes:
                raise ValueError(
                    f"portfolio member {m.name!r} declares different "
                    "request classes than the primary; the candidate's "
                    "policy/tables are shared across members")
        self.portfolio = tuple(norm)
        self.workload = first
        robust_m(self.robust, len(norm))   # fail on a typo before any sims
        self._cs_delay = False       # lazy cold-start jitter tensor cache
        self._tables = {}            # per-discipline cohort_tables cache
        self._batch_windows = None   # sticky kernel ring-buffer sizes

    @property
    def n_seeds(self) -> int:
        return self.workload.n_seeds

    @property
    def n_traces(self) -> int:
        return len(self.portfolio)

    def cold_start_delays(self):
        """The (n_traces * n_seeds, n_bins, n_pools) spin-up jitter tensor,
        drawn ONCE per scenario and sliced per racing round — every candidate
        sees identical draws anyway (they are keyed by absolute row identity
        ``member * n_seeds + seed``), so re-drawing them per
        ``simulate_fleet`` call was pure per-candidate RNG overhead. ``None``
        when no pool jitters."""
        if self._cs_delay is False:
            rows = self.n_traces * self.n_seeds
            self._cs_delay = draw_cold_start_delays(
                self.fleet.pools, rows, self.workload.n_bins,
                self.workload.dt_s, self.cold_start_seed, np.arange(rows))
        return self._cs_delay

    def _cs_rows(self, s0: int, s1: int, member: int = 0):
        cs = self.cold_start_delays()
        if cs is None:
            return None
        base = member * self.n_seeds
        return cs[base + s0:base + s1]

    def cohort_tables_for(self, discipline):
        """Cached static serve-order tables for the compiled backend."""
        from repro.fleet.discipline import cohort_tables
        key = discipline if isinstance(discipline, str) else id(discipline)
        tabs = self._tables.get(key)
        if tabs is None:
            tabs = cohort_tables(discipline, self.workload.classes,
                                 self.workload.n_bins, self.workload.dt_s)
            self._tables[key] = tabs
        return tabs

    def split_params(self, params: dict):
        """(policy_params, discipline, fleet) for one candidate — the
        cross-cutting ``discipline``/``quota:*`` dims are simulation-level,
        everything else belongs to the policy constructor."""
        policy_params = {k: v for k, v in params.items()
                         if k != "discipline" and not k.startswith("quota:")}
        discipline = params.get("discipline", self.discipline)
        fleet = self.fleet
        quotas = {k[len("quota:"):]: int(v) for k, v in params.items()
                  if k.startswith("quota:")}
        if quotas:
            pools = tuple(
                replace(p, max_replicas=quotas[p.label],
                        min_replicas=min(p.min_replicas, quotas[p.label]))
                if p.label in quotas else p for p in fleet.pools)
            fleet = FleetConfig(pools, max_queue=fleet.max_queue)
        return policy_params, discipline, fleet

    def make_policy(self, params: dict):
        policy_params, _, fleet = self.split_params(params)
        if self.build_policy is not None:
            return self.build_policy(policy_params)
        ctx = dict(self.context)
        ctx.pop("slo_s", None)
        if "fleet" in ctx or getattr(self.policy_cls, "per_pool", False):
            ctx["fleet"] = fleet
        return self.policy_cls.from_params(policy_params, **ctx)

    def _member_fleet(self, fleet: FleetConfig, member: int) -> FleetConfig:
        """Portfolio members share the PRIMARY member's initial provisioning:
        the portfolio races one starting fleet against several demand
        futures, so member ``k > 0`` gets explicit ``initial_replicas``
        pinned from member 0's opening rate — exactly what the batched
        dispatch does, whose ``init_ready`` is per-candidate, not per-row."""
        if member == 0:
            return fleet
        from repro.fleet.simulator import _initial_replicas
        rate0 = float(self.workload.total_trace().rate[0])
        first = fleet.drain_order()[0]
        pools = tuple(
            replace(pc, initial_replicas=_initial_replicas(
                pc, rate0, p == first))
            for p, pc in enumerate(fleet.pools))
        return FleetConfig(pools, max_queue=fleet.max_queue)

    def simulate(self, params: dict, s0: int, s1: int,
                 backend: str = None, member: int = 0) -> SimResult:
        """Run one candidate against the shared seed slice [s0, s1) of
        portfolio member ``member``. ``seed_indices`` pins each row's
        cold-start jitter substream to its absolute replicate id
        ``member * n_seeds + seed``, so racing's incremental slices see
        exactly the draws a single full-budget evaluation would (the
        scenario hands the pre-drawn tensor rows straight to the
        simulator)."""
        _, discipline, fleet = self.split_params(params)
        base = member * self.n_seeds
        return simulate_fleet(
            _slice_workload(self.portfolio[member], s0, s1),
            self._member_fleet(fleet, member),
            self.make_policy(params), discipline=discipline,
            max_queue=self.max_queue, cold_start_seed=self.cold_start_seed,
            seed_indices=np.arange(base + s0, base + s1),
            cold_start_delays=self._cs_rows(s0, s1, member),
            backend=self.backend if backend is None else backend,
            n_substeps=self.n_substeps, preemptive=self.preemptive)


def per_seed_metrics(sim: SimResult):
    """(cost $/hr, worst-class attainment, drop rate), each (n_seeds,), from
    one seed-vectorized simulation — the per-seed analogues of
    ``report.summarize``'s scalars (same conventions: drops count against
    attainment, the unresolved terminal backlog counts for neither side)."""
    S = sim.arrivals.shape[0]
    usd = np.zeros(S)
    for p, pc in enumerate(sim.fleet.pools):
        bins = sim.pool_billed[:, :, p].sum(axis=1)
        usd += dollar_cost(sim.dt_s, bins, pc.service.shape.chips,
                           pc.service.shape.hw)
    cost_hr = usd / max(sim.trace.duration_s / 3600.0, 1e-12)

    arrived_c = (sim.class_admitted + sim.class_dropped).sum(axis=1)
    completed_c = arrived_c - sim.class_queue[:, -1, :]
    ok_c = sim.class_ok.sum(axis=1)
    att_c = np.divide(ok_c, completed_c, out=np.ones_like(ok_c),
                      where=completed_c > 0)
    worst_att = att_c.min(axis=1)

    arrived = sim.arrivals.sum(axis=1)
    drop = sim.dropped.sum(axis=1) / np.maximum(arrived, 1.0)
    return cost_hr, worst_att, drop


def _eval_from_sim(params: dict, sim: SimResult,
                   objective: Objective) -> CandidateEval:
    cost_hr, att, drop = per_seed_metrics(sim)
    return CandidateEval(
        params=dict(params), cost_usd_hr=cost_hr, attainment=att,
        drop_rate=drop, score=np.asarray(objective.score(cost_hr, att)),
        sojourns=[(sim.sojourn_values, sim.sojourn_weights)])


def _reduce_portfolio(per_trace: list, robust: str) -> CandidateEval:
    """Fold K per-trace evals into one robust eval. Per-seed weights come
    from ``robust_weights`` over the (K, S) score matrix; the reduced score
    is the weighted trace mix (for ``worst_case``, exactly the worst
    trace's per-seed score), cost/attainment/drop use the SAME weights (the
    reported cost is the cost *on the traces that set the score*), sojourns
    pool across traces, and the raw per-trace evidence rides along in
    ``per_trace`` for overfit diagnostics."""
    scores = np.stack([ev.score for ev in per_trace])      # (K, S)
    w = robust_weights(scores, robust)

    def mix(key):
        return (w * np.stack([getattr(ev, key)
                              for ev in per_trace])).sum(axis=0)

    return CandidateEval(
        params=dict(per_trace[0].params),
        cost_usd_hr=mix("cost_usd_hr"), attainment=mix("attainment"),
        drop_rate=mix("drop_rate"), score=(w * scores).sum(axis=0),
        sojourns=[sj for ev in per_trace for sj in ev.sojourns],
        per_trace=list(per_trace))


def _batched_dynamics(scenario: TuningScenario, candidates: list,
                      s0: int, s1: int):
    """Run the whole candidate slate through ONE compiled dispatch chain:
    stack every candidate's kernel params, discipline tables and quota
    bounds, fold the trace portfolio along the seed axis (rows
    ``member * slice + seed``, so K traces ride the same candidate x row
    lattice with no per-trace Python loop), and dispatch. Returns
    ``(out, ctx)`` with the raw dynamics outputs plus everything the host
    needs to assemble per-candidate results, or ``None`` when the slate
    cannot batch (no jax, custom ``build_policy``, a family without a
    kernel)."""
    from repro.fleet import jaxsim
    if not jaxsim.available() or scenario.build_policy is not None:
        return None
    from repro.fleet.simulator import _candidate_arrays, _dynamics_inputs

    members = [_slice_workload(w, s0, s1) for w in scenario.portfolio]
    wl = members[0]
    policies, discs, fleets = [], [], []
    for params in candidates:
        _, disc, fleet = scenario.split_params(params)
        policies.append(scenario.make_policy(params))
        discs.append(disc)
        fleets.append(fleet)
    # same contract as simulate_fleet: a single-target policy cannot drive a
    # multi-pool fleet (broadcasting its target across pools would score a
    # semantically meaningless config instead of failing)
    P = fleets[0].n_pools
    if P > 1 and not getattr(policies[0], "per_pool", False):
        raise ValueError(f"policy {policies[0].name!r} returns a single "
                         f"target; a {P}-pool fleet needs a per-pool policy "
                         "(e.g. HeterogeneousPredictivePolicy)")

    # ring-buffer sizes must be static across the batch AND sticky across
    # racing rounds (a shrinking round must reuse the compiled program)
    windows = [int(p.forecaster.window_bins) for p in policies
               if hasattr(p, "forecaster")]
    # fit-to-usage keeps its own ring buffer (window_bins, no forecaster)
    windows += [int(p.window_bins) for p in policies
                if not hasattr(p, "forecaster") and hasattr(p, "window_bins")]
    sustains = [int(p.sustain.window_bins) for p in policies
                if hasattr(p, "sustain")]
    prev = scenario._batch_windows or (0, 0)
    W = max([prev[0]] + windows) or None
    Ws = max([prev[1]] + sustains) or None
    scenario._batch_windows = (W or 0, Ws or 0)

    template = fleets[0]
    if not hasattr(policies[0], "kernel"):
        return None
    kernel = policies[0].kernel(template, wl.classes,
                                max_window=W, max_sustain=Ws)
    if kernel is None:
        return None
    kp_rows = []
    for pol, fleet in zip(policies, fleets):
        k = pol.kernel(fleet, wl.classes, max_window=W, max_sustain=Ws)
        if k is not kernel:         # mixed families/configs cannot batch
            return None
        kp_rows.append(kernel.params_of(pol))

    if len(members) == 1:
        wl_rows = wl
        cs_rows = scenario._cs_rows(s0, s1)
    else:
        # the portfolio axis folds into the row (seed) axis: per class,
        # concatenate member arrival tensors; rates stay the primary's (they
        # only feed the shared initial-provisioning rate below)
        wl_rows = Workload(wl.name, wl.classes, tuple(
            Trace(tr.name, tr.dt_s, tr.rate,
                  np.concatenate([m.traces[c].arrivals for m in members],
                                 axis=0))
            for c, tr in enumerate(wl.traces)))
        cs = scenario.cold_start_delays()
        S = scenario.n_seeds
        cs_rows = None if cs is None else np.concatenate(
            [cs[k * S + s0:k * S + s1] for k in range(len(members))], axis=0)

    order = template.drain_order()
    tables = [scenario.cohort_tables_for(d) for d in discs]
    rate0 = wl.total_trace().rate[0]
    bounds = [_candidate_arrays(f, order, rate0) for f in fleets]
    max_queue = (template.max_queue if scenario.max_queue is None
                 else scenario.max_queue)
    out = jaxsim.run_dynamics(
        kernel, **_dynamics_inputs(wl_rows, template, order, cs_rows),
        max_queue=max_queue,
        tables={k: np.stack([t[k] for t in tables])
                for k in ("cnt", "cls_of_rank", "drop_rank", "key_of_rank")},
        kp={k: np.array([r[k] for r in kp_rows])
            for k in kernel.param_names},
        min_rep=np.stack([b[0] for b in bounds]),
        max_rep=np.stack([b[1] for b in bounds]),
        init_ready=np.stack([b[2] for b in bounds]),
        n_substeps=scenario.n_substeps, preemptive=scenario.preemptive,
        tile=scenario.tile)
    ctx = {"members": members, "policies": policies, "discs": discs,
           "fleets": fleets, "order": order, "s": s1 - s0}
    return out, ctx


def _assemble_evals(scenario: TuningScenario, out: dict, ctx: dict,
                    candidates: list, objective: Objective,
                    slos: np.ndarray) -> list:
    """Finish each candidate's exact latency accounting on the host, one
    SimResult per (candidate, portfolio member) from its row block of the
    dispatch outputs, then reduce members via the scenario's robust
    objective (a single-trace scenario returns the plain eval — identical
    arrays and evidence to the pre-portfolio path)."""
    from repro.fleet.discipline import get_discipline
    from repro.fleet.simulator import _result_from_dynamics

    members, s = ctx["members"], ctx["s"]
    evals = []
    for i, params in enumerate(candidates):
        disc = get_discipline(ctx["discs"][i])
        per = []
        for k, wlk in enumerate(members):
            sim = _result_from_dynamics(
                wlk, ctx["fleets"][i], disc, ctx["policies"][i].name,
                ctx["order"], slos,
                {key: v[i, k * s:(k + 1) * s] for key, v in out.items()},
                n_substeps=scenario.n_substeps,
                preemptive=scenario.preemptive)
            per.append(_eval_from_sim(params, sim, objective))
        evals.append(per[0] if len(per) == 1
                     else _reduce_portfolio(per, scenario.robust))
    return evals


def _evaluate_batched(scenario: TuningScenario, candidates: list,
                      objective: Objective, s0: int, s1: int):
    """Score the whole candidate slate in ONE jitted dispatch chain (see
    ``_batched_dynamics``); ``None`` when the slate cannot batch."""
    got = _batched_dynamics(scenario, candidates, s0, s1)
    if got is None:
        return None
    out, ctx = got
    return _assemble_evals(scenario, out, ctx, candidates, objective,
                           ctx["members"][0].slos())


def evaluate_candidates_column(scenario: TuningScenario, candidates: list,
                               objective: Objective, slo_values,
                               s0: int = 0, s1: int = None):
    """Score one candidate slate for a whole column of SLO tiers with ONE
    compiled dispatch chain. Sound for single-class workloads only: with one
    request class the SLO never enters the dynamics — policies pop
    ``slo_s`` from their context, and every built-in kernel's SLO read is
    behind a ``n_classes > 1`` guard (``_queue_demand``'s short-circuit,
    the hetero kernel's critical-demand branch) — so tiers share bin-exact
    trajectories and only the host-side exact-latency accounting (which
    requests made their bar) differs. Returns a list of per-tier eval
    lists aligned with ``slo_values``, or ``None`` when the slate cannot
    batch (caller falls back to per-tier evaluation)."""
    s1 = scenario.n_seeds if s1 is None else s1
    if len(scenario.workload.classes) != 1:
        return None
    got = _batched_dynamics(scenario, candidates, s0, s1)
    if got is None:
        return None
    out, ctx = got
    return [_assemble_evals(scenario, out, ctx, candidates, objective,
                            np.array([float(slo)]))
            for slo in slo_values]


def evaluate_candidates(scenario: TuningScenario, candidates: list,
                        objective: Objective, s0: int = 0,
                        s1: int = None, backend: str = None) -> list:
    """Score every candidate on the shared seed slice [s0, s1); identical
    slices across candidates give the paired comparison racing relies on.

    On the numpy backend, one seed-vectorized ``simulate_fleet`` call per
    candidate per portfolio member covers the whole slice. On the jax
    backend the entire candidate slate — every portfolio member included —
    is scored in one jitted candidate x (seed x trace) dispatch chain
    (``_evaluate_batched``); ``"auto"`` batches when the policy family has a
    compiled kernel and falls back to the numpy loop otherwise. ``backend``
    overrides the scenario's own setting. One "sim" is one
    (candidate, seed, trace) trajectory, whichever backend runs it."""
    s1 = scenario.n_seeds if s1 is None else s1
    if not 0 <= s0 < s1 <= scenario.n_seeds:
        raise ValueError(f"bad seed slice [{s0}, {s1}) for "
                         f"{scenario.n_seeds} replicates")
    if not candidates:
        return []
    backend = scenario.backend if backend is None else backend
    if backend not in ("numpy", "jax", "auto"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "expected 'numpy', 'jax' or 'auto'")
    telemetry.counter("tuning_sims_total",
                      len(candidates) * (s1 - s0) * scenario.n_traces,
                      backend=backend)
    if backend != "numpy":
        evals = _evaluate_batched(scenario, candidates, objective, s0, s1)
        if evals is not None:
            return evals
        if backend == "jax":
            from repro.fleet import jaxsim
            if not jaxsim.available():
                raise ValueError("backend='jax' requires jax to be installed "
                                 "(use backend='auto' to fall back to numpy)")
            raise ValueError(
                "backend='jax': this scenario cannot batch (custom "
                "build_policy or a policy family without a compiled "
                "kernel); use backend='auto' to fall back to numpy")
    out = []
    for params in candidates:
        per = [_eval_from_sim(
            params, scenario.simulate(params, s0, s1, backend="numpy",
                                      member=k), objective)
            for k in range(scenario.n_traces)]
        out.append(per[0] if len(per) == 1
                   else _reduce_portfolio(per, scenario.robust))
    return out
